"""Property-based tests (hypothesis) on the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dependency; tier-1 runs without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import analysis
from repro.core.generators import SchedParams, generate
from repro.core.schedules import B, F, W
from repro.core.simulator import CostModel, simulate
from repro.core.tape import Tape, compute_dw

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(
    P=st.sampled_from([2, 3, 4, 6, 8]),
    V=st.integers(1, 3),
    mult=st.integers(1, 3),
    unit_div=st.sampled_from([1, 2, 4]),
    method=st.sampled_from(["gpipe", "1f1b", "bfs", "zeropp",
                            "interleaved"]),
)
def test_any_generated_schedule_is_valid(P, V, mult, unit_div, method):
    """Every generated table satisfies placement, completeness and
    dependency invariants, for arbitrary geometry."""
    n_mb = mult * P
    unit = max(1, n_mb // unit_div)
    split = method == "zeropp"
    tt = generate(method, SchedParams(P=P, V=V, n_mb=n_mb, unit=unit,
                                      split_bw=split))
    tt.validate()
    c = tt.counts()
    assert c["F"] == n_mb * P * V
    if split:
        assert c["W"] == c["B"] == c["F"]


@settings(**SETTINGS)
@given(
    P=st.sampled_from([2, 4]),
    V=st.integers(1, 2),
    mult=st.integers(1, 3),
    t_w=st.floats(0.25, 2.0),
    gather=st.floats(0.0, 1.0),
)
def test_simulator_conservation_and_bounds(P, V, mult, t_w, gather):
    """Busy time is conserved; makespan ≥ critical path lower bound."""
    n_mb = mult * P
    cm = CostModel(t_f=1.0, t_b=2.0, t_w=t_w, t_p2p=0.01,
                   t_gather=gather, t_reduce=gather)
    tt = generate("zeropp", SchedParams(P=P, V=V, n_mb=n_mb))
    r = simulate(tt, cm)
    work = n_mb * V * (cm.t_f + cm.t_b + cm.t_w)
    assert np.allclose(r.busy, work)
    assert r.makespan >= work - 1e-9
    assert r.makespan <= work * (1 + 2.0 * P / max(n_mb, 1)) + \
        r.comm_busy.max() + P * V * (cm.t_f + cm.t_b) + 10 * gather


@settings(**SETTINGS)
@given(
    B_=st.sampled_from([8, 16, 32]),
    P=st.sampled_from([2, 4, 8]),
    V=st.integers(1, 4),
    L_mult=st.integers(1, 4),
)
def test_zeropp_commutes_less_than_fs1f1b(B_, P, V, L_mult):
    """§3.4: FS-ZeroPP's gather count is strictly below FS-1F1B's for any
    geometry with U ≥ 2 (the paper's headline communication claim)."""
    L = P * V * L_mult
    for U in (2, max(2, B_ // 2), B_):
        z = analysis.n_allgather(B=B_, L=L, V=V, U=U, P=P)
        f = 2 * B_ * L / P
        assert z < f


@settings(**SETTINGS)
@given(
    d=st.sampled_from([4, 8, 16]),
    batch=st.integers(1, 3),
    seed=st.integers(0, 10_000),
    depth=st.integers(1, 3),
)
def test_tape_split_backward_matches_jax_grad(d, batch, seed, depth):
    """dx from B plus dW from W equals jax.grad, for random chains."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, depth + 1)
    params = {f"w{i}": jax.random.normal(ks[i], (d, d)) * 0.3
              for i in range(depth)}
    x = jax.random.normal(ks[-1], (batch, d))

    def apply(params, x, mode):
        t = Tape(params, mode=mode)
        v = t.value(x)
        for i in range(depth):
            v = t.dense(v, f"w{i}", "bd,de->be")
            v = t.elementwise(jnp.tanh, v)
        return t, v

    def loss(params, x):
        _, v = apply(params, x, "fwd")
        return jnp.sum(v.val ** 2)

    gp, gx = jax.grad(loss, argnums=(0, 1))(params, x)
    t, out = apply(params, x, "bwd")
    cots, igrads, stash = t.backward({out.idx: 2 * out.val})
    dws = compute_dw(stash)
    np.testing.assert_allclose(cots[1], gx, rtol=1e-4, atol=1e-5)
    for i in range(depth):
        np.testing.assert_allclose(dws[f"w{i}"], gp[f"w{i}"], rtol=1e-4,
                                   atol=1e-5)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([16, 50, 64]),
    d=st.sampled_from([8, 16]),
    vocab=st.sampled_from([40, 128, 200]),
    chunk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 1000),
)
def test_chunked_xent_invariant_to_chunking(n, d, vocab, chunk, seed):
    """ref.softmax_xent must be exactly chunk-size-invariant."""
    from repro.kernels import ref

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h = jax.random.normal(ks[0], (n, d)) * 0.5
    w = jax.random.normal(ks[1], (d, vocab)) * 0.2
    labels = jax.random.randint(ks[2], (n,), 0, vocab)
    l1, (dh1, dw1) = ref.softmax_xent(h, w, labels, chunk=chunk)
    l2, (dh2, dw2) = ref.softmax_xent(h, w, labels, chunk=vocab)
    assert abs(float(l1) - float(l2)) < 1e-5
    np.testing.assert_allclose(dh1, dh2, atol=1e-6)
    np.testing.assert_allclose(dw1, dw2, atol=1e-6)
