"""Multi-device SPMD test cases, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=N (tests must not pollute
the main process's device count).

Usage: python -m tests.spmd_case <case_name> [arch]
Prints "CASE_OK <name>" on success.
"""

import os
import sys

N_DEV = os.environ.get("SPMD_DEVICES", "8")
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

import dataclasses  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.pipeline import Runtime, make_train_step  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.common import ShapeConfig  # noqa: E402


def _mesh(data, model, pod=None):
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def _batch(cfg, gb, seq, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    if cfg.frontend == "vision":
        toks = (jax.random.normal(k1, (gb, seq, cfg.d_model)) * 0.1
                ).astype(jnp.float32)
    else:
        toks = jax.random.randint(k1, (gb, seq), 0, cfg.vocab)
    batch = {"tokens": toks,
             "labels": jax.random.randint(k2, (gb, seq), 0, cfg.vocab)}
    if cfg.encdec is not None:
        batch["enc_tokens"] = (jax.random.normal(
            k3, (gb, cfg.encdec.enc_ctx, cfg.d_model)) * 0.1
        ).astype(jnp.float32)
    return batch


def _ref_grads(cfg, rc, params_ref, batch):
    def loss_fn(p):
        return M.reference_loss(
            cfg, rc, p, batch["tokens"], batch["labels"],
            enc_tokens=batch.get("enc_tokens"))
    return jax.value_and_grad(loss_fn)(params_ref)


def _pipeline_params_from_ref(rt, ref_params):
    """Re-layout reference params into the runtime's duplicated stacking."""
    segs = {}
    for seg in rt.geo.segments:
        st = ref_params["segments"][seg.name]
        V, Pe, G = seg.vpp, rt.Pe, rt.G
        order = []
        for mr in range(G * Pe):
            p = mr % Pe
            for v in range(V):
                order.append(M.storage_index(p, v, V))
        segs[seg.name] = {n: jnp.stack([a[i] for i in order])
                          for n, a in st.items()}
    return {"io": ref_params["io"], "segments": segs}


def _grads_back_to_ref(rt, grads):
    """Undo the duplicated stacking (take group 0's copy)."""
    segs = {}
    for seg in rt.geo.segments:
        V, Pe = seg.vpp, rt.Pe
        g = grads["segments"][seg.name]
        out = {}
        for n, a in g.items():
            rows = []
            for s in range(Pe * V):
                p, v = s % Pe, s // Pe
                # group 0's stacked row for (p, v):
                rows.append(a[p * V + v])
            # reorder into storage order (p-major) used by reference
            reord = [None] * (Pe * V)
            for s in range(Pe * V):
                p, v = s % Pe, s // Pe
                reord[M.storage_index(p, v, V)] = rows[s]
            out[n] = jnp.stack(reord)
        segs[seg.name] = out
    return {"io": grads["io"], "segments": segs}


def case_train_equiv(arch: str, schedule="zeropp", data=None, model=None,
                     pod=None, moe_mode=None):
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    n_dev = int(N_DEV)
    rc = dataclasses.replace(
        rc, schedule=schedule, microbatches=4, unit=2,
        **({"moe_mode": moe_mode} if moe_mode else {}))
    geo = M.build_geometry(cfg, rc)
    model = model or geo.model_ranks
    data = data or max(1, n_dev // ((pod or 1) * model))
    assert (pod or 1) * data * model <= n_dev
    assert geo.model_ranks == model, (geo.model_ranks, model)
    mesh = _mesh(data, model, pod)
    rt = Runtime(cfg, rc, mesh, multi_pod=pod is not None)

    gb = (pod or 1) * data * rc.groups * rc.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)
    ref_params = M.init_all_params(cfg, rc, jax.random.PRNGKey(0))
    loss_ref, gref = _ref_grads(cfg, rc, ref_params, batch)

    from jax.sharding import NamedSharding
    pparams = _pipeline_params_from_ref(rt, ref_params)
    pparams = jax.tree.map(
        lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec)),
        pparams,
        {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]})

    shape_cfg = ShapeConfig("toy", seq, gb, "train")
    step = make_train_step(rt, shape_cfg)
    grads, metrics = step(pparams, batch)
    # loss_and_dy returns per-microbatch losses already divided by the
    # global token count, so loss_sum is the mean xent.
    loss_pipe = float(metrics["loss_sum"])
    # compare the xent part of the loss
    ref_xent = float(loss_ref)
    if cfg.moe is not None:
        # recompute reference aux to subtract
        logits, aux = M.reference_logits(
            cfg, rc, ref_params, batch["tokens"],
            enc_tokens=batch.get("enc_tokens"))
        ref_xent = ref_xent - cfg.moe.router_aux_weight * float(aux)
    assert abs(loss_pipe - ref_xent) < 5e-3 * max(1.0, abs(ref_xent)), (
        loss_pipe, ref_xent)

    gpipe = _grads_back_to_ref(rt, jax.device_get(grads))
    flat_r = jax.tree_util.tree_flatten_with_path(gref)[0]
    flat_p = dict(jax.tree_util.tree_flatten_with_path(gpipe)[0])
    n_checked = 0
    worst = (0.0, None)
    worst_router = (0.0, None)
    for kp, vr in flat_r:
        vp = flat_p[kp]
        vr = np.asarray(vr, np.float32)
        vp = np.asarray(vp, np.float32)
        assert vr.shape == vp.shape, (kp, vr.shape, vp.shape)
        denom = np.maximum(np.abs(vr).max(), 1e-6)
        err = np.abs(vr - vp).max() / denom
        # MoE routers: the Switch aux loss is a *product of batch means*,
        # so per-microbatch aux (pipeline) differs from full-batch aux
        # (reference) by O(1/B) — expected, weight 0.01, router-only.
        if "router" in jax.tree_util.keystr(kp):
            if err > worst_router[0]:
                worst_router = (err, jax.tree_util.keystr(kp))
            n_checked += 1
            continue
        if err > worst[0]:
            worst = (err, jax.tree_util.keystr(kp))
        n_checked += 1
    assert worst[0] < 3e-2, f"grad mismatch {worst}"
    assert worst_router[0] < 8e-2, f"router mismatch {worst_router}"
    print(f"  checked {n_checked} tensors, worst rel err "
          f"{worst[0]:.2e} at {worst[1]}")
    print(f"CASE_OK train_equiv {arch} {schedule}")


def case_loss_decreases(arch: str):
    """Few pipeline SGD steps must reduce the loss."""
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, microbatches=4, unit=4)
    geo = M.build_geometry(cfg, rc)
    mesh = _mesh(2, geo.model_ranks)
    rt = Runtime(cfg, rc, mesh)
    gb = 2 * rc.groups * rc.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)
    params = rt.init_params(jax.random.PRNGKey(0))
    shape_cfg = ShapeConfig("toy", seq, gb, "train")
    step = make_train_step(rt, shape_cfg)
    losses = []
    lr = 0.1 if not (cfg.mamba or cfg.xlstm) else 0.03
    for i in range(6):
        grads, metrics = step(params, batch)
        losses.append(float(metrics["loss_sum"]))
        params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads)
    assert losses[-1] < losses[0], losses
    print(f"  losses: {[round(l, 3) for l in losses]}")
    print(f"CASE_OK loss_decreases {arch}")


CASES = {
    "train_equiv": case_train_equiv,
    "loss_decreases": case_loss_decreases,
}



def case_serve_decode(arch: str):
    """Prefill + greedy decode through the pipeline must match the
    reference model's greedy continuation."""
    from repro.core.pipeline import make_serve_step, init_serve_caches
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, microbatches=2)
    geo = M.build_geometry(cfg, rc)
    n_dev = int(N_DEV)
    model = geo.model_ranks
    data = max(1, n_dev // model)
    mesh = _mesh(data, model)
    rt = Runtime(cfg, rc, mesh)
    gb = data * rc.groups * rc.microbatches
    prompt, gen, max_seq = 8, 4, 32
    shape_cfg = ShapeConfig("toy", max_seq, gb, "decode")

    ref_params = M.init_all_params(cfg, rc, jax.random.PRNGKey(0))
    batch0 = _batch(cfg, gb, prompt)
    toks = batch0["tokens"]
    enc = batch0.get("enc_tokens")

    # reference greedy continuation (re-run full forward each step)
    ref_seq = toks
    for i in range(gen + 1):
        logits, _ = M.reference_logits(cfg, rc, ref_params, ref_seq,
                                       enc_tokens=enc)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)
        ref_seq = jnp.concatenate([ref_seq, nxt[:, None].astype(jnp.int32)],
                                  axis=1)
    ref_gen = np.asarray(ref_seq[:, prompt:])

    from jax.sharding import NamedSharding
    pparams = _pipeline_params_from_ref(rt, ref_params)
    pparams = jax.tree.map(
        lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec)),
        pparams, {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]})
    caches = jax.tree.map(
        lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype), s.sharding),
        init_serve_caches(rt, shape_cfg, max_seq=max_seq),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if cfg.encdec is not None:
        # precompute encoder memory with the reference encoder
        geo2 = M.build_geometry(cfg, rc)
        mem = enc
        seg_e = geo2.segments[0]
        from repro.core.tape import Tape
        stacked = ref_params["segments"]["enc"]
        from repro.models import blocks as B
        x = jnp.asarray(enc, jnp.float32)
        for s_ in range(geo2.seg_stages(seg_e)):
            p_, v_ = s_ % geo2.pp, s_ // geo2.pp
            idx = M.storage_index(p_, v_, seg_e.vpp)
            sp_ = {n: a[idx] for n, a in stacked.items()}
            t = Tape(sp_, mode="fwd")
            rope, _ = M.make_rope_ctx(cfg, rc, x.shape[1])
            ctx = B.LayerCtx(cfg=cfg, rc=rc, rope=rope, causal=False)
            xv, _ = M.apply_stage(t, ctx, seg_e, t.value(x), s_)
            x = xv.val
        caches["enc_memory"] = jax.device_put(
            x.astype(jnp.dtype(rc.compute_dtype)),
            NamedSharding(mesh, jax.tree.leaves(
                __import__("repro.core.pipeline", fromlist=["x"]
                           ).serve_cache_pspecs(rt, shape_cfg)[0][
                               "enc_memory"])[0]
                if False else NamedSharding(mesh, jax.sharding.PartitionSpec())))

    prefill = make_serve_step(rt, shape_cfg, prompt_len=prompt,
                              max_seq=max_seq)
    tok, caches = prefill(pparams, caches, {"tokens": toks,
                                            "pos": jnp.int32(0)})
    got = [np.asarray(tok)]
    decode = make_serve_step(rt, shape_cfg, prompt_len=1, max_seq=max_seq)
    cur = tok[:, None]
    for i in range(gen):
        cur, caches = decode(pparams, caches,
                             {"tokens": cur, "pos": jnp.int32(prompt + i)})
        cur = cur[:, None]
        got.append(np.asarray(cur[:, 0]))
    got = np.stack(got, axis=1)
    match = (got == ref_gen).mean()
    assert match > 0.9, (match, got[:2], ref_gen[:2])
    print(f"  greedy continuation agreement: {match:.2%}")
    print(f"CASE_OK serve_decode {arch}")


CASES["serve_decode"] = case_serve_decode




def case_hlo_gather_count(arch: str = "llama3.2-1b"):
    """Structural claim (§3.3): the lowered FS-ZeroPP step contains ONE
    conditional all-gather site per gatherable stage param executed
    (2V-1)·units times, vs FS-1F1B-style per-microbatch gathering — we
    verify the executor's gather events match #AllGather = B·L·(2V-1)/(U·P·V)
    and that the compiled HLO contains the gather/reduce collectives."""
    import re
    from repro.core.pipeline import Runtime, make_train_step
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, microbatches=8, unit=4)
    geo = M.build_geometry(cfg, rc)
    mesh = _mesh(2, geo.model_ranks)
    rt = Runtime(cfg, rc, mesh)
    pt = rt.tables["main"]
    V, U, B = rc.vpp, rc.unit_size, rc.microbatches
    n_units = B // U
    per_rank = (pt.gather_v >= 0).sum() / pt.Pe
    assert per_rank == (2 * V - 1) * n_units, (per_rank, V, n_units)
    # paper formula in layer-gathers (k layers per stage):
    k = geo.segments[0].k
    L = geo.padded_layers(geo.segments[0])
    expect = B * L * (2 * V - 1) / (U * rc.pp * V)
    assert per_rank * k == expect, (per_rank, k, expect)

    gb = 2 * rc.groups * rc.microbatches
    shape_cfg = ShapeConfig("toy", 16, gb, "train")
    step = make_train_step(rt, shape_cfg)
    params = rt.param_shapes()
    batch = {
        "tokens": jax.ShapeDtypeStruct((gb, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((gb, 16), jnp.int32),
    }
    txt = step.lower(params, batch).compile().as_text()
    ops = set(re.findall(
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)", txt))
    assert "all-gather" in ops, ops          # FSDP param gathers
    assert "collective-permute" in ops, ops  # pipeline wires
    assert ("reduce-scatter" in ops) or ("all-reduce" in ops), ops
    print(f"  gathers/rank={per_rank:.0f} (= (2V-1)·units), HLO ops: "
          f"{sorted(ops)}")
    print(f"CASE_OK hlo_gather_count {arch}")


CASES["hlo_gather_count"] = case_hlo_gather_count




def case_prefetch_equiv(arch: str = "llama3.2-1b"):
    """gather_prefetch must not change numerics, only HLO issue order."""
    case_train_equiv_with(arch, {"gather_prefetch": 2})
    print(f"CASE_OK prefetch_equiv {arch}")


def case_int8_grads(arch: str = "llama3.2-1b"):
    """int8 reduce-scatter with shared-scale summation: grads within 2%
    of fp32, and still optimizes."""
    from repro.core import fsdp as F
    from repro.models.common import ParamSpec
    from jax.sharding import PartitionSpec as P
    mesh = _mesh(4, 2)
    spec = ParamSpec((32, 16), fsdp_dim=0)
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 16)) * 0.1

    def body(gl):
        full = F.reduce_scatter_grad(gl[0], spec, 4, False)
        err0 = jnp.zeros_like(gl[0])
        q, err = F.reduce_scatter_grad_int8(gl[0], err0, spec, 4, False)
        return full, q, err

    from repro.core import fsdp as _fsdp
    f = _fsdp.shard_map(body, mesh=mesh,
                        in_specs=(P(None, "data"),),
                        out_specs=(P("data"), P("data"), P(None, "data")),
                        check_vma=False)
    # feed each data rank a *different* gradient contribution
    gs = g.transpose(1, 0, 2).reshape(1, 32, 4 * 16)[..., :16 * 4]
    full, q, err = jax.jit(f)(g.sum(0)[None].repeat(4, 0).reshape(
        1, 32 * 4, 16)[:, :32] if False else g.reshape(1, 4 * 32, 16)[:, :32])
    # simpler: single shared grad; int8 must match fp32 closely
    rel = float(jnp.abs(q - full).max() / jnp.abs(full).max())
    assert rel < 0.02, rel
    assert float(jnp.abs(err).max()) < 0.01  # error feedback bounded
    print(f"  int8 vs fp32 rel err {rel:.4f}")
    print(f"CASE_OK int8_grads {arch}")


def case_train_equiv_with(arch, extra_rc):
    """train_equiv with extra RunConfig overrides."""
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, schedule="zeropp", microbatches=4,
                             unit=2, **extra_rc)
    geo = M.build_geometry(cfg, rc)
    model = geo.model_ranks
    data = max(1, int(N_DEV) // model)
    mesh = _mesh(data, model)
    rt = Runtime(cfg, rc, mesh)
    gb = data * rc.groups * rc.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)
    ref_params = M.init_all_params(cfg, rc, jax.random.PRNGKey(0))
    loss_ref, gref = _ref_grads(cfg, rc, ref_params, batch)
    from jax.sharding import NamedSharding
    pparams = _pipeline_params_from_ref(rt, ref_params)
    pparams = jax.tree.map(
        lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec)),
        pparams, {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]})
    step = make_train_step(rt, ShapeConfig("toy", seq, gb, "train"))
    grads, metrics = step(pparams, batch)
    gpipe = _grads_back_to_ref(rt, jax.device_get(grads))
    flat_r = jax.tree_util.tree_flatten_with_path(gref)[0]
    flat_p = dict(jax.tree_util.tree_flatten_with_path(gpipe)[0])
    worst = 0.0
    for kp, vr in flat_r:
        vp = flat_p[kp]
        vr = np.asarray(vr, np.float32)
        vp = np.asarray(vp, np.float32)
        worst = max(worst, float(
            np.abs(vr - vp).max() / max(np.abs(vr).max(), 1e-6)))
    assert worst < 3e-2, worst
    print(f"  worst rel err {worst:.2e}")


def case_elastic_reshard(arch: str = "llama3.2-1b"):
    """Checkpoint at D=4, restore + continue at D=2 (elastic re-mesh)."""
    import tempfile
    from repro.ckpt.checkpoint import CheckpointManager
    from jax.sharding import NamedSharding
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, microbatches=4, unit=4)
    geo = M.build_geometry(cfg, rc)
    seq = 16

    def run(data, params_in=None, steps=2, seed=0):
        mesh = _mesh(data, geo.model_ranks)
        rt = Runtime(cfg, rc, mesh)
        gb = data * rc.groups * rc.microbatches
        step = make_train_step(rt, ShapeConfig("t", seq, gb, "train"))
        params = params_in if params_in is not None else rt.init_params(
            jax.random.PRNGKey(seed))
        shardings = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec),
            {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]})
        params = jax.tree.map(lambda a, sh: jax.device_put(
            jnp.asarray(a), sh), params, shardings)
        losses = []
        for s_ in range(steps):
            batch = _batch(cfg, gb, seq, seed=s_)
            grads, metrics = step(params, batch)
            losses.append(float(metrics["loss_sum"]))
            params = jax.tree.map(
                lambda p, g: (p - 0.1 * g.astype(p.dtype)).astype(p.dtype),
                params, grads)
        return params, losses, shardings

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        params4, losses4, _ = run(4, steps=2)
        saved = jax.device_get(params4)
        mgr.save(2, saved)
        tree, manifest = mgr.restore(2)
        # restore must be exact: the round-trip is the deterministic
        # invariant (the loss comparison below has run-to-run XLA noise)
        flat_s = jax.tree_util.tree_flatten_with_path(saved)[0]
        flat_r = dict(jax.tree_util.tree_flatten_with_path(tree)[0])
        for kp, vs in flat_s:
            assert np.array_equal(np.asarray(vs), np.asarray(flat_r[kp])), (
                f"restore mismatch at {jax.tree_util.keystr(kp)}")
        # resume on HALF the data axis (elastic shrink). The D=2 batch
        # is a different draw (gb halves), so 2 SGD steps of D=4
        # progress give no reliable loss-direction signal on it — the
        # robust invariants are: the restored params are actually used
        # (first-step loss deterministically differs from a fresh
        # PRNGKey(0) init on the same batch/mesh/program) and training
        # continues finitely from them.
        _, losses_fresh, _ = run(2, steps=1)
        params2, losses2, _ = run(2, params_in=tree, steps=2)
        assert losses2[0] != losses_fresh[0], (
            "resume ignored the restored params", losses_fresh, losses2)
        assert all(np.isfinite(l) for l in losses2), losses2
        assert abs(losses2[0] - losses_fresh[0]) < 1.0, (
            "resumed loss implausibly far from the trained state",
            losses_fresh, losses2)
    print(f"  D=4 losses {losses4} -> D=2 fresh {losses_fresh[0]:.4f} "
          f"vs resume losses {losses2}")
    print(f"CASE_OK elastic_reshard {arch}")


def case_api_parity(arch: str = "llama3.2-1b"):
    """repro.api.session must reproduce the hand-assembled path exactly:
    same params from the same key, allclose grads and metrics."""
    from repro.api import session

    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, microbatches=4, unit=2)
    geo = M.build_geometry(cfg, rc)
    model = geo.model_ranks
    data = max(1, int(N_DEV) // model)
    mesh = _mesh(data, model)
    rt = Runtime(cfg, rc, mesh)
    gb = data * rc.groups * rc.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)

    # hand-assembled path (the old 8-step ritual)
    params_h = rt.init_params(jax.random.PRNGKey(0))
    step = make_train_step(rt, ShapeConfig("toy", seq, gb, "train"))
    g_h, m_h = step(params_h, batch)

    # facade path
    sess = session(arch, overrides=dict(microbatches=4, unit=2),
                   data=data, seq_len=seq)
    assert sess.shape_cfg.global_batch == gb, (
        sess.shape_cfg.global_batch, gb)
    params_f = sess.init_params(jax.random.PRNGKey(0))
    g_f, m_f = sess.train_step(params_f, batch)

    for kp, vh in jax.tree_util.tree_flatten_with_path(params_h)[0]:
        vf = dict(jax.tree_util.tree_flatten_with_path(params_f)[0])[kp]
        assert np.array_equal(np.asarray(vh), np.asarray(vf)), (
            f"param mismatch at {jax.tree_util.keystr(kp)}")
    worst = (0.0, None)
    flat_f = dict(jax.tree_util.tree_flatten_with_path(g_f)[0])
    n = 0
    for kp, vh in jax.tree_util.tree_flatten_with_path(g_h)[0]:
        vh = np.asarray(vh, np.float32)
        vf = np.asarray(flat_f[kp], np.float32)
        assert vh.shape == vf.shape, (kp, vh.shape, vf.shape)
        err = np.abs(vh - vf).max() / max(np.abs(vh).max(), 1e-6)
        if err > worst[0]:
            worst = (err, jax.tree_util.keystr(kp))
        n += 1
    assert worst[0] < 1e-5, f"grad mismatch {worst}"
    assert np.allclose(float(m_h["loss_sum"]), float(m_f["loss_sum"]),
                       rtol=1e-6), (m_h, m_f)
    print(f"  {n} grad tensors allclose (worst rel err {worst[0]:.2e}); "
          f"loss {float(m_f['loss_sum']):.5f}")
    print(f"CASE_OK api_parity {arch}")


def case_auto_schedule(arch: str = "llama3.2-1b"):
    """schedule="auto" end-to-end: the session must pick the plan with
    the minimum simulated makespan among every registered schedule, then
    train AND serve with it on the fake-device mesh."""
    from repro.api import session
    from repro.core.plan import PlanAnalysis

    mod = M.get_arch(arch)
    cfg, rc0 = mod.reduced()
    geo = M.build_geometry(cfg, dataclasses.replace(rc0, microbatches=4,
                                                    unit=2))
    data = max(1, int(N_DEV) // geo.model_ranks)

    sess = session(arch, schedule="auto", data=data, seq_len=16,
                   overrides=dict(microbatches=4, unit=2))
    sel = sess.plan_selection
    assert sel is not None
    span = {n: a.makespan for n, a in sel.candidates.items()
            if isinstance(a, PlanAnalysis)}
    assert len(span) >= 5, span  # all builtins (+ autogen) simulated
    for n, m in span.items():
        assert sel.analysis.makespan <= m + 1e-12, (
            f"selected {sel.selected.name} ({sel.analysis.makespan}) "
            f"worse than {n} ({m})")
    assert sess.rc.schedule == sel.selected.name
    d = sess.describe()
    assert d["schedule"]["auto"]["selected"] == sel.selected.name
    assert d["schedule"]["preset"] in ("a800", "tpu_v5e")

    # train: two steps must run and reduce the loss direction-agnostically
    params = sess.init_params(jax.random.PRNGKey(0))
    batch = sess.stream().batch(0)
    grads, metrics = sess.train_step(params, batch)
    loss = float(metrics["loss_sum"])
    assert np.isfinite(loss), loss
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)

    # serve: prefill + decode through an auto-scheduled serve session
    sess_s = session(arch, mode="serve", schedule="auto", data=data,
                     global_batch=data * rc0.groups * 2, max_seq=24,
                     overrides=dict(microbatches=2))
    params_s = sess_s.init_params(jax.random.PRNGKey(0))
    caches = jax.tree.map(
        lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype), s.sharding),
        sess_s.init_caches(abstract=True),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    gb_s = sess_s.shape_cfg.global_batch
    toks = jax.random.randint(jax.random.PRNGKey(3), (gb_s, 8), 0,
                              cfg.vocab)
    tok, caches = sess_s.serve_prefill(params_s, caches,
                                       {"tokens": toks,
                                        "pos": jnp.int32(0)})
    tok2, caches = sess_s.serve_decode(params_s, caches,
                                       {"tokens": tok[:, None],
                                        "pos": jnp.int32(8)})
    assert tok2.shape == (gb_s,)
    assert (np.asarray(tok2) >= 0).all()
    print(f"  selected={sel.selected.name} "
          f"makespan={sel.analysis.makespan:.3e} "
          f"candidates={sorted(span, key=span.get)} loss={loss:.4f}")
    print(f"CASE_OK auto_schedule {arch}")


def case_serving_engine_equiv(arch: str = "llama3.2-1b"):
    """Continuous-batching correctness bar: engine output for 8 staggered
    requests through 4 slots must be bit-identical to 8 independent
    single-request serve_prefill/serve_decode runs. Slots are reclaimed
    and refilled mid-decode (8 requests > 4 slots, staggered lengths),
    so this also covers reset + reuse."""
    from repro.api import session

    sess = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                   overrides=dict(microbatches=2))
    params = sess.init_params(jax.random.PRNGKey(0))
    vocab = sess.cfg.vocab
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, vocab, size=n).astype(np.int32)
               for n in (3, 8, 5, 11, 4, 7, 9, 6)]  # staggered lengths
    gens = [4, 2, 6, 3, 5, 2, 4, 6]

    # reference: each request alone, via the legacy scalar-pos API
    # (prompt broadcast to every row; row 0 is the request)
    def ref_run(prompt, max_gen):
        c = sess.init_caches(abstract=False)
        toks = jnp.asarray(np.tile(prompt[None], (sess.max_slots, 1)))
        t, c = sess.serve_prefill(params, c, {"tokens": toks,
                                              "pos": jnp.int32(0)})
        out = [int(np.asarray(t)[0])]
        cur = t[:, None]
        for i in range(max_gen - 1):
            cur, c = sess.serve_decode(
                params, c,
                {"tokens": cur, "pos": jnp.int32(len(prompt) + i)})
            out.append(int(np.asarray(cur)[0]))
            cur = cur[:, None]
        return out

    refs = [ref_run(p, g) for p, g in zip(prompts, gens)]

    eng = sess.serve_engine(params)
    handles = []
    for i, (p, g) in enumerate(zip(prompts, gens)):
        handles.append(eng.submit(p, max_gen=g))
        if i % 3 == 2:
            eng.step()  # stagger admission so reclaim interleaves
    eng.run_until_idle()
    got = [h.result(timeout=5) for h in handles]
    for i, (r, g) in enumerate(zip(refs, got)):
        assert r == g, f"request {i}: engine {g} != sequential {r}"
    st = eng.stats
    assert st.finished_requests == len(prompts)
    assert st.generated_tokens == sum(len(r) for r in refs)
    # 8 requests through 4 slots forces reclaim+refill mid-decode
    assert st.decode_steps < sum(gens), (st.decode_steps, sum(gens))
    print(f"  8 staggered requests bit-identical through 4 slots "
          f"({st.decode_steps} decode ticks, occupancy "
          f"{st.occupancy:.2f})")

    # an untileable slot count (6 slots -> 3 rows/shard, tiled 2) must be
    # rejected up front, not silently drop rows
    from repro.api import SessionError
    sess_bad = session(arch, mode="serve", data=2, max_slots=6,
                       max_seq=24, overrides=dict(microbatches=2))
    try:
        sess_bad.serve_engine(params)
    except SessionError as e:
        assert "covering only" in str(e), e
    else:
        raise AssertionError("untileable max_slots=6 was accepted")

    # chunked prefill must not change tokens either
    sess_c = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                     prefill_chunk=3, overrides=dict(microbatches=2))
    eng_c = sess_c.serve_engine(params)
    hs = [eng_c.submit(p, max_gen=g) for p, g in zip(prompts, gens)]
    eng_c.run_until_idle()
    for i, (r, h) in enumerate(zip(refs, hs)):
        assert h.result(timeout=5) == r, f"chunked prefill diverged at {i}"
    assert eng_c.stats.prefill_steps > len(prompts)  # actually chunked
    print(f"  prefill_chunk=3 identical "
          f"({eng_c.stats.prefill_steps} prefill steps)")
    print(f"CASE_OK serving_engine_equiv {arch}")


CASES["serving_engine_equiv"] = case_serving_engine_equiv


def case_serving_paged_equiv(arch: str = "llama3.2-1b"):
    """Paged-KV correctness bar: the paged engine (radix sharing on) is
    token-identical to the contiguous engine on a staggered 8-request
    greedy workload, while a shared-system-prompt workload prefills
    fewer tokens than requests×prompt_len (radix hits) and never holds
    pages beyond the contiguous n_slots×max_seq footprint."""
    from repro.api import session

    sess = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                   overrides=dict(microbatches=2))
    params = sess.init_params(jax.random.PRNGKey(0))
    vocab = sess.cfg.vocab
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, vocab, size=n).astype(np.int32)
               for n in (3, 8, 5, 11, 4, 7, 9, 6)]  # staggered lengths
    gens = [4, 2, 6, 3, 5, 2, 4, 6]

    def run(s, ps):
        eng = s.serve_engine(ps)
        handles = []
        for i, (p, g) in enumerate(zip(prompts, gens)):
            handles.append(eng.submit(p, max_gen=g))
            if i % 3 == 2:
                eng.step()  # stagger admission so reclaim interleaves
        eng.run_until_idle()
        return [h.result(timeout=5) for h in handles], eng.stats

    refs, _ = run(sess, params)

    sess_p = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                     page_size=4, overrides=dict(microbatches=2))
    got, st = run(sess_p, params)
    for i, (r, g) in enumerate(zip(refs, got)):
        assert r == g, f"request {i}: paged {g} != contiguous {r}"
    # the page arena never exceeds the contiguous per-slot footprint
    ppr = 24 // 4
    assert st.peak_pages_in_use < sess.max_slots * ppr, st
    print(f"  8 staggered requests token-identical paged vs contiguous "
          f"(peak pages {st.peak_pages_in_use} < {sess.max_slots * ppr})")

    # shared system prompt: later requests resume prefill mid-prompt
    sys_prompt = rng.randint(0, vocab, size=12).astype(np.int32)
    shared = [np.concatenate([sys_prompt,
                              rng.randint(0, vocab, size=3).astype(
                                  np.int32)])
              for _ in range(6)]
    eng = sess_p.serve_engine(params)
    hs = [eng.submit(p, max_gen=3) for p in shared]
    eng.run_until_idle()
    outs = [h.result(timeout=5) for h in hs]
    st = eng.stats
    assert st.prefix_hits > 0, "no radix hits on a shared prompt"
    assert st.prefix_hit_tokens > 0, st
    total = sum(len(p) for p in shared)
    assert st.prefill_tokens < total, (st.prefill_tokens, total)
    # shared-prefix outputs must match a fresh contiguous run too
    eng_c = sess.serve_engine(params)
    hc = [eng_c.submit(p, max_gen=3) for p in shared]
    eng_c.run_until_idle()
    for i, h in enumerate(hc):
        assert h.result(timeout=5) == outs[i], f"shared-prefix req {i}"
    print(f"  shared prompt: {st.prefix_hits} hits, "
          f"{st.prefix_hit_tokens} cached tokens, prefilled "
          f"{st.prefill_tokens}/{total} prompt tokens")
    # no leaked refs: with every request finished, only the radix holds
    # pages — refcount exactly 1 on each live page (a stuck copy-source
    # pin or an unreturned request ref would show up as 2+)
    pp = eng.pool.pool
    live = [g for g in range(pp.n_pages) if pp.refcount(g) > 0]
    assert live, "shared prefix left nothing cached"
    bad = {g: pp.refcount(g) for g in live if pp.refcount(g) != 1}
    assert not bad, f"leaked page references: {bad}"

    # prefix_sharing='off' escape hatch still decodes identically
    sess_o = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                     page_size=4, prefix_sharing="off",
                     overrides=dict(microbatches=2))
    eng_o = sess_o.serve_engine(params)
    ho = [eng_o.submit(p, max_gen=3) for p in shared]
    eng_o.run_until_idle()
    for i, h in enumerate(ho):
        assert h.result(timeout=5) == outs[i], f"sharing-off req {i}"
    assert eng_o.stats.prefix_hits == 0
    print("  prefix_sharing='off' identical, zero hits")

    # int8 quantized pages: per-page scales ride beside the pool and the
    # dequantized greedy decode stays token-identical to the contiguous
    # run within the same kernel implementation
    sess_q = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                     page_size=4, kv_cache_dtype="int8",
                     overrides=dict(microbatches=2))
    got_q, st_q = run(sess_q, params)
    for i, (r, g) in enumerate(zip(refs, got_q)):
        assert r == g, f"request {i}: int8 paged {g} != contiguous {r}"
    kv = sess_q.init_caches()
    leaves = jax.tree_util.tree_leaves_with_path(kv)
    assert any("_scale" in jax.tree_util.keystr(p) for p, _ in leaves), \
        "int8 cache tree carries no scale leaves"
    assert all(l.dtype == jnp.int8 for p, l in leaves
               if jax.tree_util.keystr(p).endswith(("k']", "v']"))
               and "_scale" not in jax.tree_util.keystr(p)), leaves
    print(f"  kv_cache_dtype='int8' token-identical "
          f"(peak pages {st_q.peak_pages_in_use})")

    # explicit Pallas: the slot-aware paged kernel (interpret mode on
    # CPU) must actually be exercised — no ref.attention fallback — and
    # contiguous-Pallas vs paged-Pallas stay token-identical
    from repro.kernels import ops as kops
    p3, g3 = prompts[:3], [2, 2, 2]

    def run3(s):
        eng3 = s.serve_engine(params)
        hs3 = [eng3.submit(p, max_gen=g) for p, g in zip(p3, g3)]
        eng3.run_until_idle()
        return [h.result(timeout=60) for h in hs3]

    sess_cp = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                      overrides=dict(microbatches=2,
                                     kernel_impl="pallas"))
    ref_pal = run3(sess_cp)
    rep = sess_cp.describe()["kernels"]
    assert rep["counters"].get("pallas_slotted", 0) > 0, rep
    assert not rep["fallbacks"], rep
    sess_pp = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                      page_size=4,
                      overrides=dict(microbatches=2,
                                     kernel_impl="pallas"))
    got_pal = run3(sess_pp)
    for i, (r, g) in enumerate(zip(ref_pal, got_pal)):
        assert r == g, f"request {i}: pallas paged {g} != contiguous {r}"
    rep = sess_pp.describe()["kernels"]
    assert rep["counters"].get("pallas_paged", 0) > 0, rep
    assert rep["counters"].get("fallback_attention_ref", 0) == 0, rep
    assert kops.kernel_counters().get("pallas_paged", 0) > 0
    print("  kernel_impl='pallas': paged kernel exercised, "
          "token-identical to contiguous Pallas, zero fallbacks")
    print(f"CASE_OK serving_paged_equiv {arch}")


CASES["serving_paged_equiv"] = case_serving_paged_equiv


def case_serve_handoff(arch: str = "llama3.2-1b"):
    """Train→serve handoff: a serve session booted from a train
    checkpoint (Session.restore_params, different data axis) must serve
    the exact tokens of a session holding the trained params directly."""
    import tempfile
    from repro.api import session
    from repro.ckpt.checkpoint import CheckpointManager

    tr = session(arch, data=4, seq_len=16,
                 overrides=dict(microbatches=4, unit=2))
    params = tr.init_params(jax.random.PRNGKey(0))
    opt = tr.init_opt_state(params)
    for i in range(2):
        grads, _ = tr.train_step(params, tr.stream().batch(i))
        params, opt, _ = tr.opt_step(params, grads, opt)

    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, tr.cfg.vocab, size=n).astype(np.int32)
               for n in (5, 8, 3, 6)]

    def serve_tokens(sess, ps):
        eng = sess.serve_engine(ps)
        hs = [eng.submit(p, max_gen=4) for p in prompts]
        eng.run_until_idle()
        return [h.result(timeout=5) for h in hs]

    with tempfile.TemporaryDirectory() as d:
        # the fault-tolerance controller's usual state layout
        CheckpointManager(d).save(
            7, {"params": jax.device_get(params), "opt_step": 7})
        sv = session(arch, mode="serve", data=2, max_slots=4, max_seq=16,
                     overrides=dict(microbatches=2))
        restored = sv.restore_params(d)
        # bit-exact round-trip of every leaf
        flat_a = jax.tree_util.tree_flatten_with_path(
            jax.device_get(params))[0]
        flat_b = dict(jax.tree_util.tree_flatten_with_path(
            jax.device_get(restored))[0])
        for kp, va in flat_a:
            assert np.array_equal(
                np.asarray(va), np.asarray(flat_b[kp])), (
                f"handoff round-trip differs at "
                f"{jax.tree_util.keystr(kp)}")
        # the trained params must differ from a fresh init — otherwise
        # the token comparison below would be vacuous. (Param-level, not
        # token-level: greedy argmax ties flip under cross-process
        # CPU-XLA noise, see the elastic_reshard deflake.)
        flat_fresh = dict(jax.tree_util.tree_flatten_with_path(
            jax.device_get(sv.init_params(jax.random.PRNGKey(0))))[0])
        assert any(
            not np.array_equal(np.asarray(va), np.asarray(flat_fresh[kp]))
            for kp, va in flat_a), "training left params at their init"
        # transplant the trained params directly (no disk) as reference
        sv2 = session(arch, mode="serve", data=2, max_slots=4,
                      max_seq=16, overrides=dict(microbatches=2))
        want = serve_tokens(sv2, jax.tree.map(jnp.asarray,
                                              jax.device_get(params)))
        got = serve_tokens(sv, restored)
        assert got == want, (got, want)
    print(f"  ckpt->serve tokens match direct transplant for "
          f"{len(prompts)} requests")
    print(f"CASE_OK serve_handoff {arch}")


CASES["serve_handoff"] = case_serve_handoff


def _golden_path():
    return os.path.join(os.path.dirname(__file__), "golden",
                        "pipeline_llama3p2_1b.npz")


def _golden_outputs(arch: str = "llama3.2-1b"):
    """Deterministic train grads/metrics + serve tokens for one config."""
    from repro.core.pipeline import make_serve_step, init_serve_caches
    from jax.sharding import NamedSharding

    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, schedule="zeropp", microbatches=4, unit=2)
    geo = M.build_geometry(cfg, rc)
    data = max(1, int(N_DEV) // geo.model_ranks)
    mesh = _mesh(data, geo.model_ranks)
    rt = Runtime(cfg, rc, mesh)
    gb = data * rc.groups * rc.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)
    params = rt.init_params(jax.random.PRNGKey(0))
    step = make_train_step(rt, ShapeConfig("toy", seq, gb, "train"))
    grads, metrics = step(params, batch)

    out = {}
    for kp, v in jax.tree_util.tree_flatten_with_path(
            jax.device_get(grads))[0]:
        out["grad:" + jax.tree_util.keystr(kp)] = np.asarray(v)
    for k, v in jax.device_get(metrics).items():
        out["metric:" + k] = np.asarray(v)

    # serve path: prefill + 2 decode steps on a fresh serve runtime
    rc_s = dataclasses.replace(rc, microbatches=2)
    rt_s = Runtime(cfg, rc_s, mesh)
    gb_s = data * rc_s.groups * rc_s.microbatches
    prompt, max_seq = 8, 16
    shape_s = ShapeConfig("toy", max_seq, gb_s, "decode")
    params_s = rt_s.init_params(jax.random.PRNGKey(0))
    caches = jax.tree.map(
        lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype), s.sharding),
        init_serve_caches(rt_s, shape_s, max_seq=max_seq),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    toks = jax.random.randint(jax.random.PRNGKey(7), (gb_s, prompt), 0,
                              cfg.vocab)
    prefill = make_serve_step(rt_s, shape_s, prompt_len=prompt,
                              max_seq=max_seq)
    tok, caches = prefill(params_s, caches, {"tokens": toks,
                                             "pos": jnp.int32(0)})
    serve_toks = [np.asarray(tok)]
    decode = make_serve_step(rt_s, shape_s, prompt_len=1, max_seq=max_seq)
    cur = tok[:, None]
    for i in range(2):
        cur, caches = decode(params_s, caches,
                             {"tokens": cur, "pos": jnp.int32(prompt + i)})
        serve_toks.append(np.asarray(cur))
        cur = cur[:, None]
    out["serve:tokens"] = np.stack(serve_toks, 1)
    return out


def case_golden_parity(arch: str = "llama3.2-1b", write=None):
    """The executor must reproduce the recorded seed step outputs
    bit-for-bit (train grads + metrics + served tokens). Regenerate the
    golden file with ``python -m tests.spmd_case golden_parity write=1``
    only when a change is *intended* to alter numerics."""
    path = _golden_path()
    out = _golden_outputs(arch)
    if write:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        np.savez_compressed(path, **out)
        print(f"  wrote {path} ({len(out)} arrays)")
        print(f"CASE_OK golden_parity {arch} (wrote)")
        return
    ref = np.load(path)
    assert sorted(ref.files) == sorted(out), (
        set(ref.files) ^ set(out))
    n_bad = 0
    for k in ref.files:
        if not np.array_equal(ref[k], out[k]):
            n_bad += 1
            err = np.abs(np.asarray(ref[k], np.float64)
                         - np.asarray(out[k], np.float64)).max()
            print(f"  MISMATCH {k}: max abs err {err:.3e}")
    assert n_bad == 0, f"{n_bad}/{len(ref.files)} arrays differ from seed"
    print(f"  {len(ref.files)} arrays bit-for-bit equal to the seed")
    print(f"CASE_OK golden_parity {arch}")


def case_flat_parity(arch: str = "llama3.2-1b"):
    """coalesce="flat" (one all-gather / one reduce-scatter per tick) must
    be BIT-IDENTICAL to the per-tensor path: train grads + metrics and
    served tokens, same params, same batch."""
    from repro.core.pipeline import make_serve_step, init_serve_caches
    mod = M.get_arch(arch)
    cfg, rc0 = mod.reduced()
    rc0 = dataclasses.replace(rc0, microbatches=4, unit=2)
    geo = M.build_geometry(cfg, rc0)
    data = max(1, int(N_DEV) // geo.model_ranks)
    mesh = _mesh(data, geo.model_ranks)
    gb = data * rc0.groups * rc0.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)

    outs = {}
    for mode in ("flat", "none"):
        rc = dataclasses.replace(rc0, coalesce=mode)
        rt = Runtime(cfg, rc, mesh)
        if mode == "flat":
            fl = rt.flat_layouts["main"]
            assert fl is not None and len(fl.entries) > 1, (
                "flat parity is vacuous: layout empty or single-tensor")
        else:
            assert rt.flat_layouts["main"] is None
        params = rt.init_params(jax.random.PRNGKey(0))
        step = make_train_step(rt, ShapeConfig("toy", seq, gb, "train"))
        grads, metrics = step(params, batch)
        outs[mode] = (jax.device_get(grads), jax.device_get(metrics))

    flat_g = dict(jax.tree_util.tree_flatten_with_path(outs["flat"][0])[0])
    none_g = jax.tree_util.tree_flatten_with_path(outs["none"][0])[0]
    for kp, vn in none_g:
        assert np.array_equal(np.asarray(vn), np.asarray(flat_g[kp])), (
            f"flat grads differ at {jax.tree_util.keystr(kp)}")
    for k in outs["none"][1]:
        assert np.array_equal(np.asarray(outs["none"][1][k]),
                              np.asarray(outs["flat"][1][k])), k
    print(f"  train: {len(none_g)} grad tensors bit-identical")

    # serve: prefill + 2 decode steps under both modes
    toks_out = {}
    for mode in ("flat", "none"):
        rc = dataclasses.replace(rc0, microbatches=2, coalesce=mode)
        rt = Runtime(cfg, rc, mesh)
        gb_s = data * rc.groups * rc.microbatches
        prompt, max_seq = 8, 16
        shape_s = ShapeConfig("toy", max_seq, gb_s, "decode")
        params = rt.init_params(jax.random.PRNGKey(0))
        caches = jax.tree.map(
            lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype),
                                     s.sharding),
            init_serve_caches(rt, shape_s, max_seq=max_seq),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        toks = jax.random.randint(jax.random.PRNGKey(7), (gb_s, prompt),
                                  0, cfg.vocab)
        prefill = make_serve_step(rt, shape_s, prompt_len=prompt,
                                  max_seq=max_seq)
        tok, caches = prefill(params, caches, {"tokens": toks,
                                               "pos": jnp.int32(0)})
        seqs = [np.asarray(tok)]
        decode = make_serve_step(rt, shape_s, prompt_len=1,
                                 max_seq=max_seq)
        cur = tok[:, None]
        for i in range(2):
            cur, caches = decode(params, caches,
                                 {"tokens": cur,
                                  "pos": jnp.int32(prompt + i)})
            seqs.append(np.asarray(cur))
            cur = cur[:, None]
        toks_out[mode] = np.stack(seqs, 1)
    assert np.array_equal(toks_out["flat"], toks_out["none"]), (
        toks_out["flat"][:2], toks_out["none"][:2])
    print(f"  serve: {toks_out['flat'].shape} tokens bit-identical")
    print(f"CASE_OK flat_parity {arch}")


CASES["flat_parity"] = case_flat_parity


def case_gated_autogen_parity(arch: str = "llama3.2-1b"):
    """ISSUE-5 acceptance: the unit-gated §4 schedule must (a) actually
    claim unit-depth stash buffers (U < n_mb), (b) produce BIT-IDENTICAL
    gradients + metrics to the baseline zeropp schedule on the smoke
    config (unit blocks stay contiguous and per-slot W order is FIFO, so
    every accumulation and reduce-scatter batch is order-identical), and
    (c) simulate strictly below full-depth autogen on peak memory."""
    from repro.core.autogen import autogen
    from repro.core.generators import SchedParams
    from repro.core.simulator import CostModel, simulate

    mod = M.get_arch(arch)
    cfg, rc0 = mod.reduced()
    rc0 = dataclasses.replace(rc0, microbatches=4, unit=2)
    geo = M.build_geometry(cfg, rc0)
    data = max(1, int(N_DEV) // geo.model_ranks)
    mesh = _mesh(data, geo.model_ranks)
    gb = data * rc0.groups * rc0.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)

    outs = {}
    for sched in ("zeropp", "autogen_gated"):
        rc = dataclasses.replace(rc0, schedule=sched)
        rt = Runtime(cfg, rc, mesh)
        pt = rt.tables["main"]
        assert pt.U == rc0.unit_size, (sched, pt.U)  # unit-depth stash
        params = rt.init_params(jax.random.PRNGKey(0))
        step = make_train_step(rt, ShapeConfig("toy", seq, gb, "train"))
        grads, metrics = step(params, batch)
        outs[sched] = (jax.device_get(grads), jax.device_get(metrics))

    base_g = dict(jax.tree_util.tree_flatten_with_path(
        outs["zeropp"][0])[0])
    gated_g = jax.tree_util.tree_flatten_with_path(
        outs["autogen_gated"][0])[0]
    n_bad = 0
    for kp, vg in gated_g:
        if not np.array_equal(np.asarray(vg), np.asarray(base_g[kp])):
            n_bad += 1
            err = np.abs(np.asarray(vg, np.float64)
                         - np.asarray(base_g[kp], np.float64)).max()
            print(f"  MISMATCH {jax.tree_util.keystr(kp)}: {err:.3e}")
    assert n_bad == 0, f"{n_bad}/{len(gated_g)} grads differ from zeropp"
    for k in outs["zeropp"][1]:
        assert np.array_equal(np.asarray(outs["zeropp"][1][k]),
                              np.asarray(outs["autogen_gated"][1][k])), k
    print(f"  {len(gated_g)} grad tensors bit-identical to zeropp")

    # simulated peak activation memory: gated strictly below full-depth
    sp = SchedParams(P=rc0.pp, V=rc0.vpp, n_mb=rc0.microbatches,
                     unit=rc0.unit)
    cm = CostModel()
    sim_g = simulate(autogen(sp, cm, unit_gated=True).table, cm)
    sim_f = simulate(autogen(
        dataclasses.replace(sp, unit=sp.n_mb), cm).table, cm)
    assert sim_g.peak_mem < sim_f.peak_mem, (sim_g.peak_mem,
                                             sim_f.peak_mem)
    print(f"  simulated peak mem: gated {sim_g.peak_mem:.2f} < "
          f"full-depth {sim_f.peak_mem:.2f}")
    print(f"CASE_OK gated_autogen_parity {arch}")


CASES["gated_autogen_parity"] = case_gated_autogen_parity


def case_flat_int8(arch: str = "llama3.2-1b"):
    """grad_compress="int8" through the FLAT reduce (one int32
    psum_scatter + segment-wide shared scale + error feedback): grads
    must track the fp32 path closely and stay finite."""
    mod = M.get_arch(arch)
    cfg, rc0 = mod.reduced()
    # microbatches=4, unit=2 -> 2 reduce units per slot: the second unit's
    # quantization sees the first's error feedback re-injected.
    rc0 = dataclasses.replace(rc0, microbatches=4, unit=2)
    geo = M.build_geometry(cfg, rc0)
    data = max(1, int(N_DEV) // geo.model_ranks)
    mesh = _mesh(data, geo.model_ranks)
    gb = data * rc0.groups * rc0.microbatches
    seq = 16
    batch = _batch(cfg, gb, seq)

    grads = {}
    for compress, mode in (("none", "flat"), ("int8", "flat"),
                           ("int8", "none")):
        rc = dataclasses.replace(rc0, grad_compress=compress,
                                 coalesce=mode)
        rt = Runtime(cfg, rc, mesh)
        assert (rt.flat_layouts["main"] is not None) == (mode == "flat")
        params = rt.init_params(jax.random.PRNGKey(0))
        step = make_train_step(rt, ShapeConfig("toy", seq, gb, "train"))
        g, m = step(params, batch)
        grads[(compress, mode)] = jax.device_get(g)
        assert np.isfinite(float(m["loss_sum"]))

    flat_f = jax.tree_util.tree_flatten_with_path(
        grads[("none", "flat")])[0]
    gmax = max(np.abs(np.asarray(v, np.float32)).max()
               for _, v in flat_f)
    for key in (("int8", "flat"), ("int8", "none")):
        flat_q = dict(jax.tree_util.tree_flatten_with_path(grads[key])[0])
        worst = (0.0, None)
        for kp, vf in flat_f:
            vq = np.asarray(flat_q[kp], np.float32)
            vf = np.asarray(vf, np.float32)
            assert np.isfinite(vq).all(), kp
            # int8 quantization error is bounded by the shared scale;
            # normalize by the global grad magnitude, not per-tensor.
            err = np.abs(vq - vf).max() / gmax
            if err > worst[0]:
                worst = (err, jax.tree_util.keystr(kp))
        assert worst[0] < 0.02, f"int8 {key[1]} reduce too lossy: {worst}"
        print(f"  int8({key[1]})-vs-fp32 worst err {worst[0]:.2e} "
              f"(of global max |g|={gmax:.2e}) at {worst[1]}")
    print(f"CASE_OK flat_int8 {arch}")


CASES["flat_int8"] = case_flat_int8


def case_flat_fallback(arch: str = "llama3.2-1b"):
    """Mixed divisibility: tensors the flat layout cannot cover
    (non-divisible -> replicated) must fall back to the per-tensor path,
    bit-identically, including an ld != 0 tensor in the flat pack."""
    from repro.core import fsdp as F
    from repro.models.common import ParamSpec
    from jax.sharding import PartitionSpec as P

    D = 4
    mesh = _mesh(D, 2)
    specs = {
        "a": ParamSpec((8, 16), fsdp_dim=0),    # divisible on dim 0
        "b": ParamSpec((16, 12), fsdp_dim=1),   # divisible on dim 1 (ld=1)
        "c": ParamSpec((6, 5), fsdp_dim=0),     # 6 % 4 != 0 -> replicated
    }
    gatherable = sorted(n for n in specs
                        if F.local_dim(specs[n], D, False) is not None)
    assert gatherable == ["a", "b"] and "c" not in gatherable
    fl = F.build_flat_layout(specs, gatherable, D, False)
    assert fl is not None and fl.full_size == 8 * 16 + 16 * 12
    assert fl.entries[1].ld == 1  # the moveaxis path is exercised

    V = 2
    key = jax.random.PRNGKey(0)
    full = {n: jax.random.normal(jax.random.fold_in(key, i),
                                 (V, *specs[n].shape), jnp.float32)
            for i, n in enumerate(sorted(specs))}

    def shard_spec(n):
        sp = specs[n]
        dims = [None] * (1 + len(sp.shape))
        if F.local_dim(sp, D, False) is not None:
            dims[1 + sp.fsdp_dim] = "data"
        return P(*dims)

    in_specs = ({n: shard_spec(n) for n in specs},)

    def body_gather(seg_p):
        # per-tensor reference
        ref = {}
        for n in gatherable:
            ld = F.local_dim(specs[n], D, False)
            ref[n] = jax.lax.all_gather(seg_p[n][0], "data", axis=ld,
                                        tiled=True)
        # flat path: pack -> ONE all_gather -> unpack
        slab = F.pack_flat_stack(seg_p, fl)
        got = F.unpack_flat(F.all_gather_flat(slab[0], fl), fl)
        return ref, got

    out_specs = ({n: P() for n in gatherable}, {n: P() for n in gatherable})
    fg = F.shard_map(body_gather, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)
    ref, got = jax.jit(fg)(full)
    for n in gatherable:
        assert np.array_equal(np.asarray(ref[n]), np.asarray(got[n])), n
        assert np.array_equal(np.asarray(got[n]),
                              np.asarray(full[n][0])), n

    def body_reduce(seg_p):
        grads = {n: seg_p[n][0] if F.local_dim(specs[n], D, False) is None
                 else jax.lax.all_gather(
                     seg_p[n][0], "data",
                     axis=F.local_dim(specs[n], D, False), tiled=True)
                 for n in specs}
        ref = {n: F.reduce_scatter_grad(grads[n], specs[n], D, False)
               for n in specs}
        got = F.reduce_scatter_flat(
            {n: grads[n] for n in gatherable}, fl, jnp.float32)
        got["c"] = F.reduce_scatter_grad(grads["c"], specs["c"], D, False)
        return ref, got

    def red_spec(n):
        sp = specs[n]
        dims = [None] * len(sp.shape)
        if F.local_dim(sp, D, False) is not None:
            dims[sp.fsdp_dim] = "data"
        return P(*dims)

    out_specs_r = ({n: red_spec(n) for n in specs},
                   {n: red_spec(n) for n in specs})
    fr = F.shard_map(body_reduce, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs_r, check_vma=False)
    ref_r, got_r = jax.jit(fr)(full)
    for n in specs:
        assert np.array_equal(np.asarray(ref_r[n]),
                              np.asarray(got_r[n])), n
    print(f"  gather+reduce bit-identical; flat covers {gatherable}, "
          f"'c' replicated fallback (ld=1 moveaxis path exercised)")

    # engine-level: a data axis dividing nothing -> empty flat layout,
    # the pipeline must run the gather-free path and still match the
    # reference grads.
    case_train_equiv(arch, data=3, model=2)
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    rt = Runtime(cfg, dataclasses.replace(rc, microbatches=4, unit=2),
                 _mesh(3, 2))
    assert rt.flat_layouts["main"] is None and not rt.gatherable["main"]
    print("  data=3: nothing divisible -> empty layout, grads still match")
    print(f"CASE_OK flat_fallback {arch}")


CASES["flat_fallback"] = case_flat_fallback


def case_donation(arch: str = "llama3.2-1b"):
    """Buffer-donation audit: the serve step donates its caches and the
    opt step donates params + opt state — visible as input/output
    aliasing in the lowered modules (no spurious full-size copies)."""
    from repro.api import session

    mod = M.get_arch(arch)
    cfg, rc0 = mod.reduced()
    geo = M.build_geometry(cfg, dataclasses.replace(rc0, microbatches=2))
    data = max(1, int(N_DEV) // geo.model_ranks)

    def n_donated(txt):
        # donation lowers as an eager alias (tf.aliasing_output) or a
        # deferred XLA decision (jax.buffer_donor) depending on shardings
        return (txt.count("tf.aliasing_output")
                + txt.count("jax.buffer_donor"))

    sess = session(arch, mode="serve", data=data,
                   global_batch=data * rc0.groups * 2, max_seq=16,
                   overrides=dict(microbatches=2))
    n_alias = n_donated(sess.lower().as_text())
    n_caches = len(jax.tree_util.tree_leaves(
        sess.init_caches(abstract=True)))
    assert n_alias >= n_caches, (
        f"serve step donates {n_alias} buffers < {n_caches} cache leaves")

    tr = session(arch, data=data, seq_len=16,
                 overrides=dict(microbatches=2))
    params = tr.init_params(jax.random.PRNGKey(0))
    opt = tr.init_opt_state(params)
    g_shapes = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    lo = tr.opt_step_fn().lower(params, g_shapes, opt)
    n_alias_o = n_donated(lo.as_text())
    n_p = len(jax.tree_util.tree_leaves(params))
    assert n_alias_o >= n_p, (
        f"opt step donates {n_alias_o} buffers < {n_p} param leaves")

    d = tr.describe()
    assert d["donation"]["opt_step"] == ["params", "opt_state"]
    assert d["donation"]["serve_step"] == ["caches"]
    # and the update still actually runs + callers' rebind pattern works
    grads, _ = tr.train_step(params, tr.stream().batch(0))
    params, opt, om = tr.opt_step(params, grads, opt)
    assert np.isfinite(float(om["grad_norm"]))
    print(f"  serve aliases {n_alias}/{n_caches} cache leaves, "
          f"opt aliases {n_alias_o} (>= {n_p} params)")
    print(f"CASE_OK donation {arch}")


CASES["donation"] = case_donation


def case_moe_ep_equiv(arch: str = "qwen2-moe-a2.7b"):
    """EP as a first-class tick-engine citizen: expert-parallel training
    matches the reference model, the lowered EP step moves tokens via
    all-to-all while keeping expert weights out of the FSDP gathers, and
    ep-vs-gathered serve engines emit identical greedy tokens with live
    expert-load stats."""
    import re
    from repro.api import session

    # 1) EP pipeline grads vs the reference model
    case_train_equiv(arch, moe_mode="ep")

    # 2) structural: EP lowers all-to-all dispatch/combine and shrinks
    # the FSDP gather footprint (expert slabs stay sharded over data)
    def sites(txt, op):
        return len(re.findall(rf"\b{op}(?:-start)?\(", txt))

    txts = {}
    ep_names = {}
    for mode in ("ep", "gathered"):
        sess = session(arch, data=2, seq_len=16, moe_mode=mode,
                       overrides=dict(microbatches=2))
        txts[mode] = sess.lower().compile().as_text()
        ep_names[mode] = (set(sess.rt.ep_names["main"]),
                          set(sess.rt.gatherable["main"]))
    a2a_ep = sites(txts["ep"], "all-to-all")
    a2a_g = sites(txts["gathered"], "all-to-all")
    # gathered may still carry a couple of XLA-synthesized all-to-alls
    # (layout shuffles); EP's explicit dispatch/combine dominates them
    assert a2a_ep > a2a_g, (a2a_ep, a2a_g)
    # EP keeps the expert slabs out of the FSDP gather set entirely
    eps, gat = ep_names["ep"]
    assert eps and not (eps & gat), (eps, gat)
    eps_g, gat_g = ep_names["gathered"]
    assert not eps_g and eps <= gat_g, (eps_g, gat_g)
    print(f"  HLO: ep all-to-all={a2a_ep} (gathered {a2a_g}); "
          f"{len(eps)} expert tensors out of the gather set")

    # 3) serve engines: ep tokens == gathered tokens, load histogram live
    def serve(mode):
        s = session(arch, mode="serve", data=2, max_slots=4, max_seq=24,
                    moe_mode=mode,
                    overrides=dict(microbatches=2, moe_stats=True))
        ps = s.init_params(jax.random.PRNGKey(0))
        eng = s.serve_engine(ps)
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, s.cfg.vocab, size=n).astype(np.int32)
                   for n in (3, 8, 5, 6)]
        hs = [eng.submit(p, max_gen=4) for p in prompts]
        eng.run_until_idle()
        return [h.result(timeout=10) for h in hs], s.describe()["serving"]

    toks_ep, srv_ep = serve("ep")
    toks_g, _ = serve("gathered")
    assert toks_ep == toks_g, (toks_ep, toks_g)
    load = srv_ep["moe"]["load_per_expert"]
    assert len(load) == 8 and sum(load) > 0, load
    assert srv_ep["capacity_deferrals"] == 0  # cf=8 never defers 4 slots
    print(f"  serve ep == gathered tokens; load/expert {load}")
    print(f"CASE_OK moe_ep_equiv {arch}")


CASES["moe_ep_equiv"] = case_moe_ep_equiv


def case_moe_ep_flat(arch: str = "qwen2-moe-a2.7b"):
    """Per-expert-shard flat segments: in EP mode the expert tensors'
    cross-group reductions pack into ONE slab collective per segment
    (coalesce="flat") with grads bit-identical to the per-tensor path,
    and strictly fewer collective sites in the compiled HLO."""
    import re
    from repro.api import session

    assert int(N_DEV) >= 12, "run with SPMD_DEVICES=12 (data 2 x model 6)"
    outs = {}
    sites = {}
    for mode in ("flat", "none"):
        sess = session(arch, data=2, seq_len=16, moe_mode="ep",
                       coalesce=mode,
                       overrides=dict(microbatches=2, groups=2))
        efl = sess.rt.ep_flat_layouts["main"]
        assert (efl is not None) == (mode == "flat"), (mode, efl)
        params = sess.init_params(jax.random.PRNGKey(0))
        batch = sess.stream().batch(0)
        lo = sess.train_step_fn().lower(params, batch).compile()
        txt = lo.as_text()
        sites[mode] = {
            op: len(re.findall(rf"\b{op}(?:-start)?\(", txt))
            for op in ("all-gather", "reduce-scatter", "all-reduce",
                       "collective-permute", "all-to-all")}
        g, m = sess.train_step(params, batch)
        outs[mode] = (jax.device_get(g), float(m["loss_sum"]))

    assert outs["flat"][1] == outs["none"][1], (outs["flat"][1],
                                                outs["none"][1])
    flat_g = jax.tree_util.tree_flatten_with_path(outs["flat"][0])[0]
    base_g = dict(jax.tree_util.tree_flatten_with_path(outs["none"][0])[0])
    for kp, vg in flat_g:
        assert np.array_equal(np.asarray(vg), np.asarray(base_g[kp])), \
            jax.tree_util.keystr(kp)
    tot = {m: sum(s.values()) for m, s in sites.items()}
    assert tot["flat"] < tot["none"], (sites["flat"], sites["none"])
    print(f"  {len(flat_g)} grad tensors bit-identical; collective "
          f"sites {tot['flat']} < {tot['none']} "
          f"(permute {sites['flat']['collective-permute']} < "
          f"{sites['none']['collective-permute']})")
    print(f"CASE_OK moe_ep_flat {arch}")


CASES["moe_ep_flat"] = case_moe_ep_flat


def case_elastic_train(arch: str = "llama3.2-1b"):
    """End-to-end elastic training through the topology layer: a mid-run
    injected failure on a data=4 fake topology (8 devices) resumes from
    the verified checkpoint on a data=2 topology (4 devices) and the
    post-restore loss trajectory is BIT-EXACT against a clean
    restore-and-continue on the same shrunk topology — the restart adds
    no numerical drift, only the re-mesh."""
    import tempfile

    from repro.api import session
    from repro.runtime.fault_tolerance import (
        FaultToleranceConfig,
        TrainController,
    )
    from repro.runtime.topology import Topology

    GB = 8          # pinned across the shrink so the stream continues

    def make_sess(data):
        return session(arch, topology=Topology(kind="fake_cpu", data=data),
                       seq_len=16, global_batch=GB,
                       overrides=dict(microbatches=2),
                       optim=dict(lr=1e-2, warmup=20, total=10_000))

    ckpt = tempfile.mkdtemp(prefix="elastic_train_")
    ctl = TrainController(ckpt, FaultToleranceConfig(
        ckpt_every=2, max_failures=3, async_save=False))
    sessions = []

    def build(restored, manifest):
        sess = make_sess(2 if ctl.failures else 4)
        ctl.attach(sess)
        sessions.append(sess)
        stream = sess.stream()
        if restored is None:
            params = sess.init_params(jax.random.PRNGKey(0))
            opt = sess.init_opt_state(params)
        else:
            params = sess.adopt_params(restored["params"])
            opt = jax.tree.map(jnp.asarray, restored["opt"])
            opt["step"] = jnp.asarray(opt["step"])

        def run_one(state, step_no):
            batch = stream.batch(step_no)
            grads, metrics = sess.train_step(state["params"], batch)
            p2, o2, _ = sess.opt_step(state["params"], grads,
                                      state["opt"])
            return ({"params": p2, "opt": o2},
                    {"loss": float(metrics["loss_sum"])})

        return {"params": params, "opt": opt}, run_one, lambda s: s

    state, history = ctl.run(build, 6, inject_failure_at=4)
    assert ctl.failures == 1, ctl.failures
    assert [s.data_size for s in sessions] == [4, 2], \
        [s.data_size for s in sessions]
    assert [s for s, _ in history] == list(range(6)), history
    # the controller surfaced itself in the facade's introspection
    ft = sessions[-1].describe()["fault_tolerance"]
    assert ft["failures"] == 1 and ft["resume_steps"] == [4], ft
    topo = sessions[-1].describe()["topology"]
    assert topo["kind"] == "fake_cpu" and topo["layout"]["data"] == 2, topo
    losses = {s: m["loss"] for s, m in history}

    # reference: clean restore of the step-4 checkpoint on the SAME
    # shrunk topology, steps 4..5 — must match the elastic run bit-exactly
    sess_ref = make_sess(2)
    tree, manifest = ctl.mgr.restore(4)
    assert manifest["extra"]["step"] == 4, manifest
    params = sess_ref.adopt_params(tree["params"])
    opt = jax.tree.map(jnp.asarray, tree["opt"])
    opt["step"] = jnp.asarray(opt["step"])
    stream = sess_ref.stream()
    state_r = {"params": params, "opt": opt}
    for step_no in (4, 5):
        batch = stream.batch(step_no)
        grads, metrics = sess_ref.train_step(state_r["params"], batch)
        p2, o2, _ = sess_ref.opt_step(state_r["params"], grads,
                                      state_r["opt"])
        state_r = {"params": p2, "opt": o2}
        ref = float(metrics["loss_sum"])
        assert losses[step_no] == ref, \
            f"step {step_no}: elastic {losses[step_no]!r} != clean {ref!r}"
    print(f"  elastic 4->2 data shrink: steps 4..5 bit-exact vs clean "
          f"restore (losses {losses[4]:.6f}, {losses[5]:.6f})")
    print(f"CASE_OK elastic_train {arch}")


CASES["elastic_train"] = case_elastic_train


def case_serve_reshard(arch: str = "llama3.2-1b"):
    """ServeEngine.reshard: park a staggered in-flight workload, rebuild
    on a shrunk topology, re-admit — zero dropped requests and token
    streams identical to an uninterrupted run, on both the contiguous
    and the paged (radix-sharing) pool."""
    from repro.api import session
    from repro.runtime.topology import Topology

    def make(data, **kw):
        return session(arch, mode="serve",
                       topology=Topology(kind="fake_cpu", data=data),
                       max_slots=4, max_seq=24,
                       overrides=dict(microbatches=2), **kw)

    vocab = None
    rng = np.random.RandomState(0)
    for paged in (False, True):
        kw = dict(page_size=4) if paged else {}
        sess = make(2, **kw)
        vocab = sess.cfg.vocab
        prompts = [rng.randint(0, vocab, size=n).astype(np.int32)
                   for n in (3, 8, 5, 11, 4, 7, 9, 6)]
        gens = [4, 2, 6, 3, 5, 2, 4, 6]
        params = sess.init_params(jax.random.PRNGKey(0))
        eng = sess.serve_engine(params)
        hs = [eng.submit(p, max_gen=g) for p, g in zip(prompts, gens)]
        eng.run_until_idle()
        refs = [h.result(timeout=5) for h in hs]

        sess2 = make(2, **kw)
        eng2 = sess2.serve_engine(
            sess2.init_params(jax.random.PRNGKey(0)))
        hs2 = [eng2.submit(p, max_gen=g) for p, g in zip(prompts, gens)]
        eng2.step()
        eng2.step()     # mixture: finished + mid-decode + still queued
        in_flight = len(eng2._by_slot)
        queued = eng2.scheduler.n_queued
        assert in_flight > 0 and queued > 0, (in_flight, queued)
        r = eng2.reshard(Topology(kind="fake_cpu", data=1))
        assert eng2.session.data_size == 1
        assert r["parked"] == in_flight + queued, r
        eng2.run_until_idle()
        got = [h.result(timeout=5) for h in hs2]
        for i, (a, b) in enumerate(zip(refs, got)):
            assert a == b, f"paged={paged} request {i}: {b} != {a}"
        st = eng2.stats
        assert st.reshards == 1 and st.finished_requests == len(prompts)
        label = "paged" if paged else "contiguous"
        print(f"  {label}: reshard parked {r['parked']} "
              f"({in_flight} in flight, {queued} queued), streams "
              f"identical on data=1")
    print(f"CASE_OK serve_reshard {arch}")


CASES["serve_reshard"] = case_serve_reshard


def case_router_equiv(arch: str = "llama3.2-1b"):
    """EngineRouter correctness: 2 replicas serve the staggered PR-3
    workload token-identically to 1 engine; killing a replica mid-
    workload moves its requests to the survivor with zero drops; a
    seeded sampled stream survives the replica move bit-exactly."""
    from repro.api import session
    from repro.serving import EngineRouter

    def engine():
        sess = session(arch, mode="serve", data=2, max_slots=4,
                       max_seq=24, overrides=dict(microbatches=2))
        return sess.serve_engine(sess.init_params(jax.random.PRNGKey(0)))

    rng = np.random.RandomState(0)
    eng0 = engine()
    vocab = eng0.session.cfg.vocab
    prompts = [rng.randint(0, vocab, size=n).astype(np.int32)
               for n in (3, 8, 5, 11, 4, 7, 9, 6)]
    gens = [4, 2, 6, 3, 5, 2, 4, 6]
    seeds = [None] * 7 + [123]      # one seeded sampled request
    temps = [0.0] * 7 + [0.8]

    def submit_all(target):
        return [target.submit(p, max_gen=g, temperature=t, seed=s)
                for p, g, t, s in zip(prompts, gens, temps, seeds)]

    hs = submit_all(eng0)
    eng0.run_until_idle()
    refs = [h.result(timeout=5) for h in hs]

    # 2 replicas, no failure: token-identical, both replicas served work
    router = EngineRouter([engine(), engine()])
    hs = submit_all(router)
    router.run_until_idle()
    got = [h.result(timeout=5) for h in hs]
    assert got == refs, "2-replica output diverged from single engine"
    assert all(n > 0 for n in router.dispatched), router.dispatched
    print(f"  2 replicas token-identical "
          f"(dispatched {router.dispatched})")

    # kill replica 0 mid-workload: in-flight work (including the seeded
    # sampled stream) moves to the survivor and finishes identically
    router = EngineRouter([engine(), engine()])
    hs = submit_all(router)
    for _ in range(2):
        for i in router.alive():
            router.engines[i].step()
    moved = router.kill_replica(0)
    assert moved > 0, "kill before any work was in flight on replica 0"
    router.run_until_idle()
    got = [h.result(timeout=5) for h in hs]
    assert got == refs, "failover output diverged"
    st = router.stats()
    assert st["alive"] == 1 and st["failovers"] == 1, st
    assert st["finished_requests"] == len(prompts), st
    print(f"  replica-0 kill moved {moved} requests; streams (incl. "
          f"seeded sampling) bit-identical")
    print(f"CASE_OK router_equiv {arch}")


CASES["router_equiv"] = case_router_equiv


CASES["prefetch_equiv"] = case_prefetch_equiv
CASES["int8_grads"] = case_int8_grads
CASES["elastic_reshard"] = case_elastic_reshard
CASES["api_parity"] = case_api_parity
CASES["golden_parity"] = case_golden_parity
CASES["auto_schedule"] = case_auto_schedule


if __name__ == "__main__":
    case = sys.argv[1]
    args = sys.argv[2:]
    kwargs = {}
    pos = []
    for a in args:
        if "=" in a:
            k, v = a.split("=", 1)
            kwargs[k] = int(v) if v.isdigit() else v
        else:
            pos.append(a)
    CASES[case](*pos, **kwargs)
