"""Optimizer / data / checkpoint / fault-tolerance substrate tests."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticStream
from repro.optim import adamw
from repro.runtime.fault_tolerance import (
    FaultToleranceConfig,
    StragglerWatchdog,
    TrainController,
)
from tests.proptest import propcase


# --------------------------------------------------------------------------- #
def test_adamw_optimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.ones((8,)) * 3.0}
    st = adamw.init_state(params, cfg)
    target = jnp.arange(8.0) / 4.0
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, st, m = adamw.apply_updates(params, st, g, cfg) if False \
            else adamw.apply_updates(params, g, st, cfg)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_adamw_grad_clip_and_decay_mask():
    cfg = adamw.AdamWConfig(lr=0.0, weight_decay=1.0, grad_clip=1.0)
    params = {"w": jnp.ones((4,)), "ln.scale": jnp.ones((4,))}
    st = adamw.init_state(params, cfg)
    g = {"w": jnp.ones((4,)) * 100.0, "ln.scale": jnp.ones((4,))}
    p2, st, m = adamw.apply_updates(params, g, st, cfg)
    assert float(m["grad_norm"]) > 100
    # lr = 0 → params unchanged regardless of decay
    np.testing.assert_allclose(p2["w"], params["w"])


def test_lr_schedule_shape():
    s = jnp.arange(0, 2000, 100)
    mult = jax.vmap(lambda x: adamw.lr_schedule(
        x, base_lr=1.0, warmup=200, total=2000))(s)
    assert float(mult[0]) == 0.0
    assert float(mult[2]) == pytest.approx(1.0, abs=1e-3)
    assert float(mult[-1]) < 0.3


# --------------------------------------------------------------------------- #
def test_data_stream_deterministic_and_elastic():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab=97)
    s1 = SyntheticStream(cfg)
    b1 = s1.batch(3)
    b2 = SyntheticStream(cfg).batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # global sample stream is independent of batch re-layout
    cfg2 = DataConfig(seq_len=16, global_batch=4, vocab=97)
    s2 = SyntheticStream(cfg2)
    np.testing.assert_array_equal(
        np.concatenate([s2.batch(6)["tokens"], s2.batch(7)["tokens"]]),
        b1["tokens"],
    )
    # labels = next tokens (LM objective is learnable)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_prefetcher_cursor():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=31)
    pf = Prefetcher(SyntheticStream(cfg), start_step=5)
    s, b = next(pf)
    assert s == 5
    s, b = next(pf)
    assert s == 6
    assert pf.state()["step"] == 7
    pf.close()


# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.list_steps() == [20, 30]  # keep-2 GC
    got, manifest = mgr.restore(30)
    np.testing.assert_allclose(got["a"], np.asarray(tree["a"]) + 30)
    assert manifest["step"] == 30
    assert mgr.verify(30)


def test_checkpoint_async_and_corruption_fallback(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((8, 8))}
    mgr.save(1, tree, blocking=False)
    mgr.save(2, tree, blocking=False)
    mgr.wait()
    assert set(mgr.list_steps()) == {1, 2}
    # corrupt step 2
    path = os.path.join(str(tmp_path), "step_000000002", "w.npy")
    with open(path, "wb") as f:
        f.write(b"garbage")
    ctl = TrainController(str(tmp_path), FaultToleranceConfig())
    tree2, manifest = ctl.restore_latest()
    assert manifest["step"] == 1  # fell back past the corrupt step


def test_controller_restart_from_failure(tmp_path):
    ft = FaultToleranceConfig(ckpt_every=2, max_failures=3,
                              async_save=False)
    ctl = TrainController(str(tmp_path), ft)
    calls = {"builds": 0}

    def build(restored, manifest):
        calls["builds"] += 1
        start = (manifest or {}).get("extra", {}).get("step", 0)
        state = {"x": jnp.asarray(restored["x"]) if restored
                 else jnp.zeros(())}

        def run_one(state, step):
            return {"x": state["x"] + 1.0}, {"x": float(state["x"])}

        return state, run_one, lambda s: s

    state, hist = ctl.run(build, total_steps=10, inject_failure_at=5)
    assert calls["builds"] == 2          # one restart
    assert float(state["x"]) >= 6.0      # resumed from step-4 checkpoint
    assert ctl.failures == 1


def test_straggler_watchdog():
    wd = StragglerWatchdog(FaultToleranceConfig(straggler_factor=2.0))
    for _ in range(10):
        wd.observe(0.1)
    assert wd.flags == 0
    assert wd.observe(1.0)  # 10× slower
    assert wd.flags == 1
