"""Shared test fixtures.

The persisted plan cache (``repro.core.plan_cache``) defaults to
``~/.cache/repro/plans.json``; every test gets a throwaway path so runs
neither read developer state nor leave artifacts behind.  The env var is
also what spmd subprocess cases inherit, keeping them isolated too.
"""

import pytest


@pytest.fixture(autouse=True)
def _isolated_plan_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans.json"))
