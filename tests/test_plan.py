"""SchedulePlan / executor-plan layer + §4 auto-selection tests.

Covers the ISSUE-2 properties:
  * each §4 autogen insertion step never increases the simulated makespan;
  * ``retick`` output always passes ``TickTable.validate()``;
  * ``select_plan`` picks a plan whose makespan is ≤ every registered
    built-in, caches per key, and the packed table matches the analyzed
    table tick-for-tick.
"""

import numpy as np
import pytest

from repro.core.autogen import autogen, orders_from_table, retick
from repro.core.generators import SchedParams, generate
from repro.core.plan import (
    UNIT_GATED_SCHEDULES,
    PlanAnalysis,
    SchedulePlan,
    candidate_schedules,
    clear_plan_cache,
    fused_cost_model,
    pack_table,
    preset_cost_model,
    select_plan,
)
from repro.core.schedules import W
from repro.core.simulator import CostModel, simulate
from tests.proptest import propcase

CM = CostModel(t_f=1.0, t_b=2.0, t_w=1.0, t_p2p=0.02,
               t_gather=0.3, t_reduce=0.3)


# --------------------------------------------------------------------------- #
# §4 autogen properties
# --------------------------------------------------------------------------- #


@propcase(n_cases=8)
def test_autogen_insertions_never_increase_makespan(draw):
    """Every accepted W insertion strictly improves the simulated
    makespan — the §4 loop's invariant, as a recorded trajectory."""
    P = draw.choice([2, 3, 4])
    V = draw.choice([1, 2])
    B_ = draw.ints(1, 3) * P
    res = autogen(SchedParams(P=P, V=V, n_mb=B_), CM)
    assert res.makespans[0] == pytest.approx(res.makespan_before)
    assert res.makespans[-1] == pytest.approx(res.makespan_after)
    assert len(res.makespans) == res.n_insertions + 1
    for a, b in zip(res.makespans, res.makespans[1:]):
        assert b < a + 1e-12, res.makespans
    res.table.validate()


@propcase(n_cases=10)
def test_retick_output_always_validates(draw):
    """Re-quantizing any valid per-rank order must produce a valid
    TickTable (dependencies, placement, completeness)."""
    P = draw.choice([2, 3, 4, 8])
    V = draw.choice([1, 2, 3])
    B_ = draw.ints(1, 3) * P
    method = draw.choice(["gpipe", "1f1b", "interleaved", "bfs",
                          "zeropp", "autogen"])
    split = method in ("zeropp", "autogen")
    sp = SchedParams(P=P, V=V, n_mb=B_, split_bw=split)
    tt = generate(method, sp)
    re = retick(orders_from_table(tt), P, V, B_, sp.U)
    re.validate()
    # same task multiset before and after
    assert sorted((t.kind, t.mb, t.stage) for _, _, t in tt.tasks()) == \
        sorted((t.kind, t.mb, t.stage) for _, _, t in re.tasks())


def test_autogen_tables_are_full_depth():
    """§4 postpones W across unit boundaries, so the registered autogen
    schedule must never claim unit-depth buffers (ISSUE-2 executor
    contract)."""
    sp = SchedParams(P=4, V=2, n_mb=8, unit=2)
    tt = generate("autogen", sp)
    assert tt.unit == sp.n_mb
    assert "autogen" not in UNIT_GATED_SCHEDULES
    assert any(t.kind == W for _, _, t in tt.tasks())


# --------------------------------------------------------------------------- #
# ISSUE-5: unit-gated autogen + stash legality + RS-overlap simulation
# --------------------------------------------------------------------------- #


@propcase(n_cases=8)
def test_gated_autogen_keeps_unit_depth_and_validates(draw):
    """"autogen_gated" keeps stash depth U (< n_mb), its insertions are
    monotone like the full-depth loop, and the table passes the
    unit-stash legality check in TickTable.validate()."""
    from repro.core.schedules import unit_stash_violations

    P = draw.choice([2, 3, 4])
    V = draw.choice([1, 2])
    n_units = draw.ints(2, 3)
    U = draw.choice([1, 2]) * P
    res = autogen(SchedParams(P=P, V=V, n_mb=U * n_units, unit=U), CM,
                  unit_gated=True)
    assert res.table.unit == U < res.table.n_mb
    assert unit_stash_violations(res.table) == []
    res.table.validate()
    for a, b in zip(res.makespans, res.makespans[1:]):
        assert b < a + 1e-12, res.makespans
    # packs onto unit-depth executor buffers without tripping the gate
    assert pack_table(res.table).U == U


def test_stash_legality_rejects_full_depth_table_at_unit_depth():
    """The B→W-distance gate: a full-depth §4 table mislabeled as
    unit-gated must be rejected by validate(), pack_table() and the
    engine-boundary check."""
    import dataclasses as _dc

    from repro.core.executor import validate_unit_stash_packed
    from repro.core.schedules import unit_stash_violations

    sp = SchedParams(P=4, V=2, n_mb=8, unit=8)
    tt = autogen(sp, CM).table      # full-depth postponed W
    good = pack_table(tt)           # legal at its claimed (full) depth
    tt.unit = 2                     # mislabel: claim unit-depth stash
    assert unit_stash_violations(tt)
    with pytest.raises(AssertionError, match="stash-reuse"):
        tt.validate()
    with pytest.raises(ValueError, match="stash violation"):
        pack_table(tt)
    bad = _dc.replace(good, U=2)
    with pytest.raises(ValueError, match="unit depth"):
        validate_unit_stash_packed(bad)


def test_gated_autogen_peak_mem_strictly_below_full_depth():
    """Acceptance bar: with U < n_mb, the gated table's simulated peak
    activation memory is strictly below full-depth autogen's (the O(U)
    vs O(B) bound), at a makespan cost select_plan can trade off."""
    import dataclasses as _dc

    sp = SchedParams(P=4, V=2, n_mb=8, unit=2)
    gated = simulate(autogen(sp, CM, unit_gated=True).table, CM)
    full = simulate(autogen(_dc.replace(sp, unit=8), CM).table, CM)
    assert gated.peak_mem < full.peak_mem


def test_simulator_reduce_scatter_overlap():
    """Overlapped reduce-scatters only expose what outlives the last
    compute (a unit's tail reduce hides under the next unit's B/W);
    blocking mode charges every reduce serially."""
    import dataclasses as _dc

    sp = SchedParams(P=4, V=2, n_mb=8, unit=4)
    tt = generate("zeropp", sp)
    n_red_worst = int((tt.reduce >= 0).sum(axis=0).max())
    assert n_red_worst > 1
    ov = simulate(tt, CM)
    bl = simulate(tt, _dc.replace(CM, overlap_comm=False))
    free = simulate(tt, _dc.replace(CM, t_reduce=0.0))
    # overlap: some reduce time is hidden under later compute
    assert ov.rs_exposed < n_red_worst * CM.t_reduce
    assert ov.makespan - free.makespan == pytest.approx(ov.rs_exposed)
    # blocking: reduces cost at least the overlap exposure, usually more
    assert bl.makespan >= ov.makespan - 1e-12
    assert bl.rs_exposed >= ov.rs_exposed - 1e-12
    # and the analysis layer reports the split per candidate
    plan = SchedulePlan.from_table("zeropp", sp, tt, prefetch=1)
    ana = plan.analyze(CM, preset="abstract")
    assert ana.stash_depth == 4
    assert ana.rs_exposed == pytest.approx(ov.rs_exposed)
    assert ana.rs_overlap_saved == pytest.approx(
        n_red_worst * CM.t_reduce - ov.rs_exposed)


def test_select_plan_ranks_gated_vs_full_on_memory_budget():
    """The memory/makespan trade-off: an unconstrained selection may pick
    a full-depth plan, but a budget below full-depth peak memory forces
    the unit-depth candidates — and autogen_gated is one of them."""
    sel = select_plan(4, 2, 8, 2, CM, preset="abstract",
                      candidates=["autogen", "autogen_gated", "zeropp"])
    a_full = sel.candidates["autogen"]
    a_gate = sel.candidates["autogen_gated"]
    assert isinstance(a_gate, PlanAnalysis)
    assert a_gate.stash_depth == 2 and a_full.stash_depth == 8
    assert a_gate.peak_mem < a_full.peak_mem
    # budget between the two peaks: only unit-depth candidates fit
    budget = (a_gate.peak_mem + a_full.peak_mem) / 2
    sel_b = select_plan(4, 2, 8, 2, CM, preset="abstract",
                        candidates=["autogen", "autogen_gated", "zeropp"],
                        mem_budget=budget)
    assert sel_b.analysis.peak_mem <= budget
    assert sel_b.selected.name in ("autogen_gated", "zeropp")
    assert sel_b.mem_budget == budget
    # a budget nothing meets falls back to the min-memory candidate
    sel_min = select_plan(4, 2, 8, 2, CM, preset="abstract",
                          candidates=["autogen", "autogen_gated"],
                          mem_budget=1e-9)
    assert sel_min.selected.name == "autogen_gated"


# --------------------------------------------------------------------------- #
# SchedulePlan object
# --------------------------------------------------------------------------- #


def test_plan_bundles_table_and_packed():
    import dataclasses

    sp = SchedParams(P=4, V=2, n_mb=8, unit=4)
    plan = SchedulePlan.build("zeropp", sp)
    assert plan.packed.T == plan.table.T
    assert plan.packed.U == plan.table.unit
    assert plan.packed.prefetch == 0
    assert plan.has_w
    # packed kind grid mirrors the table cells
    for t, r, task in plan.table.tasks():
        assert plan.packed.kind[t, r] == task.kind
        assert plan.packed.mb[t, r] == task.mb
    # analyses cache per preset
    a1 = plan.analyze(CM, preset="abstract")
    a2 = plan.analyze(CM, preset="abstract")
    assert a1 is a2
    # prefetch=0 plans gather at use time: simulated blocking
    cm_block = dataclasses.replace(CM, overlap_comm=False)
    assert a1.makespan == pytest.approx(
        simulate(plan.table, cm_block).makespan)
    assert a1.gathers_per_rank == a1.n_gather / plan.table.P
    # prefetch>=1 overlaps the gathers: never slower than blocking
    a_pf = plan.with_prefetch(1).analyze(CM, preset="abstract")
    assert a_pf.prefetch == 1
    assert a_pf.makespan <= a1.makespan + 1e-12
    assert a_pf.makespan == pytest.approx(simulate(plan.table, CM).makespan)


def test_plan_with_prefetch_repacks():
    sp = SchedParams(P=4, V=2, n_mb=8, unit=4)
    plan = SchedulePlan.build("zeropp", sp)
    pf = plan.with_prefetch(2)
    assert pf is not plan and pf.prefetch == 2
    assert pf.table is plan.table  # same analyzed table
    assert plan.with_prefetch(0) is plan
    # prefetch moves gather issue ticks earlier, never later
    g0 = np.argwhere(plan.packed.gather_v >= 0)
    g2 = np.argwhere(pf.packed.gather_v >= 0)
    assert (g0[:, 0] >= g2[:, 0]).all() or len(g0) == 0


def test_preset_cost_models():
    cm_a = preset_cost_model("a800", None, P=4, V=2)
    assert cm_a.t_f == CostModel().t_f  # abstract fallback without a cfg
    with pytest.raises(ValueError, match="unknown cost preset"):
        preset_cost_model("h100", None, P=4, V=2)
    fused = fused_cost_model(CM)
    assert fused.t_b == CM.t_b + CM.t_w and fused.t_w == 0.0
    assert fused.m_wstash == 0.0


def test_preset_alpha_beta_collective_costs():
    """Gather/reduce ticks are costed α·n_coll + β·bytes: per-tensor
    collectives (coalesce='none') pay the launch latency #tensors times,
    the flat layout once — and only the α term differs."""
    from repro.core.plan import COLLECTIVE_ALPHA_BETA
    from repro.models.common import ModelConfig

    cfg = ModelConfig(name="t", n_layers=8, d_model=256, n_heads=4,
                      n_kv_heads=4, d_ff=1024, vocab=1024)
    kw = dict(P=4, V=2, seq=128, mbs=1, dp=8)
    flat = preset_cost_model("a800", cfg, **kw, n_coll_gather=1)
    per_t = preset_cost_model("a800", cfg, **kw, n_coll_gather=12)
    alpha, beta = COLLECTIVE_ALPHA_BETA["a800"]
    assert per_t.t_gather - flat.t_gather == pytest.approx(11 * alpha)
    assert flat.n_coll_gather == 1 and per_t.n_coll_gather == 12
    assert flat.coll_alpha == alpha
    # the bandwidth term is unchanged by coalescing
    assert flat.t_gather - alpha == pytest.approx(
        per_t.t_gather - 12 * alpha)
    # and the α term propagates into the simulated makespan ranking
    sp = SchedParams(P=4, V=2, n_mb=8, unit=4)
    plan_f = SchedulePlan.build("zeropp", sp)
    plan_n = SchedulePlan.build("zeropp", sp)
    mf = plan_f.analyze(flat, preset="a800").makespan
    mn = plan_n.analyze(per_t, preset="a800").makespan
    assert mf < mn  # latency-bound per-tensor ticks cost real makespan
    assert plan_n.analyze(per_t, preset="a800").n_coll_gather == 12


# --------------------------------------------------------------------------- #
# select_plan (the schedule="auto" engine)
# --------------------------------------------------------------------------- #


def test_select_plan_beats_every_builtin():
    sel = select_plan(4, 2, 8, 4, CM, preset="abstract")
    names = set(candidate_schedules())
    assert names <= set(sel.candidates) | set()
    spans = {n: a.makespan for n, a in sel.candidates.items()
             if isinstance(a, PlanAnalysis)}
    assert len(spans) >= 5
    for n, m in spans.items():
        assert sel.analysis.makespan <= m + 1e-12, (n, m)
    assert sel.selected.name in spans
    # ranking() is sorted by makespan
    r = sel.ranking()
    assert [m for _, m in r] == sorted(m for _, m in r)


def test_select_plan_caches_per_key():
    clear_plan_cache()
    key = ("test-arch", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract")
    s1 = select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key)
    s2 = select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key)
    assert s1 is s2
    s3 = select_plan(4, 2, 8, 4, CM, preset="abstract")  # no key: fresh
    assert s3 is not s1
    clear_plan_cache()


def test_select_plan_skips_broken_candidates():
    from repro.api.registry import register_schedule, SCHEDULE_REGISTRY

    name = "always-broken-plan-test"

    @register_schedule(name)
    def _broken(sp):
        raise RuntimeError("intentionally broken")

    try:
        sel = select_plan(2, 1, 4, 4, CM, preset="abstract",
                          candidates=["zeropp", name])
        assert sel.selected.name == "zeropp"
        assert str(sel.candidates[name]).startswith("failed:")
    finally:
        # keep the process-wide registry clean for later tests
        SCHEDULE_REGISTRY._entries.pop(name, None)
    assert name not in SCHEDULE_REGISTRY


def test_unit_gated_unit_depth_vs_full_depth():
    """Unit-gated candidates keep the requested unit; others run with
    full-depth buffers (n_mb) so postponed/fused work stays sound."""
    sel = select_plan(4, 1, 8, 2, CM, preset="abstract",
                      candidates=["zeropp", "1f1b", "autogen"])
    assert isinstance(sel.candidates["zeropp"], PlanAnalysis)
    # rebuild to inspect unit depths directly
    z = SchedulePlan.build("zeropp", SchedParams(P=4, V=1, n_mb=8, unit=2))
    assert z.packed.U == 2
    a = SchedulePlan.build("autogen", SchedParams(P=4, V=1, n_mb=8,
                                                  unit=2))
    assert a.packed.U == 8


def test_pack_table_roundtrip_matches_plan():
    sp = SchedParams(P=2, V=2, n_mb=4, unit=4)
    tt = generate("zeropp", sp)
    pt = pack_table(tt)
    plan = SchedulePlan.from_table("zeropp", sp, tt)
    for f in ("kind", "mb", "v", "gather_v", "reduce_v",
              "recv_f_u", "recv_b_u"):
        assert np.array_equal(getattr(pt, f), getattr(plan.packed, f)), f


# --------------------------------------------------------------------------- #
# PR-8: measured re-ranking (coarse->fine) + persisted plan cache
# --------------------------------------------------------------------------- #

import dataclasses as _dc
import json as _json

from repro.core import plan_cache
from repro.core.plan import plan_cache_info

_CANDS = ["zeropp", "1f1b", "gpipe"]


def _fake_measure(us_by_name):
    def measure(plan):
        return us_by_name[plan.name]
    return measure


def test_measured_refine_reranks_by_wallclock():
    """A measure_fn that inverts the simulated order flips the winner,
    and the winner's measured time is <= the simulated-best's measured
    time (the acceptance-criterion inequality, by construction)."""
    clear_plan_cache()
    sim = select_plan(4, 2, 8, 4, CM, preset="abstract",
                      candidates=list(_CANDS))
    order = [n for n, _ in sim.ranking() if n in _CANDS]
    us = {n: float(100 * (i + 1)) for i, n in enumerate(reversed(order))}
    sel = select_plan(4, 2, 8, 4, CM, preset="abstract",
                      candidates=list(_CANDS),
                      measure_fn=_fake_measure(us), top_k=3)
    assert sel.provenance == "search+measured"
    assert plan_cache_info()["measure_calls"] == 3
    assert sel.selected.name == order[-1]          # worst sim, best measured
    assert sel.measured == us
    assert sel.profile["simulated_best"] == order[0]
    assert sel.measured[sel.selected.name] <= \
        sel.profile["simulated_best_us"]
    # measured numbers land on the candidates' analyses
    for n, v in us.items():
        assert sel.candidates[n].measured_us == v
    # measured_ranking() is sorted by measured us
    mr = sel.measured_ranking()
    assert [v for _, v in mr] == sorted(us.values())
    clear_plan_cache()


def test_profile_budget_caps_to_one_measurement():
    """profile_budget_s=0 still measures the sim-best survivor (exactly
    one measurement), so the selection never regresses vs plain auto."""
    clear_plan_cache()
    calls = []

    def measure(plan):
        calls.append(plan.name)
        return 123.0

    sel = select_plan(4, 2, 8, 4, CM, preset="abstract",
                      candidates=list(_CANDS), measure_fn=measure,
                      top_k=3, profile_budget_s=0.0)
    assert len(calls) == 1
    assert plan_cache_info()["measure_calls"] == 1
    assert calls[0] == sel.profile["simulated_best"]
    assert sel.selected.name == calls[0]
    clear_plan_cache()


def test_measure_failure_excludes_candidate():
    """A plan whose measurement raises cannot win on merit; the others'
    measured ranking decides, and the failure is recorded."""
    clear_plan_cache()
    sim = select_plan(4, 2, 8, 4, CM, preset="abstract",
                      candidates=list(_CANDS))
    order = [n for n, _ in sim.ranking() if n in _CANDS]

    def measure(plan):
        if plan.name == order[0]:
            raise RuntimeError("compile blew up")
        return {order[1]: 50.0, order[2]: 60.0}[plan.name]

    sel = select_plan(4, 2, 8, 4, CM, preset="abstract",
                      candidates=list(_CANDS), measure_fn=measure,
                      top_k=3)
    assert sel.selected.name == order[1]
    assert str(sel.candidates[order[0]]).startswith("measure failed:")
    assert order[0] not in sel.measured
    clear_plan_cache()


def test_persisted_cache_roundtrip_zero_simulates():
    """select -> persist -> wipe memory -> reload from disk with ZERO
    simulate/measure calls, identical winner, tick-identical table."""
    clear_plan_cache()
    key = ("rt-arch", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
           "flat", "none", None, "auto", None)
    s1 = select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                     persist=True)
    assert plan_cache_info()["persisted"]["entries"] == 1
    clear_plan_cache()            # memory + counters only; disk survives
    s2 = select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                     persist=True)
    info = plan_cache_info()
    assert info["simulate_calls"] == 0
    assert info["measure_calls"] == 0
    assert info["disk_hits"] == {key: 1}
    assert s2.provenance == "cache:disk"
    assert s2.selected.name == s1.selected.name
    for f in ("kind", "mb", "v", "gather_v", "reduce_v"):
        assert np.array_equal(getattr(s2.selected.packed, f),
                              getattr(s1.selected.packed, f)), f
    assert abs(s2.analysis.makespan - s1.analysis.makespan) < 1e-9
    # the disk hit seeds the in-memory cache: third lookup is identity
    s3 = select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                     persist=True)
    assert s3 is s2
    assert plan_cache_info()["hits"] == {key: 1}
    clear_plan_cache(persisted=True)


def test_persisted_cache_restores_measured_numbers():
    """A profiled selection round-trips its measured ranking + profile
    metadata through the disk cache."""
    clear_plan_cache()
    key = ("meas-arch", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
           "flat", "none", None, "auto_profiled", 3)
    us = {"zeropp": 90.0, "1f1b": 70.0, "gpipe": 110.0}
    s1 = select_plan(4, 2, 8, 4, CM, preset="abstract",
                     candidates=list(_CANDS), cache_key=key,
                     persist=True, measure_fn=_fake_measure(us), top_k=3)
    clear_plan_cache()
    s2 = select_plan(4, 2, 8, 4, CM, preset="abstract",
                     candidates=list(_CANDS), cache_key=key,
                     persist=True, measure_fn=_fake_measure(us), top_k=3)
    assert plan_cache_info()["measure_calls"] == 0   # disk hit: no re-run
    assert s2.selected.name == s1.selected.name == "1f1b"
    assert s2.measured == us
    assert s2.profile["simulated_best"] == s1.profile["simulated_best"]
    assert s2.candidates["1f1b"].measured_us == 70.0
    clear_plan_cache(persisted=True)


def test_persisted_cache_invalidated_on_cost_model_change():
    """Changing the measured alpha-beta profile (coll_alpha) changes the
    fingerprint: the stale disk entry is ignored and a clean search
    runs."""
    clear_plan_cache()
    key = ("inv-arch", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
           "flat", "none", None, "auto", None)
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                persist=True)
    clear_plan_cache()
    cm2 = _dc.replace(CM, coll_alpha=0.25)
    sel = select_plan(4, 2, 8, 4, cm2, preset="abstract", cache_key=key,
                      persist=True)
    info = plan_cache_info()
    assert info["disk_hits"] == {}
    assert info["misses"] == 1 and info["simulate_calls"] > 0
    assert sel.provenance == "search"
    clear_plan_cache(persisted=True)


def test_persisted_cache_invalidated_on_knob_schema_change(monkeypatch):
    """Growing the selection-key schema (a new knob in a later version)
    must invalidate every stored entry."""
    from repro.core import plan as plan_mod

    clear_plan_cache()
    key = ("schema-arch", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
           "flat", "none", None, "auto", None)
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                persist=True)
    clear_plan_cache()
    monkeypatch.setattr(plan_mod, "SELECT_KEY_SCHEMA",
                        plan_mod.SELECT_KEY_SCHEMA + ("new_knob",))
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                persist=True)
    info = plan_cache_info()
    assert info["disk_hits"] == {} and info["simulate_calls"] > 0
    clear_plan_cache(persisted=True)


def test_select_key_schema_covers_moe_mode():
    """Regression: SELECT_KEY_SCHEMA once omitted moe_mode, so an "ep"
    session could replay a "gathered" session's cached plan. The knob
    must be a named key column AND flip the persisted fingerprint."""
    from repro.core.plan import SELECT_KEY_SCHEMA

    assert "moe_mode" in SELECT_KEY_SCHEMA
    # one column per key element: Session._select_key builds keys
    # positionally against this schema
    fp_a = plan_cache.fingerprint(CM, SELECT_KEY_SCHEMA)
    without = tuple(k for k in SELECT_KEY_SCHEMA if k != "moe_mode")
    fp_b = plan_cache.fingerprint(CM, without)
    assert fp_a != fp_b


def test_preset_cost_model_a2a_terms():
    """EP all-to-all ticks are costed: a per-preset :a2a alpha-beta pair
    feeds t_a2a, and F/B durations stretch by their a2a counts."""
    from repro.core.plan import COLLECTIVE_ALPHA_BETA, preset_cost_model
    from repro.models.common import ModelConfig

    cfg = ModelConfig(name="t", n_layers=8, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab=256)
    for preset in ("a800", "tpu_v5e"):
        assert f"{preset}:a2a" in COLLECTIVE_ALPHA_BETA
        cm0 = preset_cost_model(preset, cfg, P=4, V=2)
        cm2 = preset_cost_model(preset, cfg, P=4, V=2,
                                n_a2a_f=2, n_a2a_b=4, a2a_bytes=1e6)
        from repro.core.simulator import B, F

        assert cm0.t_a2a == 0.0 and cm2.t_a2a > 0.0
        assert cm2.dur(F) == cm0.dur(F) + 2 * cm2.t_a2a
        assert cm2.dur(B) == cm0.dur(B) + 4 * cm2.t_a2a
        # a2a cost participates in the fingerprint (stale plans die)
        assert plan_cache.fingerprint(cm0, ("x",)) \
            != plan_cache.fingerprint(cm2, ("x",))


def test_persisted_cache_corrupt_file_falls_back():
    """Corrupt or partially-valid cache files mean a clean search, never
    an exception."""
    clear_plan_cache()
    path = plan_cache.cache_path()
    key = ("corrupt-arch", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
           "flat", "none", None, "auto", None)
    with open(path, "w") as f:
        f.write("{not json at all")
    sel = select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                      persist=True)
    assert sel.provenance == "search"
    # partial: right fingerprint, garbage record -> also a clean search
    from repro.core.plan import SELECT_KEY_SCHEMA
    fp = plan_cache.fingerprint(CM, SELECT_KEY_SCHEMA)
    with open(path, "w") as f:
        _json.dump({"version": 1, "measurements": {}, "entries": {
            plan_cache.entry_key(key): {"fp": fp,
                                        "record": {"bogus": True}}}}, f)
    clear_plan_cache()
    sel2 = select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                       persist=True)
    assert sel2.provenance == "search"
    assert plan_cache_info()["simulate_calls"] > 0
    clear_plan_cache(persisted=True)


def test_clear_plan_cache_persisted_removes_file():
    clear_plan_cache()
    key = ("clear-arch", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
           "flat", "none", None, "auto", None)
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=key,
                persist=True)
    path = plan_cache.cache_path()
    import os as _os
    assert _os.path.exists(path)
    clear_plan_cache(persisted=True)
    assert not _os.path.exists(path)
    assert plan_cache_info()["persisted"]["entries"] == 0


def test_plan_cache_info_counts_per_key_hits():
    clear_plan_cache()
    k1 = ("hits-a", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
          "flat", "none", None, "auto", None)
    k2 = ("hits-b", 4, 2, 1, 8, 4, 0, 32, 1, 1, 1, "abstract",
          "flat", "none", None, "auto", None)
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=k1)
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=k1)
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=k1)
    select_plan(4, 2, 8, 4, CM, preset="abstract", cache_key=k2)
    info = plan_cache_info()
    assert info["hits"] == {k1: 2}
    assert info["misses"] == 2
    assert info["entries"] == 2
    clear_plan_cache()


def test_measurement_store_is_code_salt_gated(monkeypatch):
    """benchmarks/hillclimb resume entries only replay when the code
    salt matches (a source change re-measures everything)."""
    assert plan_cache.store_measurement("hillclimb|test", 42.5)
    assert plan_cache.load_measurement("hillclimb|test") == 42.5
    monkeypatch.setattr(plan_cache, "code_salt", lambda: "different")
    assert plan_cache.load_measurement("hillclimb|test") is None
