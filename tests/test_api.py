"""Tests for the repro.api facade: registries, spec validation, parity.

Registry/spec/describe tests run in-process (device-free by design —
Session defers all mesh/device work). The numerical parity check runs in
a subprocess with 8 fake devices, like the other SPMD cases.
"""

import subprocess
import sys

import pytest

from repro.api import (
    SessionError,
    RegistryError,
    generate_schedule,
    get_arch,
    greedy_schedule,
    list_archs,
    list_schedules,
    register_arch,
    register_schedule,
    session,
)
from repro.models.common import ModelConfig, RunConfig

TIMEOUT = 1200


# --------------------------------------------------------------------------- #
# Registries
# --------------------------------------------------------------------------- #


def test_builtin_registries_populated():
    archs = list_archs()
    assert "llama3p2_1b" in archs and len(archs) >= 11
    scheds = list_schedules()
    for s in ("zeropp", "gpipe", "1f1b", "interleaved", "bfs",
              "fwd_only"):
        assert s in scheds
    # aliases resolve to the same module as canonical names
    assert get_arch("llama3.2-1b") is get_arch("llama3p2_1b")


def test_arch_registry_round_trip():
    @register_arch("toy-arch-rt", aliases=("toy_arch_rt_alias",))
    class ToyArch:
        @staticmethod
        def reduced():
            cfg = ModelConfig(name="toy", n_layers=2, d_model=32,
                              n_heads=2, n_kv_heads=2, d_ff=64,
                              vocab=64, tie_embeddings=True)
            return cfg, RunConfig(pp=2, vpp=1, microbatches=2,
                                  param_dtype="float32",
                                  compute_dtype="float32")

    assert get_arch("toy-arch-rt") is ToyArch
    assert get_arch("toy_arch_rt_alias") is ToyArch
    assert "toy-arch-rt" in list_archs()
    # duplicate registration is refused unless overwrite=True
    with pytest.raises(RegistryError, match="already registered"):
        register_arch("toy-arch-rt", ToyArch)
    register_arch("toy-arch-rt", ToyArch, overwrite=True)
    # a Session builds from the registered arch without edits to core
    sess = session("toy-arch-rt", overrides=dict(unit=2))
    assert sess.cfg.name == "toy"
    assert sess.describe()["geometry"]["model_ranks"] == 2


def test_arch_registry_rejects_bad_entry():
    with pytest.raises(RegistryError, match="config\\(\\)/reduced\\(\\)"):
        register_arch("bad-arch", object())


def test_schedule_registry_round_trip():
    # custom schedule = greedy scheduler with a custom priority
    @register_schedule("gpipe-clone-rt")
    def gpipe_clone(sp):
        def prio(sp_, kind, u, s):
            return (kind, u, s)
        return greedy_schedule(sp, prio, name="gpipe-clone-rt")

    tt = generate_schedule("gpipe-clone-rt", P=2, V=2, n_mb=4)
    tt.validate()
    assert tt.counts()["F"] == 4 * 2 * 2
    assert "gpipe-clone-rt" in list_schedules()
    with pytest.raises(RegistryError, match="already registered"):
        register_schedule("gpipe-clone-rt", gpipe_clone)


def test_unknown_names_raise_actionable_errors():
    with pytest.raises(RegistryError, match="known:"):
        get_arch("no-such-arch")
    with pytest.raises(RegistryError, match="did you mean"):
        generate_schedule("zropp", P=2, V=1, n_mb=2)


# --------------------------------------------------------------------------- #
# Spec validation (device-free: errors fire before any mesh is built)
# --------------------------------------------------------------------------- #


def test_spec_rejects_unknown_arch():
    with pytest.raises(SessionError, match="unknown architecture"):
        session("no-such-arch")


def test_spec_rejects_bad_override_key():
    with pytest.raises(SessionError, match="valid fields"):
        session("llama3.2-1b", overrides={"microbatch": 4})


def test_spec_rejects_unknown_schedule():
    with pytest.raises(SessionError, match="unknown schedule"):
        session("llama3.2-1b", overrides={"schedule": "zig-zag"})


def test_spec_rejects_bad_mode_and_shape():
    with pytest.raises(SessionError, match="unknown mode"):
        session("llama3.2-1b", mode="training")
    with pytest.raises(SessionError, match="named shapes"):
        session("llama3.2-1b", shape="train_9000k")
    with pytest.raises(SessionError, match="reduced=False"):
        session("llama3.2-1b", reduced=False)  # needs a named shape
    with pytest.raises(SessionError, match="max_seq"):
        session("llama3.2-1b", mode="serve")


def test_spec_rejects_bad_geometry():
    # jamba's hybrid layer pattern is not static per slot at vpp=2 —
    # the facade surfaces the geometry error with context
    with pytest.raises(SessionError, match="invalid geometry"):
        session("jamba-v0.1-52b", overrides={"vpp": 2})


def test_describe_is_device_free():
    sess = session("llama3.2-1b", overrides=dict(microbatches=4, unit=2))
    d = sess.describe()
    assert d["geometry"]["model_ranks"] == 2
    assert d["schedule"]["name"] == "zeropp"
    assert 0.0 <= d["schedule"]["bubble_ratio"] < 1.0
    assert d["schedule"]["unit"] == 2
    assert d["n_params"] > 0
    assert "Session(" in repr(sess)


def test_opt_config_rejects_unknown_keys():
    sess = session("llama3.2-1b", optim=dict(lr=1e-3, momentum=0.9))
    with pytest.raises(SessionError, match="unknown optim option"):
        sess.opt_config()


def test_mem_budget_steers_auto_selection():
    """mem_budget is validated (auto-only, positive) and the selection
    honours it: the winner's simulated peak memory fits the cap, and
    describe() reports the per-candidate memory/makespan trade-off."""
    with pytest.raises(SessionError, match="schedule='auto'"):
        session("llama3.2-1b", mem_budget=1e9)
    with pytest.raises(SessionError, match="positive"):
        session("llama3.2-1b", schedule="auto", mem_budget=0)

    sess = session("llama3.2-1b", schedule="auto",
                   overrides=dict(microbatches=4, unit=2))
    cands = {n: a for n, a in sess.plan_selection.candidates.items()
             if not isinstance(a, str)}
    assert "autogen_gated" in cands
    assert cands["autogen_gated"].stash_depth == 2
    assert cands["autogen"].stash_depth == 4
    # cap below the biggest candidate: the winner must fit
    mems = sorted(a.peak_mem for a in cands.values())
    budget = (mems[0] + mems[-1]) / 2
    sess_b = session("llama3.2-1b", schedule="auto", mem_budget=budget,
                     overrides=dict(microbatches=4, unit=2))
    assert sess_b.plan_selection.analysis.peak_mem <= budget
    assert sess_b.plan_selection is not sess.plan_selection  # own cache
    d = sess_b.describe()
    assert d["schedule"]["auto"]["mem_budget"] == budget
    c = d["schedule"]["auto"]["candidates"]["autogen_gated"]
    assert set(c) == {"makespan", "peak_mem", "stash_depth",
                      "rs_overlap_saved"}
    assert "rs_overlap" in d["schedule"] and "stash_depth" in d["schedule"]


# --------------------------------------------------------------------------- #
# schedule="auto" (device-free selection + describe)
# --------------------------------------------------------------------------- #


def test_schedule_auto_is_device_free_and_optimal():
    sess = session("llama3.2-1b", schedule="auto",
                   overrides=dict(microbatches=4, unit=2))
    sel = sess.plan_selection
    assert sel is not None
    assert sess.rc.schedule == sel.selected.name != "auto"
    spans = {n: a.makespan for n, a in sel.candidates.items()
             if not isinstance(a, str)}
    assert len(spans) >= 5  # every registered built-in simulated
    assert all(sel.analysis.makespan <= m + 1e-12 for m in spans.values())
    d = sess.describe()
    assert d["schedule"]["name"] == sel.selected.name
    assert d["schedule"]["auto"]["selected"] == sel.selected.name
    assert set(spans) <= set(d["schedule"]["auto"]["candidates"])
    assert d["schedule"]["preset"] == "a800"
    assert d["schedule"]["makespan"] > 0


def test_schedule_auto_selection_is_cached():
    kw = dict(schedule="auto", overrides=dict(microbatches=4, unit=2))
    s1 = session("llama3.2-1b", **kw)
    s2 = session("llama3.2-1b", **kw)
    assert s1.plan_selection is s2.plan_selection  # same cache entry
    s3 = session("llama3.2-1b", schedule="auto", cost_preset="tpu_v5e",
                 overrides=dict(microbatches=4, unit=2))
    assert s3.plan_selection is not s1.plan_selection
    assert s3.describe()["schedule"]["preset"] == "tpu_v5e"


# --------------------------------------------------------------------------- #
# schedule="auto_profiled" (spec validation + full cache lifecycle,
# device-free: the measure_fn is monkeypatched so nothing compiles)
# --------------------------------------------------------------------------- #


def test_spec_validates_profile_knobs():
    with pytest.raises(SessionError, match="profile_top_k"):
        session("llama3.2-1b", schedule="auto_profiled", profile_top_k=0)
    with pytest.raises(SessionError, match="profile_budget_s"):
        session("llama3.2-1b", schedule="auto_profiled",
                profile_budget_s=-1.0)
    with pytest.raises(SessionError, match="train"):
        session("llama3.2-1b", mode="serve", schedule="auto_profiled")
    # the profile knobs only steer auto_profiled; anything else rejects
    with pytest.raises(SessionError, match="auto_profiled"):
        session("llama3.2-1b", schedule="auto", profile_top_k=5)
    with pytest.raises(SessionError, match="auto_profiled"):
        session("llama3.2-1b", schedule="zeropp", profile_budget_s=10.0)


def test_schedule_auto_profiled_full_cache_lifecycle(monkeypatch):
    """search+measured -> memory hit -> persisted hit, with the work
    counters proving the warm paths do zero simulate/measure calls."""
    from repro.api.session import Session
    from repro.core.plan import clear_plan_cache, plan_cache_info

    clear_plan_cache(persisted=True)
    calls = []

    def fake_build(self, moe_mode=None):
        # later measurements come back *faster*, so the measured winner
        # differs from the simulated-best (the re-ranking must matter)
        def measure(plan):
            calls.append(plan.name)
            return float(200 - len(calls))
        return measure

    monkeypatch.setattr(Session, "_build_measure_fn", fake_build)
    kw = dict(schedule="auto_profiled",
              overrides=dict(microbatches=4, unit=2))

    s1 = session("llama3.2-1b", **kw)
    sel = s1.plan_selection
    assert s1._plan_source == "search+measured"
    assert sel.provenance == "search+measured"
    assert len(calls) == 3                     # profile_top_k default
    assert calls[0] == sel.profile["simulated_best"]
    assert s1.rc.schedule == sel.selected.name == calls[-1]
    # acceptance inequality: winner measured <= simulated-best measured
    assert sel.measured[sel.selected.name] <= \
        sel.profile["simulated_best_us"]
    d = s1.describe()["schedule"]
    assert d["auto"]["provenance"] == {
        "selection": "search+measured", "this_session": "search+measured"}
    assert d["auto"]["measured"] == sel.measured
    assert d["auto"]["candidates"][sel.selected.name]["measured_us"] == \
        sel.measured[sel.selected.name]
    assert d["cache"]["measure_calls"] == 3

    # second identical session: in-memory hit, zero extra work
    before = plan_cache_info()
    s2 = session("llama3.2-1b", **kw)
    after = plan_cache_info()
    assert s2._plan_source == "memory-hit"
    assert s2.plan_selection is sel
    assert after["simulate_calls"] == before["simulate_calls"]
    assert after["measure_calls"] == before["measure_calls"]
    assert len(calls) == 3

    # wipe memory: third session reloads from disk — still zero work
    clear_plan_cache()
    s3 = session("llama3.2-1b", **kw)
    info = plan_cache_info()
    assert s3._plan_source == "persisted-hit"
    assert info["simulate_calls"] == 0 and info["measure_calls"] == 0
    assert s3.plan_selection.provenance == "cache:disk"
    assert s3.plan_selection.selected.name == sel.selected.name
    assert s3.plan_selection.measured == sel.measured
    d3 = s3.describe()["schedule"]
    assert d3["auto"]["provenance"]["this_session"] == "persisted-hit"
    assert d3["cache"]["disk_hits"] == 1
    assert len(calls) == 3
    clear_plan_cache(persisted=True)


def test_schedule_kw_and_override_consistency():
    # schedule= kw is shorthand for overrides["schedule"]
    s = session("llama3.2-1b", schedule="gpipe")
    assert s.rc.schedule == "gpipe"
    with pytest.raises(SessionError, match="twice and inconsistently"):
        session("llama3.2-1b", schedule="gpipe",
                overrides=dict(schedule="1f1b"))
    with pytest.raises(SessionError, match="unknown cost_preset"):
        session("llama3.2-1b", cost_preset="h100")


def test_describe_uses_simulator_not_tick_counts():
    sess = session("llama3.2-1b", overrides=dict(microbatches=4, unit=2))
    d = sess.describe()["schedule"]
    for k in ("preset", "makespan", "peak_mem", "bubble_ratio",
              "gathers_per_rank", "comm_frac"):
        assert k in d, k
    # simulated bubble fraction, not the tick-quantized ratio
    from repro.api import SchedParams, generate_schedule
    tt = generate_schedule("zeropp", SchedParams(P=2, V=1, n_mb=4, unit=2))
    assert d["ticks"] == tt.T


# --------------------------------------------------------------------------- #
# Numerical parity facade vs hand-assembled path (subprocess, 8 devices)
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_session_matches_hand_assembled_path():
    import os

    cmd = [sys.executable, "-m", "tests.spmd_case", "api_parity",
           "llama3.2-1b"]
    p = subprocess.run(
        cmd, capture_output=True, text=True, timeout=TIMEOUT,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert "CASE_OK api_parity" in p.stdout, (
        f"api_parity failed\n--- stdout ---\n{p.stdout[-3000:]}"
        f"\n--- stderr ---\n{p.stderr[-3000:]}"
    )


# --------------------------------------------------------------------------- #
# Topology (device-free: layout derivation never touches jax)
# --------------------------------------------------------------------------- #


def test_topology_preset_layouts():
    from repro.api import TOPOLOGY_PRESETS

    # a800 NVLink confinement: 256 devices / model=16 would give data=16
    # spanning two 8-GPU hosts; the rule folds the excess into pods
    lay = TOPOLOGY_PRESETS["gpu_cluster"].axis_layout(
        16, cost_preset="a800")
    assert (lay["pods"], lay["data"], lay["model"]) == (2, 8, 16)
    assert lay["devices_used"] == 256
    # tpu_v5e keeps the full-pod data axis
    lay = TOPOLOGY_PRESETS["tpu_pod"].axis_layout(
        16, cost_preset="tpu_v5e")
    assert (lay["pods"], lay["data"], lay["model"]) == (1, 16, 16)
    lay = TOPOLOGY_PRESETS["tpu_pod_x2"].axis_layout(
        16, cost_preset="tpu_v5e")
    assert (lay["pods"], lay["data"], lay["model"]) == (2, 16, 16)


def test_topology_explicit_data_and_shrink():
    from repro.api import Topology
    from repro.runtime.topology import TopologyError

    t = Topology(kind="fake_cpu", data=4)
    lay = t.axis_layout(2)
    assert (lay["data"], lay["model"]) == (4, 2)
    s = t.shrink(model_ranks=2)
    assert s.data == 2
    assert s.shrink(model_ranks=2).data == 1
    with pytest.raises(TopologyError, match="nothing left to shrink"):
        s.shrink(model_ranks=2).shrink(model_ranks=2)


def test_topology_validation_errors():
    from repro.api import Topology
    from repro.runtime.topology import TopologyError, resolve_topology

    with pytest.raises(TopologyError, match="unknown topology kind"):
        Topology(kind="warp_drive").validate()
    with pytest.raises(TopologyError, match="devices_per_host"):
        Topology(kind="gpu_cluster", hosts=4).validate()
    with pytest.raises(TopologyError, match="partition"):
        Topology(kind="gpu_cluster", hosts=5, devices_per_host=8,
                 pods=2).validate()
    with pytest.raises(TopologyError, match="unknown topology preset"):
        resolve_topology("no-such-preset")


def test_spec_topology_knob():
    from repro.api import Topology

    # topology= subsumes the legacy placement knobs — clash is an error
    with pytest.raises(SessionError, match="subsumes"):
        session("llama3.2-1b", topology="fake_cpu", data=2)
    with pytest.raises(SessionError, match="unknown topology preset"):
        session("llama3.2-1b", topology="no-such-preset")
    # describe()["topology"] resolves the layout without devices
    sess = session("llama3.2-1b",
                   topology=Topology(kind="fake_cpu", data=2),
                   overrides=dict(microbatches=4, unit=2))
    topo = sess.describe()["topology"]
    assert topo["kind"] == "fake_cpu"
    assert topo["layout"] == {"pods": 1, "data": 2, "model": 2,
                              "devices_used": 4, "devices_total": 8}
    # without topology= the report still carries the resolved layout
    sess = session("llama3.2-1b", data=2,
                   overrides=dict(microbatches=4, unit=2))
    topo = sess.describe()["topology"]
    assert topo["kind"] is None and topo["layout"]["data"] == 2


def test_topology_production_mesh_presets_agree():
    """launch.mesh's production builders are now topology presets; the
    derived layouts must match the former hard-coded 16x16 pod."""
    from repro.api import TOPOLOGY_PRESETS

    lay = TOPOLOGY_PRESETS["tpu_pod"].axis_layout(
        16, cost_preset="tpu_v5e")
    assert lay["devices_total"] == 256 == 16 * 16
    lay2 = TOPOLOGY_PRESETS["tpu_pod_x2"].axis_layout(
        16, cost_preset="tpu_v5e")
    assert lay2["devices_total"] == 512 and lay2["pods"] == 2
