"""Per-architecture smoke tests: reduced same-family config, one forward
(+loss) on CPU, asserting output shapes and no NaNs.  Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models.common import SHAPES

ARCH_IDS = [
    "whisper-large-v3",
    "qwen2-moe-a2.7b",
    "deepseek-v3-671b",
    "jamba-v0.1-52b",
    "phi-3-vision-4.2b",
    "minitron-4b",
    "yi-9b",
    "phi4-mini-3.8b",
    "llama3.2-1b",
    "xlstm-1.3b",
    "gpt_paper",
]


def _toy_inputs(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    enc = None
    if cfg.encdec is not None:
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encdec.enc_ctx, cfg.d_model)
        ) * 0.1
    return tokens, labels, enc


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward(arch):
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    params = M.init_all_params(cfg, rc, jax.random.PRNGKey(0))
    tokens, labels, enc = _toy_inputs(cfg)
    logits, aux = M.reference_logits(cfg, rc, params, tokens, enc_tokens=enc)
    assert logits.shape == (*tokens.shape, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    loss = M.reference_loss(cfg, rc, params, tokens, labels, enc_tokens=enc)
    assert np.isfinite(float(loss))
    # untrained loss should be near ln(vocab)
    assert float(loss) < np.log(cfg.vocab) * 3


@pytest.mark.parametrize("arch", ["llama3.2-1b", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "deepseek-v3-671b"])
def test_reduced_train_step_decreases_loss(arch):
    """A couple of plain jax.grad SGD steps on the reference model."""
    mod = M.get_arch(arch)
    cfg, rc = mod.reduced()
    params = M.init_all_params(cfg, rc, jax.random.PRNGKey(0))
    tokens, labels, enc = _toy_inputs(cfg, b=2, s=8)

    loss_fn = lambda p: M.reference_loss(cfg, rc, p, tokens, labels,
                                         enc_tokens=enc)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    l0, g = vg(params)
    # exp-gated recurrences (mamba/mLSTM) need small steps on toy configs
    lr = 0.05 if (cfg.mamba or cfg.xlstm) else 0.2
    for _ in range(5):
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        l1, g = vg(params)
    assert float(l1) < float(l0), (float(l0), float(l1))


def test_production_configs_match_brief():
    """Exact hyper-parameters from the assignment brief."""
    expect = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, g, ff, vcb) in expect.items():
        cfg = M.get_arch(arch).config()
        assert cfg.n_layers == L and cfg.d_model == d
        assert cfg.n_heads == h and cfg.n_kv_heads == g
        assert cfg.d_ff == ff and cfg.vocab == vcb
        # geometry must build (static layer kinds)
        rc = M.get_arch(arch).production_run("train_4k")
        geo = M.build_geometry(cfg, rc)
        assert geo.model_ranks == 16
    ds = M.get_arch("deepseek-v3-671b").config()
    assert ds.moe.n_experts == 256 and ds.moe.top_k == 8 and ds.mtp
    qw = M.get_arch("qwen2-moe-a2.7b").config()
    assert qw.moe.n_experts == 60 and qw.moe.top_k == 4
