"""Fault-tolerance and elasticity tests.

Fast cases exercise the host-side controller pieces in-process — the
corrupt-checkpoint fallback *chain*, max_failures exhaustion, the
async-save wait() on the failure path, and the describe() surfacing.
The end-to-end elastic cases (train shrink 4→2 data ranks bit-exact,
ServeEngine.reshard, EngineRouter failover) run in subprocesses with
fake host devices via tests/spmd_case.py.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.fault_tolerance import (
    FaultToleranceConfig,
    TrainController,
)
from tests.test_pipeline_equiv import _run


# --------------------------------------------------------------------------- #
# Checkpoint fallback chain (no devices)
# --------------------------------------------------------------------------- #


def test_restore_latest_falls_back_through_corruption_chain(tmp_path):
    """Both of the two newest checkpoints corrupt (one truncated leaf,
    one missing manifest) -> restore_latest walks back to the oldest
    intact step instead of failing or loading garbage."""
    ctl = TrainController(str(tmp_path), FaultToleranceConfig(keep=3))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    for step in (2, 4, 6):
        ctl.mgr.save(step, {"w": tree["w"] + step},
                     extra={"step": step}, blocking=True)
    # newest: truncated npy payload
    with open(os.path.join(str(tmp_path), "step_000000006", "w.npy"),
              "wb") as f:
        f.write(b"\x93NUMPY")
    # second newest: manifest gone entirely
    os.remove(os.path.join(str(tmp_path), "step_000000004",
                           "manifest.json"))
    got, manifest = ctl.restore_latest()
    assert manifest["extra"]["step"] == 2
    np.testing.assert_array_equal(got["w"], np.asarray(tree["w"]) + 2)


def test_restore_latest_with_every_step_corrupt_returns_none(tmp_path):
    ctl = TrainController(str(tmp_path), FaultToleranceConfig())
    ctl.mgr.save(1, {"w": jnp.ones(3)}, blocking=True)
    os.remove(os.path.join(str(tmp_path), "step_000000001",
                           "manifest.json"))
    assert ctl.restore_latest() == (None, None)


# --------------------------------------------------------------------------- #
# Controller failure paths (no devices)
# --------------------------------------------------------------------------- #


def _counting_build(calls, fail_steps=(), fail_once=False):
    armed = set(fail_steps)

    def build(restored, manifest):
        calls["builds"] += 1
        state = {"x": jnp.asarray(restored["x"]) if restored
                 else jnp.zeros(())}

        def run_one(state, step):
            if step in armed:
                if fail_once:
                    armed.discard(step)
                raise RuntimeError(f"boom at {step}")
            return {"x": state["x"] + 1.0}, {"x": float(state["x"])}

        return state, run_one, lambda s: s

    return build


def test_max_failures_exhaustion_reraises(tmp_path):
    """A step that fails on every attempt burns through max_failures and
    then re-raises the real error instead of looping forever."""
    ctl = TrainController(str(tmp_path), FaultToleranceConfig(
        ckpt_every=2, max_failures=3, async_save=False))
    calls = {"builds": 0}
    with pytest.raises(RuntimeError, match="boom at 3"):
        ctl.run(_counting_build(calls, fail_steps={3}), total_steps=6)
    assert ctl.failures == 3
    assert calls["builds"] == 3           # original + 2 restarts
    # every restart resumed from the last good checkpoint
    assert ctl.resume_steps == [2, 2]
    assert ctl.summary()["resume_steps"] == [2, 2]


def test_failure_path_waits_for_async_saves(tmp_path):
    """With async_save on, a failure right after a checkpoint was queued
    must wait() for the background save before restoring — the restart
    resumes from the freshest step, not a stale one."""
    ctl = TrainController(str(tmp_path), FaultToleranceConfig(
        ckpt_every=1, max_failures=3, async_save=True))
    calls = {"builds": 0}
    state, hist = ctl.run(_counting_build(calls, fail_steps={4},
                                          fail_once=True),
                          total_steps=6)
    assert calls["builds"] == 2 and ctl.failures == 1
    # the step-4 save was in flight when step 4 failed; wait() made it
    # durable, so the restart resumed at 4 (no recompute of 0..3)
    assert ctl.resume_steps == [4]
    assert [s for s, _ in hist] == [0, 1, 2, 3, 4, 5]
    assert float(state["x"]) == 6.0


def test_summary_and_attach_surface_in_describe():
    """attach() hooks the controller into Session.describe() without
    touching devices; summary() carries the counters."""
    import tempfile

    from repro.api import session

    ctl = TrainController(tempfile.mkdtemp(), FaultToleranceConfig(
        ckpt_every=5, max_failures=2))
    sess = session("llama3.2-1b", topology="fake_cpu",
                   overrides=dict(microbatches=4, unit=2))
    assert "fault_tolerance" not in sess.describe()
    assert ctl.attach(sess) is ctl
    ft = sess.describe()["fault_tolerance"]
    assert ft["failures"] == 0 and ft["max_failures"] == 2
    assert ft["ckpt_every"] == 5 and ft["ckpt_steps"] == []
    assert ft["straggler_flags"] == 0 and ft["resume_steps"] == []


# --------------------------------------------------------------------------- #
# End-to-end elastic cases (subprocess, 8 fake devices)
# --------------------------------------------------------------------------- #


def test_elastic_train_shrinks_topology_bit_exact():
    """Injected failure mid-run -> restart on a data-halved topology;
    the post-restore loss trajectory is bit-exact vs a clean restore."""
    _run("elastic_train", "llama3.2-1b")


def test_serve_reshard_zero_drops_token_identical():
    """ServeEngine.reshard parks a staggered in-flight workload and
    re-admits it on the shrunk mesh with identical token streams."""
    _run("serve_reshard", "llama3.2-1b")


def test_router_two_replicas_token_identical_with_failover():
    """EngineRouter: 2 replicas ≡ 1 engine; replica kill moves work."""
    _run("router_equiv", "llama3.2-1b")
