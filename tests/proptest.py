"""Tiny property-based testing shim.

``hypothesis`` is not installable in this offline container, so we provide a
minimal seeded random-sweep decorator with the same spirit: each test runs
over N randomized cases drawn from explicit strategies, with the failing
seed printed for reproduction.
"""

from __future__ import annotations

import functools
import os

import numpy as np

N_CASES = int(os.environ.get("PROPTEST_CASES", "12"))


class Draw:
    def __init__(self, rng: np.random.Generator):
        self.rng = rng

    def ints(self, lo: int, hi: int) -> int:
        """Inclusive range."""
        return int(self.rng.integers(lo, hi + 1))

    def choice(self, xs):
        return xs[int(self.rng.integers(0, len(xs)))]

    def floats(self, lo: float, hi: float) -> float:
        return float(self.rng.uniform(lo, hi))

    def bool(self) -> bool:
        return bool(self.rng.integers(0, 2))


def propcase(n_cases: int | None = None, seed: int = 0):
    """Decorator: run ``fn(draw)`` for n randomized cases."""

    def deco(fn):
        # NOTE: deliberately no functools.wraps — pytest must not see the
        # inner function's `draw` parameter (it would treat it as a fixture).
        def wrapper():
            n = n_cases or N_CASES
            for case in range(n):
                rng = np.random.default_rng(seed * 7919 + case)
                try:
                    fn(Draw(rng))
                except Exception as e:  # pragma: no cover
                    raise AssertionError(
                        f"property case {case} (seed={seed * 7919 + case}) "
                        f"failed: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
