"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_xent import softmax_xent
from repro.kernels.paged_attention import (
    decode_attention as pallas_decode_attention,
    flash_attention_slotted,
    paged_attention as pallas_paged_attention,
)
from repro.kernels.selective_scan import selective_scan
from tests.proptest import propcase


@propcase(n_cases=10)
def test_flash_attention_sweep(draw):
    b = draw.ints(1, 2)
    h = draw.choice([2, 4, 8])
    g = draw.choice([x for x in (1, 2, 4) if h % x == 0])
    e = draw.choice([32, 64])
    ev = draw.choice([e, e // 2])
    sq = draw.choice([64, 128, 200, 256])
    sk = draw.choice([sq, 2 * sq])
    causal = draw.bool() if sq == sk else False
    dtype = draw.choice([jnp.float32, jnp.bfloat16])
    q = jax.random.normal(jax.random.PRNGKey(draw.ints(0, 99)),
                          (b, sq, h, e)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, g, e)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, g, ev)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention(q, k, v, causal=causal, block_k=128)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_attention_matches_naive_oracle():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 32))
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=1e-5)


@propcase(n_cases=8)
def test_selective_scan_sweep(draw):
    b = draw.ints(1, 2)
    s = draw.choice([64, 130, 256])
    d = draw.choice([64, 128, 192])
    n = draw.choice([4, 8, 16])
    ks = jax.random.split(jax.random.PRNGKey(draw.ints(0, 99)), 6)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (d,))
    got = selective_scan(x, dt, A, B, C, D, chunk=64, block_d=64,
                         interpret=True)
    want = ref.selective_scan(x, dt, A, B, C, D, chunk=128)
    np.testing.assert_allclose(got, want, atol=5e-4)


def test_selective_scan_matches_sequential():
    b, s, d, n = 1, 100, 32, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (d,))
    h = jnp.zeros((b, d, n))
    ys = []
    for t in range(s):
        h, y = ref.selective_scan_step(h, x[:, t], dt[:, t], A, B[:, t],
                                       C[:, t], D)
        ys.append(y)
    seq = jnp.stack(ys, 1)
    got = selective_scan(x, dt, A, B, C, D, chunk=32, block_d=32,
                         interpret=True)
    np.testing.assert_allclose(got, seq, atol=5e-4)


@propcase(n_cases=6)
def test_fused_xent_sweep(draw):
    n = draw.choice([64, 200, 256])
    d = draw.choice([32, 64])
    v = draw.choice([500, 1000, 1024])
    ks = jax.random.split(jax.random.PRNGKey(draw.ints(0, 99)), 3)
    h = jax.random.normal(ks[0], (n, d)) * 0.5
    w = jax.random.normal(ks[1], (d, v)) * 0.1
    labels = jax.random.randint(ks[2], (n,), 0, v)
    loss_k, (dh_k, dw_k) = softmax_xent(h, w, labels, block_n=128,
                                        block_v=256, interpret=True)
    loss_r, (dh_r, dw_r) = ref.softmax_xent(h, w, labels, chunk=256)
    assert abs(float(loss_k) - float(loss_r)) < 1e-4
    np.testing.assert_allclose(dh_k, dh_r, atol=1e-5)
    np.testing.assert_allclose(dw_k, dw_r, atol=1e-5)


def test_xent_ref_matches_autodiff():
    n, d, v = 64, 16, 300
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (n, d)) * 0.5
    w = jax.random.normal(ks[1], (d, v)) * 0.2
    labels = jax.random.randint(ks[2], (n,), 0, v)
    loss_r, (dh_r, dw_r) = ref.softmax_xent(h, w, labels, chunk=128)
    l_n, (gh, gw) = jax.value_and_grad(
        lambda h, w: ref.softmax_xent_naive(h, w, labels),
        argnums=(0, 1))(h, w)
    assert abs(float(loss_r) - float(l_n)) < 1e-5
    np.testing.assert_allclose(dh_r, gh, atol=1e-5)
    np.testing.assert_allclose(dw_r, gw, atol=1e-5)


def test_mlstm_chunkwise_matches_step():
    b, s, h, e = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (b, s, h, e))
    k = jax.random.normal(ks[1], (b, s, h, e))
    v = jax.random.normal(ks[2], (b, s, h, e))
    ig = jax.random.normal(ks[3], (b, s, h)) * 0.5
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    got = ref.mlstm_chunkwise(q, k, v, ig, fg, chunk=16)
    state = None
    ys = []
    C = jnp.zeros((b, h, e, e))
    nrm = jnp.zeros((b, h, e))
    m = jnp.zeros((b, h))
    st = (C, nrm, m)
    for t in range(s):
        st, y = ref.mlstm_step(st, q[:, t], k[:, t], v[:, t], ig[:, t],
                               fg[:, t])
        ys.append(y)
    seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(got, seq, atol=2e-4)


@propcase(n_cases=8)
def test_slotted_attention_sweep(draw):
    """Per-row pos vector (staggered slots), GQA and MLA-absorbed dims."""
    b = draw.ints(2, 4)
    h = draw.choice([2, 4])
    g = draw.choice([x for x in (1, 2) if h % x == 0])
    e = draw.choice([16, 32])
    ev = draw.choice([e, e // 2])   # MLA-absorbed: value dim != qk dim
    sq = draw.choice([1, 3, 5])
    S = draw.choice([32, 48])
    dtype = draw.choice([jnp.float32, jnp.bfloat16])
    ks = jax.random.split(jax.random.PRNGKey(draw.ints(0, 99)), 4)
    q = jax.random.normal(ks[0], (b, sq, h, e)).astype(dtype)
    k = jax.random.normal(ks[1], (b, S, g, e)).astype(dtype)
    v = jax.random.normal(ks[2], (b, S, g, ev)).astype(dtype)
    pos = jax.random.randint(ks[3], (b,), 0, S - sq + 1)
    got = flash_attention_slotted(q, k, v, pos=pos, block_k=16,
                                  interpret=True)
    want = ref.attention(q, k, v, causal=True, q_offset=pos, block_k=16)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_slotted_decode_stats_match_and_combine():
    """Window mode emits ref-layout (m, l, acc) partials that merge via
    combine_decode_shards identically to the unsharded reference."""
    b, h, g, e, S = 3, 4, 2, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, 1, h, e))
    k = jax.random.normal(ks[1], (b, S, g, e))
    v = jax.random.normal(ks[2], (b, S, g, e))
    cache_len = jnp.asarray([1, 13, 32], jnp.int32)
    got, (m, l, acc) = pallas_decode_attention(q, k, v, cache_len,
                                               block_k=8, interpret=True)
    want, (mr, lr, accr) = ref.decode_attention(q, k, v, cache_len)
    np.testing.assert_allclose(got, want, atol=2e-5)
    np.testing.assert_allclose(l, lr, rtol=1e-5)
    np.testing.assert_allclose(acc, accr, rtol=1e-5, atol=1e-5)
    # split the cache in two "sequence shards" and merge the partials
    half = S // 2
    p1 = pallas_decode_attention(q, k[:, :half], v[:, :half],
                                 jnp.minimum(cache_len, half),
                                 block_k=8, interpret=True)[1]
    p2 = pallas_decode_attention(q, k[:, half:], v[:, half:],
                                 jnp.maximum(cache_len - half, 0),
                                 block_k=8, interpret=True)[1]
    comb = ref.combine_decode_shards([p1, p2])
    np.testing.assert_allclose(comb, want, atol=2e-5)


@propcase(n_cases=8)
def test_paged_attention_sweep(draw):
    """Page-table-native kernel vs gather+attend ref: staggered pos,
    sentinel tail pages, slot masking, GQA and MLA dims."""
    b = draw.ints(2, 3)
    h = draw.choice([2, 4])
    g = draw.choice([x for x in (1, 2) if h % x == 0])
    e = draw.choice([16, 32])
    ev = draw.choice([e, e // 2])
    sq = draw.choice([1, 4])
    ps = draw.choice([4, 8])
    ppr = draw.ints(3, 6)
    n_pages = draw.ints(8, 20)
    dtype = draw.choice([jnp.float32, jnp.bfloat16])
    ks = jax.random.split(jax.random.PRNGKey(draw.ints(0, 99)), 6)
    q = jax.random.normal(ks[0], (b, sq, h, e)).astype(dtype)
    kp = jax.random.normal(ks[1], (n_pages, ps, g, e)).astype(dtype)
    vp = jax.random.normal(ks[2], (n_pages, ps, g, ev)).astype(dtype)
    pt = jax.random.randint(ks[3], (b, ppr), 0, n_pages)
    pos = jax.random.randint(ks[4], (b,), 0, ppr * ps - sq + 1)
    # sentinel tail: zero every table entry past each row's live window
    # — the causal mask must neutralize whatever page id 0 aliases
    live = (pos + sq + ps - 1) // ps
    pt = jnp.where(jnp.arange(ppr)[None] < live[:, None], pt, 0)
    sm = jax.random.bernoulli(ks[5], 0.8, (b,))
    got = pallas_paged_attention(q, kp, vp, page_tables=pt, pos=pos,
                                 slot_mask=sm, interpret=True)
    want = ref.paged_attention(q, kp, vp, page_tables=pt, pos=pos,
                               slot_mask=sm, block_k=8)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)
    # masked-off rows emit exact zeros under both implementations
    assert not np.any(np.asarray(got)[~np.asarray(sm)])


def test_paged_attention_int8_error_bound():
    """int8 pages: kernel == ref bitwise-dequant; both within 0.5% of
    the max |o| of the fp32 pool attention."""
    b, sq, h, g, e, ps, ppr, n_pages = 3, 1, 4, 2, 32, 4, 6, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (b, sq, h, e))
    kf = jax.random.normal(ks[1], (n_pages, ps, g, e))
    vf = jax.random.normal(ks[2], (n_pages, ps, g, e))
    pt = jax.random.randint(ks[3], (b, ppr), 0, n_pages)
    pos = jax.random.randint(ks[4], (b,), 0, ppr * ps - sq + 1)
    # quantize per page × kv-head, the storage layout the cache uses
    k_sc = jnp.abs(kf).max(axis=(1, 3)) / 127.0     # [n_pages, g]
    v_sc = jnp.abs(vf).max(axis=(1, 3)) / 127.0
    ki = jnp.round(kf / k_sc[:, None, :, None]).astype(jnp.int8)
    vi = jnp.round(vf / v_sc[:, None, :, None]).astype(jnp.int8)
    o_fp = ref.paged_attention(q, kf, vf, page_tables=pt, pos=pos,
                               block_k=8)
    o_ker = pallas_paged_attention(q, ki, vi, page_tables=pt, pos=pos,
                                   k_scale=k_sc, v_scale=v_sc,
                                   interpret=True)
    o_ref = ref.paged_attention(q, ki, vi, page_tables=pt, pos=pos,
                                k_scale=k_sc, v_scale=v_sc, block_k=8)
    # kernel and ref share the exact dequant math
    np.testing.assert_allclose(o_ker, o_ref, atol=2e-5)
    bound = 0.005 * np.abs(np.asarray(o_fp)).max()
    assert np.abs(np.asarray(o_ker) - np.asarray(o_fp)).max() < bound


def test_slstm_state_continuity():
    b, s, h, e = 2, 32, 2, 8
    g = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, 4, e))
    full = ref.slstm_scan(g)
    y1, st = ref.slstm_scan(g[:, :16], return_state=True)
    y2 = ref.slstm_scan(g[:, 16:], state=st)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), full, atol=1e-5)
