"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_xent import softmax_xent
from repro.kernels.selective_scan import selective_scan
from tests.proptest import propcase


@propcase(n_cases=10)
def test_flash_attention_sweep(draw):
    b = draw.ints(1, 2)
    h = draw.choice([2, 4, 8])
    g = draw.choice([x for x in (1, 2, 4) if h % x == 0])
    e = draw.choice([32, 64])
    ev = draw.choice([e, e // 2])
    sq = draw.choice([64, 128, 200, 256])
    sk = draw.choice([sq, 2 * sq])
    causal = draw.bool() if sq == sk else False
    dtype = draw.choice([jnp.float32, jnp.bfloat16])
    q = jax.random.normal(jax.random.PRNGKey(draw.ints(0, 99)),
                          (b, sq, h, e)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, g, e)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, g, ev)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention(q, k, v, causal=causal, block_k=128)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_attention_matches_naive_oracle():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 32))
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=1e-5)


@propcase(n_cases=8)
def test_selective_scan_sweep(draw):
    b = draw.ints(1, 2)
    s = draw.choice([64, 130, 256])
    d = draw.choice([64, 128, 192])
    n = draw.choice([4, 8, 16])
    ks = jax.random.split(jax.random.PRNGKey(draw.ints(0, 99)), 6)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)) * 0.5)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (d,))
    got = selective_scan(x, dt, A, B, C, D, chunk=64, block_d=64,
                         interpret=True)
    want = ref.selective_scan(x, dt, A, B, C, D, chunk=128)
    np.testing.assert_allclose(got, want, atol=5e-4)


def test_selective_scan_matches_sequential():
    b, s, d, n = 1, 100, 32, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (d,))
    h = jnp.zeros((b, d, n))
    ys = []
    for t in range(s):
        h, y = ref.selective_scan_step(h, x[:, t], dt[:, t], A, B[:, t],
                                       C[:, t], D)
        ys.append(y)
    seq = jnp.stack(ys, 1)
    got = selective_scan(x, dt, A, B, C, D, chunk=32, block_d=32,
                         interpret=True)
    np.testing.assert_allclose(got, seq, atol=5e-4)


@propcase(n_cases=6)
def test_fused_xent_sweep(draw):
    n = draw.choice([64, 200, 256])
    d = draw.choice([32, 64])
    v = draw.choice([500, 1000, 1024])
    ks = jax.random.split(jax.random.PRNGKey(draw.ints(0, 99)), 3)
    h = jax.random.normal(ks[0], (n, d)) * 0.5
    w = jax.random.normal(ks[1], (d, v)) * 0.1
    labels = jax.random.randint(ks[2], (n,), 0, v)
    loss_k, (dh_k, dw_k) = softmax_xent(h, w, labels, block_n=128,
                                        block_v=256, interpret=True)
    loss_r, (dh_r, dw_r) = ref.softmax_xent(h, w, labels, chunk=256)
    assert abs(float(loss_k) - float(loss_r)) < 1e-4
    np.testing.assert_allclose(dh_k, dh_r, atol=1e-5)
    np.testing.assert_allclose(dw_k, dw_r, atol=1e-5)


def test_xent_ref_matches_autodiff():
    n, d, v = 64, 16, 300
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (n, d)) * 0.5
    w = jax.random.normal(ks[1], (d, v)) * 0.2
    labels = jax.random.randint(ks[2], (n,), 0, v)
    loss_r, (dh_r, dw_r) = ref.softmax_xent(h, w, labels, chunk=128)
    l_n, (gh, gw) = jax.value_and_grad(
        lambda h, w: ref.softmax_xent_naive(h, w, labels),
        argnums=(0, 1))(h, w)
    assert abs(float(loss_r) - float(l_n)) < 1e-5
    np.testing.assert_allclose(dh_r, gh, atol=1e-5)
    np.testing.assert_allclose(dw_r, gw, atol=1e-5)


def test_mlstm_chunkwise_matches_step():
    b, s, h, e = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (b, s, h, e))
    k = jax.random.normal(ks[1], (b, s, h, e))
    v = jax.random.normal(ks[2], (b, s, h, e))
    ig = jax.random.normal(ks[3], (b, s, h)) * 0.5
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    got = ref.mlstm_chunkwise(q, k, v, ig, fg, chunk=16)
    state = None
    ys = []
    C = jnp.zeros((b, h, e, e))
    nrm = jnp.zeros((b, h, e))
    m = jnp.zeros((b, h))
    st = (C, nrm, m)
    for t in range(s):
        st, y = ref.mlstm_step(st, q[:, t], k[:, t], v[:, t], ig[:, t],
                               fg[:, t])
        ys.append(y)
    seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(got, seq, atol=2e-4)


def test_slstm_state_continuity():
    b, s, h, e = 2, 32, 2, 8
    g = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, 4, e))
    full = ref.slstm_scan(g)
    y1, st = ref.slstm_scan(g[:, :16], return_state=True)
    y2 = ref.slstm_scan(g[:, 16:], state=st)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), full, atol=1e-5)
