"""Continuous-batching serving engine tests.

Fast cases exercise the host-side pieces (SlotPool bookkeeping,
FIFO/stop/max_gen scheduling policy) in-process — they never touch jax
devices. The SPMD cases (batched ≡ sequential token identity under
staggered lengths with slot reclaim, and the train→serve checkpoint
handoff) run in subprocesses with fake host devices via
tests/spmd_case.py, like the other pipeline tests.
"""

import numpy as np
import pytest

from tests.test_pipeline_equiv import _run


# --------------------------------------------------------------------------- #
# SlotPool (no devices)
# --------------------------------------------------------------------------- #


def test_slot_pool_alloc_release_cycle():
    from repro.serving import SlotPool

    pool = SlotPool(3, max_seq=16)
    a = pool.alloc(10, prompt_len=4)
    b = pool.alloc(11, prompt_len=4)
    assert (a.index, b.index) == (0, 1)
    assert pool.n_active == 2 and pool.n_free == 1
    pool.release(a.index)
    assert pool.n_free == 2
    # lowest free slot is reused, with position state reset
    a.pos = 9
    c = pool.alloc(12, prompt_len=4)
    assert c.index == 0 and c.pos == 0 and c.request_id == 12
    assert pool.alloc(13, 4) is not None
    assert pool.alloc(14, 4) is None  # full


def test_slot_pool_vectors_and_occupancy():
    from repro.serving import SlotPool

    pool = SlotPool(4, max_seq=32)
    s = pool.alloc(1, prompt_len=5)
    s.pos = 5
    assert pool.pos_vector().tolist() == [5, 0, 0, 0]
    assert pool.active_mask().tolist() == [True, False, False, False]
    assert pool.mask_for([1, 3]).tolist() == [False, True, False, True]
    pool.observe_tick()
    pool.alloc(2, prompt_len=5)
    pool.observe_tick()
    assert pool.occupancy == pytest.approx((1 + 2) / (2 * 4))


def test_slot_pool_rejects_oversized_prompt():
    from repro.serving import SlotPool

    pool = SlotPool(2, max_seq=8)
    with pytest.raises(ValueError, match="max_seq"):
        pool.alloc(1, prompt_len=8)  # no room for even one new token


# --------------------------------------------------------------------------- #
# RequestScheduler (no devices)
# --------------------------------------------------------------------------- #


def _req(n=4, **kw):
    from repro.serving import Request

    return Request(prompt=np.arange(1, n + 1, dtype=np.int32), **kw)


def test_scheduler_fifo_admission_respects_policy():
    from repro.serving import RequestScheduler, SchedulerPolicy, SlotPool

    sched = RequestScheduler(SchedulerPolicy(max_prefills_per_tick=2))
    pool = SlotPool(4, max_seq=16)
    reqs = [_req() for _ in range(5)]
    for r in reqs:
        sched.submit(r)
    first = sched.admit(pool)
    # FIFO order, capped by the interleave policy
    assert [r.id for r in first] == [reqs[0].id, reqs[1].id]
    assert [r.slot for r in first] == [0, 1]
    second = sched.admit(pool)
    assert [r.id for r in second] == [reqs[2].id, reqs[3].id]
    # pool is now full: admission stalls until a slot frees up
    assert sched.admit(pool) == [] and sched.n_queued == 1
    pool.release(first[0].slot)
    refill = sched.admit(pool)
    assert [r.id for r in refill] == [reqs[4].id]
    assert refill[0].slot == first[0].slot  # reclaimed slot refilled
    assert sched.admit(pool) == [] and sched.n_queued == 0


def test_scheduler_static_mode_waits_for_idle_pool():
    from repro.serving import RequestScheduler, SchedulerPolicy, SlotPool

    sched = RequestScheduler(SchedulerPolicy(mode="static"))
    pool = SlotPool(2, max_seq=16)
    for _ in range(4):
        sched.submit(_req())
    batch1 = sched.admit(pool)
    assert len(batch1) == 2        # fills the whole pool at once
    assert sched.admit(pool) == []  # pool busy -> no admission at all
    pool.release(0)
    assert sched.admit(pool) == []  # still one active slot
    pool.release(1)
    assert len(sched.admit(pool)) == 2


def test_request_validation():
    from repro.serving import Request

    with pytest.raises(ValueError, match="empty"):
        Request(prompt=np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="max_gen"):
        _req(max_gen=0)
    r = _req(stop=[7.0])
    assert r.stop == (7,)


def test_scheduler_policy_validation():
    from repro.serving import SchedulerPolicy

    with pytest.raises(ValueError, match="admission mode"):
        SchedulerPolicy(mode="round-robin")
    with pytest.raises(ValueError, match="max_prefills"):
        SchedulerPolicy(max_prefills_per_tick=0)


# --------------------------------------------------------------------------- #
# Spec plumbing (no devices)
# --------------------------------------------------------------------------- #


def test_spec_serving_knobs_validate():
    from repro.api import SessionError, session

    with pytest.raises(SessionError, match="serving knob"):
        session("llama3.2-1b", mode="train", max_slots=4)
    with pytest.raises(SessionError, match="disagree"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                global_batch=8)
    with pytest.raises(SessionError, match="prefill_chunk"):
        session("llama3.2-1b", mode="serve", max_seq=16, prefill_chunk=0)
    with pytest.raises(SessionError, match="divide evenly"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=3,
                data=2)
    sess = session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4)
    assert sess.max_slots == 4
    assert sess.shape_cfg.global_batch == 4


# --------------------------------------------------------------------------- #
# SPMD cases (subprocess, fake devices)
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_batched_equals_sequential_serving():
    """The issue's correctness bar: token-identical engine output for a
    staggered 8-request workload vs independent sequential serving, with
    slot reclaim/refill mid-decode and chunked prefill."""
    _run("serving_engine_equiv", "llama3.2-1b")


@pytest.mark.slow
def test_train_serve_handoff_roundtrip():
    """mode='serve' sessions boot from a train checkpoint with
    cache-aware relayout; tokens equal a direct param transplant."""
    _run("serve_handoff", "llama3.2-1b")
