"""Continuous-batching serving engine tests.

Fast cases exercise the host-side pieces (SlotPool bookkeeping,
FIFO/stop/max_gen scheduling policy) in-process — they never touch jax
devices. The SPMD cases (batched ≡ sequential token identity under
staggered lengths with slot reclaim, and the train→serve checkpoint
handoff) run in subprocesses with fake host devices via
tests/spmd_case.py, like the other pipeline tests.
"""

import numpy as np
import pytest

from tests.test_pipeline_equiv import _run


# --------------------------------------------------------------------------- #
# SlotPool (no devices)
# --------------------------------------------------------------------------- #


def test_slot_pool_alloc_release_cycle():
    from repro.serving import SlotPool

    pool = SlotPool(3, max_seq=16)
    a = pool.alloc(10, prompt_len=4)
    b = pool.alloc(11, prompt_len=4)
    assert (a.index, b.index) == (0, 1)
    assert pool.n_active == 2 and pool.n_free == 1
    pool.release(a.index)
    assert pool.n_free == 2
    # lowest free slot is reused, with position state reset
    a.pos = 9
    c = pool.alloc(12, prompt_len=4)
    assert c.index == 0 and c.pos == 0 and c.request_id == 12
    assert pool.alloc(13, 4) is not None
    assert pool.alloc(14, 4) is None  # full


def test_slot_pool_vectors_and_occupancy():
    from repro.serving import SlotPool

    pool = SlotPool(4, max_seq=32)
    s = pool.alloc(1, prompt_len=5)
    s.pos = 5
    assert pool.pos_vector().tolist() == [5, 0, 0, 0]
    assert pool.active_mask().tolist() == [True, False, False, False]
    assert pool.mask_for([1, 3]).tolist() == [False, True, False, True]
    pool.observe_tick()
    pool.alloc(2, prompt_len=5)
    pool.observe_tick()
    assert pool.occupancy == pytest.approx((1 + 2) / (2 * 4))


def test_slot_pool_rejects_oversized_prompt():
    from repro.serving import SlotPool

    pool = SlotPool(2, max_seq=8)
    with pytest.raises(ValueError, match="max_seq"):
        pool.alloc(1, prompt_len=8)  # no room for even one new token


# --------------------------------------------------------------------------- #
# RequestScheduler (no devices)
# --------------------------------------------------------------------------- #


def _req(n=4, **kw):
    from repro.serving import Request

    return Request(prompt=np.arange(1, n + 1, dtype=np.int32), **kw)


def test_scheduler_fifo_admission_respects_policy():
    from repro.serving import RequestScheduler, SchedulerPolicy, SlotPool

    sched = RequestScheduler(SchedulerPolicy(max_prefills_per_tick=2))
    pool = SlotPool(4, max_seq=16)
    reqs = [_req() for _ in range(5)]
    for r in reqs:
        sched.submit(r)
    first = sched.admit(pool)
    # FIFO order, capped by the interleave policy
    assert [r.id for r in first] == [reqs[0].id, reqs[1].id]
    assert [r.slot for r in first] == [0, 1]
    second = sched.admit(pool)
    assert [r.id for r in second] == [reqs[2].id, reqs[3].id]
    # pool is now full: admission stalls until a slot frees up
    assert sched.admit(pool) == [] and sched.n_queued == 1
    pool.release(first[0].slot)
    refill = sched.admit(pool)
    assert [r.id for r in refill] == [reqs[4].id]
    assert refill[0].slot == first[0].slot  # reclaimed slot refilled
    assert sched.admit(pool) == [] and sched.n_queued == 0


def test_scheduler_static_mode_waits_for_idle_pool():
    from repro.serving import RequestScheduler, SchedulerPolicy, SlotPool

    sched = RequestScheduler(SchedulerPolicy(mode="static"))
    pool = SlotPool(2, max_seq=16)
    for _ in range(4):
        sched.submit(_req())
    batch1 = sched.admit(pool)
    assert len(batch1) == 2        # fills the whole pool at once
    assert sched.admit(pool) == []  # pool busy -> no admission at all
    pool.release(0)
    assert sched.admit(pool) == []  # still one active slot
    pool.release(1)
    assert len(sched.admit(pool)) == 2


def test_request_validation():
    from repro.serving import Request

    with pytest.raises(ValueError, match="empty"):
        Request(prompt=np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="max_gen"):
        _req(max_gen=0)
    r = _req(stop=[7.0])
    assert r.stop == (7,)


def test_scheduler_policy_validation():
    from repro.serving import SchedulerPolicy

    with pytest.raises(ValueError, match="admission mode"):
        SchedulerPolicy(mode="round-robin")
    with pytest.raises(ValueError, match="max_prefills"):
        SchedulerPolicy(max_prefills_per_tick=0)


def test_scheduler_remove_with_multiple_queued():
    """ISSUE-5 regression: Request carries a numpy prompt, so the
    dataclass-generated __eq__ made ``req in queue`` raise "truth value
    of an array is ambiguous" whenever >= 2 requests were queued;
    requests now compare by identity (eq=False)."""
    from repro.serving import RequestScheduler

    sched = RequestScheduler()
    r1, r2, r3 = _req(), _req(), _req()
    for r in (r1, r2, r3):
        sched.submit(r)
    # crashed before the fix: r2 != r1 compares the numpy prompts
    assert sched.remove(r2) is True
    assert sched.n_queued == 2
    assert sched.remove(r2) is False          # already gone
    # an equal-valued but distinct request is NOT the queued one
    assert sched.remove(_req()) is False
    assert sched.n_queued == 2
    assert [r.id for r in sched.drain()] == [r1.id, r3.id]


def test_request_identity_semantics():
    """eq=False: equality and hashing are by identity, so requests with
    identical field values stay distinguishable in queues/dicts."""
    a, b = _req(), _req()
    assert a != b and a == a
    assert len({a, b}) == 2


# --------------------------------------------------------------------------- #
# ServeEngine failure paths + finished-request guards (fake session,
# no devices: the step fn is a numpy stub)
# --------------------------------------------------------------------------- #


class _FakeSession:
    """Duck-typed stand-in for a serve Session: a deterministic numpy
    step (token = 100*slot + per-slot call count) and no jax anywhere."""

    def __init__(self, n_slots=2, max_seq=8):
        import types

        self.spec = types.SimpleNamespace(mode="serve", prefill_chunk=None)
        self.cfg = types.SimpleNamespace(encdec=None)
        seg = types.SimpleNamespace(kinds=("attn",))
        self.geo = types.SimpleNamespace(segments=[seg])
        self.max_slots = n_slots
        self._seq = max_seq
        self.calls = np.zeros(n_slots, np.int64)

    def _max_seq(self):
        return self._seq

    def check_slot_sharding(self):
        pass

    def init_caches(self, abstract=False):
        return {}

    def reset_slot_caches(self, caches, mask):
        return caches

    def serve_step_batched(self, params, caches, batch):
        mask = batch.get("slot_mask")
        active = (np.ones(self.max_slots, bool) if mask is None
                  else np.asarray(mask))
        self.calls[active] += 1
        return 100 * np.arange(self.max_slots) + self.calls, caches


def _engine(n_slots=2, max_seq=8, **kw):
    from repro.serving import ServeEngine

    return ServeEngine(_FakeSession(n_slots, max_seq), params=None, **kw)


def test_engine_close_with_queued_requests_fails_all_waiters():
    """close() on an undriven engine must unblock every queued waiter
    with the close error instead of leaving them hanging."""
    eng = _engine(n_slots=2)
    reqs = [eng.submit([1, 2, 3], max_gen=2) for _ in range(3)]
    assert eng.scheduler.n_queued == 3
    eng.close()
    for r in reqs:
        with pytest.raises(RuntimeError, match="outstanding"):
            r.result(timeout=5)
    assert eng.scheduler.n_queued == 0
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit([1])


def test_engine_remove_after_failed_submit():
    """A submit that races an engine failure must pull the request back
    out of the queue (scheduler.remove — the numpy-__eq__ crash site,
    exercised here with a queued neighbour) and fail it loudly."""
    eng = _engine(n_slots=2)
    eng.submit([1, 2])              # a queued neighbour forces the
    #                                 req-vs-other __eq__ comparison
    # engine dies between the enqueue and submit()'s post-enqueue check
    orig_submit = eng.scheduler.submit

    def dying_submit(req):
        orig_submit(req)
        eng._failure = RuntimeError("driver died mid-submit")
        return req

    eng.scheduler.submit = dying_submit
    with pytest.raises(RuntimeError, match="engine stopped"):
        eng.submit([3, 4])
    assert eng.scheduler.n_queued == 1     # the failed one was removed
    # and a submit against the now-failed engine refuses up front
    eng.scheduler.submit = orig_submit
    with pytest.raises(RuntimeError, match="engine failed"):
        eng.submit([5, 6])
    assert eng.scheduler.n_queued == 1


def test_engine_finish_clears_slot_and_guards_late_emit():
    """ISSUE-5 regression: _finish used to release the slot but leave
    req.slot pointing at it, so a late _emit on the finished request read
    (and could finish!) a reallocated slot's state. The slot pointer is
    now cleared and _emit/_decode_tick skip finished requests."""
    eng = _engine(n_slots=1)
    r1 = eng.submit([1, 2], max_gen=1)     # finishes at prefill
    eng.step()
    assert r1.done.is_set() and r1.slot is None
    assert len(r1.tokens) == 1

    r2 = eng.submit([5], max_gen=4)        # reallocates slot 0
    eng.step()
    assert r2.slot == 0 and not r2.done.is_set()
    pos_before = eng.pool.slots[0].pos
    toks_before = list(r2.tokens)

    # late emit on the finished request: must be a no-op (before the fix
    # it dereferenced pool.slots[r1.slot] == r2's slot and could finish
    # r2's slot through r1)
    gen_before = eng.stats.generated_tokens
    eng._emit(r1, 999)
    assert len(r1.tokens) == 1 and 999 not in r1.tokens
    assert eng.stats.generated_tokens == gen_before
    assert eng.pool.slots[0].pos == pos_before
    assert eng.pool.slots[0].request_id == r2.id
    assert list(r2.tokens) == toks_before

    eng.run_until_idle()
    assert r2.done.is_set() and r2.slot is None
    assert len(r2.tokens) == 4
    assert eng.stats.finished_requests == 2


# --------------------------------------------------------------------------- #
# Spec plumbing (no devices)
# --------------------------------------------------------------------------- #


def test_spec_serving_knobs_validate():
    from repro.api import SessionError, session

    with pytest.raises(SessionError, match="serving knob"):
        session("llama3.2-1b", mode="train", max_slots=4)
    with pytest.raises(SessionError, match="disagree"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                global_batch=8)
    with pytest.raises(SessionError, match="prefill_chunk"):
        session("llama3.2-1b", mode="serve", max_seq=16, prefill_chunk=0)
    with pytest.raises(SessionError, match="divide evenly"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=3,
                data=2)
    sess = session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4)
    assert sess.max_slots == 4
    assert sess.shape_cfg.global_batch == 4


# --------------------------------------------------------------------------- #
# SPMD cases (subprocess, fake devices)
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_batched_equals_sequential_serving():
    """The issue's correctness bar: token-identical engine output for a
    staggered 8-request workload vs independent sequential serving, with
    slot reclaim/refill mid-decode and chunked prefill."""
    _run("serving_engine_equiv", "llama3.2-1b")


@pytest.mark.slow
def test_train_serve_handoff_roundtrip():
    """mode='serve' sessions boot from a train checkpoint with
    cache-aware relayout; tokens equal a direct param transplant."""
    _run("serve_handoff", "llama3.2-1b")
