"""Continuous-batching serving engine tests.

Fast cases exercise the host-side pieces (SlotPool bookkeeping,
FIFO/stop/max_gen scheduling policy) in-process — they never touch jax
devices. The SPMD cases (batched ≡ sequential token identity under
staggered lengths with slot reclaim, and the train→serve checkpoint
handoff) run in subprocesses with fake host devices via
tests/spmd_case.py, like the other pipeline tests.
"""

import numpy as np
import pytest

from tests.test_pipeline_equiv import _run


# --------------------------------------------------------------------------- #
# SlotPool (no devices)
# --------------------------------------------------------------------------- #


def test_slot_pool_alloc_release_cycle():
    from repro.serving import SlotPool

    pool = SlotPool(3, max_seq=16)
    a = pool.alloc(10, prompt_len=4)
    b = pool.alloc(11, prompt_len=4)
    assert (a.index, b.index) == (0, 1)
    assert pool.n_active == 2 and pool.n_free == 1
    pool.release(a.index)
    assert pool.n_free == 2
    # lowest free slot is reused, with position state reset
    a.pos = 9
    c = pool.alloc(12, prompt_len=4)
    assert c.index == 0 and c.pos == 0 and c.request_id == 12
    assert pool.alloc(13, 4) is not None
    assert pool.alloc(14, 4) is None  # full


def test_slot_pool_vectors_and_occupancy():
    from repro.serving import SlotPool

    pool = SlotPool(4, max_seq=32)
    s = pool.alloc(1, prompt_len=5)
    s.pos = 5
    assert pool.pos_vector().tolist() == [5, 0, 0, 0]
    assert pool.active_mask().tolist() == [True, False, False, False]
    assert pool.mask_for([1, 3]).tolist() == [False, True, False, True]
    pool.observe_tick()
    pool.alloc(2, prompt_len=5)
    pool.observe_tick()
    assert pool.occupancy == pytest.approx((1 + 2) / (2 * 4))


def test_slot_pool_rejects_oversized_prompt():
    from repro.serving import SlotPool

    pool = SlotPool(2, max_seq=8)
    with pytest.raises(ValueError, match="max_seq"):
        pool.alloc(1, prompt_len=8)  # no room for even one new token


# --------------------------------------------------------------------------- #
# RequestScheduler (no devices)
# --------------------------------------------------------------------------- #


def _req(n=4, **kw):
    from repro.serving import Request

    return Request(prompt=np.arange(1, n + 1, dtype=np.int32), **kw)


def test_scheduler_fifo_admission_respects_policy():
    from repro.serving import RequestScheduler, SchedulerPolicy, SlotPool

    sched = RequestScheduler(SchedulerPolicy(max_prefills_per_tick=2))
    pool = SlotPool(4, max_seq=16)
    reqs = [_req() for _ in range(5)]
    for r in reqs:
        sched.submit(r)
    first, _ = sched.admit(pool)
    # FIFO order, capped by the interleave policy
    assert [r.id for r in first] == [reqs[0].id, reqs[1].id]
    assert [r.slot for r in first] == [0, 1]
    second, _ = sched.admit(pool)
    assert [r.id for r in second] == [reqs[2].id, reqs[3].id]
    # pool is now full: admission stalls until a slot frees up
    assert sched.admit(pool) == ([], []) and sched.n_queued == 1
    pool.release(first[0].slot)
    refill, _ = sched.admit(pool)
    assert [r.id for r in refill] == [reqs[4].id]
    assert refill[0].slot == first[0].slot  # reclaimed slot refilled
    assert sched.admit(pool) == ([], []) and sched.n_queued == 0


def test_scheduler_static_mode_waits_for_idle_pool():
    from repro.serving import RequestScheduler, SchedulerPolicy, SlotPool

    sched = RequestScheduler(SchedulerPolicy(mode="static"))
    pool = SlotPool(2, max_seq=16)
    for _ in range(4):
        sched.submit(_req())
    batch1, _ = sched.admit(pool)
    assert len(batch1) == 2        # fills the whole pool at once
    assert sched.admit(pool) == ([], [])  # pool busy -> no admission
    pool.release(0)
    assert sched.admit(pool) == ([], [])  # still one active slot
    pool.release(1)
    admitted, _ = sched.admit(pool)
    assert len(admitted) == 2


def test_request_validation():
    from repro.serving import Request

    with pytest.raises(ValueError, match="empty"):
        Request(prompt=np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="max_gen"):
        _req(max_gen=0)
    r = _req(stop=[7.0])
    assert r.stop == (7,)


def test_scheduler_policy_validation():
    from repro.serving import SchedulerPolicy

    with pytest.raises(ValueError, match="admission mode"):
        SchedulerPolicy(mode="round-robin")
    with pytest.raises(ValueError, match="max_prefills"):
        SchedulerPolicy(max_prefills_per_tick=0)


def test_moe_capacity_bound_semantics():
    from repro.serving import MoECapacity

    # capacity(n) = ceil8(int(n*top_k/E*cf)+1) floored at 8; the bound
    # admits while skew x the uniform share still fits.
    cap = MoECapacity(n_experts=8, top_k=2, capacity_factor=8.0, skew=12.0)
    assert cap.fits(0) and cap.fits(1) and cap.fits(2)
    assert not cap.fits(3)          # hot = 3*2/8*12 = 9 > cap(3) = 8
    assert cap.max_admissible(16) == 2
    # skew=0 disables the bound entirely
    assert MoECapacity(8, 2, skew=0.0).fits(10**6)
    # uniform routing (skew=1) always fits: the capacity factor covers it
    uni = MoECapacity(n_experts=8, top_k=2, capacity_factor=1.25, skew=1.0)
    assert all(uni.fits(n) for n in range(1, 2048))

    # from_moe_cfg mirrors the model's MoE config
    from repro.models.common import MoECfg

    mo = MoECfg(n_experts=4, top_k=1, d_ff_expert=8, capacity_factor=2.0)
    c2 = MoECapacity.from_moe_cfg(mo)
    assert (c2.n_experts, c2.top_k, c2.capacity_factor) == (4, 1, 2.0)


def test_scheduler_capacity_aware_admission():
    from repro.serving import (MoECapacity, RequestScheduler,
                               SchedulerPolicy, SlotPool)

    # max_admissible = 2: the third co-resident request must wait
    cap = MoECapacity(n_experts=8, top_k=2, capacity_factor=8.0, skew=12.0)
    sched = RequestScheduler(SchedulerPolicy(max_prefills_per_tick=4,
                                             moe_capacity=cap))
    pool = SlotPool(4, max_seq=16)
    reqs = [_req() for _ in range(4)]
    for r in reqs:
        sched.submit(r)
    first, _ = sched.admit(pool)
    assert [r.id for r in first] == [reqs[0].id, reqs[1].id]
    assert sched.capacity_deferrals == 1
    # slots are free but the projected co-batch overflows: no admission
    assert sched.admit(pool) == ([], []) and sched.n_queued == 2
    assert sched.capacity_deferrals == 2
    # releasing one active slot re-opens exactly one seat, FIFO order
    pool.release(first[0].slot)
    refill, _ = sched.admit(pool)
    assert [r.id for r in refill] == [reqs[2].id]


def test_scheduler_capacity_never_livelocks_idle_pool():
    from repro.serving import (MoECapacity, RequestScheduler,
                               SchedulerPolicy, SlotPool)

    # an over-tight bound (max_admissible == 0) degrades to serial
    # serving: the first request into an idle pool always admits
    cap = MoECapacity(n_experts=8, top_k=2, capacity_factor=8.0, skew=40.0)
    assert cap.max_admissible(4) == 0
    sched = RequestScheduler(SchedulerPolicy(moe_capacity=cap))
    pool = SlotPool(4, max_seq=16)
    for _ in range(2):
        sched.submit(_req())
    one, _ = sched.admit(pool)
    assert len(one) == 1 and sched.capacity_deferrals == 1
    assert sched.admit(pool) == ([], [])   # co-residency still blocked
    pool.release(one[0].slot)
    two, _ = sched.admit(pool)
    assert len(two) == 1                    # next request proceeds alone


def test_scheduler_remove_with_multiple_queued():
    """ISSUE-5 regression: Request carries a numpy prompt, so the
    dataclass-generated __eq__ made ``req in queue`` raise "truth value
    of an array is ambiguous" whenever >= 2 requests were queued;
    requests now compare by identity (eq=False)."""
    from repro.serving import RequestScheduler

    sched = RequestScheduler()
    r1, r2, r3 = _req(), _req(), _req()
    for r in (r1, r2, r3):
        sched.submit(r)
    # crashed before the fix: r2 != r1 compares the numpy prompts
    assert sched.remove(r2) is True
    assert sched.n_queued == 2
    assert sched.remove(r2) is False          # already gone
    # an equal-valued but distinct request is NOT the queued one
    assert sched.remove(_req()) is False
    assert sched.n_queued == 2
    assert [r.id for r in sched.drain()] == [r1.id, r3.id]


def test_request_identity_semantics():
    """eq=False: equality and hashing are by identity, so requests with
    identical field values stay distinguishable in queues/dicts."""
    a, b = _req(), _req()
    assert a != b and a == a
    assert len({a, b}) == 2


def test_scheduler_admission_continues_past_poisoned_request():
    """ISSUE-6 regression: a request the pool can never hold (ValueError
    from try_admit) must fail alone — popped into the rejected list with
    its error — while admission of its queue neighbours continues. Before
    the (admitted, rejected) split the exception escaped admit() and took
    down the whole tick."""
    from repro.serving import RequestScheduler, SlotPool

    sched = RequestScheduler()
    pool = SlotPool(4, max_seq=8)
    good1, poison, good2 = _req(n=4), _req(n=9), _req(n=4)
    for r in (good1, poison, good2):
        sched.submit(r)
    admitted, rejected = sched.admit(pool)
    assert [r.id for r in admitted] == [good1.id, good2.id]
    assert len(rejected) == 1
    bad, err = rejected[0]
    assert bad is poison and isinstance(err, ValueError)
    assert "max_seq" in str(err)
    assert sched.n_queued == 0          # nothing left stranded
    assert poison.slot is None


# --------------------------------------------------------------------------- #
# Paged KV cache: PagePool / RadixIndex / PagedSlotPool (no devices)
# --------------------------------------------------------------------------- #


def test_page_pool_refcount_lifecycle():
    from repro.serving import PagePool

    pool = PagePool(8, page_size=4, shards=2)
    assert (pool.partitions, pool.n_loc, pool.dev_pages) == (2, 4, 4)
    assert pool.partition_of(5) == 1 and pool.local_id(5) == 1
    a = pool.alloc(0, 2)
    assert a == [0, 1]                     # lowest-id-first, partition 0
    assert pool.pages_in_use == 2 and pool.refcount(0) == 1
    pool.ref(0)
    assert pool.unref(0) is False          # still held
    assert pool.unref(0) is True           # went free
    assert pool.alloc(0, 1) == [0]         # lowest free id reused
    assert pool.alloc(0, 3) is None        # partition 0 short (2 free)
    assert pool.alloc(1, 4) == [4, 5, 6, 7]
    with pytest.raises(ValueError, match="free"):
        pool.ref(3)                        # never allocated
    pool.unref(1)
    with pytest.raises(ValueError, match="already free"):
        pool.unref(1)
    # groups subdivide each shard; group_of cycles per partition
    g = PagePool(8, page_size=4, shards=2, groups=2)
    assert g.n_loc == 2
    assert [g.group_of(p) for p in range(4)] == [0, 1, 0, 1]
    with pytest.raises(ValueError, match="partitions"):
        PagePool(6, page_size=4, shards=2, groups=2)


def _preq(tokens, max_gen=4, **kw):
    from repro.serving import Request

    return Request(prompt=np.asarray(tokens, np.int32), max_gen=max_gen,
                   **kw)


def test_paged_prefix_share_and_cow_divergence():
    """Two prompts sharing two full pages then diverging: the second
    request refs the shared pages and gets *private* fresh pages for its
    divergent tail (copy-on-write by construction — shared pages are
    only ever full prompt pages, never written after insert)."""
    from repro.serving import PagedSlotPool

    pool = PagedSlotPool(2, max_seq=16, page_size=4, n_pages=8)
    a = pool.try_admit(_preq(np.arange(9)))         # pages 0..8 tokens
    assert a is not None
    al_a = a.alloc
    assert (al_a.start_pos, al_a.n_shared) == (0, 0)
    assert al_a.fresh == al_a.pages                 # all 4 newly allocated
    pool.note_prefilled(a.index, np.arange(9, dtype=np.int32))

    # same first 8 tokens, divergent 9th: exactly 2 full pages shared
    b = pool.try_admit(_preq(list(range(8)) + [99]))
    al_b = b.alloc
    assert (al_b.start_pos, al_b.n_shared) == (8, 2)
    assert al_b.copies == []                        # same partition: refs
    assert al_b.pages[:2] == al_a.pages[:2]
    assert al_b.table[:2].tolist() == al_a.table[:2].tolist()
    # the divergent tail is private fresh pages, disjoint from A's
    assert set(al_b.pages[2:]) == set(al_b.fresh)
    assert not set(al_b.fresh) & set(al_a.pages)
    for gid in al_a.pages[:2]:
        assert pool.pool.refcount(gid) == 3         # A + B + radix
    assert (pool.prefix_hits, pool.prefix_hit_tokens) == (1, 8)

    pool.release(a.index)
    pool.release(b.index)
    for gid in al_a.pages[:2]:
        assert pool.pool.refcount(gid) == 1         # radix keeps them warm
    assert pool.pages_in_use == 2                   # everything else freed


def test_radix_lru_eviction_respects_refcounts():
    """evict() only drops pages whose sole reference is the trie's, and
    only leaf-first (a prefix chain never gets a hole); among droppable
    leaves the least-recently-used goes first."""
    from repro.serving import PagePool, RadixIndex

    pool = PagePool(8, page_size=2)
    radix = RadixIndex(2, pool)
    prompt = np.arange(6, dtype=np.int32)           # 3 full pages
    pages = pool.alloc(0, 3)
    assert radix.insert(prompt, 3, 0, pages) == 3
    pool.ref(pages[1])                   # an in-flight request's hold
    for gid in pages:
        pool.unref(gid)                  # the admitting request finished
    # refcounts now: [1 (trie), 2 (trie+holder), 1 (trie)]
    assert radix.evict(0, 3) == 1        # only the leaf was droppable:
    #                                      pages[1] is pinned, pages[0]
    #                                      sits above a cached descendant
    assert radix.evictions == 1
    assert pool.refcount(pages[1]) == 2 and pool.refcount(pages[0]) == 1
    pool.unref(pages[1])                 # holder done -> chain evictable
    assert radix.evict(0, 2) == 2
    assert pool.pages_in_use == 0 and radix.n_nodes == 0

    # LRU order among droppable leaves: the untouched branch goes first
    pa, pb = pool.alloc(0, 1), pool.alloc(0, 1)
    radix.insert(np.array([1, 2], np.int32), 1, 0, pa)
    radix.insert(np.array([3, 4], np.int32), 1, 0, pb)
    pool.unref(pa[0])
    pool.unref(pb[0])
    assert radix.match(np.array([1, 2], np.int32), 1)   # touch A
    assert radix.evict(0, 1) == 1
    assert radix.match(np.array([1, 2], np.int32), 1)   # A survived
    assert not radix.match(np.array([3, 4], np.int32), 1)


def test_paged_admission_evicts_under_pressure_and_defers():
    """A short free list defers admission (None, like a full SlotPool)
    while live requests pin their pages; once only the trie holds them,
    the next admission LRU-evicts exactly the shortfall."""
    from repro.serving import PagedSlotPool

    pool = PagedSlotPool(2, max_seq=16, page_size=4, n_pages=4)
    a = pool.try_admit(_preq(np.arange(13), max_gen=8))  # all 4 pages
    assert a is not None and pool.pages_in_use == 4
    assert pool.try_admit(_preq(50 + np.arange(9))) is None  # pinned
    assert pool.pages_in_use == 4                   # rollback left no refs
    pool.note_prefilled(a.index, np.arange(13, dtype=np.int32))
    pool.release(a.index)
    assert pool.pages_in_use == 3                   # 3 prompt pages cached
    b = pool.try_admit(_preq(50 + np.arange(9)))    # needs 4 fresh
    assert b is not None
    assert pool.radix.evictions == 3                # evicted the shortfall
    assert pool.pages_in_use == 4


def test_paged_pending_key_defers_co_admitted_twin():
    """A same-prefix request admitted while its twin is still mid-prefill
    would re-prefill the shared pages; it answers WAIT_PREFIX (not the
    out-of-capacity None) until the twin's radix insert, then hits it."""
    from repro.serving import PagedSlotPool
    from repro.serving.slots import WAIT_PREFIX

    pool = PagedSlotPool(2, max_seq=16, page_size=4, n_pages=8)
    prompt = np.arange(9, dtype=np.int32)
    a = pool.try_admit(_preq(prompt))
    assert pool.try_admit(_preq(prompt)) is WAIT_PREFIX   # twin: wait
    pool.note_prefilled(a.index, prompt)
    b = pool.try_admit(_preq(prompt))
    assert b is not None and b.alloc.n_shared == 2
    assert b.alloc.start_pos == 8


def test_paged_pending_defer_narrows_to_matched_extent():
    """REVIEW follow-up: the co-admission defer keys on the full pending
    prompt-page extent, not just the first page — a queued request whose
    cached chain already covers everything the in-flight prefill shares
    with it has nothing to gain by waiting and admits immediately."""
    from repro.serving import PagedSlotPool
    from repro.serving.slots import WAIT_PREFIX

    pool = PagedSlotPool(4, max_seq=16, page_size=4, n_pages=16)
    base = list(range(8))                           # 2 full shared pages
    r1 = pool.try_admit(_preq(base + [9]))
    pool.note_prefilled(r1.index, np.asarray(base + [9], np.int32))
    pool.release(r1.index)                          # pages 0-1 cached
    # in-flight prefill: shares page 0 with `base`, then diverges
    a = pool.try_admit(_preq(base[:4] + [50, 51, 52, 53, 54]))
    assert a is not None and a.alloc.pending_key is not None
    # shares only page 0 with A's pending prefill, and its own cached
    # chain already covers pages 0-1: admit now (the old first-page key
    # would have deferred this behind A's whole chunked prefill)
    b = pool.try_admit(_preq(base + [77, 78, 79, 80, 81]))
    assert b is not None and b.alloc.n_shared == 2
    # a true twin of A still waits — with the sentinel, not None
    assert pool.try_admit(_preq(base[:4] + [50, 51, 52, 53, 99])) \
        is WAIT_PREFIX


def test_scheduler_admits_past_prefix_waiting_request():
    """REVIEW follow-up: a WAIT_PREFIX verdict at the queue head no
    longer stalls the whole FIFO — neighbours behind it are admitted,
    the waiter keeps its queue position, and it admits with the shared
    pages once the holder's prefill completes."""
    from repro.serving import PagedSlotPool, RequestScheduler

    sched = RequestScheduler()
    pool = PagedSlotPool(4, max_seq=16, page_size=4, n_pages=16)
    prompt = np.arange(9, dtype=np.int32)
    holder = _preq(prompt)
    sched.submit(holder)
    admitted, _ = sched.admit(pool)
    assert [r.id for r in admitted] == [holder.id]
    twin, other = _preq(prompt), _preq(50 + np.arange(5))
    sched.submit(twin)
    sched.submit(other)
    admitted, _ = sched.admit(pool)
    assert [r.id for r in admitted] == [other.id]   # skipped the twin
    assert sched.n_queued == 1
    pool.note_prefilled(holder.slot, prompt)
    admitted, _ = sched.admit(pool)
    assert [r.id for r in admitted] == [twin.id]
    assert pool.slots[twin.slot].alloc.n_shared == 2


def test_paged_copy_sources_pinned_until_copies_executed():
    """REVIEW fix (high): a cross-partition copy SOURCE is ref-pinned at
    admission, so a later admission landing in the source's partition
    cannot LRU-evict it and re-allocate it as a fresh page — fresh pages
    are zeroed before any copy runs, so the copy (and every future
    sharer of the registered destination) would silently read zeros.
    The pin drops once the engine has executed the copies."""
    from repro.serving import PagedSlotPool

    prompt = np.arange(7, dtype=np.int32)           # 1 full page
    pool = PagedSlotPool(2, max_seq=8, page_size=4, n_pages=4, shards=2)
    a = pool.try_admit(_preq(prompt, max_gen=1))    # slot 0, partition 0
    pool.note_prefilled(a.index, prompt)            # page 0 in the radix
    src = a.alloc.pages[0]
    c = pool.try_admit(_preq(prompt, max_gen=1))    # slot 1, partition 1
    assert len(c.alloc.copies) == 1
    assert c.alloc.copies[0][0] == src
    assert c.alloc.src_refs == [src]
    assert pool.pool.refcount(src) == 3             # A + trie + C's pin
    pool.release(a.index)
    assert pool.pool.refcount(src) == 2             # trie + C's pin
    # page pressure in the SOURCE partition while the copy is pending:
    # eviction must not take the pinned source — admission defers
    big = _preq(50 + np.arange(7), max_gen=4)       # needs both pages
    assert pool.try_admit(big) is None
    assert pool.pool.refcount(src) == 2 and pool.radix.evictions == 0
    # the engine ran the copy: pin drops, eviction may proceed
    pool.copies_done(c.index)
    assert c.alloc.src_refs == []
    assert pool.pool.refcount(src) == 1             # trie only
    assert pool.try_admit(big) is not None
    assert pool.radix.evictions == 1


def test_paged_release_before_copy_returns_source_pins():
    """A request released with its copies never executed (e.g. the tick
    failed between admission and the device copy) must return its
    source pins too — otherwise the source page could never go free."""
    from repro.serving import PagedSlotPool

    prompt = np.arange(7, dtype=np.int32)
    pool = PagedSlotPool(2, max_seq=8, page_size=4, n_pages=4, shards=2)
    a = pool.try_admit(_preq(prompt, max_gen=1))    # pins partition 0
    pool.note_prefilled(a.index, prompt)
    c = pool.try_admit(_preq(prompt, max_gen=1))    # partition 1: copy
    src = c.alloc.copies[0][0]
    assert pool.pool.refcount(src) == 3             # A + trie + pin
    pool.release(c.index)                           # copies never ran
    assert pool.pool.refcount(src) == 2             # pin returned


def test_paged_sharing_off_keeps_pages_private():
    from repro.serving import PagedSlotPool

    pool = PagedSlotPool(2, max_seq=16, page_size=4, n_pages=8,
                         sharing=False)
    assert pool.radix is None
    prompt = np.arange(9, dtype=np.int32)
    a = pool.try_admit(_preq(prompt))
    pool.note_prefilled(a.index, prompt)
    b = pool.try_admit(_preq(prompt))               # identical prompt
    assert b.alloc.n_shared == 0 and b.alloc.start_pos == 0
    assert not set(b.alloc.pages) & set(a.alloc.pages)
    assert (pool.prefix_hits, pool.evictions) == (0, 0)


def test_paged_sharing_respects_fsdp_group_boundaries():
    """Cache leaves are sharded over the stage axis, so a page written by
    one FSDP group's rows does not exist in another group's replica: a
    prefix cached only in group-0 partitions is NOT a hit for a group-1
    slot (full re-prefill), while the same layout with plain data shards
    (one group) turns it into a device page-copy."""
    from repro.serving import PagedSlotPool

    prompt = np.arange(7, dtype=np.int32)           # 1 full page
    for shards, groups, shared, copies in ((1, 2, 0, 0), (2, 1, 1, 1)):
        pool = PagedSlotPool(4, max_seq=8, page_size=4, n_pages=8,
                             shards=shards, groups=groups)
        # fill partition 0 (slots 0-1) so the third request must land in
        # partition 1 — the other group (or the other data shard)
        a = pool.try_admit(_preq(prompt, max_gen=1))
        pool.note_prefilled(a.index, prompt)
        b = pool.try_admit(_preq(prompt, max_gen=1))
        assert {a.index, b.index} == {0, 1}
        assert b.alloc.n_shared == 1                # in-partition share
        c = pool.try_admit(_preq(prompt, max_gen=1))
        assert pool.partition_of_slot(c.index) == 1
        assert c.alloc.n_shared == shared
        assert len(c.alloc.copies) == copies
        if copies:
            src, dst = c.alloc.copies[0]
            assert pool.pool.partition_of(src) == 0
            assert pool.pool.partition_of(dst) == 1


# --------------------------------------------------------------------------- #
# Sampling (no devices)
# --------------------------------------------------------------------------- #


def test_sampling_params_validation():
    from repro.serving import SamplingParams

    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.7, top_p=0.9).greedy


def test_sample_token_determinism_and_top_p():
    from repro.serving import SamplingParams, sample_token
    from repro.serving.sampling import make_rng

    rng0 = np.random.default_rng(3)
    logits = rng0.normal(size=32).astype(np.float32)
    params = SamplingParams(temperature=0.8, top_p=0.9, seed=17)
    draw = [sample_token(logits, params, make_rng(params))
            for _ in range(3)]
    assert draw[0] == draw[1] == draw[2]    # same seed, fresh rng: pinned
    # the generator advances once per token: a sequence is reproducible
    r1, r2 = make_rng(params), make_rng(params)
    seq1 = [sample_token(logits, params, r1) for _ in range(8)]
    seq2 = [sample_token(logits, params, r2) for _ in range(8)]
    assert seq1 == seq2
    # a tiny nucleus collapses to the argmax regardless of seed
    tight = SamplingParams(temperature=1.0, top_p=1e-9, seed=None)
    assert sample_token(logits, tight, make_rng(tight)) \
        == int(np.argmax(logits))
    # greedy ignores the rng entirely
    assert sample_token(logits, SamplingParams(), None) \
        == int(np.argmax(logits))


# --------------------------------------------------------------------------- #
# ServeEngine failure paths + finished-request guards (fake session,
# no devices: the step fn is a numpy stub)
# --------------------------------------------------------------------------- #


class _FakeSession:
    """Duck-typed stand-in for a serve Session: a deterministic numpy
    step (token = 100*slot + per-slot call count) and no jax anywhere.
    ``want_logits`` returns a deterministic per-(slot, call) logit row so
    host-side sampling is reproducible across fresh engines."""

    vocab = 13

    def __init__(self, n_slots=2, max_seq=8):
        import types

        self.spec = types.SimpleNamespace(mode="serve", prefill_chunk=None)
        self.cfg = types.SimpleNamespace(encdec=None)
        seg = types.SimpleNamespace(kinds=("attn",))
        self.geo = types.SimpleNamespace(segments=[seg])
        self.max_slots = n_slots
        self.paged = False
        self._seq = max_seq
        self.calls = np.zeros(n_slots, np.int64)
        self.no_sampling = None     # layout's sampling_unsupported reason

    def sampling_unsupported_reason(self):
        return self.no_sampling

    def _max_seq(self):
        return self._seq

    def check_slot_sharding(self):
        pass

    def init_caches(self, abstract=False):
        return {}

    def reset_slot_caches(self, caches, mask):
        return caches

    def serve_step_batched(self, params, caches, batch,
                           want_logits=False):
        mask = batch.get("slot_mask")
        active = (np.ones(self.max_slots, bool) if mask is None
                  else np.asarray(mask))
        self.calls[active] += 1
        out = 100 * np.arange(self.max_slots) + self.calls
        if want_logits:
            phase = (np.arange(self.max_slots)[:, None] * 13
                     + self.calls[:, None] * 7
                     + np.arange(self.vocab)[None, :] * 0.7)
            return out, np.sin(phase).astype(np.float32), caches
        return out, caches


def _engine(n_slots=2, max_seq=8, **kw):
    from repro.serving import ServeEngine

    return ServeEngine(_FakeSession(n_slots, max_seq), params=None, **kw)


def test_engine_close_with_queued_requests_fails_all_waiters():
    """close() on an undriven engine must unblock every queued waiter
    with the close error instead of leaving them hanging."""
    eng = _engine(n_slots=2)
    reqs = [eng.submit([1, 2, 3], max_gen=2) for _ in range(3)]
    assert eng.scheduler.n_queued == 3
    eng.close()
    for r in reqs:
        with pytest.raises(RuntimeError, match="outstanding"):
            r.result(timeout=5)
    assert eng.scheduler.n_queued == 0
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit([1])


def test_engine_remove_after_failed_submit():
    """A submit that races an engine failure must pull the request back
    out of the queue (scheduler.remove — the numpy-__eq__ crash site,
    exercised here with a queued neighbour) and fail it loudly."""
    eng = _engine(n_slots=2)
    eng.submit([1, 2])              # a queued neighbour forces the
    #                                 req-vs-other __eq__ comparison
    # engine dies between the enqueue and submit()'s post-enqueue check
    orig_submit = eng.scheduler.submit

    def dying_submit(req):
        orig_submit(req)
        eng._failure = RuntimeError("driver died mid-submit")
        return req

    eng.scheduler.submit = dying_submit
    with pytest.raises(RuntimeError, match="engine stopped"):
        eng.submit([3, 4])
    assert eng.scheduler.n_queued == 1     # the failed one was removed
    # and a submit against the now-failed engine refuses up front
    eng.scheduler.submit = orig_submit
    with pytest.raises(RuntimeError, match="engine failed"):
        eng.submit([5, 6])
    assert eng.scheduler.n_queued == 1


def test_engine_finish_clears_slot_and_guards_late_emit():
    """ISSUE-5 regression: _finish used to release the slot but leave
    req.slot pointing at it, so a late _emit on the finished request read
    (and could finish!) a reallocated slot's state. The slot pointer is
    now cleared and _emit/_decode_tick skip finished requests."""
    eng = _engine(n_slots=1)
    r1 = eng.submit([1, 2], max_gen=1)     # finishes at prefill
    eng.step()
    assert r1.done.is_set() and r1.slot is None
    assert len(r1.tokens) == 1

    r2 = eng.submit([5], max_gen=4)        # reallocates slot 0
    eng.step()
    assert r2.slot == 0 and not r2.done.is_set()
    pos_before = eng.pool.slots[0].pos
    toks_before = list(r2.tokens)

    # late emit on the finished request: must be a no-op (before the fix
    # it dereferenced pool.slots[r1.slot] == r2's slot and could finish
    # r2's slot through r1)
    gen_before = eng.stats.generated_tokens
    eng._emit(r1, 999)
    assert len(r1.tokens) == 1 and 999 not in r1.tokens
    assert eng.stats.generated_tokens == gen_before
    assert eng.pool.slots[0].pos == pos_before
    assert eng.pool.slots[0].request_id == r2.id
    assert list(r2.tokens) == toks_before

    eng.run_until_idle()
    assert r2.done.is_set() and r2.slot is None
    assert len(r2.tokens) == 4
    assert eng.stats.finished_requests == 2


def test_engine_poisoned_request_fails_alone():
    """ISSUE-6 satellite: an admission-impossible request (slipped past
    submit-time validation) is failed with its ValueError while its queue
    neighbours are admitted and served normally — the tick, the daemon
    driver and every other request survive."""
    from repro.serving import Request

    eng = _engine(n_slots=2, max_seq=8)
    good1 = eng.submit([1, 2, 3], max_gen=2)
    # bypass submit()'s validate_prompt: a 9-token prompt can never fit
    # an 8-position cache
    poison = Request(prompt=np.arange(1, 10, dtype=np.int32), max_gen=2)
    eng.scheduler.submit(poison)
    good2 = eng.submit([4, 5], max_gen=2)
    eng.run_until_idle()
    assert eng.stats.rejected_requests == 1
    assert poison.done.is_set() and poison.slot is None
    with pytest.raises(ValueError, match="max_seq"):
        poison.result(timeout=5)
    assert len(good1.result(timeout=5)) == 2   # neighbours unharmed
    assert len(good2.result(timeout=5)) == 2
    assert eng.stats.finished_requests == 2
    assert eng._failure is None                # engine still healthy


def test_engine_rejects_sampling_on_unsupported_layout():
    """REVIEW fix: temperature>0 on a session whose serve step cannot
    return logits (multi-pod mesh / seq-sharded layout) is rejected at
    submit() — before queuing — instead of NotImplementedError surfacing
    mid-tick, failing the engine and stranding every greedy neighbour."""
    from repro.serving import ServeEngine

    fake = _FakeSession(2, 8)
    fake.no_sampling = "logits return is not wired for multi-pod meshes"
    eng = ServeEngine(fake, params=None)
    greedy = eng.submit([1, 2], max_gen=2)          # greedy still fine
    with pytest.raises(NotImplementedError, match="multi-pod"):
        eng.submit([3, 4], max_gen=2, temperature=0.7)
    assert eng.scheduler.n_queued == 1              # nothing was queued
    eng.run_until_idle()
    assert len(greedy.result(timeout=5)) == 2
    assert eng._failure is None                     # engine healthy


def test_engine_sampling_deterministic_across_restarts():
    """Same (prompt, temperature, top_p, seed) -> same sampled tokens on
    a fresh engine: the per-request generator advances once per emitted
    token, so slot placement and batch composition cannot perturb it."""
    def run():
        eng = _engine(n_slots=2, max_seq=8)
        sampled = eng.submit([1, 2], max_gen=4, temperature=0.8,
                             top_p=0.9, seed=7)
        greedy = eng.submit([3, 4], max_gen=3)
        eng.run_until_idle()
        return sampled.result(timeout=5), greedy.result(timeout=5)

    s1, g1 = run()
    s2, g2 = run()
    assert s1 == s2                       # restart-deterministic sampling
    assert g1 == g2 and len(s1) == 4
    assert all(0 <= t < _FakeSession.vocab for t in s1)

    # a different seed draws a different stream (same everything else)
    eng = _engine(n_slots=2, max_seq=8)
    other = eng.submit([1, 2], max_gen=4, temperature=0.8, top_p=0.9,
                       seed=8)
    eng.run_until_idle()
    assert other.result(timeout=5) != s1


# --------------------------------------------------------------------------- #
# Spec plumbing (no devices)
# --------------------------------------------------------------------------- #


def test_spec_serving_knobs_validate():
    from repro.api import SessionError, session

    with pytest.raises(SessionError, match="serving knob"):
        session("llama3.2-1b", mode="train", max_slots=4)
    with pytest.raises(SessionError, match="disagree"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                global_batch=8)
    with pytest.raises(SessionError, match="prefill_chunk"):
        session("llama3.2-1b", mode="serve", max_seq=16, prefill_chunk=0)
    with pytest.raises(SessionError, match="divide evenly"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=3,
                data=2)
    sess = session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4)
    assert sess.max_slots == 4
    assert sess.shape_cfg.global_batch == 4


def test_spec_paged_knobs_validate():
    from repro.api import SessionError, session

    with pytest.raises(SessionError, match="serving knob"):
        session("llama3.2-1b", mode="train", page_size=4)
    with pytest.raises(SessionError, match="page_size must be"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                page_size=0)
    with pytest.raises(SessionError, match="divide max_seq"):
        session("llama3.2-1b", mode="serve", max_seq=18, max_slots=4,
                page_size=4)
    with pytest.raises(SessionError, match="needs page_size"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                max_pages=16)
    with pytest.raises(SessionError, match="pods×data"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                page_size=4, max_pages=7, data=2)
    with pytest.raises(SessionError, match="prefix_sharing"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                page_size=4, prefix_sharing="maybe")
    # REVIEW fix: the page arena partitions over shards×groups, so a
    # max_pages/max_slots that only divides the pods×data axes must be
    # rejected at spec time, not by PagePool at engine construction
    with pytest.raises(SessionError, match="FSDP groups"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                page_size=4, max_pages=10, data=2,
                overrides=dict(groups=2))
    with pytest.raises(SessionError, match="FSDP groups"):
        session("llama3.2-1b", mode="serve", max_seq=16, max_slots=2,
                page_size=4, overrides=dict(groups=4))
    ok = session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                 page_size=4, max_pages=16, data=2,
                 overrides=dict(groups=2))
    assert ok.n_pages == 16
    sess = session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4,
                   page_size=4)
    assert sess.paged and sess.page_size == 4
    assert sess.pages_per_slot == 4
    assert sess.n_pages == 16           # default: contiguous footprint
    plain = session("llama3.2-1b", mode="serve", max_seq=16, max_slots=4)
    assert not plain.paged and plain.page_size == 0


# --------------------------------------------------------------------------- #
# SPMD cases (subprocess, fake devices)
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_batched_equals_sequential_serving():
    """The issue's correctness bar: token-identical engine output for a
    staggered 8-request workload vs independent sequential serving, with
    slot reclaim/refill mid-decode and chunked prefill."""
    _run("serving_engine_equiv", "llama3.2-1b")


@pytest.mark.slow
def test_train_serve_handoff_roundtrip():
    """mode='serve' sessions boot from a train checkpoint with
    cache-aware relayout; tokens equal a direct param transplant."""
    _run("serve_handoff", "llama3.2-1b")


@pytest.mark.slow
def test_paged_equals_contiguous_serving():
    """ISSUE-6 correctness bar: greedy paged decoding is token-identical
    to the contiguous path on the staggered 8-request workload (with
    peak pages strictly below the contiguous footprint), shared prompts
    prefill once via the radix, and prefix_sharing='off' still matches
    with zero hits.

    PYTHONHASHSEED is pinned like the golden-parity test: the case's
    int8 leg quantizes the KV cache, and hash-randomized trace-time set
    iteration can reorder accumulation enough to flip a near-tie argmax
    between the paged and contiguous programs (int8 perturbs logits by
    O(0.5%) — the API.md caveat; seed 2 reproduces the flip)."""
    _run("serving_paged_equiv", "llama3.2-1b",
         env_extra={"PYTHONHASHSEED": "0"})


# --------------------------------------------------------------------------- #
# Park / resubmit / EngineRouter (no devices)
# --------------------------------------------------------------------------- #


def test_engine_park_resubmit_roundtrip():
    """park_all folds emitted tokens into the prompt and frees the slot;
    resubmit re-queues the SAME request object, which finishes with the
    full max_gen token count on re-admission."""
    eng = _engine(n_slots=2, max_seq=16)
    r = eng.submit([1, 2, 3], max_gen=6)
    eng.step()                      # prefill + 1 decode -> 2 tokens
    assert len(r.tokens) == 2 and r.slot is not None
    parked = eng.park_all()
    assert parked == [r]
    assert r.slot is None and eng.pool.n_active == 0
    assert r.prompt_len == 3 + 2    # emitted tokens folded into prompt
    assert not r.done.is_set()
    eng.resubmit(r)
    eng.run_until_idle()
    assert r.done.is_set() and r.error is None
    assert len(r.tokens) == 6
    assert eng.stats.resubmitted_requests == 1


def test_park_all_drains_queue_in_arrival_order():
    eng = _engine(n_slots=1, max_seq=16)
    rs = [eng.submit([1, 2], max_gen=2) for _ in range(3)]
    eng.step()                      # r0 in a slot, r1/r2 queued
    parked = eng.park_all()
    assert [p.id for p in parked] == [r.id for r in rs if not
                                      r.done.is_set()]
    assert eng.scheduler.n_queued == 0


def test_park_all_fails_cache_full_edge():
    """A request parked one decode short of cache-full folds to
    prompt_len == max_seq — it cannot re-prefill, so park_all fails it
    loudly instead of truncating its stream."""
    eng = _engine(n_slots=2, max_seq=8)
    r = eng.submit([1, 2, 3, 4, 5], max_gen=6)
    eng.step()                      # prefill + decode -> 2 tokens, pos=7
    eng.step()                      # decode -> 3 tokens, pos advances on
    # the emit *after* this one, so the request is still in flight
    assert len(r.tokens) == 3 and not r.done.is_set()
    parked = eng.park_all()
    assert parked == []             # nothing reusable survived
    with pytest.raises(RuntimeError, match="cannot continue after a "
                       "reshard"):
        r.result(timeout=5)


def test_router_least_loaded_dispatch():
    from repro.serving import EngineRouter

    router = EngineRouter([_engine(n_slots=2), _engine(n_slots=2)])
    router.submit([1, 2, 3], max_gen=4)     # load 0 -> replica 0
    router.submit([1, 2, 3], max_gen=4)     # replica 0 loaded -> 1
    router.submit([1, 2], max_gen=2)        # tie on count, 0 lighter? no:
    assert router.dispatched == [2, 1]      # equal load ties break low
    assert router.engines[0].outstanding_tokens() > 0


def test_router_affinity_override_within_slack():
    from repro.serving import EngineRouter

    e0, e1 = _engine(n_slots=2), _engine(n_slots=2)
    router = EngineRouter([e0, e1], affinity_slack=256)
    e1.prefix_affinity = lambda p: 8        # replica 1 caches a prefix
    router.submit([1, 2, 3], max_gen=4)
    assert router.dispatched == [0, 1]      # affinity beat the tie
    # outside the slack the least-loaded replica wins again
    tight = EngineRouter([_engine(n_slots=2), _engine(n_slots=2)],
                         affinity_slack=0)
    tight.engines[1].prefix_affinity = lambda p: 8
    tight.engines[1].submit([1] * 4, max_gen=8)   # out-of-band load
    tight.submit([1, 2, 3], max_gen=4)
    assert tight.dispatched == [1, 0]


def test_router_kill_replica_moves_queued_work():
    from repro.serving import EngineRouter

    router = EngineRouter([_engine(n_slots=2), _engine(n_slots=2)])
    rs = [router.submit([1, 2, 3], max_gen=3) for _ in range(4)]
    moved = router.kill_replica(0)
    assert moved == 2                       # replica 0's share moved over
    router.run_until_idle()
    for r in rs:
        assert r.done.is_set() and r.error is None
        assert len(r.tokens) == 3
    st = router.stats()
    assert st["alive"] == 1 and st["failovers"] == 1
    assert st["finished_requests"] == 4
    assert st["per_replica"][1]["resubmitted_requests"] == 2
    assert router.kill_replica(0) == 0      # idempotent


def test_router_detects_dead_driver_and_fails_over():
    """A replica whose driver died (engine._failure set) is failed over
    automatically on the next dispatch — its queued work moves."""
    from repro.serving import EngineRouter

    router = EngineRouter([_engine(n_slots=2), _engine(n_slots=2)])
    r0 = router.submit([1, 2, 3], max_gen=2)
    router.engines[0]._failure = RuntimeError("driver died")
    r1 = router.submit([4, 5], max_gen=2)   # triggers alive() detection
    assert router.stats()["alive"] == 1 and router.failovers == 1
    router.run_until_idle()
    assert r0.error is None and len(r0.tokens) == 2
    assert r1.error is None and len(r1.tokens) == 2


def test_router_no_survivors_fails_requests():
    from repro.serving import EngineRouter, RouterError

    router = EngineRouter([_engine(n_slots=2)])
    r = router.submit([1, 2, 3], max_gen=4)
    router.kill_replica(0)
    with pytest.raises(RouterError, match="no survivors"):
        r.result(timeout=5)
    with pytest.raises(RouterError, match="no live replicas"):
        router.submit([1], max_gen=1)
