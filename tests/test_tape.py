"""Tape autodiff vs jax.grad oracle — the dx/dW split must be exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tape import Tape, compute_dw
from tests.proptest import propcase

jax.config.update("jax_enable_x64", False)


def _mini_stage(params, x):
    """A representative stage: norm -> dense -> gelu -> dense -> residual."""
    t = Tape(params, mode="fwd")
    return _mini_stage_tape(t, x).val


def _mini_stage_tape(t: Tape, x):
    h0 = t.value(x)
    h = t.prim(
        lambda scale, v: v * scale * jax.lax.rsqrt(
            jnp.mean(v * v, axis=-1, keepdims=True) + 1e-6
        ),
        h0,
        pnames=("norm.scale",),
    )
    h = t.dense(h, "w1", "bsd,df->bsf")
    h = t.elementwise(jax.nn.gelu, h)
    h = t.dense(h, "w2", "bsf,fd->bsd")
    out = t.add(h, h0)
    return out


def _make_params(key, d, f, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "norm.scale": jnp.ones((d,), dtype),
        "w1": (jax.random.normal(k1, (d, f)) * 0.05).astype(dtype),
        "w2": (jax.random.normal(k2, (f, d)) * 0.05).astype(dtype),
    }


def test_tape_matches_jax_grad():
    key = jax.random.PRNGKey(0)
    d, f = 16, 32
    params = _make_params(key, d, f)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, d))

    def loss_fn(params, x):
        return jnp.sum(_mini_stage(params, x) ** 2)

    ref_gp, ref_gx = jax.grad(loss_fn, argnums=(0, 1))(params, x)

    # Tape path: fwd to get y, seed dy = 2y, walk backward, replay dW.
    t = Tape(params, mode="bwd")
    xin = t.value(x)
    h0 = xin
    h = t.prim(
        lambda scale, v: v * scale * jax.lax.rsqrt(
            jnp.mean(v * v, axis=-1, keepdims=True) + 1e-6
        ),
        h0,
        pnames=("norm.scale",),
    )
    h = t.dense(h, "w1", "bsd,df->bsf")
    h = t.elementwise(jax.nn.gelu, h)
    h = t.dense(h, "w2", "bsf,fd->bsd")
    out = t.add(h, h0)

    dy = 2.0 * out.val
    cots, igrads, wstash = t.backward({out.idx: dy})
    dws = compute_dw(wstash)

    np.testing.assert_allclose(cots[xin.idx], ref_gx, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        igrads["norm.scale"], ref_gp["norm.scale"], rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(dws["w1"], ref_gp["w1"], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(dws["w2"], ref_gp["w2"], rtol=2e-5, atol=2e-5)
    # dW must come exclusively from the stash (deferred), not from B.
    assert "w1" not in igrads and "w2" not in igrads


@propcase(n_cases=8)
def test_tape_random_dags(draw):
    """Random fan-out/fan-in DAGs of dense+generic prims vs jax.grad."""
    d = draw.choice([4, 8, 12])
    b = draw.ints(1, 3)
    n_dense = draw.ints(1, 3)
    key = jax.random.PRNGKey(draw.ints(0, 10_000))
    ks = jax.random.split(key, n_dense + 2)
    params = {
        f"w{i}": jax.random.normal(ks[i], (d, d)) * 0.2 for i in range(n_dense)
    }
    params["scale"] = jnp.ones((d,)) + 0.1
    x = jax.random.normal(ks[-1], (b, d))

    def apply(params, x, mode="fwd"):
        t = Tape(params, mode=mode)
        v = t.value(x)
        branches = [v]
        for i in range(n_dense):
            src = branches[i % len(branches)]
            h = t.dense(src, f"w{i}", "bd,de->be")
            h = t.elementwise(jnp.tanh, h)
            branches.append(h)
        # fan-in: sum all branches, then a generic param prim
        acc = branches[0]
        for brc in branches[1:]:
            acc = t.add(acc, brc)
        out = t.prim(lambda s, v: v * s, acc, pnames=("scale",))
        return t, v, out

    def loss(params, x):
        _, _, out = apply(params, x)
        return jnp.sum(jnp.sin(out.val))

    ref_gp, ref_gx = jax.grad(loss, argnums=(0, 1))(params, x)

    t, v, out = apply(params, x, mode="bwd")
    dy = jnp.cos(out.val)
    cots, igrads, wstash = t.backward({out.idx: dy})
    dws = compute_dw(wstash)
    np.testing.assert_allclose(cots[v.idx], ref_gx, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(igrads["scale"], ref_gp["scale"], rtol=1e-4, atol=1e-5)
    for i in range(n_dense):
        np.testing.assert_allclose(
            dws[f"w{i}"], ref_gp[f"w{i}"], rtol=1e-4, atol=1e-5
        )


def test_wstash_contains_only_gemm_operands():
    """The W task must be pure GEMMs: stash holds (x, dy) pairs only."""
    params = _make_params(jax.random.PRNGKey(0), 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 8))
    t = Tape(params, mode="bwd")
    out = _mini_stage_tape(t, x)
    _, _, wstash = t.backward({out.idx: jnp.ones_like(out.val)})
    assert {s.pname for s in wstash} == {"w1", "w2"}
    for s in wstash:
        assert s.x.ndim == 3 and s.dy.ndim == 3
