"""Schedule IR + generators + simulator + autogen + Table-2 analysis."""

import numpy as np
import pytest

from repro.core import analysis
from repro.core.autogen import autogen, _postponed
from repro.core.generators import SchedParams, generate
from repro.core.schedules import B, F, W, slot_of
from repro.core.simulator import CostModel, simulate
from tests.proptest import propcase

CM = CostModel(t_f=1.0, t_b=2.0, t_w=1.0, t_p2p=0.02,
               t_gather=0.3, t_reduce=0.3)
CM_FUSED = CostModel(t_f=1.0, t_b=3.0, t_w=0.0, t_p2p=0.02,
                     t_gather=0.3, t_reduce=0.3)


@propcase(n_cases=16)
def test_generated_schedules_are_valid(draw):
    P = draw.choice([2, 3, 4, 8])
    V = draw.choice([1, 2, 3])
    B_ = draw.ints(1, 3) * P
    method = draw.choice(["gpipe", "1f1b", "interleaved", "bfs", "zeropp"])
    U = draw.choice([B_, max(1, B_ // 2)])
    split = method == "zeropp"
    tt = generate(method, SchedParams(P=P, V=V, n_mb=B_, unit=U,
                                      split_bw=split))
    tt.validate()
    c = tt.counts()
    assert c["F"] == B_ * P * V
    if split:
        assert c["W"] == B_ * P * V


def test_near_zero_bubble_when_U_geq_2P_minus_1():
    """§3.4: U ≥ 2P−1 ⟹ near-zero bubbles (paper Fig. 2 config)."""
    P, V = 4, 3
    U = 2 * P - 1
    tt = generate("zeropp", SchedParams(P=P, V=V, n_mb=U, unit=U))
    tt.validate()
    assert tt.bubble_ratio() <= 0.02
    # and with a small unit, bubbles appear
    tt2 = generate("zeropp", SchedParams(P=P, V=V, n_mb=8, unit=2))
    assert tt2.bubble_ratio() > 0.15


def test_gathers_per_unit_is_2V_minus_1():
    """§3.3: blockwise schedule gathers each stage block once per unit,
    reusing the last block's F gather for its backward: 2V−1 per unit."""
    for V in (1, 2, 3):
        for n_units in (1, 2):
            U = 8
            tt = generate("zeropp", SchedParams(P=4, V=V, n_mb=U * n_units,
                                                unit=U))
            per_rank = (tt.gather >= 0).sum() / tt.P
            assert per_rank == (2 * V - 1) * n_units, (V, n_units, per_rank)


def test_allgather_formula_matches_events():
    """#AllGather = B·L·(2V−1)/(U·P·V) — counted in layer-gathers."""
    P, V, Bmb, U, L = 4, 2, 8, 4, 16
    tt = generate("zeropp", SchedParams(P=P, V=V, n_mb=Bmb, unit=U))
    layers_per_stage = L / (P * V)
    # events are stage-block gathers; convert to layer gathers per GPU
    layer_gathers = (tt.gather >= 0).sum() / tt.P * layers_per_stage
    assert layer_gathers == pytest.approx(
        analysis.n_allgather(B=Bmb, L=L, V=V, U=U, P=P)
    )


def test_zeropp_beats_baselines_in_simulator():
    for B_ in (4, 8, 16):
        z = simulate(generate("zeropp", SchedParams(P=4, V=3, n_mb=B_)), CM)
        for m in ("interleaved", "bfs"):
            r = simulate(
                generate(m, SchedParams(P=4, V=3, n_mb=B_, split_bw=False)),
                CM_FUSED,
            )
            assert z.makespan <= r.makespan + 1e-9, (m, B_)


def test_zeropp_memory_below_bfs_at_full_unit():
    """Paper §5.1: even U=B needs less memory than BFSPP."""
    z = simulate(generate("zeropp", SchedParams(P=4, V=3, n_mb=16)), CM)
    b = simulate(
        generate("bfs", SchedParams(P=4, V=3, n_mb=16, split_bw=False)),
        CM_FUSED,
    )
    assert z.peak_mem <= b.peak_mem


def test_unit_size_tradeoff():
    """Fig 5 / Table 5: smaller U ⟹ less memory, more bubbles."""
    results = []
    for U in (2, 4, 8, 16):
        r = simulate(
            generate("zeropp", SchedParams(P=4, V=3, n_mb=16, unit=U)), CM
        )
        results.append((U, r.makespan, r.peak_mem))
    spans = [m for _, m, _ in results]
    mems = [m for _, _, m in results]
    assert spans == sorted(spans, reverse=True)   # makespan shrinks with U
    assert mems == sorted(mems)                   # memory grows with U


def test_autogen_fills_bubbles():
    """§4: the heuristic must improve the postponed-W schedule and not be
    (much) worse than greedy fill."""
    sp = SchedParams(P=4, V=2, n_mb=8)
    res = autogen(sp, CM)
    assert res.makespan_after < res.makespan_before
    assert res.n_insertions > 0
    res.table.validate()
    greedy = simulate(generate("zeropp", sp), CM)
    assert res.makespan_after <= greedy.makespan * 1.05


def test_table2_closed_forms():
    L, P, V, B_, D = 32, 4, 2, 16, 4
    g = analysis.analyze("gpipe", L=L, P=P, V=1, B=B_, D=D)
    assert g.bubble_units == 2 * (P - 1)
    assert g.act_mem == B_ * L / P
    i = analysis.analyze("interleaved", L=L, P=P, V=V, B=B_, D=D)
    assert i.bubble_units == 2 * (P - 1) / V
    z = analysis.analyze("fs-zeropp", L=L, P=P, V=V, B=B_, U=2 * P - 1, D=D)
    assert z.bubble_units == 0
    assert z.n_param_comm == pytest.approx(
        B_ * L * (2 * V - 1) / ((2 * P - 1) * P * V)
    )
    z2 = analysis.analyze("fs-zeropp", L=L, P=P, V=V, B=B_, U=4, D=D)
    assert z2.bubble_units == B_ * (2 * P - 1 - 4) / 4
    f1 = analysis.analyze("fs-1f1b", L=L, P=P, V=1, B=B_, D=D)
    assert f1.n_param_comm == 2 * B_ * L / P
    # FS-ZeroPP communicates far less than FS-1F1B
    assert z.n_param_comm < f1.n_param_comm / 5


def test_autogen_closed_forms_match_simulated_ordering():
    """Table-2-style closed forms for the §4 family: gated act memory is
    the O(U) fs-zeropp bound, full-depth is O(B) — and the simulator's
    watermark agrees with the ordering (and the gated bound)."""
    L, P, V, B_, U, D = 8, 4, 2, 8, 2, 1
    full = analysis.analyze("fs-autogen", L=L, P=P, V=V, B=B_, U=U, D=D)
    gated = analysis.analyze("fs-autogen-gated", L=L, P=P, V=V, B=B_,
                             U=U, D=D)
    assert gated.act_mem == analysis.analyze(
        "fs-zeropp", L=L, P=P, V=V, B=B_, U=U, D=D).act_mem
    assert gated.act_mem < full.act_mem
    assert full.act_mem == B_ * L / P

    sp = SchedParams(P=P, V=V, n_mb=B_, unit=U)
    import dataclasses as _dc
    sim_g = simulate(autogen(sp, CM, unit_gated=True).table, CM)
    sim_f = simulate(autogen(_dc.replace(sp, unit=B_), CM).table, CM)
    assert sim_g.peak_mem < sim_f.peak_mem
    # simulated watermark obeys the gated closed-form bound (act+stash
    # units per stage block, plus the 2-block gather buffer)
    bound = analysis.zeropp_max_alloc(
        L=P * V, P=P, D=1, V=V, B=B_, U=U,
        M_w=CM.m_weight, M_a=CM.m_act + CM.m_wstash)
    assert sim_g.peak_mem <= bound + 2 * CM.m_weight + 1e-9


@propcase(n_cases=8)
def test_simulator_invariants(draw):
    P = draw.choice([2, 4])
    V = draw.choice([1, 2])
    B_ = draw.ints(1, 4) * P
    U = draw.choice([B_, max(2, B_ // 2)])
    tt = generate("zeropp", SchedParams(P=P, V=V, n_mb=B_, unit=U))
    r = simulate(tt, CM)
    # busy time per rank = exactly the work assigned to it
    per_rank_work = B_ * V * (CM.t_f + CM.t_b + CM.t_w)
    assert np.allclose(r.busy, per_rank_work)
    assert r.makespan >= per_rank_work
    assert 0 <= r.bubble_frac < 1
    # activation watermark never exceeds the §3.4 bound (in block units):
    bound = analysis.zeropp_max_alloc(
        L=P * V, P=P, D=1, V=V, B=B_, U=U,
        M_w=CM.m_weight, M_a=CM.m_act + CM.m_wstash,
    )
    assert r.peak_mem <= bound + 2 * CM.m_weight + 1e-9
