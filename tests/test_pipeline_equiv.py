"""Pipeline-vs-reference equivalence and serving tests.

Each case runs in a subprocess with its own fake-device count so the main
pytest process keeps a single CPU device (per the brief). The cases live
in tests/spmd_case.py and print CASE_OK on success; the subprocess output
is attached to failures.
"""

import subprocess
import sys

import pytest

TIMEOUT = 1200


def _run(case: str, *args: str, env_extra: dict | None = None):
    cmd = [sys.executable, "-m", "tests.spmd_case", case, *args]
    p = subprocess.run(
        cmd, capture_output=True, text=True, timeout=TIMEOUT,
        env={**__import__("os").environ, "PYTHONPATH": "src",
             **(env_extra or {})},
        cwd=__import__("os").path.dirname(
            __import__("os").path.dirname(__file__)),
    )
    ok = f"CASE_OK {case}" in p.stdout
    if not ok:
        raise AssertionError(
            f"{case} {args} failed\n--- stdout ---\n{p.stdout[-3000:]}"
            f"\n--- stderr ---\n{p.stderr[-3000:]}"
        )


ALL_ARCHS = [
    "llama3.2-1b", "yi-9b", "minitron-4b", "phi4-mini-3.8b",
    "phi-3-vision-4.2b", "qwen2-moe-a2.7b", "deepseek-v3-671b",
    "jamba-v0.1-52b", "xlstm-1.3b", "whisper-large-v3", "gpt_paper",
]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_equivalence(arch):
    """Pipeline gradients == jax.grad(reference) for every architecture."""
    _run("train_equiv", arch)


@pytest.mark.slow
@pytest.mark.parametrize("schedule", ["bfs", "gpipe", "1f1b", "autogen",
                                      "autogen_gated"])
def test_baseline_schedules_equivalence(schedule):
    """Every baseline (and both §4 autogen tables) runs through the same
    tick engine, exactly."""
    _run("train_equiv", "llama3.2-1b", f"schedule={schedule}")


@pytest.mark.slow
def test_gated_autogen_bitwise_parity_and_memory():
    """ISSUE-5 acceptance: "autogen_gated" keeps unit-depth stash buffers,
    its gradients are bit-identical to the zeropp baseline on the smoke
    config, and its simulated peak memory is strictly below full-depth
    autogen."""
    _run("gated_autogen_parity", "llama3.2-1b")


@pytest.mark.slow
def test_executor_matches_seed_bit_for_bit():
    """The extracted tick engine must reproduce the recorded seed
    executor's train grads/metrics and served tokens bit-for-bit.
    PYTHONHASHSEED is pinned: trace-time set iteration order is the only
    run-to-run variance in this fully-deterministic CPU setup."""
    _run("golden_parity", "llama3.2-1b",
         env_extra={"PYTHONHASHSEED": "0"})


@pytest.mark.slow
def test_auto_schedule_trains_and_serves():
    """session(arch, schedule="auto"): picks the min-makespan plan among
    every registered schedule, then trains and serves with it."""
    _run("auto_schedule", "llama3.2-1b")


@pytest.mark.slow
def test_multi_pod_equivalence():
    _run("train_equiv", "llama3.2-1b", "pod=2", "data=2")


@pytest.mark.slow
def test_ep_moe_equivalence():
    _run("train_equiv", "deepseek-v3-671b", "moe_mode=ep")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2-moe-a2.7b"])
def test_pipeline_loss_decreases(arch):
    _run("loss_decreases", arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-1.3b",
                                  "qwen2-moe-a2.7b", "jamba-v0.1-52b",
                                  "deepseek-v3-671b"])
def test_serve_decode_matches_reference(arch):
    """Greedy continuation through the cached serving pipeline equals the
    reference model's — covers GQA, mLSTM/sLSTM, gathered MoE, Mamba
    hybrid and MLA compressed-KV decode paths."""
    _run("serve_decode", arch)


@pytest.mark.slow
def test_hlo_collective_structure():
    """§3.3 comm counts realized in the compiled HLO."""
    _run("hlo_gather_count", "llama3.2-1b")


@pytest.mark.slow
def test_gather_prefetch_is_numerically_neutral():
    _run("prefetch_equiv", "llama3.2-1b")


@pytest.mark.slow
def test_flat_coalesce_bitwise_parity():
    """coalesce="flat" (ONE all-gather / reduce-scatter per tick) must be
    bit-identical to per-tensor collectives: train grads + serve tokens."""
    _run("flat_parity", "llama3.2-1b")


@pytest.mark.slow
def test_flat_int8_error_feedback_reduce():
    """grad_compress="int8" through the flat reduce: one int32
    psum_scatter with a segment-wide shared scale + error feedback."""
    _run("flat_int8", "llama3.2-1b")


@pytest.mark.slow
def test_flat_fallback_mixed_divisibility():
    """Replicated (non-divisible) tensors fall back to per-tensor
    collectives bit-identically, incl. an ld != 0 flat-pack member."""
    _run("flat_fallback", "llama3.2-1b")


@pytest.mark.slow
def test_buffer_donation_audit():
    """Serve step donates caches, opt step donates params + opt state —
    input/output aliasing visible in the lowered modules."""
    _run("donation", "llama3.2-1b")


@pytest.mark.slow
def test_int8_grad_reduction():
    _run("int8_grads", "llama3.2-1b")


@pytest.mark.slow
def test_elastic_reshard_resume():
    """Checkpoint at D=4, restore and continue at D=2."""
    _run("elastic_reshard", "llama3.2-1b")
