"""Explore schedule plans through the facade: ``schedule="auto"`` runs
the §4 selection (every registered schedule + the autogen heuristic,
simulated under a hardware cost preset) and ``Session.describe()`` reports
the *selected* plan's simulated makespan / bubble ratio / gathers.

Device-free — no mesh is built.

    PYTHONPATH=src python examples/schedule_explorer.py [B] [U] [preset]
"""

import sys

from repro.api import list_schedules, session

B, U = (int(x) for x in (sys.argv[1:3] + [8, 4][len(sys.argv[1:3]):]))
preset = sys.argv[3] if len(sys.argv) > 3 else "a800"

print(f"registered schedules: {', '.join(list_schedules())}")
print(f"=== schedule=\"auto\" (B={B} U={U}, preset={preset}) ===")

sess = session(
    "llama3.2-1b",
    schedule="auto",
    cost_preset=preset,
    overrides=dict(microbatches=B, unit=U),
)
d = sess.describe()
sched = d["schedule"]

print(f"candidates (simulated makespan / peak mem / stash depth, "
      f"{preset} preset):")
for name, c in sorted(
        sched["auto"]["candidates"].items(),
        key=lambda kv: (isinstance(kv[1], str),
                        kv[1]["makespan"] if isinstance(kv[1], dict)
                        else kv[1])):
    mark = " <== selected" if name == sched["auto"]["selected"] else ""
    if isinstance(c, dict):
        span_s = (f"{c['makespan']:.3e}  mem={c['peak_mem']:.2e}  "
                  f"U={c['stash_depth']}  "
                  f"rs_saved={c['rs_overlap_saved']:.1e}")
    else:
        span_s = c
    print(f"  {name:14s} {span_s}{mark}")

print(f"\nselected plan: {sched['name']}  "
      f"(P={d['geometry']['pp']} V={d['geometry']['vpp']} "
      f"B={sched['microbatches']} U={sched['unit']})")
print(f"  ticks            {sched['ticks']}")
print(f"  makespan         {sched['makespan']:.3e}  ({sched['preset']})")
print(f"  bubble ratio     {sched['bubble_ratio']:.3f}  (simulated)")
print(f"  gathers/rank     {sched['gathers_per_rank']:.1f}")
print(f"  peak mem (sim)   {sched['peak_mem']:.3e}")

plan = sess.plan_selection.selected
print(f"\n=== selected tick table ({plan.name}) ===")
print(plan.table.render(max_ticks=48))
