"""Visualize ZeroPP vs baseline schedules and the §4 auto-generator.

    PYTHONPATH=src python examples/schedule_explorer.py [P] [V] [B] [U]
"""

import sys

from repro.api import SchedParams, generate_schedule, list_schedules
from repro.core.autogen import autogen
from repro.core.simulator import CostModel, simulate

P, V, B, U = (int(x) for x in (sys.argv[1:] + [4, 3, 7, 7][len(sys.argv) - 1:]))

print(f"registered schedules: {', '.join(list_schedules())}")
print(f"=== ZeroPP (paper Fig. 2 setting: P={P} V={V} B={B} U={U}) ===")
tt = generate_schedule("zeropp", SchedParams(P=P, V=V, n_mb=B, unit=U))
tt.validate()
print(tt.render())
print(f"tick-bubbles: {tt.bubble_ratio():.3f}   "
      f"gathers/rank: {(tt.gather >= 0).sum() / tt.P:.0f} (2V-1 per unit)")

cm = CostModel(t_f=1, t_b=2, t_w=1, t_p2p=0.02, t_gather=0.3, t_reduce=0.3)
for m, split in (("gpipe", False), ("1f1b", False), ("interleaved", False),
                 ("bfs", False), ("zeropp", True)):
    cmx = cm if split else CostModel(t_f=1, t_b=3, t_w=0, t_p2p=0.02,
                                     t_gather=0.3, t_reduce=0.3)
    r = simulate(generate_schedule(m, SchedParams(P=P, V=V, n_mb=B,
                                                  split_bw=split)), cmx)
    print(f"{m:12s} makespan={r.makespan:7.2f} bubble={r.bubble_frac:.3f} "
          f"peak_mem={r.peak_mem:.1f}")

print("\n=== §4 heuristic auto-generation ===")
res = autogen(SchedParams(P=P, V=min(V, 2), n_mb=B), cm)
print("\n".join(res.log[:6] + ["..."] + res.log[-2:]))
print(f"makespan {res.makespan_before:.2f} -> {res.makespan_after:.2f} "
      f"with {res.n_insertions} W insertions")
