"""Continuous-batching serving demo: staggered requests stream through a
fixed pool of KV-cache slots; finished requests release their slot
mid-decode and the FIFO queue refills it.

    PYTHONPATH=src python examples/serve_decode.py
"""

from repro.api import ensure_host_devices

ensure_host_devices(8)

import sys  # noqa: E402

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    sys.argv = ["serve", "--arch", "llama3.2-1b", "--slots", "4",
                "--n-requests", "8", "--prompt", "12", "--gen", "6",
                "--data", "2"]
    serve.main()
