"""Quickstart: train a tiny llama through the ZeroPP pipeline on 8 fake
CPU devices (P=2 pipeline × 4-way FSDP), watch the loss fall.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.pipeline import Runtime, make_train_step  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticStream  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.common import ShapeConfig  # noqa: E402
from repro.optim import adamw  # noqa: E402


def main():
    cfg, rc = M.get_arch("llama3.2-1b").reduced()
    rc = dataclasses.replace(rc, microbatches=4, unit=2)  # ZeroPP units!
    geo = M.build_geometry(cfg, rc)
    mesh = jax.make_mesh((8 // geo.model_ranks, geo.model_ranks),
                         ("data", "model"))
    rt = Runtime(cfg, rc, mesh)

    gb, seq = 4 * rc.microbatches, 32
    shape = ShapeConfig("quickstart", seq, gb, "train")
    step = make_train_step(rt, shape)

    params = rt.init_params(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    opt = adamw.init_state(params, opt_cfg)
    stream = SyntheticStream(DataConfig(seq_len=seq, global_batch=gb,
                                        vocab=cfg.vocab))

    @jax.jit
    def update(params, grads, opt):
        return adamw.apply_updates(params, grads, opt, opt_cfg)

    print(f"training {cfg.name}: P={rc.pp} V={rc.vpp} FSDP=4 "
          f"schedule={rc.schedule} U={rc.unit_size}")
    for s in range(60):
        grads, metrics = step(params, stream.batch(s))
        params, opt, om = update(params, grads, opt)
        if s % 10 == 0 or s == 59:
            print(f"  step {s:3d} loss {float(metrics['loss_sum']):.4f} "
                  f"gnorm {float(om['grad_norm']):.2f}")
    print("done — loss should be well below ln(vocab) =",
          f"{jnp.log(cfg.vocab):.2f}")


if __name__ == "__main__":
    main()
