"""Quickstart: train a tiny llama through the ZeroPP pipeline on 8 fake
CPU devices (P=2 pipeline × 4-way FSDP), watch the loss fall.

    PYTHONPATH=src python examples/quickstart.py

Everything goes through the ``repro.api`` Session facade — this file is
the canonical "single-GPU-style user code" the paper promises.
"""

from repro.api import ensure_host_devices, session

ensure_host_devices(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def main():
    sess = session(
        "llama3.2-1b",
        overrides=dict(microbatches=4, unit=2),  # ZeroPP units!
        seq_len=32,
        optim=dict(lr=3e-3),
    )
    d = sess.describe()
    print(f"training {sess.cfg.name}: P={sess.rc.pp} V={sess.rc.vpp} "
          f"FSDP={sess.data_size} schedule={sess.rc.schedule} "
          f"U={sess.rc.unit_size} "
          f"bubble={d['schedule']['bubble_ratio']:.3f}")

    params = sess.init_params(jax.random.PRNGKey(0))
    opt = sess.init_opt_state(params)
    stream = sess.stream()

    for s in range(60):
        grads, metrics = sess.train_step(params, stream.batch(s))
        params, opt, om = sess.opt_step(params, grads, opt)
        if s % 10 == 0 or s == 59:
            print(f"  step {s:3d} loss {float(metrics['loss_sum']):.4f} "
                  f"gnorm {float(om['grad_norm']):.2f}")
    print("done — loss should be well below ln(vocab) =",
          f"{jnp.log(sess.cfg.vocab):.2f}")


if __name__ == "__main__":
    main()
