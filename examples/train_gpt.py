"""Train a width-reduced GPT (the paper's model family) for a few hundred
steps with checkpoint/restart fault tolerance — the paper's end-to-end
scenario at laptop scale.

    PYTHONPATH=src python examples/train_gpt.py [--steps 200]
"""

from repro.api import ensure_host_devices

ensure_host_devices(8)

import argparse  # noqa: E402
import sys  # noqa: E402

from repro.launch import train  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    sys.argv = [
        "train", "--arch", "gpt_paper", "--steps", str(args.steps),
        "--data", "2", "--seq", "64", "--microbatches", "4",
        "--unit", "2", "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_gpt_ckpt", "--ckpt-every", "50",
    ]
    train.main()
