"""Pallas TPU selective-scan (Mamba-1 diagonal SSM), chunked.

Hardware mapping: grid = (batch, d-blocks, chunks) with the chunk axis
minormost, so each (b, dblk) pair walks its chunks sequentially with the
[block_d, n] state held in VMEM scratch. Within a chunk the diagonal
recurrence is solved with the log-space cumulative-sum trick (exact
because dt·A ≤ 0), turning the sequential scan into VPU-friendly cumsums
plus one [chunk, n] contraction per block — this is the TPU-native
re-blocking of the CUDA kernel's warp-parallel scan (DESIGN.md §3).

Validated in interpret mode against kernels/ref.py:selective_scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_sc, *,
                 n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    x = x_ref[0].astype(jnp.float32)        # [c, bd]
    dt = dt_ref[0].astype(jnp.float32)      # [c, bd]
    A = a_ref[...].astype(jnp.float32)      # [bd, n]
    Bm = b_ref[0].astype(jnp.float32)       # [c, n]
    Cm = c_ref[0].astype(jnp.float32)       # [c, n]
    Dd = d_ref[...].astype(jnp.float32)     # [bd]

    # h_t = a_t h_{t-1} + u_t, a_t = exp(dt·A) ∈ (0,1]; associative scan
    # keeps everything bounded (no exp(+cumsum) overflow).
    a = jnp.exp(dt[:, :, None] * A[None])   # [c, bd, n]
    u = dt[:, :, None] * Bm[:, None, :] * x[:, :, None]

    def comb(l, r):
        (la, lu), (ra, ru) = l, r
        return la * ra, lu * ra + ru

    A_cum, U_cum = jax.lax.associative_scan(comb, (a, u), axis=0)
    h_all = A_cum * h_sc[...][None] + U_cum      # [c, bd, n]
    y = jnp.einsum("cdn,cn->cd", h_all, Cm) + x * Dd[None]
    y_ref[0] = y.astype(y_ref.dtype)
    h_sc[...] = h_all[-1]


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(x, dt, A, B, C, D, *, chunk=128, block_d=256, h0=None,
                   return_state=False, interpret=False):
    """Same contract as ref.selective_scan (h0/return_state unsupported in
    the kernel path — ops.py falls back to the reference for those)."""
    assert h0 is None and not return_state, (
        "kernel path serves training; stateful decode uses the reference")
    b, s, d = x.shape
    n = A.shape[1]
    pc = -s % chunk
    pd = -d % block_d
    if pc:
        z2 = lambda a: jnp.pad(a, ((0, 0), (0, pc), (0, 0)))
        x, dt, B, C = z2(x), z2(dt), z2(B), z2(C)
    if pd:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pd)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pd)))
        A = jnp.pad(A, ((0, pd), (0, 0)))
        D = jnp.pad(D, ((0, pd),))
    sp, dp = s + pc, d + pd
    n_chunks, n_d = sp // chunk, dp // block_d

    out = pl.pallas_call(
        functools.partial(_scan_kernel, n_chunks=n_chunks),
        grid=(b, n_d, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda ib, idb, ic: (ib, ic, idb)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda ib, idb, ic: (ib, ic, idb)),
            pl.BlockSpec((block_d, n), lambda ib, idb, ic: (idb, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, idb, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, idb, ic: (ib, ic, 0)),
            pl.BlockSpec((block_d,), lambda ib, idb, ic: (idb,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda ib, idb, ic: (ib, ic, idb)),
        out_shape=jax.ShapeDtypeStruct((b, sp, dp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return out[:, :s, :d]
