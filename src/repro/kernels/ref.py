"""Pure-jnp oracles for every compute hot-spot.

These are (a) the CPU execution path, (b) the numerical ground truth each
Pallas kernel is validated against, and (c) written blockwise/streaming so
their memory behaviour matches the TPU kernels (no O(S²) materialization),
which keeps the dry-run's compiled memory analysis honest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# Attention (flash-style streaming softmax, causal / bidirectional, GQA)
# --------------------------------------------------------------------------- #


def attention(
    q: jnp.ndarray,  # [b, sq, h, e]
    k: jnp.ndarray,  # [b, sk, g, e]   g == kv heads, h % g == 0
    v: jnp.ndarray,  # [b, sk, g, e]
    *,
    causal: bool = True,
    q_offset: int | jnp.ndarray = 0,
    block_k: int = 512,
    scale: float | None = None,
) -> jnp.ndarray:
    """Streaming-softmax attention; O(sq * block_k) live memory.

    ``q_offset`` is the absolute position of q[0] (used for decode where
    sq << sk); a ``[b]`` vector gives each batch row its own offset
    (slotted serving, where every slot sits at a different position).
    Accumulation in f32 regardless of input dtype.
    """
    b, sq, h, e = q.shape
    _, sk, g, _ = k.shape
    ev = v.shape[-1]  # may differ from e (e.g. MLA)
    rep = h // g
    scale = scale if scale is not None else (1.0 / e ** 0.5)

    # pad sk to a multiple of block_k
    n_blocks = -(-sk // block_k)
    pad = n_blocks * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = (q * scale).astype(jnp.float32)
    kf = k.astype(jnp.float32).reshape(b, n_blocks, block_k, g, e)
    vf = v.astype(jnp.float32).reshape(b, n_blocks, block_k, g, ev)

    off = jnp.asarray(q_offset)
    per_row = off.ndim == 1  # [b] vector: per-slot absolute positions
    q_pos = jnp.arange(sq) + (off[:, None] if per_row else off)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, blk_idx = blk  # kb/vb: [b, block_k, g, e]
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        # scores: [b, h, sq, block_k]
        kb_h = jnp.repeat(kb, rep, axis=2)  # [b, block_k, h, e]
        s = jnp.einsum("bqhe,bkhe->bhqk", qf, kb_h.astype(jnp.float32))
        mask = k_pos[None, :] <= q_pos[..., :, None] if causal else (
            k_pos[None, :] >= 0
        ) & jnp.ones((sq, 1), bool)
        valid = k_pos < sk  # mask out sk padding
        mask = mask & valid[None, :]
        # [sq, bk] -> [1, 1, sq, bk]; per-row [b, sq, bk] -> [b, 1, sq, bk]
        mask = mask[:, None] if mask.ndim == 3 else mask[None, None]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        vb_h = jnp.repeat(vb, rep, axis=2).astype(jnp.float32)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhe->bhqe", p, vb_h
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, ev), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (
            jnp.moveaxis(kf, 1, 0),
            jnp.moveaxis(vf, 1, 0),
            jnp.arange(n_blocks),
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bhqe->bqhe", out).astype(q.dtype)


def attention_naive(q, k, v, *, causal=True, q_offset=0, scale=None):
    """O(S²) reference-of-the-reference for small-shape validation."""
    b, sq, h, e = q.shape
    _, sk, g, _ = k.shape
    rep = h // g
    scale = scale if scale is not None else (1.0 / e ** 0.5)
    kh = jnp.repeat(k, rep, axis=2)
    vh = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhe,bkhe->bhqk", q * scale, kh).astype(jnp.float32)
    if causal:
        q_pos = jnp.arange(sq) + q_offset
        mask = jnp.arange(sk)[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhe->bqhe", p, vh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len=None, scale=None):
    """Single-token decode attention. q: [b, 1, h, e], caches [b, S, g, e].

    ``cache_len``: number of valid cache positions (scalar or [b]).
    Returns [b, 1, h, e] plus (m, l) stats for cross-shard combination.
    """
    b, sq, h, e = q.shape
    _, S, g, _ = k_cache.shape
    rep = h // g
    scale = scale if scale is not None else (1.0 / e ** 0.5)
    kh = jnp.repeat(k_cache, rep, axis=2)
    vh = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhe,bkhe->bhqk", (q * scale).astype(jnp.float32), kh.astype(jnp.float32))
    if cache_len is not None:
        valid = jnp.arange(S)[None, :] < jnp.reshape(cache_len, (-1, 1))
        s = jnp.where(valid[:, None, None], s, -jnp.inf)
    m = s.max(axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhqk,bkhe->bhqe", p, vh.astype(jnp.float32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bhqe->bqhe", out).astype(q.dtype), (m, l, acc)


def paged_gather(pool, page_tables, scale=None):
    """Materialize per-row KV from a page pool.

    pool: [n_pages, ps, ...]; page_tables: [b, ppr] int32 (sentinel tail
    ids allowed — callers mask those positions causally); scale:
    optional [n_pages, ...head-dims] per-page dequant scales for int8
    pools. Returns [b, ppr*ps, ...] in f32 when dequantizing, else the
    pool dtype.
    """
    pt = jnp.clip(page_tables.astype(jnp.int32), 0, pool.shape[0] - 1)
    g = jnp.take(pool, pt, axis=0)  # [b, ppr, ps, ...]
    if scale is not None:
        sg = jnp.take(scale.astype(jnp.float32), pt, axis=0)  # [b, ppr, ...]
        sg = sg.reshape(sg.shape[:2] + (1,) + sg.shape[2:] + (1,))
        g = g.astype(jnp.float32) * sg
    return g.reshape((pt.shape[0], -1) + pool.shape[2:])


def paged_attention(q, k_pool, v_pool, *, page_tables, pos, k_scale=None,
                    v_scale=None, slot_mask=None, block_k=512, scale=None):
    """Oracle for the paged Pallas kernel: gather + dequant + attention.

    q: [b, sq, h, e]; pools [n_pages, ps, g, e/ev] (int8 with
    k_scale/v_scale [n_pages, g]); page_tables [b, ppr]; pos [b] int32
    absolute position of q[:, 0]. Sentinel tail pages are masked by the
    exact causal mask (their logical positions exceed pos). slot_mask
    [b] bool zeroes masked-off rows.
    """
    sq = q.shape[1]
    k = paged_gather(k_pool, page_tables, k_scale)
    v = paged_gather(v_pool, page_tables, v_scale)
    off = jnp.asarray(pos, jnp.int32).reshape(-1)
    if slot_mask is not None:
        off = jnp.where(slot_mask, off, -sq)
    return attention(q, k, v, causal=True, q_offset=off, block_k=block_k,
                     scale=scale)


def combine_decode_shards(partials):
    """Flash-decoding combine: merge per-shard (m, l, acc) stats.

    partials: list of (m, l, acc) with m,l [b,h,1], acc [b,h,1,e].
    """
    m = functools.reduce(jnp.maximum, [p[0] for p in partials])
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    l = sum(
        p_l * jnp.where(jnp.isfinite(p_m), jnp.exp(p_m - m_safe), 0.0)
        for p_m, p_l, _ in partials
    )
    acc = sum(
        p_acc * jnp.where(jnp.isfinite(p_m), jnp.exp(p_m - m_safe), 0.0)[..., None]
        for p_m, _, p_acc in partials
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bhqe->bqhe", out)


# --------------------------------------------------------------------------- #
# Selective scan (Mamba-1 diagonal SSM), chunked
# --------------------------------------------------------------------------- #


def selective_scan(
    x: jnp.ndarray,      # [b, s, d]      (post-conv activations)
    dt: jnp.ndarray,     # [b, s, d]      (softplus'd timestep)
    A: jnp.ndarray,      # [d, n]         (negative; A = -exp(A_log))
    B: jnp.ndarray,      # [b, s, n]
    C: jnp.ndarray,      # [b, s, n]
    D: jnp.ndarray,      # [d]
    *,
    chunk: int = 256,
    h0: jnp.ndarray | None = None,  # [b, d, n] initial state
    return_state: bool = False,
):
    """y_t = C_t · h_t + D x_t with h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.

    Chunked: within a chunk the diagonal recurrence is solved with a
    log-space cumulative sum; chunks are chained with a [b, d, n] state.
    """
    b, s, d = x.shape
    n = A.shape[1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, d)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, d)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, n)
    Af = A.astype(jnp.float32)

    def body(h, blk):
        xc, dtc, Bc, Cc = blk  # [b, chunk, ...]
        # h_t = a_t h_{t-1} + u_t with a_t = exp(dt_t A) ∈ (0, 1]:
        # solved with a numerically-safe associative scan (no exp(+G)).
        a = jnp.exp(dtc[..., None] * Af[None, None])          # [b,c,d,n]
        u = dtc[..., None] * Bc[:, :, None, :] * xc[..., None]

        def comb(l, r):
            (la, lu), (ra, ru) = l, r
            return la * ra, lu * ra + ru

        A_cum, U_cum = jax.lax.associative_scan(comb, (a, u), axis=1)
        h_all = A_cum * h[:, None] + U_cum  # [b, c, d, n]
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Cc)
        return h_all[:, -1], y

    h = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((b, d, n), jnp.float32)
    )
    h, ys = jax.lax.scan(
        body,
        h,
        (
            jnp.moveaxis(xf, 1, 0),
            jnp.moveaxis(dtf, 1, 0),
            jnp.moveaxis(Bf, 1, 0),
            jnp.moveaxis(Cf, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, d)[:, :s]
    y = y + x[:, :s].astype(jnp.float32) * D[None, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, h
    return y


def selective_scan_step(h, x, dt, A, B, C, D):
    """One decode step. h: [b, d, n]; x, dt: [b, d]; B, C: [b, n]."""
    g = jnp.exp(dt[..., None] * A[None])  # [b, d, n]
    h_new = g * h + dt[..., None] * B[:, None, :] * x[..., None]
    y = jnp.einsum("bdn,bn->bd", h_new, C) + D[None] * x
    return h_new, y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# mLSTM (xLSTM matrix-memory cell), chunkwise-parallel
# --------------------------------------------------------------------------- #


def mlstm_chunkwise(
    q: jnp.ndarray,   # [b, s, h, e]
    k: jnp.ndarray,   # [b, s, h, e]
    v: jnp.ndarray,   # [b, s, h, e]
    i_gate: jnp.ndarray,  # [b, s, h]  (pre-exp log input gate)
    f_gate: jnp.ndarray,  # [b, s, h]  (pre-sigmoid forget gate logits)
    *,
    chunk: int = 128,
    state: tuple | None = None,
    return_state: bool = False,
):
    """Chunkwise mLSTM: within-chunk quadratic, cross-chunk O(e²) state.

    Stabilized per the xLSTM paper with a running max-log-gate m.
    """
    b, s, h, e = q.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        z = lambda a, cv=0.0: jnp.pad(
            a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
            constant_values=cv)
        q, k, v = z(q), z(k), z(v)
        # padded steps must be identity for the carried state:
        # i → -inf (no write), f-logit → +inf (log-sigmoid 0, no decay)
        i_gate = z(i_gate, -1e30)
        f_gate = z(f_gate, 1e30)

    qf = q.astype(jnp.float32).reshape(b, nc, chunk, h, e) * (e ** -0.5)
    kf = k.astype(jnp.float32).reshape(b, nc, chunk, h, e)
    vf = v.astype(jnp.float32).reshape(b, nc, chunk, h, e)
    ig = i_gate.astype(jnp.float32).reshape(b, nc, chunk, h)
    fg = jax.nn.log_sigmoid(f_gate.astype(jnp.float32)).reshape(b, nc, chunk, h)

    def body(carry, blk):
        # Stabilized chunkwise form. State is stored pre-scaled by exp(-m):
        #   C_hat = C * exp(-m),  n_hat = n * exp(-m).
        C, nrm, m = carry  # C: [b,h,e,e], nrm: [b,h,e], m: [b,h]
        qc, kc, vc, ic, fc = blk
        c = qc.shape[1]
        F = jnp.cumsum(fc, axis=1)  # [b, c, h] inclusive cumulative log-f
        # per-position stabilizer m_t = max(m_prev + F_t, F_t + cummax(i_j - F_j))
        Mi = jax.lax.cummax(ic - F, axis=1)  # [b, c, h]
        m_t = jnp.maximum(m[:, None] + F, F + Mi)  # [b, c, h]
        # old-state contribution, weight exp(m_prev + F_t - m_t)
        w_old = jnp.exp(m[:, None] + F - m_t)  # [b, c, h]
        out_inter = (
            jnp.einsum("bche,bhef->bchf", qc, C) * w_old[..., None]
        )
        nrm_inter = jnp.einsum("bche,bhe->bch", qc, nrm) * w_old
        # intra-chunk pair weights w_tj = exp(F_t - F_j + i_j - m_t), j <= t
        lw = (
            F[:, :, None, :] - F[:, None, :, :] + ic[:, None, :, :]
            - m_t[:, :, None, :]
        )  # [b, t, j, h]
        cpos = jnp.arange(c)
        causal_m = (cpos[None, :] <= cpos[:, None])[None, :, :, None]
        w_pair = jnp.where(causal_m, jnp.exp(lw), 0.0)
        sc = jnp.einsum("bche,bjhe->bcjh", qc, kc)  # [b, t, j, h]
        sw = sc * w_pair
        out_intra = jnp.einsum("bcjh,bjhe->bche", sw, vc)
        nrm_intra = sw.sum(axis=2)  # [b, t, h]
        nrm_t = nrm_inter + nrm_intra
        denom = jnp.maximum(jnp.abs(nrm_t), jnp.exp(-m_t))
        yc = (out_inter + out_intra) / denom[..., None]
        # new state at chunk end
        m_end = m_t[:, -1]  # [b, h]
        decay = jnp.exp(m + F[:, -1] - m_end)  # [b, h]
        w_j = jnp.exp(F[:, -1:, :] - F + ic - m_end[:, None])  # [b, c, h]
        C_new = C * decay[..., None, None] + jnp.einsum(
            "bche,bchf,bch->bhef", kc, vc, w_j
        )
        nrm_new = nrm * decay[..., None] + jnp.einsum(
            "bche,bch->bhe", kc, w_j
        )
        return (C_new, nrm_new, m_end), yc

    if state is None:
        C0 = jnp.zeros((b, h, e, e), jnp.float32)
        n0 = jnp.zeros((b, h, e), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        C0, n0, m0 = state
    (C, nrm, m), ys = jax.lax.scan(
        body,
        (C0, n0, m0),
        (
            jnp.moveaxis(qf, 1, 0),
            jnp.moveaxis(kf, 1, 0),
            jnp.moveaxis(vf, 1, 0),
            jnp.moveaxis(ig, 1, 0),
            jnp.moveaxis(fg, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, h, e)[:, :s]
    if return_state:
        return y.astype(q.dtype), (C, nrm, m)
    return y.astype(q.dtype)


def mlstm_step(state, q, k, v, i_gate, f_gate):
    """One decode step. q/k/v: [b, h, e]; gates [b, h]."""
    C, nrm, m = state
    e = q.shape[-1]
    qf = q.astype(jnp.float32) * (e ** -0.5)
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    m_new = jnp.maximum(lf + m, i_gate.astype(jnp.float32))
    i_w = jnp.exp(i_gate - m_new)
    decay = jnp.exp(lf + m - m_new)
    C_new = C * decay[..., None, None] + jnp.einsum(
        "bhe,bhf,bh->bhef", k.astype(jnp.float32), v.astype(jnp.float32), i_w
    )
    n_new = nrm * decay[..., None] + k.astype(jnp.float32) * i_w[..., None]
    num = jnp.einsum("bhe,bhef->bhf", qf, C_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhe,bhe->bh", qf, n_new)), jnp.exp(-m_new)
    )
    y = num / den[..., None]
    return (C_new, n_new, m_new), y.astype(q.dtype)


# --------------------------------------------------------------------------- #
# sLSTM (scalar-memory cell with exponential gating), sequential scan
# --------------------------------------------------------------------------- #


def slstm_scan(
    x_gates: jnp.ndarray,  # [b, s, h, 4, e] pre-activations (i, f, z, o)
    *,
    state: tuple | None = None,
    return_state: bool = False,
):
    """sLSTM recurrence (no recurrent weights — block-diagonal simplification
    with R=0 keeps the cell exactly computable as a scan; the recurrent-R
    variant is noted in DESIGN.md as a deviation)."""
    b, s, h, _, e = x_gates.shape
    xg = x_gates.astype(jnp.float32)

    def body(carry, g):
        c, n, m = carry  # [b, h, e] each
        gi, gf, gz, go = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
        lf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(lf + m, gi)
        i_w = jnp.exp(gi - m_new)
        f_w = jnp.exp(lf + m - m_new)
        c_new = f_w * c + i_w * jnp.tanh(gz)
        n_new = f_w * n + i_w
        y = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new), y

    if state is None:
        z = jnp.zeros((b, h, e), jnp.float32)
        state = (z, z, z)
    state, ys = jax.lax.scan(body, state, jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).astype(x_gates.dtype)
    if return_state:
        return y, state
    return y


# --------------------------------------------------------------------------- #
# Chunked-vocab softmax cross-entropy (fwd + explicit bwd)
# --------------------------------------------------------------------------- #


def softmax_xent(
    h: jnp.ndarray,        # [n, d] final hiddens
    w_head: jnp.ndarray,   # [d, vocab]
    labels: jnp.ndarray,   # [n] int32
    *,
    chunk: int = 8192,
    mask: jnp.ndarray | None = None,  # [n] 1.0 = count this token
):
    """Returns (mean loss, (dh, dW)) without materializing [n, vocab].

    The backward is hand-derived: dlogits = softmax - onehot, streamed over
    vocab chunks; this also serves as the oracle for the fused_xent kernel.
    """
    n, d = h.shape
    vocab = w_head.shape[1]
    nc = -(-vocab // chunk)
    padded = nc * chunk
    # pad the head so chunk slices never clamp (dynamic_slice clamps OOB
    # starts, which would double-count the tail columns)
    w_pad = jnp.pad(w_head, ((0, 0), (0, padded - vocab)))
    hf = h.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)

    def pass1(carry, ci):
        m, l, corr = carry
        lo = ci * chunk
        wc = jax.lax.dynamic_slice(w_pad, (0, lo), (d, chunk))
        logits = hf @ wc.astype(jnp.float32)  # [n, chunk]
        col = lo + jnp.arange(chunk)
        valid = col < vocab
        logits = jnp.where(valid[None], logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        l_new = l * jnp.exp(jnp.where(jnp.isfinite(m), m, m_safe) - m_safe) + jnp.where(
            valid[None], jnp.exp(logits - m_safe[:, None]), 0.0
        ).sum(axis=1)
        # label logit: grab if in this chunk
        in_chunk = (labels >= lo) & (labels < lo + chunk)
        idx = jnp.clip(labels - lo, 0, chunk - 1)
        lab_logit = jnp.take_along_axis(logits, idx[:, None], axis=1)[:, 0]
        corr = jnp.where(in_chunk, lab_logit, corr)
        return (m_new, l_new, corr), None

    m0 = jnp.full((n,), -jnp.inf, jnp.float32)
    (m, l, lab), _ = jax.lax.scan(
        pass1, (m0, jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32)),
        jnp.arange(nc),
    )
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    loss_tok = (lse - lab) * mask
    loss = loss_tok.sum() / denom

    def pass2(carry, ci):
        dh = carry
        lo = ci * chunk
        wc = jax.lax.dynamic_slice(w_pad, (0, lo), (d, chunk))
        logits = hf @ wc.astype(jnp.float32)
        col = lo + jnp.arange(chunk)
        valid = col < vocab
        p = jnp.where(valid[None], jnp.exp(logits - lse[:, None]), 0.0)
        onehot = (labels[:, None] == col[None]).astype(jnp.float32)
        dlog = (p - onehot) * (mask / denom)[:, None]  # [n, chunk]
        dh = dh + dlog @ wc.astype(jnp.float32).T
        dwc = hf.T @ dlog  # [d, chunk]
        return dh, dwc

    dh, dws = jax.lax.scan(pass2, jnp.zeros((n, d), jnp.float32), jnp.arange(nc))
    dw = jnp.moveaxis(dws, 0, 1).reshape(d, padded)[:, :vocab]
    return loss, (dh.astype(h.dtype), dw.astype(w_head.dtype))


def softmax_xent_naive(h, w_head, labels, mask=None):
    logits = h.astype(jnp.float32) @ w_head.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones(h.shape[:1], jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=1)
    lab = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return ((lse - lab) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
