"""Dispatch layer: Pallas TPU kernels on TPU, jnp references elsewhere.

All model code calls through these functions. The choice is made per-call
from (a) the default backend, (b) the ``REPRO_FORCE_REF`` env var, and
(c) an explicit ``impl=`` override — so tests can compare both paths.
"""

from __future__ import annotations

import os

import jax

from repro.kernels import ref

_FORCE_REF = os.environ.get("REPRO_FORCE_REF", "0") == "1"
_WARNED_VECTOR_OFFSET = False


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _use_pallas(impl: str | None) -> bool:
    if impl == "pallas":
        return True
    if impl == "ref":
        return False
    return _on_tpu() and not _FORCE_REF


# --------------------------------------------------------------------------- #


def attention(q, k, v, *, causal=True, q_offset=0, block_k=512, impl=None):
    # per-row q_offset vectors (slotted serving) are only implemented by
    # the reference path; the Pallas kernel takes a scalar offset.
    if getattr(q_offset, "ndim", 0):
        if _use_pallas(impl):
            global _WARNED_VECTOR_OFFSET
            if not _WARNED_VECTOR_OFFSET:
                _WARNED_VECTOR_OFFSET = True
                import warnings

                warnings.warn(
                    "per-row q_offset (slotted serving) falls back to "
                    "the reference attention kernel on this backend; "
                    "expect a perf hit vs the Pallas path, and token "
                    "identity with scalar-pos serving only holds within "
                    "one kernel implementation", stacklevel=2)
        impl = "ref"
    if _use_pallas(impl):
        from repro.kernels import flash_attention

        return flash_attention.flash_attention(
            q, k, v, causal=causal, q_offset=q_offset
        )
    return ref.attention(
        q, k, v, causal=causal, q_offset=q_offset, block_k=block_k
    )


def decode_attention(q, k_cache, v_cache, cache_len=None, impl=None):
    return ref.decode_attention(q, k_cache, v_cache, cache_len=cache_len)


def combine_decode_shards(partials):
    return ref.combine_decode_shards(partials)


def selective_scan(x, dt, A, B, C, D, *, chunk=256, h0=None,
                   return_state=False, impl=None):
    if _use_pallas(impl):
        from repro.kernels import selective_scan as ss

        return ss.selective_scan(
            x, dt, A, B, C, D, chunk=chunk, h0=h0, return_state=return_state
        )
    return ref.selective_scan(
        x, dt, A, B, C, D, chunk=chunk, h0=h0, return_state=return_state
    )


def selective_scan_step(h, x, dt, A, B, C, D):
    return ref.selective_scan_step(h, x, dt, A, B, C, D)


def mlstm_chunkwise(q, k, v, i_gate, f_gate, *, chunk=128, state=None,
                    return_state=False, impl=None):
    return ref.mlstm_chunkwise(
        q, k, v, i_gate, f_gate, chunk=chunk, state=state,
        return_state=return_state,
    )


def mlstm_step(state, q, k, v, i_gate, f_gate):
    return ref.mlstm_step(state, q, k, v, i_gate, f_gate)


def slstm_scan(x_gates, *, state=None, return_state=False, impl=None):
    return ref.slstm_scan(x_gates, state=state, return_state=return_state)


def softmax_xent(h, w_head, labels, *, chunk=8192, mask=None, impl=None):
    if _use_pallas(impl):
        from repro.kernels import fused_xent

        return fused_xent.softmax_xent(h, w_head, labels, mask=mask)
    return ref.softmax_xent(h, w_head, labels, chunk=chunk, mask=mask)
