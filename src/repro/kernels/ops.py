"""Dispatch layer: Pallas TPU kernels on TPU, jnp references elsewhere.

All model code calls through these functions. The choice is made per-call
from (a) the default backend, (b) the ``REPRO_FORCE_REF`` env var, and
(c) an explicit ``impl=`` override — so tests can compare both paths.

Every dispatch decision bumps a trace-time counter (``kernel_counters``):
one count per *traced call site*, not per executed step, since dispatch
happens in Python while jit-tracing. ``Session.describe()["kernels"]``
reports per-session deltas; ``fallback_*`` keys mark calls where Pallas
was selected but the shape/backend combination still forced the ref path.
"""

from __future__ import annotations

import os

import jax

from repro.kernels import ref

_FORCE_REF = os.environ.get("REPRO_FORCE_REF", "0") == "1"

# Trace-time dispatch counters, keyed by implementation event. Monotonic
# process-wide; consumers snapshot and diff (see Session.describe()).
_COUNTERS: dict[str, int] = {}


def _count(event: str) -> None:
    _COUNTERS[event] = _COUNTERS.get(event, 0) + 1


def kernel_counters() -> dict[str, int]:
    """Snapshot of the trace-time dispatch counters (copy, safe to keep)."""
    return dict(_COUNTERS)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _use_pallas(impl: str | None) -> bool:
    if impl == "pallas":
        return True
    if impl == "ref":
        return False
    return _on_tpu() and not _FORCE_REF


def _interpret() -> bool:
    # explicit impl="pallas" off-TPU runs the kernels in interpret mode
    # (CPU tests); on TPU they compile for real.
    return not _on_tpu()


# --------------------------------------------------------------------------- #


def attention(q, k, v, *, causal=True, q_offset=0, block_k=512, impl=None):
    vector_off = getattr(q_offset, "ndim", 0) == 1
    traced_off = isinstance(q_offset, jax.core.Tracer) or vector_off or (
        getattr(q_offset, "ndim", None) == 0
    )
    if _use_pallas(impl):
        if causal and traced_off:
            # slot-aware kernel: per-row (or traced scalar) positions are
            # applied in-kernel; no ref fallback on the serving hot path.
            from repro.kernels import paged_attention as pa

            _count("pallas_slotted")
            import jax.numpy as jnp

            pos = jnp.asarray(q_offset, jnp.int32).reshape(-1)
            return pa.flash_attention_slotted(
                q, k, v, pos=pos, block_k=min(block_k, 128),
                interpret=_interpret())
        if not traced_off:
            # static scalar offset: the training-path flash kernel.
            _count("pallas_flash")
            from repro.kernels import flash_attention

            return flash_attention.flash_attention(
                q, k, v, causal=causal, q_offset=q_offset,
                interpret=_interpret())
        # non-causal with traced offset has no Pallas lowering; visible
        # (counted) fallback rather than a once-per-process warning.
        _count("fallback_attention_ref")
        impl = "ref"
    else:
        _count("ref_attention")
    return ref.attention(
        q, k, v, causal=causal, q_offset=q_offset, block_k=block_k
    )


def paged_attention(q, k_pool, v_pool, *, page_tables, pos, k_scale=None,
                    v_scale=None, slot_mask=None, block_k=512, impl=None):
    """Attention straight out of a paged KV pool (optionally int8 pages).

    Pallas path runs the page-table-native kernel (dequant in-kernel);
    ref path gathers + dequants with jnp and reuses ``ref.attention`` —
    identical math, so CPU tests pin the numerics.
    """
    if _use_pallas(impl):
        _count("pallas_paged")
        from repro.kernels import paged_attention as pa

        return pa.paged_attention(
            q, k_pool, v_pool, page_tables=page_tables, pos=pos,
            k_scale=k_scale, v_scale=v_scale, slot_mask=slot_mask,
            interpret=_interpret())
    _count("ref_paged")
    return ref.paged_attention(
        q, k_pool, v_pool, page_tables=page_tables, pos=pos,
        k_scale=k_scale, v_scale=v_scale, slot_mask=slot_mask,
        block_k=block_k)


def decode_attention(q, k_cache, v_cache, cache_len=None, impl=None):
    if _use_pallas(impl):
        _count("pallas_decode")
        from repro.kernels import paged_attention as pa

        return pa.decode_attention(
            q, k_cache, v_cache, cache_len, interpret=_interpret())
    _count("ref_decode")
    return ref.decode_attention(q, k_cache, v_cache, cache_len=cache_len)


def combine_decode_shards(partials):
    return ref.combine_decode_shards(partials)


def selective_scan(x, dt, A, B, C, D, *, chunk=256, h0=None,
                   return_state=False, impl=None):
    if _use_pallas(impl):
        from repro.kernels import selective_scan as ss

        return ss.selective_scan(
            x, dt, A, B, C, D, chunk=chunk, h0=h0, return_state=return_state
        )
    return ref.selective_scan(
        x, dt, A, B, C, D, chunk=chunk, h0=h0, return_state=return_state
    )


def selective_scan_step(h, x, dt, A, B, C, D):
    return ref.selective_scan_step(h, x, dt, A, B, C, D)


def mlstm_chunkwise(q, k, v, i_gate, f_gate, *, chunk=128, state=None,
                    return_state=False, impl=None):
    return ref.mlstm_chunkwise(
        q, k, v, i_gate, f_gate, chunk=chunk, state=state,
        return_state=return_state,
    )


def mlstm_step(state, q, k, v, i_gate, f_gate):
    return ref.mlstm_step(state, q, k, v, i_gate, f_gate)


def slstm_scan(x_gates, *, state=None, return_state=False, impl=None):
    return ref.slstm_scan(x_gates, state=state, return_state=return_state)


def softmax_xent(h, w_head, labels, *, chunk=8192, mask=None, impl=None):
    if _use_pallas(impl):
        from repro.kernels import fused_xent

        return fused_xent.softmax_xent(h, w_head, labels, mask=mask)
    return ref.softmax_xent(h, w_head, labels, chunk=chunk, mask=mask)
