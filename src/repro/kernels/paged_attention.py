"""Pallas TPU slot-aware attention: per-row positions + paged KV pools.

The serving hot path hands attention three things the training kernel
(`flash_attention.py`) never sees: a *per-row* position vector (every
continuous-batching slot sits at its own absolute offset), a *page
table* per request (the KV bytes live scattered in a shared page pool),
and optionally *int8 pages* with per-page×head scales. Until this
kernel, all three forced the jnp reference path. Two entry points:

  * ``flash_attention_slotted`` — contiguous cache ``[b, S, g, e]``,
    per-row int32 ``pos``. ``window=False`` applies the per-row causal
    mask ``k_pos <= pos[b] + i`` in-kernel; ``window=True`` is the
    decode-attention contract ``k_pos < pos[b]`` (``pos`` = cache_len).
    ``return_stats`` also returns flash-decoding ``(m, l, acc)``
    partials shaped exactly like ``ref.decode_attention``'s, so the
    sequence-shard merge (``combine_decode_shards`` / psum-logsumexp)
    is implementation-agnostic.
  * ``paged_attention`` — the cache never materializes per-row: the
    grid's minormost dim walks each row's page-table entries and the
    K/V BlockSpec index maps chase the (scalar-prefetched) page ids
    straight into the pool ``[n_pages, page_size, g, e]``. Sentinel
    tail entries (id 0 past a row's reserved length) drag in arbitrary
    live pages whose *logical* positions all exceed the row's causal
    offset — the same exact-causal masking zeroes them that the ref
    gather path relies on. With ``k_scale``/``v_scale`` (int8 pages)
    the dequant ``q_int8 * scale`` happens in-kernel, in f32, matching
    the ref path's gather-then-dequant bit for bit per key.

Hardware mapping follows flash_attention.py: (m, l, acc) running state
in VMEM scratch across the sequential minormost grid dim, f32
accumulation, GQA via the ``bh // rep`` K/V index fold, separate value
dim ``ev`` (MLA-absorbed: e = qk_nope + rope ≠ ev = v_head). Per-page
scales ride in SMEM (scalar per grid step). Both kernels emit the
*unnormalized* accumulator plus (m, l); the wrappers normalize — the
same final ``acc / max(l, eps)`` the reference performs.

Validated against kernels/ref.py in interpret mode by
tests/test_kernels.py (staggered pos, sentinel tails, GQA/MLA dims,
int8 error bound).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


# --------------------------------------------------------------------------- #
# Slotted (contiguous-cache, vector-pos) kernel
# --------------------------------------------------------------------------- #


def _slotted_kernel(pos_ref, q_ref, k_ref, v_ref, acc_o, m_o, l_o,
                    m_sc, l_sc, acc_sc, *, h, block_k, sk, sq_p, scale,
                    n_k, window):
    bh = pl.program_id(0)
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32) * scale        # [sq_p, e]
    k = k_ref[0].astype(jnp.float32)                # [bk, e]
    v = v_ref[0].astype(jnp.float32)                # [bk, ev]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [sq_p, bk]

    row = pos_ref[bh // h]                           # this batch row's pos
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (sq_p, block_k), 1)
    mask = k_pos < sk
    if window:
        # decode-attention contract: every q row sees k_pos < cache_len
        mask = mask & (k_pos < row)
    else:
        q_pos = row + jax.lax.broadcasted_iota(
            jnp.int32, (sq_p, block_k), 0)
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        acc_o[0] = acc_sc[...]
        m_o[0] = m_sc[...]
        l_o[0] = l_sc[...]


@functools.partial(
    jax.jit,
    static_argnames=("window", "return_stats", "block_k", "interpret"),
)
def flash_attention_slotted(q, k, v, *, pos, window=False,
                            return_stats=False, block_k=128,
                            interpret=False):
    """q: [b, sq, h, e]; k: [b, S, g, e]; v: [b, S, g, ev]; pos: [b] int32.

    window=False → per-row causal (k_pos <= pos[b] + i);
    window=True  → decode window (k_pos < pos[b], pos = cache_len).
    Returns [b, sq, h, ev] — with return_stats, (out, (m, l, acc)) in
    ``ref.decode_attention``'s layout (m, l: [b, h, sq]; acc f32
    [b, h, sq, ev]).
    """
    b, sq, h, e = q.shape
    _, sk, g, ev = v.shape
    rep = h // g
    scale = 1.0 / (e ** 0.5)

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))

    sq_p = _ceil_to(sq, 8)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    pk = -sk % block_k
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    n_k = (sk + pk) // block_k

    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq_p, e)
    kr = k.transpose(0, 2, 1, 3).reshape(b * g, sk + pk, e)
    vr = v.transpose(0, 2, 1, 3).reshape(b * g, sk + pk, ev)

    kernel = functools.partial(
        _slotted_kernel, h=h, block_k=block_k, sk=sk, sq_p=sq_p,
        scale=scale, n_k=n_k, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * h, n_k),
        in_specs=[
            pl.BlockSpec((1, sq_p, e), lambda bh, ik, pr: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, e),
                         lambda bh, ik, pr, rep=rep: (bh // rep, ik, 0)),
            pl.BlockSpec((1, block_k, ev),
                         lambda bh, ik, pr, rep=rep: (bh // rep, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, sq_p, ev), lambda bh, ik, pr: (bh, 0, 0)),
            pl.BlockSpec((1, sq_p), lambda bh, ik, pr: (bh, 0)),
            pl.BlockSpec((1, sq_p), lambda bh, ik, pr: (bh, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((sq_p,), jnp.float32),
            pltpu.VMEM((sq_p,), jnp.float32),
            pltpu.VMEM((sq_p, ev), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * h, sq_p, ev), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq_p), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq_p), jnp.float32),
        ],
        interpret=interpret,
    )(pos, qr, kr, vr)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(b, h, sq_p, ev)[:, :, :sq]
    o_bqhe = out.transpose(0, 2, 1, 3).astype(q.dtype)
    if not return_stats:
        return o_bqhe
    m = m.reshape(b, h, sq_p)[:, :, :sq]
    l = l.reshape(b, h, sq_p)[:, :, :sq]
    acc = acc.reshape(b, h, sq_p, ev)[:, :, :sq]
    return o_bqhe, (m, l, acc)


def decode_attention(q, k_cache, v_cache, cache_len=None, *, block_k=128,
                     interpret=False):
    """Drop-in for ``ref.decode_attention`` on the slotted kernel.

    q: [b, sq, h, e]; caches [b, S, g, e/ev]; cache_len scalar or [b]
    (None → the full cache is valid). Returns (out, (m, l, acc)).
    """
    S = k_cache.shape[1]
    if cache_len is None:
        cache_len = S
    return flash_attention_slotted(
        q, k_cache, v_cache, pos=cache_len, window=True,
        return_stats=True, block_k=block_k, interpret=interpret)


# --------------------------------------------------------------------------- #
# Paged (page-table-native) kernel
# --------------------------------------------------------------------------- #


def _paged_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, acc_o, m_o, l_o,
                  m_sc, l_sc, acc_sc, *, h, ps, sq_p, scale, ppr,
                  ks_ref=None, vs_ref=None):
    bh = pl.program_id(0)
    ip = pl.program_id(1)

    @pl.when(ip == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32) * scale        # [sq_p, e]
    k = k_ref[0, :, 0, :].astype(jnp.float32)       # [ps, e]
    v = v_ref[0, :, 0, :].astype(jnp.float32)       # [ps, ev]
    if ks_ref is not None:
        k = k * ks_ref[0, 0]
        v = v * vs_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [sq_p, ps]

    # logical key positions of this page-table entry; sentinel tail
    # entries sit past the row's causal offset, so the exact causal
    # mask zeroes whatever live page their id 0 happens to alias.
    row = pos_ref[bh // h]
    k_pos = ip * ps + jax.lax.broadcasted_iota(jnp.int32, (sq_p, ps), 1)
    q_pos = row + jax.lax.broadcasted_iota(jnp.int32, (sq_p, ps), 0)
    mask = k_pos <= q_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ip == ppr - 1)
    def _flush():
        acc_o[0] = acc_sc[...]
        m_o[0] = m_sc[...]
        l_o[0] = l_sc[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, *, page_tables, pos, k_scale=None,
                    v_scale=None, slot_mask=None, interpret=False):
    """Attention straight out of the page pool — no per-row gather.

    q: [b, sq, h, e]; pools [n_pages, ps, g, e] / [n_pages, ps, g, ev]
    (int8 with ``k_scale``/``v_scale`` [n_pages, g] f32, else float);
    page_tables: [b, ppr] int32 shard-local page ids (sentinel tails
    allowed); pos: [b] int32 first absolute position of each row's q.
    ``slot_mask`` [b] bool: masked-off rows emit zeros (their page
    tables may be stale). Returns [b, sq, h, ev] in q.dtype.
    """
    b, sq, h, e = q.shape
    n_pages, ps, g, ev = v_pool.shape
    rep = h // g
    scale = 1.0 / (e ** 0.5)
    quant = k_scale is not None

    pt = jnp.clip(page_tables.astype(jnp.int32), 0, n_pages - 1)
    ppr = pt.shape[1]
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    if slot_mask is not None:
        # masked rows: push the causal offset below every key position
        # so the row is fully masked (l == 0 → output exactly 0).
        pos = jnp.where(slot_mask, pos, -sq)

    sq_p = _ceil_to(sq, 8)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq_p, e)

    kernel = functools.partial(
        _paged_kernel, h=h, ps=ps, sq_p=sq_p, scale=scale, ppr=ppr)
    if quant:
        def kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                   acc_o, m_o, l_o, m_sc, l_sc, acc_sc):
            return _paged_kernel(
                pt_ref, pos_ref, q_ref, k_ref, v_ref, acc_o, m_o, l_o,
                m_sc, l_sc, acc_sc, h=h, ps=ps, sq_p=sq_p, scale=scale,
                ppr=ppr, ks_ref=ks_ref, vs_ref=vs_ref)

    def page_map(bh, ip, pt_ref, pos_ref, rep=rep):
        return (pt_ref[bh // h, ip], 0, (bh % h) // rep, 0)

    def scale_map(bh, ip, pt_ref, pos_ref, rep=rep):
        return (pt_ref[bh // h, ip], (bh % h) // rep)

    in_specs = [
        pl.BlockSpec((1, sq_p, e), lambda bh, ip, ptr, pr: (bh, 0, 0)),
        pl.BlockSpec((1, ps, 1, e), page_map),
        pl.BlockSpec((1, ps, 1, ev), page_map),
    ]
    operands = [qr, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1), scale_map, memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), scale_map, memory_space=pltpu.SMEM),
        ]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * h, ppr),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, sq_p, ev), lambda bh, ip, ptr, pr: (bh, 0, 0)),
            pl.BlockSpec((1, sq_p), lambda bh, ip, ptr, pr: (bh, 0)),
            pl.BlockSpec((1, sq_p), lambda bh, ip, ptr, pr: (bh, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((sq_p,), jnp.float32),
            pltpu.VMEM((sq_p,), jnp.float32),
            pltpu.VMEM((sq_p, ev), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * h, sq_p, ev), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq_p), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq_p), jnp.float32),
        ],
        interpret=interpret,
    )(pt, pos, *operands)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(b, h, sq_p, ev)[:, :, :sq]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)
