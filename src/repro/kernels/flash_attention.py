"""Pallas TPU flash attention (forward), causal + GQA + MLA-style dims.

Hardware mapping (TPU v5e target):
  * grid = (batch·q_heads, n_q_blocks, n_k_blocks); the k-block axis is the
    minormost grid dim, so it iterates sequentially per (bh, iq) and the
    running (m, l, acc) live in VMEM scratch across those steps.
  * BlockSpecs stage [block_q, e] of Q and [block_k, e] of K/V into VMEM;
    head dims are kept whole (128–576 ≤ VMEM budget), block sizes are
    multiples of 128 so the MXU sees aligned contractions.
  * GQA: the K/V index map folds q-head → kv-head (h // rep) so grouped
    heads reuse the same K/V tiles.
  * separate value dim ``ev`` (MLA uses e=192, ev=128).
  * accumulation in fp32 regardless of input dtype.

Validated in interpret mode against kernels/ref.py:attention for a sweep of
shapes/dtypes in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                causal: bool, block_q: int, block_k: int, sk: int,
                scale: float, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32) * scale        # [bq, e]
    k = k_ref[0].astype(jnp.float32)                # [bk, e]
    v = v_ref[0].astype(jnp.float32)                # [bk, ev]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [bq, bk]

    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < sk
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]                               # [bq]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0] = (
            acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(q, k, v, *, causal=True, q_offset=0, block_q=128,
                    block_k=128, interpret=False):
    """q: [b, sq, h, e]; k: [b, sk, g, e]; v: [b, sk, g, ev] → [b, sq, h, ev].

    q_offset shifts absolute q positions (decode windows); the kernel
    assumes q_offset == 0 for the causal mask when sq == sk (training) —
    decode uses ops.decode_attention instead.
    """
    b, sq, h, e = q.shape
    _, sk, g, ev = v.shape
    rep = h // g
    scale = 1.0 / (e ** 0.5)

    # pad sequence dims to block multiples
    pq = -sq % block_q
    pk = -sk % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk
    n_q, n_k = sq_p // block_q, sk_p // block_k

    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq_p, e)
    kr = k.transpose(0, 2, 1, 3).reshape(b * g, sk_p, e)
    vr = v.transpose(0, 2, 1, 3).reshape(b * g, sk_p, ev)

    kernel = functools.partial(
        _fwd_kernel, causal=causal, block_q=block_q, block_k=block_k,
        sk=sk, scale=scale, n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, e), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, e),
                         lambda bh, iq, ik, rep=rep: (bh // rep, ik, 0)),
            pl.BlockSpec((1, block_k, ev),
                         lambda bh, iq, ik, rep=rep: (bh // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, ev),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, ev), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max m
            pltpu.VMEM((block_q,), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, ev), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(b, h, sq_p, ev).transpose(0, 2, 1, 3)
    return out[:, :sq]
