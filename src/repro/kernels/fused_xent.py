"""Pallas TPU fused chunked-vocab softmax cross-entropy (fwd + bwd).

The LM-head loss at 100k–256k vocabs is memory-bound if [n, vocab] logits
ever hit HBM. Two kernels stream vocab tiles through VMEM:

  pass 1: grid=(n_blocks, v_blocks) — logits tile = h·W tile on the MXU,
          running (m, l) and the label logit in VMEM scratch; emits
          per-row (lse, label_logit).
  pass 2: recomputes the tile, forms dlogits = softmax − onehot in VMEM,
          accumulates dh (scratch) and writes the dW tile — logits are
          never materialized outside VMEM.

Validated in interpret mode against kernels/ref.py:softmax_xent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _p1_kernel(h_ref, w_ref, lab_ref, lse_ref, labl_ref, m_sc, l_sc, ll_sc,
               *, block_v: int, vocab: int, n_v: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        ll_sc[...] = jnp.zeros_like(ll_sc)

    h = h_ref[...].astype(jnp.float32)          # [bn, d]
    w = w_ref[...].astype(jnp.float32)          # [d, bv]
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [bn, bv]
    col = iv * block_v + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    valid = col < vocab
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    p = jnp.where(valid, jnp.exp(logits - m_new[:, None]), 0.0)
    l_sc[...] = l_sc[...] * jnp.exp(m_prev - m_new) + p.sum(axis=1)
    m_sc[...] = m_new

    lab = lab_ref[...]                           # [bn]
    hit = (col == lab[:, None]) & valid
    ll_sc[...] = ll_sc[...] + jnp.where(hit, logits, 0.0).sum(axis=1)

    @pl.when(iv == n_v - 1)
    def _flush():
        lse_ref[...] = m_sc[...] + jnp.log(jnp.maximum(l_sc[...], 1e-30))
        labl_ref[...] = ll_sc[...]


def _p2_kernel(h_ref, w_ref, lab_ref, lse_ref, scale_ref, dw_ref, dh_ref,
               dh_sc, *, block_v: int, vocab: int, n_v: int):
    i_n = pl.program_id(0)
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        dh_sc[...] = jnp.zeros_like(dh_sc)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    col = iv * block_v + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    valid = col < vocab
    lse = lse_ref[...]
    p = jnp.where(valid, jnp.exp(logits - lse[:, None]), 0.0)
    lab = lab_ref[...]
    oh = ((col == lab[:, None]) & valid).astype(jnp.float32)
    dlog = (p - oh) * scale_ref[...][:, None]     # [bn, bv]
    contrib = jax.lax.dot_general(
        h, dlog, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dw_ref.dtype)
    # dW tiles are revisited once per n-block: init then accumulate.
    @pl.when(i_n == 0)
    def _dw0():
        dw_ref[...] = contrib

    @pl.when(i_n != 0)
    def _dwn():
        dw_ref[...] = dw_ref[...] + contrib

    dh_sc[...] = dh_sc[...] + jax.lax.dot_general(
        dlog, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(iv == n_v - 1)
    def _flush():
        dh_ref[...] = dh_sc[...].astype(dh_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_v",
                                             "interpret"))
def softmax_xent(h, w_head, labels, *, mask=None, block_n=256,
                 block_v=1024, interpret=False):
    """Same contract as ref.softmax_xent: (loss, (dh, dW))."""
    n, d = h.shape
    vocab = w_head.shape[1]
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    pn = -n % block_n
    pv = -vocab % block_v
    hp = jnp.pad(h, ((0, pn), (0, 0)))
    wp = jnp.pad(w_head, ((0, 0), (0, pv)))
    labp = jnp.pad(labels, ((0, pn),), constant_values=0)
    np_, vp_ = n + pn, vocab + pv
    n_n, n_v = np_ // block_n, vp_ // block_v

    lse, labl = pl.pallas_call(
        functools.partial(_p1_kernel, block_v=block_v, vocab=vocab,
                          n_v=n_v),
        grid=(n_n, n_v),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_n,), jnp.float32),
            pltpu.VMEM((block_n,), jnp.float32),
            pltpu.VMEM((block_n,), jnp.float32),
        ],
        interpret=interpret,
    )(hp, wp, labp)

    maskp = jnp.pad(mask, ((0, pn),))
    loss = ((lse - labl) * maskp).sum() / denom
    scale = maskp / denom

    dw, dh = pl.pallas_call(
        functools.partial(_p2_kernel, block_v=block_v, vocab=vocab,
                          n_v=n_v),
        grid=(n_n, n_v),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, vp_), jnp.float32),
            jax.ShapeDtypeStruct((np_, d), h.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_n, d), jnp.float32)],
        interpret=interpret,
    )(hp, wp, labp, lse, scale)
    dw_full = dw[:, :vocab].astype(w_head.dtype)
    return loss, (dh[:n], dw_full)
