"""repro.serving — continuous batching over the Session facade.

ZeroPP's TP-free design means serving runs the same forward-only
pipeline table as training, so keeping every stage busy is purely a
batching problem — the serving analogue of the bubble elimination the
schedule search does for training. This package supplies that batching:

* :class:`SlotPool` — the serve caches' ``(batch, max_seq)`` rows viewed
  as independent *slots*, each with its own position/length state, so a
  finished request's row is reclaimed and refilled mid-decode without
  rebuilding the jitted step;
* :class:`RequestScheduler` — FIFO admission with a prefill/decode
  interleave policy and per-request ``max_gen``/stop handling;
* :class:`ServeEngine` — the driver: ``submit()`` enqueues a request
  from any thread, ``stream()`` yields its tokens as they are decoded,
  and a background (or manually ticked) loop runs batched prefill/decode
  steps through ``Session.serve_step_batched``;
* :class:`PagePool` / :class:`PagedSlotPool` / :class:`RadixIndex` — the
  paged KV cache (``page_size=`` on the spec): fixed-size ref-counted
  pages behind per-request page tables, with a token-prefix radix trie
  sharing prompt-prefix pages across requests (COW on divergence, LRU
  eviction of unreferenced prefixes);
* :mod:`repro.serving.sampling` — temperature / top-p decoding with
  per-request seeded generators, fed by the serve step's optional
  full-logits return;
* :class:`EngineRouter` — the data-parallel tier: N engine replicas
  behind least-outstanding-tokens dispatch with radix-affinity hinting,
  replica failure handled by parking + resubmitting to survivors
  (``ServeEngine.reshard(new_topology)`` is the single-engine elastic
  analogue: park, rebuild on the new mesh, re-admit).

Correctness bar: engine output for N staggered requests is
token-identical to N independent single-request ``serve_prefill``/
``serve_decode`` runs, and paged greedy decoding is token-identical to
the contiguous path (see tests/test_serving.py, tests/spmd_case.py).
"""

from repro.serving.engine import EngineStats, ServeEngine
from repro.serving.paging import PageAllocation, PagePool, PagedSlotPool
from repro.serving.radix import RadixIndex
from repro.serving.router import EngineRouter, RouterError
from repro.serving.sampling import SamplingParams, sample_token
from repro.serving.scheduler import (
    MoECapacity,
    Request,
    RequestScheduler,
    SchedulerPolicy,
)
from repro.serving.slots import SlotPool, SlotView

__all__ = [
    "EngineRouter",
    "EngineStats",
    "MoECapacity",
    "RouterError",
    "PageAllocation",
    "PagePool",
    "PagedSlotPool",
    "RadixIndex",
    "Request",
    "RequestScheduler",
    "SamplingParams",
    "SchedulerPolicy",
    "ServeEngine",
    "SlotPool",
    "SlotView",
    "sample_token",
]
