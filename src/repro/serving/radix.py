"""RadixIndex: token-prefix trie mapping prefixes to shared page chains.

Each trie node is exactly one *full* page of ``page_size`` tokens, keyed
by its token tuple; a root-to-node path therefore spells out a prompt
prefix, and the node records where that prefix page's K/V bytes live —
per cache *partition* (the serve cache pages are sharded over the
pods×data axes, and a slot row can only gather pages local to its own
shard), so ``node.pages`` maps ``partition -> global page id``.

A prefix cached in one partition is still a hit for a request landing in
another: admission allocates a local page and schedules a device
page-copy (``Session.copy_pages``) instead of recomputing the prefill —
and registers the local copy here so the next request in that partition
shares it for free.

The trie holds one PagePool reference per registered (node, partition)
page; requests hold their own. A page whose only remaining reference is
the trie's is *evictable*: :meth:`evict` walks the partition leaf-first
(a node's page is never dropped while a descendant still caches that
partition — the chain must stay hole-free per partition) in LRU order of
``last_used``.

Copy-on-write divergence needs no machinery here: only *full* pages
wholly covered by a prompt are ever inserted, a request's own pages
(partial prompt tail + decoded tokens) stay private to it, and a match
is capped by the caller below the prompt's last token — so shared pages
are read-only by construction and divergence simply means the walk stops
at the longest common full-page prefix.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.serving.paging import PagePool

_clock = itertools.count(1)


class RadixNode:
    """One full page of tokens; pages[partition] -> global page id."""

    __slots__ = ("key", "parent", "children", "pages", "last_used")

    def __init__(self, key: tuple, parent: "RadixNode | None"):
        self.key = key
        self.parent = parent
        self.children: dict[tuple, RadixNode] = {}
        self.pages: dict[int, int] = {}
        self.last_used = next(_clock)

    def touch(self) -> None:
        self.last_used = next(_clock)


class RadixIndex:
    """Prefix trie over full pages, with per-partition LRU eviction."""

    def __init__(self, page_size: int, pool: PagePool):
        self.page_size = page_size
        self.pool = pool
        self.root = RadixNode((), None)
        self.evictions = 0

    # ------------------------------------------------------------------ #
    def _key(self, prompt: np.ndarray, i: int) -> tuple:
        ps = self.page_size
        return tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])

    def match(self, prompt: np.ndarray, max_pages: int) -> list[RadixNode]:
        """Longest cached full-page prefix of ``prompt``, at most
        ``max_pages`` nodes. Stops at the first node whose page bytes are
        gone from every partition (evicted mid-chain elsewhere leaves a
        structural node with no content — unusable from there on)."""
        out: list[RadixNode] = []
        node = self.root
        for i in range(max_pages):
            child = node.children.get(self._key(prompt, i))
            if child is None or not child.pages:
                break
            child.touch()
            out.append(child)
            node = child
        return out

    def register(self, node: RadixNode, partition: int, page: int) -> bool:
        """Record ``page`` as ``node``'s bytes in ``partition`` (no-op if
        that partition is already cached); takes the trie's pool ref."""
        if partition in node.pages:
            return False
        self.pool.ref(page)
        node.pages[partition] = page
        node.touch()
        return True

    def insert(self, prompt: np.ndarray, n_pages: int, partition: int,
               pages: list[int], skip: int = 0) -> int:
        """Walk/create nodes for prompt pages ``[skip, n_pages)`` and
        register ``pages[i]`` for each; returns how many were newly
        registered. ``skip`` covers pages the request already shared."""
        node = self.root
        new = 0
        for i in range(n_pages):
            key = self._key(prompt, i)
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key, node)
                node.children[key] = child
            if i >= skip:
                new += self.register(child, partition, pages[i])
            else:
                child.touch()
            node = child
        return new

    # ------------------------------------------------------------------ #
    def _evictable(self, partition: int) -> list[RadixNode]:
        """Nodes whose ``partition`` page may be dropped *now*: the trie
        holds the only reference and no descendant caches that partition
        (leaf-first keeps every partition's chain hole-free)."""
        out = []

        def walk(node: RadixNode) -> bool:
            """Returns True if the subtree holds any ``partition`` page."""
            below = False
            for ch in node.children.values():
                below |= walk(ch)
            gid = node.pages.get(partition)
            if gid is None:
                return below
            if not below and self.pool.refcount(gid) == 1:
                out.append(node)
            return True

        for ch in self.root.children.values():
            walk(ch)
        return out

    def _drop(self, node: RadixNode, partition: int) -> None:
        gid = node.pages.pop(partition)
        self.pool.unref(gid)
        self.evictions += 1
        while node is not None and node.parent is not None \
                and not node.pages and not node.children:
            node.parent.children.pop(node.key, None)
            node = node.parent

    def evict(self, partition: int, need: int) -> int:
        """Free at least ``need`` pages in ``partition`` (LRU leaf-first);
        returns how many were actually freed."""
        freed = 0
        while freed < need:
            cands = self._evictable(partition)
            if not cands:
                break
            victim = min(cands, key=lambda n: n.last_used)
            self._drop(victim, partition)
            freed += 1
        return freed

    # ------------------------------------------------------------------ #
    @property
    def n_nodes(self) -> int:
        def count(node):
            return 1 + sum(count(c) for c in node.children.values())
        return count(self.root) - 1
