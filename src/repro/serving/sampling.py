"""Temperature / top-p sampling with per-request seeded generators.

The serve step keeps greedy argmax *in-graph* (bitwise parity with the
recorded goldens and the contiguous path is non-negotiable), so sampled
requests take a different route: the step optionally returns the drain
rank's full next-token logits and the engine samples host-side, one
seeded ``numpy`` Generator per request. Determinism contract: the same
(prompt, temperature, top_p, seed) produces the same token sequence
across engine restarts — the generator is private to the request and
advances exactly once per emitted token, so batch composition, admission
order and slot placement cannot perturb it.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding knobs. ``temperature == 0`` means greedy
    (the in-graph argmax token is used and no rng state advances)."""

    temperature: float = 0.0
    top_p: float = 1.0
    seed: int | None = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if not 0 < self.top_p <= 1:
            raise ValueError(
                f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0


def make_rng(params: SamplingParams) -> np.random.Generator:
    """One generator per request; an explicit seed pins the stream."""
    return np.random.default_rng(params.seed)


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: np.random.Generator) -> int:
    """Draw one token from ``logits`` [vocab] (host-side, float64).

    Temperature scales the logits; top-p keeps the smallest
    probability-sorted prefix whose mass reaches ``top_p`` (always
    including the token that crosses the threshold) and renormalizes.
    """
    if params.greedy:
        return int(np.argmax(logits))
    z = np.asarray(logits, np.float64) / params.temperature
    z -= z.max()
    p = np.exp(z)
    p /= p.sum()
    if params.top_p < 1.0:
        order = np.argsort(-p, kind="stable")
        csum = np.cumsum(p[order])
        k = int(np.searchsorted(csum, params.top_p)) + 1
        keep = order[:k]
        q = np.zeros_like(p)
        q[keep] = p[keep]
        p = q / q.sum()
    return int(rng.choice(p.size, p=p))
