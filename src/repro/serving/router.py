"""EngineRouter: the data-parallel serving tier.

One :class:`~repro.serving.engine.ServeEngine` saturates one mesh; the
millions-of-users shape from the ROADMAP is N engine *replicas* — each
with its own session, slot/page pools and radix index — behind a router:

* **dispatch** — least-outstanding-tokens (each engine's queued +
  in-flight generation budget), with a radix-affinity override: when a
  replica already caches a prefix of the incoming prompt, it wins the
  request as long as its load is within ``affinity_slack`` tokens of the
  least-loaded replica. Affinity concentrates same-prefix traffic so the
  radix keeps paying; the slack bound keeps a hot prefix from starving
  the other replicas.
* **failover** — a replica failure (its driver died, or
  :meth:`kill_replica` simulated a node loss) parks that replica's
  requests host-side (prompt + emitted tokens) and resubmits them to the
  survivors in arrival order. Request OBJECTS move, so waiters, emitted
  tokens and per-request sampling RNGs survive — a seeded sampled stream
  is bit-identical across a replica move.

Every replica serves the same model, so the router is output-transparent:
greedy streams are token-identical to single-engine serving no matter
which replica (or how many replicas) served them.
"""

from __future__ import annotations

import threading
from typing import Sequence

import numpy as np

from repro.serving.engine import ServeEngine, _fail_request
from repro.serving.scheduler import Request


class RouterError(RuntimeError):
    """No live replica can take the work."""


class EngineRouter:
    """Least-loaded dispatch + failover over N engine replicas."""

    def __init__(self, engines: Sequence[ServeEngine], *,
                 affinity_slack: int = 256):
        if not engines:
            raise ValueError("EngineRouter needs at least one engine")
        self.engines = list(engines)
        self.affinity_slack = affinity_slack
        self._lock = threading.Lock()
        self._dead: set[int] = set()
        self.dispatched = [0] * len(self.engines)   # per-replica counts
        self.failovers = 0                          # replicas failed over

    # ------------------------------------------------------------------ #
    # Dispatch
    # ------------------------------------------------------------------ #

    def alive(self) -> list[int]:
        """Replica indices currently accepting work (failed drivers are
        detected here and failed over before the list is returned)."""
        for i, eng in enumerate(self.engines):
            if i not in self._dead and eng._failure is not None:
                self._failover(i)
        return [i for i in range(len(self.engines)) if i not in self._dead]

    def pick(self, prompt) -> int:
        """The replica for ``prompt``: least outstanding tokens, unless
        a replica with cached-prefix affinity is within the slack."""
        alive = self.alive()
        if not alive:
            raise RouterError("no live replicas")
        load = {i: self.engines[i].outstanding_tokens() for i in alive}
        best = min(alive, key=lambda i: (load[i], i))
        aff = [(self.engines[i].prefix_affinity(prompt), i) for i in alive]
        hit, i_aff = max(aff)
        if hit > 0 and load[i_aff] <= load[best] + self.affinity_slack:
            return i_aff
        return best

    def submit(self, prompt, **kw) -> Request:
        """Enqueue on the chosen replica; returns the request handle
        (its tokens stream from whichever replica serves it)."""
        with self._lock:
            i = self.pick(prompt)
            req = self.engines[i].submit(prompt, **kw)
            self.dispatched[i] += 1
            return req

    # ------------------------------------------------------------------ #
    # Failover
    # ------------------------------------------------------------------ #

    def kill_replica(self, i: int) -> int:
        """Simulated node loss: park replica ``i``'s work, move it to
        the survivors, shut the replica down. Returns the number of
        requests moved. (Real failures — a driver thread dying on an
        exception — take the same path via :meth:`alive`.)"""
        with self._lock:
            return self._failover(i)

    def _failover(self, i: int) -> int:
        if i in self._dead:
            return 0
        self._dead.add(i)
        self.failovers += 1
        eng = self.engines[i]
        parked = eng.park_all()
        # the replica is drained; stop its driver. close() sees no
        # outstanding requests, so nothing gets failed here.
        try:
            eng.close()
        except RuntimeError:
            pass    # a failed driver may refuse to close cleanly
        survivors = [j for j in range(len(self.engines))
                     if j not in self._dead
                     and self.engines[j]._failure is None]
        if not survivors:
            for req in parked:
                _fail_request(req, RouterError(
                    "replica failed with no survivors to adopt its "
                    "requests"))
            return 0
        for req in parked:    # arrival order (park_all sorts by id)
            j = min(survivors,
                    key=lambda k: (self.engines[k].outstanding_tokens(),
                                   k))
            self.engines[j].resubmit(req)
            self.dispatched[j] += 1
        return len(parked)

    # ------------------------------------------------------------------ #
    # Lifecycle / introspection
    # ------------------------------------------------------------------ #

    def start(self) -> "EngineRouter":
        for i in self.alive():
            self.engines[i].start()
        return self

    def close(self) -> None:
        for i in list(self.alive()):
            self.engines[i].close()

    def __enter__(self) -> "EngineRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def run_until_idle(self, max_ticks: int = 100_000) -> dict:
        """Tick every live replica until all are idle (sync driver)."""
        for _ in range(max_ticks):
            busy = False
            for i in self.alive():
                eng = self.engines[i]
                busy |= eng.step() or eng.scheduler.n_queued > 0
            if not busy and not self._pending_anywhere():
                return self.stats()
        raise RuntimeError(f"router not idle after {max_ticks} ticks")

    def _pending_anywhere(self) -> bool:
        return any(self.engines[i].scheduler.n_queued > 0
                   for i in self.alive())

    def stats(self) -> dict:
        """Aggregate + per-replica counters."""
        per = []
        for i, eng in enumerate(self.engines):
            st = eng.stats
            per.append({
                "alive": i not in self._dead,
                "dispatched": self.dispatched[i],
                "generated_tokens": st.generated_tokens,
                "finished_requests": st.finished_requests,
                "resubmitted_requests": st.resubmitted_requests,
                "prefix_hits": st.prefix_hits,
                "occupancy": st.occupancy,
            })
        return {
            "replicas": len(self.engines),
            "alive": len(self.alive()),
            "failovers": self.failovers,
            "generated_tokens": int(np.sum(
                [p["generated_tokens"] for p in per])),
            "finished_requests": int(np.sum(
                [p["finished_requests"] for p in per])),
            "per_replica": per,
        }
