"""Request queue + admission policy for the continuous-batching engine.

The scheduler owns *which* work runs each tick; the engine owns *how*.
FIFO admission keeps the correctness story simple (and matches the
paper's framing of serving as a pure batching problem); the policy knobs
bound how much prefill work may delay in-flight decodes per tick, and
``mode="static"`` degrades admission to classic static batching (admit a
full batch only when the pool is empty) — the baseline the benchmark
compares against.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
from typing import Any, Sequence

import numpy as np

from repro.serving.slots import WAIT_PREFIX, SlotPool

_ids = itertools.count()


@dataclasses.dataclass(eq=False)
class Request:
    """One generation request plus its in-flight state.

    ``eq=False``: requests compare (and hash) by identity. The generated
    ``__eq__`` would compare the numpy ``prompt`` field element-wise and
    ``req in queue`` / ``queue.remove(req)`` would raise "truth value of
    an array is ambiguous" as soon as two requests are queued — a request
    handle is a unique in-flight object, never a value.
    """

    prompt: np.ndarray              # int32 [prompt_len]
    max_gen: int = 16               # generated-token budget (incl. first)
    stop: Sequence[int] = ()        # stop-token ids (emitted, then done)
    # non-greedy decoding (repro.serving.sampling): temperature 0 keeps
    # the in-graph greedy argmax; a seed pins the sampled stream across
    # engine restarts.
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int | None = None
    id: int = dataclasses.field(default_factory=lambda: next(_ids))

    # in-flight state (engine-owned)
    slot: int | None = None
    tokens: list = dataclasses.field(default_factory=list)  # generated
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    error: BaseException | None = None
    _stream: "queue.SimpleQueue[Any]" = dataclasses.field(
        default_factory=queue.SimpleQueue)
    # tokens already folded into ``prompt`` by an engine park (elastic
    # reshard / replica failover): re-admission re-prefills the folded
    # prompt and the next emission continues the stream exactly where it
    # stopped. Counts into ``tokens`` — never fold the same token twice.
    _folded: int = 0

    def __post_init__(self):
        from repro.serving.sampling import SamplingParams, make_rng

        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_gen < 1:
            raise ValueError(f"max_gen must be >= 1, got {self.max_gen}")
        self.stop = tuple(int(t) for t in self.stop)
        self.sampling = SamplingParams(temperature=self.temperature,
                                       top_p=self.top_p, seed=self.seed)
        self._rng = None if self.sampling.greedy \
            else make_rng(self.sampling)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    def result(self, timeout: float | None = None) -> list:
        """Block until finished; returns the generated tokens."""
        if not self.done.wait(timeout):
            raise TimeoutError(f"request {self.id} still running")
        if self.error is not None:
            raise self.error
        return list(self.tokens)


@dataclasses.dataclass(frozen=True)
class MoECapacity:
    """Capacity-aware MoE admission bound.

    Every co-resident slot routes its decode token through the MoE
    layers; the dispatch buffer holds ``capacity(tokens)`` tokens per
    expert and silently *drops* assignments beyond it. Uniform routing
    always fits (the capacity formula covers ``top_k/E`` load plus the
    capacity factor), but real routing is skewed — a hot expert drawing
    ``skew``× the uniform share overflows once enough slots decode
    together. This bound projects the hot-expert load of the would-be
    co-resident batch and defers admission past the largest batch whose
    projection still fits, trading occupancy for zero projected drops.
    """

    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # assumed hot-expert load as a multiple of the uniform share;
    # 0 disables the bound (admit regardless of projected load)
    skew: float = 2.0

    @classmethod
    def from_moe_cfg(cls, mo, skew: float = 2.0) -> "MoECapacity":
        return cls(n_experts=mo.n_experts, top_k=mo.top_k,
                   capacity_factor=mo.capacity_factor, skew=skew)

    def fits(self, n_tokens: int) -> bool:
        """Does a co-batch of ``n_tokens`` decode tokens fit the
        projected hot expert within its dispatch capacity?"""
        if self.skew <= 0 or n_tokens <= 0:
            return True
        from repro.models.blocks import _capacity

        cap = _capacity(n_tokens, self)
        hot = n_tokens * self.top_k / self.n_experts * self.skew
        return hot <= cap

    def max_admissible(self, n_slots: int) -> int:
        """Largest co-batch (<= n_slots) the bound admits."""
        n = 0
        while n < n_slots and self.fits(n + 1):
            n += 1
        return n


@dataclasses.dataclass
class SchedulerPolicy:
    # max new requests prefills per engine tick: bounds how long in-flight
    # decodes stall behind prompt processing (prefill/decode interleave)
    max_prefills_per_tick: int = 2
    # "continuous": refill any free slot each tick;
    # "static": admit only when the pool is completely idle (baseline)
    mode: str = "continuous"
    # MoE capacity-aware admission: defer admissions whose projected
    # co-resident hot-expert load would overflow the dispatch capacity.
    # None disables (dense models / unbounded admission).
    moe_capacity: MoECapacity | None = None

    def __post_init__(self):
        if self.mode not in ("continuous", "static"):
            raise ValueError(
                f"unknown admission mode {self.mode!r}; pick "
                "'continuous' or 'static'")
        if self.max_prefills_per_tick < 1:
            raise ValueError("max_prefills_per_tick must be >= 1")


class RequestScheduler:
    """Thread-safe FIFO queue with slot-pool admission."""

    def __init__(self, policy: SchedulerPolicy | None = None):
        self.policy = policy or SchedulerPolicy()
        self._lock = threading.Lock()
        self._queue: list[Request] = []
        # admissions deferred (kept queued) by the MoE capacity bound
        self.capacity_deferrals = 0

    def submit(self, req: Request) -> Request:
        with self._lock:
            self._queue.append(req)
        return req

    @property
    def n_queued(self) -> int:
        with self._lock:
            return len(self._queue)

    def remove(self, req: Request) -> bool:
        """Pull a still-queued request back out (e.g. failed submit)."""
        with self._lock:
            if req in self._queue:
                self._queue.remove(req)
                return True
            return False

    def drain(self) -> list[Request]:
        """Empty the queue, returning what was waiting (engine failure)."""
        with self._lock:
            out, self._queue = self._queue, []
            return out

    def pending(self) -> list[Request]:
        """Snapshot of the queued requests (router load accounting)."""
        with self._lock:
            return list(self._queue)

    def admit(self, pool: SlotPool,
              ) -> tuple[list[Request], list[tuple[Request, Exception]]]:
        """Move queued requests into free slots (FIFO), per the policy.

        Returns ``(admitted, rejected)``: admitted requests have
        ``req.slot`` assigned (the engine still resets + prefills them);
        rejected ones raised a ``ValueError`` from the pool — an
        impossible request (e.g. an over-long prompt that slipped past
        submit-time validation, or a page span no partition can ever
        hold). Rejection must not tear down the tick: the engine fails
        that single request and admission of its queue neighbours
        continues — an exception escaping here would kill the daemon
        driver and strand every in-flight request.

        A pool may also answer ``WAIT_PREFIX`` for a request that should
        wait on an in-flight same-prefix prefill: that request keeps its
        queue position but admission continues past it, so a deferred
        head never blocks unrelated neighbours behind it (None still
        means out-of-capacity and stops admission for the tick).

        With ``policy.moe_capacity`` set, admission additionally stops —
        FIFO order preserved — once the projected co-resident decode
        batch (active slots + already-admitted + the candidate) would
        overflow the projected hot expert's dispatch capacity; each such
        stop bumps ``capacity_deferrals``. Deferred requests re-try on
        the next tick as slots free up. The first request into an idle
        pool is always admitted — an over-tight bound degrades to serial
        serving, it never livelocks.
        """
        admitted: list[Request] = []
        rejected: list[tuple[Request, Exception]] = []
        with self._lock:
            if self.policy.mode == "static" and pool.n_active > 0:
                return admitted, rejected
            limit = (self.policy.max_prefills_per_tick
                     if self.policy.mode == "continuous"
                     else pool.n_slots)
            cap = self.policy.moe_capacity
            i = 0
            while i < len(self._queue) and len(admitted) < limit:
                req = self._queue[i]
                # the bound trades occupancy for projected drops, never
                # liveness: the first request into an idle pool always
                # admits, else an over-tight bound would livelock.
                # (n_active already counts this tick's admissions —
                # try_admit claims the slot immediately.)
                co = pool.n_active
                if cap is not None and co > 0 and not cap.fits(co + 1):
                    self.capacity_deferrals += 1
                    break
                try:
                    s = pool.try_admit(req)
                except ValueError as e:
                    self._queue.pop(i)
                    rejected.append((req, e))
                    continue
                if s is WAIT_PREFIX:
                    i += 1
                    continue
                if s is None:
                    break
                self._queue.pop(i)
                req.slot = s.index
                admitted.append(req)
        return admitted, rejected
