"""ServeEngine: the continuous-batching driver over a serve Session.

One engine owns the params, the slotted caches and the jitted step
functions (one decode step, one prefill step per distinct chunk width —
``prefill_chunk`` bounds the compile count for ragged workloads).
``submit()`` is thread-safe and non-blocking; tokens can be consumed per
request via ``stream()``/``Request.result()`` while the driver loop —
``start()`` for the async background thread, or ``step()``/
``run_until_idle()`` for deterministic manual ticking — interleaves
prefills and batched decodes per the scheduler policy.

Each tick:
  1. free slots are refilled from the FIFO queue (admission policy);
  2. each admitted request's slot rows are zeroed
     (``Session.reset_slot_caches``) and its prompt is prefilled —
     writes masked to its slot, so in-flight neighbours are untouched;
  3. one batched decode step advances every active slot at its own
     position (the per-slot ``pos`` vector), and finished requests
     (stop token, ``max_gen``, or cache-full) release their slots.

Because every cache position a request reads was written by that same
request (prefill covers [0, prompt) and each decode writes its position
before attending), a reclaimed slot never leaks state between requests —
engine output is token-identical to independent sequential serving.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Iterator, Sequence

import numpy as np

from repro.serving.scheduler import (
    Request,
    RequestScheduler,
    SchedulerPolicy,
)
from repro.serving.slots import SlotPool

_DONE = object()  # per-request stream sentinel

# prefill chunking re-runs the step with a carried cache; recurrent-state
# kinds recompute their state from scratch per call, so chunking is only
# sound for position-indexed (attention-family) caches.
_CHUNKABLE_MIXES = ("attn", "mla", "dec")


@dataclasses.dataclass
class EngineStats:
    prefill_steps: int = 0
    decode_steps: int = 0
    generated_tokens: int = 0
    finished_requests: int = 0
    occupancy: float = 0.0          # mean busy-slot fraction per decode


class ServeEngine:
    """Continuous batching over ``Session.serve_step_batched``."""

    def __init__(self, session, params, *, policy: SchedulerPolicy
                 | None = None, prefill_chunk: int | None = None):
        if session.spec.mode != "serve":
            raise ValueError(
                f"ServeEngine needs a serve-mode session (got mode="
                f"{session.spec.mode!r}); build one with "
                "session(arch, mode='serve', max_slots=..., max_seq=...)")
        if session.cfg.encdec is not None:
            raise NotImplementedError(
                "continuous batching drives the decoder-only serve path; "
                "enc-dec architectures still use serve_prefill/"
                "serve_decode")
        self.session = session
        self.params = params
        self.pool = SlotPool(session.max_slots, session._max_seq())
        self.scheduler = RequestScheduler(policy)
        self.prefill_chunk = (prefill_chunk
                              if prefill_chunk is not None
                              else session.spec.prefill_chunk)
        seg = (session.geo.segments[-1])
        if self.prefill_chunk is not None and any(
                k.split(":")[0] not in _CHUNKABLE_MIXES
                for k in seg.kinds):
            raise NotImplementedError(
                "prefill_chunk needs position-indexed caches; segment "
                f"kinds {seg.kinds} include recurrent state that does "
                "not carry across prefill chunks")
        session.check_slot_sharding()  # fail before allocating caches
        self.caches = session.init_caches(abstract=False)
        self.stats = EngineStats()
        self._by_slot: dict[int, Request] = {}
        self._lock = threading.RLock()      # one tick at a time
        self._wake = threading.Event()      # submit() -> driver loop
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._failure: BaseException | None = None
        self._closed = False

    # ------------------------------------------------------------------ #
    # Submission / consumption (any thread)
    # ------------------------------------------------------------------ #

    def submit(self, prompt, *, max_gen: int = 16,
               stop: Sequence[int] = ()) -> Request:
        """Enqueue a generation request; returns its handle immediately."""
        if self._closed:
            raise RuntimeError("engine closed; no further submissions")
        if self._failure is not None:
            raise RuntimeError("engine failed; no further submissions") \
                from self._failure
        req = Request(prompt=np.asarray(prompt, np.int32),
                      max_gen=max_gen, stop=stop)
        self.pool.validate_prompt(req.prompt_len)  # reject before queuing
        self.scheduler.submit(req)
        if self._failure is not None or self._closed:
            # the engine died or closed while we enqueued: the final
            # drain may have run before our append landed, so pull the
            # request back out and fail it loudly instead of letting it
            # hang in a dead engine's queue.
            self.scheduler.remove(req)
            _fail_request(req,
                          self._failure or RuntimeError("engine closed"))
            raise RuntimeError("engine stopped; no further submissions") \
                from self._failure
        self._wake.set()
        return req

    def stream(self, req: Request, timeout: float | None = None,
               ) -> Iterator[int]:
        """Yield ``req``'s tokens as they are decoded; returns on finish.

        Blocks between tokens by default (first-token latency includes
        jit compiles, which can be long on full-size archs); pass
        ``timeout`` seconds to raise TimeoutError on a stalled driver
        instead.
        """
        import queue as _queue

        while True:
            try:
                item = req._stream.get(timeout=timeout)
            except _queue.Empty:
                raise TimeoutError(
                    f"request {req.id}: no token within {timeout}s — is "
                    "the engine driver running (start()/step())?") \
                    from None
            if item is _DONE:
                if req.error is not None:
                    raise req.error
                return
            yield item

    # ------------------------------------------------------------------ #
    # Driving (one driver at a time: background thread OR manual ticks)
    # ------------------------------------------------------------------ #

    def step(self) -> bool:
        """One engine tick. Returns True if any work ran."""
        with self._lock:
            try:
                admitted = self.scheduler.admit(self.pool)
                if admitted:
                    reset = self.pool.mask_for(
                        [r.slot for r in admitted])
                    self.caches = self.session.reset_slot_caches(
                        self.caches, reset)
                    for req in admitted:
                        self._by_slot[req.slot] = req
                    self._prefill_admitted(admitted)
                active = self.pool.active()
                if active:
                    self._decode_tick()
                return bool(admitted or active)
            except BaseException as e:  # noqa: BLE001 — fail all waiters
                self._fail(e)
                raise

    def run_until_idle(self, max_ticks: int = 100_000) -> EngineStats:
        """Tick until the queue and every slot are empty (sync driver)."""
        for _ in range(max_ticks):
            if not self.step() and self.scheduler.n_queued == 0:
                break
        else:
            e = RuntimeError(f"not idle after {max_ticks} ticks")
            with self._lock:
                self._fail(e)  # unblock waiters like every error path
            raise e
        return self.stats

    def start(self) -> "ServeEngine":
        """Run the driver loop in a daemon thread (async driver)."""
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-serve-engine")
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop the driver. Requests still queued or in flight are failed
        (their waiters unblock with the close error) rather than left
        hanging in a dead engine."""
        self._closed = True
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=60)
            if self._thread.is_alive():
                raise RuntimeError(
                    "engine driver still running after 60s (a long "
                    "compile?); close() aborted — retry once the tick "
                    "finishes")
            self._thread = None
        with self._lock:
            if self._by_slot or self.scheduler.n_queued:
                self._fail(RuntimeError(
                    "engine closed with requests outstanding"))

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                did = self.step()
            except BaseException:  # noqa: BLE001 — recorded by step()
                return
            if not did and self.scheduler.n_queued == 0:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    # ------------------------------------------------------------------ #
    # Tick internals
    # ------------------------------------------------------------------ #

    def _step_batched(self, batch):
        """One slot-aware step; asserts the output covers every slot
        (a compacted output would silently misalign slot indexing)."""
        out, caches = self.session.serve_step_batched(
            self.params, self.caches, batch)
        if out.shape[0] != self.pool.n_slots:
            raise RuntimeError(
                f"serve step returned {out.shape[0]} tokens for "
                f"{self.pool.n_slots} slots — the step tiling does not "
                "cover the slot pool (check_slot_sharding should have "
                "caught this)")
        return out, caches

    def _prefill_admitted(self, reqs: list[Request]) -> None:
        """Prefill the admitted requests' prompts into their slots.

        Co-admitted chunks of equal width share one pipeline pass (the
        pos/mask vectors are already per-row), so K same-length prompts
        — or K chunk-aligned long prompts under ``prefill_chunk`` — cost
        one step, not K. A request's first token is sampled by the step
        that covers its prompt's last position.
        """
        n = self.pool.n_slots
        pending = [(r, 0) for r in reqs]  # (request, chunk offset)
        while pending:
            by_width: dict[int, list] = {}
            for r, off in pending:
                c = min(self.prefill_chunk or r.prompt_len,
                        r.prompt_len - off)
                by_width.setdefault(c, []).append((r, off))
            pending = []
            for c, group in sorted(by_width.items()):
                toks = np.zeros((n, c), np.int32)
                pos = self.pool.pos_vector()
                mask = np.zeros(n, bool)
                for r, off in group:
                    toks[r.slot] = r.prompt[off:off + c]
                    pos[r.slot] = off
                    mask[r.slot] = True
                out, self.caches = self._step_batched(
                    {"tokens": toks, "pos": pos, "slot_mask": mask})
                self.stats.prefill_steps += 1
                out_np = None
                for r, off in group:
                    if off + c >= r.prompt_len:
                        self.pool.slots[r.slot].pos = r.prompt_len
                        if out_np is None:
                            out_np = np.asarray(out)
                        # greedy sample from the prompt's last position
                        self._emit(r, int(out_np[r.slot]))
                    else:
                        pending.append((r, off + c))

    def _decode_tick(self) -> None:
        """One batched decode step over every active slot.

        Finished requests are skipped defensively: a request that
        completed between the ``active`` snapshot and the emit (or whose
        slot was released out-of-band) must not receive another token or
        advance a slot that may already belong to a new request.
        """
        n = self.pool.n_slots
        active = self.pool.active()
        toks = np.zeros((n, 1), np.int32)
        for s in active:
            req = self._by_slot.get(s.index)
            if req is None or req.done.is_set():
                continue
            toks[s.index, 0] = req.tokens[-1]
        batch = {"tokens": toks, "pos": self.pool.pos_vector(),
                 "slot_mask": self.pool.active_mask()}
        out, self.caches = self._step_batched(batch)
        self.pool.observe_tick()
        self.stats.decode_steps += 1
        self.stats.occupancy = self.pool.occupancy
        out_np = np.asarray(out)
        for s in active:
            req = self._by_slot.get(s.index)
            if req is None or req.done.is_set():
                continue
            s.pos += 1
            self._emit(req, int(out_np[s.index]))

    def _emit(self, req: Request, tok: int) -> None:
        if req.done.is_set() or req.slot is None:
            # late emit on a finished request: its slot may already hold
            # a different in-flight request — reading (or finishing)
            # through self.pool.slots[req.slot] would corrupt that one.
            return
        req.tokens.append(tok)
        req._stream.put(tok)
        self.stats.generated_tokens += 1
        slot = self.pool.slots[req.slot]
        if (len(req.tokens) >= req.max_gen or tok in req.stop
                or slot.pos >= self.pool.max_seq):
            self._finish(req)

    def _finish(self, req: Request) -> None:
        if req.slot is not None:
            self._by_slot.pop(req.slot, None)
            self.pool.release(req.slot)
            # the slot is free for reallocation from here on: drop the
            # request's pointer so no late _emit/_decode_tick can read a
            # reallocated slot's state through it.
            req.slot = None
        self.stats.finished_requests += 1
        req.done.set()
        req._stream.put(_DONE)
        self._wake.set()

    def _fail(self, e: BaseException) -> None:
        self._failure = e
        for req in list(self._by_slot.values()):
            _fail_request(req, e)
        self._by_slot.clear()
        for req in self.scheduler.drain():
            _fail_request(req, e)


def _fail_request(req: Request, e: BaseException) -> None:
    """Tear down one request's waiters with ``e``."""
    req.error = e
    req.slot = None   # engine is dead: never dereference pool state again
    req.done.set()
    req._stream.put(_DONE)
