"""ServeEngine: the continuous-batching driver over a serve Session.

One engine owns the params, the slotted caches and the jitted step
functions (one decode step, one prefill step per distinct chunk width —
``prefill_chunk`` bounds the compile count for ragged workloads).
``submit()`` is thread-safe and non-blocking; tokens can be consumed per
request via ``stream()``/``Request.result()`` while the driver loop —
``start()`` for the async background thread, or ``step()``/
``run_until_idle()`` for deterministic manual ticking — interleaves
prefills and batched decodes per the scheduler policy.

Each tick:
  1. free slots are refilled from the FIFO queue (admission policy);
  2. each admitted request's slot rows are zeroed
     (``Session.reset_slot_caches``) and its prompt is prefilled —
     writes masked to its slot, so in-flight neighbours are untouched;
  3. one batched decode step advances every active slot at its own
     position (the per-slot ``pos`` vector), and finished requests
     (stop token, ``max_gen``, or cache-full) release their slots.

Because every cache position a request reads was written by that same
request (prefill covers [0, prompt) and each decode writes its position
before attending), a reclaimed slot never leaks state between requests —
engine output is token-identical to independent sequential serving.

Paged sessions (``page_size=`` on the spec) swap the :class:`SlotPool`
for a :class:`PagedSlotPool`: requests carry page tables instead of
whole cache rows, the radix index shares prompt-prefix pages across
requests (prefill resumes at the first uncached token), and each tick
first zeroes the newly allocated pages, then runs the admitted
requests' cross-partition page copies in admission order, then
prefills. Greedy output stays token-identical to the contiguous path.
Sampled requests (``temperature > 0``) pull the drain rank's full
logits and draw host-side with a per-request seeded generator.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Iterator, Sequence

import numpy as np

from repro.serving.paging import PagedSlotPool
from repro.serving.sampling import sample_token
from repro.serving.scheduler import (
    MoECapacity,
    Request,
    RequestScheduler,
    SchedulerPolicy,
)
from repro.serving.slots import SlotPool

_DONE = object()  # per-request stream sentinel

# prefill chunking re-runs the step with a carried cache; recurrent-state
# kinds recompute their state from scratch per call, so chunking is only
# sound for position-indexed (attention-family) caches.
_CHUNKABLE_MIXES = ("attn", "mla", "dec")


@dataclasses.dataclass
class EngineStats:
    prefill_steps: int = 0
    decode_steps: int = 0
    generated_tokens: int = 0
    finished_requests: int = 0
    occupancy: float = 0.0          # mean busy-slot fraction per decode
    rejected_requests: int = 0      # failed at admission (impossible fit)
    prefill_tokens: int = 0         # prompt tokens actually computed
    # paged-KV counters (zero on contiguous pools)
    prefix_hits: int = 0            # admissions that reused prefix pages
    prefix_hit_tokens: int = 0      # prompt tokens skipped via the radix
    evictions: int = 0              # prefix pages LRU-evicted
    pages_in_use: int = 0           # live pages right now
    peak_pages_in_use: int = 0      # high-water mark
    # MoE capacity-aware admission (zero on dense models)
    capacity_deferrals: int = 0     # admissions deferred by the MoE bound
    # elastic serving
    reshards: int = 0               # reshard(new_topology) calls
    reshard_pause_s: float = 0.0    # total wall-clock parked in reshards
    resubmitted_requests: int = 0   # parked requests re-admitted here


class _MoEServeStats:
    """Host-side accumulation of the serve steps' expert-load returns.

    Attached to ``EngineStats`` as a plain attribute (not a dataclass
    field) so ``dataclasses.asdict`` skips it; ``describe()["serving"]``
    renders it via :meth:`as_dict`.
    """

    def __init__(self):
        self.load = None        # np [rows, E]: routed assignments/layer-row
        self.dropped = 0        # assignments dropped at dispatch capacity

    def update(self, moe_out) -> None:
        load = np.asarray(moe_out["load"], np.int64)
        self.load = load if self.load is None else self.load + load
        self.dropped += int(moe_out["dropped"])

    def as_dict(self) -> dict:
        out = {"dropped_tokens": int(self.dropped)}
        if self.load is not None:
            out["load_per_expert"] = [
                int(v) for v in self.load.sum(axis=0)]
            out["load_rows"] = int(self.load.shape[0])
        return out


class ServeEngine:
    """Continuous batching over ``Session.serve_step_batched``."""

    def __init__(self, session, params, *, policy: SchedulerPolicy
                 | None = None, prefill_chunk: int | None = None):
        if session.spec.mode != "serve":
            raise ValueError(
                f"ServeEngine needs a serve-mode session (got mode="
                f"{session.spec.mode!r}); build one with "
                "session(arch, mode='serve', max_slots=..., max_seq=...)")
        if session.cfg.encdec is not None:
            raise NotImplementedError(
                "continuous batching drives the decoder-only serve path; "
                "enc-dec architectures still use serve_prefill/"
                "serve_decode")
        self.session = session
        self.params = params
        self._paged = bool(session.paged)
        self.pool: SlotPool | PagedSlotPool = self._build_pool()
        moe_cfg = getattr(session.cfg, "moe", None)
        if policy is None and moe_cfg is not None:
            # MoE serving defaults to capacity-aware admission: defer
            # admissions whose projected co-resident hot-expert load
            # would overflow the dispatch capacity (pass an explicit
            # policy — moe_capacity=None — to admit unbounded).
            policy = SchedulerPolicy(
                moe_capacity=MoECapacity.from_moe_cfg(moe_cfg))
        self.scheduler = RequestScheduler(policy)
        self.prefill_chunk = (prefill_chunk
                              if prefill_chunk is not None
                              else session.spec.prefill_chunk)
        seg = (session.geo.segments[-1])
        if self.prefill_chunk is not None and any(
                k.split(":")[0] not in _CHUNKABLE_MIXES
                for k in seg.kinds):
            raise NotImplementedError(
                "prefill_chunk needs position-indexed caches; segment "
                f"kinds {seg.kinds} include recurrent state that does "
                "not carry across prefill chunks")
        session.check_slot_sharding()  # fail before allocating caches
        # host-side sampling needs the serve step's full-logits return,
        # which some layouts cannot provide; probe once so submit() can
        # reject temperature>0 up front instead of NotImplementedError
        # escaping mid-tick and killing every in-flight request.
        probe = getattr(session, "sampling_unsupported_reason", None)
        self._no_sampling = probe() if probe is not None else None
        self.caches = session.init_caches(abstract=False)
        self.stats = EngineStats()
        # per-expert load observability: the serve step returns one extra
        # trailing {"load", "dropped"} dict when RunConfig.moe_stats is
        # on and the segment actually routes through MoE layers.
        self._track_moe = bool(
            getattr(getattr(session, "rc", None), "moe_stats", False)
            and moe_cfg is not None
            and any(k.endswith(":moe")
                    for k in session.geo.segments[-1].kinds))
        if self._track_moe:
            self.stats.moe = _MoEServeStats()
        session._engine_stats = self.stats   # describe()["serving"]
        self._by_slot: dict[int, Request] = {}
        self._lock = threading.RLock()      # one tick at a time
        self._wake = threading.Event()      # submit() -> driver loop
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._failure: BaseException | None = None
        self._closed = False

    def _build_pool(self) -> "SlotPool | PagedSlotPool":
        """The slot (or paged-slot) pool for the CURRENT session — called
        at construction and again by :meth:`reshard` when the session is
        rebuilt on a new topology (pool partitioning follows the mesh)."""
        session = self.session
        if self._paged:
            seg_ = session.geo.segments[-1]
            if any(k.split(":")[0] not in _CHUNKABLE_MIXES
                   for k in seg_.kinds):
                raise NotImplementedError(
                    "paged KV covers position-indexed (attention-family) "
                    f"caches; segment kinds {seg_.kinds} keep per-slot "
                    "recurrent state — drop page_size for this "
                    "architecture")
            pods = getattr(session, "pods_size", None) \
                or (session.spec.pods or 1)
            return PagedSlotPool(
                session.max_slots, session._max_seq(),
                page_size=session.page_size, n_pages=session.n_pages,
                shards=pods * session.data_size, groups=session.rt.G,
                sharing=session.spec.prefix_sharing == "on")
        return SlotPool(session.max_slots, session._max_seq())

    # ------------------------------------------------------------------ #
    # Submission / consumption (any thread)
    # ------------------------------------------------------------------ #

    def submit(self, prompt, *, max_gen: int = 16,
               stop: Sequence[int] = (), temperature: float = 0.0,
               top_p: float = 1.0, seed: int | None = None) -> Request:
        """Enqueue a generation request; returns its handle immediately.

        ``temperature == 0`` (default) decodes greedily in-graph;
        ``temperature > 0`` samples host-side from the full logits, with
        ``top_p`` nucleus truncation and an optional per-request ``seed``
        that pins the sampled stream across engine restarts.
        """
        if self._closed:
            raise RuntimeError("engine closed; no further submissions")
        if self._failure is not None:
            raise RuntimeError("engine failed; no further submissions") \
                from self._failure
        req = Request(prompt=np.asarray(prompt, np.int32),
                      max_gen=max_gen, stop=stop, temperature=temperature,
                      top_p=top_p, seed=seed)
        self.pool.validate_prompt(req.prompt_len)  # reject before queuing
        if not req.sampling.greedy and self._no_sampling is not None:
            raise NotImplementedError(
                f"sampling (temperature>0) is unavailable on this "
                f"session: {self._no_sampling} — submit greedy "
                "(temperature=0) requests, or rebuild the session on a "
                "layout that can return logits")
        self.scheduler.submit(req)
        if self._failure is not None or self._closed:
            # the engine died or closed while we enqueued: the final
            # drain may have run before our append landed, so pull the
            # request back out and fail it loudly instead of letting it
            # hang in a dead engine's queue.
            self.scheduler.remove(req)
            _fail_request(req,
                          self._failure or RuntimeError("engine closed"))
            raise RuntimeError("engine stopped; no further submissions") \
                from self._failure
        self._wake.set()
        return req

    def stream(self, req: Request, timeout: float | None = None,
               ) -> Iterator[int]:
        """Yield ``req``'s tokens as they are decoded; returns on finish.

        Blocks between tokens by default (first-token latency includes
        jit compiles, which can be long on full-size archs); pass
        ``timeout`` seconds to raise TimeoutError on a stalled driver
        instead.
        """
        import queue as _queue

        while True:
            try:
                item = req._stream.get(timeout=timeout)
            except _queue.Empty:
                raise TimeoutError(
                    f"request {req.id}: no token within {timeout}s — is "
                    "the engine driver running (start()/step())?") \
                    from None
            if item is _DONE:
                if req.error is not None:
                    raise req.error
                return
            yield item

    # ------------------------------------------------------------------ #
    # Driving (one driver at a time: background thread OR manual ticks)
    # ------------------------------------------------------------------ #

    def step(self) -> bool:
        """One engine tick. Returns True if any work ran."""
        with self._lock:
            try:
                admitted, rejected = self.scheduler.admit(self.pool)
                for req, err in rejected:
                    # an impossible request fails alone; its queue
                    # neighbours were already admitted past it.
                    self.stats.rejected_requests += 1
                    _fail_request(req, err)
                if admitted:
                    if self._paged:
                        self._apply_page_plans(admitted)
                    else:
                        reset = self.pool.mask_for(
                            [r.slot for r in admitted])
                        self.caches = self.session.reset_slot_caches(
                            self.caches, reset)
                    for req in admitted:
                        self._by_slot[req.slot] = req
                    self._prefill_admitted(admitted)
                active = self.pool.active()
                if active:
                    self._decode_tick()
                self.stats.capacity_deferrals = \
                    self.scheduler.capacity_deferrals
                if self._paged:
                    self.stats.prefix_hits = self.pool.prefix_hits
                    self.stats.prefix_hit_tokens = \
                        self.pool.prefix_hit_tokens
                    self.stats.evictions = self.pool.evictions
                    self.stats.pages_in_use = self.pool.pages_in_use
                    self.stats.peak_pages_in_use = \
                        self.pool.pool.peak_in_use
                return bool(admitted or rejected or active)
            except BaseException as e:  # noqa: BLE001 — fail all waiters
                self._fail(e)
                raise

    def run_until_idle(self, max_ticks: int = 100_000) -> EngineStats:
        """Tick until the queue and every slot are empty (sync driver)."""
        for _ in range(max_ticks):
            if not self.step() and self.scheduler.n_queued == 0:
                break
        else:
            e = RuntimeError(f"not idle after {max_ticks} ticks")
            with self._lock:
                self._fail(e)  # unblock waiters like every error path
            raise e
        return self.stats

    def start(self) -> "ServeEngine":
        """Run the driver loop in a daemon thread (async driver)."""
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-serve-engine")
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop the driver. Requests still queued or in flight are failed
        (their waiters unblock with the close error) rather than left
        hanging in a dead engine."""
        self._closed = True
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=60)
            if self._thread.is_alive():
                raise RuntimeError(
                    "engine driver still running after 60s (a long "
                    "compile?); close() aborted — retry once the tick "
                    "finishes")
            self._thread = None
        with self._lock:
            if self._by_slot or self.scheduler.n_queued:
                self._fail(RuntimeError(
                    "engine closed with requests outstanding"))

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                did = self.step()
            except BaseException:  # noqa: BLE001 — recorded by step()
                return
            if not did and self.scheduler.n_queued == 0:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    # ------------------------------------------------------------------ #
    # Elastic serving: park / resubmit / reshard
    # ------------------------------------------------------------------ #

    @staticmethod
    def _fold(req: Request) -> None:
        """Fold ``req``'s emitted-but-unfolded tokens into its prompt so
        a re-prefill of the folded prompt emits exactly the next
        continuation token (prefill of length S emits the token at
        index S). Idempotent per token via ``req._folded``."""
        new = req.tokens[req._folded:]
        if new:
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(new, np.int32)])
            req._folded = len(req.tokens)

    def park_all(self) -> list[Request]:
        """Pull every request — in flight and queued — back to the host
        in arrival order: in-flight requests get their emitted tokens
        folded into their prompts (re-admission re-prefills them; radix
        sharing makes the repeat cheap) and their slots released. The
        engine is empty afterwards; the requests' waiters stay blocked
        until somewhere re-admits them (:meth:`reshard`, or a router's
        failover :meth:`resubmit` on a survivor replica)."""
        with self._lock:
            parked: list[Request] = []
            for slot, req in sorted(self._by_slot.items()):
                self._fold(req)
                self.pool.release(slot)
                req.slot = None
                if req.prompt_len >= self.pool.max_seq:
                    # one-token-from-cache-full edge: the folded prompt
                    # no longer fits re-prefill + 1 generated token.
                    # Surface it rather than silently truncating the
                    # stream (uninterrupted serving would have emitted
                    # one final token before the cache-full finish).
                    _fail_request(req, RuntimeError(
                        f"request {req.id} was parked {req.prompt_len} "
                        f"tokens into a max_seq={self.pool.max_seq} "
                        "cache — its stream cannot continue after a "
                        "reshard; resubmit with a longer max_seq"))
                    continue
                parked.append(req)
            self._by_slot.clear()
            parked.extend(self.scheduler.drain())
            parked.sort(key=lambda r: r.id)
            return parked

    def resubmit(self, req: Request) -> Request:
        """Re-admit a parked request (see :meth:`park_all`) — the
        failover path. The request OBJECT carries its emitted tokens,
        waiters and sampling RNG across, so the token stream (greedy or
        seeded-sampled) continues exactly where it stopped."""
        if self._closed:
            raise RuntimeError("engine closed; no further submissions")
        if self._failure is not None:
            raise RuntimeError("engine failed; no further submissions") \
                from self._failure
        self._fold(req)   # no-op unless the caller skipped park_all
        self.pool.validate_prompt(req.prompt_len)
        if not req.sampling.greedy and self._no_sampling is not None:
            raise NotImplementedError(
                f"sampling (temperature>0) is unavailable on this "
                f"session: {self._no_sampling} — this replica cannot "
                "adopt the request")
        self.scheduler.submit(req)
        self.stats.resubmitted_requests += 1
        self._wake.set()
        return req

    def reshard(self, new_topology) -> dict:
        """Rebuild this engine on ``new_topology`` without dropping
        work: park every request host-side, rebuild the session (mesh,
        jitted steps), relayout the params, rebuild the slot/page pools
        and caches, then re-admit the parked requests in arrival order.
        Token streams continue — consumers only observe a pause.
        Returns ``{"parked": n, "pause_s": wall_clock}``."""
        import time

        t0 = time.perf_counter()
        with self._lock:
            parked = self.park_all()
            new_sess = self.session.with_topology(new_topology)
            adopt = getattr(new_sess, "adopt_params", None)
            if self.params is not None and adopt is not None:
                host = jax_tree_to_host(self.params)
                self.params = adopt(host)
            self.session = new_sess
            self._paged = bool(new_sess.paged)
            self.pool = self._build_pool()
            self.caches = new_sess.init_caches(abstract=False)
            probe = getattr(new_sess, "sampling_unsupported_reason", None)
            self._no_sampling = probe() if probe is not None else None
            new_sess._engine_stats = self.stats
            for req in parked:
                self.scheduler.submit(req)
            self.stats.reshards += 1
            pause = time.perf_counter() - t0
            self.stats.reshard_pause_s += pause
            self._wake.set()
            return {"parked": len(parked), "pause_s": pause}

    def outstanding_tokens(self) -> int:
        """Token-denominated load: generation budget still owed to the
        in-flight requests plus prompt+budget of the queued ones — the
        router's least-loaded dispatch metric."""
        with self._lock:
            tot = 0
            for req in self._by_slot.values():
                tot += max(0, req.max_gen - len(req.tokens))
            for req in self.scheduler.pending():
                tot += req.prompt_len + req.max_gen
            return tot

    def prefix_affinity(self, prompt) -> int:
        """Tokens of ``prompt`` this engine's radix already caches (0 on
        contiguous pools / sharing off) — the router's affinity hint."""
        if not self._paged or getattr(self.pool, "radix", None) is None:
            return 0
        with self._lock:
            p = np.asarray(prompt, np.int32).reshape(-1)
            max_match = max(0, (int(p.size) - 1) // self.pool.page_size)
            if max_match == 0:
                return 0
            chain = self.pool.radix.match(p, max_match)
            return len(chain) * self.pool.page_size

    # ------------------------------------------------------------------ #
    # Tick internals
    # ------------------------------------------------------------------ #

    def _step_batched(self, batch, want_logits: bool = False):
        """One slot-aware step; asserts the output covers every slot
        (a compacted output would silently misalign slot indexing)."""
        if self._paged:
            batch = dict(batch,
                         page_tables=self.pool.page_table_matrix())
        if want_logits:
            res = self.session.serve_step_batched(
                self.params, self.caches, batch, want_logits=True)
        else:
            res = self.session.serve_step_batched(
                self.params, self.caches, batch)
        if self._track_moe:
            self.stats.moe.update(res[-1])
            res = res[:-1]
        if want_logits:
            out, logits, caches = res
        else:
            out, caches = res
            logits = None
        if out.shape[0] != self.pool.n_slots:
            raise RuntimeError(
                f"serve step returned {out.shape[0]} tokens for "
                f"{self.pool.n_slots} slots — the step tiling does not "
                "cover the slot pool (check_slot_sharding should have "
                "caught this)")
        return out, logits, caches

    def _apply_page_plans(self, reqs: list[Request]) -> None:
        """Device work for the admitted requests' page plans: zero every
        fresh page (the paged analogue of the slot-row reset — copy
        destinations get overwritten right after), then run each
        request's cross-partition page copies *in admission order*: a
        later request's copy source may itself be an earlier request's
        just-registered destination."""
        fresh = np.zeros(self.session.n_pages, bool)
        for req in reqs:
            al = self.pool.slots[req.slot].alloc
            for gid in al.fresh:
                fresh[gid] = True
        if fresh.any():
            self.caches = self.session.reset_pages(self.caches, fresh)
        w = self.pool.pages_per_req  # fixed width: one compile
        for req in reqs:
            al = self.pool.slots[req.slot].alloc
            if not al.copies:
                continue
            # pad by repeating the first pair — duplicate writes then
            # carry identical values, so the scatter stays well-defined
            src = np.full(w, al.copies[0][0], np.int32)
            dst = np.full(w, al.copies[0][1], np.int32)
            for i, (s_, d_) in enumerate(al.copies):
                src[i], dst[i] = s_, d_
            self.caches = self.session.copy_pages(self.caches, src, dst)
            # the sources' bytes are duplicated now: drop the admission
            # pins so the radix may evict them under page pressure again
            self.pool.copies_done(req.slot)

    def _prefill_admitted(self, reqs: list[Request]) -> None:
        """Prefill the admitted requests' prompts into their slots.

        Co-admitted chunks of equal width share one pipeline pass (the
        pos/mask vectors are already per-row), so K same-length prompts
        — or K chunk-aligned long prompts under ``prefill_chunk`` — cost
        one step, not K. A request's first token is sampled by the step
        that covers its prompt's last position. Paged requests whose
        prompt prefix came out of the radix start at their first
        uncached token instead of 0.
        """
        n = self.pool.n_slots

        def start_off(r):
            if self._paged:
                return self.pool.slots[r.slot].alloc.start_pos
            return 0

        pending = [(r, start_off(r)) for r in reqs]
        while pending:
            by_width: dict[int, list] = {}
            for r, off in pending:
                c = min(self.prefill_chunk or r.prompt_len,
                        r.prompt_len - off)
                by_width.setdefault(c, []).append((r, off))
            pending = []
            for c, group in sorted(by_width.items()):
                toks = np.zeros((n, c), np.int32)
                pos = self.pool.pos_vector()
                mask = np.zeros(n, bool)
                want = False
                for r, off in group:
                    toks[r.slot] = r.prompt[off:off + c]
                    pos[r.slot] = off
                    mask[r.slot] = True
                    if off + c >= r.prompt_len and not r.sampling.greedy:
                        want = True  # first token sampled this step
                out, logits, self.caches = self._step_batched(
                    {"tokens": toks, "pos": pos, "slot_mask": mask},
                    want)
                self.stats.prefill_steps += 1
                self.stats.prefill_tokens += c * len(group)
                out_np = logits_np = None
                for r, off in group:
                    if off + c >= r.prompt_len:
                        self.pool.slots[r.slot].pos = r.prompt_len
                        if self._paged:
                            # fully-prompt-covered pages turn shareable
                            self.pool.note_prefilled(r.slot, r.prompt)
                        if out_np is None:
                            out_np = np.asarray(out)
                        if logits_np is None and logits is not None:
                            logits_np = np.asarray(logits)
                        self._emit(r, self._pick_token(
                            r, out_np, logits, logits_np))
                    else:
                        pending.append((r, off + c))

    def _pick_token(self, req: Request, out_np, logits,
                    logits_np) -> int:
        """The next token for ``req``: the in-graph greedy argmax, or a
        host-side draw from its row of the returned logits."""
        if req.sampling.greedy:
            return int(out_np[req.slot])
        if logits_np is None:
            logits_np = np.asarray(logits)
        return sample_token(logits_np[req.slot], req.sampling, req._rng)

    def _decode_tick(self) -> None:
        """One batched decode step over every active slot.

        Finished requests are skipped defensively: a request that
        completed between the ``active`` snapshot and the emit (or whose
        slot was released out-of-band) must not receive another token or
        advance a slot that may already belong to a new request.
        """
        n = self.pool.n_slots
        active = self.pool.active()
        toks = np.zeros((n, 1), np.int32)
        want = False
        for s in active:
            req = self._by_slot.get(s.index)
            if req is None or req.done.is_set():
                continue
            toks[s.index, 0] = req.tokens[-1]
            if not req.sampling.greedy:
                want = True
        batch = {"tokens": toks, "pos": self.pool.pos_vector(),
                 "slot_mask": self.pool.active_mask()}
        out, logits, self.caches = self._step_batched(batch, want)
        self.pool.observe_tick()
        self.stats.decode_steps += 1
        self.stats.occupancy = self.pool.occupancy
        out_np = np.asarray(out)
        logits_np = np.asarray(logits) if logits is not None else None
        for s in active:
            req = self._by_slot.get(s.index)
            if req is None or req.done.is_set():
                continue
            s.pos += 1
            self._emit(req, self._pick_token(req, out_np, logits,
                                             logits_np))

    def _emit(self, req: Request, tok: int) -> None:
        if req.done.is_set() or req.slot is None:
            # late emit on a finished request: its slot may already hold
            # a different in-flight request — reading (or finishing)
            # through self.pool.slots[req.slot] would corrupt that one.
            return
        req.tokens.append(tok)
        req._stream.put(tok)
        self.stats.generated_tokens += 1
        slot = self.pool.slots[req.slot]
        if (len(req.tokens) >= req.max_gen or tok in req.stop
                or slot.pos >= self.pool.max_seq):
            self._finish(req)

    def _finish(self, req: Request) -> None:
        if req.slot is not None:
            self._by_slot.pop(req.slot, None)
            self.pool.release(req.slot)
            # the slot is free for reallocation from here on: drop the
            # request's pointer so no late _emit/_decode_tick can read a
            # reallocated slot's state through it.
            req.slot = None
        self.stats.finished_requests += 1
        req.done.set()
        req._stream.put(_DONE)
        self._wake.set()

    def _fail(self, e: BaseException) -> None:
        self._failure = e
        for req in list(self._by_slot.values()):
            _fail_request(req, e)
        self._by_slot.clear()
        for req in self.scheduler.drain():
            _fail_request(req, e)


def jax_tree_to_host(tree):
    """Pull a (possibly sharded) array tree to host numpy — the transfer
    half of a reshard (the new session's ``adopt_params`` re-lays the
    host tree out on the new mesh)."""
    import jax
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def _fail_request(req: Request, e: BaseException) -> None:
    """Tear down one request's waiters with ``e``."""
    req.error = e
    req.slot = None   # engine is dead: never dereference pool state again
    req.done.set()
    req._stream.put(_DONE)
