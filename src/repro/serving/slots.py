"""SlotPool: per-slot views over the monolithic serve caches.

``init_serve_caches`` allocates one ``[M·V, batch, max_seq, ...]`` tree;
the jitted step wants exactly that layout, so "per-slot caches" cannot be
physically separate buffers. Instead each batch row is a *slot* with its
own host-side position/length state, and the pool materializes the
``pos``/``slot_mask`` vectors that ``Session.serve_step_batched`` needs
each tick. Reclaiming a slot is O(1) bookkeeping here plus one masked
zeroing of its cache rows (``Session.reset_slot_caches``) — the jitted
step function is never rebuilt.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class _WaitPrefix:
    """``try_admit`` verdict distinct from None: this request should
    wait for an in-flight same-prefix prefill (its shared pages are
    about to be cached), but the pool itself has capacity — the
    scheduler may admit queue neighbours past it instead of stalling
    admission for the tick."""

    def __repr__(self) -> str:
        return "WAIT_PREFIX"


WAIT_PREFIX = _WaitPrefix()


@dataclasses.dataclass
class SlotView:
    """One cache row: independent position/length state for one request."""

    index: int
    pos: int = 0                    # next cache position to be written
    request_id: int | None = None   # owning request (None = free)

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotPool:
    """Fixed pool of ``n_slots`` cache rows with alloc/free bookkeeping."""

    def __init__(self, n_slots: int, max_seq: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.slots = [SlotView(i) for i in range(n_slots)]
        # lifetime counters for occupancy reporting
        self.ticks = 0
        self.busy_slot_ticks = 0

    # ------------------------------------------------------------------ #
    def validate_prompt(self, prompt_len: int) -> None:
        """The single authority on prompt-vs-cache sizing (the engine
        calls this at submit time, alloc at admission time)."""
        if prompt_len >= self.max_seq:
            raise ValueError(
                f"prompt of {prompt_len} tokens cannot decode inside a "
                f"max_seq={self.max_seq} cache (need >= prompt_len + 1)")

    def alloc(self, request_id: int, prompt_len: int) -> SlotView | None:
        """Claim the lowest free slot for ``request_id`` (None when
        full); rejects prompts the cache cannot hold."""
        self.validate_prompt(prompt_len)
        for s in self.slots:
            if s.free:
                s.request_id = request_id
                s.pos = 0
                return s
        return None

    def try_admit(self, req) -> SlotView | None:
        """Admission entry point shared with the paged pool: claim a
        slot for ``req`` (None when full; ValueError when the request
        can never fit — the scheduler rejects it without dequeuing its
        neighbours)."""
        return self.alloc(req.id, req.prompt_len)

    def release(self, index: int) -> None:
        s = self.slots[index]
        s.request_id = None
        s.pos = 0

    # ------------------------------------------------------------------ #
    @property
    def n_active(self) -> int:
        return sum(not s.free for s in self.slots)

    @property
    def n_free(self) -> int:
        return self.n_slots - self.n_active

    def active(self) -> list[SlotView]:
        return [s for s in self.slots if not s.free]

    # ---- vectors for serve_step_batched ------------------------------ #
    def pos_vector(self) -> np.ndarray:
        """int32 [n_slots]: each slot's next write position (free -> 0)."""
        return np.array([s.pos for s in self.slots], np.int32)

    def active_mask(self) -> np.ndarray:
        """bool [n_slots]: slots owned by an in-flight request."""
        return np.array([not s.free for s in self.slots], bool)

    def mask_for(self, indices) -> np.ndarray:
        """bool [n_slots]: one-hot-ish mask over ``indices``."""
        m = np.zeros(self.n_slots, bool)
        m[list(indices)] = True
        return m

    # ---- occupancy accounting ---------------------------------------- #
    def observe_tick(self) -> None:
        """Record one decode tick's occupancy (for the benchmark)."""
        self.ticks += 1
        self.busy_slot_ticks += self.n_active

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots busy per observed decode tick."""
        if self.ticks == 0:
            return 0.0
        return self.busy_slot_ticks / (self.ticks * self.n_slots)
