"""Paged KV cache: fixed-size ref-counted pages under the slot pool.

``init_serve_caches(page_size=...)`` lays the attention caches out as
``[M·V, n_pages, page_size, ...]`` instead of one contiguous
``(max_seq)`` row per slot; each request carries an int32 page *table*
(``max_seq // page_size`` entries, local page ids) and the cached
attention path gathers/scatters K/V through it. Cache memory then scales
with tokens actually written — and, with the radix index sharing prefix
pages across requests, with *unique* tokens.

Two host classes live here:

* :class:`PagePool` — the page arena bookkeeping: a free list and a
  refcount per page, partitioned over the pods×data shards (the device
  page axis is sharded exactly like the old batch axis, so a slot row
  can only gather pages of its own shard — every allocation is pinned to
  the partition of the slot it serves).
* :class:`PagedSlotPool` — the engine-facing pool: SlotPool-compatible
  surface (slots, pos/mask vectors, occupancy) plus paged admission:
  radix prefix match → shared-page refs (or cross-partition copies) →
  up-front reservation of the request's worst-case page span → the
  :class:`PageAllocation` the engine turns into device work (copies,
  resets, prefill from the first uncached token).

Greedy paged decoding stays token-identical to the contiguous path:
gathered pages hold the same values at the same positions, fresh pages
are zeroed like reclaimed slot rows, and anything a sentinel table entry
drags in sits beyond the causal mask (exact ``-inf`` before softmax).
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.serving.slots import WAIT_PREFIX, SlotView


class PagePool:
    """Free-list + refcount bookkeeping for ``n_pages`` fixed-size pages.

    Pages split evenly over ``shards * groups`` allocation partitions:
    ``shards`` is the device sharding of the page axis (pods×data — a
    slot row can only *gather* pages of its own shard) and ``groups``
    subdivides each shard per FSDP group — cache leaves are sharded over
    the stage axis, so a page's bytes exist only in the replica of the
    group that wrote them; sharing across groups would read unwritten
    memory. Page ids are *global*; partition ``p`` owns
    ``[p*n_loc, (p+1)*n_loc)``. Device page tables hold *shard-local*
    ids (``gid % (n_pages // shards)``)."""

    def __init__(self, n_pages: int, page_size: int, shards: int = 1,
                 groups: int = 1):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        parts = shards * groups
        if n_pages < parts or n_pages % parts != 0:
            raise ValueError(
                f"n_pages ({n_pages}) must divide evenly over the "
                f"{parts} cache partitions ({shards} pods×data shards "
                f"x {groups} groups)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.shards = shards
        self.groups = groups
        self.partitions = parts
        self.n_loc = n_pages // parts
        self.dev_pages = n_pages // shards
        self._refs = np.zeros(n_pages, np.int64)
        # lowest-id-first allocation keeps runs deterministic
        self._free = [list(range(p * self.n_loc, (p + 1) * self.n_loc))
                      for p in range(parts)]
        for f in self._free:
            heapq.heapify(f)
        self.peak_in_use = 0

    # ------------------------------------------------------------------ #
    def partition_of(self, gid: int) -> int:
        return gid // self.n_loc

    def group_of(self, partition: int) -> int:
        """Which FSDP group wrote (and may read) this partition's pages."""
        return partition % self.groups

    def local_id(self, gid: int) -> int:
        return gid % self.dev_pages

    def free_in(self, partition: int) -> int:
        return len(self._free[partition])

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - sum(len(f) for f in self._free)

    def refcount(self, gid: int) -> int:
        return int(self._refs[gid])

    # ------------------------------------------------------------------ #
    def alloc(self, partition: int, k: int) -> list[int] | None:
        """Claim ``k`` free pages in ``partition`` (refcount 1 each), or
        None if the free list is short (caller evicts / defers)."""
        free = self._free[partition]
        if len(free) < k:
            return None
        out = [heapq.heappop(free) for _ in range(k)]
        for gid in out:
            self._refs[gid] = 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return out

    def ref(self, gid: int) -> None:
        if self._refs[gid] < 1:
            raise ValueError(f"page {gid} is free; cannot add a reference")
        self._refs[gid] += 1

    def unref(self, gid: int) -> bool:
        """Drop one reference; returns True when the page went free."""
        if self._refs[gid] < 1:
            raise ValueError(f"page {gid} is already free")
        self._refs[gid] -= 1
        if self._refs[gid] == 0:
            heapq.heappush(self._free[self.partition_of(gid)], gid)
            return True
        return False


@dataclasses.dataclass
class PageAllocation:
    """One admitted request's page plan (host side of the tick work)."""

    start_pos: int                  # prefill resumes here (shared prefix)
    table: np.ndarray               # int32 [pages_per_req], LOCAL page ids
    pages: list[int]                # global ids this request holds refs on
    fresh: list[int]                # newly allocated -> device reset
    copies: list[tuple[int, int]]   # (src_gid, dst_gid) device page copies
    src_refs: list[int]             # copy sources pinned until the engine
    #                                 executes the copies (copies_done)
    n_shared: int                   # prefix pages satisfied from the radix
    n_prompt_pages: int             # pages fully covered by the prompt
    pending_key: tuple | None       # co-admission dedup key (held until
    #                                 the radix insert or release)


@dataclasses.dataclass
class PagedSlotView(SlotView):
    """A slot row plus its page allocation."""

    alloc: PageAllocation | None = None


class PagedSlotPool:
    """SlotPool-compatible pool that admits by free *pages*, not slots.

    Slot rows still exist (the jitted step is a fixed ``[n_slots]``
    batch) but carry no cache memory of their own; admission needs a free
    row in some partition AND enough free pages there — after counting
    the radix prefix hit and, if the free list is short, LRU-evicting
    unreferenced prefix pages. A prompt whose worst-case page span
    (``ceil(min(prompt+max_gen, max_seq)/page_size)``) exceeds one
    partition's pool can never run and raises; a merely-busy pool defers
    (returns None) like a full SlotPool, and a request whose prefix is
    being prefilled by an in-flight neighbour answers
    :data:`~repro.serving.slots.WAIT_PREFIX` so the scheduler can admit
    unrelated requests past it.
    """

    def __init__(self, n_slots: int, max_seq: int, *, page_size: int,
                 n_pages: int, shards: int = 1, groups: int = 1,
                 sharing: bool = True):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if max_seq % page_size != 0:
            raise ValueError(
                f"page_size ({page_size}) must divide max_seq "
                f"({max_seq}) so page tables have a fixed width")
        parts = shards * groups
        if n_slots % parts != 0:
            raise ValueError(
                f"n_slots ({n_slots}) must divide evenly over the "
                f"{parts} cache partitions")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_req = max_seq // page_size
        self.part_rows = n_slots // parts
        self.pool = PagePool(n_pages, page_size, shards, groups)
        if self.pool.n_loc < self.pages_per_req:
            raise ValueError(
                f"max_pages ({n_pages}) gives {self.pool.n_loc} pages per "
                f"partition, below the {self.pages_per_req} a single "
                f"max_seq={max_seq} request may need — raise max_pages")
        self.sharing = sharing
        if sharing:
            from repro.serving.radix import RadixIndex
            self.radix: "RadixIndex | None" = RadixIndex(page_size,
                                                         self.pool)
        else:
            self.radix = None
        self.slots = [PagedSlotView(i) for i in range(n_slots)]
        self._pending_keys: set[tuple] = set()
        # lifetime counters (occupancy mirrors SlotPool; the rest feed
        # the engine's paged stats)
        self.ticks = 0
        self.busy_slot_ticks = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0

    # ---- SlotPool-compatible surface --------------------------------- #
    def validate_prompt(self, prompt_len: int) -> None:
        if prompt_len >= self.max_seq:
            raise ValueError(
                f"prompt of {prompt_len} tokens cannot decode inside a "
                f"max_seq={self.max_seq} cache (need >= prompt_len + 1)")

    def release(self, index: int) -> None:
        s = self.slots[index]
        if s.alloc is not None:
            for gid in s.alloc.pages:
                self.pool.unref(gid)
            for gid in s.alloc.src_refs:  # copies never executed
                self.pool.unref(gid)
            self._pending_keys.discard(s.alloc.pending_key)
            s.alloc = None
        s.request_id = None
        s.pos = 0

    @property
    def n_active(self) -> int:
        return sum(not s.free for s in self.slots)

    @property
    def n_free(self) -> int:
        return self.n_slots - self.n_active

    def active(self) -> list[PagedSlotView]:
        return [s for s in self.slots if not s.free]

    def pos_vector(self) -> np.ndarray:
        return np.array([s.pos for s in self.slots], np.int32)

    def active_mask(self) -> np.ndarray:
        return np.array([not s.free for s in self.slots], bool)

    def mask_for(self, indices) -> np.ndarray:
        m = np.zeros(self.n_slots, bool)
        m[list(indices)] = True
        return m

    def observe_tick(self) -> None:
        self.ticks += 1
        self.busy_slot_ticks += self.n_active

    @property
    def occupancy(self) -> float:
        if self.ticks == 0:
            return 0.0
        return self.busy_slot_ticks / (self.ticks * self.n_slots)

    # ---- paged admission --------------------------------------------- #
    def partition_of_slot(self, index: int) -> int:
        return index // self.part_rows

    def page_table_matrix(self) -> np.ndarray:
        """int32 [n_slots, pages_per_req] of LOCAL page ids (free rows /
        unreserved tail entries hold 0 — gather-safe, causally masked)."""
        out = np.zeros((self.n_slots, self.pages_per_req), np.int32)
        for s in self.slots:
            if s.alloc is not None:
                out[s.index] = s.alloc.table
        return out

    def pages_needed(self, prompt_len: int, max_gen: int) -> int:
        horizon = min(prompt_len + max_gen, self.max_seq)
        return -(-horizon // self.page_size)

    def _page_keys(self, prompt: np.ndarray, n: int) -> tuple:
        """The first ``n`` full prompt pages as a tuple of token tuples."""
        ps = self.page_size
        return tuple(tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])
                     for i in range(n))

    def try_admit(self, req):
        """Admit one request: pick the free slot whose partition caches
        the most of its prefix, reserve its worst-case page span (evicting
        if needed), and return the view. Returns None to defer on
        capacity (admission stops for the tick), or :data:`WAIT_PREFIX`
        when a same-prefix prefill is in flight (queue neighbours may be
        admitted past this request). Raises ValueError for requests that
        can never fit."""
        self.validate_prompt(req.prompt_len)
        L = req.prompt_len
        need_total = self.pages_needed(L, req.max_gen)
        free_slots = [s for s in self.slots if s.free]
        if not free_slots:
            return None
        max_match = (L - 1) // self.page_size
        chain = (self.radix.match(req.prompt, max_match)
                 if self.radix is not None else [])
        if self.radix is not None and max_match > len(chain) \
                and self._pending_keys:
            # defer only if some in-flight prefill covers MORE of this
            # prompt than the radix already does: admitting now would
            # re-prefill pages that request is about to cache. Keyed on
            # the full matched extent (not just the first page), so a
            # request that merely shares a page-one prefix — or whose
            # chain already covers the overlap — admits immediately.
            mine = self._page_keys(req.prompt, max_match)
            for pend in self._pending_keys:
                common = 0
                for a, b in zip(mine, pend):
                    if a != b:
                        break
                    common += 1
                if common > len(chain):
                    return WAIT_PREFIX

        def local_hits(part: int) -> int:
            return sum(part in nd.pages for nd in chain)

        slot = max(free_slots,
                   key=lambda s: (local_hits(self.partition_of_slot(
                       s.index)), -s.index))
        part = self.partition_of_slot(slot.index)
        grp = self.pool.group_of(part)
        # sharing stops at the first prefix page with no usable source: a
        # page serves this slot if it is cached locally or copyable from
        # a same-group partition — other groups' stage replicas never
        # wrote its bytes, so their registrations are unreadable here.
        usable = []
        for nd in chain:
            if part in nd.pages or any(
                    self.pool.group_of(p2) == grp for p2 in nd.pages):
                usable.append(nd)
            else:
                break
        chain = usable

        # 1) ref the locally-cached prefix pages first: a live reference
        #    pins them against the eviction pass below.
        held: list[int] = []
        local_pages: list[int | None] = []
        for nd in chain:
            gid = nd.pages.get(part)
            if gid is not None:
                self.pool.ref(gid)
                held.append(gid)
            local_pages.append(gid)
        n_copies = sum(g is None for g in local_pages)
        n_fresh = (need_total - len(chain)) + n_copies

        def rollback():
            for gid in held:
                self.pool.unref(gid)

        if need_total > self.pool.n_loc:
            rollback()
            raise ValueError(
                f"request needs {need_total} pages "
                f"(prompt {L} + max_gen {req.max_gen} at page_size "
                f"{self.page_size}) but a partition holds only "
                f"{self.pool.n_loc} — raise max_pages or shrink the "
                "request")
        short = n_fresh - self.pool.free_in(part)
        if short > 0:
            if self.radix is not None:
                self.radix.evict(part, short)
            if n_fresh > self.pool.free_in(part):
                rollback()
                return None  # page pressure: stay queued
        fresh = self.pool.alloc(part, n_fresh)
        assert fresh is not None
        held.extend(fresh)
        fresh_iter = iter(fresh)

        # 2) cross-partition prefix hits: a local page + a device copy
        #    instead of a recompute; register the copy so the next
        #    request in this partition shares it for free. The SOURCE is
        #    ref-pinned until the engine has executed the copy: a later
        #    admission landing in the source's partition could otherwise
        #    evict a trie-only source and re-allocate it as a fresh page
        #    — fresh pages are zeroed before any copy runs, so the copy
        #    (and, through the registered destination, every future
        #    sharer) would silently read zeros.
        copies: list[tuple[int, int]] = []
        src_refs: list[int] = []
        for i, nd in enumerate(chain):
            if local_pages[i] is None:
                src = nd.pages[min(p2 for p2 in nd.pages
                                   if self.pool.group_of(p2) == grp)]
                self.pool.ref(src)
                src_refs.append(src)
                dst = next(fresh_iter)
                copies.append((src, dst))
                self.radix.register(nd, part, dst)
                local_pages[i] = dst

        table = np.zeros(self.pages_per_req, np.int32)
        pages = list(local_pages)
        for j in range(len(chain), need_total):
            pages.append(next(fresh_iter))
        for j, gid in enumerate(pages):
            table[j] = self.pool.local_id(gid)

        start_pos = len(chain) * self.page_size
        n_prompt_pages = L // self.page_size
        pending = None
        if self.radix is not None and n_prompt_pages > len(chain):
            pending = self._page_keys(req.prompt, n_prompt_pages)
            self._pending_keys.add(pending)
        if chain:
            self.prefix_hits += 1
            self.prefix_hit_tokens += start_pos

        slot.request_id = req.id
        slot.pos = 0
        # ``pages`` is page-index ordered (the radix insert reads
        # ``pages[i]`` for prompt page i); it covers the same one-ref-each
        # set as ``held``: locally-shared refs plus every fresh alloc.
        slot.alloc = PageAllocation(
            start_pos=start_pos, table=table, pages=pages, fresh=fresh,
            copies=copies, src_refs=src_refs, n_shared=len(chain),
            n_prompt_pages=n_prompt_pages, pending_key=pending)
        return slot

    def copies_done(self, index: int) -> None:
        """The engine executed slot ``index``'s page copies: drop the
        admission-time pins on the copy sources (from here on they live
        through the radix / their other-partition holders)."""
        al = self.slots[index].alloc
        if al is None:
            return
        for gid in al.src_refs:
            self.pool.unref(gid)
        al.src_refs = []

    def note_prefilled(self, index: int, prompt: np.ndarray) -> None:
        """The request in slot ``index`` finished its prefill: its fully-
        prompt-covered pages become shareable (radix insert) and any
        co-admission hold on its prefix key is lifted."""
        s = self.slots[index]
        al = s.alloc
        if al is None:
            return
        if self.radix is not None and al.n_prompt_pages > al.n_shared:
            part = self.partition_of_slot(index)
            self.radix.insert(prompt, al.n_prompt_pages, part,
                              al.pages, skip=al.n_shared)
        self._pending_keys.discard(al.pending_key)
        al.pending_key = None

    # ---- reporting --------------------------------------------------- #
    @property
    def pages_in_use(self) -> int:
        return self.pool.pages_in_use

    @property
    def evictions(self) -> int:
        return self.radix.evictions if self.radix is not None else 0
