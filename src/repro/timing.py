"""Shared wall-clock measurement: warmup-discard + median-of-N.

Every place the repo times real work — the comm bench A/B, the kernel
micro-bench, the profile-guided plan search (``schedule="auto_profiled"``)
and the joint knob hillclimb — goes through :func:`measure_us` so they
all share the same discipline: discard ``warmup`` calls (compile +
cache-fill), then take the median of ``iters`` timed calls with the
device queue drained (``jax.block_until_ready``) before and after each
one. Single-shot wall timings on CPU are noisy enough to flip schedule
rankings; the median is what gets recorded and compared.

``benchmarks/timing.py`` re-exports this module so benchmark drivers can
import it without src/repro on the path mattering (and vice versa: core
code never imports the ``benchmarks`` package).
"""

from __future__ import annotations

import dataclasses
import statistics
import time


def _block(x):
    """Drain the device queue for ``x`` (pytree-ok); identity off-jax."""
    try:
        import jax
        return jax.block_until_ready(x)
    except Exception:   # noqa: BLE001 — host-only callables time fine
        return x


@dataclasses.dataclass(frozen=True)
class Timing:
    """One measurement: median + the raw per-call samples (seconds)."""

    median_s: float
    times_s: tuple    # every timed call, in order
    warmup: int
    iters: int

    @property
    def median_us(self) -> float:
        return self.median_s * 1e6

    @property
    def spread(self) -> float:
        """(max - min) / median — a quick noise indicator."""
        if not self.times_s or self.median_s <= 0:
            return 0.0
        return (max(self.times_s) - min(self.times_s)) / self.median_s

    def as_dict(self) -> dict:
        return {"median_us": self.median_us, "warmup": self.warmup,
                "iters": self.iters,
                "times_us": [t * 1e6 for t in self.times_s]}


def measure(fn, *, warmup: int = 1, iters: int = 3,
            block=_block) -> Timing:
    """Time ``fn()``: ``warmup`` discarded calls, then median of
    ``iters``. ``block`` drains async work (defaults to
    ``jax.block_until_ready`` over the returned pytree)."""
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    for _ in range(max(warmup, 0)):
        block(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block(fn())
        times.append(time.perf_counter() - t0)
    return Timing(median_s=statistics.median(times), times_s=tuple(times),
                  warmup=max(warmup, 0), iters=iters)


def measure_us(fn, *, warmup: int = 1, iters: int = 3,
               block=_block) -> float:
    """Median microseconds per call (the number benches record)."""
    return measure(fn, warmup=warmup, iters=iters, block=block).median_us
