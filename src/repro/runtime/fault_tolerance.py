"""Fault-tolerance controller: checkpoint/restart, straggler watchdog,
elastic re-mesh.

At 1000+ nodes the dominant failure modes are (a) hard node loss — handled
by restart-from-checkpoint with a possibly *smaller* data axis (elastic),
(b) stragglers — detected by a step-time EMA watchdog so the launcher can
evict and re-mesh, and (c) corrupted/partial checkpoints — handled by
manifest verification + falling back to the previous step.

This module is deliberately launcher-level (pure Python around the jitted
step): the jitted program itself stays failure-oblivious, which is what
makes restarts cheap.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

from repro.ckpt.checkpoint import CheckpointManager

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class FaultToleranceConfig:
    ckpt_every: int = 50
    keep: int = 3
    max_failures: int = 3
    # straggler watchdog: flag a step slower than ema * factor
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    async_save: bool = True


class StragglerWatchdog:
    """Step-time EMA; on real clusters the flagged rank is reported to the
    scheduler for eviction. Here we surface flags + counters."""

    def __init__(self, cfg: FaultToleranceConfig):
        self.cfg = cfg
        self.ema: float | None = None
        self.flags = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.cfg.straggler_factor * \
            self.ema
        self.ema = dt if self.ema is None else (
            self.cfg.ema_decay * self.ema + (1 - self.cfg.ema_decay) * dt
        )
        if slow:
            self.flags += 1
            log.warning("straggler: step took %.3fs (ema %.3fs)", dt,
                        self.ema)
        return slow


class TrainController:
    """Restart-from-checkpoint training loop.

    ``build`` is called after every (re)start — it receives the restored
    state (or None) and must return (state, step_fn, save_tree_fn), so an
    elastic restart can rebuild the mesh/runtime at a different world size.
    """

    def __init__(self, ckpt_dir: str, cfg: FaultToleranceConfig):
        self.cfg = cfg
        self.mgr = CheckpointManager(ckpt_dir, keep=cfg.keep)
        self.watchdog = StragglerWatchdog(cfg)
        self.failures = 0
        self.resume_steps: list[int] = []   # step each restart resumed at

    def attach(self, session) -> "TrainController":
        """Surface this controller in ``session.describe()
        ["fault_tolerance"]`` (failures/flags/resumes become part of the
        run's introspection record, not just the log)."""
        session._fault_tolerance = self
        return self

    def summary(self) -> dict:
        """Counters for metrics / ``describe()["fault_tolerance"]``."""
        return {
            "failures": self.failures,
            "max_failures": self.cfg.max_failures,
            "straggler_flags": self.watchdog.flags,
            "straggler_ema_s": self.watchdog.ema,
            "resume_steps": list(self.resume_steps),
            "ckpt_every": self.cfg.ckpt_every,
            "ckpt_steps": self.mgr.list_steps(),
        }

    def restore_latest(self, shardings=None):
        step = self.mgr.latest_step()
        while step is not None:
            if self.mgr.verify(step):
                return self.mgr.restore(step, shardings)
            log.warning("checkpoint step %d corrupt; trying previous", step)
            steps = [s for s in self.mgr.list_steps() if s < step]
            step = steps[-1] if steps else None
        return None, None

    def run(self, build: Callable, total_steps: int,
            inject_failure_at: int | None = None):
        """build(restored_manifest) -> (state, run_one_step, tree_of(state)).

        run_one_step(state, step) -> (state, metrics). Exceptions trigger
        restore + rebuild up to max_failures.
        """
        history = []
        while True:
            tree, manifest = self.restore_latest()
            start = (manifest or {}).get("extra", {}).get("step", 0)
            if self.failures:
                self.resume_steps.append(start)
            state, run_one, tree_of = build(tree, manifest)
            step = start
            try:
                while step < total_steps:
                    t0 = time.time()
                    if inject_failure_at is not None and \
                            step == inject_failure_at:
                        inject_failure_at = None
                        raise RuntimeError("injected node failure")
                    state, metrics = run_one(state, step)
                    self.watchdog.observe(time.time() - t0)
                    history.append((step, metrics))
                    step += 1
                    if step % self.cfg.ckpt_every == 0 or \
                            step == total_steps:
                        self.mgr.save(step, tree_of(state),
                                      extra={"step": step},
                                      blocking=not self.cfg.async_save)
                self.mgr.wait()
                return state, history
            except Exception as e:  # noqa: BLE001 — restart on anything
                self.failures += 1
                log.error("step %d failed (%s); restart %d/%d", step, e,
                          self.failures, self.cfg.max_failures)
                if self.failures >= self.cfg.max_failures:
                    raise
                self.mgr.wait()
