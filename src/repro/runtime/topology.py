"""Topology: the device/mesh layer behind the Session facade.

Everything above this module reasons about three logical axes — "pod"
(hybrid-sharded DP: params replicated, grads all-reduced once per step),
"data" (the FSDP + EP + vocab axis) and "model" (pipeline groups ×
stages; TP-free per the paper). This module owns how those axes land on
physical devices:

* a :class:`Topology` describes the hardware — hosts × devices-per-host,
  an interconnect class, and ``kind`` ("fake_cpu" single-process CPU
  demos, "gpu_cluster" NVLink-island clusters, "tpu_pod" ICI pods);
* :meth:`Topology.axis_layout` derives the pods×data×model widths from
  the hardware under a ``cost_preset``: the a800 preset confines the
  FSDP axis to the NVLink island (intra-host gathers) and folds the
  remaining nodes into hybrid-sharded DP pods, the tpu_v5e preset keeps
  FSDP across a full 16×16 pod (uniform ICI makes the wide gather
  cheap) and maps pods to physical pods;
* :meth:`Topology.ensure_devices` performs the per-kind device
  bootstrap — fake host devices for "fake_cpu", a guarded
  ``jax.distributed.initialize`` for real multi-host kinds;
* :meth:`Topology.build_mesh` turns the derived layout into the
  ``jax.Mesh`` every Session runs on.

The old ``launch/mesh.py`` hard-coded 16×16 pod lives on as the
``tpu_pod`` / ``tpu_pod_x2`` presets; elastic restarts shrink a
topology's data axis (:meth:`Topology.shrink`) and rebuild the Session
on the survivor subset.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

KINDS = ("fake_cpu", "gpu_cluster", "tpu_pod")

# default interconnect class per kind (informational + used by the
# layout derivation notes; the α–β constants live in core/plan.py)
_INTERCONNECT = {
    "fake_cpu": "host",
    "gpu_cluster": "nvlink+ib",
    "tpu_pod": "ici",
}

# TPU v5e hardware constants (per chip) used by the roofline analysis;
# re-exported by launch/mesh.py for compatibility.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (~4 links usable)


class TopologyError(ValueError):
    """Invalid topology (message says how to fix it)."""


@dataclasses.dataclass(frozen=True)
class Topology:
    """Hosts × devices-per-host (× pods) plus the interconnect class.

    ``devices_per_host=None`` on the "fake_cpu" kind resolves from
    ``$SPMD_DEVICES`` (default 8) at :meth:`ensure_devices` /
    :meth:`total_devices` time — the same env contract every entry
    point already uses. ``data=`` pins the FSDP axis explicitly (elastic
    shrink sets it); None derives it from the hardware.
    """

    kind: str = "fake_cpu"
    hosts: int = 1
    devices_per_host: int | None = None
    pods: int = 1
    interconnect: str | None = None
    data: int | None = None         # explicit FSDP-axis width
    name: str | None = None         # preset provenance (None = ad hoc)

    def __post_init__(self):
        if self.interconnect is None:
            object.__setattr__(self, "interconnect",
                               _INTERCONNECT.get(self.kind))

    # ------------------------------------------------------------------ #
    def validate(self) -> "Topology":
        if self.kind not in KINDS:
            raise TopologyError(
                f"unknown topology kind {self.kind!r}; pick one of "
                f"{KINDS} (or a preset name from "
                f"{sorted(TOPOLOGY_PRESETS)})")
        if self.hosts < 1:
            raise TopologyError(f"hosts must be >= 1, got {self.hosts}")
        if self.devices_per_host is not None and self.devices_per_host < 1:
            raise TopologyError(
                f"devices_per_host must be >= 1, got "
                f"{self.devices_per_host}")
        if self.devices_per_host is None and self.kind != "fake_cpu":
            raise TopologyError(
                f"kind={self.kind!r} needs an explicit devices_per_host "
                "(only fake_cpu resolves it from $SPMD_DEVICES)")
        if self.pods < 1:
            raise TopologyError(f"pods must be >= 1, got {self.pods}")
        if self.hosts % self.pods != 0:
            raise TopologyError(
                f"pods ({self.pods}) must partition the hosts "
                f"({self.hosts}) evenly — a pod is a host group")
        if self.data is not None and self.data < 1:
            raise TopologyError(f"data must be >= 1, got {self.data}")
        if self.kind == "fake_cpu" and self.hosts != 1:
            raise TopologyError(
                "fake_cpu topologies are single-process (hosts=1); model "
                "a multi-host run with kind='gpu_cluster' or 'tpu_pod'")
        return self

    # ------------------------------------------------------------------ #
    @property
    def total_devices(self) -> int:
        return self.hosts * self._dph()

    def _dph(self) -> int:
        if self.devices_per_host is not None:
            return self.devices_per_host
        env = os.environ.get("SPMD_DEVICES")
        return int(env) if env else 8

    def axis_layout(self, model_ranks: int,
                    cost_preset: str = "a800") -> dict:
        """Derive the pods×data×model widths for this hardware.

        Rules (per ``cost_preset``):

        * base: ``data = total/(pods × model)`` — every device hosts one
          pipeline rank of one FSDP shard of one pod;
        * ``a800`` on "gpu_cluster": the FSDP gather/reduce ticks are
          the per-step bandwidth hot path, so the data axis is confined
          to the NVLink island (``devices_per_host``) when it would
          span hosts and divides evenly; the displaced factor folds
          into ``pods`` (hybrid-sharded DP pays one inter-node
          all-reduce per step instead of per tick);
        * ``tpu_v5e`` on "tpu_pod": uniform ICI keeps the full pod as
          one data axis — pods map to physical pods unchanged;
        * an explicit ``data=`` wins (elastic shrink pins it) and may
          use a *subset* of the devices — survivors after a node loss.
        """
        total = self.total_devices
        pods = self.pods
        if model_ranks < 1:
            raise TopologyError(f"model_ranks must be >= 1, "
                                f"got {model_ranks}")
        if self.data is not None:
            data = self.data
            if pods * data * model_ranks > total:
                raise TopologyError(
                    f"topology {self.label()}: pods×data×model = "
                    f"{pods}×{data}×{model_ranks} = "
                    f"{pods * data * model_ranks} exceeds the {total} "
                    "devices — shrink data= or add hosts")
        else:
            if total % (pods * model_ranks) != 0:
                raise TopologyError(
                    f"topology {self.label()}: {total} devices do not "
                    f"split over pods×model = {pods}×{model_ranks}; "
                    "adjust hosts/devices_per_host or pass data= "
                    "explicitly")
            data = total // (pods * model_ranks)
            if data < 1:
                raise TopologyError(
                    f"topology {self.label()}: pods×model = "
                    f"{pods}×{model_ranks} needs at least "
                    f"{pods * model_ranks} devices, have {total}")
            if (self.kind == "gpu_cluster" and cost_preset == "a800"
                    and self._dph() > 1 and data > self._dph()
                    and data % self._dph() == 0):
                # confine FSDP to the NVLink island; displaced factor
                # becomes hybrid-sharded DP across node groups
                pods = pods * (data // self._dph())
                data = self._dph()
        return {"pods": pods, "data": data, "model": model_ranks,
                "devices_used": pods * data * model_ranks,
                "devices_total": total}

    # ------------------------------------------------------------------ #
    def ensure_devices(self) -> int:
        """Per-kind device bootstrap; returns the live device count.

        "fake_cpu" routes through :func:`repro.api.devices.
        ensure_host_devices` (the XLA fake-host-device flag must be set
        before backend init — single-process demos keep working
        untouched). Real kinds initialize ``jax.distributed`` when a
        coordinator is configured (multi-host launch), else run
        single-process on whatever the backend provides.
        """
        if self.kind == "fake_cpu":
            from repro.api.devices import ensure_host_devices
            return ensure_host_devices(self.total_devices)
        if self.hosts > 1 and self._needs_distributed_init():
            import jax
            jax.distributed.initialize(
                coordinator_address=self._coordinator(),
                num_processes=self.hosts,
                process_id=int(os.environ.get("REPRO_PROCESS_ID", "0")))
        import jax
        have = len(jax.devices())
        if have < self.pods * (self.data or 1):
            raise TopologyError(
                f"topology {self.label()} expects at least "
                f"{self.pods * (self.data or 1)} devices, backend "
                f"provides {have} — is every host up and "
                "jax.distributed initialized on each?")
        return have

    @staticmethod
    def _coordinator() -> str | None:
        return (os.environ.get("REPRO_COORDINATOR_ADDRESS")
                or os.environ.get("JAX_COORDINATOR_ADDRESS"))

    def _needs_distributed_init(self) -> bool:
        """Multi-host init only when a coordinator is configured and the
        backend is not already initialized — structurally testable
        without real hardware."""
        if self._coordinator() is None:
            return False
        import jax
        try:
            return jax.process_count() <= 1
        except RuntimeError:
            return True

    def build_mesh(self, model_ranks: int, cost_preset: str = "a800"):
        """The ``jax.Mesh`` for this topology's derived axis layout
        (3-axis with a "pod" dimension when pods > 1)."""
        import jax

        lay = self.axis_layout(model_ranks, cost_preset)
        p, d, m = lay["pods"], lay["data"], lay["model"]
        if p > 1:
            return jax.make_mesh((p, d, m), ("pod", "data", "model"))
        return jax.make_mesh((d, m), ("data", "model"))

    # ------------------------------------------------------------------ #
    def shrink(self, model_ranks: int | None = None,
               factor: int = 2) -> "Topology":
        """The elastic-restart topology: same hardware description, data
        axis divided by ``factor`` (survivor subset after a node loss).
        """
        d = self.data
        if d is None:
            if model_ranks is None:
                raise TopologyError(
                    "shrink() on a derived-data topology needs "
                    "model_ranks to resolve the current data axis")
            d = self.axis_layout(model_ranks)["data"]
        if d <= 1:
            raise TopologyError(
                f"topology {self.label()}: data axis is already 1 — "
                "nothing left to shrink (restore on fresh hardware "
                "instead)")
        return dataclasses.replace(self, data=max(1, d // factor),
                                   name=None)

    def label(self) -> str:
        base = self.name or self.kind
        axes = f"hosts={self.hosts}×{self._dph()}"
        if self.pods > 1:
            axes += f" pods={self.pods}"
        if self.data is not None:
            axes += f" data={self.data}"
        return f"{base} ({axes})"

    def describe(self, model_ranks: int | None = None,
                 cost_preset: str = "a800") -> dict:
        """Device-free summary for ``Session.describe()["topology"]``."""
        out = {
            "kind": self.kind,
            "name": self.name,
            "hosts": self.hosts,
            "devices_per_host": self._dph(),
            "pods": self.pods,
            "interconnect": self.interconnect,
            "total_devices": self.total_devices,
        }
        if model_ranks is not None:
            try:
                out["layout"] = self.axis_layout(model_ranks, cost_preset)
            except TopologyError as e:
                out["layout_error"] = str(e)
        return out


# ---------------------------------------------------------------------- #
# Presets (the old launch/mesh.py constants live here now)
# ---------------------------------------------------------------------- #

TOPOLOGY_PRESETS: dict[str, Topology] = {
    # single-process CPU demos/tests; device count from $SPMD_DEVICES
    "fake_cpu": Topology(kind="fake_cpu", hosts=1, name="fake_cpu"),
    # 32 × 8-GPU NVLink nodes = 256 GPUs (the a800 cost preset's shape)
    "gpu_cluster": Topology(kind="gpu_cluster", hosts=32,
                            devices_per_host=8, name="gpu_cluster"),
    # one 16×16 v5e pod: 64 hosts × 4 chips = 256
    "tpu_pod": Topology(kind="tpu_pod", hosts=64, devices_per_host=4,
                        name="tpu_pod"),
    # two pods = 512 chips, hybrid-sharded DP across them
    "tpu_pod_x2": Topology(kind="tpu_pod", hosts=128, devices_per_host=4,
                           pods=2, name="tpu_pod_x2"),
}


def resolve_topology(t: Any) -> Topology | None:
    """None | preset name | Topology | kwargs dict -> validated Topology."""
    if t is None:
        return None
    if isinstance(t, Topology):
        return t.validate()
    if isinstance(t, str):
        if t not in TOPOLOGY_PRESETS:
            raise TopologyError(
                f"unknown topology preset {t!r}; known presets: "
                f"{', '.join(sorted(TOPOLOGY_PRESETS))} (or pass a "
                "Topology instance)")
        return TOPOLOGY_PRESETS[t]
    if isinstance(t, dict):
        try:
            return Topology(**t).validate()
        except TypeError as e:
            raise TopologyError(f"bad topology dict: {e}") from e
    raise TopologyError(
        f"topology must be a preset name, a Topology, or a kwargs dict; "
        f"got {type(t).__name__}")
