"""Zero-redundancy AdamW over the runtime's sharded parameter layout.

Optimizer states live in exactly the same sharding as the parameters
(stage-stacked [M·V, ...], FSDP-sharded over "data"), so the update is a
pure element-wise map with no communication — the grads arriving from the
pipeline are already reduce-scattered to matching shards (§3.3).

Master weights fp32; moments fp32 or bf16 (``rc.opt_moment_dtype``) — the
bf16 option halves optimizer HBM at scale (DESIGN.md hardware notes).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    # parameters whose name contains any of these skip weight decay
    no_decay: tuple = ("norm", "bias", "scale", "A_log", "Dd", "dt_bias")


def init_state(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
    }


def _decay_mask(params, cfg: AdamWConfig):
    def mask(path, _):
        name = jax.tree_util.keystr(path)
        return not any(t in name for t in cfg.no_decay)

    return jax.tree_util.tree_map_with_path(mask, params)


def global_norm(grads):
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)
    decay = _decay_mask(params, cfg)

    def upd(p, g, master, m, v, dec):
        gf = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if dec:
            delta = delta + cfg.weight_decay * master
        new_master = master - lr * delta
        return new_master.astype(p.dtype), new_master, m2.astype(mdt), \
            v2.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["master"], state["m"],
                       state["v"], decay)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_state = {
        "step": step,
        "master": jax.tree.map(lambda o: o[1], out,
                               is_leaf=lambda x: isinstance(x, tuple)),
        "m": jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple)),
        "v": jax.tree.map(lambda o: o[3], out,
                          is_leaf=lambda x: isinstance(x, tuple)),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def lr_schedule(step, *, base_lr, warmup=100, total=10_000,
                min_ratio=0.1):
    """Linear warmup + cosine decay (returns a multiplier for base_lr)."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
