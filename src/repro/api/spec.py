"""SessionSpec: the validated builder behind ``repro.api.session``.

Owns everything the old entry points assembled by hand — architecture
resolution, RunConfig overrides, shape selection, mesh sizing — and
turns bad inputs into actionable errors *before* any device work starts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.api.registry import (
    ARCH_REGISTRY,
    RegistryError,
    SCHEDULE_REGISTRY,
)
from repro.models.common import RunConfig, SHAPES, ShapeConfig


class SessionError(ValueError):
    """Invalid session specification (message says how to fix it)."""


MODES = ("train", "serve", "dry-run")
_MODE_ALIASES = {"dry_run": "dry-run", "dryrun": "dry-run"}

_RC_FIELDS = {f.name for f in dataclasses.fields(RunConfig)}


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """Everything needed to build a Session. Validated, not yet built."""

    arch: str
    mode: str = "train"
    # named ShapeConfig ("train_4k", ...), an explicit ShapeConfig, or
    # None to derive one from seq_len / global_batch / the RunConfig.
    shape: str | ShapeConfig | None = None
    reduced: bool = True            # reduced() smoke config vs production
    # pipeline schedule: a registered name, "auto" to run the §4
    # selection (every registered schedule + the autogen heuristic,
    # simulated under `cost_preset`; minimum makespan wins), or
    # "auto_profiled" for the coarse→fine search (same simulated screen,
    # then the top-K survivors are compiled and *timed* on the live mesh
    # and the minimum measured us/call wins — train mode only, needs
    # devices at construction). Shorthand for overrides["schedule"].
    schedule: str | None = None
    cost_preset: str = "a800"       # simulator preset: a800 | tpu_v5e
    # auto_profiled knobs: how many simulated survivors get a real
    # measurement, and a wall-clock cap on the measuring phase (the
    # simulated-best survivor is always measured, budget or not).
    profile_top_k: int = 3
    profile_budget_s: float | None = None
    # schedule="auto" memory cap (simulated peak bytes under the preset
    # cost model): candidates over budget lose to any that fits — the
    # knob that makes the unit-gated autogen (O(U) activation memory)
    # win over full-depth candidates when the whole batch can't stay
    # live. None ranks purely on makespan.
    mem_budget: float | None = None
    # collective coalescing: "flat" (default via RunConfig) packs each
    # stage's gatherable params into one flat buffer so every FSDP
    # gather/reduce tick issues ONE collective; "none" is the per-tensor
    # escape hatch (debugging / bitwise A-B). Shorthand for
    # overrides["coalesce"].
    coalesce: str | None = None
    # MoE expert placement: "gathered" (experts ride the FSDP
    # gather/reduce path like any tensor), "ep" (experts stay sharded
    # over the data axis; tokens move via all-to-all dispatch/combine),
    # or "auto" (cost both under the a2a-aware α–β model and keep the
    # smaller simulated makespan — with schedule="auto" the §4 search
    # runs once per mode). Shorthand for overrides["moe_mode"].
    moe_mode: str | None = None
    overrides: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    optim: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    data: int | None = None         # data-axis size (None -> derived)
    pods: int | None = None         # hybrid-sharded DP axis (reduced runs)
    multi_pod: bool = False         # production 2-pod mesh (dry-run)
    devices: int | None = None      # ensure this many host devices first
    seq_len: int | None = None      # derived-shape sequence length
    global_batch: int | None = None  # derived-shape global batch
    microbatch_size: int = 1        # samples per micro-batch (derived gb)
    max_seq: int | None = None      # serving cache length
    max_slots: int | None = None    # continuous-batching slot count
    #                                 (serve-mode global batch; each slot
    #                                 holds one in-flight request)
    prefill_chunk: int | None = None  # split prompts into chunks of this
    #                                   width (bounds the number of
    #                                   distinct prefill compilations)
    page_size: int | None = None    # paged KV cache: tokens per page
    #                                 (None -> contiguous per-slot rows)
    max_pages: int | None = None    # paged KV cache: total page count
    #                                 (None -> max_slots * max_seq/page)
    prefix_sharing: str = "on"      # radix prefix sharing across
    #                                 requests ("off": escape hatch —
    #                                 pages stay private per request)
    kv_cache_dtype: str | None = None  # KV-cache storage dtype: "fp32" |
    #                                    "bf16" | "int8" (int8 = quantized
    #                                    pages, needs page_size). Shorthand
    #                                    for overrides["kv_cache_dtype"].
    mesh: Any = None                # pre-built jax Mesh (advanced)
    # hardware topology: a preset name ("fake_cpu", "gpu_cluster",
    # "tpu_pod", "tpu_pod_x2"), a repro.runtime.topology.Topology, or a
    # kwargs dict. Subsumes the data=/pods=/multi_pod=/devices=/mesh=
    # knobs: the DP×FSDP×PP axis layout is derived from the hardware
    # under cost_preset and Session.mesh is built from it.
    topology: Any = None

    def __post_init__(self):
        object.__setattr__(self, "mode",
                           _MODE_ALIASES.get(self.mode, self.mode))
        object.__setattr__(self, "overrides", dict(self.overrides or {}))
        object.__setattr__(self, "optim", dict(self.optim or {}))
        if self.schedule is not None:
            prev = self.overrides.get("schedule")
            if prev is not None and prev != self.schedule:
                raise SessionError(
                    f"schedule given twice and inconsistently: "
                    f"schedule={self.schedule!r} vs "
                    f"overrides['schedule']={prev!r}")
            self.overrides["schedule"] = self.schedule
        if self.coalesce is not None:
            prev = self.overrides.get("coalesce")
            if prev is not None and prev != self.coalesce:
                raise SessionError(
                    f"coalesce given twice and inconsistently: "
                    f"coalesce={self.coalesce!r} vs "
                    f"overrides['coalesce']={prev!r}")
            self.overrides["coalesce"] = self.coalesce
        if self.moe_mode is not None:
            prev = self.overrides.get("moe_mode")
            if prev is not None and prev != self.moe_mode:
                raise SessionError(
                    f"moe_mode given twice and inconsistently: "
                    f"moe_mode={self.moe_mode!r} vs "
                    f"overrides['moe_mode']={prev!r}")
            self.overrides["moe_mode"] = self.moe_mode
        if self.kv_cache_dtype is not None:
            prev = self.overrides.get("kv_cache_dtype")
            if prev is not None and prev != self.kv_cache_dtype:
                raise SessionError(
                    f"kv_cache_dtype given twice and inconsistently: "
                    f"kv_cache_dtype={self.kv_cache_dtype!r} vs "
                    f"overrides['kv_cache_dtype']={prev!r}")
            self.overrides["kv_cache_dtype"] = self.kv_cache_dtype

    # ------------------------------------------------------------------ #
    def validate(self) -> "SessionSpec":
        if self.mode not in MODES:
            raise SessionError(
                f"unknown mode {self.mode!r}; pick one of {MODES}")
        try:
            ARCH_REGISTRY.get(self.arch)
        except RegistryError as e:
            raise SessionError(str(e)) from e

        bad = sorted(set(self.overrides) - _RC_FIELDS)
        if bad:
            raise SessionError(
                f"unknown RunConfig override(s) {bad}; valid fields: "
                f"{', '.join(sorted(_RC_FIELDS))}")
        sched = self.overrides.get("schedule")
        auto_modes = ("auto", "auto_profiled")
        if sched is not None and sched not in auto_modes \
                and sched not in SCHEDULE_REGISTRY:
            try:
                SCHEDULE_REGISTRY.get(sched)  # raises with the full hint
            except RegistryError as e:
                raise SessionError(
                    str(e) + " (or pass schedule='auto' to search the "
                    "registered schedules, 'auto_profiled' to also time "
                    "the finalists on the live mesh)") from e
        if sched == "auto_profiled" and self.mode != "train":
            raise SessionError(
                "schedule='auto_profiled' measures real *train* steps "
                f"during selection; this session is mode={self.mode!r} — "
                "use schedule='auto' (simulated-only) here, or tune in a "
                "train session and pass the winning schedule explicitly")
        if self.profile_top_k < 1:
            raise SessionError(
                f"profile_top_k must be >= 1 (at least the simulated-best "
                f"candidate gets measured), got {self.profile_top_k}")
        if self.profile_budget_s is not None and self.profile_budget_s < 0:
            raise SessionError(
                f"profile_budget_s must be >= 0 (0 still measures the "
                f"simulated-best candidate), got {self.profile_budget_s}")
        if sched != "auto_profiled" and (
                self.profile_top_k != 3 or self.profile_budget_s
                is not None):
            raise SessionError(
                "profile_top_k/profile_budget_s only steer the "
                "schedule='auto_profiled' measured refinement; pass "
                "schedule='auto_profiled' (or drop them)")
        co = self.overrides.get("coalesce")
        if co is not None and co not in ("flat", "none"):
            raise SessionError(
                f"unknown coalesce mode {co!r}; pick 'flat' (one "
                "collective per stage segment per tick) or 'none' "
                "(per-tensor collectives)")
        mm = self.overrides.get("moe_mode")
        if mm is not None and mm not in ("gathered", "ep", "auto"):
            raise SessionError(
                f"unknown moe_mode {mm!r}; pick 'gathered' (experts ride "
                "the FSDP collectives), 'ep' (expert-parallel: experts "
                "sharded over data, tokens all-to-all'd), or 'auto' "
                "(cost both and keep the smaller simulated makespan)")
        ki = self.overrides.get("kernel_impl")
        if ki not in (None, "ref", "pallas"):
            raise SessionError(
                f"unknown kernel_impl {ki!r}; pick 'pallas' (force the "
                "Pallas kernels; interpret mode off-TPU), 'ref' (jnp "
                "references), or None (backend default)")
        kvd = self.overrides.get("kv_cache_dtype")
        if kvd is not None:
            if kvd not in ("fp32", "bf16", "int8"):
                raise SessionError(
                    f"unknown kv_cache_dtype {kvd!r}; pick 'fp32', "
                    "'bf16', or 'int8' (quantized pages)")
            if self.mode != "serve":
                raise SessionError(
                    "kv_cache_dtype is a serving knob (KV-cache storage "
                    f"dtype); this session is mode={self.mode!r}")
            if kvd == "int8" and self.page_size is None:
                raise SessionError(
                    "kv_cache_dtype='int8' quantizes *pages* (per-page "
                    "scales live beside the page pool); pass "
                    "page_size=<tokens per page> — contiguous slot rows "
                    "have no scale storage")
        if self.topology is not None:
            clash = [k for k, v in (("data", self.data),
                                    ("pods", self.pods),
                                    ("multi_pod", self.multi_pod or None),
                                    ("devices", self.devices),
                                    ("mesh", self.mesh)) if v is not None]
            if clash:
                raise SessionError(
                    f"topology= subsumes {', '.join(clash)}: the axis "
                    "layout (and device bootstrap) is derived from the "
                    "topology under cost_preset — drop the explicit "
                    "knob(s) or pin the axis via "
                    "Topology(..., data=<width>)")
            from repro.runtime.topology import (TopologyError,
                                                resolve_topology)
            try:
                resolve_topology(self.topology)
            except TopologyError as e:
                raise SessionError(str(e)) from e

        from repro.core.plan import PRESETS
        if self.cost_preset not in PRESETS:
            raise SessionError(
                f"unknown cost_preset {self.cost_preset!r}; known "
                f"presets: {', '.join(sorted(PRESETS))}")
        if self.mem_budget is not None:
            if self.mem_budget <= 0:
                raise SessionError(
                    f"mem_budget must be a positive simulated-peak-memory "
                    f"cap (bytes under the {self.cost_preset!r} preset), "
                    f"got {self.mem_budget}")
            if sched not in auto_modes:
                raise SessionError(
                    "mem_budget only steers the schedule='auto'/"
                    "'auto_profiled' selection; pass one of those (or "
                    "drop mem_budget)")

        if isinstance(self.shape, str) and self.shape not in SHAPES:
            raise SessionError(
                f"unknown shape {self.shape!r}; named shapes: "
                f"{', '.join(sorted(SHAPES))} (or pass a ShapeConfig)")
        if not self.reduced and not isinstance(self.shape, str):
            raise SessionError(
                "production sessions (reduced=False) need a named shape "
                f"from {sorted(SHAPES)} so production_run(shape) can pick "
                "the RunConfig")
        if self.shape is None and self.mode == "serve" \
                and self.max_seq is None:
            raise SessionError(
                "serve sessions need max_seq=<prompt+gen+slack> (the KV "
                "cache length) or an explicit shape")
        if self.max_slots is not None:
            if self.mode != "serve":
                raise SessionError(
                    "max_slots is a serving knob (the continuous-batching "
                    f"slot count); this session is mode={self.mode!r}")
            if self.max_slots < 1:
                raise SessionError(
                    f"max_slots must be >= 1, got {self.max_slots}")
            if self.global_batch is not None \
                    and self.global_batch != self.max_slots:
                raise SessionError(
                    f"max_slots ({self.max_slots}) and global_batch "
                    f"({self.global_batch}) disagree; in serve mode they "
                    "are the same quantity — pass one of them")
            shards = (self.pods or 1) * (self.data or 1)
            if self.max_slots % shards != 0:
                raise SessionError(
                    f"max_slots ({self.max_slots}) must divide evenly "
                    f"over the pods×data axes ({shards}): the slotted "
                    "(per-slot pos) serve path needs a batch-sharded "
                    "cache — round max_slots up or shrink data=/pods=")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise SessionError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.prefix_sharing not in ("on", "off"):
            raise SessionError(
                f"prefix_sharing must be 'on' or 'off', got "
                f"{self.prefix_sharing!r}")
        if self.page_size is not None:
            if self.mode != "serve":
                raise SessionError(
                    "page_size is a serving knob (the paged-KV page "
                    f"width); this session is mode={self.mode!r}")
            if self.page_size < 1:
                raise SessionError(
                    f"page_size must be >= 1, got {self.page_size}")
            if self.max_seq is not None \
                    and self.max_seq % self.page_size != 0:
                raise SessionError(
                    f"page_size ({self.page_size}) must divide max_seq "
                    f"({self.max_seq}) so page tables have a fixed "
                    "width")
        if self.max_pages is not None:
            if self.page_size is None:
                raise SessionError(
                    "max_pages needs page_size=<tokens per page> (it "
                    "sizes the paged KV cache)")
            if self.max_pages < 1:
                raise SessionError(
                    f"max_pages must be >= 1, got {self.max_pages}")
            shards = (self.pods or 1) * (self.data or 1)
            if self.max_pages % shards != 0:
                raise SessionError(
                    f"max_pages ({self.max_pages}) must divide evenly "
                    f"over the pods×data axes ({shards}): the page axis "
                    "shards exactly like the slot batch axis")
        if self.page_size is not None:
            # the page arena and the slot rows partition over pods×data
            # × FSDP groups (cache leaves shard over the stage axis, so
            # a page exists only in the group replica that wrote it) —
            # catch a bad count here with the full partition count, not
            # deep in PagePool at engine construction.
            try:
                groups = self.resolve_configs()[2].groups
            except Exception:   # resolution errors surface on their own
                groups = None
            if groups is not None and groups > 1:
                shards = (self.pods or 1) * (self.data or 1)
                parts = shards * groups
                if self.max_pages is not None \
                        and self.max_pages % parts != 0:
                    raise SessionError(
                        f"max_pages ({self.max_pages}) must divide "
                        f"evenly over the {parts} cache partitions "
                        f"(pods×data ({shards}) × FSDP groups "
                        f"({groups})): a page lives only in the stage "
                        "replica of the group that wrote it — round "
                        f"max_pages to a multiple of {parts}")
                if self.max_slots is not None \
                        and self.max_slots % parts != 0:
                    raise SessionError(
                        f"max_slots ({self.max_slots}) must divide "
                        f"evenly over the {parts} cache partitions "
                        f"(pods×data ({shards}) × FSDP groups "
                        f"({groups})) for the paged serve path")
        return self

    # ------------------------------------------------------------------ #
    def resolve_configs(self):
        """Returns (arch_module, ModelConfig, RunConfig) post-overrides."""
        mod = ARCH_REGISTRY.get(self.arch)
        if self.reduced:
            if not hasattr(mod, "reduced"):
                raise SessionError(
                    f"architecture {self.arch!r} has no reduced() config; "
                    "pass reduced=False with a named shape")
            cfg, rc = mod.reduced()
        else:
            cfg = mod.config()
            rc = mod.production_run(self.shape)
        if self.overrides:
            rc = dataclasses.replace(rc, **self.overrides)
        return mod, cfg, rc
