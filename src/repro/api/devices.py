"""Host-device bootstrap shared by every entry point.

Fake host (CPU) devices for SPMD demos/tests are created via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``, which must be set
before the JAX backend initializes. This module therefore imports JAX
only *inside* the function, so ``from repro.api import
ensure_host_devices`` stays safe at the very top of a script.
"""

from __future__ import annotations

import os
import re

_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int | None = None, *, default: int = 8,
                        env_var: str = "SPMD_DEVICES",
                        force: bool = False) -> int:
    """Make sure N fake host devices exist; returns the live device count.

    Resolution order for N: explicit ``n`` argument, then ``$SPMD_DEVICES``,
    then ``default``. An existing device-count flag in ``$XLA_FLAGS`` is
    respected unless ``force=True`` (production dry-runs force 512).

    Call this before any other JAX work — if the backend already
    initialized with fewer devices, a RuntimeError explains the fix.
    """
    if n is None:
        env = os.environ.get(env_var)
        n = int(env) if env else int(default)
    n = int(n)
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(re.escape(_FLAG) + r"=(\d+)", flags)
    if m is None:
        os.environ["XLA_FLAGS"] = (flags + " " if flags else "") \
            + f"{_FLAG}={n}"
    elif force and int(m.group(1)) != n:
        os.environ["XLA_FLAGS"] = flags.replace(
            m.group(0), f"{_FLAG}={n}")
    else:
        n = int(m.group(1))  # respect the caller's explicit setting

    import jax

    devices = jax.devices()
    if devices and devices[0].platform != "cpu":
        # real accelerators: the fake-host-device flag does not apply —
        # run on what the backend provides.
        return len(devices)
    have = len(devices)
    if have < n:
        raise RuntimeError(
            f"requested {n} host devices but JAX already initialized with "
            f"{have}. Call repro.api.ensure_host_devices({n}) (or set "
            f"XLA_FLAGS={_FLAG}={n}) before any other JAX use in this "
            f"process.")
    return have
