"""Plug-in registries for architectures and pipeline schedules.

This module is deliberately import-light (stdlib only) so that it can be
imported from anywhere — ``repro.core.generators`` registers the built-in
schedules here at import time, and ``repro.models.model`` resolves
architectures through it — without creating import cycles.

Architectures
-------------
An architecture entry is anything exposing the config-module protocol
(``config()``, ``production_run(shape)``, ``reduced()`` — see
``repro/configs/_base.py``). Built-ins are registered lazily by module
path; user archs plug in with the decorator::

    @repro.api.register_arch("my-arch", aliases=("my_arch",))
    class MyArch:
        @staticmethod
        def reduced(): ...

Schedules
---------
A schedule entry is a callable ``(SchedParams) -> TickTable``. Built-ins
(zeropp / gpipe / 1f1b / interleaved / bfs / fwd_only) live in
``repro.core.generators``; new ones plug in without touching core files::

    @repro.api.register_schedule("my-sched")
    def my_sched(sp):
        return repro.api.greedy_schedule(sp, my_priority, name="my-sched")
"""

from __future__ import annotations

import difflib
import importlib
from typing import Any, Callable


class RegistryError(ValueError):
    """Unknown or conflicting registry entry (message is actionable)."""


class Registry:
    """Name -> entry mapping with aliases, lazy loading and clear errors."""

    def __init__(self, kind: str, *, preload: str | None = None,
                 normalize: Callable[[str], str] | None = None,
                 validate: Callable[[str, Any], None] | None = None,
                 register_hint: str | None = None):
        self.kind = kind
        self._preload = preload      # module that registers the built-ins
        self._normalize = normalize
        self._validate = validate
        self._register_hint = register_hint or f"register_{kind}"
        self._entries: dict[str, Any] = {}
        self._aliases: dict[str, str] = {}

    def _ensure_builtins(self) -> None:
        if self._preload is not None:
            mod, self._preload = self._preload, None
            try:
                importlib.import_module(mod)
            except BaseException:
                self._preload = mod  # keep retryable on import failure
                raise

    # ------------------------------------------------------------------ #
    def register(self, name: str, obj: Any = None, *,
                 aliases: tuple[str, ...] = (), overwrite: bool = False):
        """Register ``obj`` under ``name``; usable as a decorator."""
        if obj is None:
            return lambda o: self.register(name, o, aliases=aliases,
                                           overwrite=overwrite)
        # load lazy built-ins first so a user registration colliding with
        # one is rejected here, not blamed on the built-in's own import.
        # (Re-entrant during the preload module's import: sys.modules
        # already holds the partial module, so import_module is a no-op.)
        self._ensure_builtins()
        taken = [n for n in (name, *aliases)
                 if n in self._entries or n in self._aliases]
        if taken and not overwrite:
            raise RegistryError(
                f"{self.kind} {taken[0]!r} is already registered; pass "
                f"overwrite=True to replace it")
        if self._validate is not None and not isinstance(obj, str):
            self._validate(name, obj)
        if overwrite:
            # drop stale alias mappings so the new entry is reachable
            # under every name it was registered with
            for a in (name, *aliases):
                self._aliases.pop(a, None)
        self._entries[name] = obj
        for a in aliases:
            self._aliases[a] = name
        return obj

    def canonical(self, name: str) -> str | None:
        """Resolve a name/alias to its canonical key, or None.

        A direct entry wins over an alias of the same name, so
        ``register(alias_name, ..., overwrite=True)`` takes effect.
        """
        self._ensure_builtins()
        for cand in ([name, self._normalize(name)] if self._normalize
                     else [name]):
            if cand in self._entries:
                return cand
            cand = self._aliases.get(cand, cand)
            if cand in self._entries:
                return cand
        return None

    def get(self, name: str) -> Any:
        key = self.canonical(name)
        if key is None:
            known = ", ".join(self.names())
            close = difflib.get_close_matches(
                str(name), list(self._entries) + list(self._aliases), n=1)
            hint = f" (did you mean {close[0]!r}?)" if close else ""
            raise RegistryError(
                f"unknown {self.kind} {name!r}{hint}; known: {known}. "
                f"New {self.kind}s plug in via "
                f"repro.api.{self._register_hint}.")
        obj = self._entries[key]
        if isinstance(obj, str):  # lazy built-in: module path
            obj = importlib.import_module(obj)
            self._entries[key] = obj
        return obj

    def names(self) -> list[str]:
        self._ensure_builtins()
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return self.canonical(name) is not None


# --------------------------------------------------------------------------- #
# Architecture registry
# --------------------------------------------------------------------------- #


def _arch_normalize(name: str) -> str:
    return name.replace("-", "_").replace(".", "p")


def _arch_validate(name: str, obj: Any) -> None:
    if not (hasattr(obj, "reduced") or hasattr(obj, "config")):
        raise RegistryError(
            f"architecture {name!r} must expose at least one of "
            f"config()/reduced() (see repro/configs/_base.py for the "
            f"full protocol)")


ARCH_REGISTRY = Registry("architecture", normalize=_arch_normalize,
                         validate=_arch_validate,
                         register_hint="register_arch")

_BUILTIN_ARCHS: dict[str, tuple[str, ...]] = {
    "whisper_large_v3": ("whisper-large-v3",),
    "qwen2_moe_a2p7b": ("qwen2-moe-a2.7b",),
    "deepseek_v3_671b": ("deepseek-v3-671b",),
    "jamba_v0p1_52b": ("jamba-v0.1-52b",),
    "phi3_vision_4p2b": ("phi-3-vision-4.2b",),
    "minitron_4b": ("minitron-4b",),
    "yi_9b": ("yi-9b",),
    "phi4_mini_3p8b": ("phi4-mini-3.8b",),
    "llama3p2_1b": ("llama3.2-1b",),
    "xlstm_1p3b": ("xlstm-1.3b",),
    "gpt_paper": (),
}
for _name, _aliases in _BUILTIN_ARCHS.items():
    ARCH_REGISTRY.register(_name, f"repro.configs.{_name}",
                           aliases=_aliases)


# --------------------------------------------------------------------------- #
# Schedule registry
# --------------------------------------------------------------------------- #

SCHEDULE_REGISTRY = Registry("schedule",
                             preload="repro.core.generators")


# --------------------------------------------------------------------------- #
# Public helpers (re-exported by repro.api)
# --------------------------------------------------------------------------- #


def register_arch(name: str, obj: Any = None, *,
                  aliases: tuple[str, ...] = (), overwrite: bool = False):
    """Register an architecture (decorator-friendly)."""
    return ARCH_REGISTRY.register(name, obj, aliases=aliases,
                                  overwrite=overwrite)


def register_schedule(name: str, obj: Any = None, *,
                      aliases: tuple[str, ...] = (),
                      overwrite: bool = False):
    """Register a schedule generator ``(SchedParams) -> TickTable``."""
    return SCHEDULE_REGISTRY.register(name, obj, aliases=aliases,
                                      overwrite=overwrite)


def get_arch(name: str):
    """Resolve an architecture id (canonical name or alias)."""
    return ARCH_REGISTRY.get(name)


def list_archs() -> list[str]:
    return ARCH_REGISTRY.names()


def list_schedules() -> list[str]:
    return SCHEDULE_REGISTRY.names()


def generate_schedule(method: str, sp=None, **kw):
    """Build a TickTable for a registered schedule.

    Either pass a ``SchedParams`` as ``sp``, or its fields as keyword
    arguments (``P=4, V=2, n_mb=8, unit=4, ...``).
    """
    if sp is None:
        from repro.core.generators import SchedParams
        sp = SchedParams(**kw)
    return SCHEDULE_REGISTRY.get(method)(sp)
