"""repro.api — the single public facade over the ZeroPP runtime.

Entry points (examples, launchers, benchmarks) go through this surface
only; nothing outside ``src/repro`` should construct ``Runtime`` or the
``make_*_step`` builders directly::

    from repro.api import ensure_host_devices, session

    ensure_host_devices(8)                 # before any other JAX use
    sess = session("llama3.2-1b",
                   overrides=dict(microbatches=4, unit=2))
    grads, metrics = sess.train_step(params, batch)

Submodules load lazily (PEP 562) so that ``ensure_host_devices`` — which
must run before the JAX backend initializes — can be imported without
pulling in JAX, and so ``repro.api.registry`` stays import-light for the
core modules that register their built-ins here.
"""

_EXPORTS = {
    "ensure_host_devices": ("repro.api.devices", "ensure_host_devices"),
    "session": ("repro.api.session", "session"),
    "Session": ("repro.api.session", "Session"),
    "SessionSpec": ("repro.api.spec", "SessionSpec"),
    "SessionError": ("repro.api.spec", "SessionError"),
    "RegistryError": ("repro.api.registry", "RegistryError"),
    "register_arch": ("repro.api.registry", "register_arch"),
    "register_schedule": ("repro.api.registry", "register_schedule"),
    "get_arch": ("repro.api.registry", "get_arch"),
    "list_archs": ("repro.api.registry", "list_archs"),
    "list_schedules": ("repro.api.registry", "list_schedules"),
    "generate_schedule": ("repro.api.registry", "generate_schedule"),
    "SchedParams": ("repro.core.generators", "SchedParams"),
    "greedy_schedule": ("repro.core.generators", "greedy_schedule"),
    "SchedulePlan": ("repro.core.plan", "SchedulePlan"),
    "PlanSelection": ("repro.core.plan", "PlanSelection"),
    "select_plan": ("repro.core.plan", "select_plan"),
    "clear_plan_cache": ("repro.core.plan", "clear_plan_cache"),
    "ServeEngine": ("repro.serving", "ServeEngine"),
    "EngineRouter": ("repro.serving", "EngineRouter"),
    "Request": ("repro.serving", "Request"),
    "SchedulerPolicy": ("repro.serving", "SchedulerPolicy"),
    "SlotPool": ("repro.serving", "SlotPool"),
    "Topology": ("repro.runtime.topology", "Topology"),
    "TOPOLOGY_PRESETS": ("repro.runtime.topology", "TOPOLOGY_PRESETS"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.api' has no attribute {name!r}; public "
            f"surface: {', '.join(__all__)}") from None
    import importlib

    obj = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = obj
    return obj


def __dir__():
    return __all__
