"""The Session facade: one object for train / serve / dry-run.

Replaces the hand-assembled ritual (`get_arch -> replace(RunConfig) ->
build_geometry -> make_mesh -> Runtime -> ShapeConfig -> make_*_step ->
adamw`) that every entry point used to repeat::

    sess = repro.api.session("llama3.2-1b",
                             overrides=dict(microbatches=4, unit=2))
    params = sess.init_params()
    opt = sess.init_opt_state(params)
    grads, metrics = sess.train_step(params, sess.stream().batch(0))
    params, opt, om = sess.opt_step(params, grads, opt)

Heavy state (mesh, Runtime, jitted steps) is built lazily and cached, so
constructing a Session — and calling ``describe()`` — needs no devices.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.api.spec import SessionError, SessionSpec
from repro.core.generators import SchedParams
from repro.core.pipeline import (
    Runtime,
    init_serve_caches,
    make_serve_step,
    make_train_step,
)
from repro.core.plan import (
    UNIT_GATED_SCHEDULES,
    PlanAnalysis,
    SchedulePlan,
    fused_cost_model,
    preset_cost_model,
    select_plan,
)
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models import model as M
from repro.models.common import ShapeConfig
from repro.optim import adamw

_OPT_FIELDS = {f.name for f in dataclasses.fields(adamw.AdamWConfig)}


def session(arch: str, *, mode: str = "train", shape=None, overrides=None,
            **kw) -> "Session":
    """Build a validated Session. See SessionSpec for every knob.

    ``schedule="auto"`` (or ``overrides=dict(schedule="auto")``) runs the
    §4 plan selection: every registered schedule plus the autogen
    heuristic is simulated under ``cost_preset`` ("a800" | "tpu_v5e") and
    the minimum-makespan plan is what the session executes; the winner
    (and every candidate's simulated makespan) shows in ``describe()``.
    """
    spec = SessionSpec(arch=arch, mode=mode, shape=shape,
                       overrides=dict(overrides or {}), **kw)
    return Session(spec)


class Session:
    """A bound (arch × RunConfig × shape × mesh) with cached step fns."""

    def __init__(self, spec: SessionSpec):
        self.spec = spec.validate()
        self.arch_mod, self.cfg, self.rc = spec.resolve_configs()
        try:
            self.geo = M.build_geometry(self.cfg, self.rc)
        except ValueError as e:
            raise SessionError(
                f"invalid geometry for {spec.arch!r}: {e}. Adjust the "
                "pp/vpp/groups overrides.") from e
        self._mesh = spec.mesh
        self._shape_cfg: ShapeConfig | None = (
            spec.shape if isinstance(spec.shape, ShapeConfig)
            else M.SHAPES[spec.shape] if isinstance(spec.shape, str)
            else None)
        self._data: int | None = spec.data
        self._rt: Runtime | None = None
        self._steps: dict[Any, Any] = {}
        # schedule="auto": run the §4 plan selection now (device-free —
        # pure table generation + discrete-event simulation), so the rest
        # of the session sees a concrete schedule name + plan.
        self.plan_selection = None
        if self.rc.schedule == "auto":
            self.plan_selection = self._auto_select()
            self.rc = dataclasses.replace(
                self.rc, schedule=self.plan_selection.selected.name)

    # ------------------------------------------------------------------ #
    # Lazy distribution state
    # ------------------------------------------------------------------ #

    @property
    def multi_pod(self) -> bool:
        return self.spec.multi_pod or self.spec.pods is not None

    @property
    def mesh(self):
        if self._mesh is None:
            if self.spec.devices is not None:
                from repro.api.devices import ensure_host_devices
                ensure_host_devices(self.spec.devices)
            if not self.spec.reduced:
                from repro.launch.mesh import make_production_mesh
                self._mesh = make_production_mesh(
                    multi_pod=self.spec.multi_pod)
            else:
                n_dev = jax.device_count()
                model = self.geo.model_ranks
                pods = self.spec.pods or 1
                data = self._data or max(1, n_dev // (pods * model))
                self._data = data
                need = pods * data * model
                if need > n_dev:
                    raise SessionError(
                        f"mesh ({'pods×' if pods > 1 else ''}data×model = "
                        f"{need}) exceeds the {n_dev} available devices; "
                        f"call repro.api.ensure_host_devices({need}) "
                        f"before any other JAX use, or shrink data=/pods=")
                if pods > 1:
                    self._mesh = jax.make_mesh(
                        (pods, data, model), ("pod", "data", "model"))
                else:
                    self._mesh = jax.make_mesh((data, model),
                                               ("data", "model"))
        return self._mesh

    @property
    def data_size(self) -> int:
        if self._data is None:
            self._data = dict(self.mesh.shape)["data"]
        return self._data

    @property
    def shape_cfg(self) -> ShapeConfig:
        if self._shape_cfg is None:
            sp = self.spec
            if sp.mode == "serve":
                gb = sp.global_batch or 8
                self._shape_cfg = ShapeConfig("serve", sp.max_seq, gb,
                                              "decode")
            else:
                gb = sp.global_batch or (
                    (sp.pods or 1) * self.data_size * self.rc.groups
                    * self.rc.microbatches * sp.microbatch_size)
                self._shape_cfg = ShapeConfig(sp.mode, sp.seq_len or 32,
                                              gb, "train")
        return self._shape_cfg

    @property
    def rt(self) -> Runtime:
        """The underlying pipeline Runtime (built on first use). An
        auto-selected plan is injected so the Runtime executes exactly
        the table the selection simulated."""
        if self._rt is None:
            self._rt = Runtime(
                self.cfg, self.rc, self.mesh, multi_pod=self.multi_pod,
                plan=(self.plan_selection.selected
                      if self.plan_selection is not None else None))
        return self._rt

    # ------------------------------------------------------------------ #
    # Schedule-plan selection (schedule="auto")
    # ------------------------------------------------------------------ #

    def _cost_shape(self) -> tuple[int, int, int]:
        """(seq, mbs, dp) for the cost model — device-free: prefers the
        explicit shape/spec values, never forces a mesh build."""
        if self._shape_cfg is not None:
            seq = self._shape_cfg.seq_len
        else:
            seq = self.spec.seq_len or self.spec.max_seq or 32
        if self._data is not None:
            dp = self._data
        elif self.spec.mesh is not None:
            dp = dict(self.spec.mesh.shape).get("data", 1)
        else:
            # data axis not yet known and we must stay device-free: a
            # dp=1 guess would cost every FSDP gather/reduce at zero
            # ((dp-1)/dp = 0) and bias the selection toward
            # collective-heavy schedules, so assume the demo/CI mesh
            # width instead ((dp-1)/dp is within 15% of its asymptote
            # from dp=8 on, so the exact guess barely matters).
            dp = 8
        return seq, self.spec.microbatch_size, dp

    def _cost_model(self, vpp: int):
        seq, mbs, dp = self._cost_shape()
        return preset_cost_model(
            self.spec.cost_preset, self.cfg, P=self.rc.pp, V=vpp,
            seq=seq, mbs=mbs, dp=dp)

    def _auto_select(self):
        """Simulate every registered schedule (+ the §4 autogen heuristic)
        for this (arch × shape × mesh) and pick the minimum-makespan plan.
        Selections are cached process-wide on that key."""
        rc = self.rc
        seg = self.geo.segments[-1]
        seq, mbs, dp = self._cost_shape()
        preset = self.spec.cost_preset
        cache_key = (
            self.cfg.name, rc.pp, seg.vpp, rc.groups, rc.microbatches,
            rc.unit_size, rc.gather_prefetch, seq, mbs, dp,
            self.spec.pods or 1, preset,
        )
        return select_plan(
            rc.pp, seg.vpp, rc.microbatches, rc.unit_size,
            self._cost_model(seg.vpp), preset=preset,
            prefetch=rc.gather_prefetch, cache_key=cache_key)

    # ------------------------------------------------------------------ #
    # Parameters / optimizer
    # ------------------------------------------------------------------ #

    def init_params(self, key=None):
        return self.rt.init_params(key)

    def param_shapes(self):
        return self.rt.param_shapes()

    def input_specs(self, max_seq=None):
        return self.rt.input_specs(self.shape_cfg, max_seq=max_seq)

    def opt_config(self):
        """(AdamWConfig, use_lr_schedule, warmup, total) from spec.optim."""
        kw = dict(self.spec.optim)
        use_sched = "warmup" in kw or "total" in kw
        warmup = kw.pop("warmup", 100)
        total = kw.pop("total", 10_000)
        bad = sorted(set(kw) - _OPT_FIELDS)
        if bad:
            raise SessionError(
                f"unknown optim option(s) {bad}; valid: warmup, total, "
                f"{', '.join(sorted(_OPT_FIELDS))}")
        kw.setdefault("moment_dtype", self.rc.opt_moment_dtype)
        return adamw.AdamWConfig(**kw), use_sched, warmup, total

    def init_opt_state(self, params):
        return adamw.init_state(params, self.opt_config()[0])

    def opt_step_fn(self):
        if "opt" not in self._steps:
            opt_cfg, use_sched, warmup, total = self.opt_config()

            @jax.jit
            def _opt(params, grads, opt_state):
                scale = adamw.lr_schedule(
                    opt_state["step"], base_lr=1.0, warmup=warmup,
                    total=total) if use_sched else 1.0
                return adamw.apply_updates(params, grads, opt_state,
                                           opt_cfg, scale)

            self._steps["opt"] = _opt
        return self._steps["opt"]

    def opt_step(self, params, grads, opt_state):
        """One AdamW update; returns (params, opt_state, metrics)."""
        return self.opt_step_fn()(params, grads, opt_state)

    # ------------------------------------------------------------------ #
    # Training
    # ------------------------------------------------------------------ #

    def train_step_fn(self):
        if "train" not in self._steps:
            if self.shape_cfg.kind != "train":
                raise SessionError(
                    f"train_step needs a 'train' shape; this session is "
                    f"{self.shape_cfg.kind!r} ({self.shape_cfg.name})")
            self._steps["train"] = make_train_step(self.rt, self.shape_cfg)
        return self._steps["train"]

    def train_step(self, params, batch):
        """One pipeline step; returns (grads, metrics)."""
        return self.train_step_fn()(params, batch)

    # ------------------------------------------------------------------ #
    # Serving
    # ------------------------------------------------------------------ #

    def _max_seq(self) -> int:
        return self.spec.max_seq or self.shape_cfg.seq_len

    def serve_step_fn(self, prompt_len: int):
        key = ("serve", prompt_len)
        if key not in self._steps:
            self._steps[key] = make_serve_step(
                self.rt, self.shape_cfg, prompt_len=prompt_len,
                max_seq=self._max_seq())
        return self._steps[key]

    def init_caches(self, abstract: bool = False):
        return init_serve_caches(self.rt, self.shape_cfg,
                                 max_seq=self._max_seq(),
                                 abstract=abstract)

    def serve_prefill(self, params, caches, batch):
        """Run the prompt through the pipeline; returns (tokens, caches)."""
        prompt = batch["tokens"].shape[1]
        return self.serve_step_fn(prompt)(params, caches, batch)

    def serve_decode(self, params, caches, batch):
        """One cached decode step; returns (tokens, caches)."""
        return self.serve_step_fn(1)(params, caches, batch)

    # ------------------------------------------------------------------ #
    # Data / checkpointing / dry-run
    # ------------------------------------------------------------------ #

    def stream(self, seed: int = 0) -> SyntheticStream:
        cfg, sc = self.cfg, self.shape_cfg
        return SyntheticStream(DataConfig(
            seq_len=sc.seq_len, global_batch=sc.global_batch,
            vocab=cfg.vocab, seed=seed,
            kind=("enc_dec" if cfg.encdec else
                  "vision" if cfg.frontend == "vision" else "lm"),
            d_model=cfg.d_model,
            enc_ctx=cfg.encdec.enc_ctx if cfg.encdec else 0))

    def checkpointing(self, ckpt_dir: str, *, every: int = 50, **kw):
        """A fault-tolerance TrainController over this checkpoint dir."""
        from repro.runtime.fault_tolerance import (
            FaultToleranceConfig,
            TrainController,
        )
        return TrainController(ckpt_dir,
                               FaultToleranceConfig(ckpt_every=every, **kw))

    def lower(self):
        """Lower the step for this shape (dry-run: inspect, then compile)."""
        rt, sc = self.rt, self.shape_cfg
        params = rt.param_shapes()
        batch = rt.input_specs(sc)
        if sc.kind == "train":
            return self.train_step_fn().lower(params, batch)
        prompt = 1 if sc.kind == "decode" else (
            min(sc.seq_len, 448) if self.cfg.encdec else sc.seq_len)
        caches = self.init_caches(abstract=True)
        return self.serve_step_fn(prompt).lower(params, caches, batch)

    # ------------------------------------------------------------------ #
    # Introspection
    # ------------------------------------------------------------------ #

    def describe(self) -> dict:
        """Geometry, schedule-plan and simulated-cost summary.

        Device-free: the schedule numbers come from the discrete-event
        simulator (``core/simulator.py``) under the session's hardware
        cost preset — bubble ratio and gathers/rank are the *timed*
        quantities, not static tick counts. For ``schedule="auto"``
        sessions the dict describes the *selected* plan and lists every
        candidate's simulated makespan under ``schedule.auto``.
        """
        cfg, rc, geo = self.cfg, self.rc, self.geo
        seg = geo.segments[-1]  # "main", or "dec" for enc-dec families
        unit = (rc.unit_size if rc.schedule in UNIT_GATED_SCHEDULES
                else rc.microbatches)
        if self.plan_selection is not None:
            plan = self.plan_selection.selected
            ana = self.plan_selection.analysis
        else:
            plan = SchedulePlan.build(
                rc.schedule,
                SchedParams(P=rc.pp, V=seg.vpp, n_mb=rc.microbatches,
                            unit=unit),
                prefetch=rc.gather_prefetch)
            cm = self._cost_model(seg.vpp)
            ana = plan.analyze(cm if plan.has_w else fused_cost_model(cm),
                               preset=self.spec.cost_preset)
        n_params = sum(int(np.prod(s.shape))
                       for s in M.io_specs(cfg).values())
        for sg in geo.segments:
            n_params += geo.seg_stages(sg) * sum(
                int(np.prod(s.shape))
                for s in M.stage_specs(cfg, sg).values())
        sched: dict[str, Any] = {
            "name": rc.schedule,
            "microbatches": rc.microbatches,
            "unit": unit,
            "ticks": plan.table.T,
            "preset": ana.preset,
            "makespan": ana.makespan,
            "bubble_ratio": ana.bubble_frac,
            "peak_mem": ana.peak_mem,
            "gathers_per_rank": ana.gathers_per_rank,
            "reduces": ana.n_reduce,
            "comm_frac": ana.comm_frac,
        }
        if self.plan_selection is not None:
            sel = self.plan_selection
            sched["auto"] = {
                "selected": sel.selected.name,
                "candidates": {
                    n: (a.makespan if isinstance(a, PlanAnalysis) else
                        str(a))
                    for n, a in sel.candidates.items()},
            }
        return {
            "arch": cfg.name,
            "mode": self.spec.mode,
            "geometry": {
                "pp": rc.pp, "vpp": seg.vpp, "groups": rc.groups,
                "model_ranks": geo.model_ranks,
                "segments": [
                    {"name": sg.name, "layers": sg.n_layers,
                     "stages": geo.seg_stages(sg), "k": sg.k}
                    for sg in geo.segments],
            },
            "schedule": sched,
            "n_params": n_params,
        }

    def __repr__(self):
        return (f"Session({self.cfg.name!r}, mode={self.spec.mode!r}, "
                f"schedule={self.rc.schedule!r}, P={self.rc.pp} "
                f"V={self.rc.vpp} G={self.rc.groups} "
                f"B={self.rc.microbatches} U={self.rc.unit_size})")
