"""The Session facade: one object for train / serve / dry-run.

Replaces the hand-assembled ritual (`get_arch -> replace(RunConfig) ->
build_geometry -> make_mesh -> Runtime -> ShapeConfig -> make_*_step ->
adamw`) that every entry point used to repeat::

    sess = repro.api.session("llama3.2-1b",
                             overrides=dict(microbatches=4, unit=2))
    params = sess.init_params()
    opt = sess.init_opt_state(params)
    grads, metrics = sess.train_step(params, sess.stream().batch(0))
    params, opt, om = sess.opt_step(params, grads, opt)

Heavy state (mesh, Runtime, jitted steps) is built lazily and cached, so
constructing a Session — and calling ``describe()`` — needs no devices.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import numpy as np

from repro.api.spec import SessionError, SessionSpec
from repro.core.generators import SchedParams
from repro.core.pipeline import (
    Runtime,
    init_serve_caches,
    make_serve_step,
    make_train_step,
    serve_cache_pspecs,
)
from repro.core.plan import (
    UNIT_GATED_SCHEDULES,
    PlanAnalysis,
    SchedulePlan,
    fused_cost_model,
    preset_cost_model,
    select_plan,
)
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models import model as M
from repro.models.common import ShapeConfig
from repro.optim import adamw

_OPT_FIELDS = {f.name for f in dataclasses.fields(adamw.AdamWConfig)}


def session(arch: str, *, mode: str = "train", shape=None, overrides=None,
            **kw) -> "Session":
    """Build a validated Session. See SessionSpec for every knob.

    ``schedule="auto"`` (or ``overrides=dict(schedule="auto")``) runs the
    §4 plan selection: every registered schedule plus both autogen
    heuristics (full-depth ``autogen`` and unit-gated ``autogen_gated``)
    is simulated under ``cost_preset`` ("a800" | "tpu_v5e") and the
    minimum-makespan plan is what the session executes; pass
    ``mem_budget=<bytes>`` to cap the simulated peak memory (candidates
    over budget lose to any that fits — the real memory/makespan
    trade-off). The winner and every candidate's simulated
    makespan/peak-mem/stash-depth show in ``describe()``.

    ``schedule="auto_profiled"`` (train mode) runs the same screen, then
    compiles and *times* the ``profile_top_k`` best survivors on the
    live mesh (warmup + median-of-3 real steps, wall-clock capped by
    ``profile_budget_s``) and picks the minimum measured us/call. Both
    auto modes read/write the persisted plan cache
    (``~/.cache/repro/plans.json``, ``REPRO_PLAN_CACHE`` overrides), so
    an identical later session skips the search and the measurements.
    """
    spec = SessionSpec(arch=arch, mode=mode, shape=shape,
                       overrides=dict(overrides or {}), **kw)
    return Session(spec)


class Session:
    """A bound (arch × RunConfig × shape × mesh) with cached step fns."""

    def __init__(self, spec: SessionSpec):
        self.spec = spec.validate()
        self.arch_mod, self.cfg, self.rc = spec.resolve_configs()
        try:
            self.geo = M.build_geometry(self.cfg, self.rc)
        except ValueError as e:
            raise SessionError(
                f"invalid geometry for {spec.arch!r}: {e}. Adjust the "
                "pp/vpp/groups overrides.") from e
        self._mesh = spec.mesh
        self._shape_cfg: ShapeConfig | None = (
            spec.shape if isinstance(spec.shape, ShapeConfig)
            else M.SHAPES[spec.shape] if isinstance(spec.shape, str)
            else None)
        self._data: int | None = spec.data
        # hardware topology (spec.topology): owns the axis layout + the
        # device bootstrap when set; the derived layout is cached because
        # multi_pod/pods_size consult it before any mesh exists.
        from repro.runtime.topology import resolve_topology
        self._topology = resolve_topology(spec.topology)
        self._topo_layout: dict | None = None
        if self._topology is not None and self._topology.data is not None:
            self._data = self._topology.data
        self._fault_tolerance = None    # TrainController (attach()es)
        self._rt: Runtime | None = None
        self._steps: dict[Any, Any] = {}
        # baseline for the per-session kernel-dispatch counters: counts
        # are process-wide and trace-time, so describe() reports deltas
        # accumulated since this session was constructed.
        from repro.kernels import ops as _ops
        self._kernel_counter_base = _ops.kernel_counters()
        # schedule="auto": run the §4 plan selection now (device-free —
        # pure table generation + discrete-event simulation), so the rest
        # of the session sees a concrete schedule name + plan.
        # schedule="auto_profiled" additionally compiles and *times* the
        # top-K simulated survivors on the live mesh (needs devices) and
        # lets the measured us/call pick the winner. Both consult the
        # persisted plan cache first — a warm hit skips everything.
        self.plan_selection = None
        self._plan_source = None   # memory-hit | persisted-hit | search |
        #                            search+measured — THIS construction's
        #                            lookup outcome, for describe()
        self._moe_mode_auto = None  # moe_mode="auto" resolution summary
        self._engine_stats = None   # serving EngineStats (engine attaches)
        if self.rc.schedule in ("auto", "auto_profiled"):
            profiled = self.rc.schedule == "auto_profiled"
            if self.rc.moe_mode == "auto":
                self.plan_selection, mode = self._auto_select_moe(profiled)
                self.rc = dataclasses.replace(self.rc, moe_mode=mode)
            else:
                self.plan_selection = self._auto_select(profiled=profiled)
            self.rc = dataclasses.replace(
                self.rc, schedule=self.plan_selection.selected.name)
        elif self.rc.moe_mode == "auto":
            self.rc = dataclasses.replace(
                self.rc, moe_mode=self._resolve_moe_mode_fixed())

    # ------------------------------------------------------------------ #
    # Lazy distribution state
    # ------------------------------------------------------------------ #

    def _topology_layout(self) -> dict:
        """The topology's derived pods×data×model layout (device-free —
        hardware description + cost-preset rules only). Cached: the
        layout is consulted by multi_pod/pods_size before any mesh
        exists and must agree with the mesh eventually built."""
        if self._topo_layout is None:
            from repro.runtime.topology import TopologyError
            try:
                self._topo_layout = self._topology.axis_layout(
                    self.geo.model_ranks, self.spec.cost_preset)
            except TopologyError as e:
                raise SessionError(str(e)) from e
        return self._topo_layout

    @property
    def multi_pod(self) -> bool:
        if self._topology is not None:
            # the layout may *derive* a pod axis (e.g. the a800 rule
            # confining FSDP to the NVLink island), so judge the derived
            # layout, not the topology's nominal pods field
            return self._topology_layout()["pods"] > 1
        return self.spec.multi_pod or self.spec.pods is not None

    @property
    def pods_size(self) -> int:
        """Width of the hybrid-sharded DP ("pod") axis (1 = no pod
        axis). The topology's derived layout wins over spec.pods."""
        if self._topology is not None:
            return self._topology_layout()["pods"]
        return self.spec.pods or 1

    @property
    def mesh(self):
        if self._mesh is None:
            if self._topology is not None:
                self._topology.ensure_devices()
                lay = self._topology_layout()
                n_dev = jax.device_count()
                if lay["devices_used"] > n_dev:
                    raise SessionError(
                        f"topology {self._topology.label()} lays out "
                        f"pods×data×model = {lay['pods']}×{lay['data']}×"
                        f"{lay['model']} = {lay['devices_used']} devices "
                        f"but the backend provides {n_dev}; shrink the "
                        "topology (data=) or fix the device bootstrap")
                self._mesh = self._topology.build_mesh(
                    self.geo.model_ranks, self.spec.cost_preset)
                self._data = lay["data"]
                return self._mesh
            if self.spec.devices is not None:
                from repro.api.devices import ensure_host_devices
                ensure_host_devices(self.spec.devices)
            if not self.spec.reduced:
                from repro.launch.mesh import make_production_mesh
                self._mesh = make_production_mesh(
                    multi_pod=self.spec.multi_pod)
            else:
                n_dev = jax.device_count()
                model = self.geo.model_ranks
                pods = self.spec.pods or 1
                data = self._data or max(1, n_dev // (pods * model))
                self._data = data
                need = pods * data * model
                if need > n_dev:
                    raise SessionError(
                        f"mesh ({'pods×' if pods > 1 else ''}data×model = "
                        f"{need}) exceeds the {n_dev} available devices; "
                        f"call repro.api.ensure_host_devices({need}) "
                        f"before any other JAX use, or shrink data=/pods=")
                if pods > 1:
                    self._mesh = jax.make_mesh(
                        (pods, data, model), ("pod", "data", "model"))
                else:
                    self._mesh = jax.make_mesh((data, model),
                                               ("data", "model"))
        return self._mesh

    @property
    def data_size(self) -> int:
        if self._data is None:
            self._data = dict(self.mesh.shape)["data"]
        return self._data

    @property
    def shape_cfg(self) -> ShapeConfig:
        if self._shape_cfg is None:
            sp = self.spec
            if sp.mode == "serve":
                gb = sp.global_batch or sp.max_slots or 8
                self._shape_cfg = ShapeConfig("serve", sp.max_seq, gb,
                                              "decode")
            else:
                gb = sp.global_batch or (
                    self.pods_size * self.data_size * self.rc.groups
                    * self.rc.microbatches * sp.microbatch_size)
                self._shape_cfg = ShapeConfig(sp.mode, sp.seq_len or 32,
                                              gb, "train")
        return self._shape_cfg

    @property
    def rt(self) -> Runtime:
        """The underlying pipeline Runtime (built on first use). An
        auto-selected plan is injected so the Runtime executes exactly
        the table the selection simulated."""
        if self._rt is None:
            self._rt = Runtime(
                self.cfg, self.rc, self.mesh, multi_pod=self.multi_pod,
                plan=(self.plan_selection.selected
                      if self.plan_selection is not None else None))
        return self._rt

    # ------------------------------------------------------------------ #
    # Schedule-plan selection (schedule="auto")
    # ------------------------------------------------------------------ #

    def _cost_shape(self) -> tuple[int, int, int]:
        """(seq, mbs, dp) for the cost model — device-free: prefers the
        explicit shape/spec values, never forces a mesh build."""
        if self._shape_cfg is not None:
            seq = self._shape_cfg.seq_len
        else:
            seq = self.spec.seq_len or self.spec.max_seq or 32
        if self._data is not None:
            dp = self._data
        elif self.spec.mesh is not None:
            dp = dict(self.spec.mesh.shape).get("data", 1)
        else:
            # data axis not yet known and we must stay device-free: a
            # dp=1 guess would cost every FSDP gather/reduce at zero
            # ((dp-1)/dp = 0) and bias the selection toward
            # collective-heavy schedules, so assume the demo/CI mesh
            # width instead ((dp-1)/dp is within 15% of its asymptote
            # from dp=8 on, so the exact guess barely matters).
            dp = 8
        return seq, self.spec.microbatch_size, dp

    def _coll_counts(self, seg, moe_mode: str | None = None
                     ) -> tuple[int, int]:
        """(per-gather-tick, per-reduce-tick) collective counts for the
        α–β cost model — 1 each under the flat-segment layout, the
        gatherable tensor count under per-tensor collectives. Device-free:
        divisibility is judged against the cost-shape dp guess."""
        if self.rc.serve_resident:
            return 0, 0  # weight-resident: no FSDP collectives at all
        _, _, dp = self._cost_shape()
        mode = moe_mode if moe_mode is not None else self.rc.moe_mode
        ep = mode == "ep" and self.cfg.moe is not None
        specs = M.stage_specs(self.cfg, seg)
        n_gath = n_repl = 0
        for n, sp in specs.items():
            if sp.ep and ep:
                continue  # EP tensors never enter the FSDP collectives
            if sp.shape and sp.shape[sp.fsdp_dim] % dp == 0:
                n_gath += 1
            else:
                n_repl += 1  # replicated: psum'd per tensor on reduce
        if n_gath == 0:
            return 0, n_repl
        if self.rc.coalesce == "flat":
            return 1, 1 + n_repl
        return n_gath, n_gath + n_repl

    def _moe_layers_per_stage(self, seg) -> float:
        """Mean MoE layers per pipeline stage of ``seg`` (0 without MoE)."""
        if self.cfg.moe is None:
            return 0.0
        n_moe = sum(1 for i in range(self.cfg.n_layers)
                    if self.cfg.layer_kind(i).endswith(":moe"))
        n_stages = max(self.geo.seg_stages(seg), 1)
        return n_moe / n_stages

    def _a2a_workload(self, seg, moe_mode: str | None = None
                      ) -> tuple[int, int, float]:
        """(n_a2a_f, n_a2a_b, a2a_bytes) of one stage tick under EP.

        dispatch + combine per MoE layer in F; B re-runs the forward
        pair under remat and pays the backward pair, so 4 (2 without
        remat). Bytes = one event's wire traffic: the [E, capacity, d]
        dispatch buffer's off-rank fraction (dp-1)/dp at the compute
        dtype. All zeros unless EP MoE is active — gathered MoE moves
        tokens locally and pays the FSDP gathers instead."""
        mode = moe_mode if moe_mode is not None else self.rc.moe_mode
        mo = self.cfg.moe
        if mo is None or mode != "ep":
            return 0, 0, 0.0
        from repro.models.blocks import _capacity

        seq, mbs, dp = self._cost_shape()
        m = self._moe_layers_per_stage(seg)
        if m <= 0:
            return 0, 0, 0.0
        cap = _capacity(seq * mbs, mo)
        dtype_bytes = 2 if "16" in self.rc.compute_dtype else 4
        a2a_bytes = (mo.n_experts * cap * self.cfg.d_model * dtype_bytes
                     * (dp - 1) / max(dp, 1))
        n_f = max(1, round(2 * m))
        n_b = max(1, round((4 if self.rc.remat else 2) * m))
        return n_f, n_b, a2a_bytes

    def _moe_gather_bytes(self, seg, moe_mode: str | None = None) -> float:
        """Extra per-tick FSDP gather/reduce bytes the *gathered* MoE
        mode pays for expert tensors (EP never gathers them)."""
        mode = moe_mode if moe_mode is not None else self.rc.moe_mode
        mo = self.cfg.moe
        if mo is None or mode == "ep":
            return 0.0
        m = self._moe_layers_per_stage(seg)
        dtype_bytes = 2 if "16" in self.rc.param_dtype else 4
        return 3 * mo.n_experts * self.cfg.d_model * mo.d_ff_expert \
            * m * dtype_bytes

    def _cost_model(self, vpp: int, moe_mode: str | None = None):
        seq, mbs, dp = self._cost_shape()
        seg = self.geo.segments[-1]
        n_g, n_r = self._coll_counts(seg, moe_mode)
        n_a2a_f, n_a2a_b, a2a_bytes = self._a2a_workload(seg, moe_mode)
        return preset_cost_model(
            self.spec.cost_preset, self.cfg, P=self.rc.pp, V=vpp,
            seq=seq, mbs=mbs, dp=dp,
            n_coll_gather=n_g, n_coll_reduce=n_r,
            n_a2a_f=n_a2a_f, n_a2a_b=n_a2a_b, a2a_bytes=a2a_bytes,
            extra_stage_param_bytes=self._moe_gather_bytes(seg, moe_mode))

    def _auto_select(self, profiled: bool = False,
                     moe_mode: str | None = None):
        """Simulate every registered schedule (+ the §4 autogen heuristic)
        for this (arch × shape × mesh) and pick the minimum-makespan plan
        — or, ``profiled``, the minimum *measured* us/call among the
        top-K simulated survivors. Selections are cached process-wide on
        the key below AND persisted on disk (``core/plan_cache.py``), so
        an identical later session — this process or the next — pays
        zero simulate and zero measure calls."""
        from repro.core.plan import plan_cache_info

        rc = self.rc
        seg = self.geo.segments[-1]
        seq, mbs, dp = self._cost_shape()
        preset = self.spec.cost_preset
        mode = moe_mode if moe_mode is not None else rc.moe_mode
        # component order mirrors plan.SELECT_KEY_SCHEMA (part of the
        # persisted-cache fingerprint)
        cache_key = (
            self.cfg.name, rc.pp, seg.vpp, rc.groups, rc.microbatches,
            rc.unit_size, rc.gather_prefetch, seq, mbs, dp,
            self.spec.pods or 1, preset, rc.coalesce, rc.grad_compress,
            mode, self.spec.mem_budget, rc.schedule,
            self.spec.profile_top_k if profiled else None,
        )
        self._plan_key = cache_key
        before = plan_cache_info()
        sel = select_plan(
            rc.pp, seg.vpp, rc.microbatches, rc.unit_size,
            self._cost_model(seg.vpp, mode), preset=preset,
            prefetch=rc.gather_prefetch, cache_key=cache_key,
            mem_budget=self.spec.mem_budget,
            measure_fn=self._build_measure_fn(mode) if profiled else None,
            top_k=self.spec.profile_top_k,
            profile_budget_s=self.spec.profile_budget_s,
            persist=True)
        after = plan_cache_info()
        if after["hits"].get(cache_key, 0) > \
                before["hits"].get(cache_key, 0):
            self._plan_source = "memory-hit"
        elif after["disk_hits"].get(cache_key, 0) > \
                before["disk_hits"].get(cache_key, 0):
            self._plan_source = "persisted-hit"
        else:
            self._plan_source = sel.provenance
        return sel

    def _auto_select_moe(self, profiled: bool = False):
        """``moe_mode="auto"`` × ``schedule="auto"``: run the §4 plan
        selection once per MoE mode (each with its own mode-bearing
        cache key and a2a/gather cost model) and let the better selected
        makespan — measured us/call when profiled — pick the mode.
        Returns ``(selection, mode)`` with the loser's candidates merged
        in under ``"<mode>:<schedule>"`` keys so describe()/launch can
        rank EP vs gathered rows side by side."""
        if self.cfg.moe is None:
            return self._auto_select(profiled, "gathered"), "gathered"
        sels: dict[str, Any] = {}
        keys: dict[str, Any] = {}
        for mode in ("gathered", "ep"):
            sels[mode] = self._auto_select(profiled, mode)
            keys[mode] = self._plan_key

        def _score(sel):
            if sel.measured:
                return min(sel.measured.values())
            return sel.analysis.makespan

        mode = min(sels, key=lambda m: _score(sels[m]))
        self._plan_key = keys[mode]
        self._moe_mode_auto = {
            "resolved": mode,
            "scores": {m: _score(s) for m, s in sels.items()},
            "selected": {m: s.selected.name for m, s in sels.items()},
        }
        merged = dataclasses.replace(
            sels[mode],
            candidates={f"{m}:{n}": a
                        for m in ("gathered", "ep")
                        for n, a in sels[m].candidates.items()})
        return merged, mode

    def _resolve_moe_mode_fixed(self) -> str:
        """``moe_mode="auto"`` under a *fixed* schedule: analyze that one
        schedule's table under each mode's cost model (EP pays costed
        a2a ticks, gathered pays the expert tensors' FSDP collective
        bytes) and keep the smaller simulated makespan."""
        if self.cfg.moe is None:
            return "gathered"
        rc = self.rc
        seg = self.geo.segments[-1]
        unit = (rc.unit_size if rc.schedule in UNIT_GATED_SCHEDULES
                else rc.microbatches)
        plan = SchedulePlan.build(
            rc.schedule,
            SchedParams(P=rc.pp, V=seg.vpp, n_mb=rc.microbatches,
                        unit=unit),
            prefetch=rc.gather_prefetch)
        scores = {}
        for mode in ("gathered", "ep"):
            cm = self._cost_model(seg.vpp, mode)
            ana = plan.analyze(cm if plan.has_w else fused_cost_model(cm),
                               preset=self.spec.cost_preset)
            scores[mode] = ana.makespan
        mode = min(scores, key=scores.get)
        self._moe_mode_auto = {"resolved": mode, "scores": scores,
                               "selected": {m: rc.schedule for m in scores}}
        return mode

    def _build_measure_fn(self, moe_mode: str | None = None):
        """The auto_profiled fine pass: ``measure_fn(plan) -> us/call``.

        Each candidate gets its own Runtime (same mesh, same params —
        parameter layout does not depend on the schedule) with the plan
        injected, its train step jitted, and one warmup + median-of-3
        timed steps through ``repro.timing``. Only *called* on a cache
        miss, so warm sessions never compile a step during selection.
        """
        from repro.timing import measure_us

        state: dict[str, Any] = {}

        def _measure(plan: SchedulePlan) -> float:
            rc = dataclasses.replace(
                self.rc, schedule=plan.name,
                **({"moe_mode": moe_mode} if moe_mode else {}))
            rt = Runtime(self.cfg, rc, self.mesh,
                         multi_pod=self.multi_pod, plan=plan)
            step = make_train_step(rt, self.shape_cfg)
            if "params" not in state:
                state["params"] = rt.init_params(jax.random.PRNGKey(0))
                state["batch"] = self.stream(seed=0).batch(0)
            return measure_us(
                lambda: step(state["params"], state["batch"]),
                warmup=1, iters=3)

        return _measure

    # ------------------------------------------------------------------ #
    # Parameters / optimizer
    # ------------------------------------------------------------------ #

    def init_params(self, key=None):
        return self.rt.init_params(key)

    def param_shapes(self):
        return self.rt.param_shapes()

    def input_specs(self, max_seq=None):
        return self.rt.input_specs(self.shape_cfg, max_seq=max_seq)

    def opt_config(self):
        """(AdamWConfig, use_lr_schedule, warmup, total) from spec.optim."""
        kw = dict(self.spec.optim)
        use_sched = "warmup" in kw or "total" in kw
        warmup = kw.pop("warmup", 100)
        total = kw.pop("total", 10_000)
        bad = sorted(set(kw) - _OPT_FIELDS)
        if bad:
            raise SessionError(
                f"unknown optim option(s) {bad}; valid: warmup, total, "
                f"{', '.join(sorted(_OPT_FIELDS))}")
        kw.setdefault("moment_dtype", self.rc.opt_moment_dtype)
        return adamw.AdamWConfig(**kw), use_sched, warmup, total

    def init_opt_state(self, params):
        return adamw.init_state(params, self.opt_config()[0])

    def opt_step_fn(self):
        if "opt" not in self._steps:
            opt_cfg, use_sched, warmup, total = self.opt_config()

            # params and opt state are consumed and replaced every step:
            # donate both so the updated trees reuse their buffers (no
            # transient 2× params + 2× moments residency). Callers follow
            # the rebind pattern (``params, opt, om = sess.opt_step(...)``).
            @partial(jax.jit, donate_argnums=(0, 2))
            def _opt(params, grads, opt_state):
                scale = adamw.lr_schedule(
                    opt_state["step"], base_lr=1.0, warmup=warmup,
                    total=total) if use_sched else 1.0
                return adamw.apply_updates(params, grads, opt_state,
                                           opt_cfg, scale)

            self._steps["opt"] = _opt
        return self._steps["opt"]

    def opt_step(self, params, grads, opt_state):
        """One AdamW update; returns (params, opt_state, metrics)."""
        return self.opt_step_fn()(params, grads, opt_state)

    # ------------------------------------------------------------------ #
    # Training
    # ------------------------------------------------------------------ #

    def train_step_fn(self):
        if "train" not in self._steps:
            if self.shape_cfg.kind != "train":
                raise SessionError(
                    f"train_step needs a 'train' shape; this session is "
                    f"{self.shape_cfg.kind!r} ({self.shape_cfg.name})")
            self._steps["train"] = make_train_step(self.rt, self.shape_cfg)
        return self._steps["train"]

    def train_step(self, params, batch):
        """One pipeline step; returns (grads, metrics)."""
        return self.train_step_fn()(params, batch)

    # ------------------------------------------------------------------ #
    # Serving
    # ------------------------------------------------------------------ #

    def _max_seq(self) -> int:
        return self.spec.max_seq or self.shape_cfg.seq_len

    # ---- paged-KV geometry ------------------------------------------- #

    @property
    def paged(self) -> bool:
        """True when the serve caches are paged (spec.page_size set)."""
        return self.spec.page_size is not None

    @property
    def page_size(self) -> int:
        return self.spec.page_size or 0

    @property
    def pages_per_slot(self) -> int:
        """Worst-case pages one request can span (max_seq / page_size)."""
        return self._max_seq() // self.spec.page_size

    @property
    def n_pages(self) -> int:
        """Total page count (spec.max_pages, else the contiguous-cache
        footprint max_slots × max_seq/page_size — same bytes, but pages
        only *fill* with tokens actually written)."""
        if self.spec.max_pages is not None:
            return self.spec.max_pages
        return self.max_slots * self.pages_per_slot

    def serve_step_fn(self, prompt_len: int, want_logits: bool = False):
        key = ("serve", prompt_len, want_logits)
        if key not in self._steps:
            self._steps[key] = make_serve_step(
                self.rt, self.shape_cfg, prompt_len=prompt_len,
                max_seq=self._max_seq(), page_size=self.page_size,
                want_logits=want_logits)
        return self._steps[key]

    def init_caches(self, abstract: bool = False):
        if self.paged and self.cfg.encdec is not None:
            raise SessionError(
                "paged KV serving does not cover encoder-decoder "
                "sessions (enc_memory has no page layout) — drop "
                "page_size")
        return init_serve_caches(self.rt, self.shape_cfg,
                                 max_seq=self._max_seq(),
                                 abstract=abstract,
                                 page_size=self.page_size,
                                 n_pages=self.n_pages if self.paged
                                 else 0)

    def serve_prefill(self, params, caches, batch):
        """Run the prompt through the pipeline; returns (tokens, caches)."""
        if self.paged:
            raise SessionError(
                "paged sessions serve through the slotted path "
                "(serve_step_batched / serve_engine); the scalar-pos "
                "serve_prefill has no page tables")
        prompt = batch["tokens"].shape[1]
        return self.serve_step_fn(prompt)(params, caches, batch)

    def serve_decode(self, params, caches, batch):
        """One cached decode step; returns (tokens, caches)."""
        if self.paged:
            raise SessionError(
                "paged sessions serve through the slotted path "
                "(serve_step_batched / serve_engine); the scalar-pos "
                "serve_decode has no page tables")
        return self.serve_step_fn(1)(params, caches, batch)

    # ---- slot-aware (continuous-batching) serving -------------------- #

    @property
    def max_slots(self) -> int:
        """Serving slot count == the serve-mode global batch."""
        return self.shape_cfg.global_batch

    def serve_step_batched(self, params, caches, batch,
                           want_logits: bool = False):
        """One slot-aware step (prefill chunk s>=1 or decode s==1).

        Unlike :meth:`serve_prefill`/:meth:`serve_decode`, ``batch`` is
        per-slot: ``pos`` is an int32 ``[max_slots]`` vector (each slot's
        first absolute position) and the optional ``slot_mask`` bool
        ``[max_slots]`` gates cache writes so a prefill into one slot
        cannot clobber a neighbouring in-flight request. Paged sessions
        additionally carry ``page_tables`` (int32
        ``[max_slots, pages_per_slot]`` shard-local page ids). Returns
        ``(tokens[max_slots], caches)`` — or, with ``want_logits``,
        ``(tokens, logits[max_slots, vocab], caches)`` for the host-side
        sampling layer. Rows outside ``slot_mask`` carry garbage samples
        the caller ignores. With ``RunConfig.moe_stats`` on an MoE
        segment, one extra trailing ``{"load", "dropped"}`` dict is
        appended (per-layer-row expert-load histogram + capacity drops).
        """
        pos = batch.get("pos")
        if getattr(pos, "ndim", 0) != 1:
            raise SessionError(
                "serve_step_batched needs batch['pos'] as a per-slot "
                f"[{self.max_slots}] int32 vector (got "
                f"{getattr(pos, 'shape', None)}); use serve_prefill/"
                "serve_decode for the scalar-pos path")
        if self.paged and batch.get("page_tables") is None:
            raise SessionError(
                "paged sessions need batch['page_tables'] (int32 "
                f"[{self.max_slots}, {self.pages_per_slot}] shard-local "
                "page ids; see PagedSlotPool.page_table_matrix)")
        self.check_slot_sharding()
        s = batch["tokens"].shape[1]
        return self.serve_step_fn(s, want_logits)(params, caches, batch)

    def check_slot_sharding(self) -> None:
        """The slotted (per-slot pos) path needs a batch-sharded cache
        AND a micro-batch tiling that covers every slot row — rows
        beyond the tiling would silently never be computed. The
        spec-level check only fires when ``data=`` is explicit, so
        re-check against the materialized mesh (covers derived axes).
        Session-invariant, so the result is cached."""
        if self._steps.get("slot_sharding_ok"):
            return
        from repro.core.pipeline import serve_tiling

        shards = self.pods_size * self.data_size
        if self.max_slots % shards != 0:
            raise SessionError(
                f"max_slots ({self.max_slots}) must divide evenly over "
                f"the pods×data axes ({shards}) for the slotted serve "
                "path — round max_slots up or shrink data=/pods=")
        b_loc, Btot, mbs = serve_tiling(self.rt, self.max_slots,
                                        seq_shard=False)
        covered = self.rt.G * Btot * mbs
        if covered != b_loc:
            raise SessionError(
                f"max_slots ({self.max_slots}) gives {b_loc} slot rows "
                f"per data shard, but the serve step tiles them as "
                f"groups×microbatches×mbs = {self.rt.G}×{Btot}×{mbs}, "
                f"covering only {covered} — pick max_slots so "
                f"slots/(pods·data) is a multiple of "
                f"groups·min(microbatches, slots/(pods·data)), or "
                "adjust the microbatches override")
        self._steps["slot_sharding_ok"] = True

    def reset_slot_caches(self, caches, slot_mask):
        """Zero the cache rows of the slots flagged in ``slot_mask``
        (slot reclaim: recurrent state and stale bytes must not leak
        into the next request)."""
        if "slot_reset" not in self._steps:
            from repro.core.pipeline import reset_slot_caches
            self._steps["slot_reset"] = jax.jit(reset_slot_caches,
                                                donate_argnums=(0,))
        return self._steps["slot_reset"](caches, slot_mask)

    def reset_pages(self, caches, page_mask):
        """Zero the pages flagged in ``page_mask`` [n_pages] (paged
        analogue of :meth:`reset_slot_caches`: a request's *fresh* pages
        must read as zeros; shared prefix pages keep their contents)."""
        if "page_reset" not in self._steps:
            from repro.core.pipeline import reset_pages
            self._steps["page_reset"] = jax.jit(reset_pages,
                                                donate_argnums=(0,))
        return self._steps["page_reset"](caches, page_mask)

    def copy_pages(self, caches, src, dst):
        """Copy page ``src[i]`` -> ``dst[i]`` (int32 [w] global ids) in
        every paged leaf — cross-partition prefix reuse. Callers keep
        ``w`` fixed (pad by repeating the first pair) so this compiles
        once."""
        if "page_copy" not in self._steps:
            from repro.core.pipeline import copy_pages
            self._steps["page_copy"] = jax.jit(copy_pages,
                                               donate_argnums=(0,))
        return self._steps["page_copy"](caches, src, dst)

    def sampling_unsupported_reason(self) -> str | None:
        """None when the serve step can return full next-token logits
        (the host-side sampling layer's input); otherwise why it cannot.
        The engine checks this once and rejects ``temperature > 0``
        submissions up front, so the ``make_serve_step`` layout guards
        never fire mid-tick against an already-admitted request."""
        if self.rt.multi_pod:
            reason = "logits return is not wired for multi-pod meshes"
            if self._topology is not None:
                reason += f" (topology: {self._topology.label()})"
            elif self.spec.pods:
                reason += f" (pods={self.spec.pods})"
            return reason
        _, seq_shard, _ = serve_cache_pspecs(self.rt, self.shape_cfg)
        if seq_shard:
            return ("the sequence-sharded serve layout cannot return "
                    "per-slot logits (needs a slot count divisible by "
                    "the pods×data axes)")
        return None

    def serve_engine(self, params, **kw):
        """A continuous-batching :class:`repro.serving.ServeEngine` over
        this session (serve mode only)."""
        from repro.serving import ServeEngine
        return ServeEngine(self, params, **kw)

    # ------------------------------------------------------------------ #
    # Data / checkpointing / dry-run
    # ------------------------------------------------------------------ #

    def stream(self, seed: int = 0) -> SyntheticStream:
        cfg, sc = self.cfg, self.shape_cfg
        return SyntheticStream(DataConfig(
            seq_len=sc.seq_len, global_batch=sc.global_batch,
            vocab=cfg.vocab, seed=seed,
            kind=("enc_dec" if cfg.encdec else
                  "vision" if cfg.frontend == "vision" else "lm"),
            d_model=cfg.d_model,
            enc_ctx=cfg.encdec.enc_ctx if cfg.encdec else 0))

    def checkpointing(self, ckpt_dir: str, *, every: int = 50, **kw):
        """A fault-tolerance TrainController over this checkpoint dir."""
        from repro.runtime.fault_tolerance import (
            FaultToleranceConfig,
            TrainController,
        )
        return TrainController(ckpt_dir,
                               FaultToleranceConfig(ckpt_every=every, **kw))

    def restore_params(self, ckpt_dir: str, *, step: int | None = None):
        """Boot this session's params from a train checkpoint
        (train→serve handoff).

        Accepts checkpoints whose tree either *is* the params tree
        (``{"io": ..., "segments": ...}``) or nests it under a ``params``
        key (the fault-tolerance controller's usual state layout). The
        restored arrays are re-laid-out onto THIS session's mesh and
        shardings — a serve session may use a different data axis, dtype
        or schedule than the trainer that wrote the checkpoint; only the
        pipeline geometry (pp × vpp × groups stacking) must match, and a
        mismatch raises with the offending leaf.
        """
        from repro.ckpt.checkpoint import CheckpointManager

        mgr = CheckpointManager(ckpt_dir)
        tree, manifest = mgr.restore(step)
        if tree is None:
            raise SessionError(
                f"no checkpoint found under {ckpt_dir!r} "
                f"(steps: {mgr.list_steps()})")
        return self.adopt_params(tree)

    def adopt_params(self, tree):
        """Re-lay-out a host-side (or foreign-mesh) params tree onto THIS
        session's mesh and shardings — the relayout half of
        :meth:`restore_params`, also the elastic path: a reshard/restart
        pulls the old session's params to host and adopts them here.
        Accepts the params tree directly or nested under ``"params"``;
        leaf shapes must match (geometry mismatch raises with the leaf).
        """
        if "params" in tree and "io" not in tree:
            tree = tree["params"]
        if not ("io" in tree and "segments" in tree):
            raise SessionError(
                f"params tree has keys {sorted(tree)}; expected 'io' and "
                "'segments' (or a tree nested under 'params')")
        shapes = self.param_shapes()
        flat_want = dict(jax.tree_util.tree_flatten_with_path(shapes)[0])
        flat_got = dict(jax.tree_util.tree_flatten_with_path(
            {"io": tree["io"], "segments": tree["segments"]})[0])
        missing = sorted(set(map(jax.tree_util.keystr, flat_want))
                         - set(map(jax.tree_util.keystr, flat_got)))
        if missing:
            raise SessionError(
                f"checkpoint is missing param leaves {missing[:5]}"
                f"{'...' if len(missing) > 5 else ''} — was it written by "
                "a different architecture?")
        out_flat = {}
        for kp, want in flat_want.items():
            got = flat_got[kp]
            if tuple(got.shape) != tuple(want.shape):
                raise SessionError(
                    f"param {jax.tree_util.keystr(kp)} has shape "
                    f"{tuple(got.shape)} in the checkpoint but this "
                    f"session needs {tuple(want.shape)} — the pipeline "
                    "geometry (pp/vpp/groups) must match the trainer's")
            # host -> sharded directly; never commit a full leaf to one
            # device (large train checkpoints exceed a single device)
            out_flat[kp] = jax.device_put(
                np.asarray(got, want.dtype), want.sharding)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(shapes), [
                out_flat[kp] for kp, _ in
                jax.tree_util.tree_flatten_with_path(shapes)[0]])

    def with_topology(self, topology) -> "Session":
        """A fresh Session of this spec bound to ``topology`` — the
        elastic rebuild (train restart on a shrunk mesh, serve reshard).
        The explicit axis knobs reset: the new topology owns the layout.
        Heavy state (mesh, Runtime, jitted steps) is rebuilt lazily; use
        :meth:`adopt_params` to carry params across."""
        spec = dataclasses.replace(
            self.spec, topology=topology, data=None, pods=None,
            multi_pod=False, devices=None, mesh=None)
        return Session(spec)

    def lower(self):
        """Lower the step for this shape (dry-run: inspect, then compile)."""
        rt, sc = self.rt, self.shape_cfg
        params = rt.param_shapes()
        batch = rt.input_specs(sc)
        if sc.kind == "train":
            return self.train_step_fn().lower(params, batch)
        prompt = 1 if sc.kind == "decode" else (
            min(sc.seq_len, 448) if self.cfg.encdec else sc.seq_len)
        caches = self.init_caches(abstract=True)
        return self.serve_step_fn(prompt).lower(params, caches, batch)

    # ------------------------------------------------------------------ #
    # Introspection
    # ------------------------------------------------------------------ #

    def describe(self) -> dict:
        """Geometry, schedule-plan and simulated-cost summary.

        Device-free: the schedule numbers come from the discrete-event
        simulator (``core/simulator.py``) under the session's hardware
        cost preset — bubble ratio and gathers/rank are the *timed*
        quantities, not static tick counts. For ``schedule="auto"``
        sessions the dict describes the *selected* plan and lists every
        candidate's simulated makespan under ``schedule.auto``.
        """
        cfg, rc, geo = self.cfg, self.rc, self.geo
        seg = geo.segments[-1]  # "main", or "dec" for enc-dec families
        unit = (rc.unit_size if rc.schedule in UNIT_GATED_SCHEDULES
                else rc.microbatches)
        if self.plan_selection is not None:
            plan = self.plan_selection.selected
            ana = self.plan_selection.analysis
        else:
            plan = SchedulePlan.build(
                rc.schedule,
                SchedParams(P=rc.pp, V=seg.vpp, n_mb=rc.microbatches,
                            unit=unit),
                prefetch=rc.gather_prefetch)
            cm = self._cost_model(seg.vpp)
            ana = plan.analyze(cm if plan.has_w else fused_cost_model(cm),
                               preset=self.spec.cost_preset)
        n_params = sum(int(np.prod(s.shape))
                       for s in M.io_specs(cfg).values())
        for sg in geo.segments:
            n_params += geo.seg_stages(sg) * sum(
                int(np.prod(s.shape))
                for s in M.stage_specs(cfg, sg).values())
        from repro.core.plan import COLLECTIVE_ALPHA_BETA
        alpha, beta = COLLECTIVE_ALPHA_BETA[self.spec.cost_preset]
        a2a_alpha, a2a_beta = COLLECTIVE_ALPHA_BETA.get(
            f"{self.spec.cost_preset}:a2a", (2 * alpha, beta))
        n_g, n_r = self._coll_counts(seg)
        n_a2a_f, n_a2a_b, a2a_bytes = self._a2a_workload(seg)
        sched: dict[str, Any] = {
            "name": rc.schedule,
            "microbatches": rc.microbatches,
            "unit": unit,
            "ticks": plan.table.T,
            "preset": ana.preset,
            "makespan": ana.makespan,
            "bubble_ratio": ana.bubble_frac,
            "peak_mem": ana.peak_mem,
            "gathers_per_rank": ana.gathers_per_rank,
            "reduces": ana.n_reduce,
            "comm_frac": ana.comm_frac,
            "prefetch": rc.gather_prefetch,
            # unit-gated executor buffers: the stash depth this plan's
            # tables actually claim (U for zeropp/autogen_gated, n_mb
            # for full-depth schedules).
            "stash_depth": plan.table.unit,
            # reduce-scatter overlap accounting: exposed = critical-path
            # reduce time; saved = the worst rank's reduce time hidden
            # under the next unit's B/W compute.
            "rs_overlap": {
                "exposed_s": ana.rs_exposed,
                "saved_s": ana.rs_overlap_saved,
            },
            # α–β collective profile: per-tick counts under the session's
            # coalesce mode, with the calibrated preset constants.
            "collectives": {
                "coalesce": rc.coalesce,
                "per_gather_tick": n_g,
                "per_reduce_tick": n_r,
                "alpha_s": alpha,
                "beta_s_per_byte": beta,
                # EP MoE all-to-all profile: events per F/B tick (0 in
                # gathered mode), one event's wire bytes, the a2a α–β
                # constants, and the plan's simulated a2a totals.
                "moe_mode": rc.moe_mode,
                "a2a_per_f_tick": n_a2a_f,
                "a2a_per_b_tick": n_a2a_b,
                "a2a_bytes": a2a_bytes,
                "a2a_alpha_s": a2a_alpha,
                "a2a_beta_s_per_byte": a2a_beta,
                "a2a_t_event_s": ana.t_a2a,
                "a2a_total_s": ana.a2a_total,
            },
        }
        if self._moe_mode_auto is not None:
            sched["moe_mode_auto"] = dict(self._moe_mode_auto)
        if self.plan_selection is not None:
            sel = self.plan_selection

            def _cand(a):
                if not isinstance(a, PlanAnalysis):
                    return str(a)
                d = {"makespan": a.makespan,
                     "peak_mem": a.peak_mem,
                     "stash_depth": a.stash_depth,
                     "rs_overlap_saved": a.rs_overlap_saved}
                # measured us/call rides along only for the profiled
                # survivors — simulated-only candidates keep the
                # established 4-key shape.
                if a.measured_us is not None:
                    d["measured_us"] = a.measured_us
                # EP candidates carry their simulated a2a share (0-cost
                # candidates — gathered/dense — keep the base shape)
                if a.a2a_total > 0:
                    d["a2a_total"] = a.a2a_total
                return d

            sched["auto"] = {
                "selected": sel.selected.name,
                "mem_budget": sel.mem_budget,
                # hit/miss/refine provenance: how the *selection object*
                # came to be (search | search+measured | cache:disk) and
                # what THIS construction's lookup did (memory-hit |
                # persisted-hit | a fresh search).
                "provenance": {"selection": sel.provenance,
                               "this_session": self._plan_source},
                # per-candidate memory/makespan trade-off: stash depth,
                # simulated peak memory and reduce-overlap savings ride
                # along with the makespan each candidate was ranked on.
                "candidates": {n: _cand(a)
                               for n, a in sel.candidates.items()},
            }
            if sel.measured:
                sched["auto"]["measured"] = dict(sel.measured)
            if sel.profile:
                sched["auto"]["profile"] = dict(sel.profile)
            # persisted + in-memory plan-cache state (per-key hit counts,
            # simulate/measure work counters) for this session's key
            from repro.core.plan import plan_cache_info
            info = plan_cache_info()
            key = getattr(self, "_plan_key", None)
            sched["cache"] = {
                "key": repr(key),
                "hits": info["hits"].get(key, 0),
                "disk_hits": info["disk_hits"].get(key, 0),
                "misses": info["misses"],
                "simulate_calls": info["simulate_calls"],
                "measure_calls": info["measure_calls"],
                "entries": info["entries"],
                "persisted": info["persisted"],
            }
        out = {
            "arch": cfg.name,
            "mode": self.spec.mode,
            # jit buffer-donation audit: which step inputs alias their
            # outputs (no spurious full-size copies). The train step's
            # carry lives inside its scan; params are reused by opt_step
            # and must NOT be donated there.
            "donation": {
                "opt_step": ["params", "opt_state"],
                "serve_step": ["caches"],
                "reset_slot_caches": ["caches"],
                "reset_pages": ["caches"],
                "copy_pages": ["caches"],
                "train_step": [],
            },
            "geometry": {
                "pp": rc.pp, "vpp": seg.vpp, "groups": rc.groups,
                "model_ranks": geo.model_ranks,
                "segments": [
                    {"name": sg.name, "layers": sg.n_layers,
                     "stages": geo.seg_stages(sg), "k": sg.k}
                    for sg in geo.segments],
            },
            "schedule": sched,
            "kernels": self._kernel_report(),
            "n_params": n_params,
            "topology": self._topology_report(),
        }
        if self._engine_stats is not None:
            out["serving"] = self._serving_report()
        if self._fault_tolerance is not None:
            out["fault_tolerance"] = self._fault_tolerance.summary()
        return out

    def _topology_report(self) -> dict:
        """``describe()["topology"]`` — the resolved hardware + axis
        layout. Device-free: the topology path derives the layout from
        the hardware description; the legacy-knob path reports what the
        spec pinned (data may be None until a mesh materializes)."""
        if self._topology is not None:
            return self._topology.describe(self.geo.model_ranks,
                                           self.spec.cost_preset)
        return {
            "kind": None,
            "name": None,
            "layout": {"pods": self.spec.pods or 1,
                       "data": self._data,
                       "model": self.geo.model_ranks},
        }

    def _serving_report(self) -> dict:
        """Engine-side counters for ``describe()["serving"]`` — present
        once a :meth:`serve_engine` has attached its stats: throughput
        counters plus the capacity-admission (deferral / projected
        hot-expert overflow) and dispatch-observability (per-layer
        expert-load histogram, dropped-token) counters."""
        st = self._engine_stats
        out = dataclasses.asdict(st)
        moe = getattr(st, "moe", None)
        if moe is not None:
            out["moe"] = moe.as_dict()
        return out

    def _kernel_report(self) -> dict:
        """Kernel-dispatch summary for ``describe()["kernels"]``.

        ``counters`` are per-session deltas of the trace-time dispatch
        counters (one count per traced call site, not per executed
        step); ``fallbacks`` isolates the calls where Pallas was
        selected but the shape/backend combination still forced the
        reference path — after the slot-aware kernel this should stay
        empty on the serving hot path.
        """
        from repro.kernels import ops as _ops
        now = _ops.kernel_counters()
        base = self._kernel_counter_base
        delta = {k: v - base.get(k, 0) for k, v in now.items()
                 if v - base.get(k, 0) > 0}
        return {
            "impl": self.rc.kernel_impl or "auto",
            "kv_cache_dtype": self.rc.kv_cache_dtype or "compute",
            "counters": delta,
            "fallbacks": {k: v for k, v in delta.items()
                          if k.startswith("fallback_")},
        }

    def __repr__(self):
        return (f"Session({self.cfg.name!r}, mode={self.spec.mode!r}, "
                f"schedule={self.rc.schedule!r}, P={self.rc.pp} "
                f"V={self.rc.vpp} G={self.rc.groups} "
                f"B={self.rc.microbatches} U={self.rc.unit_size})")
