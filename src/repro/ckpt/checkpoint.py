"""Sharded checkpointing with async save, keep-k GC and elastic resharding.

Layout (one directory per step):
    step_000123/
      manifest.json      — tree structure, global shapes, mesh, data cursor
      <leaf>.npy         — full (unsharded) array per pytree leaf

On a real multi-host cluster each host writes only its local shards and the
manifest records the shard layout; in this single-process container we
device_get the addressable array (process-local = global). The *interface*
(save/restore/reshard/keep-k/async) is the production surface; restore can
re-layout to a different mesh ("elastic" D changes) because arrays are
stored in their global logical layout.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        cur = tree
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return tree


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = True):
        """Snapshot to host memory synchronously, write to disk (async
        optional), atomic rename, GC old steps."""
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def write():
            tmp = os.path.join(self.directory, f".tmp_step_{step:09d}")
            final = os.path.join(self.directory, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            for k, v in host.items():
                fn = k.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), v)
            manifest = {
                "step": step,
                "keys": sorted(host),
                "shapes": {k: list(v.shape) for k, v in host.items()},
                "dtypes": {k: str(v.dtype) for k, v in host.items()},
                "extra": extra or {},
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ #
    def list_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self):
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None):
        """Load a checkpoint; optionally device_put with new shardings
        (elastic re-mesh: the target mesh may differ from the saved one)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for k in manifest["keys"]:
            fn = k.replace("/", "__") + ".npy"
            flat[k] = np.load(os.path.join(path, fn))
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest

    def verify(self, step: int) -> bool:
        """Integrity check: manifest lists every file with right shape."""
        path = os.path.join(self.directory, f"step_{step:09d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            for k in manifest["keys"]:
                fn = k.replace("/", "__") + ".npy"
                a = np.load(os.path.join(path, fn), mmap_mode="r")
                if list(a.shape) != manifest["shapes"][k]:
                    return False
            return True
        except Exception:
            return False
