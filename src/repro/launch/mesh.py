"""Production meshes — thin wrapper over the topology presets.

The 16×16 pod shape and TPU v5e constants that used to be hard-coded
here live in :mod:`repro.runtime.topology` now; this module stays
importable (benchmarks/roofline.py pulls the constants) and keeps the
historical ``make_production_mesh`` entry point. Kept as FUNCTIONS so
importing this module never touches jax device state (entry points call
repro.api.ensure_host_devices() before any other JAX use; tests use
their own small meshes in subprocesses).
"""

from __future__ import annotations

from repro.runtime.topology import (  # noqa: F401  (re-exports)
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    TOPOLOGY_PRESETS,
)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: "data" = FSDP (+EP +vocab) axis, "model" = pipeline-group ×
    pipeline-stage axis (TP-free per the paper), "pod" = hybrid-sharded DP
    (params replicated, grads all-reduced once per step).
    """
    preset = TOPOLOGY_PRESETS["tpu_pod_x2" if multi_pod else "tpu_pod"]
    return preset.build_mesh(16, cost_preset="tpu_v5e")
