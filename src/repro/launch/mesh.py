"""Production meshes.

Kept as FUNCTIONS so importing this module never touches jax device state
(entry points call repro.api.ensure_host_devices() before any other JAX
use; tests use their own small meshes in subprocesses).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: "data" = FSDP (+EP +vocab) axis, "model" = pipeline-group ×
    pipeline-stage axis (TP-free per the paper), "pod" = hybrid-sharded DP
    (params replicated, grads all-reduced once per step).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (~4 links usable)
