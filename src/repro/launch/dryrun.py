import os

from repro.api import ensure_host_devices, get_arch, session

ensure_host_devices(int(os.environ.get("DRYRUN_DEVICES", "512")),
                    force=True)

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh), print the compiled memory/cost analyses, scrape the collective
schedule, and emit the roofline terms.

Must be run as its own process (the fake host devices — 512, or
DRYRUN_DEVICES — are forced before any other JAX use above; do NOT
import this module from tests/benchmarks).

Usage:
  PYTHONPATH=src:. python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--out results/]
  PYTHONPATH=src:. python -m repro.launch.dryrun --all [--multi-pod]

Budgeted CI cell (8 fake CPU devices, reduced smoke config, compile-time
budget enforced):
  DRYRUN_DEVICES=8 PYTHONPATH=src:. python -m repro.launch.dryrun \
      --reduced --arch llama3.2-1b --schedule auto --budget-s 600
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

from repro.models.common import SHAPES  # noqa: E402

ARCHS = [
    "whisper-large-v3", "qwen2-moe-a2.7b", "deepseek-v3-671b",
    "jamba-v0.1-52b", "phi-3-vision-4.2b", "minitron-4b", "yi-9b",
    "phi4-mini-3.8b", "llama3.2-1b", "xlstm-1.3b",
]

# long_500k needs sub-quadratic attention: only the SSM/hybrid archs run it
# (brief: skip for pure full-attention archs; noted in DESIGN.md §5).
LONG_OK = {"jamba-v0.1-52b", "xlstm-1.3b"}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]"
)
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8}


def scrape_collectives(hlo_text: str) -> dict:
    """Count collective instructions + static operand bytes in the HLO.

    Ops inside while-loop bodies appear once (the analytic model in
    benchmarks/roofline.py accounts for trip counts); this scrape is the
    structural fingerprint: which collectives exist, with what shapes.
    """
    out: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        op, dt, dims = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for tok in dims.split(","):
            if tok:
                nbytes *= int(tok)
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str | None):
    import benchmarks.roofline as RL

    shape_cfg = SHAPES[shape]
    overrides = {}
    if multi_pod and shape_cfg.kind == "train":
        # pods split the global batch: half the micro-batches per pipeline
        rc0 = get_arch(arch).production_run(shape)
        per_dp = max(shape_cfg.global_batch // (2 * 16), 1)
        overrides = dict(
            microbatches=max(per_dp // rc0.groups, 1),
            unit=min(rc0.unit or 10**9, max(per_dp // rc0.groups, 1)))
    t0 = time.time()
    sess = session(arch, mode="dry-run", shape=shape, reduced=False,
                   multi_pod=multi_pod, overrides=overrides)
    lowered = sess.lower()
    t_lower = time.time() - t0
    rt = sess.rt  # roofline analysis reads the runtime's static tables

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older JAX: one dict per device
        cost = cost[0] if cost else {}
    print(f"--- memory_analysis [{arch} × {shape} "
          f"{'multi-pod' if multi_pod else 'single-pod'}] ---")
    print(mem)
    print("--- cost_analysis (flops/bytes; while-bodies counted once) ---")
    print({k: v for k, v in sorted(cost.items())
           if isinstance(v, (int, float)) and v})

    hlo = compiled.as_text()
    colls = scrape_collectives(hlo)
    print("--- collective schedule (instructions in compiled HLO) ---")
    for op, rec in sorted(colls.items()):
        print(f"  {op:20s} n={rec['count']:4d} bytes={rec['bytes']:.3e}")

    roof = RL.analyze_cell(rt, shape_cfg)
    print("--- roofline (analytic, per device per step) ---")
    print(f"  compute    {roof.compute_s:10.4f} s")
    print(f"  memory     {roof.memory_s:10.4f} s")
    print(f"  collective {roof.collective_s:10.4f} s")
    print(f"  bottleneck {roof.bottleneck}")
    print(f"  MODEL_FLOPS/HLO_FLOPS {roof.useful_ratio:.3f}")

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "bytes_per_device": getattr(
                mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "repr": str(mem)[:2000],
        },
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "collectives": colls,
        "roofline": {
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "flops": roof.flops, "hbm_bytes": roof.hbm_bytes,
            "coll_bytes": roof.coll_bytes,
            "model_flops": roof.model_flops,
            "useful_ratio": roof.useful_ratio,
            "bottleneck": roof.bottleneck,
        },
        "status": "ok",
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape}_{'mp' if multi_pod else 'sp'}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    print(f"CELL_OK {arch} {shape} lower={t_lower:.1f}s "
          f"compile={t_compile:.1f}s")
    return rec


def run_reduced_cell(arch: str, schedule: str | None, budget_s: float,
                     out_dir: str | None):
    """Budgeted smoke dry-run: reduced() config through the facade on the
    forced (small) device count — lower + compile the train step, print
    the compiled analyses, enforce a wall-clock budget. This is the CI
    cell ROADMAP asked for once compile times were budgeted."""
    import jax

    t_start = time.time()
    overrides = dict(microbatches=4, unit=2)
    if schedule:
        overrides["schedule"] = schedule
    sess = session(arch, mode="dry-run", seq_len=32, overrides=overrides)
    d = sess.describe()
    print(f"plan: {d['schedule']['name']} "
          f"(preset={d['schedule']['preset']}, "
          f"bubble={d['schedule']['bubble_ratio']:.3f}, "
          f"makespan={d['schedule']['makespan']:.3e}, "
          f"stash_depth={d['schedule']['stash_depth']}, "
          f"rs_saved={d['schedule']['rs_overlap']['saved_s']:.2e}s)")
    if "auto" in d["schedule"]:
        print("auto candidates (makespan / peak_mem / stash depth):")
        for n, c in d["schedule"]["auto"]["candidates"].items():
            if isinstance(c, dict):
                print(f"  {n:14s} {c['makespan']:.3e}  "
                      f"mem={c['peak_mem']:.2e}  U={c['stash_depth']}")
            else:
                print(f"  {n:14s} {c}")

    t0 = time.time()
    lowered = sess.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    print(f"--- memory_analysis [{arch} reduced, "
          f"{jax.device_count()} fake devices] ---")
    print(mem)
    colls = scrape_collectives(compiled.as_text())
    print("--- collective schedule ---")
    for op, rec in sorted(colls.items()):
        print(f"  {op:20s} n={rec['count']:4d} bytes={rec['bytes']:.3e}")
    elapsed = time.time() - t_start
    over_budget = elapsed > budget_s
    rec = {
        "arch": arch, "shape": "reduced",
        "schedule": d["schedule"]["name"],
        "devices": jax.device_count(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "collectives": colls,
        "status": ("budget_exceeded" if over_budget else "ok"),
        "budget_s": budget_s, "elapsed_s": round(elapsed, 1),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}_reduced.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if over_budget:
        print(f"CELL_FAIL {arch} reduced: {elapsed:.0f}s exceeded the "
              f"{budget_s:.0f}s budget")
        raise SystemExit(1)
    print(f"CELL_OK {arch} reduced lower={t_lower:.1f}s "
          f"compile={t_compile:.1f}s budget={elapsed:.0f}/{budget_s:.0f}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--reduced", action="store_true",
                    help="budgeted smoke cell: reduced() config on the "
                         "forced device count (set DRYRUN_DEVICES)")
    ap.add_argument("--schedule", default=None,
                    help="schedule override for --reduced (e.g. auto)")
    ap.add_argument("--budget-s", type=float, default=600.0,
                    help="wall-clock budget for the --reduced cell")
    args = ap.parse_args()

    if args.reduced:
        run_reduced_cell(args.arch or "llama3.2-1b", args.schedule,
                         args.budget_s, args.out)
        return

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        if shape == "long_500k" and arch not in LONG_OK:
            print(f"CELL_SKIP {arch} long_500k (pure full attention; "
                  "DESIGN.md §5)")
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                tag = f"{arch}_{shape}_{'mp' if args.multi_pod else 'sp'}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "status": "skipped_full_attention"}, f)
            continue
        try:
            run_cell(arch, shape, args.multi_pod, args.out)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"CELL_FAIL {arch} {shape}: {e}")
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                tag = f"{arch}_{shape}_{'mp' if args.multi_pod else 'sp'}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "status": f"fail: {e}"}, f)


if __name__ == "__main__":
    main()
