"""End-to-end training driver: data pipeline → ZeroPP pipeline step →
sharded AdamW → checkpoint/restart under the fault-tolerance controller.

All assembly goes through the ``repro.api`` Session facade.
``--schedule auto`` runs the §4 plan selection (every registered schedule
+ the autogen heuristic, simulated under ``--preset``) and trains with
the winner.

Usage (CPU demo; device count via SPMD_DEVICES, default 8):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 --data 2 [--schedule auto] [--ckpt-dir /tmp/ckpt]
"""

from __future__ import annotations

import argparse

from repro.api import ensure_host_devices, session


def build_session(arch: str, *, data: int | None = None, seq: int,
                  microbatches: int, schedule: str, lr: float,
                  unit: int = 0, preset: str = "a800",
                  profile_top_k: int = 3,
                  profile_budget_s: float | None = None,
                  moe_mode: str | None = None, moe_stats: bool = False,
                  topology=None, global_batch: int | None = None):
    """One facade call replaces the old 8-step assembly ritual.

    ``topology=`` (a preset name or a ``repro.runtime.topology.Topology``)
    subsumes ``data=`` — the axis layout is derived from the hardware.
    ``global_batch=`` pins the batch across elastic restarts so the data
    stream (and the loss trajectory) continues on a shrunk mesh.
    """
    kw = {}
    if schedule == "auto_profiled":
        kw = dict(profile_top_k=profile_top_k,
                  profile_budget_s=profile_budget_s)
    if topology is not None:
        kw["topology"] = topology
    else:
        kw["data"] = data
    if global_batch is not None:
        kw["global_batch"] = global_batch
    ov = dict(schedule=schedule, microbatches=microbatches, unit=unit)
    if moe_mode is not None:
        ov["moe_mode"] = moe_mode
    if moe_stats:
        ov["moe_stats"] = True
    sess = session(
        arch, mode="train", seq_len=seq, cost_preset=preset,
        overrides=ov,
        optim=dict(lr=lr, warmup=20, total=10_000), **kw,
    )
    sched = sess.describe()["schedule"]
    auto_moe = sched.get("moe_mode_auto")
    if auto_moe:
        # the provenance line CI's moe-smoke job greps for
        print("moe_mode=auto resolved -> "
              f"{auto_moe['resolved']!r}; scores: "
              + ", ".join(f"{m}={s:.3e}"
                          for m, s in sorted(auto_moe["scores"].items())))
    coll = sched.get("collectives", {})
    if coll.get("a2a_per_f_tick", 0) or coll.get("a2a_per_b_tick", 0):
        print(f"a2a: {coll['a2a_per_f_tick']}xF+{coll['a2a_per_b_tick']}xB "
              f"events/tick, {coll['a2a_bytes']:.3e} B/event, "
              f"t_event {coll['a2a_t_event_s']:.3e}s, simulated total "
              f"{coll['a2a_total_s']:.3e}s")
    if sess.plan_selection is not None:
        sel = sess.plan_selection
        src = sess._plan_source
        if src in ("memory-hit", "persisted-hit"):
            # the provenance line CI's warm-cache re-run greps for
            kind = "persisted" if src == "persisted-hit" else "memory"
            print(f"plan-cache: hit ({kind}) -> "
                  f"{sel.selected.name!r} [{sel.provenance}]")
        else:
            print(f"plan-cache: miss (ran {src})")
        print(f"schedule={schedule} selected {sel.selected.name!r} "
              f"(makespan {sel.analysis.makespan:.3e}, preset "
              f"{sel.preset}); ranking: "
              + ", ".join(f"{n}={m:.3e}" for n, m in sel.ranking()))
        if sel.measured:
            sim_best = (sel.profile or {}).get("simulated_best")
            sim_us = (sel.profile or {}).get("simulated_best_us")
            win_us = sel.measured.get(sel.selected.name)
            print("measured us/call: "
                  + ", ".join(f"{n}={us:.1f}" for n, us in
                              sel.measured_ranking()))
            if win_us is not None and sim_us is not None \
                    and win_us <= sim_us + 1e-9:
                # CI asserts the coarse→fine contract on this line:
                # measured(winner) <= measured(simulated-best)
                print(f"AUTO_PROFILED_OK selected={sel.selected.name} "
                      f"us={win_us:.1f} simulated_best={sim_best} "
                      f"us={sim_us:.1f} "
                      f"delta={(sim_us - win_us) / max(sim_us, 1e-9):.1%}")
    return sess


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--unit", type=int, default=0)
    ap.add_argument("--schedule", default="zeropp",
                    help="a registered schedule name, 'auto' for the §4 "
                         "simulated plan selection, or 'auto_profiled' "
                         "to also time the top-K finalists on the live "
                         "mesh and pick the fastest measured step")
    ap.add_argument("--preset", default="a800",
                    help="cost preset for schedule=auto (a800 | tpu_v5e)")
    ap.add_argument("--profile-top-k", type=int, default=3,
                    help="auto_profiled: how many simulated survivors "
                         "get a real measurement")
    ap.add_argument("--profile-budget-s", type=float, default=None,
                    help="auto_profiled: wall-clock cap on the measuring "
                         "phase (the simulated-best is always measured)")
    ap.add_argument("--moe-mode", default=None,
                    help="expert placement for MoE archs: gathered | ep "
                         "| auto (cost both under the a2a-aware model)")
    ap.add_argument("--moe-stats", action="store_true",
                    help="collect per-layer expert-load histograms + "
                         "capacity-drop counters (train metrics "
                         "moe_load/moe_dropped)")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--topology", default=None,
                    help="hardware topology preset (fake_cpu | "
                         "gpu_cluster | tpu_pod | tpu_pod_x2); default "
                         "builds a fake_cpu topology pinned to --data")
    ap.add_argument("--max-failures", type=int, default=3)
    args = ap.parse_args()

    ensure_host_devices()
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.runtime.fault_tolerance import (
        FaultToleranceConfig,
        TrainController,
    )
    from repro.runtime.topology import resolve_topology

    ctl = TrainController(args.ckpt_dir,
                          FaultToleranceConfig(
                              ckpt_every=args.ckpt_every,
                              max_failures=args.max_failures))
    box: dict = {}   # first-build facts pinned across elastic restarts

    def build(restored, manifest):
        # fresh session per (re)start: an elastic restart rebuilds on a
        # topology whose data axis halves per failure (node-loss model:
        # the survivors re-mesh; params relayout from the checkpoint)
        topo = resolve_topology(args.topology or "fake_cpu")
        if ctl.failures:
            d0 = box["data"]
            topo = _dc.replace(topo, name=None,
                               data=max(1, d0 // (2 ** ctl.failures)))
        elif args.topology is None:
            topo = _dc.replace(topo, data=args.data)
        sess = build_session(
            args.arch, seq=args.seq,
            microbatches=args.microbatches, schedule=args.schedule,
            lr=args.lr, unit=args.unit, preset=args.preset,
            profile_top_k=args.profile_top_k,
            profile_budget_s=args.profile_budget_s,
            moe_mode=args.moe_mode, moe_stats=args.moe_stats,
            topology=topo, global_batch=box.get("gb"))
        ctl.attach(sess)
        box.setdefault("data", sess.data_size)
        # pin the global batch so the stream (and the loss trajectory)
        # continues unchanged when the data axis shrinks
        box.setdefault("gb", sess.shape_cfg.global_batch)
        if ctl.failures:
            start = (manifest or {}).get("extra", {}).get("step", 0)
            print(f"elastic: restart {ctl.failures}/"
                  f"{ctl.cfg.max_failures} resumed at step {start} on "
                  f"{topo.label()} (data {box['data']}->"
                  f"{sess.data_size}, global_batch {box['gb']})")
        stream = sess.stream()
        if restored is None:
            params = sess.init_params(jax.random.PRNGKey(0))
            opt_state = sess.init_opt_state(params)
        else:
            # relayout the verified checkpoint onto THIS session's mesh
            # and shardings (the restart topology may be smaller)
            params = sess.adopt_params(restored["params"])
            opt_state = jax.tree.map(jnp.asarray, restored["opt"])
            opt_state["step"] = jnp.asarray(opt_state["step"])
        state = {"params": params, "opt": opt_state}

        def run_one(state, step_no):
            batch = stream.batch(step_no)
            grads, metrics = sess.train_step(state["params"], batch)
            params, opt, om = sess.opt_step(state["params"], grads,
                                            state["opt"])
            loss = float(metrics["loss_sum"])
            extra = ""
            if "moe_load" in metrics:
                import numpy as np
                load = np.asarray(metrics["moe_load"]).sum(axis=0)
                imb = float(load.max()) / max(float(load.mean()), 1e-9)
                extra = (f" moe_imb {imb:.2f} "
                         f"dropped {int(metrics['moe_dropped'])}")
            print(f"step {step_no:4d} loss {loss:.4f} "
                  f"gnorm {float(om['grad_norm']):.3f}{extra}")
            return {"params": params, "opt": opt}, {
                "loss": loss,
                "straggler_flags": ctl.watchdog.flags,
                "failures": ctl.failures,
            }

        return state, run_one, lambda s: s

    state, history = ctl.run(build, args.steps,
                             inject_failure_at=args.inject_failure_at)
    losses = [m["loss"] for _, m in history]
    ft = ctl.summary()
    tail = (f"straggler_flags={ft['straggler_flags']} "
            f"failures={ft['failures']} "
            f"resume_steps={ft['resume_steps']}")
    if losses:
        print(f"DONE first_loss={losses[0]:.4f} "
              f"last_loss={losses[-1]:.4f} steps={len(history)} {tail}")
    else:
        # a checkpoint at/past --steps resumes to a zero-step run
        print(f"DONE resumed-at-target (checkpoint >= --steps "
              f"{args.steps}) {tail}")


if __name__ == "__main__":
    main()
