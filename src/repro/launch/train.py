"""End-to-end training driver: data pipeline → ZeroPP pipeline step →
sharded AdamW → checkpoint/restart under the fault-tolerance controller.

Usage (CPU demo; device count via SPMD_DEVICES):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 --data 2 [--schedule zeropp] [--ckpt-dir /tmp/ckpt]
"""

from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ and os.environ.get("SPMD_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["SPMD_DEVICES"])

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.ckpt.checkpoint import CheckpointManager  # noqa: E402
from repro.core.pipeline import Runtime, make_train_step  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticStream  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.common import ShapeConfig  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime.fault_tolerance import (  # noqa: E402
    FaultToleranceConfig,
    TrainController,
)


def build_trainer(arch: str, *, data: int, seq: int, microbatches: int,
                  schedule: str, lr: float, reduced: bool = True,
                  unit: int = 0):
    mod = M.get_arch(arch)
    if reduced:
        cfg, rc = mod.reduced()
    else:
        cfg, rc = mod.config(), mod.production_run("train_4k")
    rc = dataclasses.replace(rc, schedule=schedule,
                             microbatches=microbatches, unit=unit)
    geo = M.build_geometry(cfg, rc)
    mesh = jax.make_mesh((data, geo.model_ranks), ("data", "model"))
    rt = Runtime(cfg, rc, mesh)
    gb = data * rc.groups * rc.microbatches
    shape_cfg = ShapeConfig("train", seq, gb, "train")
    step_fn = make_train_step(rt, shape_cfg)
    opt_cfg = adamw.AdamWConfig(lr=lr,
                                moment_dtype=rc.opt_moment_dtype)
    dcfg = DataConfig(
        seq_len=seq, global_batch=gb, vocab=cfg.vocab,
        kind=("enc_dec" if cfg.encdec else
              "vision" if cfg.frontend == "vision" else "lm"),
        d_model=cfg.d_model,
        enc_ctx=cfg.encdec.enc_ctx if cfg.encdec else 0,
    )
    stream = SyntheticStream(dcfg)

    @jax.jit
    def opt_step(params, grads, opt_state, step_no):
        lr_scale = adamw.lr_schedule(step_no, base_lr=1.0, warmup=20,
                                     total=10_000)
        return adamw.apply_updates(params, grads, opt_state, opt_cfg,
                                   lr_scale)

    return rt, cfg, rc, shape_cfg, step_fn, opt_step, stream, gb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--unit", type=int, default=0)
    ap.add_argument("--schedule", default="zeropp")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    ft = FaultToleranceConfig(ckpt_every=args.ckpt_every)
    ctl = TrainController(args.ckpt_dir, ft)

    def build(restored, manifest):
        (rt, cfg, rc, shape_cfg, step_fn, opt_step, stream, gb
         ) = build_trainer(
            args.arch, data=args.data, seq=args.seq,
            microbatches=args.microbatches, schedule=args.schedule,
            lr=args.lr, unit=args.unit)
        if restored is None:
            params = rt.init_params(jax.random.PRNGKey(0))
            opt_state = adamw.init_state(params, adamw.AdamWConfig())
        else:
            params = jax.tree.map(jnp.asarray, restored["params"])
            opt_state = jax.tree.map(jnp.asarray, restored["opt"])
            opt_state["step"] = jnp.asarray(opt_state["step"])
        state = {"params": params, "opt": opt_state}

        def run_one(state, step_no):
            batch = stream.batch(step_no)
            grads, metrics = step_fn(state["params"], batch)
            params, opt, om = opt_step(state["params"], grads,
                                       state["opt"],
                                       state["opt"]["step"])
            loss = float(metrics["loss_sum"])
            print(f"step {step_no:4d} loss {loss:.4f} "
                  f"gnorm {float(om['grad_norm']):.3f}")
            return {"params": params, "opt": opt}, {"loss": loss}

        return state, run_one, lambda s: s

    state, history = ctl.run(build, args.steps,
                             inject_failure_at=args.inject_failure_at)
    losses = [m["loss"] for _, m in history]
    print(f"DONE first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"straggler_flags={ctl.watchdog.flags}")


if __name__ == "__main__":
    main()
