"""End-to-end training driver: data pipeline → ZeroPP pipeline step →
sharded AdamW → checkpoint/restart under the fault-tolerance controller.

All assembly goes through the ``repro.api`` Session facade.
``--schedule auto`` runs the §4 plan selection (every registered schedule
+ the autogen heuristic, simulated under ``--preset``) and trains with
the winner.

Usage (CPU demo; device count via SPMD_DEVICES, default 8):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 --data 2 [--schedule auto] [--ckpt-dir /tmp/ckpt]
"""

from __future__ import annotations

import argparse

from repro.api import ensure_host_devices, session


def build_session(arch: str, *, data: int, seq: int, microbatches: int,
                  schedule: str, lr: float, unit: int = 0,
                  preset: str = "a800"):
    """One facade call replaces the old 8-step assembly ritual."""
    sess = session(
        arch, mode="train", data=data, seq_len=seq, cost_preset=preset,
        overrides=dict(schedule=schedule, microbatches=microbatches,
                       unit=unit),
        optim=dict(lr=lr, warmup=20, total=10_000),
    )
    if sess.plan_selection is not None:
        sel = sess.plan_selection
        print(f"schedule=auto selected {sel.selected.name!r} "
              f"(makespan {sel.analysis.makespan:.3e}, preset "
              f"{sel.preset}); ranking: "
              + ", ".join(f"{n}={m:.3e}" for n, m in sel.ranking()))
    return sess


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--unit", type=int, default=0)
    ap.add_argument("--schedule", default="zeropp",
                    help="a registered schedule name, or 'auto' for the "
                         "§4 simulated plan selection")
    ap.add_argument("--preset", default="a800",
                    help="cost preset for schedule=auto (a800 | tpu_v5e)")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    ensure_host_devices()
    import jax
    import jax.numpy as jnp

    from repro.runtime.fault_tolerance import (
        FaultToleranceConfig,
        TrainController,
    )

    ctl = TrainController(args.ckpt_dir,
                          FaultToleranceConfig(ckpt_every=args.ckpt_every))

    def build(restored, manifest):
        # fresh session per (re)start: elastic restarts may re-mesh
        sess = build_session(
            args.arch, data=args.data, seq=args.seq,
            microbatches=args.microbatches, schedule=args.schedule,
            lr=args.lr, unit=args.unit, preset=args.preset)
        stream = sess.stream()
        if restored is None:
            params = sess.init_params(jax.random.PRNGKey(0))
            opt_state = sess.init_opt_state(params)
        else:
            params = jax.tree.map(jnp.asarray, restored["params"])
            opt_state = jax.tree.map(jnp.asarray, restored["opt"])
            opt_state["step"] = jnp.asarray(opt_state["step"])
        state = {"params": params, "opt": opt_state}

        def run_one(state, step_no):
            batch = stream.batch(step_no)
            grads, metrics = sess.train_step(state["params"], batch)
            params, opt, om = sess.opt_step(state["params"], grads,
                                            state["opt"])
            loss = float(metrics["loss_sum"])
            print(f"step {step_no:4d} loss {loss:.4f} "
                  f"gnorm {float(om['grad_norm']):.3f}")
            return {"params": params, "opt": opt}, {"loss": loss}

        return state, run_one, lambda s: s

    state, history = ctl.run(build, args.steps,
                             inject_failure_at=args.inject_failure_at)
    losses = [m["loss"] for _, m in history]
    print(f"DONE first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"straggler_flags={ctl.watchdog.flags}")


if __name__ == "__main__":
    main()
