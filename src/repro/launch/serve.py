"""Serving driver: batched prefill + decode through the pipeline, via the
``repro.api`` Session facade.

Usage (CPU demo):
  SPMD_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --arch llama3.2-1b --batch 8 --prompt 16 --gen 8
"""

from __future__ import annotations

import argparse
import time

from repro.api import ensure_host_devices, session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--schedule", default=None,
                    help="registered schedule name or 'auto' (§4 plan "
                         "selection; serving itself runs the fwd-only "
                         "table, the choice sizes the unit buffers)")
    args = ap.parse_args()

    ensure_host_devices()
    import jax
    import jax.numpy as jnp
    import numpy as np

    max_seq = args.prompt + args.gen + 8
    sess = session(
        args.arch, mode="serve", data=args.data,
        global_batch=args.batch, max_seq=max_seq,
        schedule=args.schedule,
        overrides=dict(microbatches=2),
    )
    d = sess.describe()["schedule"]
    print(f"serving with schedule={d['name']} "
          f"(simulated bubble {d['bubble_ratio']:.3f}, "
          f"preset {d['preset']})")
    params = sess.init_params(jax.random.PRNGKey(0))
    caches = sess.init_caches()
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt), 0,
                              sess.cfg.vocab)

    t0 = time.time()
    tok, caches = sess.serve_prefill(params, caches,
                                     {"tokens": toks,
                                      "pos": jnp.int32(0)})
    tok.block_until_ready()
    print(f"prefill: {args.batch}×{args.prompt} tokens in "
          f"{time.time() - t0:.3f}s -> first tokens {np.asarray(tok)[:4]}")

    seq = [np.asarray(tok)]
    cur = tok[:, None]
    t0 = time.time()
    for i in range(args.gen - 1):
        cur, caches = sess.serve_decode(params, caches,
                                        {"tokens": cur,
                                         "pos": jnp.int32(args.prompt + i)})
        seq.append(np.asarray(cur))
        cur = cur[:, None]
    dt = time.time() - t0
    out = np.stack(seq, 1)
    print(f"decoded {args.gen - 1} steps in {dt:.3f}s "
          f"({args.batch * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    for row in out[:4]:
        print("  ", row.tolist())
    print("SERVE_OK")


if __name__ == "__main__":
    main()
