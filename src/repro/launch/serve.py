"""Serving driver: the continuous-batching engine over the ``repro.api``
Session facade.

Requests stream through a fixed pool of KV-cache slots (``--slots``);
finished requests release their slot mid-decode and the FIFO queue
refills it without rebuilding the jitted step. The workload comes from
``--requests FILE`` (JSON / JSON-lines, see ``--help``) or is
synthesized with staggered lengths from ``--n-requests/--prompt/--gen``.

Usage (CPU demo):
  SPMD_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --arch llama3.2-1b --slots 4 --n-requests 8 --prompt 16 --gen 8

Workload file: a JSON array (or one JSON object per line) of requests::

  {"prompt_len": 12, "max_gen": 8}          # synthetic prompt (seeded)
  {"tokens": [3, 14, 15], "max_gen": 4, "stop": [0]}   # explicit prompt

A serve session can boot straight from a train checkpoint
(``--ckpt DIR``): ``Session.restore_params`` re-lays the trained params
out onto the serving mesh (train→serve handoff).
"""

from __future__ import annotations

import argparse
import json
import time

from repro.api import ensure_host_devices, get_arch, session


def load_requests(path: str, vocab: int, seed: int = 0):
    """Parse a --requests workload file into (tokens, max_gen, stop)."""
    import numpy as np

    with open(path) as f:
        text = f.read().strip()
    if text.startswith("["):
        entries = json.loads(text)
    else:
        entries = [json.loads(line) for line in text.splitlines() if line]
    rng = np.random.RandomState(seed)
    out = []
    for i, e in enumerate(entries):
        if "tokens" in e:
            toks = np.asarray(e["tokens"], np.int32)
            if toks.size and (toks.min() < 0 or toks.max() >= vocab):
                raise SystemExit(
                    f"--requests entry {i}: token ids must be in "
                    f"[0, {vocab}) for this config, got range "
                    f"[{toks.min()}, {toks.max()}] — reduced() configs "
                    "use a small demo vocab")
        elif "prompt_len" in e:
            toks = rng.randint(0, vocab, size=int(e["prompt_len"])
                               ).astype(np.int32)
        else:
            raise SystemExit(
                f"--requests entry {i} needs 'tokens' or 'prompt_len': "
                f"{e}")
        out.append((toks, int(e.get("max_gen", 8)),
                    tuple(e.get("stop", ()))))
    if not out:
        raise SystemExit(f"--requests file {path!r} holds no requests")
    return out


def synth_requests(n: int, prompt: int, gen: int, vocab: int,
                   seed: int = 0):
    """Staggered synthetic workload: lengths skewed around the means."""
    import numpy as np

    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        p = max(2, int(prompt * (0.5 + i / max(n - 1, 1))))
        g = max(2, int(gen * (0.25 + 1.5 * (i % 4) / 3)))
        toks = rng.randint(0, vocab, size=p).astype(np.int32)
        out.append((toks, g, ()))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache slots (in-flight requests)")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=16,
                    help="mean synthetic prompt length")
    ap.add_argument("--gen", type=int, default=8,
                    help="mean synthetic generation budget")
    ap.add_argument("--requests", default=None,
                    help="workload file (JSON array or JSON-lines)")
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=None,
                    help="KV cache length (default: fits the workload)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split prompts into chunks of this width "
                         "(bounds distinct prefill compilations)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged KV cache: tokens per page (shared "
                         "prompt prefixes prefill once; default: "
                         "contiguous per-slot rows)")
    ap.add_argument("--max-pages", type=int, default=None,
                    help="paged KV cache: total page count (default: "
                         "the contiguous footprint slots*max_seq/page)")
    ap.add_argument("--prefix-sharing", default="on",
                    choices=("on", "off"),
                    help="radix prefix sharing across requests "
                         "(off: pages stay private per request)")
    ap.add_argument("--kv-cache-dtype", default=None,
                    choices=("fp32", "bf16", "int8"),
                    help="KV-cache storage dtype (default: the compute "
                         "dtype); int8 quantizes the page pool with "
                         "per-page scales and needs --page-size")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, in-graph)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature)")
    ap.add_argument("--seed", type=int, default=None,
                    help="per-request sampling seed base: request i "
                         "draws from seed+i (restart-deterministic)")
    ap.add_argument("--schedule", default=None,
                    help="registered schedule name or 'auto' (§4 plan "
                         "selection; serving itself runs the fwd-only "
                         "table, the choice sizes the unit buffers)")
    ap.add_argument("--preset", default="a800",
                    help="cost preset for schedule='auto' simulation "
                         "(a800 | tpu_v5e)")
    ap.add_argument("--moe-mode", default=None,
                    help="expert placement for MoE archs: gathered | ep "
                         "| auto (cost both under the a2a-aware model)")
    ap.add_argument("--moe-stats", action="store_true",
                    help="per-expert load histogram + capacity-drop "
                         "counters in the serving summary")
    ap.add_argument("--ckpt", default=None,
                    help="train checkpoint dir to boot params from "
                         "(train→serve handoff)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel serving tier: N engine replicas "
                         "behind an EngineRouter (least-outstanding-"
                         "tokens dispatch, radix-affinity hinting, "
                         "replica-failure failover)")
    ap.add_argument("--kill-replica-after", type=int, default=None,
                    help="failover drill (needs --replicas >= 2): kill "
                         "replica 0 after this many requests finish; "
                         "its in-flight work moves to the survivors")
    args = ap.parse_args()

    ensure_host_devices()
    import jax

    # size the workload before the session so max_seq can default to
    # whatever the requests actually need (sessions default to reduced())
    vocab = get_arch(args.arch).reduced()[0].vocab
    if args.requests:
        work = load_requests(args.requests, vocab)
    else:
        work = synth_requests(args.n_requests, args.prompt, args.gen,
                              vocab)
    if not work:
        raise SystemExit("no requests to serve (--n-requests 0?)")
    need = max(len(t) + g for t, g, _ in work) + 1
    max_seq = args.max_seq or need
    if max_seq < need:
        raise SystemExit(f"--max-seq {max_seq} too small for the "
                         f"workload (needs >= {need})")
    if args.page_size:
        max_seq = -(-max_seq // args.page_size) * args.page_size

    sess_kw = dict(
        mode="serve", data=args.data, max_slots=args.slots,
        max_seq=max_seq, schedule=args.schedule, cost_preset=args.preset,
        prefill_chunk=args.prefill_chunk, page_size=args.page_size,
        max_pages=args.max_pages, prefix_sharing=args.prefix_sharing,
        kv_cache_dtype=args.kv_cache_dtype, moe_mode=args.moe_mode,
        overrides=dict(microbatches=2,
                       **({"moe_stats": True} if args.moe_stats else {})),
    )
    if args.replicas > 1:
        return _serve_routed(args, work, sess_kw)
    if args.kill_replica_after is not None:
        raise SystemExit("--kill-replica-after needs --replicas >= 2 "
                         "(there is no survivor to fail over to)")

    sess = session(args.arch, **sess_kw)
    d = sess.describe()["schedule"]
    print(f"serving with schedule={d['name']} "
          f"(simulated bubble {d['bubble_ratio']:.3f}, "
          f"preset {d['preset']}); {args.slots} slots, "
          f"max_seq {max_seq}")

    if args.ckpt:
        params = sess.restore_params(args.ckpt)
        print(f"params restored from train checkpoint {args.ckpt}")
    else:
        params = sess.init_params(jax.random.PRNGKey(0))

    eng = sess.serve_engine(params)
    t0 = time.time()
    with eng:
        handles = [
            eng.submit(toks, max_gen=g, stop=stop,
                       temperature=args.temperature, top_p=args.top_p,
                       seed=(None if args.seed is None
                             else args.seed + i))
            for i, (toks, g, stop) in enumerate(work)]
        results = [h.result(timeout=600) for h in handles]
    dt = time.time() - t0
    for i, ((toks, g, _), res) in enumerate(zip(work, results)):
        print(f"  req{i}: prompt {len(toks):3d} -> {len(res)} tokens "
              f"{res[:8]}{'...' if len(res) > 8 else ''}")
    st = eng.stats
    total = st.generated_tokens
    print(f"{len(work)} requests, {total} tokens in {dt:.3f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s, "
          f"{st.prefill_steps} prefill + {st.decode_steps} decode steps, "
          f"slot occupancy {st.occupancy:.2f})")
    if sess.paged:
        prompt_total = sum(len(t) for t, _, _ in work)
        print(f"paged: pages_in_use={st.pages_in_use} "
              f"peak={st.peak_pages_in_use}/{sess.n_pages} "
              f"prefix_hits={st.prefix_hits} "
              f"prefix_hit_tokens={st.prefix_hit_tokens} "
              f"prefilled {st.prefill_tokens}/{prompt_total} prompt "
              f"tokens, evictions={st.evictions}")
    srv = sess.describe().get("serving", {})
    moe = srv.get("moe")
    if moe is not None or srv.get("capacity_deferrals", 0):
        # MoE serving summary: capacity-aware admission + dispatch load
        line = (f"moe: capacity_deferrals="
                f"{srv.get('capacity_deferrals', 0)}")
        if moe is not None:
            line += f" dropped_tokens={moe['dropped_tokens']}"
            if "load_per_expert" in moe:
                line += f" load_per_expert={moe['load_per_expert']}"
        print(line)
    print("SERVE_OK")


def _serve_routed(args, work, sess_kw):
    """The --replicas N path: N sessions/engines behind an EngineRouter,
    optional mid-workload replica kill (--kill-replica-after)."""
    import jax

    from repro.api import session
    from repro.serving import EngineRouter

    engines = []
    for r in range(args.replicas):
        sess = session(args.arch, **sess_kw)
        if args.ckpt:
            params = sess.restore_params(args.ckpt)
        else:
            params = sess.init_params(jax.random.PRNGKey(0))
        engines.append(sess.serve_engine(params))
    d = engines[0].session.describe()["schedule"]
    print(f"serving with schedule={d['name']} x{args.replicas} replicas "
          f"({args.slots} slots each, max_seq "
          f"{engines[0].session._max_seq()})")
    router = EngineRouter(engines)
    t0 = time.time()
    failed = 0
    with router:
        handles = [
            router.submit(toks, max_gen=g, stop=stop,
                          temperature=args.temperature, top_p=args.top_p,
                          seed=(None if args.seed is None
                                else args.seed + i))
            for i, (toks, g, stop) in enumerate(work)]
        if args.kill_replica_after is not None:
            k = min(args.kill_replica_after, len(handles))
            for h in handles[:k]:
                h.result(timeout=600)
            moved = router.kill_replica(0)
            print(f"replica 0 killed after {k} results; "
                  f"{moved} in-flight/queued requests moved to survivors")
        results = []
        for h in handles:
            try:
                results.append(h.result(timeout=600))
            except BaseException as e:  # noqa: BLE001 — report, not die
                failed += 1
                results.append(e)
    dt = time.time() - t0
    for i, ((toks, g, _), res) in enumerate(zip(work, results)):
        if isinstance(res, BaseException):
            print(f"  req{i}: prompt {len(toks):3d} -> FAILED ({res})")
        else:
            print(f"  req{i}: prompt {len(toks):3d} -> {len(res)} tokens "
                  f"{res[:8]}{'...' if len(res) > 8 else ''}")
    st = router.stats()
    total = st["generated_tokens"]
    print(f"router: replicas={st['replicas']} alive={st['alive']} "
          f"failovers={st['failovers']} "
          f"dispatched={router.dispatched} "
          f"resubmitted={[p['resubmitted_requests'] for p in st['per_replica']]}")
    print(f"{len(work)} requests, {total} tokens in {dt:.3f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s aggregate, "
          f"failed={failed})")
    if failed == 0:
        print("SERVE_OK")
    else:
        raise SystemExit(f"{failed} requests failed")


if __name__ == "__main__":
    main()
