"""Serving driver: batched prefill + decode through the pipeline.

Usage (CPU demo):
  SPMD_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --arch llama3.2-1b --batch 8 --prompt 16 --gen 8
"""

from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ and os.environ.get("SPMD_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["SPMD_DEVICES"])

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.pipeline import (  # noqa: E402
    Runtime,
    init_serve_caches,
    make_serve_step,
)
from repro.models import model as M  # noqa: E402
from repro.models.common import ShapeConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--data", type=int, default=2)
    args = ap.parse_args()

    mod = M.get_arch(args.arch)
    cfg, rc = mod.reduced()
    rc = dataclasses.replace(rc, microbatches=2)
    geo = M.build_geometry(cfg, rc)
    mesh = jax.make_mesh((args.data, geo.model_ranks), ("data", "model"))
    rt = Runtime(cfg, rc, mesh)
    max_seq = args.prompt + args.gen + 8
    shape_cfg = ShapeConfig("serve", max_seq, args.batch, "decode")

    params = rt.init_params(jax.random.PRNGKey(0))
    caches = jax.tree.map(
        lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype), s.sharding),
        init_serve_caches(rt, shape_cfg, max_seq=max_seq),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt), 0, cfg.vocab)

    prefill = make_serve_step(rt, shape_cfg, prompt_len=args.prompt,
                              max_seq=max_seq)
    t0 = time.time()
    tok, caches = prefill(params, caches,
                          {"tokens": toks, "pos": jnp.int32(0)})
    tok.block_until_ready()
    print(f"prefill: {args.batch}×{args.prompt} tokens in "
          f"{time.time() - t0:.3f}s -> first tokens {np.asarray(tok)[:4]}")

    decode = make_serve_step(rt, shape_cfg, prompt_len=1, max_seq=max_seq)
    seq = [np.asarray(tok)]
    cur = tok[:, None]
    t0 = time.time()
    for i in range(args.gen - 1):
        cur, caches = decode(params, caches,
                             {"tokens": cur,
                              "pos": jnp.int32(args.prompt + i)})
        seq.append(np.asarray(cur))
        cur = cur[:, None]
    dt = time.time() - t0
    out = np.stack(seq, 1)
    print(f"decoded {args.gen - 1} steps in {dt:.3f}s "
          f"({args.batch * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    for row in out[:4]:
        print("  ", row.tolist())
    print("SERVE_OK")


if __name__ == "__main__":
    main()
