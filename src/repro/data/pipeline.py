"""Deterministic synthetic token pipeline.

Production-shaped: host-side generation with a checkpointable cursor,
double-buffered prefetch onto device, per-(pod, data)-shard streams that
are independent of world size *re-layout* (elastic restarts resume the
same global sample sequence regardless of D), and stub modality frontends
(audio frames / vision patches) for the enc-dec and VLM architectures.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    kind: str = "lm"          # lm | enc_dec | vision
    d_model: int = 0          # for stub embeddings
    enc_ctx: int = 0
    structure: int = 97       # synthetic data has learnable structure:
    # token t+1 = (a * token_t + b) % structure-ish mixture + noise


class SyntheticStream:
    """Deterministic, seekable global sample stream.

    Sample ``i`` is generated independently of batch size or sharding, so
    checkpoint/restart and elastic re-sharding resume exactly.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def sample(self, i: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + i)
        s = cfg.seq_len
        # affine-recurrence tokens with noise: learnable but nontrivial
        a = int(rng.integers(2, 8))
        b = int(rng.integers(0, cfg.structure))
        x0 = int(rng.integers(0, cfg.structure))
        toks = np.empty(s + 1, np.int32)
        toks[0] = x0
        for t in range(s):
            toks[t + 1] = (a * toks[t] + b) % cfg.structure
        noise = rng.random(s + 1) < 0.05
        toks = np.where(noise, rng.integers(0, cfg.vocab, s + 1), toks)
        toks = (toks % cfg.vocab).astype(np.int32)
        out = {"tokens": toks[:-1], "labels": toks[1:]}
        if cfg.kind == "enc_dec":
            out["enc_tokens"] = rng.standard_normal(
                (cfg.enc_ctx, cfg.d_model)).astype(np.float32) * 0.1
        if cfg.kind == "vision":
            out["tokens"] = rng.standard_normal(
                (s, cfg.d_model)).astype(np.float32) * 0.1
        return out

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        base = step * cfg.global_batch
        samples = [self.sample(base + j) for j in range(cfg.global_batch)]
        return {
            k: np.stack([s[k] for s in samples]) for k in samples[0]
        }


class Prefetcher:
    """Background-thread prefetch with a bounded queue + cursor state."""

    def __init__(self, stream: SyntheticStream, start_step: int = 0,
                 depth: int = 2):
        self.stream = stream
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, self.stream.batch(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __next__(self):
        s, b = self._q.get()
        self.step = s + 1
        return s, b

    def state(self) -> dict:
        return {"step": self.step}

    def close(self):
        self._stop.set()
