"""minitron-4b — 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.

[arXiv:2407.14679; hf] Width/depth-pruned Nemotron; GQA, SwiGLU, huge vocab.
"""

from repro.configs._base import make_run
from repro.models.common import ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", n_layers=32, d_model=3072, n_heads=24,
        n_kv_heads=8, d_ff=9216, vocab=256_000, d_head=128,
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=16, vpp=2)


def reduced():
    cfg = ModelConfig(
        name="minitron-4b-smoke", n_layers=4, d_model=48, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=512, d_head=12,
    )
    rc = RunConfig(pp=2, vpp=2, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
