"""The paper's GPT models (Table 4): 1.5B / 6.2B / 14.6B, seq 1024.

Used by the Table-3/5 and Fig-5/6/7 benchmark reproductions (simulator cost
model) and by the end-to-end training example at reduced width.
"""

from repro.models.common import ModelConfig, RunConfig

SIZES = {
    "1.5B": dict(n_layers=22, n_heads=24, d_model=2304),
    "6.2B": dict(n_layers=30, n_heads=32, d_model=4096),
    "14.6B": dict(n_layers=46, n_heads=40, d_model=5120),
}


def config(size: str = "1.5B") -> ModelConfig:
    s = SIZES[size]
    return ModelConfig(
        name=f"gpt-{size}", n_layers=s["n_layers"], d_model=s["d_model"],
        n_heads=s["n_heads"], n_kv_heads=s["n_heads"],
        d_ff=4 * s["d_model"], vocab=50304,
        norm="layernorm", act="gelu_mlp", max_seq=1024,
    )


def paper_run(n_micro: int = 8, unit: int = 0, schedule="zeropp") -> RunConfig:
    """The paper's setup: PP=4, DP(FSDP)=4 per node group."""
    return RunConfig(pp=4, vpp=2, microbatches=n_micro, unit=unit,
                     schedule=schedule)


def production_run(shape: str) -> RunConfig:
    from repro.configs._base import make_run
    return make_run(config("6.2B"), shape, pp=16, vpp=2)


def reduced():
    cfg = ModelConfig(
        name="gpt-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=256, d_head=16, norm="layernorm", act="gelu_mlp",
    )
    rc = RunConfig(pp=2, vpp=2, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
