"""Shared helpers for architecture config modules.

Each config module exports:
  config()            -> ModelConfig (exact published hyper-parameters)
  production_run(shape) -> RunConfig for the 256-chip production mesh
  reduced()           -> (ModelConfig, RunConfig) tiny same-family smoke config
"""

from __future__ import annotations

from repro.models.common import ModelConfig, RunConfig, SHAPES


def make_run(
    cfg: ModelConfig,
    shape: str,
    *,
    pp: int = 16,
    vpp: int = 2,
    groups: int = 1,
    microbatches: int | None = None,
    unit: int = 0,
    schedule: str = "zeropp",
    moe_mode: str = "gathered",
    **kw,
) -> RunConfig:
    sh = SHAPES[shape]
    if microbatches is None:
        # per-pipeline-group micro-batches for the production mesh:
        # data axis = 16, model axis = groups*pp; micro-batch size 1.
        per_dp = max(sh.global_batch // 16, 1)
        microbatches = max(per_dp // groups, 1)
    return RunConfig(
        pp=pp, vpp=vpp, groups=groups, microbatches=microbatches,
        unit=unit, schedule=schedule, moe_mode=moe_mode, **kw,
    )
