"""xlstm-1.3b — 48L d_model=2048 4H d_ff=0 vocab=50304, sLSTM + mLSTM.

[arXiv:2405.04517; unverified] Post-up-projection mLSTM blocks (factor 2)
with sLSTM blocks interleaved; d_ff=0 → no separate FFN.

Deviation (DESIGN.md §4): the paper's xLSTM[7:1] ratio needs period 8,
which does not divide any feasible layers-per-stage for 48 layers; we use
slstm_every=6 (5 mLSTM : 1 sLSTM) with 2 pipeline groups of P=8 (k=6, V=1)
so every layer kind is static per slot.
"""

from repro.configs._base import make_run
from repro.models.common import ModelConfig, RunConfig, XLSTMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", n_layers=48, d_model=2048, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab=50304, d_head=512,
        xlstm=XLSTMCfg(slstm_every=6, proj_factor=2.0),
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=8, vpp=1, groups=2)


def reduced():
    cfg = ModelConfig(
        name="xlstm-smoke", n_layers=6, d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab=256, d_head=32,
        xlstm=XLSTMCfg(slstm_every=6, proj_factor=2.0),
    )
    rc = RunConfig(pp=1, vpp=1, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
