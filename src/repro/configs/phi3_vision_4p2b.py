"""phi-3-vision-4.2b — 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

[hf:microsoft/Phi-3-vision-128k-instruct; hf] phi3-mini backbone + CLIP
frontend.  Per the brief, the vision tower is a STUB: ``input_specs``
provides precomputed patch embeddings concatenated into the token stream.
"""

from repro.configs._base import make_run
from repro.models.common import ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", n_layers=32, d_model=3072, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=32064, d_head=96,
        frontend="vision",
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=16, vpp=2)


def reduced():
    cfg = ModelConfig(
        name="phi3v-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, d_head=16, frontend="vision",
    )
    rc = RunConfig(pp=2, vpp=2, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
