"""jamba-v0.1-52b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

[arXiv:2403.19887; hf] Mamba+attention 1:7 interleave (attention at layer
index 4 of each period-8 block), MoE 16e top-2 on odd layers.

Geometry: period-8 layer pattern requires 8 | layers-per-stage, so we run
4 pipeline groups of P=4 with one full period per stage (k=8, V=1) — all
layer kinds static, zero parameter union (DESIGN.md §4). Experts are
expert-parallel over the data axis.
"""

from repro.configs._base import make_run
from repro.models.common import MambaCfg, MoECfg, ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=65536, d_head=128,
        mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
        attn_every=8, attn_offset=4,
        moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=14336, every=2,
                   offset=1),
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=4, vpp=1, groups=4, moe_mode="ep")


def reduced():
    cfg = ModelConfig(
        name="jamba-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, d_head=16,
        mamba=MambaCfg(d_state=4, d_conv=4, expand=2),
        attn_every=8, attn_offset=4,
        moe=MoECfg(capacity_factor=8.0, n_experts=4, top_k=2, d_ff_expert=128, every=2, offset=1),
    )
    rc = RunConfig(pp=1, vpp=1, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
