"""qwen2-moe-a2.7b — 24L d_model=2048 16H d_ff=1408 vocab=151936, 60e top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 4 shared experts (shared intermediate 5632)
+ 60 routed experts top-4, every layer MoE.

Geometry: 24 layers do not divide the 16-rank model axis; we run 2 pipeline
groups of P=8 (V=3, one layer per stage) — zero padding (DESIGN.md §4).
"""

from repro.configs._base import make_run
from repro.models.common import MoECfg, ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=151_936, d_head=128,
        moe=MoECfg(n_experts=60, top_k=4, d_ff_expert=1408,
                   n_shared=4, d_ff_shared=5632),
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=8, vpp=3, groups=2,
                    moe_mode="gathered")


def reduced():
    cfg = ModelConfig(
        name="qwen2-moe-smoke", n_layers=3, d_model=48, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=256, d_head=12,
        moe=MoECfg(capacity_factor=8.0, n_experts=8, top_k=2, d_ff_expert=64,
                   n_shared=1, d_ff_shared=96),
    )
    rc = RunConfig(pp=3, vpp=1, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
