"""deepseek-v3-671b — 61L d_model=7168 128H d_ff=2048 vocab=129280.

[arXiv:2412.19437; hf] MLA (q_lora 1536, kv_lora 512, rope 64, v/nope head
128), MoE 256 routed top-8 + 1 shared expert, MTP aux head.

Deviations (DESIGN.md §4): the official first-3 dense layers (d_ff 18432)
are modeled as MoE like the rest to keep stages statically uniform — the
union-parameter alternative would add ~400M params to *every* stage.
61 layers pad to 64 stages (3 masked pads, +4.7% stage params).
Experts are expert-parallel over the data axis (an FSDP gather of an
11 GB/layer expert bank is not deployable).
"""

from repro.configs._base import make_run
from repro.models.common import MLACfg, MoECfg, ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, d_ff=2048, vocab=129_280, d_head=128,
        mla=MLACfg(q_lora=1536, kv_lora=512, rope_dims=64, v_head=128,
                   qk_nope=128),
        moe=MoECfg(n_experts=256, top_k=8, d_ff_expert=2048,
                   n_shared=1, d_ff_shared=2048),
        mtp=True,
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=16, vpp=4, moe_mode="ep")


def reduced():
    cfg = ModelConfig(
        name="deepseek-v3-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=256, d_head=16,
        mla=MLACfg(q_lora=32, kv_lora=16, rope_dims=8, v_head=16,
                   qk_nope=16),
        moe=MoECfg(capacity_factor=8.0, n_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
                   d_ff_shared=64),
        mtp=True,
    )
    rc = RunConfig(pp=2, vpp=2, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
