"""yi-9b — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

[arXiv:2403.04652; hf] llama-architecture GQA dense decoder.
"""

from repro.configs._base import make_run
from repro.models.common import ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", n_layers=48, d_model=4096, n_heads=32,
        n_kv_heads=4, d_ff=11008, vocab=64000, d_head=128,
        rope_theta=5_000_000.0,
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=16, vpp=3)


def reduced():
    cfg = ModelConfig(
        name="yi-9b-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=256, d_head=16,
    )
    rc = RunConfig(pp=2, vpp=3, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
