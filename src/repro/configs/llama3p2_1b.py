"""llama3.2-1b — 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.

[hf:meta-llama/Llama-3.2-1B; unverified] Small llama3: RoPE (theta 500k),
SwiGLU, RMSNorm, tied embeddings, head_dim 64.
"""

from repro.configs._base import make_run
from repro.models.common import ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32,
        n_kv_heads=8, d_ff=8192, vocab=128256, d_head=64,
        rope_theta=500_000.0, tie_embeddings=True,
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=16, vpp=1)


def reduced():
    cfg = ModelConfig(
        name="llama3.2-1b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, d_head=16, tie_embeddings=True,
    )
    rc = RunConfig(pp=2, vpp=1, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
