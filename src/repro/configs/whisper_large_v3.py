"""whisper-large-v3 — enc-dec 32+32L d_model=1280 20H d_ff=5120 vocab=51866.

[arXiv:2212.04356; unverified] Encoder-decoder; LayerNorm + GELU MLP;
bidirectional encoder over 1500 audio frames, causal decoder with
cross-attention.  The conv frontend is a STUB per the brief:
``input_specs`` provides precomputed frame embeddings [b, 1500, d].

Geometry: two pipeline segments (enc then dec), each 32 stages = P16 × V2.
"""

from repro.configs._base import make_run
from repro.models.common import EncDecCfg, ModelConfig, RunConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", n_layers=32, d_model=1280, n_heads=20,
        n_kv_heads=20, d_ff=5120, vocab=51866, d_head=64,
        norm="layernorm", act="gelu_mlp",
        encdec=EncDecCfg(enc_layers=32, enc_ctx=1500),
        frontend="audio",
    )


def production_run(shape: str) -> RunConfig:
    return make_run(config(), shape, pp=16, vpp=2)


def reduced():
    cfg = ModelConfig(
        name="whisper-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, d_head=16,
        norm="layernorm", act="gelu_mlp",
        encdec=EncDecCfg(enc_layers=2, enc_ctx=16), frontend="audio",
    )
    rc = RunConfig(pp=2, vpp=1, microbatches=2, param_dtype="float32",
                   compute_dtype="float32")
    return cfg, rc
