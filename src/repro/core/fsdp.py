"""FSDP layout + collectives for the ZeroPP runtime.

Parameter layout (DESIGN.md §4):
  * stage params are stacked ``[M·V, ...]`` where M = model-axis ranks
    (= groups × pp); stacked index ``mr·V + v`` holds the params of logical
    stage ``v·pp + (mr % pp)`` of pipeline group ``mr // pp`` — groups
    duplicate stage params (grads are butterfly-reduced across groups).
  * dim0 shards over "model"; each tensor additionally FSDP-shards over
    "data" on ``spec.fsdp_dim`` when divisible (else replicated).
  * EP params (``spec.ep`` and moe_mode=="ep") shard their expert dim over
    "data" permanently and are never gathered.
  * the "pod" axis always replicates parameters (hybrid-sharded DP, Zhao
    et al.; §5.2 of the paper) — pods only all-reduce gradients.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec

DATA, MODEL, POD = "data", "model", "pod"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` across JAX versions.

    Older JAX (< 0.6) ships it as ``jax.experimental.shard_map`` with the
    replication check named ``check_rep`` instead of ``check_vma``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


# --------------------------------------------------------------------------- #
# PartitionSpecs
# --------------------------------------------------------------------------- #


def stage_pspec(spec: ParamSpec, dsize: int, ep: bool) -> P:
    """PartitionSpec for a stacked stage param [M·V, *shape]."""
    dims: list = [MODEL] + [None] * len(spec.shape)
    if spec.ep and ep:
        dims[1] = DATA  # expert dim
    elif spec.shape and spec.shape[spec.fsdp_dim] % dsize == 0 and (
        spec.shape[spec.fsdp_dim] // dsize > 0
    ):
        dims[1 + spec.fsdp_dim] = DATA
    return P(*dims)


def io_pspec(spec: ParamSpec, dsize: int) -> P:
    dims: list = [None] * len(spec.shape)
    if spec.shape and spec.shape[spec.fsdp_dim] % dsize == 0:
        dims[spec.fsdp_dim] = DATA
    return P(*dims)


def local_dim(spec: ParamSpec, dsize: int, ep: bool) -> int | None:
    """Which (unstacked) dim is data-sharded locally, or None."""
    if spec.ep and ep:
        return 0
    if spec.shape and spec.shape[spec.fsdp_dim] % dsize == 0:
        return spec.fsdp_dim
    return None


# --------------------------------------------------------------------------- #
# Collectives (inside shard_map)
# --------------------------------------------------------------------------- #


def gather_param(x, spec: ParamSpec, dsize: int, ep: bool):
    """All-gather one (already v-indexed) stage param over "data"."""
    d = local_dim(spec, dsize, ep)
    if d is None or (spec.ep and ep):
        return x
    return jax.lax.all_gather(x, DATA, axis=d, tiled=True)


def reduce_scatter_grad(g, spec: ParamSpec, dsize: int, ep: bool,
                        pod: bool = False):
    """Reduce a full-size gradient back to the sharded layout (+pod psum)."""
    d = local_dim(spec, dsize, ep)
    if spec.ep and ep:
        out = g  # expert grads are already local
    elif d is None:
        out = jax.lax.psum(g, DATA)
    else:
        out = jax.lax.psum_scatter(g, DATA, scatter_dimension=d, tiled=True)
    if pod:
        out = jax.lax.psum(out, POD)
    return out


def group_allreduce(x, groups: int, pp: int):
    """Butterfly all-reduce across pipeline groups on the model axis.

    Rank id = g·pp + p; partners differ in one bit of g. groups must be a
    power of two (1, 2, 4 used here).
    """
    if groups == 1:
        return x
    n = groups * pp
    step = 1
    while step < groups:
        pairs = [(r, (((r // pp) ^ step) * pp) + (r % pp)) for r in range(n)]
        x = x + jax.lax.ppermute(x, MODEL, pairs)
        step *= 2
    return x


def pipe_perm(pp: int, groups: int, direction: int):
    """ppermute pairs for the intra-group stage ring (+1 fwd / −1 bwd)."""
    pairs = []
    for g in range(groups):
        base = g * pp
        for p in range(pp):
            src = base + p
            dst = base + (p + direction) % pp
            pairs.append((src, dst))
    return pairs


# --------------------------------------------------------------------------- #
# Optional int8 gradient compression with error feedback
# --------------------------------------------------------------------------- #


def reduce_scatter_grad_int8(g, err, spec: ParamSpec, dsize: int, ep: bool,
                             pod: bool = False):
    """int8 reduce path: shared-scale quantize → sum in int32 → dequantize.

    Quarters (vs fp32) the reduce traffic; quantization noise is carried in
    the per-tensor error-feedback buffer and re-injected next step
    (Karimireddy et al. semantics). The scale is pmax-shared over "data" so
    the integer sum is exact.
    """
    gf = g.astype(jnp.float32) + err
    local_scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    d = local_dim(spec, dsize, ep)
    if spec.ep and ep:
        scale = local_scale
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        out = q * scale
    else:
        scale = jax.lax.pmax(local_scale, DATA)
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        acc = q.astype(jnp.int32)
        if d is None:
            out = jax.lax.psum(acc, DATA).astype(jnp.float32) * scale
        else:
            out = jax.lax.psum_scatter(
                acc, DATA, scatter_dimension=d, tiled=True
            ).astype(jnp.float32) * scale
    new_err = gf - q * scale
    if pod:
        out = jax.lax.psum(out, POD)
    return out, new_err
