"""FSDP layout + collectives for the ZeroPP runtime.

Parameter layout (DESIGN.md §4):
  * stage params are stacked ``[M·V, ...]`` where M = model-axis ranks
    (= groups × pp); stacked index ``mr·V + v`` holds the params of logical
    stage ``v·pp + (mr % pp)`` of pipeline group ``mr // pp`` — groups
    duplicate stage params (grads are butterfly-reduced across groups).
  * dim0 shards over "model"; each tensor additionally FSDP-shards over
    "data" on ``spec.fsdp_dim`` when divisible (else replicated).
  * EP params (``spec.ep`` and moe_mode=="ep") shard their expert dim over
    "data" permanently and are never gathered.
  * the "pod" axis always replicates parameters (hybrid-sharded DP, Zhao
    et al.; §5.2 of the paper) — pods only all-reduce gradients.

DESIGN — flat-segment coalescing (``RunConfig.coalesce="flat"``, default):

The blockwise FSDP events of §3.3 assume ONE bandwidth-bound transfer per
stage block, but a stage block is a dict of tensors — issuing one
collective per tensor turns each gather/reduce tick into dozens of small
latency-bound collectives. The flat-segment layout coalesces them:

  * every gatherable tensor of a stage (data-divisible, non-EP) is packed
    into one contiguous per-slot buffer. A tensor enters the pack with its
    data-sharded dim ``ld`` moved to axis 0 and flattened, so tiling over
    "data" on the flat axis is exactly the tensor's per-rank FSDP shard.
  * the pack is *shard-major*: each rank's local slab is the entry-order
    concatenation of its local shards (``FlatLayout.local_size`` long),
    and the gathered segment is the rank-order concatenation of slabs.
    ``FlatEntry.offset/size`` are therefore static LOCAL offsets; the
    gathered view of tensor ``i`` is
    ``seg.reshape(dsize, local_size)[:, off:off+size]`` reshaped back —
    a zero-copy view for ``ld == 0`` tensors (one transpose otherwise).
  * the tick engine then issues ONE ``lax.all_gather`` per gather tick and
    ONE ``lax.psum_scatter`` per reduce tick, independent of tensor count.
    Values are bit-identical to the per-tensor path: both collectives are
    element-exact and the per-element cross-rank reduction order is
    unchanged — only the element layout differs.
  * tensors the layout cannot cover (replicated because non-divisible, or
    EP-sharded) keep the per-tensor path: resident stacks for gathers and
    ``psum``/local accumulation for reduces. ``coalesce="none"`` restores
    the per-tensor path wholesale as an escape hatch.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import numpy as np

from repro.models.common import FlatEntry, FlatLayout, ParamSpec

DATA, MODEL, POD = "data", "model", "pod"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` across JAX versions.

    Older JAX (< 0.6) ships it as ``jax.experimental.shard_map`` with the
    replication check named ``check_rep`` instead of ``check_vma``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


# --------------------------------------------------------------------------- #
# PartitionSpecs
# --------------------------------------------------------------------------- #


def stage_pspec(spec: ParamSpec, dsize: int, ep: bool) -> P:
    """PartitionSpec for a stacked stage param [M·V, *shape]."""
    dims: list = [MODEL] + [None] * len(spec.shape)
    if spec.ep and ep:
        dims[1] = DATA  # expert dim
    elif spec.shape and spec.shape[spec.fsdp_dim] % dsize == 0 and (
        spec.shape[spec.fsdp_dim] // dsize > 0
    ):
        dims[1 + spec.fsdp_dim] = DATA
    return P(*dims)


def io_pspec(spec: ParamSpec, dsize: int) -> P:
    dims: list = [None] * len(spec.shape)
    if spec.shape and spec.shape[spec.fsdp_dim] % dsize == 0:
        dims[spec.fsdp_dim] = DATA
    return P(*dims)


def local_dim(spec: ParamSpec, dsize: int, ep: bool) -> int | None:
    """Which (unstacked) dim is data-sharded locally, or None."""
    if spec.ep and ep:
        return 0
    if spec.shape and spec.shape[spec.fsdp_dim] % dsize == 0:
        return spec.fsdp_dim
    return None


# --------------------------------------------------------------------------- #
# Collectives (inside shard_map)
# --------------------------------------------------------------------------- #


def gather_param(x, spec: ParamSpec, dsize: int, ep: bool):
    """All-gather one (already v-indexed) stage param over "data"."""
    d = local_dim(spec, dsize, ep)
    if d is None or (spec.ep and ep):
        return x
    return jax.lax.all_gather(x, DATA, axis=d, tiled=True)


def reduce_scatter_grad(g, spec: ParamSpec, dsize: int, ep: bool,
                        pod: bool = False):
    """Reduce a full-size gradient back to the sharded layout (+pod psum)."""
    d = local_dim(spec, dsize, ep)
    if spec.ep and ep:
        out = g  # expert grads are already local
    elif d is None:
        out = jax.lax.psum(g, DATA)
    else:
        out = jax.lax.psum_scatter(g, DATA, scatter_dimension=d, tiled=True)
    if pod:
        out = jax.lax.psum(out, POD)
    return out


def group_allreduce(x, groups: int, pp: int):
    """Butterfly all-reduce across pipeline groups on the model axis.

    Rank id = g·pp + p; partners differ in one bit of g. groups must be a
    power of two (1, 2, 4 used here).
    """
    if groups == 1:
        return x
    n = groups * pp
    step = 1
    while step < groups:
        pairs = [(r, (((r // pp) ^ step) * pp) + (r % pp)) for r in range(n)]
        x = x + jax.lax.ppermute(x, MODEL, pairs)
        step *= 2
    return x


def pipe_perm(pp: int, groups: int, direction: int):
    """ppermute pairs for the intra-group stage ring (+1 fwd / −1 bwd)."""
    pairs = []
    for g in range(groups):
        base = g * pp
        for p in range(pp):
            src = base + p
            dst = base + (p + direction) % pp
            pairs.append((src, dst))
    return pairs


# --------------------------------------------------------------------------- #
# Flat-segment coalescing (see the DESIGN note in the module docstring)
# --------------------------------------------------------------------------- #


def build_flat_layout(specs: dict, gatherable, dsize: int, ep: bool,
                      *, ep_segment: bool = False) -> FlatLayout | None:
    """Static offsets for one stage segment's flat buffer (None if empty).

    ``ep_segment=True`` builds the *expert* segment instead: every named
    tensor must be EP-sharded (expert dim 0 over "data") and its expert
    dim must divide the data axis — the layout then packs each tensor's
    local expert shard (``ld == 0``), so one slab collective covers the
    stage's whole expert bank. A non-divisible expert dim returns None
    (per-tensor fallback).
    """
    entries = []
    off = 0
    for n in sorted(gatherable):
        sp = specs[n]
        if ep_segment:
            if not (sp.ep and ep) or not sp.shape or sp.shape[0] % dsize:
                return None  # mixed / non-divisible expert set: fall back
            ld = 0
        else:
            ld = local_dim(sp, dsize, ep)
            assert ld is not None and not (sp.ep and ep), (
                f"{n} is not flat-packable (replicated or EP)")
        size = int(np.prod(sp.shape)) // dsize
        entries.append(FlatEntry(name=n, shape=tuple(sp.shape), ld=ld,
                                 offset=off, size=size))
        off += size
    if not entries:
        return None
    return FlatLayout(entries=tuple(entries), local_size=off, dsize=dsize)


def _rest_shape(e: FlatEntry) -> tuple[int, ...]:
    return tuple(s for i, s in enumerate(e.shape) if i != e.ld)


def pack_flat_stack(seg_p: dict, fl: FlatLayout):
    """[V, local_size] slab stack from the per-rank local param stacks.

    ``seg_p[n]`` is the shard_map-local ``[V, *local_shape]`` stack (dim
    ``ld`` already divided by dsize). Packed once per step — the gather
    tick then just indexes a row.
    """
    parts = []
    V = None
    for e in fl.entries:
        x = seg_p[e.name]
        V = x.shape[0]
        parts.append(jnp.moveaxis(x, e.ld + 1, 1).reshape(V, e.size))
    return jnp.concatenate(parts, axis=1)


def all_gather_flat(local_slab, fl: FlatLayout):
    """ONE all-gather for the whole stage segment: [local] -> [full]."""
    return jax.lax.all_gather(local_slab, DATA, axis=0, tiled=True)


def unpack_flat(seg, fl: FlatLayout) -> dict:
    """Per-tensor views of a gathered [full_size] segment (static offsets)."""
    m = seg.reshape(fl.dsize, fl.local_size)
    out = {}
    for e in fl.entries:
        rest = _rest_shape(e)
        t = m[:, e.offset:e.offset + e.size].reshape(
            (e.shape[e.ld],) + rest)
        out[e.name] = jnp.moveaxis(t, 0, e.ld)
    return out


def unpack_flat_local(loc, fl: FlatLayout) -> dict:
    """Per-tensor local shards of a [local_size] slab (post reduce-scatter)."""
    out = {}
    for e in fl.entries:
        rest = _rest_shape(e)
        t = loc[e.offset:e.offset + e.size].reshape(
            (e.shape[e.ld] // fl.dsize,) + rest)
        out[e.name] = jnp.moveaxis(t, 0, e.ld)
    return out


def unpack_flat_stack(slab, fl: FlatLayout) -> dict:
    """Inverse of :func:`pack_flat_stack`: [V, local_size] slab stack back
    to the per-tensor ``{n: [V, *local_shape]}`` stacks."""
    V = slab.shape[0]
    out = {}
    for e in fl.entries:
        rest = _rest_shape(e)
        t = slab[:, e.offset:e.offset + e.size].reshape(
            (V, e.shape[e.ld] // fl.dsize) + rest)
        out[e.name] = jnp.moveaxis(t, 1, e.ld + 1)
    return out


def ep_allreduce_flat(slab, groups: int, pp: int, pod: bool = False):
    """Cross-group (+ cross-pod) reduction of one EP gradient slab.

    EP expert grads are already local-complete over "data"; the only
    collectives they need are the group butterfly and the pod psum.
    Coalescing a stage's expert tensors into ONE [V, ep_local_size] slab
    turns the per-tensor ppermute/psum chains into one collective each —
    bitwise identical values (both are element-exact and the per-element
    reduction order is unchanged; only the wire layout is coalesced).
    """
    out = group_allreduce(slab, groups, pp)
    if pod:
        out = jax.lax.psum(out, POD)
    return out


def ep_allreduce_flat_int8(slab, groups: int, pp: int, pod: bool = False):
    """int8 EP slab reduction: shared-scale quantize → int32 sum → dequant.

    The scale is pmax-shared over the summed axes so the integer
    accumulation is exact. Like the per-tensor EP int8 path there is no
    error-feedback buffer — the EP reduction runs once per step, so no
    later tick exists to re-inject feedback into. Identity meshes
    (groups == 1, no pods) skip quantization entirely: nothing is summed,
    so there is no wire to compress.
    """
    if groups == 1 and not pod:
        return slab
    gf = slab.astype(jnp.float32)
    local_scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    axes = (MODEL,) + ((POD,) if pod else ())
    scale = jax.lax.pmax(local_scale, axes)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int32)
    acc = group_allreduce(q, groups, pp)
    if pod:
        acc = jax.lax.psum(acc, POD)
    return acc.astype(jnp.float32) * scale


def _pack_full_flat(grads: dict, fl: FlatLayout, dtype):
    """[full_size] shard-major flat buffer from full-size per-rank grads."""
    parts = []
    for e in fl.entries:
        g = jnp.moveaxis(grads[e.name], e.ld, 0).astype(dtype)
        parts.append(g.reshape(fl.dsize, e.size))
    return jnp.concatenate(parts, axis=1).reshape(-1)


def reduce_scatter_flat(grads: dict, fl: FlatLayout, rs_dtype) -> dict:
    """ONE psum_scatter for the whole stage segment's gradients.

    ``grads`` are full-size per-rank accumulations; returns each tensor's
    reduced LOCAL shard (same values, bit-for-bit, as per-tensor
    ``reduce_scatter_grad`` — only the wire layout is coalesced).
    """
    flat = _pack_full_flat(grads, fl, jnp.dtype(rs_dtype))
    red = jax.lax.psum_scatter(flat, DATA, scatter_dimension=0, tiled=True)
    return unpack_flat_local(red, fl)


def reduce_scatter_flat_int8(grads: dict, err_flat, fl: FlatLayout):
    """int8 flat reduce with error feedback over the whole segment.

    Like :func:`reduce_scatter_grad_int8` but with ONE collective and one
    pmax-shared scale for the entire flat segment (coarser than the
    per-tensor scale — the error-feedback buffer absorbs the difference).
    ``err_flat`` is the [full_size] fp32 feedback carried across reduce
    ticks; returns (per-tensor local shards, new err_flat).
    """
    gf = _pack_full_flat(grads, fl, jnp.float32) + err_flat
    local_scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scale = jax.lax.pmax(local_scale, DATA)
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    red = jax.lax.psum_scatter(
        q.astype(jnp.int32), DATA, scatter_dimension=0, tiled=True
    ).astype(jnp.float32) * scale
    new_err = gf - q * scale
    return unpack_flat_local(red, fl), new_err


# --------------------------------------------------------------------------- #
# Optional int8 gradient compression with error feedback
# --------------------------------------------------------------------------- #


def reduce_scatter_grad_int8(g, err, spec: ParamSpec, dsize: int, ep: bool,
                             pod: bool = False):
    """int8 reduce path: shared-scale quantize → sum in int32 → dequantize.

    Quarters (vs fp32) the reduce traffic; quantization noise is carried in
    the per-tensor error-feedback buffer and re-injected next step
    (Karimireddy et al. semantics). The scale is pmax-shared over "data" so
    the integer sum is exact.
    """
    gf = g.astype(jnp.float32) + err
    local_scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    d = local_dim(spec, dsize, ep)
    if spec.ep and ep:
        scale = local_scale
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        out = q * scale
    else:
        scale = jax.lax.pmax(local_scale, DATA)
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        acc = q.astype(jnp.int32)
        if d is None:
            out = jax.lax.psum(acc, DATA).astype(jnp.float32) * scale
        else:
            out = jax.lax.psum_scatter(
                acc, DATA, scatter_dimension=d, tiled=True
            ).astype(jnp.float32) * scale
    new_err = gf - q * scale
    if pod:
        out = jax.lax.psum(out, POD)
    return out, new_err
