"""Schedule IR: typed tasks, per-rank tick tables, and validity checking.

A schedule is materialized as a dense tick table ``[T, P]`` of
``(kind, mb, v)`` cells plus per-tick FSDP communication events. The same
table drives (a) the discrete-event simulator (with a real cost model) and
(b) the SPMD executor (core/pipeline.py), so what we analyze is exactly
what runs.

Task kinds (int codes used in device tables):
  NOP=0, F=1, B=2 (input-grad, includes the remat re-forward), W=3
  (weight-grad GEMMs), and for serving F-only tables.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

NOP, F, B, W = 0, 1, 2, 3
KIND_NAMES = {NOP: "·", F: "F", B: "B", W: "W"}


@dataclasses.dataclass(frozen=True)
class Task:
    kind: int
    mb: int      # microbatch index within the step (0..n_mb-1)
    stage: int   # global stage id (0..S-1)

    def __repr__(self):
        return f"{KIND_NAMES[self.kind]}(u{self.mb},s{self.stage})"


@dataclasses.dataclass
class TickTable:
    """Dense schedule: cell [t, r] = Task or None. Plus comm events."""

    P: int                      # ranks per pipeline group
    V: int                      # stage slots per rank
    n_mb: int                   # B micro-batches
    unit: int                   # U scheduling-unit size
    grid: list[list[Task | None]]            # [T][P]
    # FSDP events: per tick per rank, gather/reduce of local slot v (or -1).
    gather: np.ndarray | None = None         # [T, P] int, -1 = none
    reduce: np.ndarray | None = None         # [T, P] int, -1 = none
    segment: str = "main"

    @property
    def T(self) -> int:
        return len(self.grid)

    def tasks(self) -> Iterable[tuple[int, int, Task]]:
        for t, row in enumerate(self.grid):
            for r, task in enumerate(row):
                if task is not None:
                    yield t, r, task

    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        """Check dependency, placement and completeness invariants."""
        P, V, n_mb = self.P, self.V, self.n_mb
        S = P * V
        start: dict[tuple[int, int, int], int] = {}
        for t, r, task in self.tasks():
            assert 0 <= task.stage < S, f"bad stage {task}"
            assert task.stage % P == r, (
                f"task {task} at rank {r}: circular placement requires "
                f"rank {task.stage % P}"
            )
            key = (task.kind, task.mb, task.stage)
            assert key not in start, f"duplicate {task}"
            start[key] = t

        # completeness
        has_bwd = any(k == B for (k, _, _) in start)
        has_w = any(k == W for (k, _, _) in start)
        for u in range(n_mb):
            for s in range(S):
                assert (F, u, s) in start, f"missing F(u{u},s{s})"
                if has_bwd:
                    assert (B, u, s) in start, f"missing B(u{u},s{s})"
                if has_w:
                    assert (W, u, s) in start, f"missing W(u{u},s{s})"

        # dependencies (producer tick < consumer tick; ppermute delivers
        # at the tick boundary)
        for (k, u, s), t in start.items():
            if k == F and s > 0:
                assert start[(F, u, s - 1)] < t, f"F dep violated u{u} s{s}"
            if k == B:
                assert start[(F, u, s)] < t, f"B needs F u{u} s{s}"
                if s < S - 1:
                    assert start[(B, u, s + 1)] < t, f"B dep violated u{u} s{s}"
            if k == W:
                assert start[(B, u, s)] <= t, f"W needs B u{u} s{s}"

        # unit-depth stash legality: a split-backward table claiming
        # ``unit < n_mb`` must actually be runnable on U-deep buffers
        # (fused baselines may carry a nominal unit label; they are
        # executed full-depth, so only W-bearing tables are gated here).
        if has_w and 0 < self.unit < self.n_mb:
            bad = unit_stash_violations(self)
            assert not bad, (
                f"table claims unit depth {self.unit} but violates the "
                f"stash-reuse window ({len(bad)} violation(s)): {bad[0]}")

    # ------------------------------------------------------------------ #
    def render(self, max_ticks: int | None = None) -> str:
        """ASCII timeline (ranks × ticks)."""
        out = []
        Tt = min(self.T, max_ticks or self.T)
        for r in range(self.P):
            row = []
            for t in range(Tt):
                task = self.grid[t][r]
                if task is None:
                    row.append(" · ")
                else:
                    row.append(
                        f"{KIND_NAMES[task.kind]}{task.mb:<2d}"
                    )
            out.append(f"r{r:<2d} " + "".join(row))
        return "\n".join(out)

    def counts(self) -> dict[str, int]:
        c = {"F": 0, "B": 0, "W": 0, "nop": 0, "gather": 0, "reduce": 0}
        for t, row in enumerate(self.grid):
            for r, task in enumerate(row):
                if task is None:
                    c["nop"] += 1
                else:
                    c[KIND_NAMES[task.kind]] += 1
        if self.gather is not None:
            c["gather"] = int((self.gather >= 0).sum())
        if self.reduce is not None:
            c["reduce"] = int((self.reduce >= 0).sum())
        return c

    def bubble_ratio(self) -> float:
        """Fraction of (tick, rank) slots idle between each rank's first
        and last task — the tick-quantized pipeline-bubble measure."""
        idle = 0
        span = 0
        for r in range(self.P):
            ticks = [t for t in range(self.T) if self.grid[t][r] is not None]
            if not ticks:
                continue
            lo, hi = ticks[0], ticks[-1]
            span += hi - lo + 1
            idle += (hi - lo + 1) - len(ticks)
        return idle / max(span, 1)


def unit_stash_violations(tt: "TickTable") -> list[str]:
    """Unit-depth buffer legality: the reasons a table with ``unit < n_mb``
    could NOT run on U-deep stash/wire buffers.

    The executor (core/executor.py) holds every per-micro-batch buffer at
    unit depth, indexed by ``mb % U``: ``fstash``/``wx``/``wdy`` (the F→B
    activation and B→W (x, dy) stashes) and ``xbuf``/``bbuf`` (the wire
    landing buffers). Micro-batch ``u + U`` therefore *overwrites* micro-
    batch ``u``'s slot, so every reader of slot ``u % U`` must run before
    the overwrite lands:

      * ``W(u, s)`` before ``B(u+U, s)``   — the B→W (x, dy) stash; this
        is the "B→W distance exceeds the unit-depth stash" check the §4
        postponed-W tables used to violate;
      * ``B(u, s)`` before ``F(u+U, s)``   — the F→B activation stash;
      * ``F(u, s)`` no later than ``F(u+U, s-1)`` — the fwd wire buffer
        (the overwriting activation lands one tick after its producer);
      * ``B(u, s)`` no later than ``B(u+U, s+1)`` — the bwd wire buffer.

    Pairwise-nearest checks suffice: together with the task dependencies
    they order all same-slot occupants transitively. Returns a list of
    human-readable violations (empty = legal at depth ``tt.unit``).

    The same window rules gate packed tables at the engine boundary
    (``core/executor.py:validate_unit_stash_packed``) through
    ``stash_window_violations`` below, so the two layers cannot drift.
    """
    tick = {(task.kind, task.mb, task.stage): t
            for t, _, task in tt.tasks()}
    return stash_window_violations(tick, tt.unit, tt.n_mb, tt.P * tt.V)


def stash_window_violations(tick: dict, U: int, n_mb: int, S: int,
                            ) -> list[str]:
    """The shared stash-window rule set over a (kind, mb, stage) → tick
    map (see ``unit_stash_violations`` for the derivation)."""
    if U <= 0 or U >= n_mb:
        return []
    out: list[str] = []

    def _chk(a, b, strict, what):
        ta, tb = tick.get(a), tick.get(b)
        if ta is None or tb is None:
            return
        if (ta >= tb) if strict else (ta > tb):
            out.append(
                f"{what}: {KIND_NAMES[a[0]]}(u{a[1]},s{a[2]})@t{ta} vs "
                f"{KIND_NAMES[b[0]]}(u{b[1]},s{b[2]})@t{tb} "
                f"(unit depth {U})")

    for u in range(n_mb - U):
        for s in range(S):
            _chk((W, u, s), (B, u + U, s), True, "B->W stash overwrite")
            _chk((B, u, s), (F, u + U, s), True, "F->B stash overwrite")
            if s > 0:
                _chk((F, u, s), (F, u + U, s - 1), False,
                     "fwd wire overwrite")
            if s < S - 1:
                _chk((B, u, s), (B, u + U, s + 1), False,
                     "bwd wire overwrite")
    return out


def stage_of(rank: int, v: int, P: int) -> int:
    return v * P + rank


def rank_of(stage: int, P: int) -> int:
    return stage % P


def slot_of(stage: int, P: int) -> int:
    return stage // P


def to_arrays(tt: TickTable):
    """Pack the table into device-ready int32 arrays.

    Returns dict of [T, P] arrays: kind, mb, v  (+ gather/reduce slots).
    """
    T, P = tt.T, tt.P
    kind = np.zeros((T, P), np.int32)
    mb = np.zeros((T, P), np.int32)
    v = np.zeros((T, P), np.int32)
    for t, r, task in tt.tasks():
        kind[t, r] = task.kind
        mb[t, r] = task.mb
        v[t, r] = slot_of(task.stage, P)
    gather = tt.gather if tt.gather is not None else -np.ones((T, P), np.int32)
    reduce = tt.reduce if tt.reduce is not None else -np.ones((T, P), np.int32)
    return {
        "kind": kind, "mb": mb, "v": v,
        "gather": gather.astype(np.int32), "reduce": reduce.astype(np.int32),
    }
