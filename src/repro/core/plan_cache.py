"""Persisted on-disk plan cache for ``select_plan`` outcomes.

A production job should pay for the §4 schedule search — and for the
``auto_profiled`` measured refinement, which compiles and times real
steps — exactly once per (arch × shape × mesh × preset × knobs ×
code-version) point, across *processes*. This module stores the winner's
TickTable (via ``to_arrays``) plus every candidate's analysis in one
JSON file, so a warm hit rebuilds the selection with zero schedule
generation, zero simulation and zero measurement: pure array
reconstruction + ``pack_table``.

Location: ``~/.cache/repro/plans.json`` by default; the
``REPRO_PLAN_CACHE`` env var overrides the path (repo-local caches for
CI), and the values ``0``/``off``/``none`` disable persistence entirely.

Invalidation is by fingerprint, not by deleting entries: every entry
records a hash of (cost-model profile × knob schema × code salt), where
the code salt covers the schedule-generation/simulation sources. An
entry whose fingerprint no longer matches is treated as a miss — a
changed α–β profile, a new selection knob, or edited scheduling code can
never serve a stale plan. Corrupt or partial cache files (killed writer,
concurrent truncation, hand edits) degrade to a clean search; they never
raise into the session.

The same file carries a ``measurements`` section: the hillclimb
(``benchmarks/hillclimb.py``) records every measured knob-vector there,
keyed by vector + code salt, which is what makes an interrupted climb
resumable.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile

import numpy as np

DEFAULT_PATH = "~/.cache/repro/plans.json"
ENV_VAR = "REPRO_PLAN_CACHE"
_OFF_VALUES = ("0", "off", "none", "disabled")
_VERSION = 1

# sources whose edits can change what select_plan would pick — the code
# salt folds their bytes into every entry fingerprint
_SALT_FILES = ("plan.py", "simulator.py", "schedules.py", "generators.py",
               "autogen.py")
_SALT_CACHE: dict[str, str] = {}


def cache_path() -> str | None:
    """Resolved cache file path, or None when persistence is disabled."""
    v = os.environ.get(ENV_VAR)
    if v is not None:
        if v.strip().lower() in _OFF_VALUES:
            return None
        return os.path.abspath(os.path.expanduser(v))
    return os.path.expanduser(DEFAULT_PATH)


def code_salt() -> str:
    """Hash of the schedule-generation/simulation sources (cached)."""
    d = os.path.dirname(os.path.abspath(__file__))
    if d not in _SALT_CACHE:
        h = hashlib.sha256()
        for fn in _SALT_FILES:
            try:
                with open(os.path.join(d, fn), "rb") as f:
                    h.update(f.read())
            except OSError:
                h.update(fn.encode())
        _SALT_CACHE[d] = h.hexdigest()[:16]
    return _SALT_CACHE[d]


def fingerprint(cm, knob_schema: tuple) -> str:
    """Entry validity stamp: cost-model profile × knob schema × code.

    ``knob_schema`` is the *names* of the key components (not their
    values — values live in the key itself): adding a selection knob in
    a later version changes the schema and invalidates every old entry.
    """
    payload = {
        "cost_model": dataclasses.asdict(cm),
        "knob_schema": list(knob_schema),
        "salt": code_salt(),
    }
    blob = json.dumps(payload, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def entry_key(cache_key: tuple) -> str:
    """Stable string form of a selection cache key tuple."""
    return "|".join(repr(k) for k in cache_key)


# --------------------------------------------------------------------------- #
# (De)serialization
# --------------------------------------------------------------------------- #


def table_record(tt) -> dict:
    """JSON-able form of a TickTable (dense arrays; no Task objects)."""
    from repro.core.schedules import to_arrays

    arr = to_arrays(tt)
    return {
        "P": tt.P, "V": tt.V, "n_mb": tt.n_mb, "unit": tt.unit,
        "segment": tt.segment,
        "kind": arr["kind"].tolist(), "mb": arr["mb"].tolist(),
        "v": arr["v"].tolist(), "gather": arr["gather"].tolist(),
        "reduce": arr["reduce"].tolist(),
    }


def table_from_record(rec: dict):
    """Rebuild a TickTable from :func:`table_record` output (validated)."""
    from repro.core.schedules import NOP, Task, TickTable

    P, V, n_mb = int(rec["P"]), int(rec["V"]), int(rec["n_mb"])
    kind = np.asarray(rec["kind"], np.int32)
    mb = np.asarray(rec["mb"], np.int32)
    v = np.asarray(rec["v"], np.int32)
    if kind.ndim != 2 or kind.shape[1] != P or kind.shape != mb.shape \
            or kind.shape != v.shape:
        raise ValueError(f"table arrays malformed: {kind.shape}")
    grid = [[(Task(int(kind[t, r]), int(mb[t, r]), int(v[t, r]) * P + r)
              if kind[t, r] != NOP else None)
             for r in range(P)] for t in range(kind.shape[0])]
    tt = TickTable(
        P=P, V=V, n_mb=n_mb, unit=int(rec["unit"]), grid=grid,
        gather=np.asarray(rec["gather"], np.int32),
        reduce=np.asarray(rec["reduce"], np.int32),
        segment=rec.get("segment", "main"))
    tt.validate()
    return tt


def selection_record(sel) -> dict:
    """JSON-able form of a PlanSelection (winner table + all analyses)."""
    from repro.core.plan import PlanAnalysis

    win = sel.selected
    return {
        "schedule": win.name,
        "sched_params": dataclasses.asdict(win.params),
        "prefetch": win.prefetch,
        "table": table_record(win.table),
        "analysis": sel.analysis.as_dict(),
        "candidates": {
            n: (a.as_dict() if isinstance(a, PlanAnalysis) else str(a))
            for n, a in sel.candidates.items()},
        "preset": sel.preset,
        "mem_budget": sel.mem_budget,
        "provenance": sel.provenance,
        "measured": sel.measured,
        "profile": sel.profile,
    }


def selection_from_record(rec: dict, cache_key: tuple):
    """Rebuild a PlanSelection — no generate/autogen/simulate calls."""
    from repro.core.generators import SchedParams
    from repro.core.plan import PlanAnalysis, PlanSelection, SchedulePlan

    sp_fields = {f.name for f in dataclasses.fields(SchedParams)}
    sp = SchedParams(**{k: v for k, v in rec["sched_params"].items()
                        if k in sp_fields})
    plan = SchedulePlan.from_table(rec["schedule"], sp,
                                   table_from_record(rec["table"]),
                                   prefetch=int(rec["prefetch"]))
    ana_fields = {f.name for f in dataclasses.fields(PlanAnalysis)}

    def _ana(d):
        if not isinstance(d, dict):
            return str(d)
        return PlanAnalysis(**{k: v for k, v in d.items()
                               if k in ana_fields})

    analysis = _ana(rec["analysis"])
    if not isinstance(analysis, PlanAnalysis):
        raise ValueError("winner analysis malformed")
    # seed the plan's per-preset analysis cache so .analyze() under the
    # same collective profile returns the stored numbers without a sim
    plan.analyses[(analysis.preset, analysis.n_coll_gather,
                   analysis.n_coll_reduce, analysis.coll_alpha,
                   analysis.n_a2a_f, analysis.n_a2a_b,
                   analysis.t_a2a)] = analysis
    return PlanSelection(
        selected=plan, analysis=analysis, preset=rec["preset"],
        candidates={n: _ana(a) for n, a in rec["candidates"].items()},
        key=cache_key, mem_budget=rec.get("mem_budget"),
        provenance="cache:disk",
        measured=rec.get("measured"), profile=rec.get("profile"))


# --------------------------------------------------------------------------- #
# File I/O (best-effort, never raises into the caller)
# --------------------------------------------------------------------------- #


def _read(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        if not isinstance(data, dict) or \
                data.get("version") != _VERSION or \
                not isinstance(data.get("entries"), dict):
            return {"version": _VERSION, "entries": {}, "measurements": {}}
        data.setdefault("measurements", {})
        return data
    except (OSError, ValueError):
        # missing / corrupt / truncated file: clean-search fallback
        return {"version": _VERSION, "entries": {}, "measurements": {}}


def _write(path: str, data: dict) -> bool:
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=".plans-", suffix=".json")
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return True
    except OSError:
        return False


def load_entry(cache_key: tuple, fp: str):
    """The stored record for (key, fingerprint) or None (miss/invalid)."""
    path = cache_path()
    if path is None:
        return None
    ent = _read(path)["entries"].get(entry_key(cache_key))
    if not isinstance(ent, dict) or ent.get("fp") != fp:
        return None
    return ent.get("record")


def store_entry(cache_key: tuple, fp: str, record: dict) -> bool:
    """Write (merge) one selection record; False when disabled/failed."""
    path = cache_path()
    if path is None:
        return False
    data = _read(path)   # re-read: merge with concurrent writers
    data["entries"][entry_key(cache_key)] = {"fp": fp, "record": record}
    return _write(path, data)


def load_measurement(key: str):
    """Stored hillclimb measurement for ``key`` (code-salt gated)."""
    path = cache_path()
    if path is None:
        return None
    ent = _read(path)["measurements"].get(key)
    if not isinstance(ent, dict) or ent.get("salt") != code_salt():
        return None
    return ent.get("value")


def store_measurement(key: str, value) -> bool:
    path = cache_path()
    if path is None:
        return False
    data = _read(path)
    data["measurements"][key] = {"salt": code_salt(), "value": value}
    return _write(path, data)


def clear_disk() -> bool:
    """Delete the persisted cache file (True if one was removed)."""
    path = cache_path()
    if path is None:
        return False
    try:
        os.remove(path)
        return True
    except OSError:
        return False


def info() -> dict:
    """Persisted-cache summary for ``plan_cache_info()``."""
    path = cache_path()
    if path is None:
        return {"path": None, "enabled": False, "entries": 0,
                "measurements": 0}
    data = _read(path)
    return {"path": path, "enabled": True,
            "entries": len(data["entries"]),
            "measurements": len(data["measurements"])}
