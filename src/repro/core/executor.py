"""The shared SPMD tick machine and its train/serve handler sets.

One ``TickEngine`` runs every schedule-plan table — train, serve,
encoder/decoder segments, ZeroPP and all baselines. Each tick it:

  1. stores incoming wires (activations fwd / input-grads bwd) into
     micro-batch buffers per the plan's static receive maps;
  2. conditionally issues this tick's blockwise FSDP all-gather (§3.3)
     into a rotating two-slot buffer;
  3. dispatches this rank's table cell through a branch-handler table
     ({NOP, F, B, W} for training, {NOP, F} for serving);
  4. (training) conditionally reduce-scatters a finished stage block's
     gradients (once per scheduling unit, §3.3);
  5. runs the boundary ``ppermute``s around the intra-group stage ring.

Steps 1/2/4/5 — the gather/reduce/wire plumbing — live here once; the
bodies below (``train_body`` / ``serve_body``) only supply the branch
handlers (F/B/W math, loss seeding, KV-cache get/put) and the carry
extras those handlers need. ``core/pipeline.py`` keeps the Runtime and
the jit/shard_map step builders on top of these bodies.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import fsdp
from repro.core.plan import PackedTable
from repro.core.tape import Tape
from repro.models import blocks, model as M
from repro.models.common import rope_tables

DATA, MODEL, POD = "data", "model", "pod"


# --------------------------------------------------------------------------- #
# Small dynamic-index helpers (shared by all handlers)
# --------------------------------------------------------------------------- #


def _dyn_set2(buf, i, j, val):
    """buf[i, j] = val with dynamic scalar indices."""
    row = jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
    row = jax.lax.dynamic_update_index_in_dim(row, val, j, 0)
    return jax.lax.dynamic_update_index_in_dim(buf, row, i, 0)


def _dyn_get2(buf, i, j):
    row = jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
    return jax.lax.dynamic_index_in_dim(row, j, 0, keepdims=False)


def _dyn_add(buf, i, val):
    old = jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
    return jax.lax.dynamic_update_index_in_dim(buf, old + val, i, 0)


def _gathered_shape(spec, dsize, ep):
    return spec.shape


def _local_shape(spec, dsize, ep):
    ld = fsdp.local_dim(spec, dsize, ep)
    if ld is None:
        return spec.shape
    sh = list(spec.shape)
    sh[ld] = sh[ld] // dsize
    return tuple(sh)


def _loss_iog_proto(cfg, io_p, vloc):
    names = ["final_norm.scale"]
    if cfg.norm == "layernorm":
        names.append("final_norm.bias")
    names.append("embed.table" if cfg.tie_embeddings else "head.w")
    if cfg.mtp:
        names += [n for n in io_p
                  if n.startswith(("mtp.proj", "mtp.layer", "mtp.norm"))]
        if not cfg.tie_embeddings:
            names.append("embed.table")  # MTP ties emb grads in too
    return {n: io_p[n] for n in names}


def _rope_for(cfg, rc, seq):
    dims = {cfg.head_dim}
    if cfg.mla is not None:
        dims.add(cfg.mla.rope_dims)
    return {e: rope_tables(seq, e, cfg.rope_theta) for e in dims}


def make_tok_slice(g_rank, Btot: int, mbs: int) -> Callable:
    """This rank's micro-batch slice of a [global_batch, ...] array."""
    def tok_slice(arr, u):
        start = (g_rank * Btot + u) * mbs
        return jax.lax.dynamic_slice_in_dim(arr, start, mbs, axis=0)
    return tok_slice


# --------------------------------------------------------------------------- #
# The tick engine
# --------------------------------------------------------------------------- #


_FLAT = "__flat__"  # gbuf key of the coalesced flat segment


def validate_unit_stash_packed(pt: PackedTable) -> None:
    """Reject packed tables whose task spacing exceeds the U-deep buffers.

    The engine's ``fstash``/``wx``/``wdy``/``xbuf``/``bbuf`` carries are
    ``pt.U`` deep and indexed by ``mb % U``, so micro-batch ``u + U``
    overwrites ``u``'s slot; a table where a postponed W (or a late B)
    outlives its slot would silently replay the *wrong* micro-batch's
    stash. ``pack_table`` already gates TickTables; this re-checks the
    packed arrays at the engine boundary — with the SAME window rules
    (``schedules.stash_window_violations``) — so an injected PackedTable
    can never scan with an illegal stash depth. (Cheap: one pass over
    the [T, Pe] grids at trace time.)
    """
    from repro.core.schedules import stash_window_violations

    U, n_mb = pt.U, pt.n_mb
    if not (0 < U < n_mb):
        return
    tick: dict[tuple, int] = {}
    for t in range(pt.T):
        for r in range(pt.Pe):
            k = int(pt.kind[t, r])
            if k:
                s = int(pt.v[t, r]) * pt.Pe + r
                tick[(k, int(pt.mb[t, r]), s)] = t
    bad = stash_window_violations(tick, U, n_mb, pt.Pe * pt.V)
    if bad:
        raise ValueError(
            f"packed table illegal at unit depth U={U}: "
            f"{len(bad)} stash violation(s), first: {bad[0]}")


@dataclasses.dataclass
class TickEngine:
    """Scans one PackedTable with the shared gather/reduce/wire plumbing.

    Handlers receive ``(carry, row)`` and return the updated carry; they
    read stage parameters via ``stage_params`` and may use any extra
    carry entries the body placed there. ``rs_dtype`` enables the
    per-unit reduce-scatter step (training only).

    With a ``flat`` layout (``RunConfig.coalesce="flat"``), the gather
    tick issues ONE ``all_gather`` of the pre-packed per-slot slab
    (``seg_flat``) and the reduce tick ONE ``psum_scatter`` of the
    coalesced gradient segment, regardless of tensor count; per-tensor
    views come from the gathered slab via static offsets. Tensors the
    layout cannot cover (replicated / EP) keep the per-tensor path.
    """

    pt: PackedTable
    Pe: int
    G: int
    V: int
    specs: dict
    gatherable: list
    seg_p: dict
    dsize: int
    ep: bool
    cdt: Any
    p_rank: Any
    g_rank: Any
    backward: bool = False
    rs_dtype: Any = None
    flat: Any = None        # FlatLayout | None (coalesced collectives)
    seg_flat: Any = None    # [V, local_size] pre-packed local slabs
    grad_compress: str = "none"   # none | int8 (error-feedback reduce)

    def __post_init__(self):
        # Unit-gated tables (stash depth U < n_mb) are only runnable when
        # every stash/wire slot is read before its mb+U overwrite lands.
        if self.backward:
            validate_unit_stash_packed(self.pt)

    # ------------------------------------------------------------------ #
    def stage_params(self, v, use_slot, gbuf):
        """Params of local slot v: gathered buffer or resident stack."""
        out = {}
        if self.flat is not None and self.gatherable:
            slab = jax.lax.dynamic_index_in_dim(
                gbuf[_FLAT], jnp.clip(use_slot, 0, 1), 0, keepdims=False)
            out.update(fsdp.unpack_flat(slab, self.flat))
        for n in self.specs:
            if n in self.gatherable:
                if self.flat is None:
                    out[n] = jax.lax.dynamic_index_in_dim(
                        gbuf[n], jnp.clip(use_slot, 0, 1), 0,
                        keepdims=False)
            else:
                out[n] = jax.lax.dynamic_index_in_dim(
                    self.seg_p[n], jnp.clip(v, 0, self.V - 1), 0,
                    keepdims=False)
        return out

    def init_gbuf(self):
        """Rotating two-slot buffer for blockwise FSDP gathers."""
        if self.flat is not None:
            if not self.gatherable:
                return {}
            return {_FLAT: jnp.zeros((2, self.flat.full_size), self.cdt)}
        return {
            n: jnp.zeros(
                (2, *_gathered_shape(self.specs[n], self.dsize, self.ep)),
                self.cdt)
            for n in self.gatherable
        }

    def init_gerr(self):
        """fp32 error-feedback buffers for the int8 reduce path.

        int8 compression covers the gatherable (FSDP reduce-scatter) set —
        the bulk of the traffic; replicated/EP tensors keep fp reduces.
        """
        if self.grad_compress != "int8" or not self.gatherable:
            return None
        if self.flat is not None:
            return {_FLAT: jnp.zeros((self.V, self.flat.full_size),
                                     jnp.float32)}
        return {
            n: jnp.zeros((self.V, *self.specs[n].shape), jnp.float32)
            for n in self.gatherable
        }

    # ------------------------------------------------------------------ #
    def _store_wires(self, c, row):
        """Step 1: land last boundary's wires in the mb buffers."""
        Btot, U = self.pt.n_mb, self.pt.U
        ruf = row["recv_f_u"]
        c["xbuf"] = jax.lax.cond(
            ruf >= 0,
            lambda b: jax.lax.dynamic_update_index_in_dim(
                b, c["recv_f"], jnp.clip(ruf, 0, Btot) % U, 0),
            lambda b: b, c["xbuf"])
        if self.backward:
            rub = row["recv_b_u"]
            c["bbuf"] = jax.lax.cond(
                rub >= 0,
                lambda b: jax.lax.dynamic_update_index_in_dim(
                    b, c["recv_b"], jnp.clip(rub, 0, Btot) % U, 0),
                lambda b: b, c["bbuf"])
        return c

    def _gather_step(self, c, row):
        """Step 2: blockwise FSDP gather into the rotating slot.

        Flat layout: ONE all_gather of the slot's pre-packed slab; else
        one all_gather per gatherable tensor.
        """
        gv, gs = row["gather_v"], row["gather_slot"]

        def do_gather(gb):
            gb = dict(gb)
            if self.flat is not None:
                pv = jax.lax.dynamic_index_in_dim(
                    self.seg_flat, jnp.clip(gv, 0, self.V - 1), 0,
                    keepdims=False)
                full = fsdp.all_gather_flat(pv, self.flat)
                gb[_FLAT] = jax.lax.dynamic_update_index_in_dim(
                    gb[_FLAT], full.astype(self.cdt), jnp.clip(gs, 0, 1),
                    0)
                return gb
            for n in self.gatherable:
                pv = jax.lax.dynamic_index_in_dim(
                    self.seg_p[n], jnp.clip(gv, 0, self.V - 1), 0,
                    keepdims=False)
                ld = fsdp.local_dim(self.specs[n], self.dsize, self.ep)
                full = jax.lax.all_gather(pv, DATA, axis=ld, tiled=True)
                gb[n] = jax.lax.dynamic_update_index_in_dim(
                    gb[n], full.astype(self.cdt), jnp.clip(gs, 0, 1), 0)
            return gb

        if self.gatherable:
            c["gbuf"] = jax.lax.cond(gv >= 0, do_gather, lambda gb: gb,
                                     c["gbuf"])
        return c

    def _reduce_step(self, c, row):
        """Step 4: per-unit blockwise reduce-scatter of finished grads.

        Flat layout: ONE psum_scatter coalesces every gatherable tensor's
        gradient; replicated/EP leftovers keep their per-tensor reduces.
        ``grad_compress="int8"`` routes the gatherable set through the
        error-feedback int8 path (``c["gerr"]`` carries the feedback).

        Overlap safety: the plan places each reduce at its unit's last-W
        tick, and the scattered shard is only consumed after the scan (by
        the optimizer step), never by a later tick — so XLA is free to
        run the collective asynchronously under the next unit's B/W
        compute. The simulator models exactly this window (a tail
        reduce-scatter overlapping the following unit; see
        ``core/simulator.py``), and it is sound even if a next-unit B
        accumulates into ``acc_full`` before this tick's scatter drains
        it: reduce-scatter is linear and every contribution passes
        through exactly one scatter, so the per-shard sum is unchanged.
        """
        rv = row["reduce_v"]
        rs_dt = jnp.dtype(self.rs_dtype)
        flat_set = set(self.gatherable) if self.flat is not None else set()
        int8 = self.grad_compress == "int8" and bool(self.gatherable)

        def do_reduce(args):
            full, shard = dict(args[0]), dict(args[1])
            gerr = dict(args[2]) if int8 else None
            rv_c = jnp.clip(rv, 0, self.V - 1)
            if flat_set:
                grads = {n: jax.lax.dynamic_index_in_dim(
                    full[n], rv_c, 0, keepdims=False) for n in flat_set}
                if int8:
                    err_v = jax.lax.dynamic_index_in_dim(
                        gerr[_FLAT], rv_c, 0, keepdims=False)
                    red, new_err = fsdp.reduce_scatter_flat_int8(
                        grads, err_v, self.flat)
                    gerr[_FLAT] = jax.lax.dynamic_update_index_in_dim(
                        gerr[_FLAT], new_err, rv_c, 0)
                else:
                    red = fsdp.reduce_scatter_flat(grads, self.flat, rs_dt)
                for n, r in red.items():
                    shard[n] = _dyn_add(shard[n], rv,
                                        r.astype(jnp.float32))
                    full[n] = jax.lax.dynamic_update_index_in_dim(
                        full[n], jnp.zeros_like(grads[n]), rv_c, 0)
            for n in full:
                if n in flat_set:
                    continue
                g = jax.lax.dynamic_index_in_dim(full[n], rv_c, 0,
                                                 keepdims=False)
                if int8 and self.flat is None and n in self.gatherable:
                    err_v = jax.lax.dynamic_index_in_dim(
                        gerr[n], rv_c, 0, keepdims=False)
                    red_t, new_err = fsdp.reduce_scatter_grad_int8(
                        g, err_v, self.specs[n], self.dsize, self.ep)
                    gerr[n] = jax.lax.dynamic_update_index_in_dim(
                        gerr[n], new_err, rv_c, 0)
                else:
                    red_t = fsdp.reduce_scatter_grad(g.astype(rs_dt),
                                                     self.specs[n],
                                                     self.dsize, self.ep)
                shard[n] = _dyn_add(shard[n], rv,
                                    red_t.astype(jnp.float32))
                full[n] = jax.lax.dynamic_update_index_in_dim(
                    full[n], jnp.zeros_like(g), rv_c, 0)
            out = (full, shard) + ((gerr,) if int8 else ())
            return out

        operands = (c["acc_full"], c["acc_shard"]) + (
            (c["gerr"],) if int8 else ())
        res = jax.lax.cond(rv >= 0, do_reduce, lambda a: a, operands)
        c["acc_full"], c["acc_shard"] = res[0], res[1]
        if int8:
            c["gerr"] = res[2]
        return c

    def _boundary(self, c):
        """Step 5: boundary permutes (intra-group stage rings)."""
        c["recv_f"] = jax.lax.ppermute(c["send_f"], MODEL,
                                       fsdp.pipe_perm(self.Pe, self.G, +1))
        if self.backward:
            c["recv_b"] = jax.lax.ppermute(
                c["send_b"], MODEL, fsdp.pipe_perm(self.Pe, self.G, -1))
        return c

    # ------------------------------------------------------------------ #
    def run(self, carry, branches: list):
        """lax.scan the plan's ticks, dispatching cells to ``branches``.

        ``branches`` is the {NOP, F[, B, W]} handler table; a 2-entry
        table (serving) clamps B/W cells to the F handler's index so
        forward-only tables never index out of range.
        """
        def tick(c, row_all):
            row = {k: a[self.p_rank] for k, a in row_all.items()}
            c = dict(c)
            c = self._store_wires(c, row)
            c = self._gather_step(c, row)
            kind = (row["kind"] if len(branches) == 4
                    else jnp.minimum(row["kind"], len(branches) - 1))
            c = jax.lax.switch(kind, branches, c, row)
            if self.rs_dtype is not None:
                c = self._reduce_step(c, row)
            c = self._boundary(c)
            return c, ()

        carry, _ = jax.lax.scan(tick, carry, self.pt.rows())
        return carry


# --------------------------------------------------------------------------- #
# Training: segment scan as F/B/W handlers over the engine
# --------------------------------------------------------------------------- #


def segment_train_scan(
    rt, seg, pt: PackedTable, seg_p, io_p, batch, mbs, seq,
    vloc, denom, aux_seed, io_g0, metrics0, p_rank, g_rank, *,
    inject: str, seed: str | None, membuf, dmembuf, seed_buf=None,
    carry_in=None, tmpl_override=None,
):
    """Run one segment's schedule-plan as a tick-engine scan.

    inject:  batch key providing stage-0 inputs (int tokens or float embeds)
    seed:    "loss" (LM head at last stage) | "buffer" (seed_buf[u]) | None
    membuf:  None | "collect" (store drain outputs) | array [U, mbs, ctx, d]
             (cross-attention memory for decoder segments)
    dmembuf: "collect" to accumulate d(enc_memory) during B tasks
    carry_in: reuse stash buffers from a previous scan of the same segment
    """
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    cdt = jnp.dtype(rc.compute_dtype)
    d = cfg.d_model
    V, Pe, G, U = seg.vpp, rt.Pe, rt.G, pt.U
    Btot = pt.n_mb
    S = Pe * V
    specs = rt.stage_specs[seg.name]
    gatherable = rt.gatherable[seg.name]
    ep_names = set(rt.ep_names[seg.name])
    ep_axis = DATA if (rt.ep and any(
        k.endswith(":moe") for k in seg.kinds)) else None
    has_cross = membuf is not None and not isinstance(membuf, str)
    cross_ctx = cfg.encdec.enc_ctx if (has_cross and cfg.encdec) else None
    # Fused-backward baselines have no W tasks: every dense's dW is
    # computed immediately inside B (classic 1F1B/GPipe semantics).
    if tmpl_override is not None:
        no_defer, tmpl = tmpl_override
    else:
        no_defer = set(ep_names) if pt.has_w else set(specs)
        if rc.no_defer_extra and pt.has_w:
            no_defer |= {n for n in specs
                         if any(sub in n for sub in rc.no_defer_extra)}
        tmpl = rt._stash_tmpl(seg, (mbs, seq), no_defer,
                              cross_ctx=cross_ctx)
    tokens = batch[inject]
    int_tokens = jnp.issubdtype(tokens.dtype, jnp.integer)
    labels = batch.get("labels")

    rope = _rope_for(cfg, rc, seq)
    dsize = rt.dsize

    flat = rt.flat_layouts.get(seg.name)
    eng = TickEngine(
        pt=pt, Pe=Pe, G=G, V=V, specs=specs, gatherable=gatherable,
        seg_p=seg_p, dsize=dsize, ep=rt.ep, cdt=cdt,
        p_rank=p_rank, g_rank=g_rank, backward=True,
        rs_dtype=rc.grad_rs_dtype, flat=flat,
        seg_flat=(fsdp.pack_flat_stack(seg_p, flat)
                  if flat is not None else None),
        grad_compress=rc.grad_compress)
    tok_slice = make_tok_slice(g_rank, Btot, mbs)
    stage_params = eng.stage_params

    # ---- carry ------------------------------------------------------------ #
    act = (mbs, seq, d)
    zeros_act = jnp.zeros(act, cdt)
    gerr0 = eng.init_gerr()
    if carry_in is None:
        carry = dict(
            send_f=zeros_act, send_b=zeros_act,
            recv_f=zeros_act, recv_b=zeros_act,
            xbuf=jnp.zeros((U, *act), cdt),
            bbuf=jnp.zeros((U, *act), cdt),
            fstash=jnp.zeros((V, U, *act), cdt),
            wx=[jnp.zeros((V, U, *sh), dt) for sh, dt in tmpl.x_shapes],
            wdy=[jnp.zeros((V, U, *sh), dt) for sh, dt in tmpl.dy_shapes],
            gbuf=eng.init_gbuf(),
            acc_full={n: jnp.zeros((V, *specs[n].shape), jnp.float32)
                      for n in specs if n not in ep_names},
            **({"gerr": gerr0} if gerr0 is not None else {}),
            acc_shard={n: jnp.zeros(
                (V, *_local_shape(specs[n], dsize, rt.ep)), jnp.float32)
                for n in specs},
            io_g=io_g0,
            metrics=metrics0,
        )
    else:
        carry = carry_in
        carry["io_g"] = io_g0
        carry["metrics"] = metrics0
    if membuf == "collect":
        carry["membuf"] = jnp.zeros((Btot, mbs, seq, d), cdt)
    if dmembuf == "collect":
        enc_ctx2 = cfg.encdec.enc_ctx
        carry["dmembuf"] = jnp.zeros((Btot, mbs, enc_ctx2, d), cdt)

    # ---- branch bodies ----------------------------------------------------#
    track_moe = "moe_load" in metrics0 and any(
        k.endswith(":moe") for k in seg.kinds)  # rc.moe_stats histograms

    def make_ctx(tape, u):
        """Returns (ctx, mem_tval or None)."""
        mem = None
        if has_cross:
            mem = tape.value(jax.lax.dynamic_index_in_dim(
                membuf, u, 0, keepdims=False))
        ctx = blocks.LayerCtx(cfg=cfg, rc=rc, rope=rope, causal=seg.causal,
                              ep_axis=ep_axis, enc_memory=mem)
        return ctx, mem

    def get_input(c, u, v):
        uu = u % U
        x = jax.lax.dynamic_index_in_dim(c["xbuf"], uu, 0, keepdims=False)
        is_inject = (p_rank == 0) & (v == 0)

        def do_embed(_):
            ids_or_emb = tok_slice(tokens, u)
            if int_tokens:
                return Vb.embed_lookup(io_p["embed.table"], ids_or_emb,
                                       vloc, cdt)
            return ids_or_emb.astype(cdt)

        return jax.lax.cond(is_inject, do_embed, lambda _: x, None)

    def f_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        x = get_input(c, u, v)
        params_v = stage_params(v, row["use_slot"], c["gbuf"])
        t = Tape(params_v, mode="fwd", no_defer=frozenset(no_defer))
        stage_id = v * Pe + p_rank
        ctx, _ = make_ctx(t, u)
        y, _aux = M.apply_stage(t, ctx, seg, t.value(x), stage_id)
        c = dict(c)
        c["fstash"] = _dyn_set2(c["fstash"], v, uu, x)
        c["send_f"] = y.val
        if "membuf" in c:
            is_drain = (p_rank == Pe - 1) & (v == V - 1)
            c["membuf"] = jax.lax.cond(
                is_drain,
                lambda mb: jax.lax.dynamic_update_index_in_dim(
                    mb, y.val, u, 0),
                lambda mb: mb, c["membuf"])
        return c

    def b_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        x = jax.lax.dynamic_index_in_dim(c["fstash"], jnp.clip(v, 0, V - 1),
                                         0, keepdims=False)
        x = jax.lax.dynamic_index_in_dim(x, uu, 0, keepdims=False)
        params_v = stage_params(v, row["use_slot"], c["gbuf"])
        t = Tape(params_v, mode="bwd", no_defer=frozenset(no_defer))
        ctx, mem_tv = make_ctx(t, u)
        if track_moe:
            # accumulate only in B (once per micro-batch per stage; the
            # F pass of the same micro-batch would double-count)
            ctx.moe_stats = []
        stage_id = v * Pe + p_rank
        xin = t.value(x)
        out, aux = M.apply_stage(t, ctx, seg, xin, stage_id)

        is_last = (p_rank == Pe - 1) & (v == V - 1)
        c = dict(c)
        if seed == "loss":
            def with_loss(_):
                h = out.val.reshape(mbs * seq, d)
                lab_u = tok_slice(labels, u).reshape(mbs * seq)
                loss, dh, iog = Vb.loss_and_dy(
                    cfg, rc, io_p, h, lab_u, denom, vloc, dsize)
                if cfg.mtp:
                    # DeepSeek multi-token-prediction aux head: one extra
                    # layer over [norm(h); emb(label_t)] predicting t+2.
                    lam = M.MTP_WEIGHT
                    lab2d = tok_slice(labels, u)
                    emb_next = Vb.embed_lookup(
                        io_p["embed.table"], lab2d, vloc, out.val.dtype)
                    mtp_ep = DATA if rt.ep else None
                    hm, mtp_vjp = jax.vjp(
                        lambda hh, ee, mp: M.mtp_hidden(
                            cfg, rc, {**io_p, **mp}, hh, ee,
                            ep_axis=mtp_ep),
                        out.val, emb_next,
                        {n: a for n, a in io_p.items()
                         if n.startswith(("mtp.proj", "mtp.layer"))})
                    lab_mtp = jnp.concatenate(
                        [lab2d[:, 1:], lab2d[:, -1:]], 1).reshape(-1)
                    mask = jnp.concatenate(
                        [jnp.ones((mbs, seq - 1), jnp.float32),
                         jnp.zeros((mbs, 1), jnp.float32)], 1).reshape(-1)
                    denom_mtp = float(denom / seq * (seq - 1))
                    l_m, dhm, iog_m = Vb.loss_and_dy(
                        cfg, rc, io_p, hm.reshape(mbs * seq, d), lab_mtp,
                        denom_mtp, vloc, dsize, norm_key="mtp.norm",
                        mask=mask)
                    dh_b, demb, dmtp = mtp_vjp(
                        (lam * dhm).reshape(mbs, seq, d).astype(hm.dtype))
                    dh2 = dh.reshape(mbs, seq, d) + dh_b.astype(dh.dtype)
                    loss = loss + lam * l_m
                    proto = _loss_iog_proto(cfg, io_p, vloc)
                    for nk, v2 in proto.items():
                        if nk not in iog:
                            iog[nk] = jnp.zeros(v2.shape, jnp.float32)
                    for nk, gv in iog_m.items():
                        iog[nk] = iog[nk] + lam * gv
                    for nk, gv in dmtp.items():
                        iog[nk] = iog[nk] + gv.astype(jnp.float32)
                    # emb_next gradient scatters into the embedding rows
                    iog["__emb_mtp_ids"] = lab2d
                    iog["__emb_mtp_dx"] = demb.astype(jnp.float32)
                    return dh2, loss, iog
                proto = _loss_iog_proto(cfg, io_p, vloc)
                for nk, v2 in proto.items():
                    if nk not in iog:
                        iog[nk] = jnp.zeros(v2.shape, jnp.float32)
                return dh.reshape(mbs, seq, d), loss, iog

            def no_loss(_):
                dy = jax.lax.dynamic_index_in_dim(c["bbuf"], uu, 0,
                                                  keepdims=False)
                iog = {n: jnp.zeros(v2.shape, jnp.float32) for n, v2 in
                       _loss_iog_proto(cfg, io_p, vloc).items()}
                if cfg.mtp:
                    iog["__emb_mtp_ids"] = jnp.zeros((mbs, seq), jnp.int32)
                    iog["__emb_mtp_dx"] = jnp.zeros((mbs, seq, d),
                                                    jnp.float32)
                return dy, jnp.zeros((), jnp.float32), iog

            dy, loss_d, iog_d = jax.lax.cond(is_last, with_loss, no_loss,
                                             None)
            c["io_g"] = dict(c["io_g"])
            c["metrics"] = dict(c["metrics"])
            if cfg.mtp:
                ids_m = iog_d.pop("__emb_mtp_ids")
                dx_m = iog_d.pop("__emb_mtp_dx")
                acc_m, dr_m = Vb.embed_grad(
                    ids_m, dx_m, vloc, cfg.vocab,
                    c["io_g"]["embed.table"])
                c["io_g"]["embed.table"] = acc_m
                c["metrics"]["emb_dropped"] = (
                    c["metrics"]["emb_dropped"] + dr_m)
            for n, g in iog_d.items():
                c["io_g"][n] = c["io_g"][n] + g
            c["metrics"] = dict(c["metrics"])
            c["metrics"]["loss_sum"] = c["metrics"]["loss_sum"] + loss_d
        elif seed == "buffer":
            dy_seed = jax.lax.dynamic_index_in_dim(seed_buf, u, 0,
                                                   keepdims=False)
            dy_wire = jax.lax.dynamic_index_in_dim(c["bbuf"], uu, 0,
                                                   keepdims=False)
            dy = jnp.where(is_last, dy_seed.astype(cdt), dy_wire)
        else:
            dy = jax.lax.dynamic_index_in_dim(c["bbuf"], uu, 0,
                                              keepdims=False)

        seeds = {out.idx: dy.astype(out.val.dtype)}
        if aux is not None:
            seeds[aux.idx] = jnp.asarray(aux_seed, jnp.float32)
        cots, igrads, stash = t.backward(seeds)
        dx = cots[xin.idx]
        c["send_b"] = dx.astype(cdt)

        # stash (x, dy) pairs for the deferred W task
        sx: dict[int, Any] = {}
        for (pname, spec_s, xs_i, dy_i), s in zip(tmpl.entries, stash):
            if xs_i not in sx:
                c["wx"][xs_i] = _dyn_set2(c["wx"][xs_i], v, uu,
                                          s.x.astype(c["wx"][xs_i].dtype))
                sx[xs_i] = True
            c["wdy"][dy_i] = _dyn_set2(c["wdy"][dy_i], v, uu,
                                       s.dy.astype(c["wdy"][dy_i].dtype))
        c["wx"] = list(c["wx"])
        c["wdy"] = list(c["wdy"])

        # immediate grads: EP experts -> sharded accum; small -> full accum
        for n, g in igrads.items():
            if n in ep_names:
                c["acc_shard"] = dict(c["acc_shard"])
                c["acc_shard"][n] = _dyn_add(c["acc_shard"][n], v,
                                             g.astype(jnp.float32))
            else:
                c["acc_full"] = dict(c["acc_full"])
                c["acc_full"][n] = _dyn_add(c["acc_full"][n], v,
                                            g.astype(jnp.float32))

        # embedding gradient at the first stage
        if int_tokens:
            is_first = (p_rank == 0) & (v == 0)

            def emb_g(args):
                acc, drop = args
                ids = tok_slice(tokens, u)
                acc2, dr = Vb.embed_grad(ids, dx.astype(jnp.float32), vloc,
                                         cfg.vocab, acc)
                return acc2, drop + dr

            c["io_g"] = dict(c["io_g"])
            c["metrics"] = dict(c["metrics"])
            acc2, drop2 = jax.lax.cond(
                is_first, emb_g, lambda a: a,
                (c["io_g"]["embed.table"], c["metrics"]["emb_dropped"]))
            c["io_g"]["embed.table"] = acc2
            c["metrics"]["emb_dropped"] = drop2

        if "dmembuf" in c and has_cross and mem_tv is not None:
            # cotangent of the cross-attention memory input
            dmem = cots.get(mem_tv.idx)
            if dmem is not None:
                c["dmembuf"] = _dyn_add(c["dmembuf"], u,
                                        dmem.astype(cdt))

        c["metrics"] = dict(c["metrics"])
        c["metrics"]["aux_sum"] = (
            c["metrics"]["aux_sum"] + aux.val.astype(jnp.float32))
        if track_moe and ctx.moe_stats:
            Ls = len(seg.kinds)
            ml, dr = c["metrics"]["moe_load"], c["metrics"]["moe_dropped"]
            for pfx_, load, dropped in ctx.moe_stats:
                j = int(pfx_.split(".", 1)[0][1:])  # "L{j}.ffn" -> j
                ml = ml.at[stage_id * Ls + j].add(load)
                dr = dr + dropped
            c["metrics"]["moe_load"] = ml
            c["metrics"]["moe_dropped"] = dr
        return c

    def w_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        c = dict(c)
        c["acc_full"] = dict(c["acc_full"])
        c["acc_shard"] = dict(c["acc_shard"])
        for (pname, spec_s, xs_i, dy_i) in tmpl.entries:
            xv = _dyn_get2(c["wx"][xs_i], v, uu)
            dyv = _dyn_get2(c["wdy"][dy_i], v, uu)
            g = jnp.einsum(spec_s, xv, dyv).astype(jnp.float32)
            c["acc_full"][pname] = _dyn_add(c["acc_full"][pname], v, g)
        return c

    def nop_branch(c, row):
        return c

    carry = eng.run(carry, [nop_branch, f_branch, b_branch, w_branch])

    return {
        "stage_grads": carry["acc_shard"],
        "io_grads": carry["io_g"],
        "metrics": carry["metrics"],
        "membuf": carry.get("membuf"),
        "dmembuf": carry.get("dmembuf"),
        "carry_out": carry,
    }


# --------------------------------------------------------------------------- #
# Train body (the SPMD program under shard_map)
# --------------------------------------------------------------------------- #


def train_body(params, batch, *, rt, shape_cfg, mbs, vloc,
               denom, aux_seed):
    """The SPMD program (runs per device under shard_map)."""
    cfg, rc = rt.cfg, rt.rc

    io_p = params["io"]
    mr = jax.lax.axis_index(MODEL)
    Pe, G, V = rt.Pe, rt.G, rc.vpp
    p_rank = mr % Pe
    g_rank = mr // Pe

    # io params arrive in their local (possibly vocab-sharded) shapes
    io_zero = {n: jnp.zeros(a.shape, jnp.float32) for n, a in io_p.items()}

    metrics0 = {"loss_sum": jnp.zeros((), jnp.float32),
                "aux_sum": jnp.zeros((), jnp.float32),
                "emb_dropped": jnp.zeros((), jnp.int32)}
    if rc.moe_stats and cfg.moe is not None:
        # per-(stage, stage-layer) expert-load histogram: row
        # stage_id * len(seg.kinds) + j is global (padded) layer j of
        # stage stage_id; the final psum totals it across ranks.
        seg_m = rt.segs["dec" if cfg.encdec is not None else "main"]
        rows = rt.Pe * seg_m.vpp * len(seg_m.kinds)
        metrics0["moe_load"] = jnp.zeros((rows, cfg.moe.n_experts),
                                         jnp.int32)
        metrics0["moe_dropped"] = jnp.zeros((), jnp.int32)

    if cfg.encdec is None:
        seg = rt.segs["main"]
        pt = rt.tables["main"]
        res = segment_train_scan(
            rt, seg, pt, params["segments"]["main"], io_p,
            batch, mbs, shape_cfg.seq_len, vloc, denom, aux_seed,
            io_zero, metrics0, p_rank, g_rank,
            inject="tokens", seed="loss", membuf=None, dmembuf=None,
        )
        seg_grads = {"main": res["stage_grads"]}
        io_g, metrics = res["io_grads"], res["metrics"]
    else:
        seg_e, seg_d = rt.segs["enc"], rt.segs["dec"]
        enc_ctx = cfg.encdec.enc_ctx
        # the enc forward scan must allocate the stash buffers its later
        # backward scan (which *does* defer W) will fill
        enc_nd = set(rt.ep_names["enc"])
        enc_tmpl = (enc_nd, rt._stash_tmpl(seg_e, (mbs, enc_ctx), enc_nd))
        # 1) encoder forward (stash inputs for its later backward)
        res_e = segment_train_scan(
            rt, seg_e, rt.tables["enc_fwd"], params["segments"]["enc"],
            io_p, batch, mbs, enc_ctx, vloc, denom, aux_seed,
            io_zero, metrics0, p_rank, g_rank,
            inject="enc_tokens", seed=None, membuf="collect", dmembuf=None,
            tmpl_override=enc_tmpl,
        )
        membuf = jax.lax.psum(res_e["membuf"], MODEL)
        # 2) decoder train (full F/B/W) with cross-attention memory
        res_d = segment_train_scan(
            rt, seg_d, rt.tables["dec"], params["segments"]["dec"], io_p,
            batch, mbs, shape_cfg.seq_len, vloc, denom, aux_seed,
            res_e["io_grads"], res_e["metrics"], p_rank, g_rank,
            inject="tokens", seed="loss", membuf=membuf, dmembuf="collect",
        )
        dmem = jax.lax.psum(res_d["dmembuf"], MODEL)
        # 3) encoder backward (B/W only, seeded by accumulated dMemory)
        res_eb = segment_train_scan(
            rt, seg_e, rt.tables["enc_bwd"], params["segments"]["enc"],
            io_p, batch, mbs, enc_ctx, vloc, denom, aux_seed,
            res_d["io_grads"], res_d["metrics"], p_rank, g_rank,
            inject="enc_tokens", seed="buffer", membuf=None, dmembuf=None,
            seed_buf=dmem, carry_in=res_e["carry_out"],
            tmpl_override=enc_tmpl,
        )
        seg_grads = {"enc": res_eb["stage_grads"],
                     "dec": res_d["stage_grads"]}
        io_g, metrics = res_eb["io_grads"], res_eb["metrics"]

    # ---- cross-group / cross-pod gradient reduction ----------------------- #
    # EP expert grads are local-complete over "data"; they only need the
    # cross-group butterfly + cross-pod psum. With flat coalescing each
    # stage's expert bank rides ONE slab collective (bitwise identical to
    # the per-tensor chain); int8 grad compression quantizes the slab wire.
    for sname in seg_grads:
        sg = seg_grads[sname]
        efl = rt.ep_flat_layouts.get(sname)
        out_g = {}
        if efl is not None:
            slab = fsdp.pack_flat_stack(sg, efl)
            if rc.grad_compress == "int8":
                slab = fsdp.ep_allreduce_flat_int8(slab, rt.G, Pe,
                                                   pod=rt.multi_pod)
            else:
                slab = fsdp.ep_allreduce_flat(slab, rt.G, Pe,
                                              pod=rt.multi_pod)
            out_g.update(fsdp.unpack_flat_stack(slab, efl))
        for n, g in sg.items():
            if n in out_g:
                continue
            g = fsdp.group_allreduce(g, rt.G, Pe)
            if rt.multi_pod:
                g = jax.lax.psum(g, POD)
            out_g[n] = g
        seg_grads[sname] = out_g
    io_g = {n: jax.lax.psum(g, MODEL) for n, g in io_g.items()}
    if rt.multi_pod:
        io_g = {n: jax.lax.psum(g, POD) for n, g in io_g.items()}
    # replicated io params need the data-sum of per-shard contributions;
    # vocab-sharded embed/head rows and EP-sharded MTP experts are already
    # local-complete.
    ep_io = {n for n, sp_ in rt.io_specs.items() if sp_.ep and rt.ep}
    for n in io_g:
        if n in ep_io:
            continue
        if vloc is None or n not in ("embed.table", "head.w"):
            io_g[n] = jax.lax.psum(io_g[n], DATA)

    metrics = {k: jax.lax.psum(v, (DATA, MODEL) + ((POD,) if rt.multi_pod
                                                   else ()))
               for k, v in metrics.items()}
    grads = {"io": io_g, "segments": seg_grads}
    return grads, metrics


# --------------------------------------------------------------------------- #
# Serving: KV-cache hooks + F handler over the same engine
# --------------------------------------------------------------------------- #


def make_cache_io(cfg, rc, seg, *, seq_shard: bool, g_rank, Btot: int,
                  mbs: int, paged: bool = False):
    """(cache_get, cache_put) hooks for one segment's layer-cache tree.

    ``paged``: leaves are page pools ([V, n_loc, page_size, ...] locally)
    shared by every row — hand the stage the whole pool; the attention
    path scatters/gathers through each row's page table instead of this
    hook slicing per-micro-batch rows.
    """

    def cache_get(tree, j, v, u):
        # iterate the tree's own keys (not the layer spec's) so extra
        # leaves riding beside the pools — e.g. int8 per-page scales —
        # flow to the stage; the trailing dot keeps L1 from matching L10
        out = {}
        pfx = f"L{j}."
        for key in tree:
            if not key.startswith(pfx):
                continue
            n = key[len(pfx):]
            a = tree[key]
            av = jax.lax.dynamic_index_in_dim(a, v, 0, keepdims=False)
            if paged or seq_shard:
                out[n] = av  # whole pool / full local batch
            else:
                start = (g_rank * Btot + u) * mbs
                out[n] = jax.lax.dynamic_slice_in_dim(av, start, mbs, 0)
        return out

    def cache_put(tree, j, v, u, cd):
        for n, val in cd.items():
            a = tree[f"L{j}.{n}"]
            av = jax.lax.dynamic_index_in_dim(a, v, 0, keepdims=False)
            if paged or seq_shard:
                av = val.astype(a.dtype)
            else:
                start = (g_rank * Btot + u) * mbs
                av = jax.lax.dynamic_update_slice_in_dim(
                    av, val.astype(a.dtype), start, 0)
            tree[f"L{j}.{n}"] = jax.lax.dynamic_update_index_in_dim(
                a, av, v, 0)
        return tree

    return cache_get, cache_put


def serve_body(params, caches, batch, *, rt, shape_cfg, mbs,
               Btot, vloc, prompt_len, max_seq, seq_shard,
               page_size=0, want_logits=False):
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    io_p = params["io"]
    mr = jax.lax.axis_index(MODEL)
    Pe, G = rt.Pe, rt.G
    p_rank = mr % Pe
    g_rank = mr // Pe
    cdt = jnp.dtype(rc.compute_dtype)
    d = cfg.d_model
    s = prompt_len
    tokens = batch["tokens"]
    pos = batch.get("pos", jnp.zeros((), jnp.int32))
    slot_mask = batch.get("slot_mask")
    page_tables = batch.get("page_tables")
    # pos may be a [gb] per-slot vector (continuous batching): every slot
    # sits at its own absolute position and only the rows flagged in
    # slot_mask commit cache writes. Sliced per micro-batch below.
    per_slot = getattr(pos, "ndim", 0) == 1
    if per_slot and seq_shard:
        raise NotImplementedError(
            "per-slot pos vectors need a batch-sharded cache; this shape "
            "fell back to the sequence-sharded (500k) cache layout — use "
            "a global_batch divisible by the data axis")
    if page_tables is not None and not (per_slot and page_size > 0):
        raise ValueError(
            "page_tables require a per-slot pos vector and page_size > 0")

    seg = rt.segs["dec"] if cfg.encdec is not None else rt.segs["main"]
    seg_key = "dec" if cfg.encdec is not None else "main"
    seg_p = params["segments"][seg_key]
    specs = rt.stage_specs[seg_key]
    gatherable = rt.gatherable[seg_key]
    V = seg.vpp
    pt = rt.tables["serve_dec" if cfg.encdec is not None else "serve_main"]
    U = pt.U
    cache_tree = caches[seg_key]

    dims = {cfg.head_dim}
    if cfg.mla is not None:
        dims.add(cfg.mla.rope_dims)
    rope = {e: rope_tables(max_seq, e, cfg.rope_theta) for e in dims}
    ctx = blocks.LayerCtx(
        cfg=cfg, rc=rc, rope=rope, causal=True,
        ep_axis=DATA if rt.ep else None,
        kv_seq_shard=seq_shard, kv_shards=rt.dsize,
        page_size=page_size)
    if cfg.encdec is not None:
        ctx.enc_memory = None  # set per micro-batch below

    # The engine's wire buffers are indexed per the serve table
    # (pt.n_mb / pt.U); the caller's Btot — which make_serve_step may
    # shrink below rc.microbatches on degenerate tiny batches — only
    # governs token slicing, cache addressing and the out_tok layout.
    flat = rt.flat_layouts.get(seg_key)
    eng = TickEngine(
        pt=pt, Pe=Pe, G=G, V=V, specs=specs, gatherable=gatherable,
        seg_p=seg_p, dsize=rt.dsize, ep=rt.ep, cdt=cdt,
        p_rank=p_rank, g_rank=g_rank, backward=False, rs_dtype=None,
        flat=flat, seg_flat=(fsdp.pack_flat_stack(seg_p, flat)
                             if flat is not None else None))
    tok_slice = make_tok_slice(g_rank, Btot, mbs)
    stage_params = eng.stage_params
    cache_get, cache_put = make_cache_io(
        cfg, rc, seg, seq_shard=seq_shard, g_rank=g_rank, Btot=Btot,
        mbs=mbs, paged=page_tables is not None)

    act = (mbs, s, d)
    track_moe = (rc.moe_stats and cfg.moe is not None
                 and any(k.endswith(":moe") for k in seg.kinds))
    carry = dict(
        send_f=jnp.zeros(act, cdt),
        recv_f=jnp.zeros(act, cdt),
        xbuf=jnp.zeros((U, *act), cdt),
        gbuf=eng.init_gbuf(),
        caches=dict(cache_tree),
        out_tok=jnp.zeros((G * Btot, mbs), jnp.int32),
    )
    if track_moe:
        rows_m = Pe * V * len(seg.kinds)
        carry["moe_load"] = jnp.zeros((rows_m, cfg.moe.n_experts),
                                      jnp.int32)
        carry["moe_dropped"] = jnp.zeros((), jnp.int32)
    if want_logits:
        # per-u drain logits land here; vloc path: every data rank
        # computes its vocab slice for ALL data ranks' rows (the
        # all_gather inside serve_logits), hence the D·mbs row block.
        lrows = (rt.dsize if vloc else 1) * mbs
        carry["out_logits"] = jnp.zeros(
            (G * Btot, lrows, vloc or cfg.vocab), jnp.float32)

    def f_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        is_inject = (p_rank == 0) & (v == 0)

        def do_embed(_):
            ids = tok_slice(tokens, u) if not seq_shard else tokens
            if jnp.issubdtype(tokens.dtype, jnp.integer):
                return Vb.embed_lookup(io_p["embed.table"], ids, vloc, cdt)
            return ids.astype(cdt)

        x = jax.lax.cond(
            is_inject, do_embed,
            lambda _: jax.lax.dynamic_index_in_dim(c["xbuf"], uu, 0,
                                                   keepdims=False), None)
        params_v = stage_params(v, row["use_slot"], c["gbuf"])
        if cfg.encdec is not None:
            mem = caches["enc_memory"]
            ctx.enc_memory = (mem if seq_shard else tok_slice(mem, u))
        stage_id = v * Pe + p_rank
        pos_u = tok_slice(pos, u) if per_slot else pos
        ctx.slot_mask = (tok_slice(slot_mask, u)
                         if slot_mask is not None else None)
        ctx.page_tables = (tok_slice(page_tables, u)
                           if page_tables is not None else None)
        ch = [cache_get(c["caches"], j, v, u)
              for j in range(len(seg.kinds))]
        if track_moe:
            ctx.moe_stats = []
        y, ch2 = M.cached_stage(ctx, seg, params_v, x, ch, stage_id, pos_u)
        c = dict(c)
        if track_moe and ctx.moe_stats:
            Ls = len(seg.kinds)
            ml, dr = c["moe_load"], c["moe_dropped"]
            for pfx_, load, dropped in ctx.moe_stats:
                j = int(pfx_.split(".", 1)[0][1:])
                ml = ml.at[stage_id * Ls + j].add(load)
                dr = dr + dropped
            c["moe_load"], c["moe_dropped"] = ml, dr
        c["caches"] = dict(c["caches"])
        for j in range(len(seg.kinds)):
            c["caches"] = cache_put(c["caches"], j, v, u, ch2[j])
        c["send_f"] = y

        is_drain = (p_rank == Pe - 1) & (v == V - 1)

        if want_logits:
            def sample_l(bufs):
                ot, ol = bufs
                h_last = y[:, -1]
                idx = g_rank * Btot + (u % Btot)
                tok = Vb.greedy_sample(cfg, rc, io_p, h_last, vloc)
                ot = jax.lax.dynamic_update_index_in_dim(ot, tok, idx, 0)
                lg = Vb.serve_logits(cfg, rc, io_p, h_last, vloc)
                ol = jax.lax.dynamic_update_index_in_dim(
                    ol, lg.astype(ol.dtype), idx, 0)
                return ot, ol

            c["out_tok"], c["out_logits"] = jax.lax.cond(
                is_drain, sample_l, lambda bufs: bufs,
                (c["out_tok"], c["out_logits"]))
            return c

        def sample(ot):
            h_last = y[:, -1]
            tok = Vb.greedy_sample(cfg, rc, io_p, h_last, vloc)
            return jax.lax.dynamic_update_index_in_dim(
                ot, tok, g_rank * Btot + (u % Btot), 0)

        c["out_tok"] = jax.lax.cond(is_drain, sample, lambda ot: ot,
                                    c["out_tok"])
        return c

    def nop_branch(c, row):
        return c

    carry = eng.run(carry, [nop_branch, f_branch])

    out_tok = carry["out_tok"].reshape(-1)
    # drain ranks hold the sampled tokens; share them
    out_tok = jax.lax.psum(
        jnp.where((p_rank == Pe - 1), out_tok, jnp.zeros_like(out_tok)),
        MODEL)
    caches_out = dict(caches)
    caches_out[seg_key] = carry["caches"]
    moe_out = None
    if track_moe:
        # MODEL totals the per-stage rows; the data/pod axes hold
        # disjoint slot shards only when the batch is sharded (seq_shard
        # replicates the batch — summing would multiply by dsize).
        axes = (MODEL,) + (() if seq_shard else
                           ((POD, DATA) if rt.multi_pod else (DATA,)))
        moe_out = {"load": jax.lax.psum(carry["moe_load"], axes),
                   "dropped": jax.lax.psum(carry["moe_dropped"], axes)}
    if want_logits:
        ol = carry["out_logits"]  # [G·Btot, (D·)mbs, vloc|vocab]
        if vloc:
            # reorder to [D, b_loc, vloc] -> [D·b_loc, vloc]: global row
            # r of the gb batch is data-rank r // b_loc's local row
            # r % b_loc, and each u-block holds all D ranks' mbs rows.
            D = rt.dsize
            ol = ol.reshape(G * Btot, D, mbs, vloc)
            ol = ol.transpose(1, 0, 2, 3).reshape(D * G * Btot * mbs, vloc)
        else:
            ol = ol.reshape(G * Btot * mbs, cfg.vocab)
        ol = jax.lax.psum(
            jnp.where((p_rank == Pe - 1), ol, jnp.zeros_like(ol)), MODEL)
        return ((out_tok, ol, caches_out, moe_out) if track_moe
                else (out_tok, ol, caches_out))
    return ((out_tok, caches_out, moe_out) if track_moe
            else (out_tok, caches_out))
