"""Closed-form analysis from the paper's Table 2 / §3.4.

Symbols (Table 1): L layers, M_w / M_a per-layer weight/activation memory,
V stages per device, B micro-batches, P pipeline size, D DP size.

These formulas are validated against the discrete-event simulator in
tests/test_analysis.py and reproduced in benchmarks/bench_table2.py.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MethodAnalysis:
    bubble_units: float        # pipeline bubbles, in per-mb task units
    weight_mem: float
    act_mem: float
    n_param_comm: float        # parameter all-gathers per step (x = 0)


def analyze(
    method: str,
    *,
    L: int,
    P: int,
    V: int,
    B: int,
    U: int | None = None,
    D: int = 1,
    M_w: float = 1.0,
    M_a: float = 1.0,
) -> MethodAnalysis:
    U = U or B
    if method == "gpipe":
        return MethodAnalysis(2 * (P - 1), L * M_w / P, B * L * M_a / P, 0)
    if method == "1f1b":
        return MethodAnalysis(2 * (P - 1), L * M_w / P, L * M_a, 0)
    if method == "fs-1f1b":
        # sharded base + a per-layer double gather buffer (Table 2: "M_w")
        return MethodAnalysis(2 * (P - 1), L * M_w / (P * D) + 2 * M_w,
                              L * M_a, 2 * B * L / P)
    if method == "interleaved":
        return MethodAnalysis(
            2 * (P - 1) / V, L * M_w / P,
            L * M_a * (1 + (P - 1) / (V * P)), 0,
        )
    if method == "bfs":
        return MethodAnalysis(2 * (P - 1) / V, L * M_w / P, B * L * M_a / P, 0)
    if method == "fs-bfs":
        return MethodAnalysis(
            2 * (P - 1) / V, L * M_w / (P * D) + 2 * L * M_w / (P * V),
            B * L * M_a / P, L * (2 * V - 1) / (P * V) * 1,
        )
    if method == "zeropp":
        bub = 0.0 if U >= 2 * P - 1 else B * (2 * P - 1 - U) / U
        return MethodAnalysis(
            bub, L * M_w / P, min(B, 2 * P - 1) * L * M_a / P, 0,
        )
    if method == "fs-zeropp":
        bub = 0.0 if U >= 2 * P - 1 else B * (2 * P - 1 - U) / U
        # §3.4: Max Allocation = L·M_w/(P·D) + L·M_w/(P·V) + MIN(B,U)·L·M_a/P
        return MethodAnalysis(
            bub,
            L * M_w / (P * D) + L * M_w / (P * V),
            min(B, U) * L * M_a / P,
            n_allgather(B=B, L=L, V=V, U=U, P=P),
        )
    if method == "fs-autogen":
        # full-depth §4 auto-generation: W postponement crosses unit
        # boundaries, so the whole batch's activations/(x,dy) stashes
        # stay live — the O(B) bound the unit-gated variant closes.
        a = analyze("fs-zeropp", L=L, P=P, V=V, B=B, U=B, D=D, M_w=M_w,
                    M_a=M_a)
        return MethodAnalysis(0.0, a.weight_mem, B * L * M_a / P,
                              a.n_param_comm)
    if method == "fs-autogen-gated":
        # unit-gated §4: insertions confined to each unit's live window,
        # so memory matches fs-zeropp's O(U) allocation; bubbles land
        # between zero (U >= 2P-1) and the zeropp bound, where inside
        # the window the heuristic fills what greedy W-fill leaves.
        return analyze("fs-zeropp", L=L, P=P, V=V, B=B, U=U, D=D,
                       M_w=M_w, M_a=M_a)
    raise ValueError(method)


def n_allgather(*, B: int, L: int, V: int, U: int, P: int) -> float:
    """§3.4: #AllGather = B·L·(2V−1)/(U·P·V)."""
    return B * L * (2 * V - 1) / (U * P * V)


def optimal_active_microbatches(P: int) -> int:
    """§3.4: near-zero bubbles need U ≥ 2P−1 active micro-batches."""
    return 2 * P - 1


def zeropp_max_alloc(*, L, P, D, V, B, U, M_w=1.0, M_a=1.0) -> float:
    return (L * M_w / (P * D) + L * M_w / (P * V)
            + min(B, U) * L * M_a / P)
