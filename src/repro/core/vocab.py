"""Vocab-sharded embedding + LM-head loss under manual SPMD.

The "data" mesh axis does double duty: it shards the batch *and* the
embedding/head vocab dim. Each data-rank therefore holds different tokens
AND a different vocab shard, so:

  * embed lookup: psum over "data" of masked local-window lookups;
  * loss: a *ring* over vocab shards — rotate the local head chunk around
    the data axis, maintaining streaming (m, l, label-logit) stats, then a
    second ring for dlogits → (dh, ring-reduced dW). Two rotations of the
    head per drained micro-batch, no [n, vocab] materialization;
  * embed grads: contributions to other ranks' rows are dispatched with a
    capacity-padded all_to_all (same machinery as MoE dispatch; capacity
    factor 2, drop counts surfaced in metrics).

When vocab % data_size != 0 (whisper's 51866) or under single-device smoke
tests, everything falls back to the exact replicated path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DATA = "data"


def vocab_shard(vocab: int, dsize: int) -> int | None:
    """Rows per shard, or None -> replicated."""
    if dsize > 1 and vocab % dsize == 0 and vocab // dsize >= 8:
        return vocab // dsize
    return None


# --------------------------------------------------------------------------- #
# Embedding
# --------------------------------------------------------------------------- #


def embed_lookup(table, ids, vloc: int | None, dtype):
    """table: [vloc|vocab, d] local; ids [b, s] int32 (per-rank tokens).

    Sharded path: each data-rank holds a different vocab window AND
    different tokens, so gather everyone's ids, serve lookups from the
    local window, psum, and slice back this rank's block.
    """
    if vloc is None:
        return table[ids].astype(dtype)
    r = jax.lax.axis_index(DATA)
    ids_all = jax.lax.all_gather(ids, DATA, axis=0, tiled=True)  # [D·b, s]
    lo = r * vloc
    loc = jnp.clip(ids_all - lo, 0, vloc - 1)
    hit = (ids_all >= lo) & (ids_all < lo + vloc)
    e = table[loc] * hit[..., None].astype(table.dtype)
    e = jax.lax.psum(e, DATA)
    b = ids.shape[0]
    return jax.lax.dynamic_slice_in_dim(e, r * b, b, 0).astype(dtype)


def embed_grad(ids, dx, vloc: int | None, vocab: int, acc):
    """Scatter-add dx into the (possibly sharded) table-grad accumulator.

    Sharded path: capacity-padded all_to_all dispatch to row owners.
    Returns (acc, n_dropped).
    """
    n = ids.size
    d = dx.shape[-1]
    idf = ids.reshape(n)
    dxf = dx.reshape(n, d).astype(acc.dtype)
    if vloc is None:
        return acc.at[idf].add(dxf), jnp.zeros((), jnp.int32)
    dsize = vocab // vloc
    dest = idf // vloc
    cap = max(8, -(-2 * n // dsize))
    oh = jax.nn.one_hot(dest, dsize, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh
    slot = (pos * oh).sum(-1)
    keep = slot < cap
    dropped = n - keep.sum()
    slot = jnp.where(keep, slot, cap)
    buf = jnp.zeros((dsize, cap + 1, d), acc.dtype)
    buf = buf.at[dest, slot].add(dxf)
    rbuf = jnp.zeros((dsize, cap + 1), jnp.int32)
    rbuf = rbuf.at[dest, slot].set(
        jnp.where(keep, idf % vloc + 1, 0)  # +1: 0 = empty slot
    )
    buf = jax.lax.all_to_all(buf[:, :cap], DATA, split_axis=0,
                             concat_axis=0, tiled=True)
    rbuf = jax.lax.all_to_all(rbuf[:, :cap], DATA, split_axis=0,
                              concat_axis=0, tiled=True)
    rows = rbuf.reshape(-1)
    vals = buf.reshape(-1, d)
    ok = rows > 0
    acc = acc.at[jnp.where(ok, rows - 1, vloc)].add(
        jnp.where(ok[:, None], vals, 0.0),
        mode="drop",
    )
    return acc, dropped.astype(jnp.int32)


# --------------------------------------------------------------------------- #
# Loss (final RMS/LayerNorm + softmax-xent) with explicit backward
# --------------------------------------------------------------------------- #


def _final_norm_fwd(cfg, io_p, h, norm_key="final_norm"):
    hf = h.astype(jnp.float32)
    scale = io_p[f"{norm_key}.scale"].astype(jnp.float32)
    if cfg.norm == "layernorm" and norm_key == "final_norm":
        mu = hf.mean(-1, keepdims=True)
        var = ((hf - mu) ** 2).mean(-1, keepdims=True)
        inv = jax.lax.rsqrt(var + 1e-5)
        hn = (hf - mu) * inv
        y = hn * scale + io_p["final_norm.bias"].astype(jnp.float32)
        return y, (hf, hn, inv, scale)  # layernorm path
    inv = jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-6)
    return hf * inv * scale, (hf, hf * inv, inv, scale)


def _final_norm_bwd(cfg, res, dy, norm_key="final_norm"):
    hf, hn, inv, scale = res
    d = hf.shape[-1]
    dscale = (dy * hn).sum(axis=tuple(range(dy.ndim - 1)))
    g = dy * scale
    if cfg.norm == "layernorm" and norm_key == "final_norm":
        dbias = dy.sum(axis=tuple(range(dy.ndim - 1)))
        gm = g.mean(-1, keepdims=True)
        ghn = (g * hn).mean(-1, keepdims=True)
        dh = inv * (g - gm - hn * ghn)
        return dh, {"final_norm.scale": dscale, "final_norm.bias": dbias}
    dot = (g * hf).mean(-1, keepdims=True)
    dh = inv * g - hf * (inv ** 3) * dot
    return dh, {f"{norm_key}.scale": dscale}


def loss_and_dy(cfg, rc, io_p, h, labels, denom: float, vloc: int | None,
                dsize: int, norm_key: str = "final_norm", mask=None):
    """h: [n, d] final hiddens (one micro-batch, flattened), labels [n].

    Returns (loss_sum_scaled, dh, io_grad_deltas). ``denom`` is the global
    token count — gradients come out mean-normalized. ``mask`` [n] zeroes
    positions (MTP's last column); ``norm_key`` selects the pre-head norm.
    """
    if mask is None:
        mask = jnp.ones(h.shape[:1], jnp.float32)
    hn, res = _final_norm_fwd(cfg, io_p, h, norm_key)
    tied = cfg.tie_embeddings
    w = io_p["embed.table"] if tied else io_p["head.w"]
    n, d = h.shape

    if vloc is None:
        wl = (w.T if tied else w).astype(jnp.float32)  # [d, vocab]
        logits = hn @ wl
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
        loss = ((lse - lab) * mask).sum() / denom
        p = jnp.exp(logits - lse[:, None])
        dlog = (p - jax.nn.one_hot(labels, wl.shape[1])) \
            * mask[:, None] / denom
        dhn = dlog @ wl.T
        dw = hn.T @ dlog
        dh, ng = _final_norm_bwd(cfg, res, dhn, norm_key)
        grads = dict(ng)
        key = "embed.table" if tied else "head.w"
        grads[key] = dw.T if tied else dw
        return loss, dh.astype(h.dtype), grads

    # ---- gather-tokens formulation --------------------------------------- #
    # Every data-rank holds a different vocab shard AND different tokens.
    # Gather all shards' tokens (all_gather over "data"), compute this
    # rank's vocab-shard logits for *all* tokens, psum-combine streaming
    # softmax stats, then dW is complete locally and dh psum-reduces.
    # Only all_gather/psum are used — they are group-local collectives and
    # therefore legal inside rank-conditional branches (DESIGN.md §3).
    lo = jax.lax.axis_index(DATA) * vloc
    hn_all = jax.lax.all_gather(hn, DATA, axis=0, tiled=True)  # [D·n, d]
    lab_all = jax.lax.all_gather(labels, DATA, axis=0, tiled=True)
    mask_all = jax.lax.all_gather(mask, DATA, axis=0, tiled=True)
    wl = (w.T if tied else w).astype(jnp.float32)              # [d, vloc]
    na = hn_all.shape[0]
    chunk = min(vloc, max(512, rc.vocab_chunk))
    nc = -(-vloc // chunk)
    pad_v = nc * chunk - vloc
    wl_p = jnp.pad(wl, ((0, 0), (0, pad_v)))

    idx = jnp.clip(lab_all - lo, 0, vloc - 1)
    inw = (lab_all >= lo) & (lab_all < lo + vloc)

    def p1(carry, ci):
        m, l, lab = carry
        wc = jax.lax.dynamic_slice(wl_p, (0, ci * chunk), (d, chunk))
        lg = hn_all @ wc
        col = ci * chunk + jnp.arange(chunk)
        valid = col < vloc
        lg = jnp.where(valid[None], lg, -jnp.inf)
        m_new = jnp.maximum(m, lg.max(-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.where(valid[None],
                                     jnp.exp(lg - m_safe[:, None]),
                                     0.0).sum(-1)
        inc = (idx >= ci * chunk) & (idx < (ci + 1) * chunk) & inw
        lv = jnp.take_along_axis(
            lg, jnp.clip(idx - ci * chunk, 0, chunk - 1)[:, None], 1)[:, 0]
        lab = jnp.where(inc, lv, lab)
        return (m_new, l_new, lab), None

    m0 = jnp.full((na,), -jnp.inf, jnp.float32)
    (m_loc, l_loc, lv_loc), _ = jax.lax.scan(
        p1, (m0, jnp.zeros((na,), jnp.float32),
             jnp.zeros((na,), jnp.float32)), jnp.arange(nc))
    m = jax.lax.pmax(m_loc, DATA)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    l = jax.lax.psum(l_loc * jnp.where(jnp.isfinite(m_loc),
                                       jnp.exp(m_loc - m_safe), 0.0), DATA)
    lab_logit = jax.lax.psum(jnp.where(inw, lv_loc, 0.0), DATA)
    lse = m_safe + jnp.log(jnp.maximum(l, 1e-30))
    # each rank reports the loss of its OWN tokens (avoids double count)
    r0 = jax.lax.axis_index(DATA)
    mine = jax.lax.dynamic_slice_in_dim(
        (lse - lab_logit) * mask_all, r0 * n, n, 0)
    loss = mine.sum() / denom

    def p2(carry, ci):
        dhn_all, dw = carry
        wc = jax.lax.dynamic_slice(wl_p, (0, ci * chunk), (d, chunk))
        lg = hn_all @ wc
        col = ci * chunk + jnp.arange(chunk)
        valid = col < vloc
        p = jnp.where(valid[None], jnp.exp(lg - lse[:, None]), 0.0)
        inc = (idx >= ci * chunk) & (idx < (ci + 1) * chunk) & inw
        oh = jax.nn.one_hot(jnp.clip(idx - ci * chunk, 0, chunk - 1),
                            chunk, dtype=jnp.float32) * inc[:, None]
        dlog = (p - oh) * mask_all[:, None] / denom
        dhn_all = dhn_all + dlog @ wc.T
        dw = jax.lax.dynamic_update_slice(
            dw, hn_all.T @ dlog, (0, ci * chunk))
        return (dhn_all, dw), None

    (dhn_all, dw_p), _ = jax.lax.scan(
        p2, (jnp.zeros((na, d), jnp.float32),
             jnp.zeros((d, nc * chunk), jnp.float32)), jnp.arange(nc))
    dw = dw_p[:, :vloc]
    dhn_all = jax.lax.psum(dhn_all, DATA)                       # [D·n, d]
    dhn = jax.lax.dynamic_slice_in_dim(dhn_all, r0 * n, n, 0)
    dh, ng = _final_norm_bwd(cfg, res, dhn, norm_key)
    grads = dict(ng)
    key = "embed.table" if tied else "head.w"
    grads[key] = dw.T if tied else dw
    return loss, dh.astype(h.dtype), grads


def serve_logits(cfg, rc, io_p, h, vloc: int | None):
    """Full next-token logits from final hiddens h [b, d] (float32).

    Replicated head: [b, vocab] for this rank's own rows. Sharded head:
    every data-rank gathers all rows and computes its vocab slice →
    [D·b, vloc] (globally [D·b, vocab] with the vocab axis on "data").
    Feeds the host-side sampling layer; greedy decoding never calls this.
    """
    hn, _ = _final_norm_fwd(cfg, io_p, h)
    tied = cfg.tie_embeddings
    w = io_p["embed.table"] if tied else io_p["head.w"]
    wl = (w.T if tied else w).astype(jnp.float32)
    if vloc is None:
        return hn @ wl
    hn_all = jax.lax.all_gather(hn, DATA, axis=0, tiled=True)
    return hn_all @ wl


def greedy_sample(cfg, rc, io_p, h, vloc: int | None):
    """Greedy next token from final hiddens h [b, d] (sharded head)."""
    hn, _ = _final_norm_fwd(cfg, io_p, h)
    tied = cfg.tie_embeddings
    w = io_p["embed.table"] if tied else io_p["head.w"]
    wl = (w.T if tied else w).astype(jnp.float32)
    logits = hn @ wl  # [b, vloc or vocab]
    if vloc is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # each data-rank holds different rows AND a different vocab shard:
    # gather rows, reduce the argmax across shards, slice own rows back
    b = hn.shape[0]
    r = jax.lax.axis_index(DATA)
    hn_all = jax.lax.all_gather(hn, DATA, axis=0, tiled=True)
    logits = hn_all @ wl                      # [D·b, vloc]
    lmax = logits.max(-1)
    lidx = jnp.argmax(logits, -1).astype(jnp.int32)
    gmax = jax.lax.pmax(lmax, DATA)
    lo = r * vloc
    cand = jnp.where(lmax >= gmax, lidx + lo, 0)
    tok_all = jax.lax.pmax(cand, DATA).astype(jnp.int32)
    return jax.lax.dynamic_slice_in_dim(tok_all, r * b, b, 0)
