"""§4 heuristic schedule auto-generation.

Faithful reproduction of the paper's algorithm:

1. Schedule the F and B passes gradient-fast-propagation style and postpone
   all W passes to the end (``w_fill="postpone"``).
2. Simulate ("profile the actual timeline" — we profile with the cost
   model instead of CUDA events; the container has no accelerator).
3. Find the PP rank with the longest schedule, then the interleaved stage
   within that rank with the largest total bubble; insert a postponed W of
   that same stage (whose B is already complete and whose F precedes the
   bubble) into the largest such bubble.
4. Repeat — the longest rank may shift — until no insertion shortens the
   makespan.

The result is expressed as per-rank task orders and re-quantized into a
TickTable so it can be executed by the SPMD runtime.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.generators import SchedParams, attach_fsdp_events, generate
from repro.core.schedules import (
    B,
    F,
    NOP,
    W,
    Task,
    TickTable,
    slot_of,
    unit_stash_violations,
)
from repro.core.simulator import CostModel, SimResult, simulate


def orders_from_table(tt: TickTable) -> list[list[Task]]:
    orders: list[list[Task]] = [[] for _ in range(tt.P)]
    for t, r, task in tt.tasks():
        orders[r].append(task)
    return orders


def retick(orders: list[list[Task]], P: int, V: int, n_mb: int,
           unit: int, assume_f: bool = False,
           unit_gated: bool = False) -> TickTable:
    """Quantize per-rank orders into the densest valid tick table.

    assume_f: treat all F tasks as already done (encoder-backward tables,
    whose forwards ran in a previous segment scan).
    unit_gated: additionally reject (RuntimeError) any quantization whose
    B→W / stash distances exceed the unit-depth buffers — the legality
    gate the gated §4 insertion loop leans on to discard trial moves.
    """
    S = P * V
    pos = [0] * P
    placed: dict[tuple, int] = {}
    if assume_f:
        for u in range(n_mb):
            for s in range(S):
                placed[(F, u, s)] = -1
    grid: list[list[Task | None]] = []
    total = sum(len(o) for o in orders)
    done = 0
    t = 0
    while done < total and t < total * 3 + 64:
        row: list[Task | None] = [None] * P
        for r in range(P):
            if pos[r] >= len(orders[r]):
                continue
            task = orders[r][pos[r]]
            deps = []
            if task.kind == F and task.stage > 0:
                deps.append((F, task.mb, task.stage - 1))
            if task.kind == B:
                deps.append((F, task.mb, task.stage))
                if task.stage < S - 1:
                    deps.append((B, task.mb, task.stage + 1))
            if task.kind == W:
                deps.append((B, task.mb, task.stage))
            if all(d in placed and placed[d] < t for d in deps):
                row[r] = task
        for r in range(P):
            if row[r] is not None:
                placed[(row[r].kind, row[r].mb, row[r].stage)] = t
                pos[r] += 1
                done += 1
        grid.append(row)
        t += 1
    if done < total:
        raise RuntimeError("retick failed: invalid order")
    tt = TickTable(P=P, V=V, n_mb=n_mb, unit=unit, grid=grid)
    if unit_gated:
        bad = unit_stash_violations(tt)
        if bad:
            raise RuntimeError(
                f"retick: order illegal at unit depth {unit}: {bad[0]}")
    attach_fsdp_events(tt)
    return tt


@dataclasses.dataclass
class AutogenResult:
    table: TickTable
    makespan_before: float
    makespan_after: float
    n_insertions: int
    log: list[str]
    # simulated makespan after init and after each accepted W insertion —
    # §4's loop only accepts strictly-improving moves, so this is
    # monotonically non-increasing (property-tested in tests/test_plan.py)
    makespans: list[float] = dataclasses.field(default_factory=list)


def autogen(sp: SchedParams, cm: CostModel, max_iters: int = 2000, *,
            unit_gated: bool = False) -> AutogenResult:
    """Run the §4 loop starting from the postponed-W fast-propagation
    schedule.

    unit_gated=False (the registered ``"autogen"`` schedule) postpones W
    across the whole step, so the result needs full-depth (n_mb) stash
    buffers. unit_gated=True (``"autogen_gated"``) postpones W only to the
    tail of its own §3.1 scheduling unit and constrains every insertion to
    bubbles inside that unit's live window, so stash depth stays ``sp.U``
    and the paper's O(U) activation-memory bound survives; each trial is
    re-quantized with ``retick(unit_gated=True)``, whose stash-legality
    gate rejects any move that would stretch a B→W distance past the
    unit-depth buffers. Gated insertions also scan candidates first-in-
    first-out (lowest task index first) instead of most-postponed-first,
    preserving the per-(rank, stage-slot) W execution order of the greedy
    zeropp table — which keeps gradient accumulation order, and therefore
    bits, identical to the baseline schedule.
    """
    U = sp.U if unit_gated else sp.n_mb
    base = _postponed(sp, per_unit=unit_gated)
    orders = orders_from_table(base)
    P, V = sp.P, sp.V
    tt = retick(orders, P, V, sp.n_mb, sp.U, unit_gated=unit_gated)
    res = simulate(tt, cm)
    t0 = res.makespan
    log = [f"init makespan {t0:.3f}"]
    n_ins = 0
    history = [t0]

    for it in range(max_iters):
        res = simulate(tt, cm)
        # rank with the longest schedule
        last_end = np.zeros(P)
        for (k, u, s), e in res.task_end.items():
            last_end[s % P] = max(last_end[s % P], e)
        r_star = int(np.argmax(last_end))
        order = orders[r_star]
        # bubbles on r_star: gaps between consecutive tasks
        gaps = []  # (size, after_index, gap_start)
        for i in range(len(order) - 1):
            a = (order[i].kind, order[i].mb, order[i].stage)
            b2 = (order[i + 1].kind, order[i + 1].mb, order[i + 1].stage)
            gap = res.task_start[b2] - res.task_end[a]
            if gap > 1e-9:
                gaps.append((gap, i, res.task_end[a]))
        if not gaps:
            log.append(f"iter {it}: no bubbles on longest rank r{r_star}")
            break
        # group bubbles by the interleaved stage of the *preceding* task
        by_v: dict[int, float] = {}
        for gap, i, _ in gaps:
            v = slot_of(order[i].stage, P)
            by_v[v] = by_v.get(v, 0.0) + gap
        v_star = max(by_v, key=by_v.get)
        cands = [(g, i, gs) for (g, i, gs) in gaps
                 if slot_of(order[i].stage, P) == v_star]
        cands.sort(reverse=True)
        inserted = False
        for gap, i, gap_start in cands:
            # find a postponed W of stage slot v_star on r_star whose B is
            # done before the gap and which currently sits *after* i.
            # Gated mode scans forward (FIFO: the earliest such W moves
            # first, keeping per-slot W order) and only into bubbles of
            # the W's own unit (per-rank unit blocks stay contiguous, so
            # unit-depth stash reuse and per-unit reduce batching hold);
            # full-depth mode keeps the original most-postponed-first scan.
            j_range = (range(i + 1, len(order)) if unit_gated
                       else range(len(order) - 1, i, -1))
            for j in j_range:
                tsk = order[j]
                if tsk.kind != W or slot_of(tsk.stage, P) != v_star:
                    continue
                if unit_gated and order[i].mb // U != tsk.mb // U:
                    continue  # bubble outside this W's unit live window
                bkey = (B, tsk.mb, tsk.stage)
                if bkey not in res.task_end or res.task_end[bkey] > gap_start:
                    continue
                cand = order[: i + 1] + [tsk] + [
                    o for idx2, o in enumerate(order) if idx2 > i and idx2 != j
                ]
                trial_orders = [list(o) for o in orders]
                trial_orders[r_star] = cand
                try:
                    trial_tt = retick(trial_orders, P, V, sp.n_mb, sp.U,
                                      unit_gated=unit_gated)
                except RuntimeError:
                    continue
                trial_res = simulate(trial_tt, cm)
                if trial_res.makespan < res.makespan - 1e-12:
                    orders = trial_orders
                    tt = trial_tt
                    n_ins += 1
                    history.append(trial_res.makespan)
                    log.append(
                        f"iter {it}: moved {tsk} into {gap:.3f} bubble on "
                        f"r{r_star} v{v_star} -> {trial_res.makespan:.3f}"
                    )
                    inserted = True
                break
            if inserted:
                break
        if not inserted:
            log.append(f"iter {it}: no W insertion improves r{r_star}")
            break

    final = simulate(tt, cm)
    return AutogenResult(tt, t0, final.makespan, n_ins, log,
                         makespans=history)


def _postponed(sp: SchedParams, per_unit: bool = False) -> TickTable:
    """F/B fast-propagation with W postponed to the tail (§4 step 1).

    per_unit=False: every W moves to the very end of its rank's order
    (the paper's full-depth starting point). per_unit=True: each W only
    moves to the tail of its own scheduling unit's block, so unit blocks
    stay contiguous per rank and unit-depth stash reuse stays legal.
    """
    tt = generate("zeropp", sp)
    orders = orders_from_table(tt)
    U = sp.U
    for r in range(len(orders)):
        if per_unit:
            n_units = -(-sp.n_mb // U)
            blocks: list[Task] = []
            for n in range(n_units):
                blk = [t for t in orders[r] if t.mb // U == n]
                blocks += [t for t in blk if t.kind != W]
                blocks += [t for t in blk if t.kind == W]
            orders[r] = blocks
        else:
            fb = [t for t in orders[r] if t.kind != W]
            ws = [t for t in orders[r] if t.kind == W]
            orders[r] = fb + ws
    return retick(orders, sp.P, sp.V, sp.n_mb, sp.U,
                  unit_gated=per_unit)
