"""Continuous-time discrete-event simulator for pipeline schedules.

Takes a TickTable (which fixes each rank's task *order*) plus a CostModel
(per-task durations, p2p latency, collective times) and computes the real
timeline: makespan, per-rank busy/idle, bubble fraction, memory watermark,
and communication counts. This is the engine behind the paper-table
reproductions (Tables 2/3/5, Figs 5–7) and behind the §4 heuristic
auto-generator (autogen.py), which needs "profiled" timelines.

Hardware presets: A800 (the paper's testbed) and TPU v5e (our target).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedules import B, F, NOP, W, TickTable, slot_of

# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class CostModel:
    t_f: float = 1.0          # forward, one stage × one micro-batch
    t_b: float = 2.0          # input-grad (includes remat re-forward)
    t_w: float = 1.0          # weight-grad GEMMs
    t_p2p: float = 0.05       # stage-boundary activation transfer
    t_gather: float = 0.5     # FSDP all-gather, one stage block (α·n + β·B)
    t_reduce: float = 0.5     # grad reduce-scatter, one stage block
    overlap_comm: bool = True  # collectives overlap compute (async)
    # memory accounting (arbitrary units, per stage block)
    m_act: float = 1.0        # activation stash of one (mb, stage) F→B
    m_wstash: float = 0.5     # (x, dy) stash of one (mb, stage) B→W
    m_weight: float = 1.0     # one stage block of parameters (gathered)
    # α–β collective metadata (already folded into t_gather/t_reduce; kept
    # so analyses/describe() can report the latency-vs-bandwidth split)
    coll_alpha: float = 0.0       # per-collective launch latency (s)
    n_coll_gather: int = 1        # collectives issued per gather tick
    n_coll_reduce: int = 1        # collectives issued per reduce tick
    # EP MoE all-to-all: dispatch/combine ride *inside* the F/B compute of
    # a stage tick (they are lax.all_to_all calls in the traced layer
    # body), so a2a time charges into dur() rather than the gather/reduce
    # channels. n_a2a_f/_b count a2a events per F/B tick of one stage
    # (0 for gathered MoE / dense models); t_a2a is one event's α–β time.
    t_a2a: float = 0.0            # one all-to-all event (s)
    n_a2a_f: int = 0              # a2a events inside one F tick
    n_a2a_b: int = 0              # a2a events inside one B tick
    a2a_bytes: float = 0.0        # wire bytes of one a2a event (metadata)
    a2a_alpha: float = 0.0        # a2a launch latency (s, metadata)

    def dur(self, kind: int) -> float:
        if kind == F:
            return self.t_f + self.n_a2a_f * self.t_a2a
        if kind == B:
            return self.t_b + self.n_a2a_b * self.t_a2a
        return self.t_w


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy: np.ndarray          # [P] busy time
    bubble_frac: float        # 1 - mean(busy)/makespan
    peak_mem: float           # per-rank max of the memory trace
    peak_mem_rank: np.ndarray  # [P]
    n_gather: int
    n_reduce: int
    task_start: dict          # (kind, mb, stage) -> start time
    task_end: dict
    comm_busy: np.ndarray     # [P]
    rs_exposed: float = 0.0   # reduce-scatter time visible in the makespan
    #                           (tail + any serial reduce charges); the
    #                           hidden remainder overlapped B/W compute

    def throughput(self, samples_per_step: float) -> float:
        return samples_per_step / self.makespan


def simulate(tt: TickTable, cm: CostModel, *,
             _skip_mem: bool = False) -> SimResult:
    """List-scheduled execution: each rank runs its tasks in table order,
    starting each as soon as (a) the rank is free and (b) dependencies
    (+ p2p) and any required parameter gather have completed.

    Reduce-scatters are issued when the task they are attached to (the
    unit's last weight-grad) finishes. With ``overlap_comm`` they ride an
    async per-rank reduce channel: a unit's tail reduce-scatter overlaps
    the next unit's B/W compute and only its *exposed* part — whatever
    outlives the last compute on the timeline — reaches the makespan
    (``rs_exposed``). Without overlap (blocking gathers, prefetch-0
    plans) each reduce charges its full α–β time serially on the rank.
    """
    P, V, U = tt.P, tt.V, tt.unit
    S = P * V
    orders: list[list] = [[] for _ in range(P)]
    for t, r, task in tt.tasks():
        g = tt.gather[t, r] if tt.gather is not None else -1
        red = tt.reduce is not None and tt.reduce[t, r] >= 0
        orders[r].append((task, g, red))

    end: dict[tuple, float] = {}
    start: dict[tuple, float] = {}
    rank_free = np.zeros(P)
    comm_free = np.zeros(P)   # per-rank gather channel
    red_free = np.zeros(P)    # per-rank reduce-scatter channel
    comm_busy = np.zeros(P)
    reduce_end_max = 0.0
    n_gather = 0

    # iterate in rounds until all scheduled (tasks unlock across ranks)
    idx = [0] * P
    total = sum(len(o) for o in orders)
    done_ct = 0
    guard = 0
    while done_ct < total and guard < total * P + 64:
        guard += 1
        progressed = False
        for r in range(P):
            while idx[r] < len(orders[r]):
                task, g, red = orders[r][idx[r]]
                key = (task.kind, task.mb, task.stage)
                # dependency readiness
                deps = []
                if task.kind == F and task.stage > 0:
                    deps.append((F, task.mb, task.stage - 1))
                if task.kind == B:
                    deps.append((F, task.mb, task.stage))
                    if task.stage < S - 1:
                        deps.append((B, task.mb, task.stage + 1))
                if task.kind == W:
                    deps.append((B, task.mb, task.stage))
                if any(d not in end for d in deps):
                    break  # must wait; revisit next round
                ready = rank_free[r]
                for d in deps:
                    lat = cm.t_p2p if d[2] != task.stage or d[0] != task.kind else 0.0
                    cross = (d[2] % P) != r
                    ready = max(ready, end[d] + (cm.t_p2p if cross else 0.0))
                # parameter gather (FSDP)
                if g >= 0:
                    gk = (r, idx[r])
                    if cm.overlap_comm:
                        # issued as early as the comm channel allows
                        g_start = comm_free[r]
                        g_end = g_start + cm.t_gather
                        comm_free[r] = g_end
                    else:
                        g_end = ready + cm.t_gather
                    comm_busy[r] += cm.t_gather
                    n_gather += 1
                    ready = max(ready, g_end)
                s0 = ready
                e0 = s0 + cm.dur(task.kind)
                start[key] = s0
                end[key] = e0
                rank_free[r] = e0
                # reduce-scatter attached to this tick (unit's last W):
                # issued at task end; async channel when overlapped,
                # serial rank time when blocking.
                if red and cm.t_reduce > 0:
                    if cm.overlap_comm:
                        r_end = max(e0, red_free[r]) + cm.t_reduce
                        red_free[r] = r_end
                    else:
                        r_end = e0 + cm.t_reduce
                        rank_free[r] = r_end
                    reduce_end_max = max(reduce_end_max, r_end)
                comm_busy[r] += cm.t_reduce if red else 0.0
                idx[r] += 1
                done_ct += 1
                progressed = True
        if not progressed:
            # stuck: deadlock in table (shouldn't happen on valid tables)
            raise RuntimeError("simulator deadlock — invalid schedule order")

    task_makespan = float(max(end.values()))
    makespan = max(task_makespan, reduce_end_max)
    busy = np.zeros(P)
    for (k, u, s), e in end.items():
        busy[s % P] += cm.dur(k)

    n_reduce = int((tt.reduce >= 0).sum()) if tt.reduce is not None else 0

    # exposed reduce-scatter time: what the reduces actually add to the
    # critical path (tail exposure under overlap; the serial charges are
    # already folded into the task timeline when blocking, so compare
    # against a reduce-free replay of the same table — timeline only,
    # the replay's memory trace would be discarded).
    rs_exposed = makespan - task_makespan
    if not cm.overlap_comm and n_reduce and cm.t_reduce > 0:
        rs_exposed = makespan - simulate(
            tt, dataclasses.replace(cm, t_reduce=0.0),
            _skip_mem=True).makespan

    if _skip_mem:
        peak, peak_rank = 0.0, np.zeros(P)
    else:
        peak, peak_rank = _memory_trace(tt, cm, start, end)
    return SimResult(
        makespan=makespan,
        busy=busy,
        bubble_frac=float(1.0 - busy.mean() / makespan),
        peak_mem=float(peak),
        peak_mem_rank=peak_rank,
        n_gather=n_gather,
        n_reduce=n_reduce,
        task_start=start,
        task_end=end,
        comm_busy=comm_busy,
        rs_exposed=float(max(rs_exposed, 0.0)),
    )


def _memory_trace(tt, cm, start, end):
    """Activation/stash/weight-buffer watermark per rank (paper §3.4 model).

    * activation of (mb, stage): alive F-end → B-end
    * W-stash of (mb, stage):    alive B-end → W-end (split schedules)
    * gathered weights: double-buffer of 2 stage blocks when FSDP events
      exist, else resident L/P share (non-FSDP baselines).
    """
    P = tt.P
    events: list[list[tuple[float, float]]] = [[] for _ in range(P)]
    has_w = any(task.kind == W for _, _, task in tt.tasks())
    for (k, u, s), e in end.items():
        r = s % P
        if k == F:
            events[r].append((e, +cm.m_act))
        elif k == B:
            events[r].append((e, -cm.m_act))
            if has_w:
                events[r].append((e, +cm.m_wstash))
        elif k == W:
            events[r].append((e, -cm.m_wstash))
    peak_rank = np.zeros(P)
    for r in range(P):
        cur = 0.0
        for _, delta in sorted(events[r], key=lambda x: (x[0], -x[1])):
            cur += delta
            peak_rank[r] = max(peak_rank[r], cur)
    fsdp = tt.gather is not None and (tt.gather >= 0).any()
    wbuf = 2 * cm.m_weight if fsdp else tt.V * cm.m_weight
    peak_rank = peak_rank + wbuf
    return peak_rank.max(), peak_rank


# --------------------------------------------------------------------------- #
# Hardware presets → CostModel for a given model/stage workload
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops: float          # peak per chip, per second
    hbm_bw: float         # bytes/s
    link_bw: float        # bytes/s inter-chip (p2p / collective)
    intra_bw: float = 0.0  # bytes/s within a node (if hierarchical)


A800 = Hardware("A800", flops=312e12, hbm_bw=2.0e12, link_bw=25e9,
                intra_bw=200e9)
TPU_V5E = Hardware("v5e", flops=197e12, hbm_bw=819e9, link_bw=50e9)


def cost_model_for(
    hw: Hardware,
    *,
    layer_flops_f: float,      # forward flops of one layer × one micro-batch
    layers_per_stage: float,
    act_bytes: float,          # stage-boundary activation bytes (one mb)
    stage_param_bytes: float,
    dp: int,
    mfu: float = 0.5,
    remat: bool = True,
    cross_node_dp: bool = False,
    alpha: float = 0.0,        # per-collective launch latency (s)
    beta: float | None = None,  # s/byte on the collective path (1/bw_eff)
    n_coll_gather: int = 1,    # collectives per gather tick (1 = flat)
    n_coll_reduce: int = 1,    # collectives per reduce tick
    a2a_alpha: float = 0.0,    # EP all-to-all launch latency (s)
    a2a_beta: float | None = None,  # s/byte on the a2a path
    a2a_bytes: float = 0.0,    # wire bytes of one a2a event
    n_a2a_f: int = 0,          # a2a events inside one F tick
    n_a2a_b: int = 0,          # a2a events inside one B tick
) -> CostModel:
    """Napkin-math durations from hardware peaks at an assumed MFU.

    Collective ticks are costed α–β style: ``n_collectives × α`` (launch
    latency — the term per-tensor collectives lose on) plus
    ``bytes × β`` (bandwidth — identical either way). ``beta=None``
    falls back to the preset's link/intra bandwidth.
    """
    eff = hw.flops * mfu
    t_f = layers_per_stage * layer_flops_f / eff
    # B = input-grad (≈ fwd flops) + remat re-forward when enabled
    t_b = (layers_per_stage * layer_flops_f * (2 if remat else 1)) / eff
    t_w = layers_per_stage * layer_flops_f / eff
    bw = hw.link_bw if cross_node_dp or hw.intra_bw == 0 else hw.intra_bw
    b = beta if beta is not None else 1.0 / bw
    wire_bytes = stage_param_bytes * (dp - 1) / dp
    # 0 collectives per tick = none issued at all (weight-resident serve)
    t_gather = (alpha * n_coll_gather + wire_bytes * b
                if n_coll_gather > 0 else 0.0)
    t_reduce = (alpha * n_coll_reduce + wire_bytes * b
                if n_coll_reduce > 0 else 0.0)
    ab = a2a_beta if a2a_beta is not None else b
    t_a2a = (a2a_alpha + a2a_bytes * ab
             if (n_a2a_f or n_a2a_b) else 0.0)
    return CostModel(
        t_f=t_f, t_b=t_b, t_w=t_w,
        t_p2p=act_bytes / hw.link_bw,
        t_gather=t_gather, t_reduce=t_reduce,
        m_act=act_bytes, m_wstash=2 * act_bytes,
        m_weight=stage_param_bytes,
        coll_alpha=alpha, n_coll_gather=n_coll_gather,
        n_coll_reduce=n_coll_reduce,
        t_a2a=t_a2a, n_a2a_f=n_a2a_f, n_a2a_b=n_a2a_b,
        a2a_bytes=a2a_bytes, a2a_alpha=a2a_alpha,
    )
