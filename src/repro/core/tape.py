"""Stage-level mini-autodiff with the ZeroPP F / B(dx) / W(dW) split.

The paper (§2, §3.2) relies on separating the backward pass of every
parameterized GEMM into

  * **B** — the input-gradient pass ``dx = dy · Wᵀ`` which sits on the
    pipeline's critical path and must be scheduled as early as possible, and
  * **W** — the weight-gradient pass ``dW = xᵀ · dy`` which has no
    inter-device data dependency and can be inserted into pipeline bubbles.

PyTorch implementations intercept autograd; JAX is functional, so stages are
written against this small tape.  Every parameterized contraction is recorded
as a ``dense`` node (its ``(x, dy)`` pair is *stashed* during B and the dW
GEMM is replayed later by :func:`compute_dw`), while everything else
(norms, rotary, attention cores, scan cores, element-wise glue) is a
``generic`` node whose backward comes from ``jax.vjp`` — those parameters
(norm scales, biases, SSM Δ/A params, routers) receive *immediate* gradients
during B, which is what GPU implementations of the paper do as well (W tasks
are GEMM weight-gradients only).

Numerics are validated against ``jax.grad`` in ``tests/test_tape.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "Tape",
    "TVal",
    "WStash",
    "compute_dw",
    "dw_zeros_like",
]


@dataclasses.dataclass
class TVal:
    """A tape-tracked value (single array)."""

    idx: int
    val: jnp.ndarray

    @property
    def shape(self):
        return self.val.shape

    @property
    def dtype(self):
        return self.val.dtype


def _derive_specs(spec: str) -> tuple[str, str]:
    """From a forward einsum ``"x,w->y"`` derive the dx and dW einsum specs."""
    lhs, out = spec.split("->")
    x_s, w_s = lhs.split(",")
    dx_spec = f"{out},{w_s}->{x_s}"
    dw_spec = f"{x_s},{out}->{w_s}"
    return dx_spec, dw_spec


@dataclasses.dataclass
class _DenseRec:
    out_idx: int
    in_idx: int
    pname: str
    spec: str
    x_saved: jnp.ndarray
    w_ref: jnp.ndarray


@dataclasses.dataclass
class _GenericRec:
    out_idxs: tuple[int, ...]
    in_idxs: tuple[int, ...]
    pnames: tuple[str, ...]
    vjp_fn: Callable  # closes over tracers; valid within one trace
    out_avals: tuple[Any, ...]  # (shape, dtype) per output, for zero-filling


# A W-stash entry: everything needed to replay dW = einsum(dw_spec, x, dy).
# Kept as a flat pytree-compatible tuple so it can live in scan carries.
@dataclasses.dataclass
class WStash:
    pname: str
    dw_spec: str
    x: jnp.ndarray
    dy: jnp.ndarray


class Tape:
    """One stage execution context.

    mode="fwd"  : primitives just compute (the F task).
    mode="bwd"  : primitives compute *and* record; :meth:`backward` then
                  walks the records in reverse producing input cotangents,
                  immediate (non-GEMM) parameter grads, and the W-stash.
    """

    def __init__(self, params: dict[str, jnp.ndarray], mode: str = "fwd",
                 no_defer: frozenset[str] | set[str] = frozenset()):
        assert mode in ("fwd", "bwd")
        self.params = params
        self.mode = mode
        self.no_defer = no_defer  # dense params whose dW is computed in B
        self._n = 0
        self._records: list[Any] = []

    # ------------------------------------------------------------------ #
    def value(self, arr: jnp.ndarray) -> TVal:
        """Wrap an externally produced array as a tape input."""
        self._n += 1
        return TVal(self._n, arr)

    def param(self, name: str) -> jnp.ndarray:
        return self.params[name]

    # ------------------------------------------------------------------ #
    def dense(self, x: TVal, pname: str, spec: str) -> TVal:
        """y = einsum(spec, x, params[pname]) — a deferred-dW contraction."""
        w = self.params[pname]
        y = jnp.einsum(spec, x.val, w)
        out = self.value(y)
        if self.mode == "bwd":
            self._records.append(
                _DenseRec(out.idx, x.idx, pname, spec, x.val, w)
            )
        return out

    def prim(
        self,
        fn: Callable,
        *xs: TVal,
        pnames: Sequence[str] = (),
        n_out: int = 1,
    ):
        """Apply ``fn(*param_values, *x_values)``; backward via jax.vjp.

        Parameters named in ``pnames`` receive immediate gradients in B.
        """
        pvals = tuple(self.params[p] for p in pnames)
        xvals = tuple(x.val for x in xs)
        if self.mode == "bwd":
            outs, vjp_fn = jax.vjp(fn, *pvals, *xvals)
        else:
            outs = fn(*pvals, *xvals)
            vjp_fn = None
        if n_out == 1:
            outs_t = (outs,)
        else:
            outs_t = tuple(outs)
        out_vals = tuple(self.value(o) for o in outs_t)
        if self.mode == "bwd":
            self._records.append(
                _GenericRec(
                    tuple(o.idx for o in out_vals),
                    tuple(x.idx for x in xs),
                    tuple(pnames),
                    vjp_fn,
                    tuple((o.val.shape, o.val.dtype) for o in out_vals),
                )
            )
        return out_vals[0] if n_out == 1 else out_vals

    # Convenience wrappers ------------------------------------------------ #
    def add(self, a: TVal, b: TVal) -> TVal:
        return self.prim(lambda x, y: x + y, a, b)

    def mul(self, a: TVal, b: TVal) -> TVal:
        return self.prim(lambda x, y: x * y, a, b)

    def elementwise(self, fn: Callable, x: TVal) -> TVal:
        return self.prim(fn, x)

    # ------------------------------------------------------------------ #
    def backward(
        self, seeds: dict[int, jnp.ndarray]
    ) -> tuple[dict[int, jnp.ndarray], dict[str, jnp.ndarray], list[WStash]]:
        """Reverse-walk the tape.

        seeds: {TVal.idx: cotangent} for the stage outputs.
        Returns (input cotangents by idx, immediate param grads, W-stash).
        """
        assert self.mode == "bwd", "backward() requires a bwd-mode tape"
        cot: dict[int, jnp.ndarray] = dict(seeds)
        igrads: dict[str, jnp.ndarray] = {}
        wstash: list[WStash] = []

        def _acc(d: dict, k, v):
            if v is None:
                return
            if k in d:
                d[k] = d[k] + v
            else:
                d[k] = v

        for rec in reversed(self._records):
            if isinstance(rec, _DenseRec):
                dy = cot.pop(rec.out_idx, None)
                if dy is None:
                    continue
                dx_spec, dw_spec = _derive_specs(rec.spec)
                dx = jnp.einsum(dx_spec, dy, rec.w_ref)
                _acc(cot, rec.in_idx, dx)
                if rec.pname in self.no_defer:
                    # e.g. EP expert banks: dW now (stash would be huge)
                    _acc(igrads, rec.pname,
                         jnp.einsum(dw_spec, rec.x_saved, dy))
                else:
                    wstash.append(WStash(rec.pname, dw_spec, rec.x_saved, dy))
            else:  # _GenericRec
                dys = tuple(cot.pop(i, None) for i in rec.out_idxs)
                if all(d is None for d in dys):
                    continue
                # vjp needs the full cotangent structure; fill gaps with 0.
                dys_full = [
                    d if d is not None else jnp.zeros(shape, dtype)
                    for d, (shape, dtype) in zip(dys, rec.out_avals)
                ]
                grads_in = rec.vjp_fn(
                    dys_full[0] if len(dys_full) == 1 else tuple(dys_full)
                )
                np_, nx = len(rec.pnames), len(rec.in_idxs)
                for p, g in zip(rec.pnames, grads_in[:np_]):
                    _acc(igrads, p, g)
                for i, g in zip(rec.in_idxs, grads_in[np_: np_ + nx]):
                    _acc(cot, i, g)
        return cot, igrads, wstash


# -------------------------------------------------------------------------- #
def compute_dw(wstash: Sequence[WStash]) -> dict[str, jnp.ndarray]:
    """The W task: replay only the dW GEMMs from the stash."""
    grads: dict[str, jnp.ndarray] = {}
    for s in wstash:
        g = jnp.einsum(s.dw_spec, s.x, s.dy)
        if s.pname in grads:
            grads[s.pname] = grads[s.pname] + g
        else:
            grads[s.pname] = g
    return grads


def dw_zeros_like(params: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
    return {k: jnp.zeros_like(v) for k, v in params.items()}
