"""Schedule generators: ZeroPP + every baseline the paper compares against.

All schedules are produced by one greedy list scheduler driven by
per-method task priorities and gating rules, then packed into a TickTable.
This mirrors how the paper builds schedules (§3.2: blockwise F order, input
gradients as early as possible, weight gradients into bubbles; §3.1: units
are strictly sequential so their memory can be reused).

Baselines (gpipe / 1f1b / interleaved / bfs) do not split the backward:
they carry F and fused-B tasks only (``split_bw=False``), exactly like the
methods they model.

Every built-in is registered in the schedule registry
(``repro.api.registry``); new schedules plug in without touching this
file — register a ``(SchedParams) -> TickTable`` builder (usually a thin
wrapper over ``greedy_schedule`` with a custom priority).
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.api.registry import register_schedule
from repro.core.schedules import (
    B,
    F,
    NOP,
    W,
    Task,
    TickTable,
    rank_of,
    slot_of,
    stage_of,
)


@dataclasses.dataclass(frozen=True)
class SchedParams:
    P: int
    V: int
    n_mb: int
    unit: int = 0            # U; 0 -> n_mb (single unit)
    split_bw: bool = True    # ZeroPP-style dx/dW separation
    w_fill: str = "greedy"   # greedy | postpone (autogen then inserts)
    spill_w: bool = False    # beyond-paper: let W spill into the next unit

    @property
    def U(self) -> int:
        return self.unit or self.n_mb


def _unit_of(u: int, sp: SchedParams) -> int:
    return u // sp.U


def generate(method: str, sp: SchedParams) -> TickTable:
    """Build the TickTable for any registered schedule by name."""
    from repro.api.registry import SCHEDULE_REGISTRY

    return SCHEDULE_REGISTRY.get(method)(sp)


def _interleaved(sp: SchedParams) -> TickTable:
    """Megatron-style interleaved 1F1B (explicit construction).

    Virtual micro-batches are processed chunk-major in groups of P; each
    rank warms up with (P−r−1)·2 + (V−1)·P forwards then alternates 1F1B.
    """
    from repro.core import autogen as _ag  # retick (no cycle at call time)

    P, V, n_mb = sp.P, sp.V, sp.n_mb
    total = n_mb * V

    def f_task(k: int, r: int) -> Task:
        chunk = (k % (P * V)) // P
        mb = P * (k // (P * V)) + (k % P)
        return Task(F, mb, stage_of(r, chunk, P))

    def b_task(k: int, r: int) -> Task:
        chunk = V - 1 - (k % (P * V)) // P
        mb = P * (k // (P * V)) + (k % P)
        return Task(B, mb, stage_of(r, chunk, P))

    orders: list[list[Task]] = []
    for r in range(P):
        warmup = min((P - r - 1) * 2 + (V - 1) * P, total)
        order = [f_task(k, r) for k in range(warmup)]
        nf, nb = warmup, 0
        while nf < total or nb < total:
            if nf < total:
                order.append(f_task(nf, r))
                nf += 1
            if nb < total:
                order.append(b_task(nb, r))
                nb += 1
        orders.append(order)
    return _ag.retick(orders, P, V, n_mb, sp.U)


# --------------------------------------------------------------------------- #
# Greedy list scheduler
# --------------------------------------------------------------------------- #


def _prio_fwd_only(sp: SchedParams, kind: int, u: int, s: int):
    return (slot_of(s, sp.P), u, s)


def _prio_gpipe(sp: SchedParams, kind: int, u: int, s: int):
    # strict F-then-B phases, microbatch-major
    return (0 if kind == F else 1, slot_of(s, sp.P), u, s)


def _prio_bfs(sp: SchedParams, kind: int, u: int, s: int):
    # breadth-first by stage (v-major blocks), GPipe-like phases
    v = slot_of(s, sp.P)
    return (0 if kind == F else 1, v if kind == F else (sp.V - 1 - v), u)


def _prio_1f1b(sp: SchedParams, kind: int, u: int, s: int):
    # backward as early as possible (classic 1F1B emerges greedily)
    return (0 if kind == B else 1, u, slot_of(s, sp.P))


def _prio_interleaved(sp: SchedParams, kind: int, u: int, s: int):
    # megatron-style chunked round-robin: groups of P micro-batches
    v = slot_of(s, sp.P)
    if kind == B:
        return (0, u, sp.V - 1 - v)
    return (1, u // sp.P, v, u % sp.P)


def _prio_zeropp(sp: SchedParams, kind: int, u: int, s: int):
    # per-unit blocks; B first (input grads as early as possible,
    # breadth-first by stage block §3.2), blockwise F (v-major within
    # unit), W lowest (fills bubbles greedily).
    v = slot_of(s, sp.P)
    unit = _unit_of(u, sp)
    if kind == B:
        return (unit, 0, sp.V - 1 - v, u)
    if kind == F:
        return (unit, 1, v, u)
    return (unit, 2, v, u)  # W


def greedy_schedule(sp: SchedParams, priority, *, name: str = "custom",
                    split_bw: bool = False, fwd_only: bool = False,
                    unit_gated: bool = False) -> TickTable:
    """Greedy list scheduler driven by ``priority(sp, kind, u, s)``.

    ``split_bw`` generates separate W (weight-grad) tasks when the
    SchedParams ask for it; ``unit_gated`` enforces ZeroPP's per-unit
    memory-reuse gating. This is the building block custom registered
    schedules compose (see the registered built-ins below).
    """
    P, V, n_mb = sp.P, sp.V, sp.n_mb
    S = P * V
    split = sp.split_bw and split_bw

    # --- build the task set and dependency map --------------------------- #
    tasks: list[tuple[int, int, int]] = []  # (kind, u, s)
    for u in range(n_mb):
        for s in range(S):
            tasks.append((F, u, s))
            if not fwd_only:
                tasks.append((B, u, s))
                if split:
                    tasks.append((W, u, s))

    deps: dict[tuple, list[tuple]] = {t: [] for t in tasks}
    for u in range(n_mb):
        for s in range(S):
            if s > 0:
                deps[(F, u, s)].append((F, u, s - 1))
            if fwd_only:
                continue
            deps[(B, u, s)].append((F, u, s))
            if s < S - 1:
                deps[(B, u, s)].append((B, u, s + 1))
            if split:
                deps[(W, u, s)].append((B, u, s))
    # unit gating: nothing of unit n+1 starts before unit n fully done
    # (ZeroPP memory-reuse semantics; other methods use a single unit).
    if unit_gated and sp.U < n_mb:
        n_units = -(-n_mb // sp.U)
        unit_tasks = {n: [] for n in range(n_units)}
        for t in tasks:
            unit_tasks[_unit_of(t[1], sp)].append(t)
        for n in range(1, n_units):
            prev = [
                t for t in unit_tasks[n - 1]
                if t[0] != W or not sp.spill_w
            ]
            # gate only the F tasks of the next unit (B/W follow F anyway)
            for t in unit_tasks[n]:
                if t[0] == F and slot_of(t[2], P) == 0:
                    deps[t].extend(prev)

    # --- greedy tick loop (indegree-tracked list scheduling) -------------- #
    dependents: dict[tuple, list[tuple]] = {t_: [] for t_ in tasks}
    indeg: dict[tuple, int] = {}
    for t_, ds in deps.items():
        indeg[t_] = len(ds)
        for d in ds:
            dependents[d].append(t_)

    avail: list[list] = [[] for _ in range(P)]  # heaps of (prio, task)
    for t_ in tasks:
        if indeg[t_] == 0:
            heapq.heappush(
                avail[rank_of(t_[2], P)], (priority(sp, *t_), t_)
            )

    n_left = len(tasks)
    grid: list[list[Task | None]] = []
    staged: list[tuple] = []  # become available next tick
    max_ticks = len(tasks) * 3 + 64
    t = 0
    while n_left and t < max_ticks:
        row: list[Task | None] = [None] * P
        completed = []
        for r in range(P):
            if avail[r]:
                _, (k, u, s) = heapq.heappop(avail[r])
                row[r] = Task(k, u, s)
                completed.append((k, u, s))
                n_left -= 1
        grid.append(row)
        # tasks enabled by this tick's completions are usable from t+1
        for c in completed:
            for dep in dependents[c]:
                indeg[dep] -= 1
                if indeg[dep] == 0:
                    staged.append(dep)
        for t_ in staged:
            heapq.heappush(
                avail[rank_of(t_[2], P)], (priority(sp, *t_), t_)
            )
        staged = []
        t += 1
    if n_left:
        raise RuntimeError(
            f"schedule {name} did not converge: {n_left} tasks left"
        )

    tt = TickTable(P=P, V=V, n_mb=n_mb, unit=sp.U, grid=grid)
    attach_fsdp_events(tt)
    return tt


# --------------------------------------------------------------------------- #
# Built-in schedules (registered; new ones plug in the same way)
# --------------------------------------------------------------------------- #


@register_schedule("zeropp")
def _gen_zeropp(sp: SchedParams) -> TickTable:
    return greedy_schedule(sp, _prio_zeropp, name="zeropp",
                           split_bw=True, unit_gated=True)


@register_schedule("gpipe")
def _gen_gpipe(sp: SchedParams) -> TickTable:
    return greedy_schedule(sp, _prio_gpipe, name="gpipe")


@register_schedule("1f1b")
def _gen_1f1b(sp: SchedParams) -> TickTable:
    return greedy_schedule(sp, _prio_1f1b, name="1f1b")


@register_schedule("bfs")
def _gen_bfs(sp: SchedParams) -> TickTable:
    return greedy_schedule(sp, _prio_bfs, name="bfs")


@register_schedule("interleaved")
def _gen_interleaved(sp: SchedParams) -> TickTable:
    if sp.n_mb % sp.P == 0 and sp.V > 1:
        return _interleaved(sp)
    return greedy_schedule(sp, _prio_interleaved, name="interleaved")


@register_schedule("fwd_only")
def _gen_fwd_only(sp: SchedParams) -> TickTable:
    return greedy_schedule(sp, _prio_fwd_only, name="fwd_only",
                           fwd_only=True)


@register_schedule("autogen")
def _gen_autogen(sp: SchedParams) -> TickTable:
    """§4 heuristic auto-generation under the abstract unit-cost model.

    ``schedule="auto"`` sessions instead profile with a hardware preset
    (core.plan.select_plan passes the preset CostModel to autogen), but
    registering the abstract variant makes ``schedule="autogen"`` usable
    anywhere a schedule name is (RunConfig, generate_schedule, ...).

    W postponement crosses unit boundaries, so the table keeps the whole
    batch live (unit = n_mb) — unit-depth stash buffers would be
    overwritten before the postponed W tasks replay them. The
    ``"autogen_gated"`` sibling below keeps the §3.1 unit gating instead.
    """
    from repro.core.autogen import autogen
    from repro.core.simulator import CostModel

    return autogen(dataclasses.replace(sp, unit=sp.n_mb), CostModel()).table


@register_schedule("autogen_gated")
def _gen_autogen_gated(sp: SchedParams) -> TickTable:
    """Unit-gated §4 auto-generation under the abstract unit-cost model.

    Same bubble-filling loop as ``"autogen"``, but W passes are postponed
    only inside their own scheduling unit's live window and every
    insertion is checked against the unit-depth stash (B→W distance ≤ U),
    so the table keeps ``unit = sp.U`` and the paper's O(U) activation-
    memory bound — the trade the full-depth variant forfeits. With
    ``unit >= n_mb`` this degenerates to the full-depth search space.
    """
    from repro.core.autogen import autogen
    from repro.core.simulator import CostModel

    return autogen(sp, CostModel(), unit_gated=True).table


# --------------------------------------------------------------------------- #
# FSDP communication events (blockwise gathers, per-unit reduce-scatters)
# --------------------------------------------------------------------------- #


def attach_fsdp_events(tt: TickTable) -> None:
    """Gather before first use per (unit, v, phase); reduce after last
    weight-grad per (unit, v). Mirrors §3.3: 2V−1 gathers per unit (the
    F-phase gather of the last stage block is still resident when its
    backward starts)."""
    T, P, V, U = tt.T, tt.P, tt.V, tt.unit
    gather = -np.ones((T, P), np.int32)
    reduce = -np.ones((T, P), np.int32)
    first_use: dict[tuple, int] = {}   # (r, unit, v, phase) -> tick
    last_w: dict[tuple, int] = {}      # (r, unit, v) -> tick
    for t, r, task in tt.tasks():
        unit = task.mb // U
        v = slot_of(task.stage, P)
        phase = 0 if task.kind == F else 1
        key = (r, unit, v, phase)
        if task.kind in (F, B) and key not in first_use:
            first_use[key] = t
        if task.kind in (W, B):
            k2 = (r, unit, v)
            last_w[k2] = max(last_w.get(k2, -1), t)
    for (r, unit, v, phase), t in first_use.items():
        if phase == 1:
            # reuse: no re-gather if this block's F-phase gather is still
            # resident, i.e. no other stage block was gathered in between
            # (the buffer holds one stage block, §3.4).
            f_t = first_use.get((r, unit, v, 0))
            intervening = [
                tf for (r2, u2, v2, p2), tf in first_use.items()
                if r2 == r and (u2, v2, p2) != (unit, v, 0)
                and f_t is not None and f_t < tf <= t
                and not (u2 == unit and v2 == v and p2 == 1)
            ]
            if f_t is not None and not intervening:
                continue
        gather[t, r] = v
    for (r, unit, v), t in last_w.items():
        reduce[t, r] = v
    tt.gather = gather
    tt.reduce = reduce
