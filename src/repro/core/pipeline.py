"""Table-driven SPMD pipeline executor (the ZeroPP runtime).

One jitted program per step: ``shard_map`` over the production mesh, inside
which each segment's schedule runs as a ``lax.scan`` over ticks. Every tick:

  1. incoming wires (activations fwd / input-grads bwd) are stored into
     micro-batch buffers per static receive maps derived from the table;
  2. a ``lax.cond`` issues this tick's FSDP all-gather (blockwise, §3.3)
     into a rotating two-slot buffer;
  3. a ``lax.switch`` dispatches {NOP, F, B, W} on this rank's table cell —
     F runs the tape forward and stashes the stage input (remat), B re-runs
     forward + input-grad backward and stashes (x, dy) per GEMM, W replays
     the deferred dW GEMMs (the paper's bubble filler);
  4. a ``lax.cond`` reduce-scatters a finished stage block's gradients
     (once per scheduling unit, §3.3);
  5. boundary ``ppermute``s move activations (+1) and input-grads (−1)
     around the intra-group stage ring.

The same executor runs ZeroPP and every baseline (they are just different
tables), forward-only tables for prefill/decode serving, and the whisper
encoder/decoder as chained segment scans (enc-fwd → dec-train → enc-bwd).

All rank-varying branching is driven by *static* numpy tables indexed by
the dynamic model-axis rank — see DESIGN.md §3 for why this is the
TPU-native realization of the paper's per-rank GPU kernel queues.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import fsdp
from repro.core.generators import SchedParams, generate
from repro.core.schedules import B as KB
from repro.core.schedules import F as KF
from repro.core.schedules import NOP as KN
from repro.core.schedules import W as KW
from repro.core.schedules import TickTable, to_arrays
from repro.core.tape import Tape, compute_dw
from repro.models import blocks, model as M
from repro.models.common import ModelConfig, RunConfig, rope_tables

DATA, MODEL, POD = "data", "model", "pod"


# --------------------------------------------------------------------------- #
# Static table preprocessing
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class PackedTable:
    """Device-ready per-tick arrays [T, Pe] + static metadata."""

    T: int
    Pe: int            # ranks per pipeline group
    V: int
    U: int             # unit size (xbuf/stash depth)
    n_mb: int
    kind: np.ndarray   # [T, Pe] {0 nop, 1 F, 2 B, 3 W}
    mb: np.ndarray     # [T, Pe] microbatch index
    v: np.ndarray      # [T, Pe] local stage slot
    gather_v: np.ndarray    # [T, Pe] slot to all-gather (-1 none)
    gather_slot: np.ndarray  # [T, Pe] double-buffer slot for that gather
    use_slot: np.ndarray    # [T, Pe] which buffer slot holds params of v
    reduce_v: np.ndarray    # [T, Pe] slot to reduce-scatter (-1 none)
    recv_f_u: np.ndarray    # [T, Pe] mb arriving on fwd wire this tick (-1)
    recv_b_u: np.ndarray    # [T, Pe] mb arriving on bwd wire this tick (-1)

    def rows(self):
        """As jnp arrays stacked for lax.scan xs."""
        fields = ["kind", "mb", "v", "gather_v", "gather_slot", "use_slot",
                  "reduce_v", "recv_f_u", "recv_b_u"]
        return {f: jnp.asarray(getattr(self, f)) for f in fields}

    @property
    def has_w(self) -> bool:
        """False for fused-backward baselines (dW computed inside B)."""
        return bool((self.kind == KW).any())


def pack_table(tt: TickTable, prefetch: int = 0) -> PackedTable:
    arr = to_arrays(tt)
    T, Pe = arr["kind"].shape
    V = tt.V
    kind, mb, v = arr["kind"], arr["mb"], arr["v"]
    gather_v = arr["gather"]
    reduce_v = arr["reduce"]

    if prefetch > 0:
        # §3.3 prefetch: issue each stage-block gather up to `prefetch`
        # ticks before its first use so the async all-gather overlaps the
        # previous block's compute. Safe moves only: the target tick must
        # be gather-free, and no task between target and origin may still
        # be *reading* the destination buffer slot (the slot parity
        # alternates per gather, so skipping past reads of the other slot
        # is fine — we recompute slot assignments afterwards).
        for p_ in range(Pe):
            order = [t for t in range(T) if gather_v[t, p_] >= 0]
            for gi, t in enumerate(order):
                slot_parity = gi % 2
                tgt = t
                for back in range(1, prefetch + 1):
                    cand = t - back
                    if cand < 0 or gather_v[cand, p_] >= 0:
                        break
                    # reads of the same slot between cand and t?
                    conflict = False
                    for tt_ in range(cand, t):
                        if kind[tt_, p_] in (KF, KB, KW):
                            # which slot does that task read? parity of
                            # the most recent gather before tt_
                            prev = [g for g in order[:gi] if g <= tt_]
                            if prev and (len(prev) - 1) % 2 == slot_parity:
                                conflict = True
                                break
                    if conflict:
                        break
                    tgt = cand
                if tgt != t:
                    gather_v[tgt, p_] = gather_v[t, p_]
                    gather_v[t, p_] = -1

    # Rotating two-slot gather buffer assignment.
    gather_slot = -np.ones((T, Pe), np.int32)
    use_slot = np.zeros((T, Pe), np.int32)
    for p in range(Pe):
        nxt = 0
        holds = {}  # v -> slot
        for t in range(T):
            if gather_v[t, p] >= 0:
                gather_slot[t, p] = nxt
                holds[gather_v[t, p]] = nxt
                nxt = 1 - nxt
            if kind[t, p] in (KF, KB, KW):
                use_slot[t, p] = holds.get(v[t, p], 0)

    # Receive maps: what lands on each wire at the END of tick t-1 (i.e. is
    # available at tick t). Sender of fwd wire for rank p is p-1 (ring).
    recv_f_u = -np.ones((T, Pe), np.int32)
    recv_b_u = -np.ones((T, Pe), np.int32)
    S = Pe * V
    for t in range(1, T):
        for p in range(Pe):
            prev = (p - 1) % Pe
            if kind[t - 1, prev] == KF:
                stage = v[t - 1, prev] * Pe + prev
                if stage < S - 1:
                    recv_f_u[t, p] = mb[t - 1, prev]
            nxt_r = (p + 1) % Pe
            if kind[t - 1, nxt_r] == KB:
                stage = v[t - 1, nxt_r] * Pe + nxt_r
                if stage > 0:
                    recv_b_u[t, p] = mb[t - 1, nxt_r]
    return PackedTable(
        T=T, Pe=Pe, V=V, U=tt.unit, n_mb=tt.n_mb,
        kind=kind, mb=mb, v=v,
        gather_v=gather_v, gather_slot=gather_slot, use_slot=use_slot,
        reduce_v=reduce_v, recv_f_u=recv_f_u, recv_b_u=recv_b_u,
    )


# --------------------------------------------------------------------------- #
# W-stash template (traced shapes, deduped GEMM operands)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class StashTemplate:
    entries: list          # [(pname, dw_spec, x_slot, dy_slot)]
    x_shapes: list         # slot -> (shape, dtype)
    dy_shapes: list
    igrad_names: list      # param names receiving immediate grads in B


def stash_template(cfg, rc, seg, specs, mb_shape, no_defer,
                   cross_ctx: int | None = None) -> StashTemplate:
    """Abstractly trace stage_bwd once to learn the stash structure.

    The template is traced with ep_axis=None (collective-free); EP expert
    GEMMs are in ``no_defer`` so their shapes never enter the stash, and
    the remaining entries are EP-independent.
    """
    cdt = jnp.dtype(rc.compute_dtype)
    rope = _rope_for(cfg, rc, mb_shape[1])
    params = {
        n: jax.ShapeDtypeStruct(sp.shape, jnp.dtype(rc.param_dtype))
        for n, sp in specs.items()
    }
    x = jax.ShapeDtypeStruct((*mb_shape, cfg.d_model), cdt)
    mem = (jax.ShapeDtypeStruct((mb_shape[0], cross_ctx, cfg.d_model), cdt)
           if cross_ctx else None)
    template: dict = {}

    def run(params, x, dy, mem):
        t = Tape(params, mode="bwd", no_defer=frozenset(no_defer))
        xin = t.value(x)
        ctx = blocks.LayerCtx(
            cfg=cfg, rc=rc, rope=rope, causal=seg.causal, ep_axis=None,
            enc_memory=t.value(mem) if mem is not None else None)
        out, aux = M.apply_stage(t, ctx, seg, xin, jnp.int32(0))
        cots, igrads, stash = t.backward(
            {out.idx: dy, aux.idx: jnp.zeros((), jnp.float32)}
        )
        xs, dys, entries = [], [], []
        xid = {}
        for s in stash:
            key = id(s.x)
            if key not in xid:
                xid[key] = len(xs)
                xs.append((s.x.shape, s.x.dtype))
            dyid = len(dys)
            dys.append((s.dy.shape, s.dy.dtype))
            entries.append((s.pname, s.dw_spec, xid[key], dyid))
        template["entries"] = entries
        template["x_shapes"] = xs
        template["dy_shapes"] = dys
        template["igrads"] = sorted(igrads)
        return cots[xin.idx]

    if mem is None:
        jax.eval_shape(lambda p, xx, dd: run(p, xx, dd, None), params, x, x)
    else:
        jax.eval_shape(run, params, x, x, mem)
    return StashTemplate(
        template["entries"], template["x_shapes"], template["dy_shapes"],
        template["igrads"],
    )


# --------------------------------------------------------------------------- #
# Runtime
# --------------------------------------------------------------------------- #


class Runtime:
    """Builds and runs the SPMD train/prefill/decode programs for one
    (ModelConfig, RunConfig) on a ("data","model"[, "pod"]) mesh."""

    def __init__(self, cfg: ModelConfig, rc: RunConfig, mesh,
                 multi_pod: bool = False):
        self.cfg, self.rc, self.mesh = cfg, rc, mesh
        self.geo = M.build_geometry(cfg, rc)
        self.multi_pod = multi_pod
        ax = dict(mesh.shape)
        self.dsize = ax[DATA]
        self.pods = ax.get(POD, 1)
        assert ax[MODEL] == self.geo.model_ranks, (
            f"mesh model axis {ax[MODEL]} != groups*pp "
            f"{self.geo.model_ranks}"
        )
        self.Pe = rc.pp
        self.G = rc.groups
        self.ep = rc.moe_mode == "ep" and cfg.moe is not None
        if cfg.encdec is not None:
            assert self.G == 1, "enc-dec uses a single pipeline group"

        # --- schedules per segment ---------------------------------------- #
        # Scheduling units only gate ZeroPP; other methods keep the whole
        # batch live, so their buffers must be n_mb deep.
        unit = rc.unit_size if rc.schedule == "zeropp" else rc.microbatches
        sp = SchedParams(P=rc.pp, V=rc.vpp, n_mb=rc.microbatches,
                         unit=unit)
        pf = rc.gather_prefetch

        def pack(t):
            return pack_table(t, prefetch=pf)
        self.tables: dict[str, PackedTable] = {}
        segs = {s.name: s for s in self.geo.segments}
        self.segs = segs
        if cfg.encdec is not None:
            # encoder passes are not unit-gated (fwd-only, then stripped
            # bwd) so their buffers must hold every micro-batch
            enc_sp = dataclasses.replace(sp, V=segs["enc"].vpp,
                                         unit=rc.microbatches)
            dec_sp = dataclasses.replace(sp, V=segs["dec"].vpp)
            self.tables["enc_fwd"] = pack(generate("fwd_only", enc_sp))
            full = generate(rc.schedule, dec_sp)
            self.tables["dec"] = pack(full)
            enc_full = generate(rc.schedule, enc_sp)
            self.tables["enc_bwd"] = pack(_strip_fwd(enc_full))
        else:
            self.tables["main"] = pack(generate(rc.schedule, sp))
        # serving tables (forward-only pipeline; not unit-gated, so the
        # buffers hold every micro-batch)
        sp_full = dataclasses.replace(sp, unit=rc.microbatches)
        if cfg.encdec is not None:
            self.tables["serve_main"] = self.tables["enc_fwd"]
            self.tables["serve_dec"] = pack(generate(
                "fwd_only", dataclasses.replace(dec_sp,
                                                unit=rc.microbatches)))
        else:
            self.tables["serve_main"] = pack(
                generate("fwd_only", sp_full))

        # --- parameter specs & shardings ---------------------------------- #
        self.stage_specs = {
            s.name: M.stage_specs(cfg, segs[s.name]) for s in
            self.geo.segments
        }
        self.io_specs = M.io_specs(cfg)
        self.pspecs = {
            "io": {n: fsdp.io_pspec(sp_, self.dsize)
                   for n, sp_ in self.io_specs.items()},
            "segments": {
                sname: {n: fsdp.stage_pspec(sp_, self.dsize, self.ep)
                        for n, sp_ in sps.items()}
                for sname, sps in self.stage_specs.items()
            },
        }
        self.gatherable = {
            sname: sorted(
                n for n, sp_ in sps.items()
                if fsdp.local_dim(sp_, self.dsize, self.ep) is not None
                and not (sp_.ep and self.ep)
            )
            for sname, sps in self.stage_specs.items()
        }
        if rc.serve_resident:
            # weight-resident serving (beyond-paper, §Perf): non-EP params
            # live fully gathered on each model rank — zero per-step FSDP
            # gathers, at V×stage_params HBM cost.
            for sname, sps in self.stage_specs.items():
                for n in self.gatherable[sname]:
                    self.pspecs["segments"][sname][n] = P(
                        MODEL, *([None] * len(sps[n].shape)))
                self.gatherable[sname] = []
        self.ep_names = {
            sname: sorted(n for n, sp_ in sps.items() if sp_.ep and self.ep)
            for sname, sps in self.stage_specs.items()
        }
        # io params: only the vocab-dim of embed/head shards (per the
        # vocab-shard decision); everything else is replicated — io params
        # are consumed outside the gather machinery.
        from repro.core import vocab as Vb
        vloc = Vb.vocab_shard(cfg.vocab, self.dsize)
        for n, sp_ in self.io_specs.items():
            if n in ("embed.table", "head.w") and vloc is not None:
                dims = [None] * len(sp_.shape)
                dims[sp_.fsdp_dim] = DATA
                self.pspecs["io"][n] = P(*dims)
            elif sp_.ep and self.ep:
                # MTP expert bank: EP-sharded like the stage experts
                self.pspecs["io"][n] = P(DATA,
                                         *([None] * (len(sp_.shape) - 1)))
            else:
                self.pspecs["io"][n] = P(*([None] * len(sp_.shape)))
        self._tmpl_cache: dict = {}

    def _stash_tmpl(self, seg, mb_shape, no_defer, cross_ctx=None):
        key = (seg.name, tuple(mb_shape), cross_ctx,
               tuple(sorted(no_defer)))
        if key not in self._tmpl_cache:
            self._tmpl_cache[key] = stash_template(
                self.cfg, self.rc, seg, self.stage_specs[seg.name],
                mb_shape, no_defer, cross_ctx=cross_ctx)
        return self._tmpl_cache[key]

    # ------------------------------------------------------------------ #
    def init_params(self, key=None):
        """Host init with the pipeline's duplicated-stage layout, then
        device_put with the runtime shardings."""
        from jax.sharding import NamedSharding

        key = key if key is not None else jax.random.PRNGKey(0)
        base = M.init_all_params(self.cfg, self.rc, key)
        segs = {}
        for seg in self.geo.segments:
            st = base["segments"][seg.name]
            V, Pe, G = seg.vpp, self.Pe, self.G
            order = []
            for mr in range(G * Pe):
                p = mr % Pe
                for v in range(V):
                    order.append(M.storage_index(p, v, V))
            segs[seg.name] = {
                n: jnp.stack([a[i] for i in order]) for n, a in st.items()
            }
        params = {"io": base["io"], "segments": segs}
        out = jax.tree.map(
            lambda a, spec: jax.device_put(
                a, NamedSharding(self.mesh, spec)),
            params,
            {"io": self.pspecs["io"], "segments": self.pspecs["segments"]},
        )
        return out

    def param_shapes(self):
        """ShapeDtypeStructs (for dry-run lowering without allocation)."""
        from jax.sharding import NamedSharding

        dt = jnp.dtype(self.rc.param_dtype)
        segs = {}
        for seg in self.geo.segments:
            V = seg.vpp
            segs[seg.name] = {
                n: jax.ShapeDtypeStruct(
                    (self.G * self.Pe * V, *sp_.shape), dt,
                    sharding=NamedSharding(
                        self.mesh,
                        self.pspecs["segments"][seg.name][n]),
                )
                for n, sp_ in self.stage_specs[seg.name].items()
            }
        io = {
            n: jax.ShapeDtypeStruct(
                sp_.shape, dt,
                sharding=NamedSharding(self.mesh, self.pspecs["io"][n]))
            for n, sp_ in self.io_specs.items()
        }
        return {"io": io, "segments": segs}

    # ------------------------------------------------------------------ #
    def batch_pspec(self):
        return P((POD, DATA)) if self.multi_pod else P(DATA)

    def input_specs(self, shape_cfg, max_seq=None):
        """ShapeDtypeStructs for the step inputs (see launch/dryrun.py)."""
        from jax.sharding import NamedSharding

        cfg, rc = self.cfg, self.rc
        gb, s = shape_cfg.global_batch, shape_cfg.seq_len
        shards = self.pods * self.dsize
        batch_shardable = gb % shards == 0 and gb >= shards
        sh = NamedSharding(
            self.mesh,
            self.batch_pspec() if batch_shardable else P())
        rep = NamedSharding(self.mesh, P())
        if shape_cfg.kind == "train":
            toks = (
                jax.ShapeDtypeStruct((gb, s, cfg.d_model), jnp.bfloat16,
                                     sharding=sh)
                if cfg.frontend == "vision"
                else jax.ShapeDtypeStruct((gb, s), jnp.int32, sharding=sh)
            )
            batch = {"tokens": toks,
                     "labels": jax.ShapeDtypeStruct((gb, s), jnp.int32,
                                                    sharding=sh)}
            if cfg.encdec is not None:
                batch["enc_tokens"] = jax.ShapeDtypeStruct(
                    (gb, cfg.encdec.enc_ctx, cfg.d_model), jnp.bfloat16,
                    sharding=sh)
                batch["tokens"] = jax.ShapeDtypeStruct(
                    (gb, s), jnp.int32, sharding=sh)
            return batch
        if shape_cfg.kind == "prefill":
            toks = (
                jax.ShapeDtypeStruct((gb, s, cfg.d_model), jnp.bfloat16,
                                     sharding=sh)
                if cfg.frontend == "vision"
                else jax.ShapeDtypeStruct((gb, s), jnp.int32, sharding=sh)
            )
            batch = {"tokens": toks}
            if cfg.encdec is not None:
                batch["enc_tokens"] = jax.ShapeDtypeStruct(
                    (gb, cfg.encdec.enc_ctx, cfg.d_model), jnp.bfloat16,
                    sharding=sh)
                batch["tokens"] = jax.ShapeDtypeStruct(
                    (gb, min(s, 448)), jnp.int32, sharding=sh)
            return batch
        # decode: one new token against a cache of length max_seq
        batch = {"tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32,
                                                sharding=sh),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)}
        if cfg.encdec is not None:
            batch["enc_tokens"] = jax.ShapeDtypeStruct(
                (gb, cfg.encdec.enc_ctx, cfg.d_model), jnp.bfloat16,
                sharding=sh)
        return batch


def _strip_fwd(tt: TickTable) -> TickTable:
    """B/W-only table (encoder backward segment): F ran in a prior scan."""
    from repro.core.autogen import orders_from_table, retick

    orders = orders_from_table(tt)
    orders = [[t for t in o if t.kind != KF] for o in orders]
    return retick(orders, tt.P, tt.V, tt.n_mb, tt.unit, assume_f=True)


# --------------------------------------------------------------------------- #
# Train step
# --------------------------------------------------------------------------- #


def make_train_step(rt: Runtime, shape_cfg):
    """Returns jit(step)(params, batch) -> (grads, metrics)."""
    cfg, rc, geo = rt.cfg, rt.rc, rt.geo
    from repro.core import vocab as Vb

    seq = shape_cfg.seq_len
    gb = shape_cfg.global_batch
    n_local = gb // (rt.pods * rt.dsize)
    Btot = rc.microbatches
    mbs = max(n_local // (rt.G * Btot), 1)
    assert mbs * rt.G * Btot == n_local, (
        f"global_batch {gb} must split into pods*data*groups*microbatches"
    )
    cdt = jnp.dtype(rc.compute_dtype)
    gdt = jnp.float32
    d = cfg.d_model
    vloc = Vb.vocab_shard(cfg.vocab, rt.dsize)
    denom = float(gb * seq)  # global token count
    n_moe = (sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i).endswith(":moe"))
             if cfg.moe else 0)
    # Reference semantics: loss += w * sum over (stages, micro-batches of
    # per-token-mean aux); each micro-batch contributes aux/B_global.
    aux_seed = (
        cfg.moe.router_aux_weight / (Btot * rt.G * rt.dsize * rt.pods)
        if cfg.moe else 0.0
    )

    mesh = rt.mesh
    batch_spec = rt.batch_pspec()

    def step(params, batch):
        in_specs = (
            {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]},
            jax.tree.map(lambda _: batch_spec, batch),
        )
        grad_specs = {"io": rt.pspecs["io"],
                      "segments": rt.pspecs["segments"]}
        out_specs = (grad_specs, P())
        fn = fsdp.shard_map(
            partial(_train_body, rt=rt, shape_cfg=shape_cfg, mbs=mbs,
                    vloc=vloc, denom=denom, aux_seed=aux_seed),
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return fn(params, batch)

    return jax.jit(step)


def _train_body(params, batch, *, rt: Runtime, shape_cfg, mbs, vloc,
                denom, aux_seed):
    """The SPMD program (runs per device under shard_map)."""
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    io_p = params["io"]
    mr = jax.lax.axis_index(MODEL)
    Pe, G, V = rt.Pe, rt.G, rc.vpp
    p_rank = mr % Pe
    g_rank = mr // Pe
    cdt = jnp.dtype(rc.compute_dtype)
    d = cfg.d_model

    # io params arrive in their local (possibly vocab-sharded) shapes
    io_zero = {n: jnp.zeros(a.shape, jnp.float32) for n, a in io_p.items()}

    metrics0 = {"loss_sum": jnp.zeros((), jnp.float32),
                "aux_sum": jnp.zeros((), jnp.float32),
                "emb_dropped": jnp.zeros((), jnp.int32)}

    if cfg.encdec is None:
        seg = rt.segs["main"]
        pt = rt.tables["main"]
        res = _segment_train_scan(
            rt, seg, pt, params["segments"]["main"], io_p,
            batch, mbs, shape_cfg.seq_len, vloc, denom, aux_seed,
            io_zero, metrics0, p_rank, g_rank,
            inject="tokens", seed="loss", membuf=None, dmembuf=None,
        )
        seg_grads = {"main": res["stage_grads"]}
        io_g, metrics = res["io_grads"], res["metrics"]
    else:
        seg_e, seg_d = rt.segs["enc"], rt.segs["dec"]
        enc_ctx = cfg.encdec.enc_ctx
        # the enc forward scan must allocate the stash buffers its later
        # backward scan (which *does* defer W) will fill
        enc_nd = set(rt.ep_names["enc"])
        enc_tmpl = (enc_nd, rt._stash_tmpl(seg_e, (mbs, enc_ctx), enc_nd))
        # 1) encoder forward (stash inputs for its later backward)
        res_e = _segment_train_scan(
            rt, seg_e, rt.tables["enc_fwd"], params["segments"]["enc"],
            io_p, batch, mbs, enc_ctx, vloc, denom, aux_seed,
            io_zero, metrics0, p_rank, g_rank,
            inject="enc_tokens", seed=None, membuf="collect", dmembuf=None,
            tmpl_override=enc_tmpl,
        )
        membuf = jax.lax.psum(res_e["membuf"], MODEL)
        # 2) decoder train (full F/B/W) with cross-attention memory
        res_d = _segment_train_scan(
            rt, seg_d, rt.tables["dec"], params["segments"]["dec"], io_p,
            batch, mbs, shape_cfg.seq_len, vloc, denom, aux_seed,
            res_e["io_grads"], res_e["metrics"], p_rank, g_rank,
            inject="tokens", seed="loss", membuf=membuf, dmembuf="collect",
        )
        dmem = jax.lax.psum(res_d["dmembuf"], MODEL)
        # 3) encoder backward (B/W only, seeded by accumulated dMemory)
        res_eb = _segment_train_scan(
            rt, seg_e, rt.tables["enc_bwd"], params["segments"]["enc"],
            io_p, batch, mbs, enc_ctx, vloc, denom, aux_seed,
            res_d["io_grads"], res_d["metrics"], p_rank, g_rank,
            inject="enc_tokens", seed="buffer", membuf=None, dmembuf=None,
            seed_buf=dmem, carry_in=res_e["carry_out"],
            tmpl_override=enc_tmpl,
        )
        seg_grads = {"enc": res_eb["stage_grads"],
                     "dec": res_d["stage_grads"]}
        io_g, metrics = res_eb["io_grads"], res_eb["metrics"]

    # ---- cross-group / cross-pod gradient reduction ----------------------- #
    for sname in seg_grads:
        seg_grads[sname] = {
            n: fsdp.group_allreduce(g, rt.G, Pe)
            for n, g in seg_grads[sname].items()
        }
        if rt.multi_pod:
            seg_grads[sname] = {n: jax.lax.psum(g, POD)
                                for n, g in seg_grads[sname].items()}
    io_g = {n: jax.lax.psum(g, MODEL) for n, g in io_g.items()}
    if rt.multi_pod:
        io_g = {n: jax.lax.psum(g, POD) for n, g in io_g.items()}
    # replicated io params need the data-sum of per-shard contributions;
    # vocab-sharded embed/head rows and EP-sharded MTP experts are already
    # local-complete.
    ep_io = {n for n, sp_ in rt.io_specs.items() if sp_.ep and rt.ep}
    for n in io_g:
        if n in ep_io:
            continue
        if vloc is None or n not in ("embed.table", "head.w"):
            io_g[n] = jax.lax.psum(io_g[n], DATA)

    metrics = {k: jax.lax.psum(v, (DATA, MODEL) + ((POD,) if rt.multi_pod
                                                   else ()))
               for k, v in metrics.items()}
    grads = {"io": io_g, "segments": seg_grads}
    return grads, metrics


def _segment_train_scan(
    rt: Runtime, seg, pt: PackedTable, seg_p, io_p, batch, mbs, seq,
    vloc, denom, aux_seed, io_g0, metrics0, p_rank, g_rank, *,
    inject: str, seed: str | None, membuf, dmembuf, seed_buf=None,
    carry_in=None, tmpl_override=None,
):
    """Run one segment's schedule as a lax.scan over ticks.

    inject:  batch key providing stage-0 inputs (int tokens or float embeds)
    seed:    "loss" (LM head at last stage) | "buffer" (seed_buf[u]) | None
    membuf:  None | "collect" (store drain outputs) | array [U, mbs, ctx, d]
             (cross-attention memory for decoder segments)
    dmembuf: "collect" to accumulate d(enc_memory) during B tasks
    carry_in: reuse stash buffers from a previous scan of the same segment
    """
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    cdt = jnp.dtype(rc.compute_dtype)
    d = cfg.d_model
    V, Pe, G, U = seg.vpp, rt.Pe, rt.G, pt.U
    Btot = pt.n_mb
    S = Pe * V
    specs = rt.stage_specs[seg.name]
    gatherable = rt.gatherable[seg.name]
    ep_names = set(rt.ep_names[seg.name])
    ep_axis = DATA if (rt.ep and any(
        k.endswith(":moe") for k in seg.kinds)) else None
    has_cross = membuf is not None and not isinstance(membuf, str)
    cross_ctx = cfg.encdec.enc_ctx if (has_cross and cfg.encdec) else None
    # Fused-backward baselines have no W tasks: every dense's dW is
    # computed immediately inside B (classic 1F1B/GPipe semantics).
    if tmpl_override is not None:
        no_defer, tmpl = tmpl_override
    else:
        no_defer = set(ep_names) if pt.has_w else set(specs)
        if rc.no_defer_extra and pt.has_w:
            no_defer |= {n for n in specs
                         if any(sub in n for sub in rc.no_defer_extra)}
        tmpl = rt._stash_tmpl(seg, (mbs, seq), no_defer,
                              cross_ctx=cross_ctx)
    tokens = batch[inject]
    int_tokens = jnp.issubdtype(tokens.dtype, jnp.integer)
    labels = batch.get("labels")

    rope = _rope_for(cfg, rc, seq)
    dsize = rt.dsize

    def tok_slice(arr, u):
        start = (g_rank * Btot + u) * mbs
        return jax.lax.dynamic_slice_in_dim(arr, start, mbs, axis=0)

    def stage_params(v, use_slot, gbuf):
        out = {}
        for n in specs:
            if n in gatherable:
                out[n] = jax.lax.dynamic_index_in_dim(
                    gbuf[n], jnp.clip(use_slot, 0, 1), 0, keepdims=False)
            else:
                out[n] = jax.lax.dynamic_index_in_dim(
                    seg_p[n], jnp.clip(v, 0, V - 1), 0, keepdims=False)
        return out

    # ---- carry ------------------------------------------------------------ #
    act = (mbs, seq, d)
    zeros_act = jnp.zeros(act, cdt)
    if carry_in is None:
        gbuf = {
            n: jnp.zeros((2, *_gathered_shape(specs[n], dsize, rt.ep)), cdt)
            for n in gatherable
        }
        carry = dict(
            send_f=zeros_act, send_b=zeros_act,
            recv_f=zeros_act, recv_b=zeros_act,
            xbuf=jnp.zeros((U, *act), cdt),
            bbuf=jnp.zeros((U, *act), cdt),
            fstash=jnp.zeros((V, U, *act), cdt),
            wx=[jnp.zeros((V, U, *sh), dt) for sh, dt in tmpl.x_shapes],
            wdy=[jnp.zeros((V, U, *sh), dt) for sh, dt in tmpl.dy_shapes],
            gbuf=gbuf,
            acc_full={n: jnp.zeros((V, *specs[n].shape), jnp.float32)
                      for n in specs if n not in ep_names},
            acc_shard={n: jnp.zeros(
                (V, *_local_shape(specs[n], dsize, rt.ep)), jnp.float32)
                for n in specs},
            io_g=io_g0,
            metrics=metrics0,
        )
    else:
        carry = carry_in
        carry["io_g"] = io_g0
        carry["metrics"] = metrics0
    if membuf == "collect":
        carry["membuf"] = jnp.zeros((Btot, mbs, seq, d), cdt)
    if dmembuf == "collect":
        enc_ctx2 = cfg.encdec.enc_ctx
        carry["dmembuf"] = jnp.zeros((Btot, mbs, enc_ctx2, d), cdt)

    # ---- branch bodies ----------------------------------------------------#
    def make_ctx(tape, u):
        """Returns (ctx, mem_tval or None)."""
        mem = None
        if has_cross:
            mem = tape.value(jax.lax.dynamic_index_in_dim(
                membuf, u, 0, keepdims=False))
        ctx = blocks.LayerCtx(cfg=cfg, rc=rc, rope=rope, causal=seg.causal,
                              ep_axis=ep_axis, enc_memory=mem)
        return ctx, mem

    def get_input(c, u, v):
        uu = u % U
        x = jax.lax.dynamic_index_in_dim(c["xbuf"], uu, 0, keepdims=False)
        is_inject = (p_rank == 0) & (v == 0)

        def do_embed(_):
            ids_or_emb = tok_slice(tokens, u)
            if int_tokens:
                return Vb.embed_lookup(io_p["embed.table"], ids_or_emb,
                                       vloc, cdt)
            return ids_or_emb.astype(cdt)

        return jax.lax.cond(is_inject, do_embed, lambda _: x, None)

    def f_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        x = get_input(c, u, v)
        params_v = stage_params(v, row["use_slot"], c["gbuf"])
        t = Tape(params_v, mode="fwd", no_defer=frozenset(no_defer))
        stage_id = v * Pe + p_rank
        ctx, _ = make_ctx(t, u)
        y, _aux = M.apply_stage(t, ctx, seg, t.value(x), stage_id)
        c = dict(c)
        c["fstash"] = _dyn_set2(c["fstash"], v, uu, x)
        c["send_f"] = y.val
        if "membuf" in c:
            is_drain = (p_rank == Pe - 1) & (v == V - 1)
            c["membuf"] = jax.lax.cond(
                is_drain,
                lambda mb: jax.lax.dynamic_update_index_in_dim(
                    mb, y.val, u, 0),
                lambda mb: mb, c["membuf"])
        return c

    def b_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        x = jax.lax.dynamic_index_in_dim(c["fstash"], jnp.clip(v, 0, V - 1),
                                         0, keepdims=False)
        x = jax.lax.dynamic_index_in_dim(x, uu, 0, keepdims=False)
        params_v = stage_params(v, row["use_slot"], c["gbuf"])
        t = Tape(params_v, mode="bwd", no_defer=frozenset(no_defer))
        ctx, mem_tv = make_ctx(t, u)
        stage_id = v * Pe + p_rank
        xin = t.value(x)
        out, aux = M.apply_stage(t, ctx, seg, xin, stage_id)

        is_last = (p_rank == Pe - 1) & (v == V - 1)
        c = dict(c)
        if seed == "loss":
            def with_loss(_):
                h = out.val.reshape(mbs * seq, d)
                lab_u = tok_slice(labels, u).reshape(mbs * seq)
                loss, dh, iog = Vb.loss_and_dy(
                    cfg, rc, io_p, h, lab_u, denom, vloc, dsize)
                if cfg.mtp:
                    # DeepSeek multi-token-prediction aux head: one extra
                    # layer over [norm(h); emb(label_t)] predicting t+2.
                    lam = M.MTP_WEIGHT
                    lab2d = tok_slice(labels, u)
                    emb_next = Vb.embed_lookup(
                        io_p["embed.table"], lab2d, vloc, out.val.dtype)
                    mtp_ep = DATA if rt.ep else None
                    hm, mtp_vjp = jax.vjp(
                        lambda hh, ee, mp: M.mtp_hidden(
                            cfg, rc, {**io_p, **mp}, hh, ee,
                            ep_axis=mtp_ep),
                        out.val, emb_next,
                        {n: a for n, a in io_p.items()
                         if n.startswith(("mtp.proj", "mtp.layer"))})
                    lab_mtp = jnp.concatenate(
                        [lab2d[:, 1:], lab2d[:, -1:]], 1).reshape(-1)
                    mask = jnp.concatenate(
                        [jnp.ones((mbs, seq - 1), jnp.float32),
                         jnp.zeros((mbs, 1), jnp.float32)], 1).reshape(-1)
                    denom_mtp = float(denom / seq * (seq - 1))
                    l_m, dhm, iog_m = Vb.loss_and_dy(
                        cfg, rc, io_p, hm.reshape(mbs * seq, d), lab_mtp,
                        denom_mtp, vloc, dsize, norm_key="mtp.norm",
                        mask=mask)
                    dh_b, demb, dmtp = mtp_vjp(
                        (lam * dhm).reshape(mbs, seq, d).astype(hm.dtype))
                    dh2 = dh.reshape(mbs, seq, d) + dh_b.astype(dh.dtype)
                    loss = loss + lam * l_m
                    proto = _loss_iog_proto(cfg, io_p, vloc)
                    for nk, v2 in proto.items():
                        if nk not in iog:
                            iog[nk] = jnp.zeros(v2.shape, jnp.float32)
                    for nk, gv in iog_m.items():
                        iog[nk] = iog[nk] + lam * gv
                    for nk, gv in dmtp.items():
                        iog[nk] = iog[nk] + gv.astype(jnp.float32)
                    # emb_next gradient scatters into the embedding rows
                    iog["__emb_mtp_ids"] = lab2d
                    iog["__emb_mtp_dx"] = demb.astype(jnp.float32)
                    return dh2, loss, iog
                proto = _loss_iog_proto(cfg, io_p, vloc)
                for nk, v2 in proto.items():
                    if nk not in iog:
                        iog[nk] = jnp.zeros(v2.shape, jnp.float32)
                return dh.reshape(mbs, seq, d), loss, iog

            def no_loss(_):
                dy = jax.lax.dynamic_index_in_dim(c["bbuf"], uu, 0,
                                                  keepdims=False)
                iog = {n: jnp.zeros(v2.shape, jnp.float32) for n, v2 in
                       _loss_iog_proto(cfg, io_p, vloc).items()}
                if cfg.mtp:
                    iog["__emb_mtp_ids"] = jnp.zeros((mbs, seq), jnp.int32)
                    iog["__emb_mtp_dx"] = jnp.zeros((mbs, seq, d),
                                                    jnp.float32)
                return dy, jnp.zeros((), jnp.float32), iog

            dy, loss_d, iog_d = jax.lax.cond(is_last, with_loss, no_loss,
                                             None)
            c["io_g"] = dict(c["io_g"])
            c["metrics"] = dict(c["metrics"])
            if cfg.mtp:
                ids_m = iog_d.pop("__emb_mtp_ids")
                dx_m = iog_d.pop("__emb_mtp_dx")
                acc_m, dr_m = Vb.embed_grad(
                    ids_m, dx_m, vloc, cfg.vocab,
                    c["io_g"]["embed.table"])
                c["io_g"]["embed.table"] = acc_m
                c["metrics"]["emb_dropped"] = (
                    c["metrics"]["emb_dropped"] + dr_m)
            for n, g in iog_d.items():
                c["io_g"][n] = c["io_g"][n] + g
            c["metrics"] = dict(c["metrics"])
            c["metrics"]["loss_sum"] = c["metrics"]["loss_sum"] + loss_d
        elif seed == "buffer":
            dy_seed = jax.lax.dynamic_index_in_dim(seed_buf, u, 0,
                                                   keepdims=False)
            dy_wire = jax.lax.dynamic_index_in_dim(c["bbuf"], uu, 0,
                                                   keepdims=False)
            dy = jnp.where(is_last, dy_seed.astype(cdt), dy_wire)
        else:
            dy = jax.lax.dynamic_index_in_dim(c["bbuf"], uu, 0,
                                              keepdims=False)

        seeds = {out.idx: dy.astype(out.val.dtype)}
        if aux is not None:
            seeds[aux.idx] = jnp.asarray(aux_seed, jnp.float32)
        cots, igrads, stash = t.backward(seeds)
        dx = cots[xin.idx]
        c["send_b"] = dx.astype(cdt)

        # stash (x, dy) pairs for the deferred W task
        sx: dict[int, Any] = {}
        for (pname, spec_s, xs_i, dy_i), s in zip(tmpl.entries, stash):
            if xs_i not in sx:
                c["wx"][xs_i] = _dyn_set2(c["wx"][xs_i], v, uu,
                                          s.x.astype(c["wx"][xs_i].dtype))
                sx[xs_i] = True
            c["wdy"][dy_i] = _dyn_set2(c["wdy"][dy_i], v, uu,
                                       s.dy.astype(c["wdy"][dy_i].dtype))
        c["wx"] = list(c["wx"])
        c["wdy"] = list(c["wdy"])

        # immediate grads: EP experts -> sharded accum; small -> full accum
        for n, g in igrads.items():
            if n in ep_names:
                c["acc_shard"] = dict(c["acc_shard"])
                c["acc_shard"][n] = _dyn_add(c["acc_shard"][n], v,
                                             g.astype(jnp.float32))
            else:
                c["acc_full"] = dict(c["acc_full"])
                c["acc_full"][n] = _dyn_add(c["acc_full"][n], v,
                                            g.astype(jnp.float32))

        # embedding gradient at the first stage
        if int_tokens:
            is_first = (p_rank == 0) & (v == 0)

            def emb_g(args):
                acc, drop = args
                ids = tok_slice(tokens, u)
                acc2, dr = Vb.embed_grad(ids, dx.astype(jnp.float32), vloc,
                                         cfg.vocab, acc)
                return acc2, drop + dr

            c["io_g"] = dict(c["io_g"])
            c["metrics"] = dict(c["metrics"])
            acc2, drop2 = jax.lax.cond(
                is_first, emb_g, lambda a: a,
                (c["io_g"]["embed.table"], c["metrics"]["emb_dropped"]))
            c["io_g"]["embed.table"] = acc2
            c["metrics"]["emb_dropped"] = drop2

        if "dmembuf" in c and has_cross and mem_tv is not None:
            # cotangent of the cross-attention memory input
            dmem = cots.get(mem_tv.idx)
            if dmem is not None:
                c["dmembuf"] = _dyn_add(c["dmembuf"], u,
                                        dmem.astype(cdt))

        c["metrics"] = dict(c["metrics"])
        c["metrics"]["aux_sum"] = (
            c["metrics"]["aux_sum"] + aux.val.astype(jnp.float32))
        return c

    def w_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        c = dict(c)
        c["acc_full"] = dict(c["acc_full"])
        c["acc_shard"] = dict(c["acc_shard"])
        for (pname, spec_s, xs_i, dy_i) in tmpl.entries:
            xv = _dyn_get2(c["wx"][xs_i], v, uu)
            dyv = _dyn_get2(c["wdy"][dy_i], v, uu)
            g = jnp.einsum(spec_s, xv, dyv).astype(jnp.float32)
            c["acc_full"][pname] = _dyn_add(c["acc_full"][pname], v, g)
        return c

    def nop_branch(c, row):
        return c

    # ---- tick ------------------------------------------------------------ #
    def tick(c, row_all):
        row = {k: a[p_rank] for k, a in row_all.items()}
        # 1. store wires that arrived at the last boundary
        ruf, rub = row["recv_f_u"], row["recv_b_u"]
        c = dict(c)
        c["xbuf"] = jax.lax.cond(
            ruf >= 0,
            lambda b: jax.lax.dynamic_update_index_in_dim(
                b, c["recv_f"], jnp.clip(ruf, 0, Btot) % U, 0),
            lambda b: b, c["xbuf"])
        c["bbuf"] = jax.lax.cond(
            rub >= 0,
            lambda b: jax.lax.dynamic_update_index_in_dim(
                b, c["recv_b"], jnp.clip(rub, 0, Btot) % U, 0),
            lambda b: b, c["bbuf"])

        # 2. blockwise FSDP gather into the rotating slot
        gv, gs = row["gather_v"], row["gather_slot"]

        def do_gather(gb):
            gb = dict(gb)
            for n in gatherable:
                pv = jax.lax.dynamic_index_in_dim(
                    seg_p[n], jnp.clip(gv, 0, V - 1), 0, keepdims=False)
                ld = fsdp.local_dim(specs[n], dsize, rt.ep)
                full = jax.lax.all_gather(pv, DATA, axis=ld, tiled=True)
                gb[n] = jax.lax.dynamic_update_index_in_dim(
                    gb[n], full.astype(cdt), jnp.clip(gs, 0, 1), 0)
            return gb

        if gatherable:
            c["gbuf"] = jax.lax.cond(gv >= 0, do_gather, lambda gb: gb,
                                     c["gbuf"])

        # 3. dispatch F/B/W
        c = jax.lax.switch(
            row["kind"],
            [nop_branch, f_branch, b_branch, w_branch],
            c, row,
        )

        # 4. per-unit blockwise reduce-scatter of finished stage grads
        rv = row["reduce_v"]

        rs_dt = jnp.dtype(rc.grad_rs_dtype)

        def do_reduce(args):
            full, shard = args
            full, shard = dict(full), dict(shard)
            for n in full:
                g = jax.lax.dynamic_index_in_dim(full[n],
                                                 jnp.clip(rv, 0, V - 1),
                                                 0, keepdims=False)
                red = fsdp.reduce_scatter_grad(g.astype(rs_dt), specs[n],
                                               dsize, rt.ep)
                shard[n] = _dyn_add(shard[n], rv, red.astype(jnp.float32))
                full[n] = jax.lax.dynamic_update_index_in_dim(
                    full[n], jnp.zeros_like(g), jnp.clip(rv, 0, V - 1), 0)
            return full, shard

        c["acc_full"], c["acc_shard"] = jax.lax.cond(
            rv >= 0, do_reduce, lambda a: a,
            (c["acc_full"], c["acc_shard"]))

        # 5. boundary permutes (intra-group stage rings)
        c["recv_f"] = jax.lax.ppermute(c["send_f"], MODEL,
                                       fsdp.pipe_perm(Pe, G, +1))
        c["recv_b"] = jax.lax.ppermute(c["send_b"], MODEL,
                                       fsdp.pipe_perm(Pe, G, -1))
        return c, ()

    rows = pt.rows()
    carry, _ = jax.lax.scan(tick, carry, rows)

    return {
        "stage_grads": carry["acc_shard"],
        "io_grads": carry["io_g"],
        "metrics": carry["metrics"],
        "membuf": carry.get("membuf"),
        "dmembuf": carry.get("dmembuf"),
        "carry_out": carry,
    }


# ---- small helpers -------------------------------------------------------- #


def _dyn_set2(buf, i, j, val):
    """buf[i, j] = val with dynamic scalar indices."""
    row = jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
    row = jax.lax.dynamic_update_index_in_dim(row, val, j, 0)
    return jax.lax.dynamic_update_index_in_dim(buf, row, i, 0)


def _dyn_get2(buf, i, j):
    row = jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
    return jax.lax.dynamic_index_in_dim(row, j, 0, keepdims=False)


def _dyn_add(buf, i, val):
    old = jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)
    return jax.lax.dynamic_update_index_in_dim(buf, old + val, i, 0)


def _gathered_shape(spec, dsize, ep):
    return spec.shape


def _local_shape(spec, dsize, ep):
    ld = fsdp.local_dim(spec, dsize, ep)
    if ld is None:
        return spec.shape
    sh = list(spec.shape)
    sh[ld] = sh[ld] // dsize
    return tuple(sh)


def _loss_iog_proto(cfg, io_p, vloc):
    names = ["final_norm.scale"]
    if cfg.norm == "layernorm":
        names.append("final_norm.bias")
    names.append("embed.table" if cfg.tie_embeddings else "head.w")
    if cfg.mtp:
        names += [n for n in io_p
                  if n.startswith(("mtp.proj", "mtp.layer", "mtp.norm"))]
        if not cfg.tie_embeddings:
            names.append("embed.table")  # MTP ties emb grads in too
    return {n: io_p[n] for n in names}


def _rope_for(cfg, rc, seq):
    dims = {cfg.head_dim}
    if cfg.mla is not None:
        dims.add(cfg.mla.rope_dims)
    return {e: rope_tables(seq, e, cfg.rope_theta) for e in dims}


# --------------------------------------------------------------------------- #
# Serving: prefill (s = prompt len) and decode (s = 1) steps
# --------------------------------------------------------------------------- #


def _cache_specs_for(rt: Runtime, seg, b_loc: int, max_seq: int,
                     seq_shard: bool):
    """ShapeDtypeStructs per layer slot (batch = full local batch)."""
    cfg, rc = rt.cfg, rt.rc
    out = []
    for j, kind in enumerate(seg.kinds):
        cs = M.layer_cache_spec(cfg, rc, kind, b_loc, max_seq)
        if seq_shard:
            cs = {
                n: (jax.ShapeDtypeStruct(
                    (s.shape[0], s.shape[1] // rt.dsize) + s.shape[2:],
                    s.dtype)
                    if n in ("k", "v", "ckv") else s)
                for n, s in cs.items()
            }
        out.append(cs)
    return out


def serve_cache_pspecs(rt: Runtime, shape_cfg):
    """PartitionSpecs for the serving cache tree."""
    gb = shape_cfg.global_batch
    batch_shardable = gb % (rt.pods * rt.dsize) == 0 and gb >= (
        rt.pods * rt.dsize)
    seq_shard = not batch_shardable
    bspec = ((POD, DATA) if rt.multi_pod else DATA) if batch_shardable \
        else None
    tree = {}
    for seg in rt.geo.segments:
        if seg.name == "enc":
            continue
        slots = {}
        for j, kind in enumerate(seg.kinds):
            cs = M.layer_cache_spec(rt.cfg, rt.rc, kind, 1, 1)
            for n, s in cs.items():
                if seq_shard and n in ("k", "v", "ckv"):
                    dims = [MODEL, None, DATA] + [None] * (len(s.shape) - 2)
                else:
                    dims = [MODEL, bspec] + [None] * (len(s.shape) - 1)
                slots[f"L{j}.{n}"] = P(*dims)
        tree[seg.name] = slots
    if rt.cfg.encdec is not None:
        tree["enc_memory"] = P(bspec)
    return tree, seq_shard, bspec


def init_serve_caches(rt: Runtime, shape_cfg, max_seq=None, abstract=True):
    """Cache tree: {seg: {"L{j}.{name}": [M·V, b_loc, ...]}}."""
    from jax.sharding import NamedSharding

    cfg, rc = rt.cfg, rt.rc
    gb = shape_cfg.global_batch
    max_seq = max_seq or shape_cfg.seq_len
    pspecs, seq_shard, bspec = serve_cache_pspecs(rt, shape_cfg)
    tree = {}
    for seg in rt.geo.segments:
        if seg.name == "enc":
            continue
        V = seg.vpp
        slots = {}
        for j, kind in enumerate(seg.kinds):
            cs = M.layer_cache_spec(cfg, rc, kind, gb, max_seq)
            for n, s in cs.items():
                shape = (rt.G * rt.Pe * V,) + s.shape
                sh = NamedSharding(rt.mesh, pspecs[seg.name][f"L{j}.{n}"])
                slots[f"L{j}.{n}"] = (
                    jax.ShapeDtypeStruct(shape, s.dtype, sharding=sh)
                    if abstract else
                    jax.device_put(jnp.zeros(shape, s.dtype), sh))
        tree[seg.name] = slots
    if cfg.encdec is not None:
        shape = (gb, cfg.encdec.enc_ctx, cfg.d_model)
        sh = NamedSharding(rt.mesh, pspecs["enc_memory"])
        tree["enc_memory"] = (
            jax.ShapeDtypeStruct(shape, jnp.dtype(rc.compute_dtype),
                                 sharding=sh)
            if abstract else jax.device_put(
                jnp.zeros(shape, jnp.dtype(rc.compute_dtype)), sh))
    return tree


def make_serve_step(rt: Runtime, shape_cfg, *, prompt_len: int = 1,
                    max_seq: int | None = None):
    """Returns jit(step)(params, caches, batch) -> (tokens_out, caches).

    prompt_len == 1  → decode step (batch["pos"] gives the position).
    prompt_len > 1   → prefill: runs the prompt through the pipeline,
                       filling caches, and samples the first token.
    """
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    gb = shape_cfg.global_batch
    max_seq = max_seq or shape_cfg.seq_len
    pspecs, seq_shard, bspec = serve_cache_pspecs(rt, shape_cfg)
    shards = rt.pods * rt.dsize if rt.multi_pod else rt.dsize
    b_loc = gb // shards if not seq_shard else gb
    Btot = min(rc.microbatches, b_loc)
    mbs = b_loc // (rt.G * Btot) if b_loc >= rt.G * Btot else 1
    # degenerate tiny batches: one microbatch per group
    if b_loc < rt.G * Btot:
        Btot = max(b_loc // rt.G, 1)
        mbs = 1
    vloc = Vb.vocab_shard(cfg.vocab, rt.dsize)
    batch_spec = P(bspec) if bspec else P()

    mesh = rt.mesh

    def step(params, caches, batch):
        bsp = {k: (P() if k == "pos" else batch_spec) for k in batch}
        in_specs = (
            {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]},
            pspecs if cfg.encdec is not None else {
                k: v for k, v in pspecs.items() if k != "enc_memory"},
            bsp,
        )
        out_specs = (P(bspec) if bspec else P(),
                     in_specs[1])
        fn = fsdp.shard_map(
            partial(_serve_body, rt=rt, shape_cfg=shape_cfg, mbs=mbs,
                    Btot=Btot, vloc=vloc, prompt_len=prompt_len,
                    max_seq=max_seq, seq_shard=seq_shard),
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return fn(params, caches, batch)

    return jax.jit(step, donate_argnums=(1,))


def _serve_body(params, caches, batch, *, rt: Runtime, shape_cfg, mbs,
                Btot, vloc, prompt_len, max_seq, seq_shard):
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    io_p = params["io"]
    mr = jax.lax.axis_index(MODEL)
    Pe, G = rt.Pe, rt.G
    p_rank = mr % Pe
    g_rank = mr // Pe
    cdt = jnp.dtype(rc.compute_dtype)
    d = cfg.d_model
    s = prompt_len
    tokens = batch["tokens"]
    pos = batch.get("pos", jnp.zeros((), jnp.int32))

    seg = rt.segs["dec"] if cfg.encdec is not None else rt.segs["main"]
    seg_key = "dec" if cfg.encdec is not None else "main"
    seg_p = params["segments"][seg_key]
    specs = rt.stage_specs[seg_key]
    gatherable = rt.gatherable[seg_key]
    ep_names = set(rt.ep_names[seg_key])
    V = seg.vpp
    pt = rt.tables["serve_dec" if cfg.encdec is not None else "serve_main"]
    U = pt.U
    cache_tree = caches[seg_key]

    dims = {cfg.head_dim}
    if cfg.mla is not None:
        dims.add(cfg.mla.rope_dims)
    rope = {e: rope_tables(max_seq, e, cfg.rope_theta) for e in dims}
    ctx = blocks.LayerCtx(
        cfg=cfg, rc=rc, rope=rope, causal=True,
        ep_axis=DATA if rt.ep else None,
        kv_seq_shard=seq_shard, kv_shards=rt.dsize)
    if cfg.encdec is not None:
        ctx.enc_memory = None  # set per micro-batch below

    def tok_slice(arr, u):
        start = (g_rank * Btot + u) * mbs
        return jax.lax.dynamic_slice_in_dim(arr, start, mbs, axis=0)

    def stage_params(v, use_slot, gbuf):
        out = {}
        for n in specs:
            if n in gatherable:
                out[n] = jax.lax.dynamic_index_in_dim(
                    gbuf[n], jnp.clip(use_slot, 0, 1), 0, keepdims=False)
            else:
                out[n] = jax.lax.dynamic_index_in_dim(
                    seg_p[n], jnp.clip(v, 0, V - 1), 0, keepdims=False)
        return out

    def cache_get(tree, j, v, u):
        out = {}
        for n in M.layer_cache_spec(cfg, rc, seg.kinds[j], 1, 1):
            a = tree[f"L{j}.{n}"]
            av = jax.lax.dynamic_index_in_dim(a, v, 0, keepdims=False)
            if seq_shard:
                out[n] = av  # batch == full local batch (1)
            else:
                start = (g_rank * Btot + u) * mbs
                out[n] = jax.lax.dynamic_slice_in_dim(av, start, mbs, 0)
        return out

    def cache_put(tree, j, v, u, cd):
        for n, val in cd.items():
            a = tree[f"L{j}.{n}"]
            av = jax.lax.dynamic_index_in_dim(a, v, 0, keepdims=False)
            if seq_shard:
                av = val.astype(a.dtype)
            else:
                start = (g_rank * Btot + u) * mbs
                av = jax.lax.dynamic_update_slice_in_dim(
                    av, val.astype(a.dtype), start, 0)
            tree[f"L{j}.{n}"] = jax.lax.dynamic_update_index_in_dim(
                a, av, v, 0)
        return tree

    act = (mbs, s, d)
    carry = dict(
        send_f=jnp.zeros(act, cdt),
        recv_f=jnp.zeros(act, cdt),
        xbuf=jnp.zeros((U, *act), cdt),
        gbuf={n: jnp.zeros((2, *specs[n].shape), cdt) for n in gatherable},
        caches=dict(cache_tree),
        out_tok=jnp.zeros((G * Btot, mbs), jnp.int32),
    )

    def f_branch(c, row):
        u, v = row["mb"], row["v"]
        uu = u % U
        is_inject = (p_rank == 0) & (v == 0)

        def do_embed(_):
            ids = tok_slice(tokens, u) if not seq_shard else tokens
            if jnp.issubdtype(tokens.dtype, jnp.integer):
                return Vb.embed_lookup(io_p["embed.table"], ids, vloc, cdt)
            return ids.astype(cdt)

        x = jax.lax.cond(
            is_inject, do_embed,
            lambda _: jax.lax.dynamic_index_in_dim(c["xbuf"], uu, 0,
                                                   keepdims=False), None)
        params_v = stage_params(v, row["use_slot"], c["gbuf"])
        if cfg.encdec is not None:
            mem = caches["enc_memory"]
            ctx.enc_memory = (mem if seq_shard else tok_slice(mem, u))
        stage_id = v * Pe + p_rank
        ch = [cache_get(c["caches"], j, v, u)
              for j in range(len(seg.kinds))]
        y, ch2 = M.cached_stage(ctx, seg, params_v, x, ch, stage_id, pos)
        c = dict(c)
        c["caches"] = dict(c["caches"])
        for j in range(len(seg.kinds)):
            c["caches"] = cache_put(c["caches"], j, v, u, ch2[j])
        c["send_f"] = y

        is_drain = (p_rank == Pe - 1) & (v == V - 1)

        def sample(ot):
            h_last = y[:, -1]
            tok = Vb.greedy_sample(cfg, rc, io_p, h_last, vloc)
            return jax.lax.dynamic_update_index_in_dim(
                ot, tok, g_rank * Btot + (u % Btot), 0)

        c["out_tok"] = jax.lax.cond(is_drain, sample, lambda ot: ot,
                                    c["out_tok"])
        return c

    def nop_branch(c, row):
        return c

    def tick(c, row_all):
        row = {k: a[p_rank] for k, a in row_all.items()}
        ruf = row["recv_f_u"]
        c = dict(c)
        c["xbuf"] = jax.lax.cond(
            ruf >= 0,
            lambda b: jax.lax.dynamic_update_index_in_dim(
                b, c["recv_f"], jnp.clip(ruf, 0, pt.n_mb) % U, 0),
            lambda b: b, c["xbuf"])
        gv, gs = row["gather_v"], row["gather_slot"]

        def do_gather(gb):
            gb = dict(gb)
            for n in gatherable:
                pv = jax.lax.dynamic_index_in_dim(
                    seg_p[n], jnp.clip(gv, 0, V - 1), 0, keepdims=False)
                ld = fsdp.local_dim(specs[n], rt.dsize, rt.ep)
                full = jax.lax.all_gather(pv, DATA, axis=ld, tiled=True)
                gb[n] = jax.lax.dynamic_update_index_in_dim(
                    gb[n], full.astype(cdt), jnp.clip(gs, 0, 1), 0)
            return gb

        if gatherable:
            c["gbuf"] = jax.lax.cond(gv >= 0, do_gather, lambda g: g,
                                     c["gbuf"])
        c = jax.lax.switch(jnp.minimum(row["kind"], 1),
                           [nop_branch, f_branch], c, row)
        c["recv_f"] = jax.lax.ppermute(c["send_f"], MODEL,
                                       fsdp.pipe_perm(Pe, G, +1))
        return c, ()

    carry, _ = jax.lax.scan(tick, carry, pt.rows())

    out_tok = carry["out_tok"].reshape(-1)
    # drain ranks hold the sampled tokens; share them
    out_tok = jax.lax.psum(
        jnp.where((p_rank == Pe - 1), out_tok, jnp.zeros_like(out_tok)),
        MODEL)
    caches_out = dict(caches)
    caches_out[seg_key] = carry["caches"]
    return out_tok, caches_out
