"""Runtime + step builders for the table-driven SPMD pipeline (ZeroPP).

One jitted program per step: ``shard_map`` over the production mesh,
inside which each segment's ``SchedulePlan`` runs on the shared tick
engine (``core/executor.py``). The plan objects (``core/plan.py``) bundle
the TickTable the simulator analyzes with the PackedTable the executor
scans, so what we analyze is exactly what runs — structurally.

This module owns the *static* side only:

  * ``Runtime`` — builds the per-segment SchedulePlans (train, serve,
    encoder/decoder), parameter specs + shardings, and the W-stash
    templates the executor's B/W handlers replay;
  * ``make_train_step`` / ``make_serve_step`` — wrap the executor bodies
    in ``shard_map`` + ``jit`` with the right in/out specs;
  * serve-cache construction (``init_serve_caches``).

All rank-varying branching is driven by *static* numpy tables indexed by
the dynamic model-axis rank — see DESIGN.md §3 for why this is the
TPU-native realization of the paper's per-rank GPU kernel queues.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import fsdp
from repro.core.executor import (
    _rope_for,
    serve_body as _serve_body,
    train_body as _train_body,
)
from repro.core.generators import SchedParams, generate
from repro.core.plan import (
    UNIT_GATED_SCHEDULES,
    PackedTable,
    SchedulePlan,
    pack_table,
    strip_fwd as _strip_fwd,
)
from repro.core.tape import Tape
from repro.models import blocks, model as M
from repro.models.common import ModelConfig, RunConfig

DATA, MODEL, POD = "data", "model", "pod"


# --------------------------------------------------------------------------- #
# W-stash template (traced shapes, deduped GEMM operands)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class StashTemplate:
    entries: list          # [(pname, dw_spec, x_slot, dy_slot)]
    x_shapes: list         # slot -> (shape, dtype)
    dy_shapes: list
    igrad_names: list      # param names receiving immediate grads in B


def stash_template(cfg, rc, seg, specs, mb_shape, no_defer,
                   cross_ctx: int | None = None) -> StashTemplate:
    """Abstractly trace stage_bwd once to learn the stash structure.

    The template is traced with ep_axis=None (collective-free); EP expert
    GEMMs are in ``no_defer`` so their shapes never enter the stash, and
    the remaining entries are EP-independent.
    """
    cdt = jnp.dtype(rc.compute_dtype)
    rope = _rope_for(cfg, rc, mb_shape[1])
    params = {
        n: jax.ShapeDtypeStruct(sp.shape, jnp.dtype(rc.param_dtype))
        for n, sp in specs.items()
    }
    x = jax.ShapeDtypeStruct((*mb_shape, cfg.d_model), cdt)
    mem = (jax.ShapeDtypeStruct((mb_shape[0], cross_ctx, cfg.d_model), cdt)
           if cross_ctx else None)
    template: dict = {}

    def run(params, x, dy, mem):
        t = Tape(params, mode="bwd", no_defer=frozenset(no_defer))
        xin = t.value(x)
        ctx = blocks.LayerCtx(
            cfg=cfg, rc=rc, rope=rope, causal=seg.causal, ep_axis=None,
            enc_memory=t.value(mem) if mem is not None else None)
        out, aux = M.apply_stage(t, ctx, seg, xin, jnp.int32(0))
        cots, igrads, stash = t.backward(
            {out.idx: dy, aux.idx: jnp.zeros((), jnp.float32)}
        )
        xs, dys, entries = [], [], []
        xid = {}
        for s in stash:
            key = id(s.x)
            if key not in xid:
                xid[key] = len(xs)
                xs.append((s.x.shape, s.x.dtype))
            dyid = len(dys)
            dys.append((s.dy.shape, s.dy.dtype))
            entries.append((s.pname, s.dw_spec, xid[key], dyid))
        template["entries"] = entries
        template["x_shapes"] = xs
        template["dy_shapes"] = dys
        template["igrads"] = sorted(igrads)
        return cots[xin.idx]

    if mem is None:
        jax.eval_shape(lambda p, xx, dd: run(p, xx, dd, None), params, x, x)
    else:
        jax.eval_shape(run, params, x, x, mem)
    return StashTemplate(
        template["entries"], template["x_shapes"], template["dy_shapes"],
        template["igrads"],
    )


# --------------------------------------------------------------------------- #
# Runtime
# --------------------------------------------------------------------------- #


class Runtime:
    """Builds and runs the SPMD train/prefill/decode programs for one
    (ModelConfig, RunConfig) on a ("data","model"[, "pod"]) mesh.

    ``plan`` (optional) injects a pre-selected :class:`SchedulePlan` —
    e.g. the winner of ``schedule="auto"`` — for the trainable segment
    ("main", or "dec" for enc-dec families) instead of regenerating the
    table from ``rc.schedule``.
    """

    def __init__(self, cfg: ModelConfig, rc: RunConfig, mesh,
                 multi_pod: bool = False,
                 plan: SchedulePlan | None = None):
        self.cfg, self.rc, self.mesh = cfg, rc, mesh
        self.geo = M.build_geometry(cfg, rc)
        self.multi_pod = multi_pod
        ax = dict(mesh.shape)
        self.dsize = ax[DATA]
        self.pods = ax.get(POD, 1)
        assert ax[MODEL] == self.geo.model_ranks, (
            f"mesh model axis {ax[MODEL]} != groups*pp "
            f"{self.geo.model_ranks}"
        )
        self.Pe = rc.pp
        self.G = rc.groups
        self.ep = rc.moe_mode == "ep" and cfg.moe is not None
        if cfg.encdec is not None:
            assert self.G == 1, "enc-dec uses a single pipeline group"

        # --- schedule plans per segment ------------------------------------ #
        # Scheduling units only gate ZeroPP-family schedules; other methods
        # keep the whole batch live, so their buffers must be n_mb deep.
        unit = (rc.unit_size if rc.schedule in UNIT_GATED_SCHEDULES
                else rc.microbatches)
        sp = SchedParams(P=rc.pp, V=rc.vpp, n_mb=rc.microbatches,
                         unit=unit)
        pf = rc.gather_prefetch

        def build(name, sp_):
            return SchedulePlan.build(name, sp_, prefetch=pf)
        self.plans: dict[str, SchedulePlan] = {}
        segs = {s.name: s for s in self.geo.segments}
        self.segs = segs
        if cfg.encdec is not None:
            # encoder passes are not unit-gated (fwd-only, then stripped
            # bwd) so their buffers must hold every micro-batch
            enc_sp = dataclasses.replace(sp, V=segs["enc"].vpp,
                                         unit=rc.microbatches)
            dec_sp = dataclasses.replace(sp, V=segs["dec"].vpp)
            self.plans["enc_fwd"] = build("fwd_only", enc_sp)
            self.plans["dec"] = (self._adopt(plan, dec_sp)
                                 if plan is not None else
                                 build(rc.schedule, dec_sp))
            enc_full = generate(rc.schedule, enc_sp)
            self.plans["enc_bwd"] = SchedulePlan.from_table(
                f"strip_fwd[{rc.schedule}]", enc_sp,
                _strip_fwd(enc_full), prefetch=pf)
        else:
            self.plans["main"] = (self._adopt(plan, sp)
                                  if plan is not None else
                                  build(rc.schedule, sp))
        # serving plans (forward-only pipeline; not unit-gated, so the
        # buffers hold every micro-batch)
        sp_full = dataclasses.replace(sp, unit=rc.microbatches)
        if cfg.encdec is not None:
            self.plans["serve_main"] = self.plans["enc_fwd"]
            self.plans["serve_dec"] = build(
                "fwd_only", dataclasses.replace(dec_sp,
                                                unit=rc.microbatches))
        else:
            self.plans["serve_main"] = build("fwd_only", sp_full)

        # --- parameter specs & shardings ---------------------------------- #
        self.stage_specs = {
            s.name: M.stage_specs(cfg, segs[s.name]) for s in
            self.geo.segments
        }
        self.io_specs = M.io_specs(cfg)
        self.pspecs = {
            "io": {n: fsdp.io_pspec(sp_, self.dsize)
                   for n, sp_ in self.io_specs.items()},
            "segments": {
                sname: {n: fsdp.stage_pspec(sp_, self.dsize, self.ep)
                        for n, sp_ in sps.items()}
                for sname, sps in self.stage_specs.items()
            },
        }
        self.gatherable = {
            sname: sorted(
                n for n, sp_ in sps.items()
                if fsdp.local_dim(sp_, self.dsize, self.ep) is not None
                and not (sp_.ep and self.ep)
            )
            for sname, sps in self.stage_specs.items()
        }
        if rc.serve_resident:
            # weight-resident serving (beyond-paper, §Perf): non-EP params
            # live fully gathered on each model rank — zero per-step FSDP
            # gathers, at V×stage_params HBM cost.
            for sname, sps in self.stage_specs.items():
                for n in self.gatherable[sname]:
                    self.pspecs["segments"][sname][n] = P(
                        MODEL, *([None] * len(sps[n].shape)))
                self.gatherable[sname] = []
        self.ep_names = {
            sname: sorted(n for n, sp_ in sps.items() if sp_.ep and self.ep)
            for sname, sps in self.stage_specs.items()
        }
        # --- flat-segment coalescing (one collective per tick) ------------- #
        if rc.coalesce not in ("flat", "none"):
            raise ValueError(
                f"unknown coalesce mode {rc.coalesce!r}; pick 'flat' (one "
                "all-gather / reduce-scatter per stage segment per tick) "
                "or 'none' (per-tensor collectives)")
        self.flat_layouts: dict[str, object] = {
            sname: (fsdp.build_flat_layout(
                        self.stage_specs[sname], self.gatherable[sname],
                        self.dsize, self.ep)
                    if rc.coalesce == "flat" else None)
            for sname in self.stage_specs
        }
        # EP expert tensors get their own flat segment: the cross-group /
        # cross-pod gradient reduction then runs as ONE slab collective
        # per stage instead of one per expert tensor (per-tensor fallback
        # when the expert dim does not divide the data axis).
        self.ep_flat_layouts: dict[str, object] = {
            sname: (fsdp.build_flat_layout(
                        self.stage_specs[sname], self.ep_names[sname],
                        self.dsize, self.ep, ep_segment=True)
                    if rc.coalesce == "flat" and self.ep_names[sname]
                    else None)
            for sname in self.stage_specs
        }
        # io params: only the vocab-dim of embed/head shards (per the
        # vocab-shard decision); everything else is replicated — io params
        # are consumed outside the gather machinery.
        from repro.core import vocab as Vb
        vloc = Vb.vocab_shard(cfg.vocab, self.dsize)
        for n, sp_ in self.io_specs.items():
            if n in ("embed.table", "head.w") and vloc is not None:
                dims = [None] * len(sp_.shape)
                dims[sp_.fsdp_dim] = DATA
                self.pspecs["io"][n] = P(*dims)
            elif sp_.ep and self.ep:
                # MTP expert bank: EP-sharded like the stage experts
                self.pspecs["io"][n] = P(DATA,
                                         *([None] * (len(sp_.shape) - 1)))
            else:
                self.pspecs["io"][n] = P(*([None] * len(sp_.shape)))
        self._tmpl_cache: dict = {}

    def _adopt(self, plan: SchedulePlan, sp: SchedParams) -> SchedulePlan:
        """Validate an injected plan against this runtime's geometry and
        re-pack it for this runtime's gather-prefetch depth."""
        pp = plan.params
        if (pp.P, pp.V, pp.n_mb) != (sp.P, sp.V, sp.n_mb):
            raise ValueError(
                f"injected plan {plan.name!r} was built for "
                f"(P={pp.P}, V={pp.V}, B={pp.n_mb}) but this runtime "
                f"needs (P={sp.P}, V={sp.V}, B={sp.n_mb})")
        return plan.with_prefetch(self.rc.gather_prefetch)

    @property
    def tables(self) -> dict[str, PackedTable]:
        """Device-ready packed tables per segment (plan view)."""
        return {k: p.packed for k, p in self.plans.items()}

    def _stash_tmpl(self, seg, mb_shape, no_defer, cross_ctx=None):
        key = (seg.name, tuple(mb_shape), cross_ctx,
               tuple(sorted(no_defer)))
        if key not in self._tmpl_cache:
            self._tmpl_cache[key] = stash_template(
                self.cfg, self.rc, seg, self.stage_specs[seg.name],
                mb_shape, no_defer, cross_ctx=cross_ctx)
        return self._tmpl_cache[key]

    # ------------------------------------------------------------------ #
    def init_params(self, key=None):
        """Host init with the pipeline's duplicated-stage layout, then
        device_put with the runtime shardings."""
        from jax.sharding import NamedSharding

        key = key if key is not None else jax.random.PRNGKey(0)
        base = M.init_all_params(self.cfg, self.rc, key)
        segs = {}
        for seg in self.geo.segments:
            st = base["segments"][seg.name]
            V, Pe, G = seg.vpp, self.Pe, self.G
            order = []
            for mr in range(G * Pe):
                p = mr % Pe
                for v in range(V):
                    order.append(M.storage_index(p, v, V))
            segs[seg.name] = {
                n: jnp.stack([a[i] for i in order]) for n, a in st.items()
            }
        params = {"io": base["io"], "segments": segs}
        out = jax.tree.map(
            lambda a, spec: jax.device_put(
                a, NamedSharding(self.mesh, spec)),
            params,
            {"io": self.pspecs["io"], "segments": self.pspecs["segments"]},
        )
        return out

    def param_shapes(self):
        """ShapeDtypeStructs (for dry-run lowering without allocation)."""
        from jax.sharding import NamedSharding

        dt = jnp.dtype(self.rc.param_dtype)
        segs = {}
        for seg in self.geo.segments:
            V = seg.vpp
            segs[seg.name] = {
                n: jax.ShapeDtypeStruct(
                    (self.G * self.Pe * V, *sp_.shape), dt,
                    sharding=NamedSharding(
                        self.mesh,
                        self.pspecs["segments"][seg.name][n]),
                )
                for n, sp_ in self.stage_specs[seg.name].items()
            }
        io = {
            n: jax.ShapeDtypeStruct(
                sp_.shape, dt,
                sharding=NamedSharding(self.mesh, self.pspecs["io"][n]))
            for n, sp_ in self.io_specs.items()
        }
        return {"io": io, "segments": segs}

    # ------------------------------------------------------------------ #
    def batch_pspec(self):
        return P((POD, DATA)) if self.multi_pod else P(DATA)

    def input_specs(self, shape_cfg, max_seq=None):
        """ShapeDtypeStructs for the step inputs (see launch/dryrun.py)."""
        from jax.sharding import NamedSharding

        cfg, rc = self.cfg, self.rc
        gb, s = shape_cfg.global_batch, shape_cfg.seq_len
        shards = self.pods * self.dsize
        batch_shardable = gb % shards == 0 and gb >= shards
        sh = NamedSharding(
            self.mesh,
            self.batch_pspec() if batch_shardable else P())
        rep = NamedSharding(self.mesh, P())
        if shape_cfg.kind == "train":
            toks = (
                jax.ShapeDtypeStruct((gb, s, cfg.d_model), jnp.bfloat16,
                                     sharding=sh)
                if cfg.frontend == "vision"
                else jax.ShapeDtypeStruct((gb, s), jnp.int32, sharding=sh)
            )
            batch = {"tokens": toks,
                     "labels": jax.ShapeDtypeStruct((gb, s), jnp.int32,
                                                    sharding=sh)}
            if cfg.encdec is not None:
                batch["enc_tokens"] = jax.ShapeDtypeStruct(
                    (gb, cfg.encdec.enc_ctx, cfg.d_model), jnp.bfloat16,
                    sharding=sh)
                batch["tokens"] = jax.ShapeDtypeStruct(
                    (gb, s), jnp.int32, sharding=sh)
            return batch
        if shape_cfg.kind == "prefill":
            toks = (
                jax.ShapeDtypeStruct((gb, s, cfg.d_model), jnp.bfloat16,
                                     sharding=sh)
                if cfg.frontend == "vision"
                else jax.ShapeDtypeStruct((gb, s), jnp.int32, sharding=sh)
            )
            batch = {"tokens": toks}
            if cfg.encdec is not None:
                batch["enc_tokens"] = jax.ShapeDtypeStruct(
                    (gb, cfg.encdec.enc_ctx, cfg.d_model), jnp.bfloat16,
                    sharding=sh)
                batch["tokens"] = jax.ShapeDtypeStruct(
                    (gb, min(s, 448)), jnp.int32, sharding=sh)
            return batch
        # decode: one new token against a cache of length max_seq
        batch = {"tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32,
                                                sharding=sh),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)}
        if cfg.encdec is not None:
            batch["enc_tokens"] = jax.ShapeDtypeStruct(
                (gb, cfg.encdec.enc_ctx, cfg.d_model), jnp.bfloat16,
                sharding=sh)
        return batch


# --------------------------------------------------------------------------- #
# Train step
# --------------------------------------------------------------------------- #


def make_train_step(rt: Runtime, shape_cfg):
    """Returns jit(step)(params, batch) -> (grads, metrics)."""
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    seq = shape_cfg.seq_len
    gb = shape_cfg.global_batch
    n_local = gb // (rt.pods * rt.dsize)
    Btot = rc.microbatches
    mbs = max(n_local // (rt.G * Btot), 1)
    assert mbs * rt.G * Btot == n_local, (
        f"global_batch {gb} must split into pods*data*groups*microbatches"
    )
    vloc = Vb.vocab_shard(cfg.vocab, rt.dsize)
    denom = float(gb * seq)  # global token count
    # Reference semantics: loss += w * sum over (stages, micro-batches of
    # per-token-mean aux); each micro-batch contributes aux/B_global.
    aux_seed = (
        cfg.moe.router_aux_weight / (Btot * rt.G * rt.dsize * rt.pods)
        if cfg.moe else 0.0
    )

    mesh = rt.mesh
    batch_spec = rt.batch_pspec()

    def step(params, batch):
        in_specs = (
            {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]},
            jax.tree.map(lambda _: batch_spec, batch),
        )
        grad_specs = {"io": rt.pspecs["io"],
                      "segments": rt.pspecs["segments"]}
        out_specs = (grad_specs, P())
        fn = fsdp.shard_map(
            partial(_train_body, rt=rt, shape_cfg=shape_cfg, mbs=mbs,
                    vloc=vloc, denom=denom, aux_seed=aux_seed),
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return fn(params, batch)

    return jax.jit(step)


# --------------------------------------------------------------------------- #
# Serving: prefill (s = prompt len) and decode (s = 1) steps
# --------------------------------------------------------------------------- #


def _cache_specs_for(rt: Runtime, seg, b_loc: int, max_seq: int,
                     seq_shard: bool):
    """ShapeDtypeStructs per layer slot (batch = full local batch)."""
    cfg, rc = rt.cfg, rt.rc
    out = []
    for j, kind in enumerate(seg.kinds):
        cs = M.layer_cache_spec(cfg, rc, kind, b_loc, max_seq)
        if seq_shard:
            cs = {
                n: (jax.ShapeDtypeStruct(
                    (s.shape[0], s.shape[1] // rt.dsize) + s.shape[2:],
                    s.dtype)
                    if n in ("k", "v", "ckv") else s)
                for n, s in cs.items()
            }
        out.append(cs)
    return out


def serve_cache_pspecs(rt: Runtime, shape_cfg, paged: bool = False):
    """PartitionSpecs for the serving cache tree.

    Paged caches reuse the batch-shardable layout verbatim: a
    ``[M·V, n_pages, page_size, ...]`` leaf has the same rank as the
    contiguous ``[M·V, gb, max_seq, ...]`` one and shards its page axis
    exactly like the batch axis (each pods×data shard owns a block of
    pages, gathered locally through per-row page tables).
    """
    gb = shape_cfg.global_batch
    batch_shardable = gb % (rt.pods * rt.dsize) == 0 and gb >= (
        rt.pods * rt.dsize)
    seq_shard = not batch_shardable
    if paged and seq_shard:
        raise ValueError(
            "paged KV caches need the batch-shardable cache layout; "
            f"global_batch={gb} fell back to sequence sharding — use a "
            "slot count divisible by the pods×data axes")
    bspec = ((POD, DATA) if rt.multi_pod else DATA) if batch_shardable \
        else None
    tree = {}
    for seg in rt.geo.segments:
        if seg.name == "enc":
            continue
        slots = {}
        for j, kind in enumerate(seg.kinds):
            cs = M.layer_cache_spec(rt.cfg, rt.rc, kind, 1, 1)
            for n, s in cs.items():
                if seq_shard and n in ("k", "v", "ckv"):
                    dims = [MODEL, None, DATA] + [None] * (len(s.shape) - 2)
                else:
                    dims = [MODEL, bspec] + [None] * (len(s.shape) - 1)
                slots[f"L{j}.{n}"] = P(*dims)
                if paged and rt.rc.kv_cache_dtype == "int8" and n in (
                        "k", "v", "ckv"):
                    # per-page(×head) scales: [M·V, n_pages, ...] — the
                    # page axis shards exactly like its pool's.
                    slots[f"L{j}.{n}_scale"] = P(
                        *([MODEL, bspec] + [None] * (len(s.shape) - 3)))
        tree[seg.name] = slots
    if rt.cfg.encdec is not None:
        tree["enc_memory"] = P(bspec)
    return tree, seq_shard, bspec


def init_serve_caches(rt: Runtime, shape_cfg, max_seq=None, abstract=True,
                      *, page_size: int = 0, n_pages: int = 0):
    """Cache tree: {seg: {"L{j}.{name}": [M·V, b_loc, ...]}}.

    With ``page_size > 0`` the attention leaves come out paged —
    ``[M·V, n_pages, page_size, ...]`` — and rows address them through
    the per-request page tables the serve step is handed each tick.
    """
    from jax.sharding import NamedSharding

    cfg, rc = rt.cfg, rt.rc
    gb = shape_cfg.global_batch
    max_seq = max_seq or shape_cfg.seq_len
    pspecs, seq_shard, bspec = serve_cache_pspecs(
        rt, shape_cfg, paged=page_size > 0)
    tree = {}
    for seg in rt.geo.segments:
        if seg.name == "enc":
            continue
        V = seg.vpp
        slots = {}
        for j, kind in enumerate(seg.kinds):
            cs = dict(M.layer_cache_spec(cfg, rc, kind, gb, max_seq))
            for n in list(cs):
                s = cs[n]
                if page_size and n in ("k", "v", "ckv"):
                    cs[n] = jax.ShapeDtypeStruct(
                        (n_pages, page_size) + s.shape[2:], s.dtype)
                    if rc.kv_cache_dtype == "int8":
                        # scales live beside the pool and move with its
                        # pages through reset_pages/copy_pages (any leaf
                        # with the page axis at dim 1 is handled there)
                        cs[n + "_scale"] = jax.ShapeDtypeStruct(
                            (n_pages,) + s.shape[2:-1], jnp.float32)
                elif page_size:
                    raise ValueError(
                        f"paged serving covers attention caches only; "
                        f"layer kind {kind!r} keeps per-slot state "
                        f"({n!r}) that has no page layout — set "
                        "prefix_sharing='off' / page_size=0 for this "
                        "architecture")
            for n, s in cs.items():
                shape = (rt.G * rt.Pe * V,) + s.shape
                sh = NamedSharding(rt.mesh, pspecs[seg.name][f"L{j}.{n}"])
                slots[f"L{j}.{n}"] = (
                    jax.ShapeDtypeStruct(shape, s.dtype, sharding=sh)
                    if abstract else
                    jax.device_put(jnp.zeros(shape, s.dtype), sh))
        tree[seg.name] = slots
    if cfg.encdec is not None:
        shape = (gb, cfg.encdec.enc_ctx, cfg.d_model)
        sh = NamedSharding(rt.mesh, pspecs["enc_memory"])
        tree["enc_memory"] = (
            jax.ShapeDtypeStruct(shape, jnp.dtype(rc.compute_dtype),
                                 sharding=sh)
            if abstract else jax.device_put(
                jnp.zeros(shape, jnp.dtype(rc.compute_dtype)), sh))
    return tree


def reset_slot_caches(caches, slot_mask):
    """Zero the cache rows of the slots flagged in ``slot_mask`` [gb].

    Used by the continuous-batching engine when a slot is reclaimed for a
    new request: attention caches are overwritten position-by-position
    anyway, but recurrent state (mamba/xlstm) and any stale bytes beyond
    the new request's horizon must not leak between requests. Cache leaves
    are [M·V, gb, ...] (batch on axis 1); ``enc_memory`` is [gb, ...].
    """
    out = {}
    for key, sub in caches.items():
        if key == "enc_memory":
            m = slot_mask.reshape((-1,) + (1,) * (sub.ndim - 1))
            out[key] = jnp.where(m, jnp.zeros((), sub.dtype), sub)
        else:
            out[key] = {
                n: jnp.where(
                    slot_mask.reshape((1, -1) + (1,) * (a.ndim - 2)),
                    jnp.zeros((), a.dtype), a)
                for n, a in sub.items()
            }
    return out


def reset_pages(caches, page_mask):
    """Zero the pages flagged in ``page_mask`` [n_pages] of every paged
    leaf ([M·V, n_pages, page_size, ...]; page axis 1).

    The paged analogue of ``reset_slot_caches``: freshly allocated pages
    must read as zeros (the contiguous path zeroes whole slot rows on
    admission, and greedy parity leans on identical gathered bytes) —
    shared prefix pages keep their contents, so the mask carries only a
    request's *fresh* pages.
    """
    return {
        key: {
            n: jnp.where(
                page_mask.reshape((1, -1) + (1,) * (a.ndim - 2)),
                jnp.zeros((), a.dtype), a)
            for n, a in sub.items()
        }
        for key, sub in caches.items()
    }


def copy_pages(caches, src, dst):
    """Copy page ``src[i]`` -> ``dst[i]`` (int32 [w] *global* page ids)
    in every paged leaf.

    Cross-partition prefix reuse: the radix found the pages in another
    pods×data shard's block, so the bytes move on device (XLA lowers the
    axis-1 gather/scatter across the page sharding) instead of being
    recomputed by a prefill. ``dst`` entries must be distinct except as
    exact repeats of the same (src, dst) pair — fixed-width callers pad
    by repeating their first real pair, so duplicate writes carry
    identical values.
    """
    return {
        key: {n: a.at[:, dst].set(a[:, src]) for n, a in sub.items()}
        for key, sub in caches.items()
    }


def serve_tiling(rt: Runtime, gb: int, seq_shard: bool):
    """(b_loc, Btot, mbs): how the serve step tiles a local batch into
    (groups × micro-batches × mbs). Shared by ``make_serve_step`` and
    the slot-count validation — rows beyond G·Btot·mbs would silently
    never be computed, so slotted callers must check exact coverage."""
    shards = rt.pods * rt.dsize if rt.multi_pod else rt.dsize
    b_loc = gb // shards if not seq_shard else gb
    Btot = min(rt.rc.microbatches, b_loc)
    mbs = b_loc // (rt.G * Btot) if b_loc >= rt.G * Btot else 1
    # degenerate tiny batches: one microbatch per group
    if b_loc < rt.G * Btot:
        Btot = max(b_loc // rt.G, 1)
        mbs = 1
    return b_loc, Btot, mbs


def make_serve_step(rt: Runtime, shape_cfg, *, prompt_len: int = 1,
                    max_seq: int | None = None, page_size: int = 0,
                    want_logits: bool = False):
    """Returns jit(step)(params, caches, batch) -> (tokens_out, caches)
    — or (tokens_out, logits, caches) with ``want_logits``.

    prompt_len == 1  → decode step (batch["pos"] gives the position).
    prompt_len > 1   → prefill: runs the prompt through the pipeline,
                       filling caches, and samples the first token.
    page_size > 0    → paged caches: batch carries "page_tables"
                       (int32 [gb, max_seq // page_size] shard-local
                       page ids) and the attention leaves are page
                       pools instead of per-slot rows.
    want_logits      → additionally return the drain rank's full
                       next-token logits [gb, vocab] (float32) so the
                       engine can sample host-side; the in-graph greedy
                       token stream is unchanged.
    """
    cfg, rc = rt.cfg, rt.rc
    from repro.core import vocab as Vb

    gb = shape_cfg.global_batch
    max_seq = max_seq or shape_cfg.seq_len
    pspecs, seq_shard, bspec = serve_cache_pspecs(
        rt, shape_cfg, paged=page_size > 0)
    b_loc, Btot, mbs = serve_tiling(rt, gb, seq_shard)
    vloc = Vb.vocab_shard(cfg.vocab, rt.dsize)
    batch_spec = P(bspec) if bspec else P()
    if want_logits and seq_shard:
        raise NotImplementedError(
            "logits return needs the batch-shardable serve layout")
    if want_logits and rt.multi_pod:
        raise NotImplementedError(
            "logits return is not wired for multi-pod meshes")

    mesh = rt.mesh

    def step(params, caches, batch):
        # scalar pos is replicated; a per-slot [gb] pos vector (and the
        # slot_mask / page_tables that ride with it) shards with the
        # batch rows.
        bsp = {k: (P() if k == "pos" and not getattr(batch[k], "ndim", 0)
                   else batch_spec) for k in batch}
        in_specs = (
            {"io": rt.pspecs["io"], "segments": rt.pspecs["segments"]},
            pspecs if cfg.encdec is not None else {
                k: v for k, v in pspecs.items() if k != "enc_memory"},
            bsp,
        )
        tok_spec = P(bspec) if bspec else P()
        seg_m = rt.segs["dec" if cfg.encdec is not None else "main"]
        track_moe = (rc.moe_stats and cfg.moe is not None
                     and any(k.endswith(":moe") for k in seg_m.kinds))
        moe_spec = ({"load": P(), "dropped": P()},) if track_moe else ()
        if want_logits:
            # vocab-sharded head: every data rank computes its vocab
            # slice for ALL rows -> [gb, vloc] local, vocab axis sharded.
            # replicated head: each rank holds its own rows' full vocab.
            logit_spec = P(None, DATA) if vloc else P(bspec)
            out_specs = (tok_spec, logit_spec, in_specs[1]) + moe_spec
        else:
            out_specs = (tok_spec, in_specs[1]) + moe_spec
        fn = fsdp.shard_map(
            partial(_serve_body, rt=rt, shape_cfg=shape_cfg, mbs=mbs,
                    Btot=Btot, vloc=vloc, prompt_len=prompt_len,
                    max_seq=max_seq, seq_shard=seq_shard,
                    page_size=page_size, want_logits=want_logits),
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return fn(params, caches, batch)

    return jax.jit(step, donate_argnums=(1,))
