"""SchedulePlan: the one schedule object that is analyzed, cached, and run.

The paper's whole §3–§4 argument is that a well-scheduled PP×FSDP tick
table beats TP — which only holds if the table we *analyze* (discrete-event
simulator, core/simulator.py) is the table we *execute* (SPMD tick engine,
core/executor.py). ``SchedulePlan`` makes that structural: it bundles

  * the ``TickTable`` (task order + FSDP gather/reduce events),
  * the ``PackedTable`` (device-ready per-tick arrays the executor scans),
  * per-preset ``PlanAnalysis`` (simulated makespan, bubble fraction,
    peak memory, collective counts).

``select_plan`` runs the §4 selection: every registered schedule (plus
both §4 autogen heuristics — full-depth ``autogen`` and the unit-gated
``autogen_gated``) is built for the same (P, V, B, U), simulated under
a hardware cost preset (A800 = paper testbed, TPU v5e = our target), and
the minimum-makespan plan wins — optionally under a ``mem_budget`` peak-
memory cap, which is what makes the gated/full choice a real
memory/makespan trade-off. Selections are cached per
(arch × shape × mesh) key so repeated sessions pay once.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.generators import SchedParams, generate
from repro.core.schedules import B as KB
from repro.core.schedules import F as KF
from repro.core.schedules import W as KW
from repro.core.schedules import TickTable, to_arrays
from repro.core.simulator import (
    A800,
    TPU_V5E,
    CostModel,
    cost_model_for,
    simulate,
)

# --------------------------------------------------------------------------- #
# Static table preprocessing (device-ready arrays for the executor)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class PackedTable:
    """Device-ready per-tick arrays [T, Pe] + static metadata."""

    T: int
    Pe: int            # ranks per pipeline group
    V: int
    U: int             # unit size (xbuf/stash depth)
    n_mb: int
    prefetch: int      # gather issue distance the arrays were packed for
    kind: np.ndarray   # [T, Pe] {0 nop, 1 F, 2 B, 3 W}
    mb: np.ndarray     # [T, Pe] microbatch index
    v: np.ndarray      # [T, Pe] local stage slot
    gather_v: np.ndarray    # [T, Pe] slot to all-gather (-1 none)
    gather_slot: np.ndarray  # [T, Pe] double-buffer slot for that gather
    use_slot: np.ndarray    # [T, Pe] which buffer slot holds params of v
    reduce_v: np.ndarray    # [T, Pe] slot to reduce-scatter (-1 none)
    recv_f_u: np.ndarray    # [T, Pe] mb arriving on fwd wire this tick (-1)
    recv_b_u: np.ndarray    # [T, Pe] mb arriving on bwd wire this tick (-1)

    def rows(self):
        """As jnp arrays stacked for lax.scan xs."""
        import jax.numpy as jnp

        fields = ["kind", "mb", "v", "gather_v", "gather_slot", "use_slot",
                  "reduce_v", "recv_f_u", "recv_b_u"]
        return {f: jnp.asarray(getattr(self, f)) for f in fields}

    @property
    def has_w(self) -> bool:
        """False for fused-backward baselines (dW computed inside B)."""
        return bool((self.kind == KW).any())


def pack_table(tt: TickTable, prefetch: int = 0) -> PackedTable:
    # unit-gated stash legality: packed arrays drive U-deep executor
    # buffers, so a W-bearing table claiming unit < n_mb must fit the
    # stash-reuse window (B→W distance ≤ unit depth) before it can scan.
    if 0 < tt.unit < tt.n_mb:
        from repro.core.schedules import unit_stash_violations

        bad = unit_stash_violations(tt)
        if bad:
            raise ValueError(
                f"cannot pack table at unit depth {tt.unit}: "
                f"{len(bad)} stash violation(s), first: {bad[0]}")
    arr = to_arrays(tt)
    T, Pe = arr["kind"].shape
    V = tt.V
    kind, mb, v = arr["kind"], arr["mb"], arr["v"]
    gather_v = arr["gather"]
    reduce_v = arr["reduce"]

    if prefetch > 0:
        # §3.3 prefetch: issue each stage-block gather up to `prefetch`
        # ticks before its first use so the async all-gather overlaps the
        # previous block's compute. Safe moves only: the target tick must
        # be gather-free, and no task between target and origin may still
        # be *reading* the destination buffer slot (the slot parity
        # alternates per gather, so skipping past reads of the other slot
        # is fine — we recompute slot assignments afterwards).
        for p_ in range(Pe):
            order = [t for t in range(T) if gather_v[t, p_] >= 0]
            for gi, t in enumerate(order):
                slot_parity = gi % 2
                tgt = t
                for back in range(1, prefetch + 1):
                    cand = t - back
                    if cand < 0 or gather_v[cand, p_] >= 0:
                        break
                    # reads of the same slot between cand and t?
                    conflict = False
                    for tt_ in range(cand, t):
                        if kind[tt_, p_] in (KF, KB, KW):
                            # which slot does that task read? parity of
                            # the most recent gather before tt_
                            prev = [g for g in order[:gi] if g <= tt_]
                            if prev and (len(prev) - 1) % 2 == slot_parity:
                                conflict = True
                                break
                    if conflict:
                        break
                    tgt = cand
                if tgt != t:
                    gather_v[tgt, p_] = gather_v[t, p_]
                    gather_v[t, p_] = -1

    # Rotating two-slot gather buffer assignment.
    gather_slot = -np.ones((T, Pe), np.int32)
    use_slot = np.zeros((T, Pe), np.int32)
    for p in range(Pe):
        nxt = 0
        holds = {}  # v -> slot
        for t in range(T):
            if gather_v[t, p] >= 0:
                gather_slot[t, p] = nxt
                holds[gather_v[t, p]] = nxt
                nxt = 1 - nxt
            if kind[t, p] in (KF, KB, KW):
                use_slot[t, p] = holds.get(v[t, p], 0)

    # Receive maps: what lands on each wire at the END of tick t-1 (i.e. is
    # available at tick t). Sender of fwd wire for rank p is p-1 (ring).
    recv_f_u = -np.ones((T, Pe), np.int32)
    recv_b_u = -np.ones((T, Pe), np.int32)
    S = Pe * V
    for t in range(1, T):
        for p in range(Pe):
            prev = (p - 1) % Pe
            if kind[t - 1, prev] == KF:
                stage = v[t - 1, prev] * Pe + prev
                if stage < S - 1:
                    recv_f_u[t, p] = mb[t - 1, prev]
            nxt_r = (p + 1) % Pe
            if kind[t - 1, nxt_r] == KB:
                stage = v[t - 1, nxt_r] * Pe + nxt_r
                if stage > 0:
                    recv_b_u[t, p] = mb[t - 1, nxt_r]
    return PackedTable(
        T=T, Pe=Pe, V=V, U=tt.unit, n_mb=tt.n_mb, prefetch=prefetch,
        kind=kind, mb=mb, v=v,
        gather_v=gather_v, gather_slot=gather_slot, use_slot=use_slot,
        reduce_v=reduce_v, recv_f_u=recv_f_u, recv_b_u=recv_b_u,
    )


def strip_fwd(tt: TickTable) -> TickTable:
    """B/W-only table (encoder backward segment): F ran in a prior scan."""
    from repro.core.autogen import orders_from_table, retick

    orders = orders_from_table(tt)
    orders = [[t for t in o if t.kind != KF] for o in orders]
    return retick(orders, tt.P, tt.V, tt.n_mb, tt.unit, assume_f=True)


# --------------------------------------------------------------------------- #
# SchedulePlan
# --------------------------------------------------------------------------- #


# Schedules whose tables gate micro-batches into §3.1 scheduling units —
# their buffers only need unit depth. Everything else keeps the whole
# batch live (unit = n_mb); notably the full-depth §4 "autogen" schedule
# postpones W tasks across unit boundaries, which is incompatible with
# unit-depth stash reuse. Its "autogen_gated" sibling constrains the §4
# insertion loop to each unit's live window (B→W distance ≤ U, enforced
# by the stash-legality gate in retick/pack_table), so it keeps the
# requested unit depth and the O(U) activation bound. Custom unit-gated
# schedules register here.
UNIT_GATED_SCHEDULES = {"zeropp", "autogen_gated"}


@dataclasses.dataclass(frozen=True)
class PlanAnalysis:
    """Discrete-event-simulated properties of one plan under one preset."""

    preset: str
    makespan: float
    bubble_frac: float
    peak_mem: float
    n_gather: int
    n_reduce: int
    gathers_per_rank: float
    comm_frac: float       # mean per-rank collective time / makespan
    prefetch: int = 0      # gather issue distance the analysis assumed
    coll_alpha: float = 0.0      # per-collective latency of the cost model
    n_coll_gather: int = 1       # collectives per gather tick (1 = flat)
    n_coll_reduce: int = 1
    stash_depth: int = 0         # unit depth the executor buffers need
    #                              (U for unit-gated tables, n_mb else)
    rs_exposed: float = 0.0      # reduce-scatter time on the critical path
    rs_overlap_saved: float = 0.0  # worst rank's reduce time hidden under
    #                                the next unit's B/W compute
    measured_us: float | None = None  # profiled real-step wall time
    #                                   (auto_profiled refinement; None =
    #                                   simulated-only candidate)
    # EP MoE all-to-all terms (0 unless the cost model carried an EP
    # dispatch/combine workload — defaults keep pre-a2a cache records
    # loadable through plan_cache.selection_from_record's field filter)
    t_a2a: float = 0.0           # one a2a event's α–β time (s)
    n_a2a_f: int = 0             # a2a events inside one F tick
    n_a2a_b: int = 0             # a2a events inside one B tick
    a2a_bytes: float = 0.0       # wire bytes of one a2a event
    a2a_total: float = 0.0       # simulated a2a time summed over the step

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SchedulePlan:
    """A runnable + analyzable schedule: TickTable, PackedTable, analyses.

    The packed arrays the executor scans are derived from exactly the
    table the simulator sees; nothing else flows between them.
    """

    name: str
    params: SchedParams
    table: TickTable
    packed: PackedTable
    prefetch: int = 0
    analyses: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def build(cls, name: str, sp: SchedParams, *,
              prefetch: int = 0) -> "SchedulePlan":
        """Generate a registered schedule's table and pack it."""
        return cls.from_table(name, sp, generate(name, sp),
                              prefetch=prefetch)

    @classmethod
    def from_table(cls, name: str, sp: SchedParams, tt: TickTable, *,
                   prefetch: int = 0) -> "SchedulePlan":
        return cls(name=name, params=sp, table=tt,
                   packed=pack_table(tt, prefetch=prefetch),
                   prefetch=prefetch)

    def with_prefetch(self, prefetch: int) -> "SchedulePlan":
        """Same table, re-packed for a different gather-prefetch depth.

        Analyses are NOT carried over: the simulation models prefetch
        (prefetch=0 gathers block at use; ≥1 overlap), so cached numbers
        would be stale for the new depth.
        """
        if prefetch == self.prefetch:
            return self
        return SchedulePlan(
            name=self.name, params=self.params, table=self.table,
            packed=pack_table(self.table, prefetch=prefetch),
            prefetch=prefetch)

    @property
    def has_w(self) -> bool:
        return self.packed.has_w

    def validate(self) -> None:
        self.table.validate()

    def analyze(self, cm: CostModel, preset: str = "abstract"
                ) -> PlanAnalysis:
        """Simulate this plan under ``cm``; cached per preset name.

        Prefetch-aware: a plan packed with ``prefetch == 0`` gathers at
        use time, so its collectives are simulated blocking
        (``overlap_comm=False``); ``prefetch >= 1`` keeps the async
        overlapped issue the executor actually performs.

        The cache key includes the cost model's collective profile —
        one preset name now spans several models (per-tick collective
        counts differ between coalesce modes), so an A/B of the same
        plan under both must not alias.
        """
        key = (preset, cm.n_coll_gather, cm.n_coll_reduce, cm.coll_alpha,
               cm.n_a2a_f, cm.n_a2a_b, cm.t_a2a)
        if key not in self.analyses:
            cm_eff = (cm if self.prefetch > 0 else
                      dataclasses.replace(cm, overlap_comm=False))
            res = simulate(self.table, cm_eff)
            # reduce-scatter overlap accounting: the worst rank's total
            # reduce time is what fully-serial charging would add to it;
            # whatever the simulator did not expose on the critical path
            # overlapped the next unit's B/W compute.
            if self.table.reduce is not None and cm_eff.t_reduce > 0:
                rs_total = float(
                    (self.table.reduce >= 0).sum(axis=0).max()
                    * cm_eff.t_reduce)
            else:
                rs_total = 0.0
            self.analyses[key] = PlanAnalysis(
                preset=preset,
                makespan=res.makespan,
                bubble_frac=res.bubble_frac,
                peak_mem=res.peak_mem,
                n_gather=res.n_gather,
                n_reduce=res.n_reduce,
                gathers_per_rank=res.n_gather / self.table.P,
                comm_frac=float(res.comm_busy.mean()
                                / max(res.makespan, 1e-12)),
                prefetch=self.prefetch,
                coll_alpha=cm.coll_alpha,
                n_coll_gather=cm.n_coll_gather,
                n_coll_reduce=cm.n_coll_reduce,
                stash_depth=self.table.unit,
                rs_exposed=res.rs_exposed,
                rs_overlap_saved=max(0.0, rs_total - res.rs_exposed),
                t_a2a=cm_eff.t_a2a,
                n_a2a_f=cm_eff.n_a2a_f,
                n_a2a_b=cm_eff.n_a2a_b,
                a2a_bytes=cm_eff.a2a_bytes,
                a2a_total=cm_eff.t_a2a * sum(
                    cm_eff.n_a2a_f if task.kind == KF
                    else cm_eff.n_a2a_b if task.kind == KB else 0
                    for _, _, task in self.table.tasks()),
            )
        return self.analyses[key]


# --------------------------------------------------------------------------- #
# Hardware cost presets
# --------------------------------------------------------------------------- #

PRESETS = {"a800": A800, "tpu_v5e": TPU_V5E}

#: Calibrated α–β collective constants per preset: (alpha, beta) with
#: t_collective(n, bytes) = n·α + bytes·β.  α is the per-collective launch
#: latency (the term a per-tensor gather tick pays #tensors times and the
#: flat-segment tick pays once): published small-message latencies for the
#: preset's DP interconnect (NCCL intra-node all-gather ≈ 8 µs on A800
#: NVSwitch; ~1.2 µs per ICI hop on v5e).  β is the inverse *effective*
#: collective bandwidth on the FSDP (data) axis: the simulator Hardware
#: preset's intra-node/link peak at ~90% efficiency.
#: ``benchmarks/comm_bench.py --calibrate`` re-derives both from those
#: sources and fails on >=25% drift (so a Hardware-preset bandwidth edit
#: cannot silently desync these literals), and reports the per-cell
#: α-term share over the ``benchmarks/roofline.py`` byte-accounting grid
#: (the terms the compiled-HLO structural scrape validates).
COLLECTIVE_ALPHA_BETA: dict[str, tuple[float, float]] = {
    "a800": (8.0e-06, 1.0 / 180e9),     # NVSwitch intra-node DP axis
    "tpu_v5e": (1.2e-06, 1.0 / 45e9),   # 50 GB/s ICI at ~90% efficiency
    # EP MoE all-to-all (dispatch/combine) over the same DP interconnect:
    # α doubles the point-to-point launch latency (an a2a is a full
    # pairwise exchange, not one fan-in/fan-out collective), β is the
    # same inverse effective bandwidth. ``comm_bench --calibrate``
    # re-derives these via A2A_LATENCY_FACTOR and drift-gates them too.
    "a800:a2a": (1.6e-05, 1.0 / 180e9),
    "tpu_v5e:a2a": (2.4e-06, 1.0 / 45e9),
}


def fused_cost_model(cm: CostModel) -> CostModel:
    """Fold W into B for schedules without split backward (baselines)."""
    return dataclasses.replace(cm, t_b=cm.t_b + cm.t_w, t_w=0.0,
                               m_wstash=0.0)


def preset_cost_model(preset: str, cfg=None, *, P: int, V: int,
                      seq: int = 1024, mbs: int = 1, dp: int = 1,
                      mfu: float = 0.5, n_coll_gather: int = 1,
                      n_coll_reduce: int | None = None,
                      n_a2a_f: int = 0, n_a2a_b: int = 0,
                      a2a_bytes: float = 0.0,
                      extra_stage_param_bytes: float = 0.0) -> CostModel:
    """CostModel for a hardware preset and a (model × shape) workload.

    With a ModelConfig, per-task durations come from transformer napkin
    math (GEMM flops at an assumed MFU, stage-boundary activation bytes,
    blockwise FSDP gather bytes) via ``cost_model_for``; without one, the
    abstract unit-cost model (F=1, B=2, W=1) is returned so device-free
    callers still get a simulatable preset.

    Collective ticks are costed α–β style with the calibrated
    ``COLLECTIVE_ALPHA_BETA`` constants: ``n_coll_gather`` /
    ``n_coll_reduce`` are the collectives issued per gather/reduce tick —
    1 under the flat-segment layout (``coalesce="flat"``), the gatherable
    tensor count under per-tensor collectives (``coalesce="none"``).

    ``n_a2a_f``/``n_a2a_b`` are the EP MoE all-to-all events riding
    inside one stage's F/B tick (dispatch + combine per MoE layer; B
    pays them twice under remat) and ``a2a_bytes`` one event's wire
    bytes — costed with the preset's ``"<preset>:a2a"`` α–β constants.
    """
    if preset not in PRESETS:
        raise ValueError(
            f"unknown cost preset {preset!r}; known: "
            f"{', '.join(sorted(PRESETS))}")
    if cfg is None:
        return CostModel()
    hw = PRESETS[preset]
    alpha, beta = COLLECTIVE_ALPHA_BETA[preset]
    d = cfg.d_model
    L = max(cfg.n_layers, 1)
    layers_per_stage = max(L / (P * V), 1e-9)
    layer_flops = 2 * (12 * d * d) * seq * mbs + 2 * seq * seq * d * mbs
    act_bytes = seq * mbs * d * 2
    # extra_stage_param_bytes: workload the napkin 12d² misses — e.g.
    # gathered-MoE expert tensors riding the FSDP collectives (EP keeps
    # them sharded and pays a2a instead).
    stage_param_bytes = (12 * d * d * layers_per_stage * 2
                         + max(extra_stage_param_bytes, 0.0))
    a2a_alpha, a2a_beta = COLLECTIVE_ALPHA_BETA.get(
        f"{preset}:a2a", (2 * alpha, beta))
    return cost_model_for(
        hw, layer_flops_f=layer_flops, layers_per_stage=layers_per_stage,
        act_bytes=act_bytes, stage_param_bytes=stage_param_bytes,
        dp=max(dp, 1), mfu=mfu, alpha=alpha, beta=beta,
        n_coll_gather=max(n_coll_gather, 0),
        n_coll_reduce=max(n_coll_reduce if n_coll_reduce is not None
                          else n_coll_gather, 0),
        a2a_alpha=a2a_alpha, a2a_beta=a2a_beta, a2a_bytes=a2a_bytes,
        n_a2a_f=max(n_a2a_f, 0), n_a2a_b=max(n_a2a_b, 0))


# --------------------------------------------------------------------------- #
# §4 plan selection (schedule="auto")
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class PlanSelection:
    """Outcome of one auto-selection: winner + every candidate's analysis."""

    selected: SchedulePlan
    analysis: PlanAnalysis
    preset: str
    candidates: dict    # name -> PlanAnalysis | "failed: ..." str
    key: tuple | None = None
    mem_budget: float | None = None   # peak-mem cap the ranking honoured
    # how this selection came to be: "search" (simulated screen only),
    # "search+measured" (auto_profiled coarse→fine refinement), or
    # "cache:disk" (rebuilt from the persisted plan cache — zero
    # simulate, zero measure). In-memory hits return the original
    # object, so its provenance stays whatever produced it; per-lookup
    # hit/miss accounting lives in plan_cache_info().
    provenance: str = "search"
    measured: dict | None = None      # name -> measured us/call for the
    #                                   refined survivors (profiled mode)
    profile: dict | None = None       # measurement metadata: top_k,
    #                                   budget_s, wall seconds spent,
    #                                   simulated-best name + its us

    def ranking(self) -> list[tuple[str, float]]:
        ok = [(n, a.makespan) for n, a in self.candidates.items()
              if isinstance(a, PlanAnalysis)]
        return sorted(ok, key=lambda x: x[1])

    def measured_ranking(self) -> list[tuple[str, float]]:
        """(name, measured us/call) for the profiled survivors, best first."""
        return sorted((self.measured or {}).items(), key=lambda x: x[1])


_PLAN_CACHE: dict[tuple, PlanSelection] = {}
# process-wide selection accounting: per-key hit counts + the work
# counters the persisted-cache tests assert on ("zero simulate calls on
# a warm hit" is checked against simulate_calls/measure_calls deltas).
_CACHE_STATS: dict = {
    "hits": {},        # key -> in-memory hit count
    "disk_hits": {},   # key -> persisted-cache hit count
    "misses": 0,       # full searches run
    "simulate_calls": 0,   # candidate discrete-event simulations
    "measure_calls": 0,    # real-step measurements (auto_profiled)
}


def clear_plan_cache(persisted: bool = False) -> None:
    """Reset the in-memory selection cache and its counters;
    ``persisted=True`` also deletes the on-disk cache file."""
    _PLAN_CACHE.clear()
    _CACHE_STATS["hits"] = {}
    _CACHE_STATS["disk_hits"] = {}
    _CACHE_STATS["misses"] = 0
    _CACHE_STATS["simulate_calls"] = 0
    _CACHE_STATS["measure_calls"] = 0
    if persisted:
        from repro.core import plan_cache

        plan_cache.clear_disk()


def plan_cache_info() -> dict:
    """Selection-cache state: entries, per-key hit counts, and the
    simulate/measure work counters (reset by ``clear_plan_cache``)."""
    from repro.core import plan_cache

    return {
        "entries": len(_PLAN_CACHE),
        # keys mix None/float/str at the same position (mem_budget,
        # profile_top_k), so sort on repr — tuple order would TypeError
        "keys": sorted(_PLAN_CACHE, key=repr),
        "hits": dict(_CACHE_STATS["hits"]),
        "disk_hits": dict(_CACHE_STATS["disk_hits"]),
        "misses": _CACHE_STATS["misses"],
        "simulate_calls": _CACHE_STATS["simulate_calls"],
        "measure_calls": _CACHE_STATS["measure_calls"],
        "persisted": plan_cache.info(),
    }


def candidate_schedules() -> list[str]:
    """Registered schedules eligible for auto-selection (trainable ones)."""
    from repro.api.registry import SCHEDULE_REGISTRY

    return [n for n in SCHEDULE_REGISTRY.names() if n != "fwd_only"]


#: Ordered component names of the Session-level selection cache key —
#: folded into the persisted-cache fingerprint, so *adding a selection
#: knob* in a later version invalidates every stored entry (the key
#: string alone would just silently never match, which is the same
#: outcome for lookups but not for schema-drift debugging).
SELECT_KEY_SCHEMA = (
    "arch", "pp", "vpp", "groups", "microbatches", "unit",
    "gather_prefetch", "seq", "mbs", "dp", "pods", "preset", "coalesce",
    "grad_compress", "moe_mode", "mem_budget", "select_mode",
    "profile_top_k",
)


def select_plan(P: int, V: int, n_mb: int, unit: int, cm: CostModel, *,
                preset: str = "abstract", prefetch: int = 0,
                candidates: list[str] | None = None,
                cache_key: tuple | None = None,
                mem_budget: float | None = None,
                measure_fn=None, top_k: int = 3,
                profile_budget_s: float | None = None,
                persist: bool = False) -> PlanSelection:
    """Build + simulate every candidate schedule; the minimum simulated
    makespan wins (ties keep the earlier candidate). Unit-gated schedules
    (UNIT_GATED_SCHEDULES: zeropp and the gated §4 heuristic
    ``autogen_gated``) use the requested unit; all others — including
    full-depth autogen, whose postponed W passes cross unit boundaries
    and therefore need full-depth stash buffers — keep the whole batch
    live (unit = n_mb). Fused-backward candidates are costed with W
    folded into B so total work is identical across candidates.

    ``mem_budget`` (same units as the cost model's memory terms — bytes
    under the hardware presets) makes the ranking a real memory/makespan
    trade-off: candidates whose simulated peak memory exceeds the budget
    are ranked only among themselves if *nothing* fits (min peak memory
    wins then), exactly how the paper picks "the best U that still fits
    in HBM" — this is what lets the unit-gated autogen beat its
    full-depth sibling when the whole batch does not fit.

    ``measure_fn`` turns the search coarse→fine (``auto_profiled``): the
    simulated screen above still runs every candidate, but then the
    ``top_k`` budget-respecting survivors (best simulated makespan
    first) are *measured* — ``measure_fn(plan) -> us/call`` compiles and
    times real steps — and the minimum measured time wins. The
    simulated-best survivor is always measured first, so the winner's
    measured time is ≤ the measured time of the plan the purely
    simulated ranking would have picked, by construction.
    ``profile_budget_s`` caps the wall-clock spent measuring (at least
    one candidate is always measured); a candidate whose measurement
    raises is excluded from the measured ranking but keeps its simulated
    numbers.

    ``persist=True`` (with a ``cache_key``) reads/writes the on-disk
    plan cache (``core/plan_cache.py``): a fingerprint-valid disk hit
    rebuilds the whole selection — winner table included — with zero
    simulate and zero measure calls."""
    from repro.core import plan_cache

    if cache_key is not None and cache_key in _PLAN_CACHE:
        _CACHE_STATS["hits"][cache_key] = \
            _CACHE_STATS["hits"].get(cache_key, 0) + 1
        return _PLAN_CACHE[cache_key]

    fp = plan_cache.fingerprint(cm, SELECT_KEY_SCHEMA)
    if persist and cache_key is not None:
        rec = plan_cache.load_entry(cache_key, fp)
        if rec is not None:
            try:
                sel = plan_cache.selection_from_record(rec, cache_key)
            except Exception:  # noqa: BLE001 — corrupt record: clean search
                sel = None
            if sel is not None:
                _CACHE_STATS["disk_hits"][cache_key] = \
                    _CACHE_STATS["disk_hits"].get(cache_key, 0) + 1
                # seed the in-memory cache: repeated sessions in this
                # process must share the identical PlanSelection object
                _PLAN_CACHE[cache_key] = sel
                return sel

    _CACHE_STATS["misses"] += 1
    names = list(candidates) if candidates is not None \
        else candidate_schedules()
    cm_fused = fused_cost_model(cm)
    results: dict = {}
    plans: dict[str, SchedulePlan] = {}
    fits: tuple[SchedulePlan, PlanAnalysis] | None = None   # within budget
    slim: tuple[SchedulePlan, PlanAnalysis] | None = None   # min peak_mem
    for name in names:
        sp = SchedParams(
            P=P, V=V, n_mb=n_mb,
            unit=(unit if name in UNIT_GATED_SCHEDULES else n_mb),
            split_bw=True)
        try:
            if name in ("autogen", "autogen_gated"):
                # §4 heuristic profiles with the *preset* cost model, not
                # the abstract default the registry builder would use.
                from repro.core.autogen import autogen

                tt = autogen(sp, cm,
                             unit_gated=(name == "autogen_gated")).table
                plan = SchedulePlan.from_table(name, sp, tt,
                                               prefetch=prefetch)
            else:
                plan = SchedulePlan.build(name, sp, prefetch=prefetch)
        except Exception as e:  # noqa: BLE001 — skip broken candidates
            results[name] = f"failed: {e}"
            continue
        ana = plan.analyze(cm if plan.has_w else cm_fused, preset=preset)
        _CACHE_STATS["simulate_calls"] += 1
        results[name] = ana
        plans[name] = plan
        if mem_budget is None or ana.peak_mem <= mem_budget:
            if fits is None or ana.makespan < fits[1].makespan - 1e-12:
                fits = (plan, ana)
        if slim is None or ana.peak_mem < slim[1].peak_mem - 1e-12:
            slim = (plan, ana)
    best = fits or slim
    if best is None:
        raise RuntimeError(
            f"no schedule candidate could be built for P={P} V={V} "
            f"n_mb={n_mb} unit={unit}: {results}")

    provenance, measured, profile = "search", None, None
    if measure_fn is not None:
        best, measured, profile = _measured_refine(
            plans, results, fits, best, measure_fn,
            mem_budget=mem_budget, top_k=top_k,
            profile_budget_s=profile_budget_s)
        provenance = "search+measured"
    sel = PlanSelection(selected=best[0], analysis=best[1], preset=preset,
                        candidates=results, key=cache_key,
                        mem_budget=mem_budget, provenance=provenance,
                        measured=measured, profile=profile)
    if cache_key is not None:
        _PLAN_CACHE[cache_key] = sel
        if persist:
            try:
                plan_cache.store_entry(
                    cache_key, fp, plan_cache.selection_record(sel))
            except Exception:  # noqa: BLE001 — persistence is best-effort
                pass
    return sel


def _measured_refine(plans: dict, results: dict, fits, best, measure_fn, *,
                     mem_budget, top_k: int,
                     profile_budget_s: float | None):
    """Fine pass of the coarse→fine search: measure the top-K simulated
    survivors with ``measure_fn`` and re-rank by real us/call.

    Survivor order is the coarse ranking the purely simulated selection
    uses — budget-fitting candidates by makespan when anything fits, else
    everything by peak memory — so the first measurement is always the
    plan ``schedule="auto"`` would have picked. Returns
    ``((plan, analysis), measured, profile)`` with measured numbers
    attached to the surviving candidates' analyses.
    """
    import time as _time

    ok = [(n, a) for n, a in results.items()
          if isinstance(a, PlanAnalysis)]
    if fits is not None:
        pool = [(n, a) for n, a in ok
                if mem_budget is None or a.peak_mem <= mem_budget]
        pool.sort(key=lambda x: x[1].makespan)
    else:
        pool = sorted(ok, key=lambda x: x[1].peak_mem)
    survivors = pool[:max(top_k, 1)]
    sim_best_name = survivors[0][0] if survivors else None

    measured: dict[str, float] = {}
    t_start = _time.perf_counter()
    for i, (name, _) in enumerate(survivors):
        spent = _time.perf_counter() - t_start
        if i > 0 and profile_budget_s is not None \
                and spent >= profile_budget_s:
            break   # budget exhausted; the sim-best was measured first
        try:
            us = float(measure_fn(plans[name]))
        except Exception as e:  # noqa: BLE001 — a plan that won't run
            results[name] = f"measure failed: {e}"   # can't win on merit
            continue
        finally:
            _CACHE_STATS["measure_calls"] += 1
        measured[name] = us
        results[name] = dataclasses.replace(results[name], measured_us=us)
    profile = {
        "top_k": top_k,
        "budget_s": profile_budget_s,
        "measure_s": _time.perf_counter() - t_start,
        "survivors": [n for n, _ in survivors],
        "simulated_best": sim_best_name,
        "simulated_best_us": measured.get(sim_best_name),
    }
    if measured:
        win = min(measured, key=measured.get)
        best = (plans[win], results[win])
    # else: every measurement failed — keep the simulated winner
    return best, measured, profile
