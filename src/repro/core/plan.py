"""SchedulePlan: the one schedule object that is analyzed, cached, and run.

The paper's whole §3–§4 argument is that a well-scheduled PP×FSDP tick
table beats TP — which only holds if the table we *analyze* (discrete-event
simulator, core/simulator.py) is the table we *execute* (SPMD tick engine,
core/executor.py). ``SchedulePlan`` makes that structural: it bundles

  * the ``TickTable`` (task order + FSDP gather/reduce events),
  * the ``PackedTable`` (device-ready per-tick arrays the executor scans),
  * per-preset ``PlanAnalysis`` (simulated makespan, bubble fraction,
    peak memory, collective counts).

``select_plan`` runs the §4 selection: every registered schedule (plus
both §4 autogen heuristics — full-depth ``autogen`` and the unit-gated
``autogen_gated``) is built for the same (P, V, B, U), simulated under
a hardware cost preset (A800 = paper testbed, TPU v5e = our target), and
the minimum-makespan plan wins — optionally under a ``mem_budget`` peak-
memory cap, which is what makes the gated/full choice a real
memory/makespan trade-off. Selections are cached per
(arch × shape × mesh) key so repeated sessions pay once.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.generators import SchedParams, generate
from repro.core.schedules import B as KB
from repro.core.schedules import F as KF
from repro.core.schedules import W as KW
from repro.core.schedules import TickTable, to_arrays
from repro.core.simulator import (
    A800,
    TPU_V5E,
    CostModel,
    cost_model_for,
    simulate,
)

# --------------------------------------------------------------------------- #
# Static table preprocessing (device-ready arrays for the executor)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class PackedTable:
    """Device-ready per-tick arrays [T, Pe] + static metadata."""

    T: int
    Pe: int            # ranks per pipeline group
    V: int
    U: int             # unit size (xbuf/stash depth)
    n_mb: int
    prefetch: int      # gather issue distance the arrays were packed for
    kind: np.ndarray   # [T, Pe] {0 nop, 1 F, 2 B, 3 W}
    mb: np.ndarray     # [T, Pe] microbatch index
    v: np.ndarray      # [T, Pe] local stage slot
    gather_v: np.ndarray    # [T, Pe] slot to all-gather (-1 none)
    gather_slot: np.ndarray  # [T, Pe] double-buffer slot for that gather
    use_slot: np.ndarray    # [T, Pe] which buffer slot holds params of v
    reduce_v: np.ndarray    # [T, Pe] slot to reduce-scatter (-1 none)
    recv_f_u: np.ndarray    # [T, Pe] mb arriving on fwd wire this tick (-1)
    recv_b_u: np.ndarray    # [T, Pe] mb arriving on bwd wire this tick (-1)

    def rows(self):
        """As jnp arrays stacked for lax.scan xs."""
        import jax.numpy as jnp

        fields = ["kind", "mb", "v", "gather_v", "gather_slot", "use_slot",
                  "reduce_v", "recv_f_u", "recv_b_u"]
        return {f: jnp.asarray(getattr(self, f)) for f in fields}

    @property
    def has_w(self) -> bool:
        """False for fused-backward baselines (dW computed inside B)."""
        return bool((self.kind == KW).any())


def pack_table(tt: TickTable, prefetch: int = 0) -> PackedTable:
    # unit-gated stash legality: packed arrays drive U-deep executor
    # buffers, so a W-bearing table claiming unit < n_mb must fit the
    # stash-reuse window (B→W distance ≤ unit depth) before it can scan.
    if 0 < tt.unit < tt.n_mb:
        from repro.core.schedules import unit_stash_violations

        bad = unit_stash_violations(tt)
        if bad:
            raise ValueError(
                f"cannot pack table at unit depth {tt.unit}: "
                f"{len(bad)} stash violation(s), first: {bad[0]}")
    arr = to_arrays(tt)
    T, Pe = arr["kind"].shape
    V = tt.V
    kind, mb, v = arr["kind"], arr["mb"], arr["v"]
    gather_v = arr["gather"]
    reduce_v = arr["reduce"]

    if prefetch > 0:
        # §3.3 prefetch: issue each stage-block gather up to `prefetch`
        # ticks before its first use so the async all-gather overlaps the
        # previous block's compute. Safe moves only: the target tick must
        # be gather-free, and no task between target and origin may still
        # be *reading* the destination buffer slot (the slot parity
        # alternates per gather, so skipping past reads of the other slot
        # is fine — we recompute slot assignments afterwards).
        for p_ in range(Pe):
            order = [t for t in range(T) if gather_v[t, p_] >= 0]
            for gi, t in enumerate(order):
                slot_parity = gi % 2
                tgt = t
                for back in range(1, prefetch + 1):
                    cand = t - back
                    if cand < 0 or gather_v[cand, p_] >= 0:
                        break
                    # reads of the same slot between cand and t?
                    conflict = False
                    for tt_ in range(cand, t):
                        if kind[tt_, p_] in (KF, KB, KW):
                            # which slot does that task read? parity of
                            # the most recent gather before tt_
                            prev = [g for g in order[:gi] if g <= tt_]
                            if prev and (len(prev) - 1) % 2 == slot_parity:
                                conflict = True
                                break
                    if conflict:
                        break
                    tgt = cand
                if tgt != t:
                    gather_v[tgt, p_] = gather_v[t, p_]
                    gather_v[t, p_] = -1

    # Rotating two-slot gather buffer assignment.
    gather_slot = -np.ones((T, Pe), np.int32)
    use_slot = np.zeros((T, Pe), np.int32)
    for p in range(Pe):
        nxt = 0
        holds = {}  # v -> slot
        for t in range(T):
            if gather_v[t, p] >= 0:
                gather_slot[t, p] = nxt
                holds[gather_v[t, p]] = nxt
                nxt = 1 - nxt
            if kind[t, p] in (KF, KB, KW):
                use_slot[t, p] = holds.get(v[t, p], 0)

    # Receive maps: what lands on each wire at the END of tick t-1 (i.e. is
    # available at tick t). Sender of fwd wire for rank p is p-1 (ring).
    recv_f_u = -np.ones((T, Pe), np.int32)
    recv_b_u = -np.ones((T, Pe), np.int32)
    S = Pe * V
    for t in range(1, T):
        for p in range(Pe):
            prev = (p - 1) % Pe
            if kind[t - 1, prev] == KF:
                stage = v[t - 1, prev] * Pe + prev
                if stage < S - 1:
                    recv_f_u[t, p] = mb[t - 1, prev]
            nxt_r = (p + 1) % Pe
            if kind[t - 1, nxt_r] == KB:
                stage = v[t - 1, nxt_r] * Pe + nxt_r
                if stage > 0:
                    recv_b_u[t, p] = mb[t - 1, nxt_r]
    return PackedTable(
        T=T, Pe=Pe, V=V, U=tt.unit, n_mb=tt.n_mb, prefetch=prefetch,
        kind=kind, mb=mb, v=v,
        gather_v=gather_v, gather_slot=gather_slot, use_slot=use_slot,
        reduce_v=reduce_v, recv_f_u=recv_f_u, recv_b_u=recv_b_u,
    )


def strip_fwd(tt: TickTable) -> TickTable:
    """B/W-only table (encoder backward segment): F ran in a prior scan."""
    from repro.core.autogen import orders_from_table, retick

    orders = orders_from_table(tt)
    orders = [[t for t in o if t.kind != KF] for o in orders]
    return retick(orders, tt.P, tt.V, tt.n_mb, tt.unit, assume_f=True)


# --------------------------------------------------------------------------- #
# SchedulePlan
# --------------------------------------------------------------------------- #


# Schedules whose tables gate micro-batches into §3.1 scheduling units —
# their buffers only need unit depth. Everything else keeps the whole
# batch live (unit = n_mb); notably the full-depth §4 "autogen" schedule
# postpones W tasks across unit boundaries, which is incompatible with
# unit-depth stash reuse. Its "autogen_gated" sibling constrains the §4
# insertion loop to each unit's live window (B→W distance ≤ U, enforced
# by the stash-legality gate in retick/pack_table), so it keeps the
# requested unit depth and the O(U) activation bound. Custom unit-gated
# schedules register here.
UNIT_GATED_SCHEDULES = {"zeropp", "autogen_gated"}


@dataclasses.dataclass(frozen=True)
class PlanAnalysis:
    """Discrete-event-simulated properties of one plan under one preset."""

    preset: str
    makespan: float
    bubble_frac: float
    peak_mem: float
    n_gather: int
    n_reduce: int
    gathers_per_rank: float
    comm_frac: float       # mean per-rank collective time / makespan
    prefetch: int = 0      # gather issue distance the analysis assumed
    coll_alpha: float = 0.0      # per-collective latency of the cost model
    n_coll_gather: int = 1       # collectives per gather tick (1 = flat)
    n_coll_reduce: int = 1
    stash_depth: int = 0         # unit depth the executor buffers need
    #                              (U for unit-gated tables, n_mb else)
    rs_exposed: float = 0.0      # reduce-scatter time on the critical path
    rs_overlap_saved: float = 0.0  # worst rank's reduce time hidden under
    #                                the next unit's B/W compute

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SchedulePlan:
    """A runnable + analyzable schedule: TickTable, PackedTable, analyses.

    The packed arrays the executor scans are derived from exactly the
    table the simulator sees; nothing else flows between them.
    """

    name: str
    params: SchedParams
    table: TickTable
    packed: PackedTable
    prefetch: int = 0
    analyses: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def build(cls, name: str, sp: SchedParams, *,
              prefetch: int = 0) -> "SchedulePlan":
        """Generate a registered schedule's table and pack it."""
        return cls.from_table(name, sp, generate(name, sp),
                              prefetch=prefetch)

    @classmethod
    def from_table(cls, name: str, sp: SchedParams, tt: TickTable, *,
                   prefetch: int = 0) -> "SchedulePlan":
        return cls(name=name, params=sp, table=tt,
                   packed=pack_table(tt, prefetch=prefetch),
                   prefetch=prefetch)

    def with_prefetch(self, prefetch: int) -> "SchedulePlan":
        """Same table, re-packed for a different gather-prefetch depth.

        Analyses are NOT carried over: the simulation models prefetch
        (prefetch=0 gathers block at use; ≥1 overlap), so cached numbers
        would be stale for the new depth.
        """
        if prefetch == self.prefetch:
            return self
        return SchedulePlan(
            name=self.name, params=self.params, table=self.table,
            packed=pack_table(self.table, prefetch=prefetch),
            prefetch=prefetch)

    @property
    def has_w(self) -> bool:
        return self.packed.has_w

    def validate(self) -> None:
        self.table.validate()

    def analyze(self, cm: CostModel, preset: str = "abstract"
                ) -> PlanAnalysis:
        """Simulate this plan under ``cm``; cached per preset name.

        Prefetch-aware: a plan packed with ``prefetch == 0`` gathers at
        use time, so its collectives are simulated blocking
        (``overlap_comm=False``); ``prefetch >= 1`` keeps the async
        overlapped issue the executor actually performs.

        The cache key includes the cost model's collective profile —
        one preset name now spans several models (per-tick collective
        counts differ between coalesce modes), so an A/B of the same
        plan under both must not alias.
        """
        key = (preset, cm.n_coll_gather, cm.n_coll_reduce, cm.coll_alpha)
        if key not in self.analyses:
            cm_eff = (cm if self.prefetch > 0 else
                      dataclasses.replace(cm, overlap_comm=False))
            res = simulate(self.table, cm_eff)
            # reduce-scatter overlap accounting: the worst rank's total
            # reduce time is what fully-serial charging would add to it;
            # whatever the simulator did not expose on the critical path
            # overlapped the next unit's B/W compute.
            if self.table.reduce is not None and cm_eff.t_reduce > 0:
                rs_total = float(
                    (self.table.reduce >= 0).sum(axis=0).max()
                    * cm_eff.t_reduce)
            else:
                rs_total = 0.0
            self.analyses[key] = PlanAnalysis(
                preset=preset,
                makespan=res.makespan,
                bubble_frac=res.bubble_frac,
                peak_mem=res.peak_mem,
                n_gather=res.n_gather,
                n_reduce=res.n_reduce,
                gathers_per_rank=res.n_gather / self.table.P,
                comm_frac=float(res.comm_busy.mean()
                                / max(res.makespan, 1e-12)),
                prefetch=self.prefetch,
                coll_alpha=cm.coll_alpha,
                n_coll_gather=cm.n_coll_gather,
                n_coll_reduce=cm.n_coll_reduce,
                stash_depth=self.table.unit,
                rs_exposed=res.rs_exposed,
                rs_overlap_saved=max(0.0, rs_total - res.rs_exposed),
            )
        return self.analyses[key]


# --------------------------------------------------------------------------- #
# Hardware cost presets
# --------------------------------------------------------------------------- #

PRESETS = {"a800": A800, "tpu_v5e": TPU_V5E}

#: Calibrated α–β collective constants per preset: (alpha, beta) with
#: t_collective(n, bytes) = n·α + bytes·β.  α is the per-collective launch
#: latency (the term a per-tensor gather tick pays #tensors times and the
#: flat-segment tick pays once): published small-message latencies for the
#: preset's DP interconnect (NCCL intra-node all-gather ≈ 8 µs on A800
#: NVSwitch; ~1.2 µs per ICI hop on v5e).  β is the inverse *effective*
#: collective bandwidth on the FSDP (data) axis: the simulator Hardware
#: preset's intra-node/link peak at ~90% efficiency.
#: ``benchmarks/comm_bench.py --calibrate`` re-derives both from those
#: sources and fails on >=25% drift (so a Hardware-preset bandwidth edit
#: cannot silently desync these literals), and reports the per-cell
#: α-term share over the ``benchmarks/roofline.py`` byte-accounting grid
#: (the terms the compiled-HLO structural scrape validates).
COLLECTIVE_ALPHA_BETA: dict[str, tuple[float, float]] = {
    "a800": (8.0e-06, 1.0 / 180e9),     # NVSwitch intra-node DP axis
    "tpu_v5e": (1.2e-06, 1.0 / 45e9),   # 50 GB/s ICI at ~90% efficiency
}


def fused_cost_model(cm: CostModel) -> CostModel:
    """Fold W into B for schedules without split backward (baselines)."""
    return dataclasses.replace(cm, t_b=cm.t_b + cm.t_w, t_w=0.0,
                               m_wstash=0.0)


def preset_cost_model(preset: str, cfg=None, *, P: int, V: int,
                      seq: int = 1024, mbs: int = 1, dp: int = 1,
                      mfu: float = 0.5, n_coll_gather: int = 1,
                      n_coll_reduce: int | None = None) -> CostModel:
    """CostModel for a hardware preset and a (model × shape) workload.

    With a ModelConfig, per-task durations come from transformer napkin
    math (GEMM flops at an assumed MFU, stage-boundary activation bytes,
    blockwise FSDP gather bytes) via ``cost_model_for``; without one, the
    abstract unit-cost model (F=1, B=2, W=1) is returned so device-free
    callers still get a simulatable preset.

    Collective ticks are costed α–β style with the calibrated
    ``COLLECTIVE_ALPHA_BETA`` constants: ``n_coll_gather`` /
    ``n_coll_reduce`` are the collectives issued per gather/reduce tick —
    1 under the flat-segment layout (``coalesce="flat"``), the gatherable
    tensor count under per-tensor collectives (``coalesce="none"``).
    """
    if preset not in PRESETS:
        raise ValueError(
            f"unknown cost preset {preset!r}; known: "
            f"{', '.join(sorted(PRESETS))}")
    if cfg is None:
        return CostModel()
    hw = PRESETS[preset]
    alpha, beta = COLLECTIVE_ALPHA_BETA[preset]
    d = cfg.d_model
    L = max(cfg.n_layers, 1)
    layers_per_stage = max(L / (P * V), 1e-9)
    layer_flops = 2 * (12 * d * d) * seq * mbs + 2 * seq * seq * d * mbs
    act_bytes = seq * mbs * d * 2
    stage_param_bytes = 12 * d * d * layers_per_stage * 2
    return cost_model_for(
        hw, layer_flops_f=layer_flops, layers_per_stage=layers_per_stage,
        act_bytes=act_bytes, stage_param_bytes=stage_param_bytes,
        dp=max(dp, 1), mfu=mfu, alpha=alpha, beta=beta,
        n_coll_gather=max(n_coll_gather, 0),
        n_coll_reduce=max(n_coll_reduce if n_coll_reduce is not None
                          else n_coll_gather, 0))


# --------------------------------------------------------------------------- #
# §4 plan selection (schedule="auto")
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class PlanSelection:
    """Outcome of one auto-selection: winner + every candidate's analysis."""

    selected: SchedulePlan
    analysis: PlanAnalysis
    preset: str
    candidates: dict    # name -> PlanAnalysis | "failed: ..." str
    key: tuple | None = None
    mem_budget: float | None = None   # peak-mem cap the ranking honoured

    def ranking(self) -> list[tuple[str, float]]:
        ok = [(n, a.makespan) for n, a in self.candidates.items()
              if isinstance(a, PlanAnalysis)]
        return sorted(ok, key=lambda x: x[1])


_PLAN_CACHE: dict[tuple, PlanSelection] = {}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def plan_cache_info() -> dict:
    return {"entries": len(_PLAN_CACHE), "keys": sorted(_PLAN_CACHE)}


def candidate_schedules() -> list[str]:
    """Registered schedules eligible for auto-selection (trainable ones)."""
    from repro.api.registry import SCHEDULE_REGISTRY

    return [n for n in SCHEDULE_REGISTRY.names() if n != "fwd_only"]


def select_plan(P: int, V: int, n_mb: int, unit: int, cm: CostModel, *,
                preset: str = "abstract", prefetch: int = 0,
                candidates: list[str] | None = None,
                cache_key: tuple | None = None,
                mem_budget: float | None = None) -> PlanSelection:
    """Build + simulate every candidate schedule; the minimum simulated
    makespan wins (ties keep the earlier candidate). Unit-gated schedules
    (UNIT_GATED_SCHEDULES: zeropp and the gated §4 heuristic
    ``autogen_gated``) use the requested unit; all others — including
    full-depth autogen, whose postponed W passes cross unit boundaries
    and therefore need full-depth stash buffers — keep the whole batch
    live (unit = n_mb). Fused-backward candidates are costed with W
    folded into B so total work is identical across candidates.

    ``mem_budget`` (same units as the cost model's memory terms — bytes
    under the hardware presets) makes the ranking a real memory/makespan
    trade-off: candidates whose simulated peak memory exceeds the budget
    are ranked only among themselves if *nothing* fits (min peak memory
    wins then), exactly how the paper picks "the best U that still fits
    in HBM" — this is what lets the unit-gated autogen beat its
    full-depth sibling when the whole batch does not fit."""
    if cache_key is not None and cache_key in _PLAN_CACHE:
        return _PLAN_CACHE[cache_key]

    names = list(candidates) if candidates is not None \
        else candidate_schedules()
    cm_fused = fused_cost_model(cm)
    results: dict = {}
    fits: tuple[SchedulePlan, PlanAnalysis] | None = None   # within budget
    slim: tuple[SchedulePlan, PlanAnalysis] | None = None   # min peak_mem
    for name in names:
        sp = SchedParams(
            P=P, V=V, n_mb=n_mb,
            unit=(unit if name in UNIT_GATED_SCHEDULES else n_mb),
            split_bw=True)
        try:
            if name in ("autogen", "autogen_gated"):
                # §4 heuristic profiles with the *preset* cost model, not
                # the abstract default the registry builder would use.
                from repro.core.autogen import autogen

                tt = autogen(sp, cm,
                             unit_gated=(name == "autogen_gated")).table
                plan = SchedulePlan.from_table(name, sp, tt,
                                               prefetch=prefetch)
            else:
                plan = SchedulePlan.build(name, sp, prefetch=prefetch)
        except Exception as e:  # noqa: BLE001 — skip broken candidates
            results[name] = f"failed: {e}"
            continue
        ana = plan.analyze(cm if plan.has_w else cm_fused, preset=preset)
        results[name] = ana
        if mem_budget is None or ana.peak_mem <= mem_budget:
            if fits is None or ana.makespan < fits[1].makespan - 1e-12:
                fits = (plan, ana)
        if slim is None or ana.peak_mem < slim[1].peak_mem - 1e-12:
            slim = (plan, ana)
    best = fits or slim
    if best is None:
        raise RuntimeError(
            f"no schedule candidate could be built for P={P} V={V} "
            f"n_mb={n_mb} unit={unit}: {results}")
    sel = PlanSelection(selected=best[0], analysis=best[1], preset=preset,
                        candidates=results, key=cache_key,
                        mem_budget=mem_budget)
    if cache_key is not None:
        _PLAN_CACHE[cache_key] = sel
    return sel
