"""Architecture assembly: pipeline geometry, stage params, stage apply.

Pipeline geometry
-----------------
The model axis of the production mesh (16 ranks) is factored into
``groups × pp`` pipeline groups (a beyond-paper generalization that lets
every assigned architecture divide evenly into stages with *statically*
uniform layer kinds — see DESIGN.md §4).  Within a group, the paper's
circular placement is used: stage ``s = v·pp + p`` lives on group-rank
``p``, local slot ``v``.  Each stage holds ``k`` consecutive layers
(``i = s·k + j``); architectures whose layer-kind pattern has period
``q`` require ``q | k`` so that the kind of slot ``j`` is static.

Parameters are stored *rank-major*: stacked index ``p·V + v`` ↦ stage
``v·pp + p``, so a contiguous shard over the model axis gives each rank
exactly its V stages.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import ARCH_REGISTRY as _ARCH_REGISTRY
from repro.core.tape import Tape, TVal
from repro.kernels import ops
from repro.models import blocks
from repro.models.common import (
    ModelConfig,
    ParamSpec,
    RunConfig,
    SHAPES,
    init_params,
    rope_tables,
)

# --------------------------------------------------------------------------- #
# Geometry
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str                # "main" | "enc" | "dec"
    n_layers: int            # real (unpadded) layers
    vpp: int                 # V
    k: int                   # layers per stage
    kinds: tuple[str, ...]   # static kind per layer slot j (len k)
    causal: bool = True

    @property
    def n_stages(self):
        return None  # filled via geometry (needs pp)


@dataclasses.dataclass(frozen=True)
class Geometry:
    pp: int                  # ranks per pipeline group
    groups: int              # pipeline groups on the model axis
    segments: tuple[Segment, ...]

    @property
    def model_ranks(self):
        return self.pp * self.groups

    def seg_stages(self, seg: Segment) -> int:
        return self.pp * seg.vpp

    def padded_layers(self, seg: Segment) -> int:
        return self.pp * seg.vpp * seg.k


def build_geometry(cfg: ModelConfig, rc: RunConfig) -> Geometry:
    """Derive (and validate) the static stage layout."""
    segs = []
    if cfg.encdec is not None:
        enc_kinds = ("enc",)
        dec_kinds = ("dec",)
        v_enc = max(1, cfg.encdec.enc_layers // rc.pp)
        v_dec = max(1, cfg.n_layers // rc.pp)
        segs.append(Segment("enc", cfg.encdec.enc_layers, v_enc, 1,
                            enc_kinds, causal=False))
        segs.append(Segment("dec", cfg.n_layers, v_dec, 1, dec_kinds))
    else:
        L = cfg.n_layers
        pv = rc.pp * rc.vpp
        k = -(-L // pv)
        kinds = tuple(cfg.layer_kind(j) for j in range(k))
        # static-kind check: kind(i) must equal kind(i mod k)
        for i in range(L):
            if cfg.layer_kind(i) != kinds[i % k]:
                raise ValueError(
                    f"{cfg.name}: layer kinds are not static per slot with "
                    f"pp={rc.pp} vpp={rc.vpp} (k={k}); adjust geometry"
                )
        segs.append(Segment("main", L, rc.vpp, k, kinds))
    return Geometry(rc.pp, rc.groups, tuple(segs))


# --------------------------------------------------------------------------- #
# Param specs
# --------------------------------------------------------------------------- #


def layer_slot_specs(cfg: ModelConfig, kind: str, pfx: str):
    """Specs for one layer slot of the given static kind."""
    mix, ffn = kind.split(":") if ":" in kind else (kind, "none")
    sp: dict[str, ParamSpec] = {}
    if kind == "enc":
        sp.update(blocks.norm_specs(cfg, f"{pfx}.ln1"))
        sp.update(blocks.attn_specs(cfg, f"{pfx}.mix"))
        sp.update(blocks.norm_specs(cfg, f"{pfx}.ln2"))
        sp.update(blocks.ffn_specs(cfg, f"{pfx}.ffn"))
        return sp
    if kind == "dec":
        sp.update(blocks.norm_specs(cfg, f"{pfx}.ln1"))
        sp.update(blocks.attn_specs(cfg, f"{pfx}.mix"))
        sp.update(blocks.norm_specs(cfg, f"{pfx}.ln2"))
        sp.update(blocks.attn_specs(cfg, f"{pfx}.xattn"))
        sp.update(blocks.norm_specs(cfg, f"{pfx}.ln3"))
        sp.update(blocks.ffn_specs(cfg, f"{pfx}.ffn"))
        return sp
    sp.update(blocks.norm_specs(cfg, f"{pfx}.ln1"))
    if mix == "attn":
        sp.update(blocks.attn_specs(cfg, f"{pfx}.mix"))
    elif mix == "mla":
        sp.update(blocks.mla_specs(cfg, f"{pfx}.mix"))
    elif mix == "mamba":
        sp.update(blocks.mamba_specs(cfg, f"{pfx}.mix"))
    elif mix == "mlstm":
        sp.update(blocks.mlstm_specs(cfg, f"{pfx}.mix"))
    elif mix == "slstm":
        sp.update(blocks.slstm_specs(cfg, f"{pfx}.mix"))
    else:
        raise ValueError(mix)
    if ffn != "none":
        sp.update(blocks.norm_specs(cfg, f"{pfx}.ln2"))
        if ffn == "moe":
            sp.update(blocks.moe_specs(cfg, f"{pfx}.ffn"))
        else:
            sp.update(blocks.ffn_specs(cfg, f"{pfx}.ffn"))
    return sp


def stage_specs(cfg: ModelConfig, seg: Segment) -> dict[str, ParamSpec]:
    sp = {}
    for j, kind in enumerate(seg.kinds):
        sp.update(layer_slot_specs(cfg, kind, f"L{j}"))
    return sp


def io_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    """Embedding / final norm / head (+MTP) params, outside the pipeline."""
    d, vcb = cfg.d_model, cfg.vocab
    sp = {
        "embed.table": ParamSpec((vcb, d), fsdp_dim=0, scale=1.0),
        "final_norm.scale": ParamSpec((d,), "ones"),
    }
    if cfg.norm == "layernorm":
        sp["final_norm.bias"] = ParamSpec((d,), "zeros")
    if not cfg.tie_embeddings:
        sp["head.w"] = ParamSpec((d, vcb), fsdp_dim=1)
    if cfg.mtp:
        sp.update({
            "mtp.proj": ParamSpec((2 * d, d), fsdp_dim=0),
            "mtp.norm.scale": ParamSpec((d,), "ones"),
        })
        sp.update(layer_slot_specs(cfg, cfg.layer_kind(cfg.n_layers - 1),
                                   "mtp.layer"))
    if cfg.frontend == "audio":
        # conv frontend is stubbed: inputs are precomputed frame embeddings.
        pass
    return sp


# --------------------------------------------------------------------------- #
# Stage application (tape — train/prefill path)
# --------------------------------------------------------------------------- #


def apply_layer(
    t: Tape,
    ctx: blocks.LayerCtx,
    kind: str,
    pfx: str,
    x: TVal,
    keep,  # traced 0/1 (pad masking) or python 1
) -> tuple[TVal, TVal | None]:
    """Pre-norm residual layer. Returns (y, aux_loss or None)."""
    aux = None
    mix, ffn = kind.split(":") if ":" in kind else (kind, "none")

    def res_add(a, b):
        return t.prim(lambda u, v: u + v * keep, a, b)

    if kind == "enc":
        h = apply_mix(t, ctx, "attn", f"{pfx}", x, causal=False)
        x = res_add(x, h)
        h2 = blocks.apply_norm(t, ctx.cfg, f"{pfx}.ln2", x)
        h2 = blocks.apply_ffn(t, ctx, f"{pfx}.ffn", h2)
        return res_add(x, h2), aux
    if kind == "dec":
        h = apply_mix(t, ctx, "attn", f"{pfx}", x, causal=True)
        x = res_add(x, h)
        h2 = blocks.apply_norm(t, ctx.cfg, f"{pfx}.ln2", x)
        h2 = blocks.apply_attn(t, ctx, f"{pfx}.xattn", h2, cross=True)
        x = res_add(x, h2)
        h3 = blocks.apply_norm(t, ctx.cfg, f"{pfx}.ln3", x)
        h3 = blocks.apply_ffn(t, ctx, f"{pfx}.ffn", h3)
        return res_add(x, h3), aux

    h = apply_mix(t, ctx, mix, pfx, x, causal=ctx.causal)
    x = res_add(x, h)
    if ffn != "none":
        h2 = blocks.apply_norm(t, ctx.cfg, f"{pfx}.ln2", x)
        if ffn == "moe":
            h2, aux = blocks.apply_moe(t, ctx, f"{pfx}.ffn", h2)
        else:
            h2 = blocks.apply_ffn(t, ctx, f"{pfx}.ffn", h2)
        x = res_add(x, h2)
    return x, aux


def apply_mix(t, ctx, mix, pfx, x, causal=True):
    h = blocks.apply_norm(t, ctx.cfg, f"{pfx}.ln1", x)
    ctx2 = dataclasses.replace(ctx, causal=causal)
    if mix == "attn":
        return blocks.apply_attn(t, ctx2, f"{pfx}.mix", h)
    if mix == "mla":
        return blocks.apply_mla(t, ctx2, f"{pfx}.mix", h)
    if mix == "mamba":
        return blocks.apply_mamba(t, ctx2, f"{pfx}.mix", h)
    if mix == "mlstm":
        return blocks.apply_mlstm(t, ctx2, f"{pfx}.mix", h)
    if mix == "slstm":
        return blocks.apply_slstm(t, ctx2, f"{pfx}.mix", h)
    raise ValueError(mix)


def apply_stage(
    t: Tape,
    ctx: blocks.LayerCtx,
    seg: Segment,
    x: TVal,
    stage_id,  # traced int (v·pp + p)
) -> tuple[TVal, TVal]:
    """Apply the k layers of one stage. Returns (y, aux_scalar)."""
    aux_total = t.value(jnp.zeros((), jnp.float32))
    for j, kind in enumerate(seg.kinds):
        layer_id = stage_id * seg.k + j
        keep = jnp.asarray(layer_id < seg.n_layers).astype(x.val.dtype)
        x, aux = apply_layer(t, ctx, kind, f"L{j}", x, keep)
        if aux is not None:
            aux_total = t.prim(
                lambda a, b: a + b.astype(jnp.float32)
                * keep.astype(jnp.float32),
                aux_total, aux,
            )
    return x, aux_total


# --------------------------------------------------------------------------- #
# Decode path (pure jnp, cached)
# --------------------------------------------------------------------------- #


def layer_cache_spec(cfg, rc, kind, batch, max_seq) -> dict[str, Any]:
    """ShapeDtypeStructs for one layer's decode cache."""
    mix = kind.split(":")[0] if ":" in kind else kind
    f32, cdt = jnp.float32, jnp.dtype(rc.compute_dtype)
    # KV storage dtype is decoupled from compute: fp32/bf16 for accuracy/
    # memory, int8 for quantized pages (per-page scales live in separate
    # "<name>_scale" pool leaves added by init_serve_caches). Recurrent
    # state caches (mamba/mlstm/slstm) always keep the compute dtype.
    kv_dt = jnp.dtype({
        None: rc.compute_dtype, "fp32": jnp.float32,
        "bf16": jnp.bfloat16, "int8": jnp.int8,
    }[rc.kv_cache_dtype])
    g, e = cfg.n_kv_heads, cfg.head_dim
    if mix in ("attn", "dec"):
        return {
            "k": jax.ShapeDtypeStruct((batch, max_seq, g, e), kv_dt),
            "v": jax.ShapeDtypeStruct((batch, max_seq, g, e), kv_dt),
        }
    if mix == "enc":
        return {}
    if mix == "mla":
        m = cfg.mla
        return {"ckv": jax.ShapeDtypeStruct(
            (batch, max_seq, m.kv_lora + m.rope_dims), kv_dt)}
    if mix == "mamba":
        mc, di, _ = blocks._mamba_dims(cfg)
        return {
            "conv": jax.ShapeDtypeStruct((batch, mc.d_conv - 1, di), cdt),
            "h": jax.ShapeDtypeStruct((batch, di, mc.d_state), f32),
        }
    if mix == "mlstm":
        d = cfg.d_model
        di = int(cfg.xlstm.proj_factor * d)
        h = cfg.n_heads
        e2 = di // h
        return {
            "C": jax.ShapeDtypeStruct((batch, h, e2, e2), f32),
            "n": jax.ShapeDtypeStruct((batch, h, e2), f32),
            "m": jax.ShapeDtypeStruct((batch, h), f32),
        }
    if mix == "slstm":
        h = cfg.n_heads
        e2 = cfg.d_model // h
        z = jax.ShapeDtypeStruct((batch, h, e2), f32)
        return {"c": z, "n": z, "m": z}
    raise ValueError(mix)


def decode_layer(ctx, params, kind, pfx, x, cache, pos):
    """One cached decode step for one layer. Returns (y, new_cache)."""
    cfg = ctx.cfg
    mix = kind.split(":")[0] if ":" in kind else kind
    ffn = kind.split(":")[1] if ":" in kind else (
        "dense" if kind in ("enc", "dec") else "none"
    )
    h = blocks.norm_fwd(cfg, params, f"{pfx}.ln1", x)
    if mix in ("attn", "dec"):
        dh, cache = blocks.attn_decode(ctx, params, f"{pfx}.mix", h, cache, pos)
    elif mix == "mla":
        dh, cache = blocks.mla_decode(ctx, params, f"{pfx}.mix", h, cache, pos)
    elif mix == "mamba":
        dh, cache = blocks.mamba_decode(ctx, params, f"{pfx}.mix", h, cache, pos)
    elif mix == "mlstm":
        dh, cache = blocks.mlstm_decode(ctx, params, f"{pfx}.mix", h, cache, pos)
    elif mix == "slstm":
        dh, cache = blocks.slstm_decode(ctx, params, f"{pfx}.mix", h, cache, pos)
    else:
        raise ValueError(mix)
    x = x + dh
    if mix == "dec":
        h2 = blocks.norm_fwd(cfg, params, f"{pfx}.ln2", x)
        x = x + blocks.cross_attn_decode(ctx, params, f"{pfx}.xattn", h2,
                                         ctx.enc_memory)
        h3 = blocks.norm_fwd(cfg, params, f"{pfx}.ln3", x)
        x = x + blocks.ffn_fwd(ctx, params, f"{pfx}.ffn", h3)
        return x, cache
    if ffn != "none":
        h2 = blocks.norm_fwd(cfg, params, f"{pfx}.ln2", x)
        if ffn == "moe":
            x = x + blocks.moe_fwd(ctx, params, f"{pfx}.ffn", h2)
        else:
            x = x + blocks.ffn_fwd(ctx, params, f"{pfx}.ffn", h2)
    return x, cache


def decode_stage(ctx, seg: Segment, params, x, caches, stage_id, pos):
    """caches: list of per-slot cache dicts."""
    new_caches = []
    for j, kind in enumerate(seg.kinds):
        layer_id = stage_id * seg.k + j
        keep = (layer_id < seg.n_layers).astype(x.dtype)
        y, cj = decode_layer(ctx, params, kind, f"L{j}", x, caches[j], pos)
        x = x + (y - x) * keep
        new_caches.append(cj)
    return x, new_caches


# --------------------------------------------------------------------------- #
# Embedding / head / loss (outside the pipeline body)
# --------------------------------------------------------------------------- #


def embed_tokens(params, tokens, cfg, dtype):
    """tokens int32 [b, s] OR pre-computed embeddings float [b, s, d]."""
    if jnp.issubdtype(tokens.dtype, jnp.floating):
        return tokens.astype(dtype)  # stubbed modality frontend
    return params["embed.table"][tokens].astype(dtype)


def head_loss(params, cfg, rc, h, labels, mask=None):
    """Final norm + chunked-vocab xent. Returns loss and (dh, dW, dnorm…)
    via explicit formulas (no jax.grad) so the drain tick stays cheap.

    h: [n, d] f32/bf16, labels [n]. Returns (loss, dh, head_grads dict).
    """
    d = cfg.d_model
    scale = params["final_norm.scale"]
    hf = h.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-6)
    hn = hf * rms * scale
    w = params["embed.table"].T if cfg.tie_embeddings else params["head.w"]
    loss, (dhn, dw) = ops.softmax_xent(
        hn.astype(h.dtype), w, labels, chunk=rc.vocab_chunk, mask=mask
    )
    dhn = dhn.astype(jnp.float32)
    dscale = (dhn * hf * rms).sum(0)
    dh_pre = dhn * scale * rms
    # d/dh of rms normalizer term
    dot = jnp.sum(dhn * scale * hf, -1, keepdims=True)
    dh = dh_pre - hf * (rms ** 3) * dot / d
    grads = {"final_norm.scale": dscale}
    if cfg.tie_embeddings:
        grads["embed.table"] = dw.T
    else:
        grads["head.w"] = dw
    return loss, dh.astype(h.dtype), grads


# --------------------------------------------------------------------------- #
# Single-device reference model (numerics oracle, smoke tests)
# --------------------------------------------------------------------------- #


def make_rope_ctx(cfg: ModelConfig, rc: RunConfig, seq: int, offset=0,
                  decode=False, full_seq: int | None = None):
    dims = {cfg.head_dim}
    if cfg.mla is not None:
        dims.add(cfg.mla.rope_dims)
    rope = {}
    rope_full = {}
    for e in dims:
        cos, sin = rope_tables(seq if not decode else 1, e, cfg.rope_theta)
        if decode:
            cos_f, sin_f = rope_tables(full_seq, e, cfg.rope_theta)
            # current position table computed via dynamic slice by caller
            rope[e] = (cos_f, sin_f)  # caller slices
            rope_full[e] = (cos_f, sin_f)
        else:
            rope[e] = (cos, sin)
    return rope, rope_full


def init_all_params(cfg: ModelConfig, rc: RunConfig, key=None):
    """Full (unsharded) parameter tree: {io: {...}, segments: {name: {name: [S,...]}}}."""
    key = key if key is not None else jax.random.PRNGKey(0)
    dtype = jnp.dtype(rc.param_dtype)
    geo = build_geometry(cfg, rc)
    kio, kseg = jax.random.split(key)
    io = init_params(kio, io_specs(cfg), dtype)
    segments = {}
    for seg in geo.segments:
        sp = stage_specs(cfg, seg)
        S = geo.seg_stages(seg)
        keys = jax.random.split(jax.random.fold_in(kseg, hash(seg.name) % 2**31), S)
        stacked = None
        per_stage = [init_params(keys[s], sp, dtype) for s in range(S)]
        stacked = {
            name: jnp.stack([ps[name] for ps in per_stage])
            for name in sp
        }
        segments[seg.name] = stacked
    return {"io": io, "segments": segments}


def storage_index(p: int, v: int, V: int) -> int:
    """Rank-major stacked index for logical stage s = v·pp + p."""
    return p * V + v


def reference_logits(cfg, rc, params, tokens, enc_tokens=None,
                     return_hidden=False):
    """Full forward on one device, looping stages in logical order."""
    geo = build_geometry(cfg, rc)
    dtype = jnp.dtype(rc.compute_dtype)
    io = params["io"]
    aux_total = jnp.zeros((), jnp.float32)

    def run_segment(seg, x):
        nonlocal aux_total
        rope, _ = make_rope_ctx(cfg, rc, x.shape[1])
        ctx = blocks.LayerCtx(cfg=cfg, rc=rc, rope=rope, causal=seg.causal)
        if seg.name == "dec":
            ctx.enc_memory = None  # set by caller below
        stacked = params["segments"][seg.name]
        S = geo.seg_stages(seg)
        for s in range(S):
            p, v = s % geo.pp, s // geo.pp
            idx = storage_index(p, v, seg.vpp)
            sp = {n: a[idx] for n, a in stacked.items()}
            t = Tape(sp, mode="fwd")
            if ctx.enc_memory is not None and not isinstance(
                ctx.enc_memory, TVal
            ):
                ctx.enc_memory = t.value(ctx.enc_memory)
            xv, aux = apply_stage(t, ctx, seg, t.value(x), s)
            x = xv.val
            aux_total = aux_total + aux.val
            ctx.enc_memory = (
                ctx.enc_memory.val if isinstance(ctx.enc_memory, TVal)
                else ctx.enc_memory
            )
        return x

    if cfg.encdec is not None:
        enc_x = embed_tokens(io, enc_tokens, cfg, dtype)
        seg_e, seg_d = geo.segments
        memory = run_segment(seg_e, enc_x)
        x = embed_tokens(io, tokens, cfg, dtype)
        # decoder segment with cross-attention memory
        rope, _ = make_rope_ctx(cfg, rc, x.shape[1])
        ctx = blocks.LayerCtx(cfg=cfg, rc=rc, rope=rope, causal=True)
        stacked = params["segments"]["dec"]
        for s in range(geo.seg_stages(seg_d)):
            p, v = s % geo.pp, s // geo.pp
            idx = storage_index(p, v, seg_d.vpp)
            sp = {n: a[idx] for n, a in stacked.items()}
            t = Tape(sp, mode="fwd")
            ctx.enc_memory = t.value(memory)
            xv, _ = apply_stage(t, ctx, seg_d, t.value(x), s)
            x = xv.val
    else:
        x = embed_tokens(io, tokens, cfg, dtype)
        x = run_segment(geo.segments[0], x)

    scale = io["final_norm.scale"]
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        hn = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * scale \
            + io["final_norm.bias"]
    else:
        hn = xf * jax.lax.rsqrt(
            jnp.mean(xf * xf, -1, keepdims=True) + 1e-6) * scale
    w = io["embed.table"].T if cfg.tie_embeddings else io["head.w"]
    logits = hn.astype(dtype) @ w
    if return_hidden:
        return logits, aux_total, x
    return logits, aux_total


def reference_loss(cfg, rc, params, tokens, labels, enc_tokens=None):
    logits, aux = reference_logits(cfg, rc, params, tokens,
                                   enc_tokens=enc_tokens)
    n = logits.shape[0] * logits.shape[1]
    lf = logits.reshape(n, -1).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    lab = jnp.take_along_axis(lf, labels.reshape(n)[:, None], axis=1)[:, 0]
    loss = (lse - lab).mean()
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    if cfg.mtp:
        _, _, h_final = reference_logits(cfg, rc, params, tokens,
                                         enc_tokens=enc_tokens,
                                         return_hidden=True)
        loss = loss + MTP_WEIGHT * mtp_reference_loss(
            cfg, rc, params["io"], h_final, tokens, labels)
    return loss


# --------------------------------------------------------------------------- #
# Registry (delegates to the plug-in registry in repro.api.registry)
# --------------------------------------------------------------------------- #


def __getattr__(name):
    # ARCHS is a live view of the registry (PEP 562), so archs added via
    # repro.api.register_arch appear here too.
    if name == "ARCHS":
        return _ARCH_REGISTRY.names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def get_arch(name: str):
    """Returns the config module/object for an architecture id.

    Resolution (canonical names, aliases, custom registrations) lives in
    ``repro.api.registry``; plug new architectures in with
    ``repro.api.register_arch`` instead of editing this file.
    """
    return _ARCH_REGISTRY.get(name)


# --------------------------------------------------------------------------- #
# Cached stage execution (prefill / decode serving)
# --------------------------------------------------------------------------- #


def cached_layer(ctx, params, kind, pfx, x, cache, pos):
    """Unified prefill (s>1) / decode (s=1) for one layer."""
    cfg = ctx.cfg
    mix = kind.split(":")[0] if ":" in kind else kind
    ffn = kind.split(":")[1] if ":" in kind else (
        "dense" if kind in ("enc", "dec") else "none")
    h = blocks.norm_fwd(cfg, params, f"{pfx}.ln1", x)
    if kind == "enc":
        o = _enc_attn_fwd(ctx, params, f"{pfx}.mix", h)
        x = x + o
        h2 = blocks.norm_fwd(cfg, params, f"{pfx}.ln2", x)
        return x + blocks.ffn_fwd(ctx, params, f"{pfx}.ffn", h2), cache
    if mix in ("attn", "dec"):
        dh, cache = blocks.attn_cached(ctx, params, f"{pfx}.mix", h, cache,
                                       pos)
    elif mix == "mla":
        dh, cache = blocks.mla_cached(ctx, params, f"{pfx}.mix", h, cache,
                                      pos)
    elif mix == "mamba":
        dh, c2 = blocks.mamba_cached(ctx, params, f"{pfx}.mix", h, cache,
                                     pos)
        cache = blocks._slot_state(ctx, cache, c2)
    elif mix == "mlstm":
        dh, c2 = blocks.mlstm_cached(ctx, params, f"{pfx}.mix", h, cache,
                                     pos)
        cache = blocks._slot_state(ctx, cache, c2)
    elif mix == "slstm":
        dh, c2 = blocks.slstm_cached(ctx, params, f"{pfx}.mix", h, cache,
                                     pos)
        cache = blocks._slot_state(ctx, cache, c2)
    else:
        raise ValueError(mix)
    x = x + dh
    if mix == "dec":
        h2 = blocks.norm_fwd(cfg, params, f"{pfx}.ln2", x)
        x = x + blocks.cross_attn_decode(ctx, params, f"{pfx}.xattn", h2,
                                         ctx.enc_memory)
        h3 = blocks.norm_fwd(cfg, params, f"{pfx}.ln3", x)
        return x + blocks.ffn_fwd(ctx, params, f"{pfx}.ffn", h3), cache
    if ffn != "none":
        h2 = blocks.norm_fwd(cfg, params, f"{pfx}.ln2", x)
        if ffn == "moe":
            x = x + blocks.moe_fwd(ctx, params, f"{pfx}.ffn", h2)
        else:
            x = x + blocks.ffn_fwd(ctx, params, f"{pfx}.ffn", h2)
    return x, cache


def _enc_attn_fwd(ctx, params, pfx, x):
    q = jnp.einsum("bsd,dhe->bshe", x, params[f"{pfx}.wq"])
    k = jnp.einsum("bsd,dge->bsge", x, params[f"{pfx}.wk"])
    v = jnp.einsum("bsd,dge->bsge", x, params[f"{pfx}.wv"])
    from repro.kernels import ops as _ops
    o = _ops.attention(q, k, v, causal=False, block_k=ctx.rc.attn_block_k)
    return jnp.einsum("bshe,hed->bsd", o, params[f"{pfx}.wo"])


def cached_stage(ctx, seg, params, x, caches, stage_id, pos):
    """caches: list (per layer slot j) of cache dicts (possibly empty)."""
    new_caches = []
    for j, kind in enumerate(seg.kinds):
        layer_id = stage_id * seg.k + j
        keep = jnp.asarray(layer_id < seg.n_layers).astype(x.dtype)
        y, cj = cached_layer(ctx, params, kind, f"L{j}", x, caches[j], pos)
        x = x + (y - x) * keep
        new_caches.append(cj)
    return x, new_caches


# --------------------------------------------------------------------------- #
# DeepSeek MTP (multi-token prediction) auxiliary head
# --------------------------------------------------------------------------- #

MTP_WEIGHT = 0.1


def mtp_hidden(cfg, rc, io_params, h, emb_next, ep_axis=None):
    """DeepSeek MTP module: RMSNorm(h) ∥ RMSNorm(emb_{t+1}) → proj →
    one transformer layer → hidden for predicting token t+2.

    h: [b, s, d] final backbone hiddens; emb_next: [b, s, d] embeddings of
    the next token. MTP params are replicated io params, so the layer runs
    in gathered mode with no collectives.
    """
    from repro.core.tape import Tape

    def rms(v):
        vf = v.astype(jnp.float32)
        return (vf * jax.lax.rsqrt(
            jnp.mean(vf * vf, -1, keepdims=True) + 1e-6)).astype(v.dtype)

    cat = jnp.concatenate([rms(h), rms(emb_next)], axis=-1)
    x = jnp.einsum("bse,ed->bsd", cat, io_params["mtp.proj"])
    # one full backbone-style layer (params under "mtp.layer.")
    sub = {"L0." + n[len("mtp.layer."):]: a
           for n, a in io_params.items() if n.startswith("mtp.layer.")}
    t = Tape(sub, mode="fwd")
    kind = cfg.layer_kind(cfg.n_layers - 1)
    dims = {cfg.head_dim}
    if cfg.mla is not None:
        dims.add(cfg.mla.rope_dims)
    rope = {e: rope_tables(h.shape[1], e, cfg.rope_theta) for e in dims}
    ctx = blocks.LayerCtx(cfg=cfg, rc=rc, rope=rope, causal=True,
                          ep_axis=ep_axis)
    y, _ = apply_layer(t, ctx, kind, "L0", t.value(x), jnp.float32(1.0))
    return y.val


def mtp_reference_loss(cfg, rc, io_params, h, tokens, labels):
    """Mean xent of predicting token t+2 (reference path, replicated)."""
    b, s, d = h.shape
    emb_next = io_params["embed.table"][labels].astype(h.dtype)
    hm = mtp_hidden(cfg, rc, io_params, h, emb_next)
    # labels for t+2: shift labels left; mask the last position
    lab2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((b, s - 1)), jnp.zeros((b, 1))], axis=1)
    scale = io_params["mtp.norm.scale"]
    hf = hm.astype(jnp.float32)
    hn = hf * jax.lax.rsqrt(
        jnp.mean(hf * hf, -1, keepdims=True) + 1e-6) * scale
    w = (io_params["embed.table"].T if cfg.tie_embeddings
         else io_params["head.w"])
    logits = hn @ w.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    lab_logit = jnp.take_along_axis(
        logits.reshape(b * s, -1), lab2.reshape(b * s)[:, None], 1
    ).reshape(b, s)
    return ((lse - lab_logit) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
