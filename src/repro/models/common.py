"""Model/shape/run configuration dataclasses and parameter-spec machinery."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------- #
# Configs
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # d_ff of the shared expert(s)
    capacity_factor: float = 1.25
    every: int = 1                # MoE FFN every N layers (else dense FFN)
    offset: int = 0               # which residue (mod every) gets MoE
    first_dense: int = 0          # first N layers use a dense FFN instead
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dims: int = 64
    v_head: int = 128
    qk_nope: int = 128


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> d_model // 16


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    slstm_every: int = 8   # one sLSTM block every N (rest mLSTM)
    proj_factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int = 32
    enc_ctx: int = 1500   # whisper audio frames after conv frontend


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int               # decoder layers for encdec families
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"         # swiglu | gelu_mlp
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    mamba: MambaCfg | None = None
    attn_every: int = 0         # hybrid: attention layer every N (else mamba)
    attn_offset: int = 0        # which residue mod attn_every is attention
    xlstm: XLSTMCfg | None = None
    encdec: EncDecCfg | None = None
    frontend: str | None = None  # "audio" | "vision" (stubbed embeddings)
    mtp: bool = False            # DeepSeek multi-token-prediction aux head
    max_seq: int = 131_072

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def layer_kind(self, i: int) -> str:
        """Static mixer/ffn kind of global layer i (pre-pipeline-padding)."""
        if self.xlstm is not None:
            mix = "slstm" if (i % self.xlstm.slstm_every
                              == self.xlstm.slstm_every - 1) else "mlstm"
            return f"{mix}:none"
        if self.mamba is not None and self.attn_every:
            mix = ("attn" if i % self.attn_every == self.attn_offset
                   else "mamba")
        elif self.mamba is not None:
            mix = "mamba"
        elif self.mla is not None:
            mix = "mla"
        else:
            mix = "attn"
        if self.moe is not None:
            if (i < self.moe.first_dense
                    or (i % self.moe.every) != self.moe.offset):
                ffn = "dense"
            else:
                ffn = "moe"
        elif self.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        return f"{mix}:{ffn}"

    @property
    def is_mixed(self) -> bool:
        """Do layers differ in kind (union stage blocks needed)?"""
        kinds = {self.layer_kind(i) for i in range(self.n_layers)}
        return len(kinds) > 1


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Distribution + schedule hyper-parameters for one launch."""

    pp: int = 16                 # pipeline size P (per pipeline group)
    vpp: int = 2                 # interleaved stages per device V
    groups: int = 1              # pipeline groups sharing the model axis
    microbatches: int = 8        # B: micro-batches per pipeline per step
    unit: int = 0                # U: scheduling-unit size (0 -> B)
    schedule: str = "zeropp"     # zeropp|gpipe|1f1b|interleaved|bfs|
                                 # autogen|autogen_gated (§4; _gated keeps
                                 # unit-depth stash buffers)
    fsdp: bool = True
    moe_mode: str = "gathered"   # gathered | ep | auto (Session resolves
                                 # "auto" to a concrete mode via the
                                 # a2a-aware cost model before any build)
    moe_stats: bool = False      # collect per-layer expert-load histograms
                                 # + capacity-drop counters (train metrics
                                 # "moe_load"/"moe_dropped"; serve steps
                                 # return an extra trailing stats dict)
    remat: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    grad_compress: str = "none"  # none | int8
    grad_rs_dtype: str = "float32"  # reduce-scatter wire dtype (bf16 halves
                                    # grad traffic; accum stays fp32)
    coalesce: str = "flat"          # flat: one all-gather / reduce-scatter
                                    # per stage segment per tick (flat
                                    # buffers, §3.3 bandwidth-bound); none:
                                    # one collective per tensor (escape
                                    # hatch / debugging)
    serve_resident: bool = False    # serving: keep non-EP params gathered
                                    # (no per-step FSDP gathers)
    no_defer_extra: tuple = ()      # param-name substrings whose dW is
                                    # computed in B (partial W-deferral —
                                    # trades bubble-filler mass for stash
                                    # memory on huge projections)
    opt_moment_dtype: str = "float32"
    gather_prefetch: int = 1        # issue stage gathers N ticks early
                                    # (paper §3.3 prefetch; ≥1 lets the
                                    # async all-gather overlap the prior
                                    # block's compute; 0 = gather at use)
    attn_block_k: int = 512
    vocab_chunk: int = 8192
    kernel_impl: str | None = None  # None: backend default (Pallas on TPU,
                                    # ref elsewhere); "pallas"/"ref" force a
                                    # path (pallas runs interpret off-TPU)
    kv_cache_dtype: str | None = None  # serving KV-cache storage dtype:
                                       # None (= compute_dtype) | "fp32" |
                                       # "bf16" | "int8" (paged only;
                                       # per-page×head scales ride along)

    @property
    def unit_size(self) -> int:
        return self.unit or self.microbatches


# --------------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    init: str = "normal"         # normal | zeros | ones | small
    fsdp_dim: int = 0            # which dim FSDP shards over "data"
    scale: float = 1.0           # init scale multiplier
    ep: bool = False             # expert-parallel: dim0 stays sharded over
                                 # "data" (never FSDP-gathered) in ep mode


@dataclasses.dataclass(frozen=True)
class FlatEntry:
    """One gatherable tensor's slice of a stage's flat segment.

    The segment stores each tensor with its data-sharded dim moved to
    axis 0 and flattened, laid out *shard-major*: the per-rank local
    packs concatenate in entry order, and the gathered segment is the
    rank-order concatenation of those locals. ``offset``/``size`` index
    the LOCAL (per-shard) pack — the gathered view of tensor ``i`` is
    ``seg.reshape(dsize, local_size)[:, offset:offset+size]``.
    """

    name: str
    shape: tuple[int, ...]       # full (unsharded) tensor shape
    ld: int                      # data-sharded dim (moved to axis 0)
    offset: int                  # start in the local flat pack (elements)
    size: int                    # local element count (= prod(shape)/dsize)


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static offsets of one stage segment's flat parameter buffer."""

    entries: tuple[FlatEntry, ...]
    local_size: int              # per-shard flat length
    dsize: int                   # data-axis size the layout was built for

    @property
    def full_size(self) -> int:
        return self.local_size * self.dsize

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(e.name for e in self.entries)


def init_param(key, spec: ParamSpec, dtype) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if spec.shape else 1
    std = spec.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_params(
    key, specs: dict[str, ParamSpec], dtype=jnp.bfloat16
) -> dict[str, jnp.ndarray]:
    out = {}
    names = sorted(specs)
    keys = jax.random.split(key, max(len(names), 1))
    for k, name in zip(keys, names):
        out[name] = init_param(k, specs[name], dtype)
    return out


def rope_tables(seq: int, d: int, theta: float, dtype=jnp.float32):
    """cos/sin tables [seq, d/2]."""
    inv = 1.0 / theta ** (np.arange(0, d, 2) / d)
    pos = np.arange(seq)
    ang = np.einsum("s,f->sf", pos, inv)
    return jnp.asarray(np.cos(ang), dtype), jnp.asarray(np.sin(ang), dtype)


def apply_rope(x: jnp.ndarray, cos, sin):
    """x: [..., s, h, e] with cos/sin [s, e/2] — or [b, s, e/2] when each
    batch row sits at its own absolute position (slotted serving) —
    broadcast over heads.

    Rotation in fp32, result cast back to x.dtype (keeps bf16 pipelines
    bf16 — fp32 tables must not promote activations)."""
    e = x.shape[-1]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : e // 2], xf[..., e // 2:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)
