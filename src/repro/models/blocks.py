"""Transformer / MoE / SSM / xLSTM blocks written against the ZeroPP tape.

Every parameterized GEMM goes through ``Tape.dense`` (deferred dW → the W
task); everything else is a generic prim (immediate small grads in B).
Each block also has a ``*_decode`` pure-jnp variant for cached serving.

Naming: params are flat dicts; a layer's params are prefixed ``L{j}.``
by the stage assembly in model.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.tape import Tape, TVal
from repro.kernels import ops
from repro.models.common import (
    MLACfg,
    ModelConfig,
    ParamSpec,
    RunConfig,
    apply_rope,
)

# --------------------------------------------------------------------------- #
# Context threaded through layer application
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class LayerCtx:
    cfg: ModelConfig
    rc: RunConfig
    rope: dict[int, tuple[jnp.ndarray, jnp.ndarray]]  # head_dim -> (cos, sin)
    causal: bool = True
    ep_axis: str | None = None       # all_to_all axis for EP MoE (under shard_map)
    enc_memory: Any = None           # TVal [b, enc_ctx, d] for cross-attn
    decode: bool = False
    rope_full: dict | None = None    # head_dim -> full-cache rope tables (decode)
    kv_seq_shard: bool = False       # 500k path: KV cache sharded on seq
    kv_shards: int = 1               # over this many "data" ranks
    slot_mask: Any = None            # [b] bool: rows allowed to write their
    #                                  cache slot (continuous batching);
    #                                  None = every row writes
    page_tables: Any = None          # [b, pages_per_req] int32 local page
    #                                  ids (paged KV cache); None = the
    #                                  contiguous per-row cache layout
    page_size: int = 0               # tokens per page when paged
    moe_stats: Any = None            # None (off) | list collector: apply_moe
    #                                  appends (pfx, load[E], dropped) per
    #                                  layer when set (RunConfig.moe_stats)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #


def norm_specs(cfg: ModelConfig, pfx: str) -> dict[str, ParamSpec]:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {
            f"{pfx}.scale": ParamSpec((d,), "ones", fsdp_dim=0),
            f"{pfx}.bias": ParamSpec((d,), "zeros", fsdp_dim=0),
        }
    return {f"{pfx}.scale": ParamSpec((d,), "ones", fsdp_dim=0)}


def apply_norm(t: Tape, cfg: ModelConfig, pfx: str, x: TVal) -> TVal:
    if cfg.norm == "layernorm":
        def ln(scale, bias, v):
            vf = v.astype(jnp.float32)
            mu = vf.mean(axis=-1, keepdims=True)
            var = ((vf - mu) ** 2).mean(axis=-1, keepdims=True)
            y = (vf - mu) * jax.lax.rsqrt(var + 1e-5)
            return (y * scale + bias).astype(v.dtype)

        return t.prim(ln, x, pnames=(f"{pfx}.scale", f"{pfx}.bias"))

    def rms(scale, v):
        vf = v.astype(jnp.float32)
        y = vf * jax.lax.rsqrt(jnp.mean(vf * vf, axis=-1, keepdims=True) + 1e-6)
        return (y * scale).astype(v.dtype)

    return t.prim(rms, x, pnames=(f"{pfx}.scale",))


def norm_fwd(cfg, params, pfx, x):
    """Pure fwd (decode path)."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(axis=-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        return (y * params[f"{pfx}.scale"] + params[f"{pfx}.bias"]).astype(x.dtype)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (y * params[f"{pfx}.scale"]).astype(x.dtype)


# --------------------------------------------------------------------------- #
# GQA attention
# --------------------------------------------------------------------------- #


def attn_specs(cfg: ModelConfig, pfx: str, cross: bool = False):
    d, h, g, e = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = {
        f"{pfx}.wq": ParamSpec((d, h, e), fsdp_dim=0),
        f"{pfx}.wk": ParamSpec((d, g, e), fsdp_dim=0),
        f"{pfx}.wv": ParamSpec((d, g, e), fsdp_dim=0),
        f"{pfx}.wo": ParamSpec((h, e, d), fsdp_dim=2),
    }
    return sp


def apply_attn(
    t: Tape, ctx: LayerCtx, pfx: str, x: TVal, *, cross: bool = False
) -> TVal:
    cfg, rc = ctx.cfg, ctx.rc
    q = t.dense(x, f"{pfx}.wq", "bsd,dhe->bshe")
    kv_src = ctx.enc_memory if cross else x
    k = t.dense(kv_src, f"{pfx}.wk", "bsd,dge->bsge")
    v = t.dense(kv_src, f"{pfx}.wv", "bsd,dge->bsge")
    if not cross:
        cos, sin = ctx.rope[cfg.head_dim]

        def core(qv, kv, vv):
            qr = apply_rope(qv, cos, sin)
            kr = apply_rope(kv, cos, sin)
            return ops.attention(
                qr, kr, vv, causal=ctx.causal, block_k=rc.attn_block_k,
                impl=rc.kernel_impl,
            )

        o = t.prim(core, q, k, v)
    else:

        def core(qv, kv, vv):
            return ops.attention(qv, kv, vv, causal=False,
                                 block_k=rc.attn_block_k,
                                 impl=rc.kernel_impl)

        o = t.prim(core, q, k, v)
    return t.dense(o, f"{pfx}.wo", "bshe,hed->bsd")


def attn_decode(ctx: LayerCtx, params, pfx, x, cache, pos):
    """x: [b, 1, d]; cache: dict(k: [b,S,g,e], v: [b,S,g,e]); pos scalar."""
    cfg = ctx.cfg
    q = jnp.einsum("bsd,dhe->bshe", x, params[f"{pfx}.wq"])
    k = jnp.einsum("bsd,dge->bsge", x, params[f"{pfx}.wk"])
    v = jnp.einsum("bsd,dge->bsge", x, params[f"{pfx}.wv"])
    cos, sin = ctx.rope[cfg.head_dim]  # [1, e/2] at current pos
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0)
    )
    o, _ = ops.decode_attention(q, k_cache, v_cache, cache_len=pos + 1,
                                impl=ctx.rc.kernel_impl)
    y = jnp.einsum("bshe,hed->bsd", o, params[f"{pfx}.wo"])
    return y, {"k": k_cache, "v": v_cache}


def cross_attn_decode(ctx, params, pfx, x, memory):
    q = jnp.einsum("bsd,dhe->bshe", x, params[f"{pfx}.wq"])
    k = jnp.einsum("bsd,dge->bsge", memory, params[f"{pfx}.wk"])
    v = jnp.einsum("bsd,dge->bsge", memory, params[f"{pfx}.wv"])
    o = ops.attention(q, k, v, causal=False)
    return jnp.einsum("bshe,hed->bsd", o, params[f"{pfx}.wo"])


# --------------------------------------------------------------------------- #
# MLA (DeepSeek multi-head latent attention)
# --------------------------------------------------------------------------- #


def mla_specs(cfg: ModelConfig, pfx: str):
    m: MLACfg = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    return {
        f"{pfx}.wdq": ParamSpec((d, m.q_lora), fsdp_dim=0),
        f"{pfx}.qnorm.scale": ParamSpec((m.q_lora,), "ones"),
        f"{pfx}.wuq": ParamSpec((m.q_lora, h, m.qk_nope + m.rope_dims),
                                fsdp_dim=0),
        f"{pfx}.wdkv": ParamSpec((d, m.kv_lora + m.rope_dims), fsdp_dim=0),
        f"{pfx}.kvnorm.scale": ParamSpec((m.kv_lora,), "ones"),
        f"{pfx}.wuk": ParamSpec((m.kv_lora, h, m.qk_nope), fsdp_dim=0),
        f"{pfx}.wuv": ParamSpec((m.kv_lora, h, m.v_head), fsdp_dim=0),
        f"{pfx}.wo": ParamSpec((h, m.v_head, d), fsdp_dim=2),
    }


def apply_mla(t: Tape, ctx: LayerCtx, pfx: str, x: TVal) -> TVal:
    cfg = ctx.cfg
    m: MLACfg = cfg.mla
    cq = t.dense(x, f"{pfx}.wdq", "bsd,dr->bsr")
    cq = _rms_sub(t, f"{pfx}.qnorm.scale", cq)
    q = t.dense(cq, f"{pfx}.wuq", "bsr,rhe->bshe")  # e = qk_nope + rope
    ckv = t.dense(x, f"{pfx}.wdkv", "bsd,dc->bsc")  # c = kv_lora + rope

    def split_norm(scale, c):
        c_kv = c[..., : m.kv_lora]
        k_rope = c[..., m.kv_lora:]
        cf = c_kv.astype(jnp.float32)
        c_kv = (
            cf * jax.lax.rsqrt(jnp.mean(cf * cf, -1, keepdims=True) + 1e-6)
            * scale
        ).astype(c.dtype)
        return c_kv, k_rope

    c_kv, k_rope = t.prim(
        split_norm, ckv, pnames=(f"{pfx}.kvnorm.scale",), n_out=2
    )
    k_nope = t.dense(c_kv, f"{pfx}.wuk", "bsc,che->bshe")
    vv = t.dense(c_kv, f"{pfx}.wuv", "bsc,che->bshe")
    cos, sin = ctx.rope[m.rope_dims]

    def core(qv, knope, krope, val):
        q_nope, q_rope = qv[..., : m.qk_nope], qv[..., m.qk_nope:]
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope_r = apply_rope(krope[:, :, None, :], cos, sin)
        k_rope_b = jnp.broadcast_to(
            k_rope_r, knope.shape[:3] + (m.rope_dims,)
        )
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        kf = jnp.concatenate([knope, k_rope_b], axis=-1)
        scale = 1.0 / (m.qk_nope + m.rope_dims) ** 0.5
        return ops.attention(
            qf, kf, val, causal=ctx.causal, block_k=ctx.rc.attn_block_k,
            impl=ctx.rc.kernel_impl,
        )

    o = t.prim(core, q, k_nope, k_rope, vv)
    return t.dense(o, f"{pfx}.wo", "bshe,hed->bsd")


def mla_decode(ctx, params, pfx, x, cache, pos):
    """Cache holds the *compressed* ckv [b, S, kv_lora + rope_dims]."""
    cfg = ctx.cfg
    m: MLACfg = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, params[f"{pfx}.wdq"])
    cqf = cq.astype(jnp.float32)
    cq = (cqf * jax.lax.rsqrt(jnp.mean(cqf * cqf, -1, keepdims=True) + 1e-6)
          * params[f"{pfx}.qnorm.scale"]).astype(x.dtype)
    q = jnp.einsum("bsr,rhe->bshe", cq, params[f"{pfx}.wuq"])
    ckv = jnp.einsum("bsd,dc->bsc", x, params[f"{pfx}.wdkv"])
    cache_new = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0)
    )
    full = cache_new  # [b, S, c]
    c_kv, k_rope = full[..., : m.kv_lora], full[..., m.kv_lora:]
    cf = c_kv.astype(jnp.float32)
    c_kv = (cf * jax.lax.rsqrt(jnp.mean(cf * cf, -1, keepdims=True) + 1e-6)
            * params[f"{pfx}.kvnorm.scale"]).astype(x.dtype)
    k_nope = jnp.einsum("bsc,che->bshe", c_kv, params[f"{pfx}.wuk"])
    v = jnp.einsum("bsc,che->bshe", c_kv, params[f"{pfx}.wuv"])
    cos_q, sin_q = ctx.rope[m.rope_dims]          # [1, rope/2] current pos
    cos_k, sin_k = ctx.rope_full[m.rope_dims]     # [S, rope/2]
    q_nope, q_rope = q[..., : m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope, cos_q, sin_q)
    k_rope = apply_rope(k_rope[:, :, None, :], cos_k, sin_k)
    k_rope = jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.rope_dims,))
    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope, k_rope], -1)
    scale = 1.0 / (m.qk_nope + m.rope_dims) ** 0.5
    o, _ = ops.decode_attention(qf, kf, v, cache_len=pos + 1,
                                impl=ctx.rc.kernel_impl)
    y = jnp.einsum("bshe,hed->bsd", o, params[f"{pfx}.wo"])
    return y, {"ckv": cache_new}


def _rms_sub(t: Tape, scale_name: str, x: TVal) -> TVal:
    def rms(scale, v):
        vf = v.astype(jnp.float32)
        y = vf * jax.lax.rsqrt(jnp.mean(vf * vf, -1, keepdims=True) + 1e-6)
        return (y * scale).astype(v.dtype)

    return t.prim(rms, x, pnames=(scale_name,))


# --------------------------------------------------------------------------- #
# Dense FFN (SwiGLU or GELU-MLP)
# --------------------------------------------------------------------------- #


def ffn_specs(cfg: ModelConfig, pfx: str, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "gelu_mlp":
        return {
            f"{pfx}.wi": ParamSpec((d, f), fsdp_dim=1),
            f"{pfx}.wd": ParamSpec((f, d), fsdp_dim=0),
        }
    return {
        f"{pfx}.wg": ParamSpec((d, f), fsdp_dim=1),
        f"{pfx}.wu": ParamSpec((d, f), fsdp_dim=1),
        f"{pfx}.wd": ParamSpec((f, d), fsdp_dim=0),
    }


def apply_ffn(t: Tape, ctx: LayerCtx, pfx: str, x: TVal) -> TVal:
    if ctx.cfg.act == "gelu_mlp":
        h = t.dense(x, f"{pfx}.wi", "bsd,df->bsf")
        h = t.elementwise(jax.nn.gelu, h)
        return t.dense(h, f"{pfx}.wd", "bsf,fd->bsd")
    g = t.dense(x, f"{pfx}.wg", "bsd,df->bsf")
    u = t.dense(x, f"{pfx}.wu", "bsd,df->bsf")
    h = t.prim(lambda a, b: jax.nn.silu(a) * b, g, u)
    return t.dense(h, f"{pfx}.wd", "bsf,fd->bsd")


def ffn_fwd(ctx, params, pfx, x):
    if ctx.cfg.act == "gelu_mlp":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params[f"{pfx}.wi"]))
        return jnp.einsum("bsf,fd->bsd", h, params[f"{pfx}.wd"])
    g = jnp.einsum("bsd,df->bsf", x, params[f"{pfx}.wg"])
    u = jnp.einsum("bsd,df->bsf", x, params[f"{pfx}.wu"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params[f"{pfx}.wd"])


# --------------------------------------------------------------------------- #
# MoE (shared + routed top-k, capacity-based dispatch)
# --------------------------------------------------------------------------- #


def moe_specs(cfg: ModelConfig, pfx: str):
    mo = cfg.moe
    d, fe = cfg.d_model, mo.d_ff_expert
    sp = {
        f"{pfx}.router": ParamSpec((d, mo.n_experts), fsdp_dim=0, scale=0.1),
        f"{pfx}.e_wg": ParamSpec((mo.n_experts, d, fe), fsdp_dim=2, ep=True),
        f"{pfx}.e_wu": ParamSpec((mo.n_experts, d, fe), fsdp_dim=2, ep=True),
        f"{pfx}.e_wd": ParamSpec((mo.n_experts, fe, d), fsdp_dim=1, ep=True),
    }
    if mo.n_shared:
        fs = mo.d_ff_shared or fe * mo.n_shared
        sp.update({
            f"{pfx}.s_wg": ParamSpec((d, fs), fsdp_dim=1),
            f"{pfx}.s_wu": ParamSpec((d, fs), fsdp_dim=1),
            f"{pfx}.s_wd": ParamSpec((fs, d), fsdp_dim=0),
        })
    return sp


def _capacity(n_tok: int, mo) -> int:
    c = int(n_tok * mo.top_k / mo.n_experts * mo.capacity_factor) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def apply_moe(t: Tape, ctx: LayerCtx, pfx: str, x: TVal) -> tuple[TVal, TVal]:
    """Returns (y, aux_loss)."""
    cfg, mo = ctx.cfg, ctx.cfg.moe
    b, s, d = x.shape
    n = b * s
    cap = _capacity(n, mo)
    E, K = mo.n_experts, mo.top_k

    logits = t.dense(x, f"{pfx}.router", "bsd,de->bse")

    # Routing (indices exit the tape as closure captures; weights stay on it).
    holder = {}

    def route(lg):
        lgf = lg.reshape(n, E).astype(jnp.float32)
        probs = jax.nn.softmax(lgf, axis=-1)
        topw, topi = jax.lax.top_k(probs, K)          # [n, K]
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
        # position of each (token, k) within its expert
        onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)  # [n, K, E]
        flat_oh = onehot.reshape(n * K, E)
        pos = jnp.cumsum(flat_oh, axis=0) - flat_oh         # rank within expert
        slot = (pos * flat_oh).sum(-1).reshape(n, K)        # [n, K]
        keep = slot < cap
        # aux load-balance loss (Switch-style)
        frac_tok = jnp.mean(onehot[:, 0].astype(jnp.float32), axis=0)
        frac_prob = probs.mean(axis=0)
        aux = (frac_tok * frac_prob).sum() * E
        holder["topi"] = topi
        holder["slot"] = jnp.where(keep, slot, cap)  # cap = drop slot
        if ctx.moe_stats is not None:
            # dispatch observability (RunConfig.moe_stats): routed
            # assignment count per expert and capacity-dropped count —
            # integers exiting the tape as closure captures like topi.
            # Slotted serving pads inactive rows; mask them out so the
            # histogram counts only live requests' tokens.
            if ctx.slot_mask is not None:
                live = jnp.repeat(ctx.slot_mask.astype(jnp.int32), s * K)
            else:
                live = jnp.ones((n * K,), jnp.int32)
            holder["load"] = (flat_oh * live[:, None]).sum(0)
            holder["dropped"] = (
                (~keep).reshape(-1).astype(jnp.int32) * live).sum()
        return topw, aux

    topw, aux = t.prim(route, logits, n_out=2)

    def dispatch(xv):
        xf = xv.reshape(n, d)
        buf = jnp.zeros((E, cap + 1, d), xv.dtype)
        ti = holder["topi"].reshape(-1)
        sl = holder["slot"].reshape(-1)
        xk = jnp.repeat(xf, K, axis=0)
        return buf.at[ti, sl].add(xk)[:, :cap]

    xe = t.prim(dispatch, x)  # [E, cap, d]

    if ctx.ep_axis is not None:
        # all_to_all: split experts over the data axis, concat capacity.
        ax = ctx.ep_axis

        def a2a_fwd(v):
            return jax.lax.all_to_all(v, ax, split_axis=0, concat_axis=1,
                                      tiled=True)

        xe = t.prim(a2a_fwd, xe)  # [E/D, cap*D, d]

    g = t.dense(xe, f"{pfx}.e_wg", "ecd,edf->ecf")
    u = t.dense(xe, f"{pfx}.e_wu", "ecd,edf->ecf")
    hh = t.prim(lambda a, b2: jax.nn.silu(a) * b2, g, u)
    ye = t.dense(hh, f"{pfx}.e_wd", "ecf,efd->ecd")

    if ctx.ep_axis is not None:
        ax = ctx.ep_axis

        def a2a_bwd(v):
            return jax.lax.all_to_all(v, ax, split_axis=1, concat_axis=0,
                                      tiled=True)

        ye = t.prim(a2a_bwd, ye)

    def combine(yv, wv):
        ti = holder["topi"]            # [n, K]
        sl = holder["slot"]            # [n, K] (cap = dropped)
        ypad = jnp.pad(yv, ((0, 0), (0, 1), (0, 0)))  # drop slot reads zeros
        gathered = ypad[ti, sl]        # [n, K, d]
        out = (gathered * wv[..., None].astype(yv.dtype)).sum(axis=1)
        return out.reshape(b, s, d)

    y = t.prim(combine, ye, topw)

    if mo.n_shared:
        g2 = t.dense(x, f"{pfx}.s_wg", "bsd,df->bsf")
        u2 = t.dense(x, f"{pfx}.s_wu", "bsd,df->bsf")
        h2 = t.prim(lambda a, b2: jax.nn.silu(a) * b2, g2, u2)
        y2 = t.dense(h2, f"{pfx}.s_wd", "bsf,fd->bsd")
        y = t.add(y, y2)
    if ctx.moe_stats is not None:
        ctx.moe_stats.append((pfx, holder["load"], holder["dropped"]))
    return y, aux


def moe_fwd(ctx, params, pfx, x):
    """Decode/plain path (no tape, gathered experts)."""
    t = Tape(params, mode="fwd")
    y, _ = apply_moe(t, ctx, pfx, t.value(x))
    return y.val


# --------------------------------------------------------------------------- #
# Mamba (selective SSM)
# --------------------------------------------------------------------------- #


def _mamba_dims(cfg):
    mc = cfg.mamba
    di = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or max(1, cfg.d_model // 16)
    return mc, di, dt_rank


def mamba_specs(cfg: ModelConfig, pfx: str):
    mc, di, dt_rank = _mamba_dims(cfg)
    d, n = cfg.d_model, mc.d_state
    return {
        f"{pfx}.w_in": ParamSpec((d, 2 * di), fsdp_dim=1),
        f"{pfx}.conv_w": ParamSpec((mc.d_conv, di), "small", fsdp_dim=1,
                                   scale=0.5),
        f"{pfx}.conv_b": ParamSpec((di,), "zeros"),
        f"{pfx}.w_x": ParamSpec((di, dt_rank + 2 * n), fsdp_dim=0),
        f"{pfx}.w_dt": ParamSpec((dt_rank, di), fsdp_dim=1),
        f"{pfx}.dt_bias": ParamSpec((di,), "zeros"),
        f"{pfx}.A_log": ParamSpec((di, n), "ones"),
        f"{pfx}.Dd": ParamSpec((di,), "ones"),
        f"{pfx}.w_out": ParamSpec((di, d), fsdp_dim=0),
    }


def apply_mamba(t: Tape, ctx: LayerCtx, pfx: str, x: TVal) -> TVal:
    cfg = ctx.cfg
    mc, di, dt_rank = _mamba_dims(cfg)
    n = mc.d_state
    xz = t.dense(x, f"{pfx}.w_in", "bsd,de->bse")  # e = 2*di

    def conv_split(cw, cb, v):
        xs, z = v[..., :di], v[..., di:]
        # causal depthwise conv over seq
        pad = jnp.pad(xs, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
        out = sum(
            pad[:, i: i + xs.shape[1]] * cw[i][None, None]
            for i in range(mc.d_conv)
        ) + cb
        return jax.nn.silu(out), z

    xs, z = t.prim(
        conv_split, xz, pnames=(f"{pfx}.conv_w", f"{pfx}.conv_b"), n_out=2
    )
    bcdt = t.dense(xs, f"{pfx}.w_x", "bse,er->bsr")  # r = dt_rank + 2n

    def ssm(w_dt, dt_bias, a_log, dd, xs_v, bcdt_v, z_v):
        dt_in = bcdt_v[..., :dt_rank]
        Bm = bcdt_v[..., dt_rank: dt_rank + n].astype(jnp.float32)
        Cm = bcdt_v[..., dt_rank + n:].astype(jnp.float32)
        dt = jax.nn.softplus(
            jnp.einsum("bsr,re->bse", dt_in, w_dt) + dt_bias
        ).astype(jnp.float32)
        A = -jnp.exp(a_log.astype(jnp.float32))
        y = ops.selective_scan(
            xs_v.astype(jnp.float32), dt, A, Bm, Cm,
            dd.astype(jnp.float32),
        )
        return (y * jax.nn.silu(z_v.astype(jnp.float32))).astype(xs_v.dtype)

    y = t.prim(
        ssm, xs, bcdt, z,
        pnames=(f"{pfx}.w_dt", f"{pfx}.dt_bias", f"{pfx}.A_log", f"{pfx}.Dd"),
    )
    return t.dense(y, f"{pfx}.w_out", "bse,ed->bsd")


def mamba_decode(ctx, params, pfx, x, cache, pos):
    """cache: {"conv": [b, d_conv-1, di], "h": [b, di, n]}; x [b, 1, d]."""
    cfg = ctx.cfg
    mc, di, dt_rank = _mamba_dims(cfg)
    n = mc.d_state
    xz = jnp.einsum("bsd,de->bse", x, params[f"{pfx}.w_in"])[:, 0]
    xs, z = xz[..., :di], xz[..., di:]
    conv_in = jnp.concatenate([cache["conv"], xs[:, None]], axis=1)
    cw = params[f"{pfx}.conv_w"]
    out = sum(conv_in[:, i] * cw[i][None] for i in range(mc.d_conv))
    xs_c = jax.nn.silu(out + params[f"{pfx}.conv_b"])
    bcdt = jnp.einsum("be,er->br", xs_c, params[f"{pfx}.w_x"])
    dt = jax.nn.softplus(
        jnp.einsum("br,re->be", bcdt[..., :dt_rank], params[f"{pfx}.w_dt"])
        + params[f"{pfx}.dt_bias"]
    ).astype(jnp.float32)
    Bm = bcdt[..., dt_rank: dt_rank + n].astype(jnp.float32)
    Cm = bcdt[..., dt_rank + n:].astype(jnp.float32)
    A = -jnp.exp(params[f"{pfx}.A_log"].astype(jnp.float32))
    h_new, y = ops.selective_scan_step(
        cache["h"], xs_c.astype(jnp.float32), dt, A, Bm, Cm,
        params[f"{pfx}.Dd"].astype(jnp.float32),
    )
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = jnp.einsum("be,ed->bd", y, params[f"{pfx}.w_out"])[:, None]
    return y, {"conv": conv_in[:, 1:], "h": h_new}


# --------------------------------------------------------------------------- #
# xLSTM blocks
# --------------------------------------------------------------------------- #


def mlstm_specs(cfg: ModelConfig, pfx: str):
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    h = cfg.n_heads
    e = di // h
    return {
        f"{pfx}.w_up": ParamSpec((d, 2 * di), fsdp_dim=1),
        f"{pfx}.wq": ParamSpec((di, h, e), fsdp_dim=0),
        f"{pfx}.wk": ParamSpec((di, h, e), fsdp_dim=0),
        f"{pfx}.wv": ParamSpec((di, h, e), fsdp_dim=0),
        f"{pfx}.w_if": ParamSpec((di, 2, h), fsdp_dim=0, scale=0.1),
        f"{pfx}.if_bias": ParamSpec((2, h), "zeros"),
        f"{pfx}.w_out": ParamSpec((di, d), fsdp_dim=0),
    }


def apply_mlstm(t: Tape, ctx: LayerCtx, pfx: str, x: TVal) -> TVal:
    cfg = ctx.cfg
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    up = t.dense(x, f"{pfx}.w_up", "bsd,de->bse")
    xb, z = t.prim(lambda v: (v[..., :di], v[..., di:]), up, n_out=2)
    q = t.dense(xb, f"{pfx}.wq", "bse,ehf->bshf")
    k = t.dense(xb, f"{pfx}.wk", "bse,ehf->bshf")
    v = t.dense(xb, f"{pfx}.wv", "bse,ehf->bshf")

    def core(w_if, if_bias, xbv, qv, kv, vv, zv):
        gates = jnp.einsum("bse,egh->bsgh", xbv.astype(jnp.float32),
                           w_if.astype(jnp.float32)) + if_bias
        ig, fg = gates[:, :, 0], gates[:, :, 1] + 1.0
        y = ops.mlstm_chunkwise(qv, kv, vv, ig, fg)
        y = y.reshape(y.shape[0], y.shape[1], -1)
        return y * jax.nn.silu(zv)

    y = t.prim(core, xb, q, k, v, z,
               pnames=(f"{pfx}.w_if", f"{pfx}.if_bias"))
    return t.dense(y, f"{pfx}.w_out", "bse,ed->bsd")


def mlstm_decode(ctx, params, pfx, x, cache, pos):
    cfg = ctx.cfg
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    h = cfg.n_heads
    e = di // h
    up = jnp.einsum("bsd,de->bse", x, params[f"{pfx}.w_up"])[:, 0]
    xb, z = up[..., :di], up[..., di:]
    q = jnp.einsum("be,ehf->bhf", xb, params[f"{pfx}.wq"])
    k = jnp.einsum("be,ehf->bhf", xb, params[f"{pfx}.wk"])
    v = jnp.einsum("be,ehf->bhf", xb, params[f"{pfx}.wv"])
    gates = jnp.einsum("be,egh->bgh", xb.astype(jnp.float32),
                       params[f"{pfx}.w_if"].astype(jnp.float32))
    gates = gates + params[f"{pfx}.if_bias"]
    ig, fg = gates[:, 0], gates[:, 1] + 1.0
    state = (cache["C"], cache["n"], cache["m"])
    state, y = ops.mlstm_step(state, q, k, v, ig, fg)
    y = (y.reshape(y.shape[0], -1) * jax.nn.silu(z)).astype(x.dtype)
    y = jnp.einsum("be,ed->bd", y, params[f"{pfx}.w_out"])[:, None]
    return y, {"C": state[0], "n": state[1], "m": state[2]}


def slstm_specs(cfg: ModelConfig, pfx: str):
    d = cfg.d_model
    h = cfg.n_heads
    e = d // h
    return {
        f"{pfx}.w_gates": ParamSpec((d, h, 4, e), fsdp_dim=0),
        f"{pfx}.g_bias": ParamSpec((h, 4, e), "zeros"),
        f"{pfx}.w_out": ParamSpec((d, d), fsdp_dim=0),
    }


def apply_slstm(t: Tape, ctx: LayerCtx, pfx: str, x: TVal) -> TVal:
    g = t.dense(x, f"{pfx}.w_gates", "bsd,dhge->bshge")

    def core(g_bias, gv):
        gv = gv + g_bias
        # reorder to [b, s, h, 4, e]
        gv = jnp.einsum("bshge->bshge", gv)
        return ops.slstm_scan(gv)

    y = t.prim(core, g, pnames=(f"{pfx}.g_bias",))
    y = t.prim(lambda v: v.reshape(v.shape[0], v.shape[1], -1), y)
    return t.dense(y, f"{pfx}.w_out", "bsd,de->bse")


def slstm_decode(ctx, params, pfx, x, cache, pos):
    g = jnp.einsum("bsd,dhge->bshge", x, params[f"{pfx}.w_gates"])
    g = (g + params[f"{pfx}.g_bias"])[:, 0]  # [b, h, 4, e]
    state = (cache["c"], cache["n"], cache["m"])
    y, state = ops.slstm_scan(g[:, None], state=state, return_state=True)
    y = y[:, 0].reshape(x.shape[0], -1)
    y = jnp.einsum("bd,de->be", y, params[f"{pfx}.w_out"])[:, None]
    return y, {"c": state[0], "n": state[1], "m": state[2]}


# --------------------------------------------------------------------------- #
# Unified cached execution (prefill s>1 / decode s=1) for serving
# --------------------------------------------------------------------------- #


def _rope_slice(ctx, e, pos, s):
    cos, sin = ctx.rope[e]  # full tables [max_seq, e/2]
    if getattr(pos, "ndim", 0):  # per-slot [b] positions -> [b, s, e/2]
        return jax.vmap(lambda p: (
            jax.lax.dynamic_slice_in_dim(cos, p, s, 0),
            jax.lax.dynamic_slice_in_dim(sin, p, s, 0)))(pos)
    return (jax.lax.dynamic_slice_in_dim(cos, pos, s, 0),
            jax.lax.dynamic_slice_in_dim(sin, pos, s, 0))


def _slot_scatter(ctx, cache_arr, new, pos):
    """Write ``new`` [b, s, ...] into ``cache_arr`` [b, S, ...] at the
    per-row position ``pos`` [b], honouring ``ctx.slot_mask``: masked-off
    rows keep their cache bytes untouched (their window is read back and
    rewritten unchanged), so a prefill into one slot can never clobber a
    neighbouring in-flight request."""
    s = new.shape[1]
    mask = ctx.slot_mask
    if mask is None:
        mask = jnp.ones((new.shape[0],), bool)

    def upd(row, new_row, p, m):
        old = jax.lax.dynamic_slice_in_dim(row, p, s, 0)
        win = jnp.where(m, new_row.astype(row.dtype), old)
        return jax.lax.dynamic_update_slice_in_dim(row, win, p, 0)

    return jax.vmap(upd)(cache_arr, new, pos, mask)


def _paged_gather(ctx, pool, scale=None, width=None):
    """Assemble each row's K/V window from the shared page pool.

    pool: [n_pages_loc, ps, ...]; ctx.page_tables: [b, ppr] local page
    ids. Returns [b, ppr*ps, ...] — same shape and same values at every
    causally-visible position as the contiguous per-row cache, so the
    attention that follows is bitwise identical to the slotted path.
    Sentinel table entries (unreserved tail) drag in arbitrary live
    pages; every such position sits beyond the row's causal offset and
    is masked to exact -inf before the softmax.

    ``scale`` (int8 pools): [n_pages_loc, ...head-dims] per-page dequant
    scales — the gather dequantizes to f32 with the exact per-element
    product the Pallas paged kernel computes in-kernel.
    """
    pt = jnp.clip(ctx.page_tables, 0, pool.shape[0] - 1)
    g = jnp.take(pool, pt, axis=0)            # [b, ppr, ps, ...]
    if scale is not None:
        sg = jnp.take(scale.astype(jnp.float32), pt, axis=0)
        sg = sg.reshape(sg.shape[:2] + (1,) + sg.shape[2:] + (1,))
        g = g.astype(jnp.float32) * sg
    g = g.reshape((pt.shape[0], -1) + pool.shape[2:])
    if width is not None and g.shape[1] != width:
        g = g[:, :width]
    return g


def _paged_scatter(ctx, pool, new, pos, scale=None):
    """Write ``new`` [b, s, ...] into the page pool at each row's
    absolute positions ``pos + [0, s)``, routed through its page table.
    Masked-off rows (``ctx.slot_mask``) are redirected out of bounds and
    dropped — the paged analogue of :func:`_slot_scatter`'s read-back.
    Rows never share writable pages (shared prefix pages are read-only
    by construction and prefill resumes past them), so the flat indices
    are collision-free. Returns ``(pool, scale)``.

    With ``scale`` (int8 pages, [n_loc, ...head-dims] f32): per-page
    scales only ever grow (scatter-max of amax/127), existing page
    content is requantized by the old/new ratio — exactly 1.0 for every
    untouched page, so shared prefix pages stay bitwise stable — and the
    incoming tokens are quantized with their page's updated scale.
    """
    b, s = new.shape[:2]
    ps = ctx.page_size
    n_loc = pool.shape[0]
    t = pos[:, None] + jnp.arange(s, dtype=pos.dtype)[None]   # [b, s]
    page = jnp.take_along_axis(ctx.page_tables, t // ps, axis=1)
    mask = ctx.slot_mask
    if mask is None:
        mask = jnp.ones((b,), bool)
    page = jnp.where(mask[:, None], page, n_loc)  # OOB -> dropped
    flat = page * ps + t % ps
    if scale is not None:
        nf = new.astype(jnp.float32)
        # per-token amax at the scale granularity: [b, s] + scale dims
        amax = jnp.abs(nf).max(axis=-1)
        scale_new = scale.at[page.reshape(-1)].max(
            (amax / 127.0).reshape((-1,) + scale.shape[1:]), mode="drop")
        # requantize existing bytes where this write grew a page's scale
        # (ratio is exactly 1.0 everywhere else — identity round-trip)
        ratio = jnp.where(scale_new > 0,
                          scale / jnp.maximum(scale_new, 1e-30), 1.0)
        ratio = ratio.reshape((n_loc, 1) + scale.shape[1:] + (1,))
        pool = jnp.clip(jnp.round(pool.astype(jnp.float32) * ratio),
                        -127, 127).astype(pool.dtype)
        # quantize the incoming tokens with their page's final scale
        sc_tok = scale_new[jnp.clip(page, 0, n_loc - 1).reshape(-1)]
        sc_tok = sc_tok.reshape((b, s) + scale.shape[1:])[..., None]
        new = jnp.clip(jnp.round(nf / jnp.maximum(sc_tok, 1e-30)),
                       -127, 127)
        scale = scale_new
    pool_flat = pool.reshape((n_loc * ps,) + pool.shape[2:])
    pool_flat = pool_flat.at[flat.reshape(-1)].set(
        new.reshape((b * s,) + new.shape[2:]).astype(pool.dtype),
        mode="drop")
    return pool_flat.reshape(pool.shape), scale


def _slot_state(ctx, old, new):
    """Per-row select for positionless (recurrent) caches: masked-off rows
    keep their previous state. No-op without a slot mask (legacy path)."""
    mask = ctx.slot_mask
    if mask is None:
        return new
    out = {}
    for n, v in new.items():
        m = mask.reshape((-1,) + (1,) * (v.ndim - 1))
        out[n] = jnp.where(m, v.astype(old[n].dtype), old[n])
    return out


def attn_cached(ctx: LayerCtx, params, pfx, x, cache, pos):
    """x: [b, s, d]; cache k/v: [b, S, g, e]; pos: first absolute position.

    ``pos`` may be a [b] vector (slotted serving): each row scatters into
    its cache at its own position, writes gated by ``ctx.slot_mask``, and
    attends with a per-row causal offset.

    s == 1 with ctx.kv_seq_shard uses flash-decoding combine over "data"
    (the 500k-context path: the KV cache is sequence-sharded).
    """
    cfg = ctx.cfg
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, params[f"{pfx}.wq"])
    k = jnp.einsum("bsd,dge->bsge", x, params[f"{pfx}.wk"])
    v = jnp.einsum("bsd,dge->bsge", x, params[f"{pfx}.wv"])
    cos, sin = _rope_slice(ctx, cfg.head_dim, pos, s)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if ctx.page_tables is not None:
        # paged KV: scatter this step's K/V through the page tables
        # (quantizing when the pool is int8), then attend straight out
        # of the pool — the page-table-native kernel (or its jnp mirror)
        # applies the per-row causal offset and sentinel masking itself.
        # The pool (not a per-row window) is the cache state.
        ksc, vsc = cache.get("k_scale"), cache.get("v_scale")
        kp, ksc = _paged_scatter(ctx, cache["k"], k, pos, ksc)
        vp, vsc = _paged_scatter(ctx, cache["v"], v, pos, vsc)
        o = ops.paged_attention(
            q, kp, vp, page_tables=ctx.page_tables, pos=pos,
            k_scale=ksc, v_scale=vsc, slot_mask=ctx.slot_mask,
            block_k=ctx.rc.attn_block_k, impl=ctx.rc.kernel_impl)
        cache = {"k": kp, "v": vp}
        if ksc is not None:
            cache["k_scale"], cache["v_scale"] = ksc, vsc
    elif getattr(pos, "ndim", 0):
        kc = _slot_scatter(ctx, cache["k"], k, pos)
        vc = _slot_scatter(ctx, cache["v"], v, pos)
        o = ops.attention(q, kc, vc, causal=True, q_offset=pos,
                          block_k=ctx.rc.attn_block_k,
                          impl=ctx.rc.kernel_impl)
        cache = {"k": kc, "v": vc}
    elif getattr(ctx, "kv_seq_shard", False):
        # cache local window [b, S/D, g, e]; only the owner of `pos` writes
        dsz = ctx.kv_shards
        S_loc = cache["k"].shape[1]
        r = jax.lax.axis_index("data")
        lo = r * S_loc
        in_win = (pos >= lo) & (pos < lo + S_loc)
        off = jnp.clip(pos - lo, 0, S_loc - 1)
        k_old = jax.lax.dynamic_slice_in_dim(cache["k"], off, s, axis=1)
        v_old = jax.lax.dynamic_slice_in_dim(cache["v"], off, s, axis=1)
        kc = jax.lax.dynamic_update_slice(
            cache["k"], jnp.where(in_win, k, k_old).astype(
                cache["k"].dtype), (0, off, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], jnp.where(in_win, v, v_old).astype(
                cache["v"].dtype), (0, off, 0, 0))
        # local partial attention with global positions
        n_valid = jnp.clip(pos + s - lo, 0, S_loc)
        _, (m, l, acc) = ops.decode_attention(q, kc, vc, cache_len=n_valid,
                                              impl=ctx.rc.kernel_impl)
        # combine across shards: psum-logsumexp (all data ranks aligned)
        m_g = jax.lax.pmax(m, "data")
        m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        w_ = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_g = jax.lax.psum(l * w_, "data")
        acc_g = jax.lax.psum(acc * w_[..., None], "data")
        o = (acc_g / jnp.maximum(l_g, 1e-30)[..., None])
        o = jnp.einsum("bhqe->bqhe", o).astype(x.dtype)
        cache = {"k": kc, "v": vc}
    else:
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        o = ops.attention(q, kc, vc, causal=True, q_offset=pos,
                          block_k=ctx.rc.attn_block_k,
                          impl=ctx.rc.kernel_impl)
        cache = {"k": kc, "v": vc}
    y = jnp.einsum("bshe,hed->bsd", o, params[f"{pfx}.wo"])
    return y, cache


def mla_cached(ctx, params, pfx, x, cache, pos):
    cfg = ctx.cfg
    m: MLACfg = cfg.mla
    b, s, d = x.shape
    cq = jnp.einsum("bsd,dr->bsr", x, params[f"{pfx}.wdq"])
    cqf = cq.astype(jnp.float32)
    cq = (cqf * jax.lax.rsqrt(jnp.mean(cqf * cqf, -1, keepdims=True) + 1e-6)
          * params[f"{pfx}.qnorm.scale"]).astype(x.dtype)
    q = jnp.einsum("bsr,rhe->bshe", cq, params[f"{pfx}.wuq"])
    ckv = jnp.einsum("bsd,dc->bsc", x, params[f"{pfx}.wdkv"])
    ckv_sc = None
    if ctx.page_tables is not None:  # paged latent cache
        # MLA always gathers the latent pages (the up-projection makes
        # dense K/V before attention), so int8 dequant happens here —
        # identically under both kernel implementations — and only the
        # attention after routes through the slot-aware Pallas kernel.
        ckv_sc = cache.get("ckv_scale")
        cache_new, ckv_sc = _paged_scatter(ctx, cache["ckv"], ckv, pos,
                                           ckv_sc)
        full = _paged_gather(ctx, cache_new, ckv_sc)
        if ckv_sc is not None:
            full = full.astype(x.dtype)
    elif getattr(pos, "ndim", 0):  # per-slot positions (slotted serving)
        cache_new = _slot_scatter(ctx, cache["ckv"], ckv, pos)
        full = cache_new
    else:
        cache_new = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
        full = cache_new
    c_kv, k_rope = full[..., : m.kv_lora], full[..., m.kv_lora:]
    cf = c_kv.astype(jnp.float32)
    c_kv = (cf * jax.lax.rsqrt(jnp.mean(cf * cf, -1, keepdims=True) + 1e-6)
            * params[f"{pfx}.kvnorm.scale"]).astype(x.dtype)
    k_nope = jnp.einsum("bsc,che->bshe", c_kv, params[f"{pfx}.wuk"])
    vv = jnp.einsum("bsc,che->bshe", c_kv, params[f"{pfx}.wuv"])
    cos_q, sin_q = _rope_slice(ctx, m.rope_dims, pos, s)
    cos_k, sin_k = ctx.rope[m.rope_dims]
    q_nope, q_rope = q[..., : m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope, cos_q, sin_q)
    k_rope = apply_rope(k_rope[:, :, None, :], cos_k[: full.shape[1]],
                        sin_k[: full.shape[1]])
    k_rope = jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.rope_dims,))
    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope, k_rope], -1)
    o = ops.attention(qf, kf, vv, causal=True, q_offset=pos,
                      block_k=ctx.rc.attn_block_k,
                      impl=ctx.rc.kernel_impl)
    y = jnp.einsum("bshe,hed->bsd", o, params[f"{pfx}.wo"])
    out_cache = {"ckv": cache_new}
    if ckv_sc is not None:
        out_cache["ckv_scale"] = ckv_sc
    return y, out_cache


def mamba_cached(ctx, params, pfx, x, cache, pos):
    """Prefill runs the chunked scan (state out); decode steps the SSM."""
    cfg = ctx.cfg
    mc, di, dt_rank = _mamba_dims(cfg)
    n = mc.d_state
    b, s, d = x.shape
    if s == 1:
        return mamba_decode(ctx, params, pfx, x, cache, pos)
    xz = jnp.einsum("bsd,de->bse", x, params[f"{pfx}.w_in"])
    xs, z = xz[..., :di], xz[..., di:]
    pad = jnp.pad(xs, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    cw = params[f"{pfx}.conv_w"]
    out = sum(pad[:, i: i + s] * cw[i][None, None]
              for i in range(mc.d_conv)) + params[f"{pfx}.conv_b"]
    xs_c = jax.nn.silu(out)
    bcdt = jnp.einsum("bse,er->bsr", xs_c, params[f"{pfx}.w_x"])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", bcdt[..., :dt_rank], params[f"{pfx}.w_dt"])
        + params[f"{pfx}.dt_bias"]).astype(jnp.float32)
    Bm = bcdt[..., dt_rank: dt_rank + n].astype(jnp.float32)
    Cm = bcdt[..., dt_rank + n:].astype(jnp.float32)
    A = -jnp.exp(params[f"{pfx}.A_log"].astype(jnp.float32))
    y, h = ops.selective_scan(
        xs_c.astype(jnp.float32), dt, A, Bm, Cm,
        params[f"{pfx}.Dd"].astype(jnp.float32), return_state=True)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", y, params[f"{pfx}.w_out"])
    conv_state = xs[:, -(mc.d_conv - 1):]
    return y, {"conv": conv_state.astype(cache["conv"].dtype), "h": h}


def mlstm_cached(ctx, params, pfx, x, cache, pos):
    cfg = ctx.cfg
    if x.shape[1] == 1:
        return mlstm_decode(ctx, params, pfx, x, cache, pos)
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    up = jnp.einsum("bsd,de->bse", x, params[f"{pfx}.w_up"])
    xb, z = up[..., :di], up[..., di:]
    q = jnp.einsum("bse,ehf->bshf", xb, params[f"{pfx}.wq"])
    k = jnp.einsum("bse,ehf->bshf", xb, params[f"{pfx}.wk"])
    v = jnp.einsum("bse,ehf->bshf", xb, params[f"{pfx}.wv"])
    gates = jnp.einsum("bse,egh->bsgh", xb.astype(jnp.float32),
                       params[f"{pfx}.w_if"].astype(jnp.float32))
    gates = gates + params[f"{pfx}.if_bias"]
    ig, fg = gates[:, :, 0], gates[:, :, 1] + 1.0
    y, state = ops.mlstm_chunkwise(q, k, v, ig, fg, return_state=True)
    y = y.reshape(y.shape[0], y.shape[1], -1)
    y = (y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    y = jnp.einsum("bse,ed->bsd", y.astype(x.dtype),
                   params[f"{pfx}.w_out"])
    return y, {"C": state[0], "n": state[1], "m": state[2]}


def slstm_cached(ctx, params, pfx, x, cache, pos):
    if x.shape[1] == 1:
        return slstm_decode(ctx, params, pfx, x, cache, pos)
    g = jnp.einsum("bsd,dhge->bshge", x, params[f"{pfx}.w_gates"])
    g = g + params[f"{pfx}.g_bias"]
    state = (cache["c"], cache["n"], cache["m"])
    y, state = ops.slstm_scan(g, state=state, return_state=True)
    y = y.reshape(x.shape[0], x.shape[1], -1).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", y, params[f"{pfx}.w_out"])
    return y, {"c": state[0], "n": state[1], "m": state[2]}
